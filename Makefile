# Convenience targets. `artifacts` is the optional PJRT compile path
# (python/compile/README.md); everything Rust goes through cargo directly.

ARTIFACTS_DIR ?= artifacts

.PHONY: build test bench artifacts clean

build:
	cargo build --release

test:
	cargo test -q

bench:
	cargo bench --bench microbench

# Lower the jax/Pallas kernels + model forwards to HLO-text artifacts
# consumed by `--features pjrt` builds (requires a Python env with jax).
artifacts:
	python3 -m python.compile.aot --out-dir $(ARTIFACTS_DIR)

clean:
	cargo clean
	rm -rf $(ARTIFACTS_DIR)
