"""AOT lowering: jax functions → HLO *text* artifacts + manifest.toml.

HLO text (NOT `.serialize()`): jax ≥ 0.5 emits HloModuleProtos with 64-bit
instruction ids that the runtime's xla_extension 0.5.1 rejects; the text
parser reassigns ids and round-trips cleanly (rust/DESIGN.md §4).

Run via `make artifacts` (no-op when inputs are unchanged). Python never
runs at request time — the Rust binary is self-contained afterwards.
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants=True is load-bearing: the default ELIDES big
    # literals as `constant({...})`, which the runtime's HLO-text parser
    # silently reads back as zeros — the baked model weights would vanish.
    return comp.as_hlo_text(print_large_constants=True)


def shape_sig(shapes) -> str:
    return ";".join("x".join(str(d) for d in s) for s in shapes)


# Artifact catalogue. Sizes are kept modest so the CPU PJRT compile in the
# Rust tests stays fast; shapes are the "scaled testbed" defaults used
# throughout (s=256, d=128, 8 hp tokens = effective 4.125 bits).
S, D, DFF, NLAYERS, HP = 256, 128, 256, 2, 8


def build_artifacts():
    key = jax.random.PRNGKey(0)
    params = model.init_params(key, D, DFF, NLAYERS)

    def qdq_fn(x):
        return (model.stamp_qdq(x, levels=3, hp_tokens=HP, hp_bits=8, lp_bits=4),)

    def stamp_linear_fn(x, w):
        from .kernels import stamp_linear as sl

        return (sl.stamp_linear(x, w, None, levels=3, hp_tokens=HP, hp_bits=8, lp_bits=4),)

    def model_fp_fn(x):
        return (model.model_fwd(params, x, quantize=False),)

    def model_stamp_fn(x):
        return (
            model.model_fwd(
                params, x, quantize=True, levels=3, hp_tokens=HP, hp_bits=8, lp_bits=4
            ),
        )

    f32 = jnp.float32
    return {
        "stamp_qdq": (qdq_fn, [jax.ShapeDtypeStruct((S, D), f32)]),
        "stamp_linear": (
            stamp_linear_fn,
            [jax.ShapeDtypeStruct((S, D), f32), jax.ShapeDtypeStruct((D, D), f32)],
        ),
        "model_fp": (model_fp_fn, [jax.ShapeDtypeStruct((S, D), f32)]),
        "model_stamp": (model_stamp_fn, [jax.ShapeDtypeStruct((S, D), f32)]),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest_lines = []
    for name, (fn, specs) in build_artifacts().items():
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(args.out_dir, fname), "w") as f:
            f.write(text)
        # Output shapes from the lowered signature.
        out_shapes = [tuple(s.shape) for s in jax.eval_shape(fn, *specs)]
        in_sig = shape_sig([tuple(s.shape) for s in specs])
        out_sig = shape_sig(out_shapes)
        manifest_lines.append(
            f"[artifact.{name}]\nfile = \"{fname}\"\ninputs = \"{in_sig}\"\noutputs = \"{out_sig}\"\n"
        )
        print(f"wrote {fname} ({len(text)} chars) inputs={in_sig} outputs={out_sig}")

    with open(os.path.join(args.out_dir, "manifest.toml"), "w") as f:
        f.write("\n".join(manifest_lines))
    print(f"wrote manifest.toml with {len(manifest_lines)} artifacts")


if __name__ == "__main__":
    main()
