"""L2: the JAX model — a transformer block stack whose linear layers run
through the fused L1 STaMP kernel. `aot.py` lowers the functions here to
HLO text once; the Rust runtime executes them forever after.

The model mirrors rust/src/model/gpt.rs's Block (RMSNorm → MHA → RMSNorm →
gated MLP) over a pre-embedded activation matrix `x: f32[s, d]`, so the
same artifact serves both the LLM- and LVM-shaped serving paths.
"""

import functools

import jax
import jax.numpy as jnp

from .kernels import quant as qk
from .kernels import stamp_linear as sl


def init_params(key, d_model, d_ff, n_layers):
    """Deterministic parameter pytree for the AOT model."""
    params = []
    for i in range(n_layers):
        k = jax.random.fold_in(key, i)
        ks = jax.random.split(k, 8)
        scale = 1.0 / jnp.sqrt(d_model)
        params.append(
            {
                "g1": jnp.ones((d_model,), jnp.float32),
                "wq": jax.random.normal(ks[0], (d_model, d_model), jnp.float32) * scale,
                "wk": jax.random.normal(ks[1], (d_model, d_model), jnp.float32) * scale,
                "wv": jax.random.normal(ks[2], (d_model, d_model), jnp.float32) * scale,
                "wo": jax.random.normal(ks[3], (d_model, d_model), jnp.float32) * scale,
                "g2": jnp.ones((d_model,), jnp.float32),
                "wu": jax.random.normal(ks[4], (d_model, d_ff), jnp.float32) * scale,
                "wg": jax.random.normal(ks[5], (d_model, d_ff), jnp.float32) * scale,
                "wd": jax.random.normal(ks[6], (d_ff, d_model), jnp.float32)
                * (1.0 / jnp.sqrt(d_ff)),
            }
        )
    return params


def rmsnorm(x, gamma, eps=1e-5):
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ms + eps) * gamma


def attention(q, k, v, n_heads, causal=True):
    s, d = q.shape
    dh = d // n_heads
    q = q.reshape(s, n_heads, dh).transpose(1, 0, 2)
    k = k.reshape(s, n_heads, dh).transpose(1, 0, 2)
    v = v.reshape(s, n_heads, dh).transpose(1, 0, 2)
    scores = jnp.einsum("hid,hjd->hij", q, k) / jnp.sqrt(dh).astype(q.dtype)
    if causal:
        mask = jnp.tril(jnp.ones((s, s), bool))
        scores = jnp.where(mask[None], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("hij,hjd->hid", probs, v)
    return out.transpose(1, 0, 2).reshape(s, d)


def _linear(x, w, quantize, **stamp_kw):
    """Linear layer: fused STaMP kernel when quantizing, plain dot in FP."""
    if quantize:
        return sl.stamp_linear(x, w, None, **stamp_kw)
    return x @ w


def block_fwd(p, x, n_heads, quantize, **stamp_kw):
    h = rmsnorm(x, p["g1"])
    q = _linear(h, p["wq"], quantize, **stamp_kw)
    k = _linear(h, p["wk"], quantize, **stamp_kw)
    v = _linear(h, p["wv"], quantize, **stamp_kw)
    a = _linear(attention(q, k, v, n_heads), p["wo"], quantize, **stamp_kw)
    x = x + a
    h = rmsnorm(x, p["g2"])
    u = _linear(h, p["wu"], quantize, **stamp_kw)
    g = _linear(h, p["wg"], quantize, **stamp_kw)
    m = _linear(jax.nn.silu(g) * u, p["wd"], quantize, **stamp_kw)
    return x + m


def model_fwd(params, x, n_heads=4, quantize=True, **stamp_kw):
    """Full block-stack forward over a pre-embedded activation matrix."""
    for p in params:
        x = block_fwd(p, x, n_heads, quantize, **stamp_kw)
    return x


def stamp_qdq(x, levels=3, hp_tokens=64, hp_bits=8, lp_bits=4):
    """Standalone STaMP QDQ: L^-1(Q_mixed(L x)) — the activation-only path
    used by the eval/serving artifacts."""
    from .kernels import haar

    lx = haar.haar_dwt(x, levels)
    q = qk.qdq(lx, hp_tokens, hp_bits, lp_bits)
    return haar.haar_idwt(q, levels)
