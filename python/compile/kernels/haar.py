"""L1 Pallas kernel: multi-level Haar DWT along the sequence dimension.

TPU mapping (rust/DESIGN.md §9, hardware adaptation): the grid tiles the *feature*
dimension so each grid step streams an (s × D_TILE) panel HBM→VMEM, runs
ALL `levels` butterfly steps on the resident panel, and writes back once —
one HBM round-trip instead of `levels` (the paper's memory-layout-aware
CUDA kernel, rethought for VMEM). The sequence dimension stays whole inside
the block because every level's butterfly is a strided add/sub over it.

interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; real-TPU perf is estimated analytically in
rust/EXPERIMENTS.md §Hardware notes.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INV_SQRT2 = 0.7071067811865476

# Feature-tile width. 128 matches the TPU lane width; VMEM footprint per
# block = s × 128 × 4 B ≈ 1 MiB at s = 2048 — comfortably resident.
D_TILE = 128


def _dwt_kernel(x_ref, o_ref, *, levels):
    buf = x_ref[...]
    n = buf.shape[0]
    for _ in range(levels):
        head = buf[:n]
        even = head[0::2]
        odd = head[1::2]
        approx = (even + odd) * INV_SQRT2
        detail = (even - odd) * INV_SQRT2
        buf = jnp.concatenate([approx, detail, buf[n:]], axis=0)
        n //= 2
    o_ref[...] = buf


def _idwt_kernel(y_ref, o_ref, *, levels):
    buf = y_ref[...]
    s = buf.shape[0]
    n = s >> (levels - 1)
    for _ in range(levels):
        half = n // 2
        approx = buf[:half]
        detail = buf[half:n]
        even = (approx + detail) * INV_SQRT2
        odd = (approx - detail) * INV_SQRT2
        inter = jnp.stack([even, odd], axis=1).reshape((n, buf.shape[1]))
        buf = jnp.concatenate([inter, buf[n:]], axis=0)
        n *= 2
    o_ref[...] = buf


def _tiled_call(kernel, x, levels):
    s, d = x.shape
    assert s % (1 << levels) == 0, f"seq {s} not divisible by 2^{levels}"
    d_tile = min(D_TILE, d)
    assert d % d_tile == 0, f"feature dim {d} not divisible by tile {d_tile}"
    return pl.pallas_call(
        functools.partial(kernel, levels=levels),
        out_shape=jax.ShapeDtypeStruct((s, d), x.dtype),
        grid=(d // d_tile,),
        in_specs=[pl.BlockSpec((s, d_tile), lambda i: (0, i))],
        out_specs=pl.BlockSpec((s, d_tile), lambda i: (0, i)),
        interpret=True,
    )(x)


def haar_dwt(x, levels):
    """Forward multi-level Haar DWT (Pallas)."""
    return _tiled_call(_dwt_kernel, x, levels)


def haar_idwt(y, levels):
    """Inverse multi-level Haar DWT (Pallas)."""
    return _tiled_call(_idwt_kernel, y, levels)
