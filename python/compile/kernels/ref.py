"""Pure-jnp reference oracles for every L1 Pallas kernel.

These are the CORE correctness signal: pytest (and hypothesis sweeps)
compare each Pallas kernel's interpret-mode output against these, and the
Rust side's unit tests implement the same math independently, so the three
implementations (jnp / Pallas / Rust) triangulate each other.
"""

import jax.numpy as jnp

INV_SQRT2 = 0.7071067811865476


def haar_dwt_ref(x, levels):
    """Multi-level orthonormal Haar DWT along axis 0.

    Output layout: [approx_L | detail_L | ... | detail_1] — identical to
    rust/src/transforms/haar.rs.
    """
    s = x.shape[0]
    assert s % (1 << levels) == 0, f"{s} not divisible by 2^{levels}"
    buf = x
    n = s
    for _ in range(levels):
        head = buf[:n]
        even = head[0::2]
        odd = head[1::2]
        approx = (even + odd) * INV_SQRT2
        detail = (even - odd) * INV_SQRT2
        buf = jnp.concatenate([approx, detail, buf[n:]], axis=0)
        n //= 2
    return buf


def haar_idwt_ref(y, levels):
    """Inverse of :func:`haar_dwt_ref`."""
    s = y.shape[0]
    buf = y
    n = s >> (levels - 1)
    for _ in range(levels):
        half = n // 2
        approx = buf[:half]
        detail = buf[half:n]
        even = (approx + detail) * INV_SQRT2
        odd = (approx - detail) * INV_SQRT2
        inter = jnp.stack([even, odd], axis=1).reshape((n,) + y.shape[1:])
        buf = jnp.concatenate([inter, buf[n:]], axis=0)
        n *= 2
    return buf


def qdq_ref(x, hp_tokens, hp_bits, lp_bits):
    """Per-token asymmetric min-max fake-quant with 2-level mixed precision.

    Token i uses hp_bits when i < hp_tokens else lp_bits (paper Eq. 1 +
    the §3.3 two-level scheme). Matches rust/src/quant/qdq.rs.
    """
    s = x.shape[0]
    mn = x.min(axis=1, keepdims=True)
    mx = x.max(axis=1, keepdims=True)
    bits = jnp.where(jnp.arange(s)[:, None] < hp_tokens, hp_bits, lp_bits)
    qmax = 2.0 ** bits.astype(x.dtype) - 1.0
    scale = jnp.maximum(mx - mn, 1e-12) / qmax
    zero = jnp.round(-mn / scale)
    q = jnp.clip(jnp.round(x / scale + zero), 0.0, qmax)
    return (q - zero) * scale


def stamp_linear_ref(x, w, bias, levels, hp_tokens, hp_bits, lp_bits):
    """Figure-2a pseudocode: Y = L^-1( Q_mixed(L X) W ) + 1 b^T."""
    lx = haar_dwt_ref(x, levels)
    q = qdq_ref(lx, hp_tokens, hp_bits, lp_bits)
    y = q @ w
    out = haar_idwt_ref(y, levels)
    if bias is not None:
        out = out + bias[None, :]
    return out


def dct_matrix(s, dtype=jnp.float32):
    """Orthonormal DCT-II matrix (rows = basis vectors)."""
    import numpy as np

    n = np.arange(s, dtype=np.float64)
    k = np.arange(s, dtype=np.float64)[:, None]
    m = np.cos(np.pi / s * (n[None, :] + 0.5) * k)
    norm = np.where(k == 0, np.sqrt(1.0 / s), np.sqrt(2.0 / s))
    return jnp.asarray(norm * m, dtype=dtype)


def wht_matrix(s, dtype=jnp.float32):
    """Sequency-ordered orthonormal Walsh-Hadamard matrix."""
    assert s & (s - 1) == 0, "power of two required"
    import numpy as np

    h = np.ones((1, 1))
    while h.shape[0] < s:
        h = np.block([[h, h], [h, -h]])
    # Sequency order = sort rows by sign-change count.
    changes = (np.diff(np.sign(h), axis=1) != 0).sum(axis=1)
    order = np.argsort(changes, kind="stable")
    return jnp.asarray(h[order] / np.sqrt(s), dtype=dtype)
