"""L1 Pallas kernel: per-token min-max fake-quant with 2-level mixed
precision (paper Eq. 1 + §3.3).

The grid tiles the *sequence* dimension (each token's min/max reduction
needs its whole feature row resident), S_TILE tokens per block. The
hp/lp bit decision is made from the global token index via the block
program id, so mixed precision costs zero extra memory traffic.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Token-tile height: 128 tokens × d ≤ 1024 × 4 B = 512 KiB VMEM worst case.
S_TILE = 128


def _qdq_kernel(x_ref, o_ref, *, s_tile, hp_tokens, hp_bits, lp_bits):
    i = pl.program_id(0)
    x = x_ref[...]
    mn = x.min(axis=1, keepdims=True)
    mx = x.max(axis=1, keepdims=True)
    token_idx = i * s_tile + jnp.arange(x.shape[0])[:, None]
    qmax = jnp.where(
        token_idx < hp_tokens,
        jnp.float32(2.0**hp_bits - 1.0),
        jnp.float32(2.0**lp_bits - 1.0),
    ).astype(x.dtype)
    scale = jnp.maximum(mx - mn, 1e-12) / qmax
    zero = jnp.round(-mn / scale)
    q = jnp.clip(jnp.round(x / scale + zero), 0.0, qmax)
    o_ref[...] = (q - zero) * scale


def qdq(x, hp_tokens, hp_bits, lp_bits):
    """Quantize-dequantize with per-token min-max scales (Pallas)."""
    s, d = x.shape
    s_tile = min(S_TILE, s)
    assert s % s_tile == 0, f"seq {s} not divisible by tile {s_tile}"
    return pl.pallas_call(
        functools.partial(
            _qdq_kernel,
            s_tile=s_tile,
            hp_tokens=hp_tokens,
            hp_bits=hp_bits,
            lp_bits=lp_bits,
        ),
        out_shape=jax.ShapeDtypeStruct((s, d), x.dtype),
        grid=(s // s_tile,),
        in_specs=[pl.BlockSpec((s_tile, d), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((s_tile, d), lambda i: (i, 0)),
        interpret=True,
    )(x)
