"""L1 Pallas kernel: the fused STaMP linear layer (Figure 2a).

One kernel computes `Q_mixed(L X) @ W` — the sequence transform, the
mixed-precision QDQ, and the MXU matmul — so the transformed activation
never round-trips to HBM in fp. `L^-1` is applied by a second (cheap, O(sd))
DWT-inverse kernel after the matmul, exactly the Eq. 7 placement.

TPU mapping: grid over output-column tiles (N_TILE = 128, MXU-aligned);
each grid step keeps the full (s × d) activation panel in VMEM (s·d ≤
256 × 512 ⇒ ≤ 512 KiB), re-uses the transformed+quantized panel across
output tiles via the index_map returning the same block, and streams one
(d × N_TILE) weight panel per step.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import haar

INV_SQRT2 = 0.7071067811865476
N_TILE = 128


def _fused_kernel(x_ref, w_ref, o_ref, *, levels, hp_tokens, hp_bits, lp_bits):
    x = x_ref[...]
    # --- L X: all DWT levels on the resident panel ---
    n = x.shape[0]
    buf = x
    for _ in range(levels):
        head = buf[:n]
        even = head[0::2]
        odd = head[1::2]
        buf = jnp.concatenate(
            [(even + odd) * INV_SQRT2, (even - odd) * INV_SQRT2, buf[n:]], axis=0
        )
        n //= 2
    # --- Q_mixed ---
    mn = buf.min(axis=1, keepdims=True)
    mx = buf.max(axis=1, keepdims=True)
    token_idx = jnp.arange(buf.shape[0])[:, None]
    qmax = jnp.where(
        token_idx < hp_tokens,
        jnp.float32(2.0**hp_bits - 1.0),
        jnp.float32(2.0**lp_bits - 1.0),
    ).astype(buf.dtype)
    scale = jnp.maximum(mx - mn, 1e-12) / qmax
    zero = jnp.round(-mn / scale)
    q = jnp.clip(jnp.round(buf / scale + zero), 0.0, qmax)
    deq = (q - zero) * scale
    # --- MXU matmul with the resident weight tile ---
    o_ref[...] = jnp.dot(deq, w_ref[...], preferred_element_type=jnp.float32)


def stamp_linear(x, w, bias, *, levels=3, hp_tokens=64, hp_bits=8, lp_bits=4):
    """Fused STaMP-quantized linear: `L^-1(Q(LX) W) + b`."""
    s, d = x.shape
    d2, n = w.shape
    assert d == d2, f"shape mismatch {x.shape} @ {w.shape}"
    n_tile = min(N_TILE, n)
    assert n % n_tile == 0
    y = pl.pallas_call(
        functools.partial(
            _fused_kernel,
            levels=levels,
            hp_tokens=hp_tokens,
            hp_bits=hp_bits,
            lp_bits=lp_bits,
        ),
        out_shape=jax.ShapeDtypeStruct((s, n), x.dtype),
        grid=(n // n_tile,),
        in_specs=[
            pl.BlockSpec((s, d), lambda j: (0, 0)),  # activation panel reused
            pl.BlockSpec((d, n_tile), lambda j: (0, j)),
        ],
        out_specs=pl.BlockSpec((s, n_tile), lambda j: (0, j)),
        interpret=True,
    )(x, w)
    out = haar.haar_idwt(y, levels)
    if bias is not None:
        out = out + bias[None, :]
    return out
