"""L1 correctness: every Pallas kernel vs its pure-jnp oracle, with
hypothesis sweeping shapes/levels/bit-widths. This is the core build-time
quality gate (`make test`)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import haar, quant, ref, stamp_linear


def rand(key, shape, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32) * scale


# ---------- Haar DWT ----------


@pytest.mark.parametrize("s,levels", [(8, 1), (64, 3), (256, 3), (128, 7)])
def test_dwt_matches_ref(s, levels):
    x = rand(1, (s, 16))
    got = haar.haar_dwt(x, levels)
    want = ref.haar_dwt_ref(x, levels)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("s,levels", [(16, 2), (256, 3)])
def test_idwt_roundtrip(s, levels):
    x = rand(2, (s, 8))
    y = haar.haar_dwt(x, levels)
    back = haar.haar_idwt(y, levels)
    np.testing.assert_allclose(back, x, rtol=1e-5, atol=1e-5)


def test_dwt_energy_preserved():
    x = rand(3, (128, 32), scale=3.0)
    y = haar.haar_dwt(x, 3)
    assert jnp.allclose(jnp.sum(x * x), jnp.sum(y * y), rtol=1e-5)


def test_dwt_constant_concentrates():
    x = jnp.ones((64, 4))
    y = haar.haar_dwt(x, 6)
    energy = jnp.sum(y * y, axis=1)
    assert energy[0] / jnp.sum(energy) > 0.999


@settings(max_examples=20, deadline=None)
@given(
    log_s=st.integers(3, 8),
    levels=st.integers(1, 3),
    d=st.sampled_from([4, 16, 128, 256]),
    seed=st.integers(0, 2**31 - 1),
)
def test_dwt_hypothesis_sweep(log_s, levels, d, seed):
    s = 1 << log_s
    x = rand(seed, (s, d))
    np.testing.assert_allclose(
        haar.haar_dwt(x, levels), ref.haar_dwt_ref(x, levels), rtol=1e-5, atol=1e-5
    )
    np.testing.assert_allclose(
        haar.haar_idwt(haar.haar_dwt(x, levels), levels), x, rtol=1e-4, atol=1e-4
    )


# ---------- QDQ ----------


@pytest.mark.parametrize("hp_tokens,hp_bits,lp_bits", [(0, 8, 4), (8, 8, 4), (64, 8, 2), (128, 16, 16)])
def test_qdq_matches_ref(hp_tokens, hp_bits, lp_bits):
    x = rand(4, (128, 64), scale=2.0)
    got = quant.qdq(x, hp_tokens, hp_bits, lp_bits)
    want = ref.qdq_ref(x, hp_tokens, hp_bits, lp_bits)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_qdq_high_bits_near_lossless():
    x = rand(5, (64, 32))
    q = quant.qdq(x, 0, 16, 16)
    np.testing.assert_allclose(q, x, atol=2e-4)


def test_qdq_hp_rows_more_accurate():
    x = rand(6, (128, 64))
    q = quant.qdq(x, 64, 8, 2)
    err = jnp.sum((q - x) ** 2, axis=1)
    assert jnp.sum(err[:64]) * 10 < jnp.sum(err[64:])


def test_qdq_rounding_bounded_by_scale():
    x = rand(7, (32, 16))
    q = quant.qdq(x, 0, 4, 4)
    rng = x.max(axis=1, keepdims=True) - x.min(axis=1, keepdims=True)
    step = rng / 15.0
    assert jnp.all(jnp.abs(q - x) <= 0.51 * step + 1e-6)


@settings(max_examples=20, deadline=None)
@given(
    s=st.sampled_from([128, 256]),
    d=st.sampled_from([8, 64, 256]),
    hp=st.integers(0, 128),
    lp_bits=st.integers(2, 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_qdq_hypothesis_sweep(s, d, hp, lp_bits, seed):
    x = rand(seed, (s, d), scale=5.0)
    np.testing.assert_allclose(
        quant.qdq(x, hp, 8, lp_bits), ref.qdq_ref(x, hp, 8, lp_bits), rtol=1e-5, atol=1e-5
    )


# ---------- fused stamp_linear ----------


@pytest.mark.parametrize("s,d,n", [(64, 32, 32), (256, 128, 128), (128, 64, 256)])
def test_stamp_linear_matches_ref(s, d, n):
    x = rand(8, (s, d))
    w = rand(9, (d, n), scale=0.1)
    got = stamp_linear.stamp_linear(x, w, None, levels=3, hp_tokens=8, hp_bits=8, lp_bits=4)
    want = ref.stamp_linear_ref(x, w, None, 3, 8, 8, 4)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_stamp_linear_bias():
    x = rand(10, (64, 32))
    w = rand(11, (32, 32), scale=0.1)
    b = jnp.arange(32, dtype=jnp.float32) * 0.1
    got = stamp_linear.stamp_linear(x, w, b, levels=2, hp_tokens=8, hp_bits=8, lp_bits=4)
    want = ref.stamp_linear_ref(x, w, b, 2, 8, 8, 4)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_stamp_linear_high_bits_equals_fp():
    x = rand(12, (64, 32))
    w = rand(13, (32, 64), scale=0.1)
    got = stamp_linear.stamp_linear(x, w, None, levels=3, hp_tokens=0, hp_bits=16, lp_bits=16)
    np.testing.assert_allclose(got, x @ w, rtol=1e-3, atol=1e-3)


def test_stamp_improves_quant_error_on_smooth_inputs():
    # The headline effect at the kernel level: smooth (locally correlated)
    # inputs quantize better through the DWT at equal low bits.
    t = jnp.linspace(0, 8, 256)[:, None]
    x = jnp.sin(t + jnp.arange(32)[None, :] * 0.3).astype(jnp.float32)
    w = rand(14, (32, 32), scale=0.1)
    fp = x @ w
    plain = ref.qdq_ref(x, 0, 4, 4) @ w
    stamp = stamp_linear.stamp_linear(x, w, None, levels=3, hp_tokens=16, hp_bits=8, lp_bits=4)
    err_plain = float(jnp.sum((plain - fp) ** 2))
    err_stamp = float(jnp.sum((stamp - fp) ** 2))
    assert err_stamp < err_plain, (err_stamp, err_plain)


# ---------- transform matrices (L2 support) ----------


def test_dct_matrix_orthonormal():
    m = ref.dct_matrix(32)
    np.testing.assert_allclose(m @ m.T, jnp.eye(32), atol=1e-5)


def test_wht_matrix_orthonormal_and_sequency():
    m = np.asarray(ref.wht_matrix(16))
    np.testing.assert_allclose(m @ m.T, np.eye(16), atol=1e-6)
    changes = (np.diff(np.sign(m), axis=1) != 0).sum(axis=1)
    assert list(changes) == list(range(16))
