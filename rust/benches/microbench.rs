//! §Perf micro-benchmarks: the L3 hot paths in isolation — QDQ throughput,
//! the packed integer path (quantize + qgemm vs QDQ + f32 matmul),
//! sequence transforms, matmul, autoregressive decode through the KV
//! cache (fp32 vs packed two-level), the coordinator's router/batcher,
//! and the end-to-end serving loop. Baseline/after numbers recorded in
//! EXPERIMENTS.md §Perf; results also land in `BENCH_microbench.json`
//! (machine-readable; `STAMP_BENCH_QUICK=1` bounds the run for CI smoke).

use stamp::baselines::{quantize_weight, quantize_weight_packed, WeightQuantCfg};
use stamp::bench::Harness;
use stamp::coordinator::{DynamicBatcher, Request};
use stamp::decode::{DecodeEngine, GenRequest, Sampling};
use stamp::kvcache::{KvCache, KvCacheConfig};
use stamp::model::{FpHook, Gpt, GptConfig};
use stamp::quant::{BitAllocation, Granularity, QTensor, QuantScheme, Quantizer};
use stamp::stamp::SeqTransformKind;
use stamp::tensor::{matmul, matmul_transb, qgemm, qgemm_scalar, Tensor};
use stamp::transforms::{
    DctTransform, HaarDwt, HadamardFeature, SequenceTransform, WhtTransform,
};
use stamp::transforms::FeatureTransform;
use std::time::{Duration, Instant};

/// 95th-percentile of a set of queue waits, in microseconds.
fn p95_us(waits: &[Duration]) -> f64 {
    let mut us: Vec<f64> = waits.iter().map(|d| d.as_secs_f64() * 1e6).collect();
    us.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let idx = ((us.len() as f64 * 0.95).ceil() as usize).max(1) - 1;
    us[idx.min(us.len() - 1)]
}

fn main() {
    let mut h = Harness::from_env();
    println!(
        "threads: {} (set STAMP_THREADS=1 for the serial baseline)",
        stamp::parallel::num_threads()
    );
    let s = 2048usize;
    let d = 512usize;
    let x = Tensor::randn(&[s, d], 1);
    let bytes = (s * d * 4) as f64;

    Harness::header("quantization (2048x512 f32)");
    let scheme4 = QuantScheme::uniform(4, Granularity::PerToken);
    let st = h.bench("qdq per-token u4", || scheme4.apply(&x));
    println!("    -> {:.2} GB/s", st.throughput(bytes) / 1e9);
    let mixed = QuantScheme {
        granularity: Granularity::PerToken,
        bits: BitAllocation::two_level(64, 8, 4),
    };
    let st = h.bench("qdq mixed {8x64,4}", || mixed.apply(&x));
    println!("    -> {:.2} GB/s", st.throughput(bytes) / 1e9);
    let blk = QuantScheme::uniform(4, Granularity::PerBlock { block: 64 });
    let st = h.bench("qdq per-block-64 u4", || blk.apply(&x));
    println!("    -> {:.2} GB/s", st.throughput(bytes) / 1e9);

    // The acceptance gate for the packed path: at w4a4 two-level (the
    // paper's main setting), quantize + integer GEMM must beat the
    // simulated QDQ + f32 matmul it replaces. 2048×512 activations against
    // a 512×512 weight, both per-output-channel W4.
    Harness::header("packed integer path (2048x512x512, w4a4 two-level)");
    let gemm_flops = 2.0 * (s as f64) * (d as f64) * (d as f64);
    let w = Tensor::randn(&[d, d], 9);
    let wcfg = WeightQuantCfg::w4_per_channel();
    let wdq = quantize_weight(&w, &wcfg);
    let qw = quantize_weight_packed(&w, &wcfg);
    let quantizer = Quantizer::new(mixed.clone(), s);
    let st = h.bench("qdq + f32 matmul (simulated w4a4)", || mixed.apply(&x).matmul(&wdq));
    println!("    -> {:.2} GFLOP/s-equiv", st.throughput(gemm_flops) / 1e9);
    let st = h.bench("quantize + qgemm (packed w4a4)", || qgemm(&quantizer.quantize(&x), &qw));
    println!("    -> {:.2} GFLOP/s-equiv", st.throughput(gemm_flops) / 1e9);
    let qa = quantizer.quantize(&x);
    let st = h.bench("qgemm only (pre-quantized act)", || qgemm(&qa, &qw));
    println!("    -> {:.2} GFLOP/s-equiv", st.throughput(gemm_flops) / 1e9);
    let st = h.bench("quantize only (pack 2048x512)", || quantizer.quantize(&x));
    println!("    -> {:.2} GB/s", st.throughput(bytes) / 1e9);

    // PR 9 acceptance rows: the word-parallel SWAR kernel vs the scalar
    // oracle it is bit-identical to, at the prefill shape above and the
    // decode shape (a handful of activation rows per step). The micro16
    // rows quantize the activation at MicroBlock{16} and take the
    // dedicated in-register folding path. GOP/s counts integer
    // multiply-adds (2·m·n·k), same as the f32 rows count FLOPs.
    Harness::header("swar qgemm (w4a4, scalar oracle vs swar vs swar+micro16)");
    let qa4 = QTensor::quantize(&x, &BitAllocation::uniform(4), Granularity::PerToken);
    let qa4_micro =
        QTensor::quantize(&x, &BitAllocation::uniform(4), Granularity::MicroBlock { block: 16 });
    let st = h.bench("swar qgemm prefill 2048x512x512 (scalar oracle)", || qgemm_scalar(&qa4, &qw));
    println!("    -> {:.2} GOP/s", st.throughput(gemm_flops) / 1e9);
    let st = h.bench("swar qgemm prefill 2048x512x512 (swar)", || qgemm(&qa4, &qw));
    println!("    -> {:.2} GOP/s", st.throughput(gemm_flops) / 1e9);
    let st = h.bench("swar qgemm prefill 2048x512x512 (swar + micro16)", || {
        qgemm(&qa4_micro, &qw)
    });
    println!("    -> {:.2} GOP/s", st.throughput(gemm_flops) / 1e9);
    let xd = x.slice_rows(0, 8);
    let decode_flops = 2.0 * 8.0 * (d as f64) * (d as f64);
    let qd4 = QTensor::quantize(&xd, &BitAllocation::uniform(4), Granularity::PerToken);
    let qd4_micro =
        QTensor::quantize(&xd, &BitAllocation::uniform(4), Granularity::MicroBlock { block: 16 });
    let st = h.bench("swar qgemm decode 8x512x512 (scalar oracle)", || qgemm_scalar(&qd4, &qw));
    println!("    -> {:.2} GOP/s", st.throughput(decode_flops) / 1e9);
    let st = h.bench("swar qgemm decode 8x512x512 (swar)", || qgemm(&qd4, &qw));
    println!("    -> {:.2} GOP/s", st.throughput(decode_flops) / 1e9);
    let st = h.bench("swar qgemm decode 8x512x512 (swar + micro16)", || qgemm(&qd4_micro, &qw));
    println!("    -> {:.2} GOP/s", st.throughput(decode_flops) / 1e9);

    Harness::header("sequence transforms (2048x512)");
    let dwt = HaarDwt::new(s, 3);
    let st = h.bench("haar dwt fwd (3 lvl)", || dwt.forward(&x));
    println!("    -> {:.2} GB/s", st.throughput(bytes) / 1e9);
    h.bench("haar dwt inv (3 lvl)", || dwt.inverse(&x));
    let wht = WhtTransform::new(s);
    let st = h.bench("wht fwd", || wht.forward(&x));
    println!("    -> {:.2} GB/s", st.throughput(bytes) / 1e9);
    let dct = DctTransform::new(512);
    let xs = Tensor::randn(&[512, d], 2);
    h.bench("dct fwd (512x512 matrix)", || dct.forward(&xs));

    Harness::header("feature transform + matmul");
    let had = HadamardFeature::new(d, 3);
    let st = h.bench("hadamard feature (2048x512)", || had.apply(&x));
    println!("    -> {:.2} GB/s", st.throughput(bytes) / 1e9);
    let a = Tensor::randn(&[256, 512], 4);
    let w = Tensor::randn(&[512, 512], 5);
    let st = h.bench("matmul 256x512x512", || matmul(&a, &w));
    let flops = 2.0 * 256.0 * 512.0 * 512.0;
    println!("    -> {:.2} GFLOP/s", st.throughput(flops) / 1e9);

    // Square sizes (m=n=k): the EXPERIMENTS.md §Perf threading table.
    Harness::header("matmul m=n=k (threaded vs STAMP_THREADS=1)");
    for n in [256usize, 512] {
        let a = Tensor::randn(&[n, n], 6);
        let b = Tensor::randn(&[n, n], 7);
        let st = h.bench(&format!("matmul {n}x{n}x{n}"), || matmul(&a, &b));
        let flops = 2.0 * (n as f64).powi(3);
        println!("    -> {:.2} GFLOP/s", st.throughput(flops) / 1e9);
        let bt = Tensor::randn(&[n, n], 8);
        let st = h.bench(&format!("matmul_transb {n}x({n}x{n})"), || matmul_transb(&a, &bt));
        println!("    -> {:.2} GFLOP/s", st.throughput(flops) / 1e9);
    }

    // Autoregressive decode through the KV-cache subsystem: tokens/sec
    // with the fp32 reference cache vs the packed two-level cache (± DWT
    // blocks). The 1-thread and N-thread rows of the EXPERIMENTS.md table
    // come from running this binary under STAMP_THREADS=1 / default, like
    // every other section.
    Harness::header("autoregressive decode (tiny GPT, prefill 16 + 48 tokens)");
    let gpt = std::sync::Arc::new(Gpt::new(GptConfig::tiny(), 0xD3C0));
    let prompt: Vec<u32> = (0..16).map(|i| ((i * 5) % 72) as u32).collect();
    let n_new = 48usize;
    let st = h.bench("decode 48 tok (fp32 cache)", || {
        let mut cache = KvCache::fp32(gpt.cfg.n_layers);
        gpt.generate_greedy(&FpHook, &prompt, n_new, &mut cache)
    });
    println!("    -> {:.0} tok/s", st.throughput(n_new as f64));
    let st = h.bench("decode 48 tok (packed two-level kv)", || {
        let mut cache =
            KvCache::new(gpt.cfg.n_layers, KvCacheConfig::two_level(8, 8, 4, 16));
        gpt.generate_greedy(&FpHook, &prompt, n_new, &mut cache)
    });
    println!("    -> {:.0} tok/s", st.throughput(n_new as f64));
    let st = h.bench("decode 48 tok (packed kv + dwt blocks)", || {
        let mut cache = KvCache::new(
            gpt.cfg.n_layers,
            KvCacheConfig::two_level(8, 8, 4, 16).with_transform(SeqTransformKind::HaarDwt),
        );
        gpt.generate_greedy(&FpHook, &prompt, n_new, &mut cache)
    });
    println!("    -> {:.0} tok/s", st.throughput(n_new as f64));

    // Batched decode: the step-synchronized engine fuses N concurrent
    // streams into one GEMM per linear per step. Rows report aggregate
    // tokens/sec and tokens/sec **per stream** — the acceptance metric is
    // batch-8 per-stream throughput vs the serial per-request baseline
    // above it (8 independent generate_greedy runs, the PR 3 serving
    // behavior).
    Harness::header("batched decode (tiny GPT, ragged prompts + 32 tokens/stream)");
    let n_new_b = 32usize;
    let prompts: Vec<Vec<u32>> = (0..8)
        .map(|i| (0..(12 + 2 * i)).map(|j| ((j * 5 + i * 7) % 72) as u32).collect())
        .collect();
    let st = h.bench("serial decode x8 (fp32 kv, per-request)", || {
        prompts
            .iter()
            .map(|p| {
                let mut cache = KvCache::fp32(gpt.cfg.n_layers);
                gpt.generate_greedy(&FpHook, p, n_new_b, &mut cache)
            })
            .collect::<Vec<_>>()
    });
    println!(
        "    -> {:.0} tok/s aggregate, {:.0} tok/s/stream",
        st.throughput((8 * n_new_b) as f64),
        st.throughput((8 * n_new_b) as f64) / 8.0
    );
    for batch in [1usize, 4, 8] {
        let reqs: Vec<GenRequest> = prompts[..batch]
            .iter()
            .map(|p| GenRequest { prompt: p.clone(), n_new: n_new_b })
            .collect();
        let mut engine = DecodeEngine::new(gpt.clone(), KvCacheConfig::fp32(), Sampling::Greedy)
            .with_decode_batch(batch);
        let st = h.bench(&format!("batched decode b={batch} (fp32 kv)"), || {
            engine.run_fp(&reqs).unwrap()
        });
        println!(
            "    -> {:.0} tok/s aggregate, {:.0} tok/s/stream",
            st.throughput((batch * n_new_b) as f64),
            st.throughput((batch * n_new_b) as f64) / batch as f64
        );
    }
    let reqs8: Vec<GenRequest> =
        prompts.iter().map(|p| GenRequest { prompt: p.clone(), n_new: n_new_b }).collect();
    let mut engine = DecodeEngine::new(
        gpt.clone(),
        KvCacheConfig::two_level(8, 8, 4, 16),
        Sampling::Greedy,
    )
    .with_decode_batch(8);
    let st = h.bench("batched decode b=8 (packed two-level kv)", || {
        engine.run_fp(&reqs8).unwrap()
    });
    println!(
        "    -> {:.0} tok/s aggregate, {:.0} tok/s/stream",
        st.throughput((8 * n_new_b) as f64),
        st.throughput((8 * n_new_b) as f64) / 8.0
    );

    // Sliding-window eviction: long-sequence decode at bounded residency.
    // Same-length rows compare the window policy's overhead against the
    // unbounded cache; the 4× max_seq row is the workload only the window
    // policy can serve at all (the unbounded cache hits the positional
    // table at 256). Resident storage_bits per row quantify the memory
    // ceiling the policy pins.
    Harness::header("windowed decode (tiny GPT, sink 16 + window 64 kv eviction)");
    let kv_unbounded = KvCacheConfig::two_level(16, 8, 4, 16);
    let kv_windowed = KvCacheConfig::two_level(16, 8, 4, 16).with_window(16, 64);
    let n_mid = 192usize;
    let n_long = 4 * gpt.cfg.max_seq;
    // The bench closure stashes the run's resident footprint so the rows
    // can report it without re-running the generation untimed.
    let bits = std::cell::Cell::new(0usize);
    let st = h.bench("windowed decode 192 tok (unbounded kv)", || {
        let mut cache = KvCache::new(gpt.cfg.n_layers, kv_unbounded.clone());
        let out = gpt.generate_greedy(&FpHook, &prompt, n_mid, &mut cache);
        bits.set(cache.storage_bits());
        out
    });
    println!("    -> {:.0} tok/s, resident {} bits", st.throughput(n_mid as f64), bits.get());
    let st = h.bench("windowed decode 192 tok (sink 16 + window 64)", || {
        let mut cache = KvCache::new(gpt.cfg.n_layers, kv_windowed.clone());
        let out = gpt.generate_greedy(&FpHook, &prompt, n_mid, &mut cache);
        bits.set(cache.storage_bits());
        out
    });
    println!("    -> {:.0} tok/s, resident {} bits", st.throughput(n_mid as f64), bits.get());
    let st = h.bench("windowed decode 1024 tok (4x max_seq)", || {
        let mut cache = KvCache::new(gpt.cfg.n_layers, kv_windowed.clone());
        let out = gpt.generate_greedy(&FpHook, &prompt, n_long, &mut cache);
        bits.set(cache.storage_bits());
        out
    });
    println!("    -> {:.0} tok/s, resident {} bits", st.throughput(n_long as f64), bits.get());

    // Continuous decode (PR 6): eight ragged streams contending for four
    // engine slots. "One-shot waves" is the PR 4 serving behavior — a
    // full wave of 4 runs to completion before the next wave is seated,
    // so every wave is dominated by its slowest stream and wave 2 queues
    // behind the whole of wave 1. "In-flight admission" refills a slot
    // the moment a stream retires. Same total work, so in-flight must
    // come out ≥ one-shot on aggregate tokens/sec (CI asserts the rows
    // exist; EXPERIMENTS.md records the ratio), and p95 queue wait — the
    // admission latency of the 95th-percentile request — drops from
    // "an entire wave" to "one retirement".
    Harness::header("continuous decode (tiny GPT, 8 ragged streams, 4 slots)");
    let budgets: Vec<usize> = (0..8).map(|i| 8 + 4 * i).collect();
    let creqs: Vec<GenRequest> = prompts
        .iter()
        .zip(&budgets)
        .map(|(p, &n)| GenRequest { prompt: p.clone(), n_new: n })
        .collect();
    let total_tokens: usize = budgets.iter().sum();
    let waits = std::cell::RefCell::new(Vec::new());
    let mut engine = DecodeEngine::new(gpt.clone(), KvCacheConfig::fp32(), Sampling::Greedy)
        .with_max_inflight(4);
    let st = h.bench("one-shot waves of 4 (fp32 kv)", || {
        let t0 = Instant::now();
        let mut w = vec![Duration::ZERO; 4];
        let a = engine.run_fp(&creqs[..4]).unwrap();
        w.resize(8, t0.elapsed());
        let b = engine.run_fp(&creqs[4..]).unwrap();
        *waits.borrow_mut() = w;
        (a, b)
    });
    println!(
        "    -> {:.0} tok/s aggregate, p95 queue wait {:.0} us",
        st.throughput(total_tokens as f64),
        p95_us(&waits.borrow())
    );
    let st = h.bench("in-flight admission (fp32 kv)", || {
        let t0 = Instant::now();
        let mut w = vec![Duration::ZERO; creqs.len()];
        let mut next = 0usize;
        let mut out = Vec::new();
        while next < creqs.len() || engine.has_work() {
            while next < creqs.len() && engine.free_slots() > 0 {
                w[next] = t0.elapsed();
                engine.admit(creqs[next].clone()).unwrap();
                next += 1;
            }
            engine.step(&FpHook);
            out.extend(engine.drain());
        }
        *waits.borrow_mut() = w;
        out
    });
    println!(
        "    -> {:.0} tok/s aggregate, p95 queue wait {:.0} us",
        st.throughput(total_tokens as f64),
        p95_us(&waits.borrow())
    );

    // Obs overhead (PR 8): the tracing tax when a ring is attached.
    // Both engines record TTFT/TPOT (the always-on cost: a few relaxed
    // atomics per token); the traced engine additionally writes every
    // Admit/PrefillChunk/DecodeStep/Retire event into a 4096-slot
    // overwrite-oldest ring. Identical workload, so the row pair is a
    // direct A/B of the record path; the assert pins the acceptance
    // bound — tracing must stay within 3% of untraced on the fused
    // decode hot path (plus an absolute grace for timer noise on
    // quick-mode runs).
    Harness::header("obs overhead (tiny GPT, 4 streams x 32 tokens)");
    let oreqs: Vec<GenRequest> = prompts[..4]
        .iter()
        .map(|p| GenRequest { prompt: p.clone(), n_new: n_new_b })
        .collect();
    let mut plain = DecodeEngine::new(gpt.clone(), KvCacheConfig::fp32(), Sampling::Greedy)
        .with_decode_batch(4);
    let st_plain =
        h.bench("obs overhead decode b=4 (untraced)", || plain.run_fp(&oreqs).unwrap());
    println!("    -> {:.0} tok/s aggregate", st_plain.throughput((4 * n_new_b) as f64));
    let mut traced = DecodeEngine::new(gpt.clone(), KvCacheConfig::fp32(), Sampling::Greedy)
        .with_decode_batch(4)
        .with_obs(std::sync::Arc::new(stamp::obs::EngineObs::with_trace(4096)));
    let st_traced =
        h.bench("obs overhead decode b=4 (traced ring 4096)", || traced.run_fp(&oreqs).unwrap());
    println!(
        "    -> {:.0} tok/s aggregate ({:+.2}% vs untraced)",
        st_traced.throughput((4 * n_new_b) as f64),
        (st_traced.min_ns / st_plain.min_ns - 1.0) * 100.0
    );
    assert!(
        st_traced.min_ns <= st_plain.min_ns * 1.03 + 500_000.0,
        "tracing overhead above 3%: traced {:.0} ns vs untraced {:.0} ns",
        st_traced.min_ns,
        st_plain.min_ns
    );
    println!("    traced ring: {} events dropped (overwrite-oldest)", traced.obs().trace_dropped());

    // Prefix reuse (PR 7): eight streams sharing a 128-token prompt
    // prefix, admitted with a 1-token budget so a run measures exactly
    // admit-to-first-token. The unpooled engine re-prefills the shared
    // 128 tokens per stream; the pooled engine (warm prefix cache) seats
    // each stream on the pooled blocks and prefills only the private
    // suffix. The storage line quantifies the other half of the win: the
    // per-stream `storage_bits` sum (what 8 private caches would store)
    // vs the physical footprint holding the prefix once.
    Harness::header("prefix reuse (tiny GPT, 8 streams x shared 128-token prefix)");
    let shared: Vec<u32> = (0..128).map(|j| ((j * 5 + 1) % 72) as u32).collect();
    let preqs: Vec<GenRequest> = (0..8)
        .map(|i| {
            let mut p = shared.clone();
            p.extend((0..4).map(|j| ((i * 7 + j * 11 + 2) % 72) as u32));
            GenRequest { prompt: p, n_new: 1 }
        })
        .collect();
    let kv_pool = KvCacheConfig::two_level(16, 8, 4, 16);
    let mut unpooled = DecodeEngine::new(gpt.clone(), kv_pool.clone(), Sampling::Greedy);
    let st = h.bench("prefix admit-to-first-token x8 (unpooled kv)", || {
        unpooled.run_fp(&preqs).unwrap()
    });
    println!("    -> {:.1} first tokens/s", st.throughput(8.0));
    let mut pooled =
        DecodeEngine::new(gpt.clone(), kv_pool.clone().with_prefix_cache(), Sampling::Greedy);
    // Warm the pool once: the warmer's prompt prefill registers every
    // block-aligned prefix of the shared span.
    pooled.run_fp(&[GenRequest { prompt: shared.clone(), n_new: 1 }]).unwrap();
    let st = h.bench("prefix admit-to-first-token x8 (pooled kv, warm prefix cache)", || {
        pooled.run_fp(&preqs).unwrap()
    });
    println!(
        "    -> {:.1} first tokens/s ({} cumulative prefix hits)",
        st.throughput(8.0),
        pooled.prefix_hits()
    );
    // Aggregate storage with all 8 seated on the shared prefix.
    for r in &preqs {
        pooled.admit(r.clone()).unwrap();
    }
    println!(
        "    storage_bits x8 in flight: logical {} vs physical {} (unpooled kv stores the logical sum)",
        pooled.inflight_storage_bits(),
        pooled.pool().resident_bits() + pooled.inflight_tail_bits()
    );
    while pooled.has_work() {
        pooled.step(&FpHook);
        pooled.drain();
    }

    // Speculative decode (PR 10): draft → one ragged verify GEMM →
    // accept/rollback. Greedy output is bit-identical to the plain
    // engine (tests/speculative.rs pins it), so the rows are a pure
    // throughput A/B at identical content: the plain baseline pays one
    // GEMV-shaped step per token; the speculative rows amortize the
    // weight traffic over `accepted+1` rows per verify step. The
    // accepted-length line is the distribution that decides the win —
    // mean near 0 degenerates to baseline (plus draft cost), mean near
    // k approaches (k+1)-token steps.
    Harness::header("speculative decode (tiny GPT, 4 streams x 32 tokens, k=4)");
    use stamp::decode::{DraftKind, SpecConfig};
    let sreqs: Vec<GenRequest> = prompts[..4]
        .iter()
        .map(|p| GenRequest { prompt: p.clone(), n_new: n_new_b })
        .collect();
    let mut plain_s = DecodeEngine::new(gpt.clone(), KvCacheConfig::fp32(), Sampling::Greedy)
        .with_decode_batch(4);
    let st_base =
        h.bench("speculative decode b=4 (plain greedy baseline)", || plain_s.run_fp(&sreqs).unwrap());
    println!("    -> {:.0} tok/s aggregate", st_base.throughput((4 * n_new_b) as f64));
    for (label, draft) in
        [("ngram", DraftKind::Ngram), ("packed fork", DraftKind::Packed)]
    {
        let mut eng = DecodeEngine::new(gpt.clone(), KvCacheConfig::fp32(), Sampling::Greedy)
            .with_decode_batch(4)
            .with_speculative(SpecConfig { draft, k: 4 });
        let st = h.bench(&format!("speculative decode b=4 ({label} k=4)"), || {
            eng.run_fp(&sreqs).unwrap()
        });
        let acc = &eng.obs().accepted_len;
        println!(
            "    -> {:.0} tok/s aggregate ({:+.2}% vs plain), accepted len mean {:.2} p50 {} p90 {} over {} verify steps",
            st.throughput((4 * n_new_b) as f64),
            (st_base.min_ns / st.min_ns - 1.0) * 100.0,
            acc.mean(),
            acc.quantile(0.5),
            acc.quantile(0.9),
            acc.count()
        );
    }

    Harness::header("coordinator hot path");
    let st = h.bench("batcher push+flush (batch 8)", || {
        let now = Instant::now();
        let mut b = DynamicBatcher::new("v", 8, Duration::from_millis(1));
        let mut out = None;
        for i in 0..8u64 {
            let (tx, _rx) = std::sync::mpsc::channel();
            let req = Request {
                id: i,
                variant: "v".into(),
                input: Tensor::zeros(&[1, 1]),
                submitted: now,
                respond: tx,
            };
            out = b.push(req, now);
        }
        out
    });
    println!("    -> {:.0} ns per request overhead", st.median_ns / 8.0);

    // Machine-readable trajectory artifact (overridable for out-of-tree
    // CI layouts).
    let json_path =
        std::env::var("STAMP_BENCH_JSON").unwrap_or_else(|_| "BENCH_microbench.json".into());
    h.write_json(std::path::Path::new(&json_path)).expect("write bench json");
    println!("\nwrote {json_path}");
}
