//! **Figure 9** regeneration: per-token vs per-block vs STaMP tradeoff.
use stamp::eval::tables::{fig9_blockq, TableOpts};

fn main() {
    let t0 = std::time::Instant::now();
    let opts = if std::env::args().any(|a| a == "--full") { TableOpts::full() } else { TableOpts::fast() };
    println!("{}", fig9_blockq(&opts).render());
    println!("regenerated in {:.1?}", t0.elapsed());
}
