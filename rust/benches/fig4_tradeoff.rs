//! **Figure 4b** regeneration: hp-token count vs SQNR sweep.
use stamp::eval::tables::{fig4b_sweep, TableOpts};

fn main() {
    let t0 = std::time::Instant::now();
    let opts = if std::env::args().any(|a| a == "--full") { TableOpts::full() } else { TableOpts::fast() };
    println!("{}", fig4b_sweep(&opts).render());
    println!("regenerated in {:.1?}", t0.elapsed());
}
