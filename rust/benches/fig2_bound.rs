//! **Figure 2b** regeneration: Theorem-1 bound vs measured error, uniform
//! vs STaMP at matched average bits.
use stamp::data::{ActivationGenerator, ActivationSpec};
use stamp::eval::figures::fig2_bound_curve;
use stamp::quant::BitAllocation;
use stamp::transforms::{HaarDwt, IdentitySeq, SequenceTransform};

fn main() {
    let gen = ActivationGenerator::new(ActivationSpec {
        outlier_channels: 0,
        sink_scale: 0.0,
        ..ActivationSpec::llm(256, 64)
    });
    let x = gen.sample(0xF16);
    let id = IdentitySeq::new(256);
    let dwt = HaarDwt::new(256, 3);
    println!("{:>8} {:>22} {:>14} {:>14}", "avg_bits", "scheme", "measured", "bound");
    for b in 3u32..=8 {
        for (name, tr, alloc) in [
            ("uniform/identity", &id as &dyn SequenceTransform, BitAllocation::uniform(b)),
            ("STaMP dwt 2-level", &dwt as &dyn SequenceTransform, BitAllocation::two_level(32, 8, b.saturating_sub(1).max(1))),
        ] {
            let p = &fig2_bound_curve(&x, tr, &[alloc])[0];
            println!("{:>8.2} {:>22} {:>14.4} {:>14.4}", p.avg_bits, name, p.measured_error, p.bound);
            assert!(p.measured_error <= p.bound * 1.0001, "bound violated");
        }
    }
    println!("\nbound >= measured everywhere; STaMP rows sit below uniform at matched bits.");
}
