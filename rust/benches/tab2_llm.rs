//! **Table 2** regeneration (LLM W4A4KV4 PPL, ± STaMP) with wall-clock.
use stamp::eval::tables::{table2_llm, TableOpts};

fn main() {
    let t0 = std::time::Instant::now();
    let opts = if std::env::args().any(|a| a == "--full") { TableOpts::full() } else { TableOpts::fast() };
    let table = table2_llm(&opts);
    println!("{}", table.render());
    println!("regenerated in {:.1?}", t0.elapsed());
}
