//! **Table 3** — transform overhead for one DiT denoising step:
//! FLOPs (analytic) and measured latency overhead [%] for
//! {feature Hadamard, sequence Hadamard(WHT), sequence DWT, both}.
//!
//! The paper's claim to reproduce: seq-Hadamard is much more expensive
//! than DWT (memory-layout cost), while DWT ≈ feature-Hadamard ≈ small.

use stamp::bench::Harness;
use stamp::model::{Dit, DitConfig, FpHook, LinearHook};
use stamp::tensor::{matmul, Tensor};
use stamp::transforms::{
    FeatureTransform, HaarDwt2d, HadamardFeature, SequenceTransform, WhtTransform,
};

/// Hook that applies transforms (and their inverses) around every linear,
/// WITHOUT quantization — isolating pure transform overhead, as Table 3 does.
struct TransformHook {
    feature: bool,
    seq: Option<Box<dyn SequenceTransform>>,
    feats: std::cell::RefCell<std::collections::HashMap<usize, HadamardFeature>>,
}

impl TransformHook {
    fn new(feature: bool, seq: Option<Box<dyn SequenceTransform>>) -> Self {
        TransformHook { feature, seq, feats: Default::default() }
    }
}

impl LinearHook for TransformHook {
    fn linear(&self, _site: &str, x: &Tensor, w: &Tensor, bias: Option<&[f32]>) -> Tensor {
        let mut a = x.clone();
        if self.feature {
            let mut feats = self.feats.borrow_mut();
            let f = feats.entry(x.cols()).or_insert_with(|| HadamardFeature::new(x.cols(), 1));
            a = f.invert(&f.apply(&a));
        }
        if let Some(seq) = &self.seq {
            if seq.seq_len() == a.rows() {
                a = seq.inverse(&seq.forward(&a));
            }
        }
        let mut y = matmul(&a, w);
        if let Some(b) = bias {
            y = y.add_row_broadcast(b);
        }
        y
    }
}

fn main() {
    let dit = Dit::new(DitConfig { steps: 1, ..DitConfig::pixart() }, 0xD17);
    let (h, w) = (dit.cfg.grid_h, dit.cfg.grid_w);
    let s = dit.cfg.seq_len();
    let d = dit.cfg.d_model;
    let z = Tensor::randn(&[s, dit.latent_dim], 1);

    let mut harness = Harness::new();
    Harness::header("Table 3: transform overhead on one DiT denoise step");

    let base = harness.bench("baseline (no transform)", || {
        dit.denoise_step(&FpHook, &z, "bench prompt", 0)
    });

    let configs: Vec<(&str, bool, Option<Box<dyn SequenceTransform>>)> = vec![
        ("feature Hadamard", true, None),
        ("sequence Hadamard (WHT)", false, Some(Box::new(WhtTransform::new(s)))),
        ("sequence DWT (2-D, 3 lvl)", false, Some(Box::new(HaarDwt2d::new(h, w, 3)))),
        ("feature Had + seq DWT", true, Some(Box::new(HaarDwt2d::new(h, w, 3)))),
    ];

    // Analytic FLOPs for one denoise step (linears only, the dominant term).
    let sites_per_layer = 8u64; // q,k,v,o + to_q,to_out + up,down
    let layer_flops = sites_per_layer * 2 * (s as u64) * (d as u64) * (d as u64);
    let step_flops = layer_flops * dit.cfg.n_layers as u64;

    println!("\n{:<28} {:>12} {:>14}", "transform", "FLOPs [%]", "latency [%]");
    for (name, feat, seq) in configs {
        // FLOP overhead: 2 applications (fwd+inv) per linear site.
        let per_site: u64 = {
            let f = if feat { 2 * HadamardFeature::new(d, 1).flops(s) } else { 0 };
            let q = seq.as_ref().map(|t| 2 * t.flops(d)).unwrap_or(0);
            f + q
        };
        let total_sites = sites_per_layer * dit.cfg.n_layers as u64;
        let flop_pct = 100.0 * (per_site * total_sites) as f64 / step_flops as f64;

        let hook = TransformHook::new(feat, seq);
        let stats = harness.bench(name, || dit.denoise_step(&hook, &z, "bench prompt", 0));
        let lat_pct = 100.0 * (stats.median_ns - base.median_ns) / base.median_ns;
        println!("{name:<28} {flop_pct:>11.2}% {lat_pct:>13.1}%");
    }
    println!("\nshape check (paper Table 3): seq-Hadamard ≫ DWT ≈ feature-Hadamard.");
}
