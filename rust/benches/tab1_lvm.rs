//! **Table 1** regeneration (LVM W4A4 block-64, ± STaMP) with wall-clock.
use stamp::eval::tables::{table1_lvm, TableOpts};

fn main() {
    let t0 = std::time::Instant::now();
    let opts = if std::env::args().any(|a| a == "--full") { TableOpts::full() } else { TableOpts::fast() };
    let table = table1_lvm(&opts);
    println!("{}", table.render());
    println!("regenerated in {:.1?}", t0.elapsed());
}
