//! **Figure 7** regeneration: feature x sequence transform grid.
use stamp::eval::tables::{fig7_grid, TableOpts};

fn main() {
    let t0 = std::time::Instant::now();
    let opts = if std::env::args().any(|a| a == "--full") { TableOpts::full() } else { TableOpts::fast() };
    let (lvm, llm) = fig7_grid(&opts);
    println!("{}", lvm.render());
    println!("{}", llm.render());
    println!("regenerated in {:.1?}", t0.elapsed());
}
