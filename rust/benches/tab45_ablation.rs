//! **Tables 4 & 5** regeneration: per-site ablation + companion metrics.
use stamp::eval::tables::{table4_sites, table5_metrics, TableOpts};

fn main() {
    let t0 = std::time::Instant::now();
    let opts = if std::env::args().any(|a| a == "--full") { TableOpts::full() } else { TableOpts::fast() };
    println!("{}", table4_sites(&opts).render());
    println!("{}", table5_metrics(&opts).render());
    println!("regenerated in {:.1?}", t0.elapsed());
}
