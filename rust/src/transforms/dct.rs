//! Orthonormal DCT-II along the sequence dimension.
//!
//! By Szegő's theorem the eigenbasis of a symmetric Toeplitz matrix is
//! asymptotically the Fourier basis; since activation autocorrelations are
//! real and symmetric the paper uses the *cosine* basis (§3.2). This gives
//! a near-KLT energy concentration with no calibration.
//!
//! Implementation notes: we apply the transform with a precomputed `s×s`
//! orthonormal DCT matrix via the blocked matmul. A factorized
//! O(s log s) butterfly exists (and the FLOP accounting in [`flops`]
//! reports the fast-algorithm cost the paper cites); at the sequence
//! lengths used here (≤4096) the matmul form is both simpler and — with
//! the blocked kernel — not the bottleneck on CPU. The Pallas L1 kernel
//! mirrors the same matrix formulation.

use super::SequenceTransform;
use crate::tensor::{matmul, Tensor};

/// Orthonormal DCT-II sequence transform.
pub struct DctTransform {
    s: usize,
    /// Precomputed `L` (s×s), rows = DCT basis vectors.
    mat: Tensor,
}

impl DctTransform {
    pub fn new(s: usize) -> Self {
        assert!(s >= 2);
        let mut mat = Tensor::zeros(&[s, s]);
        let norm0 = (1.0 / s as f64).sqrt();
        let norm = (2.0 / s as f64).sqrt();
        for k in 0..s {
            let nk = if k == 0 { norm0 } else { norm };
            for n in 0..s {
                let v = nk
                    * ((std::f64::consts::PI / s as f64) * (n as f64 + 0.5) * k as f64).cos();
                mat.set(k, n, v as f32);
            }
        }
        DctTransform { s, mat }
    }
}

impl SequenceTransform for DctTransform {
    fn name(&self) -> &'static str {
        "dct"
    }

    fn seq_len(&self) -> usize {
        self.s
    }

    fn forward(&self, x: &Tensor) -> Tensor {
        assert_eq!(x.rows(), self.s);
        matmul(&self.mat, x)
    }

    fn inverse(&self, y: &Tensor) -> Tensor {
        assert_eq!(y.rows(), self.s);
        // Orthonormal: L⁻¹ = Lᵀ.
        matmul(&self.mat.transpose(), y)
    }

    fn flops(&self, d: usize) -> u64 {
        // Fast-DCT cost (what hardware would pay): ~2.5 · s log₂ s per
        // feature column.
        let s = self.s as u64;
        let logs = (64 - (self.s as u64).leading_zeros() - 1) as u64;
        (5 * s * logs / 2) * d as u64
    }

    fn matrix(&self) -> Tensor {
        self.mat.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{ar1_covariance, eigh, orthogonality_defect};

    #[test]
    fn dc_row_is_constant() {
        let t = DctTransform::new(16);
        let m = t.matrix();
        let v0 = m.at(0, 0);
        for n in 0..16 {
            assert!((m.at(0, n) - v0).abs() < 1e-6);
        }
        assert!((v0 - 0.25).abs() < 1e-6); // 1/√16
    }

    #[test]
    fn orthonormal() {
        let t = DctTransform::new(33); // non power-of-two is fine for DCT
        assert!(orthogonality_defect(&t.matrix()) < 1e-5);
    }

    #[test]
    fn constant_signal_to_dc() {
        let t = DctTransform::new(32);
        let x = Tensor::full(&[32, 3], 2.0);
        let y = t.forward(&x);
        // All energy in row 0.
        let e0: f64 = y.row(0).iter().map(|v| (*v as f64).powi(2)).sum();
        assert!((e0 / y.sq_norm() - 1.0).abs() < 1e-6);
        // DC value = 2·√32.
        assert!((y.at(0, 0) - 2.0 * 32f32.sqrt()).abs() < 1e-4);
    }

    #[test]
    fn approximates_klt_on_toeplitz() {
        // Szegő: DCT diagonalizes AR(1) covariance asymptotically. Compare
        // energy compaction of DCT vs exact KLT — DCT must capture ≥95% of
        // what KLT captures in the top quarter of coefficients.
        let s = 64;
        let cov = ar1_covariance(s, 0.9, 1.0);
        let eig = eigh(&cov, 60, 1e-10);
        let dct = DctTransform::new(s);
        let m = dct.matrix();

        let top = s / 4;
        // Energy of transform row i on covariance S is lᵢᵀ S lᵢ.
        let energy = |l: &Tensor, i: usize| -> f64 {
            let mut acc = 0.0f64;
            for a in 0..s {
                for b in 0..s {
                    acc += (l.at(i, a) * cov.at(a, b) * l.at(i, b)) as f64;
                }
            }
            acc
        };
        let mut dct_energies: Vec<f64> = (0..s).map(|i| energy(&m, i)).collect();
        dct_energies.sort_by(|a, b| b.partial_cmp(a).unwrap());
        let dct_top: f64 = dct_energies[..top].iter().sum();
        let klt_top: f64 = eig.values[..top].iter().map(|&v| v as f64).sum();
        assert!(dct_top / klt_top > 0.95, "ratio {}", dct_top / klt_top);
    }

    #[test]
    fn roundtrip() {
        let t = DctTransform::new(48);
        let x = Tensor::randn(&[48, 7], 9);
        assert!(t.inverse(&t.forward(&x)).max_abs_diff(&x) < 1e-5);
    }
}
