//! Orthonormal Haar discrete wavelet transform along the sequence axis.
//!
//! This is the transform the paper actually deploys (§3.2, §3.3): each
//! level costs O(sd), it needs `levels ≤ log₂ s` steps, and it concentrates
//! energy into a *discrete* set of levels — the property that makes the
//! simple {8-bit × 64 tokens, 4-bit rest} allocation work. Coefficients are
//! emitted in the standard multiresolution order
//! `[approx_L | detail_L | detail_{L-1} | … | detail_1]`, so the
//! high-energy approximation coefficients are the *leading* tokens and the
//! mixed-precision scheme can simply keep "the first k tokens" in 8 bits.
//!
//! [`HaarDwt2d`] applies the separable 2-D version to a flattened `h×w`
//! token grid (LVM latents), matching the paper's "one quarter per level"
//! 2-D energy concentration.

use super::SequenceTransform;
use crate::tensor::Tensor;

const SQRT1_2: f32 = std::f32::consts::FRAC_1_SQRT_2;

/// Multi-level 1-D Haar DWT over the sequence (row) dimension.
///
/// The Haar basis is orthonormal, so the transform preserves total energy
/// (Frobenius norm) exactly — the property Theorem 1 relies on to equate
/// transformed-domain and original-domain quantization error:
///
/// ```
/// use stamp::tensor::Tensor;
/// use stamp::transforms::{HaarDwt, SequenceTransform};
///
/// let t = HaarDwt::new(128, 3);
/// let x = Tensor::randn(&[128, 16], 3);
/// let y = t.forward(&x);
/// let rel = (y.sq_norm() - x.sq_norm()).abs() / x.sq_norm();
/// assert!(t.orthogonal());
/// assert!(rel < 1e-5, "energy drifted by {rel:e}");
/// ```
pub struct HaarDwt {
    s: usize,
    levels: usize,
}

impl HaarDwt {
    /// `s` must be divisible by `2^levels`.
    pub fn new(s: usize, levels: usize) -> Self {
        assert!(levels >= 1, "need at least one level");
        assert!(
            s % (1 << levels) == 0,
            "sequence length {s} not divisible by 2^{levels}"
        );
        HaarDwt { s, levels }
    }

    /// Largest level count usable for sequence length `s` (full pyramid).
    pub fn max_levels(s: usize) -> usize {
        s.trailing_zeros() as usize
    }

    pub fn levels(&self) -> usize {
        self.levels
    }

    /// One analysis step on the first `n` rows of `x`, writing averages to
    /// rows `[0, n/2)` and details to `[n/2, n)`.
    ///
    /// Approx coefficients are written **in place** (row `p` is only
    /// written after rows `2p, 2p+1` were read, and `2p ≥ p`); details go
    /// through a half-size scratch that is copied back once. This is 3
    /// memory passes per level instead of the naive 5 (EXPERIMENTS.md
    /// §Perf iteration 3).
    fn step_forward(x: &mut Tensor, n: usize, scratch: &mut [f32]) {
        let d = x.cols();
        let half = n / 2;
        let data = x.data_mut();
        for p in 0..half {
            let (head, tail) = data.split_at_mut((2 * p) * d);
            let even = &tail[..d];
            let odd = &tail[d..2 * d];
            let det = &mut scratch[p * d..(p + 1) * d];
            if p == 0 {
                // approx row 0 aliases even row 0: stage through det first.
                for j in 0..d {
                    det[j] = (even[j] - odd[j]) * SQRT1_2;
                }
                for j in 0..d {
                    tail[j] = (tail[j] + tail[d + j]) * SQRT1_2;
                }
            } else {
                let approx = &mut head[p * d..(p + 1) * d];
                for j in 0..d {
                    approx[j] = (even[j] + odd[j]) * SQRT1_2;
                    det[j] = (even[j] - odd[j]) * SQRT1_2;
                }
            }
        }
        data[half * d..n * d].copy_from_slice(&scratch[..half * d]);
    }

    /// One synthesis step inverting `step_forward`. Details are staged
    /// through scratch, then rows are expanded in place descending (target
    /// rows `2p, 2p+1 ≥ p` never clobber an unread approx row).
    fn step_inverse(x: &mut Tensor, n: usize, scratch: &mut [f32]) {
        let d = x.cols();
        let half = n / 2;
        let data = x.data_mut();
        scratch[..half * d].copy_from_slice(&data[half * d..n * d]);
        for p in (0..half).rev() {
            let det = &scratch[p * d..(p + 1) * d];
            let (head, tail) = data.split_at_mut((2 * p) * d);
            if p == 0 {
                for j in 0..d {
                    let a = tail[j];
                    tail[j] = (a + det[j]) * SQRT1_2;
                    tail[d + j] = (a - det[j]) * SQRT1_2;
                }
            } else {
                let avg = &head[p * d..(p + 1) * d];
                for j in 0..d {
                    tail[j] = (avg[j] + det[j]) * SQRT1_2;
                    tail[d + j] = (avg[j] - det[j]) * SQRT1_2;
                }
            }
        }
    }
}

impl SequenceTransform for HaarDwt {
    fn name(&self) -> &'static str {
        "haar-dwt"
    }

    fn seq_len(&self) -> usize {
        self.s
    }

    fn forward(&self, x: &Tensor) -> Tensor {
        assert_eq!(x.rows(), self.s, "HaarDwt built for s={}, got {}", self.s, x.rows());
        let mut out = x.clone();
        let mut scratch = vec![0.0f32; (self.s / 2) * x.cols()];
        let mut n = self.s;
        for _ in 0..self.levels {
            Self::step_forward(&mut out, n, &mut scratch);
            n /= 2;
        }
        out
    }

    fn inverse(&self, y: &Tensor) -> Tensor {
        assert_eq!(y.rows(), self.s);
        let mut out = y.clone();
        let mut scratch = vec![0.0f32; (self.s / 2) * y.cols()];
        let mut n = self.s >> (self.levels - 1);
        for _ in 0..self.levels {
            Self::step_inverse(&mut out, n, &mut scratch);
            n *= 2;
        }
        out
    }

    fn flops(&self, d: usize) -> u64 {
        // Each level over n rows: n/2 butterflies × d features × 4 flops
        // (add, sub, two scales) = 2nd flops; n halves per level.
        let mut total = 0u64;
        let mut n = self.s as u64;
        for _ in 0..self.levels {
            total += 2 * n * d as u64;
            n /= 2;
        }
        total
    }
}

/// Separable 2-D Haar DWT over a flattened `h×w` token grid.
///
/// Each level applies one Haar analysis step along `x` (within grid rows)
/// then one along `y` (within grid columns), quartering the low-pass region
/// per level. Output tokens are re-flattened so that the low-pass block
/// occupies the *leading* sequence positions, nested per level (the 2-D
/// analogue of the 1-D multiresolution order).
pub struct HaarDwt2d {
    h: usize,
    w: usize,
    levels: usize,
}

impl HaarDwt2d {
    pub fn new(h: usize, w: usize, levels: usize) -> Self {
        assert!(levels >= 1);
        assert!(h % (1 << levels) == 0, "grid height {h} not divisible by 2^{levels}");
        assert!(w % (1 << levels) == 0, "grid width {w} not divisible by 2^{levels}");
        HaarDwt2d { h, w, levels }
    }

    pub fn grid(&self) -> (usize, usize) {
        (self.h, self.w)
    }

    /// Index of token `(y, x)` in the flattened sequence.
    #[inline]
    fn idx(&self, y: usize, x: usize) -> usize {
        y * self.w + x
    }

    /// Haar step along grid-x for the active `ah×aw` low-pass block.
    fn step_x(&self, t: &mut Tensor, ah: usize, aw: usize) {
        let d = t.cols();
        let half = aw / 2;
        let mut buf = vec![0.0f32; aw * d];
        for y in 0..ah {
            // Gather the active row into buf, transform, scatter back.
            for x in 0..aw {
                let src = self.idx(y, x) * d;
                buf[x * d..(x + 1) * d].copy_from_slice(&t.data()[src..src + d]);
            }
            for p in 0..half {
                for j in 0..d {
                    let e = buf[2 * p * d + j];
                    let o = buf[(2 * p + 1) * d + j];
                    let dst_a = self.idx(y, p) * d + j;
                    let dst_d = self.idx(y, half + p) * d + j;
                    t.data_mut()[dst_a] = (e + o) * SQRT1_2;
                    t.data_mut()[dst_d] = (e - o) * SQRT1_2;
                }
            }
        }
    }

    fn step_x_inv(&self, t: &mut Tensor, ah: usize, aw: usize) {
        let d = t.cols();
        let half = aw / 2;
        let mut buf = vec![0.0f32; aw * d];
        for y in 0..ah {
            for x in 0..aw {
                let src = self.idx(y, x) * d;
                buf[x * d..(x + 1) * d].copy_from_slice(&t.data()[src..src + d]);
            }
            for p in 0..half {
                for j in 0..d {
                    let a = buf[p * d + j];
                    let dt = buf[(half + p) * d + j];
                    t.data_mut()[self.idx(y, 2 * p) * d + j] = (a + dt) * SQRT1_2;
                    t.data_mut()[self.idx(y, 2 * p + 1) * d + j] = (a - dt) * SQRT1_2;
                }
            }
        }
    }

    /// Haar step along grid-y for the active block.
    fn step_y(&self, t: &mut Tensor, ah: usize, aw: usize) {
        let d = t.cols();
        let half = ah / 2;
        let mut buf = vec![0.0f32; ah * d];
        for x in 0..aw {
            for y in 0..ah {
                let src = self.idx(y, x) * d;
                buf[y * d..(y + 1) * d].copy_from_slice(&t.data()[src..src + d]);
            }
            for p in 0..half {
                for j in 0..d {
                    let e = buf[2 * p * d + j];
                    let o = buf[(2 * p + 1) * d + j];
                    t.data_mut()[self.idx(p, x) * d + j] = (e + o) * SQRT1_2;
                    t.data_mut()[self.idx(half + p, x) * d + j] = (e - o) * SQRT1_2;
                }
            }
        }
    }

    fn step_y_inv(&self, t: &mut Tensor, ah: usize, aw: usize) {
        let d = t.cols();
        let half = ah / 2;
        let mut buf = vec![0.0f32; ah * d];
        for x in 0..aw {
            for y in 0..ah {
                let src = self.idx(y, x) * d;
                buf[y * d..(y + 1) * d].copy_from_slice(&t.data()[src..src + d]);
            }
            for p in 0..half {
                for j in 0..d {
                    let a = buf[p * d + j];
                    let dt = buf[(half + p) * d + j];
                    t.data_mut()[self.idx(2 * p, x) * d + j] = (a + dt) * SQRT1_2;
                    t.data_mut()[self.idx(2 * p + 1, x) * d + j] = (a - dt) * SQRT1_2;
                }
            }
        }
    }

    /// Permutation mapping grid position → output sequence position such
    /// that lower-level (higher-energy) coefficients come first. We order
    /// by the level at which a coefficient becomes low-pass, then raster.
    fn output_order(&self) -> Vec<usize> {
        // Region rank: coefficients inside the final low-pass block first,
        // then each level's detail bands from coarsest to finest.
        let mut keyed: Vec<(usize, usize)> = Vec::with_capacity(self.h * self.w);
        for y in 0..self.h {
            for x in 0..self.w {
                // level k detail bands live at coords where
                // max(y,x) ∈ [size_k/2, size_k) for size_k = h>>.. — rank by
                // the smallest block that contains the coefficient.
                let mut rank = 0usize;
                for lvl in (1..=self.levels).rev() {
                    let bh = self.h >> lvl;
                    let bw = self.w >> lvl;
                    if y < bh && x < bw {
                        break;
                    }
                    rank += 1;
                    if y < 2 * bh && x < 2 * bw {
                        break;
                    }
                }
                keyed.push((rank, y * self.w + x));
            }
        }
        keyed.sort();
        keyed.into_iter().map(|(_, i)| i).collect()
    }
}

impl SequenceTransform for HaarDwt2d {
    fn name(&self) -> &'static str {
        "haar-dwt-2d"
    }

    fn seq_len(&self) -> usize {
        self.h * self.w
    }

    fn forward(&self, x: &Tensor) -> Tensor {
        assert_eq!(x.rows(), self.h * self.w);
        let d = x.cols();
        let mut t = x.clone();
        let (mut ah, mut aw) = (self.h, self.w);
        for _ in 0..self.levels {
            self.step_x(&mut t, ah, aw);
            self.step_y(&mut t, ah, aw);
            ah /= 2;
            aw /= 2;
        }
        // Reorder so low-pass coefficients lead the sequence.
        let order = self.output_order();
        let mut out = Tensor::zeros(&[self.h * self.w, d]);
        for (dst, &src) in order.iter().enumerate() {
            out.row_mut(dst).copy_from_slice(&t.data()[src * d..(src + 1) * d]);
        }
        out
    }

    fn inverse(&self, y: &Tensor) -> Tensor {
        assert_eq!(y.rows(), self.h * self.w);
        let d = y.cols();
        // Undo the reorder.
        let order = self.output_order();
        let mut t = Tensor::zeros(&[self.h * self.w, d]);
        for (src, &dst) in order.iter().enumerate() {
            t.row_mut(dst).copy_from_slice(&y.data()[src * d..(src + 1) * d]);
        }
        let (mut ah, mut aw) = (self.h >> self.levels, self.w >> self.levels);
        for _ in 0..self.levels {
            ah *= 2;
            aw *= 2;
            self.step_y_inv(&mut t, ah, aw);
            self.step_x_inv(&mut t, ah, aw);
        }
        t
    }

    fn flops(&self, d: usize) -> u64 {
        let mut total = 0u64;
        let (mut ah, mut aw) = (self.h as u64, self.w as u64);
        for _ in 0..self.levels {
            // x-pass + y-pass, each 2·(active cells)·d flops.
            total += 4 * ah * aw * d as u64;
            ah /= 2;
            aw /= 2;
        }
        total
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transforms::SequenceTransform;

    #[test]
    fn single_level_known_values() {
        // x = [1, 3] per feature → avg = 4/√2, det = −2/√2.
        let x = Tensor::from_vec(&[2, 1], vec![1.0, 3.0]);
        let t = HaarDwt::new(2, 1);
        let y = t.forward(&x);
        assert!((y.at(0, 0) - 4.0 * SQRT1_2).abs() < 1e-6);
        assert!((y.at(1, 0) + 2.0 * SQRT1_2).abs() < 1e-6);
    }

    #[test]
    fn constant_signal_concentrates_fully() {
        // A constant sequence has ALL energy in the single approximation
        // coefficient after a full pyramid.
        let s = 64;
        let x = Tensor::full(&[s, 4], 1.0);
        let t = HaarDwt::new(s, HaarDwt::max_levels(s));
        let y = t.forward(&x);
        let e0: f32 = y.row(0).iter().map(|v| v * v).sum();
        let etot = y.sq_norm() as f32;
        assert!((e0 / etot - 1.0).abs() < 1e-5);
    }

    #[test]
    fn smooth_signal_energy_in_prefix() {
        // AR(1)-like smooth ramp: ≥90% of energy in the first s/8 tokens
        // after 3 levels.
        let s = 128;
        let d = 8;
        let mut x = Tensor::zeros(&[s, d]);
        for i in 0..s {
            for j in 0..d {
                x.set(i, j, ((i as f32) * 0.05 + j as f32).sin());
            }
        }
        let t = HaarDwt::new(s, 3);
        let y = t.forward(&x);
        let prefix: f64 = (0..s / 8).map(|i| y.row(i).iter().map(|v| (*v as f64).powi(2)).sum::<f64>()).sum();
        assert!(prefix / y.sq_norm() > 0.9, "prefix share {}", prefix / y.sq_norm());
    }

    #[test]
    fn multilevel_roundtrip() {
        let x = Tensor::randn(&[256, 16], 42);
        for levels in 1..=4 {
            let t = HaarDwt::new(256, levels);
            let err = t.inverse(&t.forward(&x)).max_abs_diff(&x);
            assert!(err < 1e-5, "levels={levels} err={err}");
        }
    }

    #[test]
    fn dwt2d_roundtrip_and_energy() {
        let (h, w, d) = (16, 16, 8);
        // Smooth 2-D field.
        let mut x = Tensor::zeros(&[h * w, d]);
        for y in 0..h {
            for xg in 0..w {
                for j in 0..d {
                    x.set(y * w + xg, j, ((y as f32) * 0.2).cos() + ((xg as f32) * 0.15).sin());
                }
            }
        }
        let t = HaarDwt2d::new(h, w, 2);
        let f = t.forward(&x);
        assert!(t.inverse(&f).max_abs_diff(&x) < 1e-5);
        // Energy preserved.
        assert!(((f.sq_norm() - x.sq_norm()) / x.sq_norm()).abs() < 1e-6);
        // Low-pass block = first h*w/16 tokens after 2 levels holds most energy.
        let k = h * w / 16;
        let prefix: f64 = (0..k).map(|i| f.row(i).iter().map(|v| (*v as f64).powi(2)).sum::<f64>()).sum();
        assert!(prefix / f.sq_norm() > 0.95, "2-D prefix share {}", prefix / f.sq_norm());
    }

    #[test]
    fn output_order_is_permutation() {
        let t = HaarDwt2d::new(8, 8, 3);
        let mut order = t.output_order();
        order.sort();
        assert_eq!(order, (0..64).collect::<Vec<_>>());
    }

    #[test]
    #[should_panic]
    fn rejects_indivisible_length() {
        HaarDwt::new(48, 5);
    }

    #[test]
    fn flops_scale_linearly_in_d() {
        let t = HaarDwt::new(128, 3);
        assert_eq!(t.flops(16) * 2, t.flops(32));
    }
}
