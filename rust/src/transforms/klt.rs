//! Karhunen–Loève transform: the *optimal* energy-concentrating sequence
//! transform (paper §3.2, Eq. 9). `L = Uᵀ` where `S = E[XXᵀ] = U Λ Uᵀ`.
//!
//! KLT needs a calibration set to estimate `S` and costs a full `s×s`
//! matmul per application, so the paper uses it only as the optimality
//! reference that DCT/WHT/DWT are compared against (Fig. 3b) — we do the
//! same: the eval harness calibrates a KLT per activation site and reports
//! its energy spectrum next to the cheap transforms'.

use super::SequenceTransform;
use crate::linalg::eigh;
use crate::tensor::{matmul, Tensor};

/// Calibrated KLT sequence transform.
pub struct KltTransform {
    s: usize,
    /// Rows = eigenvectors of S, descending eigenvalue order.
    basis: Tensor,
    /// Eigenvalues (descending) = energies of the transformed tokens.
    energies: Vec<f32>,
}

impl KltTransform {
    /// Calibrate from activation samples: `samples` is a list of `s×d`
    /// matrices drawn from the target distribution.
    pub fn calibrate(samples: &[Tensor]) -> Self {
        assert!(!samples.is_empty(), "KLT needs at least one calibration sample");
        let s = samples[0].rows();
        let mut cov = Tensor::zeros(&[s, s]);
        let mut count = 0usize;
        for x in samples {
            assert_eq!(x.rows(), s, "inconsistent sequence length in calibration set");
            // S += X Xᵀ (accumulated across features and samples).
            let xxt = matmul(x, &x.transpose());
            cov = cov.add(&xxt);
            count += x.cols();
        }
        cov = cov.scale(1.0 / count as f32);
        Self::from_autocorrelation(&cov)
    }

    /// Build directly from a known autocorrelation matrix `S`.
    pub fn from_autocorrelation(cov: &Tensor) -> Self {
        let s = cov.rows();
        let eig = eigh(cov, 60, 1e-9);
        KltTransform { s, basis: eig.vectors, energies: eig.values }
    }

    /// Per-token energies of the transformed sequence (the λᵢ of Fig. 3b).
    pub fn energies(&self) -> &[f32] {
        &self.energies
    }
}

impl SequenceTransform for KltTransform {
    fn name(&self) -> &'static str {
        "klt"
    }

    fn seq_len(&self) -> usize {
        self.s
    }

    fn forward(&self, x: &Tensor) -> Tensor {
        assert_eq!(x.rows(), self.s);
        matmul(&self.basis, x)
    }

    fn inverse(&self, y: &Tensor) -> Tensor {
        assert_eq!(y.rows(), self.s);
        matmul(&self.basis.transpose(), y)
    }

    fn flops(&self, d: usize) -> u64 {
        // Full matmul: 2 s² d — the "impractical" cost the paper notes.
        2 * (self.s as u64) * (self.s as u64) * d as u64
    }

    fn matrix(&self) -> Tensor {
        self.basis.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{ar1_covariance, orthogonality_defect};
    use crate::tensor::Tensor;

    #[test]
    fn roundtrip_and_orthogonality() {
        let cov = ar1_covariance(32, 0.9, 1.0);
        let t = KltTransform::from_autocorrelation(&cov);
        assert!(orthogonality_defect(&t.matrix()) < 1e-4);
        let x = Tensor::randn(&[32, 5], 3);
        assert!(t.inverse(&t.forward(&x)).max_abs_diff(&x) < 1e-4);
    }

    #[test]
    fn energies_descending() {
        let cov = ar1_covariance(24, 0.8, 1.0);
        let t = KltTransform::from_autocorrelation(&cov);
        for w in t.energies().windows(2) {
            assert!(w[0] >= w[1] - 1e-4);
        }
    }

    #[test]
    fn klt_beats_identity_energy_concentration() {
        // Sample AR(1) sequences; transformed prefix energy must dominate
        // the untransformed prefix energy.
        let s = 32;
        let cov = ar1_covariance(s, 0.95, 1.0);
        let l = crate::linalg::cholesky(&cov);
        let mut samples = Vec::new();
        for seed in 0..8u64 {
            let z = Tensor::randn(&[s, 16], seed);
            samples.push(l.matmul(&z));
        }
        let t = KltTransform::calibrate(&samples);
        let x = {
            let z = Tensor::randn(&[s, 16], 99);
            l.matmul(&z)
        };
        let y = t.forward(&x);
        let prefix_energy = |m: &Tensor, k: usize| -> f64 {
            (0..k).map(|i| m.row(i).iter().map(|v| (*v as f64).powi(2)).sum::<f64>()).sum()
        };
        let k = s / 4;
        assert!(prefix_energy(&y, k) > 2.0 * prefix_energy(&x, k));
    }

    #[test]
    fn calibrated_energies_match_empirical() {
        let s = 16;
        let cov = ar1_covariance(s, 0.9, 1.0);
        let t = KltTransform::from_autocorrelation(&cov);
        // lᵢᵀ S lᵢ must equal the eigenvalue.
        let m = t.matrix();
        for i in 0..s {
            let mut e = 0.0f64;
            for a in 0..s {
                for b in 0..s {
                    e += (m.at(i, a) * cov.at(a, b) * m.at(i, b)) as f64;
                }
            }
            assert!((e - t.energies()[i] as f64).abs() < 1e-3, "token {i}");
        }
    }
}
