//! Sequency-ordered Walsh–Hadamard transform along the sequence dimension.
//!
//! The paper's middle option (§3.2): retain only the *sign* of the Fourier
//! coefficients, which approximates the DCT while allowing an add/sub-only
//! butterfly (Fino & Algazi 1976) — O(s log s) with no multiplies beyond
//! the final 1/√s normalization. Rows are permuted from Hadamard (natural)
//! order to **sequency** order so that, like the DCT, low-index outputs
//! carry the smooth (high-energy) content of locally-correlated sequences.

use super::SequenceTransform;
use crate::tensor::Tensor;

/// Sequency-ordered WHT; requires power-of-two sequence length.
pub struct WhtTransform {
    s: usize,
    /// `perm[k]` = natural-order Hadamard row carrying sequency rank k.
    perm: Vec<usize>,
    /// Inverse permutation.
    inv_perm: Vec<usize>,
}

impl WhtTransform {
    pub fn new(s: usize) -> Self {
        assert!(s.is_power_of_two(), "WHT needs power-of-two length, got {s}");
        // Natural-order Hadamard row h has H[h, n] = (−1)^{popcount(h & n)}.
        // Its sequency (number of sign changes over n = 0..s−1) is computed
        // directly; sorting rows by sequency yields the Walsh ordering.
        let mut seq_of_row: Vec<(usize, usize)> = (0..s)
            .map(|h| {
                let mut changes = 0usize;
                let mut prev = 1i32;
                for n in 0..s {
                    let sign = if (h & n).count_ones() % 2 == 0 { 1 } else { -1 };
                    if n > 0 && sign != prev {
                        changes += 1;
                    }
                    prev = sign;
                }
                (changes, h)
            })
            .collect();
        seq_of_row.sort();
        let perm: Vec<usize> = seq_of_row.into_iter().map(|(_, h)| h).collect();
        let mut inv_perm = vec![0usize; s];
        for (k, &h) in perm.iter().enumerate() {
            inv_perm[h] = k;
        }
        WhtTransform { s, perm, inv_perm }
    }

    /// In-place natural-order fast WHT butterfly over rows (unnormalized).
    fn fwht_rows(x: &mut Tensor) {
        let s = x.rows();
        let d = x.cols();
        let data = x.data_mut();
        let mut len = 1usize;
        while len < s {
            let stride = len * 2;
            for base in (0..s).step_by(stride) {
                for i in base..base + len {
                    let (a_off, b_off) = (i * d, (i + len) * d);
                    for j in 0..d {
                        let a = data[a_off + j];
                        let b = data[b_off + j];
                        data[a_off + j] = a + b;
                        data[b_off + j] = a - b;
                    }
                }
            }
            len = stride;
        }
    }
}

impl SequenceTransform for WhtTransform {
    fn name(&self) -> &'static str {
        "wht"
    }

    fn seq_len(&self) -> usize {
        self.s
    }

    fn forward(&self, x: &Tensor) -> Tensor {
        assert_eq!(x.rows(), self.s);
        let d = x.cols();
        let mut t = x.clone();
        Self::fwht_rows(&mut t);
        let scale = 1.0 / (self.s as f32).sqrt();
        // Permute natural order → sequency order and normalize.
        let mut out = Tensor::zeros(&[self.s, d]);
        for k in 0..self.s {
            let src = self.perm[k] * d;
            let dst = out.row_mut(k);
            for j in 0..d {
                dst[j] = t.data()[src + j] * scale;
            }
        }
        out
    }

    fn inverse(&self, y: &Tensor) -> Tensor {
        assert_eq!(y.rows(), self.s);
        let d = y.cols();
        // Un-permute, then apply the self-inverse butterfly.
        let mut t = Tensor::zeros(&[self.s, d]);
        for h in 0..self.s {
            let src = self.inv_perm[h] * d;
            t.row_mut(h).copy_from_slice(&y.data()[src..src + d]);
        }
        Self::fwht_rows(&mut t);
        let scale = 1.0 / (self.s as f32).sqrt();
        t.map_inplace(|v| v * scale);
        t
    }

    fn flops(&self, d: usize) -> u64 {
        // s log₂ s add/subs per feature + s normalizing multiplies.
        let s = self.s as u64;
        let logs = s.trailing_zeros() as u64;
        (s * logs + s) * d as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::orthogonality_defect;

    #[test]
    fn matches_explicit_hadamard_4() {
        let t = WhtTransform::new(4);
        let m = t.matrix();
        // Sequency-ordered Walsh rows for s=4 (normalized by 1/2):
        // [+ + + +], [+ + − −], [+ − − +], [+ − + −]
        let want = [
            [0.5, 0.5, 0.5, 0.5],
            [0.5, 0.5, -0.5, -0.5],
            [0.5, -0.5, -0.5, 0.5],
            [0.5, -0.5, 0.5, -0.5],
        ];
        for i in 0..4 {
            for j in 0..4 {
                assert!((m.at(i, j) - want[i][j]).abs() < 1e-6, "({i},{j})");
            }
        }
    }

    #[test]
    fn sequency_is_monotone() {
        let t = WhtTransform::new(32);
        let m = t.matrix();
        let mut prev = 0usize;
        for k in 0..32 {
            let mut changes = 0usize;
            for n in 1..32 {
                if (m.at(k, n) > 0.0) != (m.at(k, n - 1) > 0.0) {
                    changes += 1;
                }
            }
            assert!(changes >= prev, "row {k}: sequency {changes} < {prev}");
            assert_eq!(changes, k, "Walsh row k has exactly k sign changes");
            prev = changes;
        }
    }

    #[test]
    fn orthonormal_and_roundtrip() {
        let t = WhtTransform::new(64);
        assert!(orthogonality_defect(&t.matrix()) < 1e-5);
        let x = Tensor::randn(&[64, 9], 10);
        assert!(t.inverse(&t.forward(&x)).max_abs_diff(&x) < 1e-5);
    }

    #[test]
    fn constant_signal_to_first_row() {
        let t = WhtTransform::new(16);
        let x = Tensor::full(&[16, 2], 1.0);
        let y = t.forward(&x);
        let e0: f64 = y.row(0).iter().map(|v| (*v as f64).powi(2)).sum();
        assert!((e0 / y.sq_norm() - 1.0).abs() < 1e-6);
    }

    #[test]
    #[should_panic]
    fn rejects_non_power_of_two() {
        WhtTransform::new(24);
    }
}
