//! Function-preserving transforms.
//!
//! The paper distinguishes two families (Eq. 6):
//!
//! * **Sequence transforms** `L` — (left-)invertible matrices applied along
//!   the *sequence* dimension: `X → L X`. Orthogonal `L` preserves total
//!   energy and the quantization error is exactly the error of the
//!   transformed matrix (Theorem 1, Eq. 10). Implementations: [`KltTransform`]
//!   (optimal, calibration-time eigenbasis of `E[XXᵀ]`), [`DctTransform`]
//!   (Szegő approximation for Toeplitz autocorrelation), [`WhtTransform`]
//!   (sign-only DCT approximation), [`HaarDwt`] / [`HaarDwt2d`] (the O(sd)
//!   transform the paper ships), and [`IdentitySeq`].
//! * **Feature transforms** `R` — applied along the feature dimension:
//!   `X → X R`, with `R⁻¹` fused into the following weight. Implementations:
//!   [`HadamardFeature`] (QuaRot-style randomized Hadamard),
//!   [`ScalingFeature`] (SmoothQuant per-channel scaling), and
//!   [`AffineFeature`] (FlatQuant-lite calibrated affine).

mod dct;
mod feature;
mod haar;
mod klt;
mod wht;

pub use dct::DctTransform;
pub use feature::{AffineFeature, HadamardFeature, IdentityFeature, ScalingFeature};
pub use haar::{HaarDwt, HaarDwt2d};
pub use klt::KltTransform;
pub use wht::WhtTransform;

use crate::tensor::Tensor;

/// An invertible linear transform applied along the sequence dimension.
///
/// Implementations must satisfy `inverse(forward(x)) == x` (up to float
/// round-off) for any `x` with `x.rows() == seq_len()`, and orthogonal
/// implementations additionally preserve the Frobenius norm.
///
/// The round-trip contract, checked here for every shipped transform:
///
/// ```
/// use stamp::tensor::Tensor;
/// use stamp::transforms::{
///     DctTransform, HaarDwt, IdentitySeq, SequenceTransform, WhtTransform,
/// };
///
/// let x = Tensor::randn(&[64, 8], 7);
/// let transforms: Vec<Box<dyn SequenceTransform>> = vec![
///     Box::new(IdentitySeq::new(64)),
///     Box::new(HaarDwt::new(64, 3)),
///     Box::new(DctTransform::new(64)),
///     Box::new(WhtTransform::new(64)),
/// ];
/// for t in &transforms {
///     let roundtrip = t.inverse(&t.forward(&x));
///     assert!(
///         roundtrip.max_abs_diff(&x) < 1e-4,
///         "{} does not invert its forward",
///         t.name()
///     );
/// }
/// ```
pub trait SequenceTransform: Send + Sync {
    fn name(&self) -> &'static str;

    /// Sequence length this instance was built for.
    fn seq_len(&self) -> usize;

    /// `L X`.
    fn forward(&self, x: &Tensor) -> Tensor;

    /// `L⁻¹ Y`.
    fn inverse(&self, y: &Tensor) -> Tensor;

    /// Whether `L` is orthogonal (`L⁻¹ = Lᵀ`); true for everything here.
    fn orthogonal(&self) -> bool {
        true
    }

    /// Floating-point ops for one forward application on an `s×d` input.
    /// Used by the Table-3 overhead harness.
    fn flops(&self, d: usize) -> u64;

    /// Materialize `L` (s×s) by transforming the identity. Slow; used in
    /// tests and for the Figure-3c basis visualizations.
    fn matrix(&self) -> Tensor {
        let s = self.seq_len();
        self.forward(&Tensor::eye(s))
    }
}

/// Identity sequence transform (the "no STaMP" arm of every ablation).
pub struct IdentitySeq {
    s: usize,
}

impl IdentitySeq {
    pub fn new(s: usize) -> Self {
        IdentitySeq { s }
    }
}

impl SequenceTransform for IdentitySeq {
    fn name(&self) -> &'static str {
        "identity"
    }
    fn seq_len(&self) -> usize {
        self.s
    }
    fn forward(&self, x: &Tensor) -> Tensor {
        assert_eq!(x.rows(), self.s);
        x.clone()
    }
    fn inverse(&self, y: &Tensor) -> Tensor {
        y.clone()
    }
    fn flops(&self, _d: usize) -> u64 {
        0
    }
}

/// An invertible linear transform applied along the feature dimension.
pub trait FeatureTransform: Send + Sync {
    fn name(&self) -> &'static str;

    /// Feature width this instance was built for.
    fn dim(&self) -> usize;

    /// `X R`.
    fn apply(&self, x: &Tensor) -> Tensor;

    /// `Y R⁻¹`.
    fn invert(&self, y: &Tensor) -> Tensor;

    /// Fuse `R⁻¹` into a following weight stored `[in, out]`: `W → R⁻¹ W`,
    /// so that `(X R)(R⁻¹ W) = X W` and the inverse costs nothing at
    /// runtime (paper §2.2 / Ashkboos et al. 2024).
    fn fuse_into_weight(&self, w: &Tensor) -> Tensor;

    /// FLOPs for one application on an `s×d` input.
    fn flops(&self, s: usize) -> u64;
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Shared contract test: reconstruction + energy preservation for every
    /// orthogonal sequence transform at several sizes.
    fn check_seq_contract(t: &dyn SequenceTransform, d: usize, seed: u64) {
        let s = t.seq_len();
        let x = Tensor::randn(&[s, d], seed);
        let y = t.forward(&x);
        assert_eq!(y.shape(), x.shape(), "{} shape", t.name());
        let back = t.inverse(&y);
        let err = back.max_abs_diff(&x);
        assert!(err < 1e-4, "{} reconstruction err {}", t.name(), err);
        if t.orthogonal() {
            let rel = (y.sq_norm() - x.sq_norm()).abs() / x.sq_norm();
            assert!(rel < 1e-5, "{} energy not preserved: rel {}", t.name(), rel);
        }
    }

    #[test]
    fn identity_contract() {
        check_seq_contract(&IdentitySeq::new(17), 5, 1);
    }

    #[test]
    fn all_transforms_contract() {
        for s in [16usize, 64, 256] {
            check_seq_contract(&HaarDwt::new(s, 3), 8, 2);
            check_seq_contract(&DctTransform::new(s), 8, 3);
            check_seq_contract(&WhtTransform::new(s), 8, 4);
        }
        check_seq_contract(&HaarDwt2d::new(8, 8, 2), 8, 5);
    }

    #[test]
    fn matrices_are_orthogonal() {
        use crate::linalg::orthogonality_defect;
        for t in [
            Box::new(HaarDwt::new(32, 3)) as Box<dyn SequenceTransform>,
            Box::new(DctTransform::new(32)),
            Box::new(WhtTransform::new(32)),
            Box::new(HaarDwt2d::new(4, 8, 2)),
        ] {
            let m = t.matrix();
            assert!(
                orthogonality_defect(&m) < 1e-4,
                "{} defect {}",
                t.name(),
                orthogonality_defect(&m)
            );
        }
    }
}
