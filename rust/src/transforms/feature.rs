//! Feature-dimension transforms `R` (paper §2.2): applied as `X → X R`
//! before quantization, with `R⁻¹` fused into the next linear layer's
//! weight so the inverse is free at inference time.

use super::FeatureTransform;
use crate::tensor::{matmul, Tensor, XorShiftRng};

/// Identity feature transform.
pub struct IdentityFeature {
    d: usize,
}

impl IdentityFeature {
    pub fn new(d: usize) -> Self {
        IdentityFeature { d }
    }
}

impl FeatureTransform for IdentityFeature {
    fn name(&self) -> &'static str {
        "identity"
    }
    fn dim(&self) -> usize {
        self.d
    }
    fn apply(&self, x: &Tensor) -> Tensor {
        assert_eq!(x.cols(), self.d);
        x.clone()
    }
    fn invert(&self, y: &Tensor) -> Tensor {
        y.clone()
    }
    fn fuse_into_weight(&self, w: &Tensor) -> Tensor {
        w.clone()
    }
    fn flops(&self, _s: usize) -> u64 {
        0
    }
}

/// QuaRot-style randomized Hadamard rotation: `R = H D / √d` with `D` a
/// random ±1 diagonal. Spreads activation outliers across all channels,
/// flattening the per-token range (Eq. 5). Orthogonal, so `R⁻¹ = Rᵀ`.
pub struct HadamardFeature {
    d: usize,
    /// Random sign diagonal.
    signs: Vec<f32>,
}

impl HadamardFeature {
    pub fn new(d: usize, seed: u64) -> Self {
        assert!(d.is_power_of_two(), "Hadamard needs power-of-two dim, got {d}");
        let mut rng = XorShiftRng::new(seed);
        let signs = (0..d).map(|_| if rng.next_f32() < 0.5 { -1.0 } else { 1.0 }).collect();
        HadamardFeature { d, signs }
    }

    /// In-place fast Walsh–Hadamard butterfly over the columns of one row.
    fn fwht_row(row: &mut [f32]) {
        let d = row.len();
        let mut len = 1usize;
        while len < d {
            let stride = len * 2;
            for base in (0..d).step_by(stride) {
                for i in base..base + len {
                    let a = row[i];
                    let b = row[i + len];
                    row[i] = a + b;
                    row[i + len] = a - b;
                }
            }
            len = stride;
        }
    }

    /// `X D H / √d` applied row-wise.
    fn transform(&self, x: &Tensor, pre_sign: bool) -> Tensor {
        assert_eq!(x.cols(), self.d);
        let mut out = x.clone();
        let scale = 1.0 / (self.d as f32).sqrt();
        for i in 0..out.rows() {
            let row = out.row_mut(i);
            if pre_sign {
                for (v, s) in row.iter_mut().zip(&self.signs) {
                    *v *= s;
                }
            }
            Self::fwht_row(row);
            for v in row.iter_mut() {
                *v *= scale;
            }
            if !pre_sign {
                for (v, s) in row.iter_mut().zip(&self.signs) {
                    *v *= s;
                }
            }
        }
        out
    }
}

impl FeatureTransform for HadamardFeature {
    fn name(&self) -> &'static str {
        "hadamard"
    }

    fn dim(&self) -> usize {
        self.d
    }

    /// `X R` with `R = D H/√d`.
    fn apply(&self, x: &Tensor) -> Tensor {
        self.transform(x, true)
    }

    /// `Y R⁻¹` with `R⁻¹ = Rᵀ = (H/√d) D` (H symmetric, D diagonal ±1).
    fn invert(&self, y: &Tensor) -> Tensor {
        self.transform(y, false)
    }

    /// `R⁻¹ W` for `W` stored `[in, out]`: apply `Rᵀ` to the *rows*, i.e.
    /// transform `Wᵀ` columns — equivalently `((Wᵀ) R)ᵀ` using apply on Wᵀ.
    fn fuse_into_weight(&self, w: &Tensor) -> Tensor {
        assert_eq!(w.rows(), self.d, "weight [in,out] must have in=dim");
        // R⁻¹ W = (Wᵀ R)ᵀ because R⁻¹ = Rᵀ.
        self.apply(&w.transpose()).transpose()
    }

    fn flops(&self, s: usize) -> u64 {
        let d = self.d as u64;
        let logd = d.trailing_zeros() as u64;
        // butterfly + sign + scale per row.
        (d * logd + 2 * d) * s as u64
    }
}

/// SmoothQuant-style per-channel scaling: `R = diag(1/λ_j)` with
/// `λ_j = max|x_j|^α / max|w_j|^{1−α}` — shifts quantization difficulty
/// from activations to weights (Xiao et al., 2023).
pub struct ScalingFeature {
    d: usize,
    /// Per-channel divisor λ_j applied to activations.
    lambdas: Vec<f32>,
}

impl ScalingFeature {
    /// Calibrate from per-channel activation max and weight max.
    pub fn calibrate(act_absmax: &[f32], w_absmax: &[f32], alpha: f32) -> Self {
        assert_eq!(act_absmax.len(), w_absmax.len());
        let lambdas = act_absmax
            .iter()
            .zip(w_absmax)
            .map(|(&a, &w)| {
                let a = a.max(1e-5);
                let w = w.max(1e-5);
                (a.powf(alpha) / w.powf(1.0 - alpha)).max(1e-5)
            })
            .collect();
        ScalingFeature { d: act_absmax.len(), lambdas }
    }

    pub fn from_lambdas(lambdas: Vec<f32>) -> Self {
        ScalingFeature { d: lambdas.len(), lambdas }
    }

    pub fn lambdas(&self) -> &[f32] {
        &self.lambdas
    }
}

impl FeatureTransform for ScalingFeature {
    fn name(&self) -> &'static str {
        "smoothquant-scale"
    }

    fn dim(&self) -> usize {
        self.d
    }

    fn apply(&self, x: &Tensor) -> Tensor {
        assert_eq!(x.cols(), self.d);
        let mut out = x.clone();
        for i in 0..out.rows() {
            for (v, l) in out.row_mut(i).iter_mut().zip(&self.lambdas) {
                *v /= l;
            }
        }
        out
    }

    fn invert(&self, y: &Tensor) -> Tensor {
        assert_eq!(y.cols(), self.d);
        let mut out = y.clone();
        for i in 0..out.rows() {
            for (v, l) in out.row_mut(i).iter_mut().zip(&self.lambdas) {
                *v *= l;
            }
        }
        out
    }

    /// `diag(λ) W` — scale weight rows up to compensate.
    fn fuse_into_weight(&self, w: &Tensor) -> Tensor {
        assert_eq!(w.rows(), self.d);
        let mut out = w.clone();
        for i in 0..self.d {
            let l = self.lambdas[i];
            for v in out.row_mut(i) {
                *v *= l;
            }
        }
        out
    }

    fn flops(&self, s: usize) -> u64 {
        (self.d as u64) * s as u64
    }
}

/// FlatQuant-lite: a calibrated affine feature transform `R` (here a
/// whitening-style rotation-plus-scale learned from per-channel second
/// moments), with explicit inverse. Stands in for FlatQuant's
/// Kronecker-factored learned transform (Sun et al., 2025) — same
/// interface, same role in the baseline stack, calibration is closed-form
/// instead of 15-epoch gradient descent.
pub struct AffineFeature {
    d: usize,
    r: Tensor,
    r_inv: Tensor,
}

impl AffineFeature {
    /// Calibrate: whiten per-channel scale, then apply a fixed Hadamard
    /// rotation — `R = diag(1/σ_j) H/√d`, `R⁻¹ = (H/√d)ᵀ diag(σ_j)`.
    pub fn calibrate(x_samples: &[Tensor], seed: u64) -> Self {
        assert!(!x_samples.is_empty());
        let d = x_samples[0].cols();
        assert!(d.is_power_of_two(), "AffineFeature needs power-of-two dim");
        // Per-channel RMS.
        let mut ms = vec![0.0f64; d];
        let mut n = 0usize;
        for x in x_samples {
            assert_eq!(x.cols(), d);
            for i in 0..x.rows() {
                for (j, &v) in x.row(i).iter().enumerate() {
                    ms[j] += (v as f64) * (v as f64);
                }
            }
            n += x.rows();
        }
        let sigma: Vec<f32> = ms.iter().map(|&m| ((m / n as f64).sqrt() as f32).max(1e-4)).collect();

        let had = HadamardFeature::new(d, seed);
        // R = diag(1/σ) applied first, then Hadamard rotation: build dense
        // matrices once at calibration time (runtime uses them via matmul;
        // the dense form also lets tests verify exact invertibility).
        let mut scale = Tensor::zeros(&[d, d]);
        for j in 0..d {
            scale.set(j, j, 1.0 / sigma[j]);
        }
        let h = had.apply(&Tensor::eye(d)); // rows i: e_i R_h
        let r = scale.matmul(&h);
        let mut unscale = Tensor::zeros(&[d, d]);
        for j in 0..d {
            unscale.set(j, j, sigma[j]);
        }
        let r_inv = h.transpose().matmul(&unscale);
        AffineFeature { d, r, r_inv }
    }

    pub fn from_matrices(r: Tensor, r_inv: Tensor) -> Self {
        assert_eq!(r.rows(), r.cols());
        let d = r.rows();
        AffineFeature { d, r, r_inv }
    }
}

impl FeatureTransform for AffineFeature {
    fn name(&self) -> &'static str {
        "flatquant-affine"
    }

    fn dim(&self) -> usize {
        self.d
    }

    fn apply(&self, x: &Tensor) -> Tensor {
        matmul(x, &self.r)
    }

    fn invert(&self, y: &Tensor) -> Tensor {
        matmul(y, &self.r_inv)
    }

    fn fuse_into_weight(&self, w: &Tensor) -> Tensor {
        matmul(&self.r_inv, w)
    }

    fn flops(&self, s: usize) -> u64 {
        2 * (self.d as u64) * (self.d as u64) * s as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn check_feature_contract(t: &dyn FeatureTransform, s: usize, seed: u64, tol: f32) {
        let x = Tensor::randn(&[s, t.dim()], seed);
        let y = t.apply(&x);
        let back = t.invert(&y);
        assert!(back.max_abs_diff(&x) < tol, "{} reconstruction", t.name());

        // Function preservation: (X R)(R⁻¹ W) == X W.
        let w = Tensor::randn(&[t.dim(), 12], seed + 1);
        let fused = t.fuse_into_weight(&w);
        let a = y.matmul(&fused);
        let b = x.matmul(&w);
        let rel = a.max_abs_diff(&b) / b.abs_max().max(1e-6);
        assert!(rel < 1e-3, "{} function preservation rel {}", t.name(), rel);
    }

    #[test]
    fn identity_contract() {
        check_feature_contract(&IdentityFeature::new(16), 7, 1, 1e-6);
    }

    #[test]
    fn hadamard_contract() {
        check_feature_contract(&HadamardFeature::new(64, 5), 9, 2, 1e-4);
    }

    #[test]
    fn hadamard_is_orthogonal() {
        let t = HadamardFeature::new(32, 3);
        let r = t.apply(&Tensor::eye(32));
        assert!(crate::linalg::orthogonality_defect(&r) < 1e-5);
    }

    #[test]
    fn hadamard_flattens_outliers() {
        // One massive outlier channel → after rotation, per-row range shrinks.
        let s = 16;
        let d = 64;
        let mut x = Tensor::randn(&[s, d], 8);
        for i in 0..s {
            x.set(i, 3, 100.0); // outlier channel
        }
        let t = HadamardFeature::new(d, 1);
        let y = t.apply(&x);
        let range = |m: &Tensor| -> f32 {
            (0..s)
                .map(|i| {
                    let r = m.row(i);
                    let mx = r.iter().cloned().fold(f32::MIN, f32::max);
                    let mn = r.iter().cloned().fold(f32::MAX, f32::min);
                    mx - mn
                })
                .sum::<f32>()
                / s as f32
        };
        assert!(range(&y) < 0.5 * range(&x), "{} vs {}", range(&y), range(&x));
    }

    #[test]
    fn scaling_contract() {
        let act = vec![10.0; 16];
        let w = vec![1.0; 16];
        let t = ScalingFeature::calibrate(&act, &w, 0.5);
        check_feature_contract(&t, 5, 4, 1e-4);
        // α=0.5 with act=10,w=1 → λ=√10.
        assert!((t.lambdas()[0] - 10f32.sqrt()).abs() < 1e-4);
    }

    #[test]
    fn scaling_reduces_activation_range() {
        let mut x = Tensor::randn(&[8, 4], 6);
        for i in 0..8 {
            x.set(i, 0, x.at(i, 0) * 50.0);
        }
        let act_max: Vec<f32> =
            (0..4).map(|j| (0..8).map(|i| x.at(i, j).abs()).fold(0.0, f32::max)).collect();
        let w_max = vec![1.0; 4];
        let t = ScalingFeature::calibrate(&act_max, &w_max, 0.5);
        let y = t.apply(&x);
        assert!(y.abs_max() < 0.5 * x.abs_max());
    }

    #[test]
    fn affine_contract() {
        let samples: Vec<Tensor> = (0..4).map(|i| Tensor::randn(&[32, 16], i)).collect();
        let t = AffineFeature::calibrate(&samples, 7);
        check_feature_contract(&t, 8, 5, 1e-3);
    }
}
