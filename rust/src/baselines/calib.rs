//! Calibration: a hook that records, per activation site, the statistics
//! each baseline's transform construction needs — per-channel activation
//! absmax (SmoothQuant/ViDiT-Q), raw activation samples (FlatQuant / KLT /
//! QuaRot dimension discovery), per-in-channel weight absmax (SmoothQuant's
//! difficulty-shifting denominator), and the weight itself (SVDQuant).

use crate::model::LinearHook;
use crate::tensor::{matmul, Tensor};
use std::cell::RefCell;
use std::collections::HashMap;

/// Per-site calibration statistics.
#[derive(Clone, Default)]
pub struct SiteStats {
    /// Input feature width.
    pub dim: usize,
    /// Running per-channel |x| max.
    pub act_absmax: Vec<f32>,
    /// Per-in-channel |w| max (max over the output dimension).
    pub w_absmax: Vec<f32>,
    /// Up to `max_samples` full activation matrices.
    pub samples: Vec<Tensor>,
    /// The layer weight `[in, out]` (recorded once).
    pub weight: Option<Tensor>,
}

/// Recording hook; computes the FP result so calibration runs don't skew
/// downstream activations.
pub struct CalibHook {
    stats: RefCell<HashMap<String, SiteStats>>,
    max_samples: usize,
}

impl CalibHook {
    pub fn new(max_samples: usize) -> Self {
        CalibHook { stats: RefCell::new(HashMap::new()), max_samples }
    }

    pub fn take(self) -> HashMap<String, SiteStats> {
        self.stats.into_inner()
    }
}

impl LinearHook for CalibHook {
    fn linear(&self, site: &str, x: &Tensor, w: &Tensor, bias: Option<&[f32]>) -> Tensor {
        {
            let mut all = self.stats.borrow_mut();
            let st = all.entry(site.to_string()).or_default();
            if st.dim == 0 {
                st.dim = x.cols();
                st.act_absmax = vec![0.0; x.cols()];
                // Per-in-channel weight absmax = max over each row of [in,out].
                st.w_absmax = (0..w.rows())
                    .map(|i| w.row(i).iter().fold(0.0f32, |m, &v| m.max(v.abs())))
                    .collect();
                st.weight = Some(w.clone());
            }
            for i in 0..x.rows() {
                for (j, &v) in x.row(i).iter().enumerate() {
                    st.act_absmax[j] = st.act_absmax[j].max(v.abs());
                }
            }
            if st.samples.len() < self.max_samples {
                st.samples.push(x.clone());
            }
        }
        let mut y = matmul(x, w);
        if let Some(b) = bias {
            y = y.add_row_broadcast(b);
        }
        y
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{Gpt, GptConfig};

    #[test]
    fn records_all_gpt_sites() {
        let gpt = Gpt::new(GptConfig::tiny(), 1);
        let hook = CalibHook::new(2);
        let tokens: Vec<u32> = (0..32).map(|i| (i % 60) as u32).collect();
        let _ = gpt.logits_hooked(&hook, &tokens);
        let _ = gpt.logits_hooked(&hook, &tokens);
        let stats = hook.take();
        // 2 layers × {attn1, attn1.to_out, ffn.up_proj, ffn.down_proj}.
        assert!(stats.len() >= 8, "sites: {:?}", stats.keys().collect::<Vec<_>>());
        let st = &stats["layer0.attn1.to_q"];
        assert_eq!(st.dim, 64);
        assert_eq!(st.act_absmax.len(), 64);
        assert_eq!(st.w_absmax.len(), 64);
        assert_eq!(st.samples.len(), 2, "respects max_samples");
        assert!(st.weight.is_some());
        assert!(st.act_absmax.iter().all(|&m| m >= 0.0));
        assert!(st.act_absmax.iter().any(|&m| m > 0.0));
    }

    #[test]
    fn absmax_is_running_max() {
        let hook = CalibHook::new(0);
        let w = Tensor::eye(2);
        let x1 = Tensor::from_vec(&[1, 2], vec![1.0, -2.0]);
        let x2 = Tensor::from_vec(&[1, 2], vec![-3.0, 0.5]);
        let _ = hook.linear("s", &x1, &w, None);
        let _ = hook.linear("s", &x2, &w, None);
        let stats = hook.take();
        assert_eq!(stats["s"].act_absmax, vec![3.0, 2.0]);
    }
}
