//! Low-rank factorization via block power iteration — the SVDQuant
//! substrate. `W ≈ U·V` with `U: [in, r]`, `V: [r, out]` capturing the top
//! singular directions, so the residual `W − UV` has a much smaller dynamic
//! range and quantizes cleanly (Li et al., 2025).

use crate::tensor::{matmul, Tensor};

/// Top-`rank` factorization of `w` (`[in, out]`) by orthogonal (block
/// power) iteration on `WᵀW`. Returns `(U, V)` with `U·V ≈ W` capturing the
/// dominant singular subspace.
pub fn low_rank_factor(w: &Tensor, rank: usize, iters: usize) -> (Tensor, Tensor) {
    let (din, dout) = (w.rows(), w.cols());
    let r = rank.min(din.min(dout));
    // Initialize V-side basis with a deterministic random matrix.
    let mut q = Tensor::randn(&[dout, r], 0xBADC0FFE ^ (din * dout) as u64);
    orthonormalize_cols(&mut q);
    for _ in 0..iters {
        // q ← orth((WᵀW) q); computed as Wᵀ(W q) to stay O(din·dout·r).
        let wq = matmul(w, &q); // [in, r]
        let mut wtq = matmul(&w.transpose(), &wq); // [out, r]
        orthonormalize_cols(&mut wtq);
        q = wtq;
    }
    // V = qᵀ (right singular basis), U = W q.
    let u = matmul(w, &q); // [in, r] — carries the singular values
    let v = q.transpose(); // [r, out]
    (u, v)
}

/// Gram–Schmidt orthonormalization of the columns of `m` in place, with
/// re-orthogonalization ("twice is enough", Giraud et al.) and random
/// replacement of numerically-degenerate columns — without this, a
/// rank-deficient iterate leaves catastrophic-cancellation noise that is
/// *not* orthogonal to the leading columns and `q qᵀ` stops being a
/// projector.
fn orthonormalize_cols(m: &mut Tensor) {
    let (n, r) = (m.rows(), m.cols());
    let col_norm = |m: &Tensor, j: usize| -> f32 {
        (0..n).map(|i| m.at(i, j) * m.at(i, j)).sum::<f32>().sqrt()
    };
    let subtract_prev = |m: &mut Tensor, j: usize| {
        for k in 0..j {
            let mut dot = 0.0f32;
            for i in 0..n {
                dot += m.at(i, j) * m.at(i, k);
            }
            for i in 0..n {
                let v = m.at(i, j) - dot * m.at(i, k);
                m.set(i, j, v);
            }
        }
    };
    for j in 0..r {
        let orig = col_norm(m, j);
        subtract_prev(m, j);
        subtract_prev(m, j); // kill cancellation residue
        let mut norm = col_norm(m, j);
        if norm <= 1e-5 * orig.max(1e-20) {
            // Column collapsed (rank-deficient input): reseed with a
            // deterministic random direction and orthogonalize that.
            let mut rng = crate::tensor::XorShiftRng::new(0xC011_A92E ^ (j as u64 + 1));
            for i in 0..n {
                m.set(i, j, rng.next_gaussian());
            }
            subtract_prev(m, j);
            subtract_prev(m, j);
            norm = col_norm(m, j);
        }
        let inv = 1.0 / norm.max(1e-20);
        for i in 0..n {
            m.set(i, j, m.at(i, j) * inv);
        }
    }
}

/// Relative Frobenius error of the rank-`r` approximation.
pub fn low_rank_rel_error(w: &Tensor, u: &Tensor, v: &Tensor) -> f64 {
    let rec = matmul(u, v);
    (rec.sub(w).sq_norm() / w.sq_norm()).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::matmul_transb;

    #[test]
    fn exact_for_true_low_rank() {
        // W = a·bᵀ is rank 1; a rank-2 factorization must recover it.
        let a = Tensor::randn(&[24, 1], 1);
        let b = Tensor::randn(&[1, 16], 2);
        let w = matmul(&a, &b);
        let (u, v) = low_rank_factor(&w, 2, 15);
        assert!(low_rank_rel_error(&w, &u, &v) < 1e-3);
    }

    #[test]
    fn captures_dominant_energy() {
        // Random + strong rank-1 spike: rank-4 must capture most energy.
        let mut w = Tensor::randn(&[64, 32], 3);
        let a = Tensor::randn(&[64, 1], 4);
        let b = Tensor::randn(&[1, 32], 5);
        let spike = matmul(&a, &b).scale(10.0);
        w = w.add(&spike);
        let (u, v) = low_rank_factor(&w, 4, 15);
        let rel = low_rank_rel_error(&w, &u, &v);
        assert!(rel < 0.35, "rel err {rel}");
    }

    #[test]
    fn residual_range_shrinks_with_outlier_weight() {
        // The SVDQuant property: the residual after removing the top
        // subspace has smaller absmax than the original outlier-heavy W.
        let mut w = Tensor::randn(&[64, 64], 6);
        let a = Tensor::randn(&[64, 1], 7);
        let b = Tensor::randn(&[1, 64], 8);
        w = w.add(&matmul(&a, &b).scale(8.0));
        let (u, v) = low_rank_factor(&w, 8, 15);
        let resid = w.sub(&matmul(&u, &v));
        assert!(resid.abs_max() < 0.5 * w.abs_max(), "{} vs {}", resid.abs_max(), w.abs_max());
    }

    #[test]
    fn matmul_transb_helper_unused_guard() {
        // Silence potential dead-import drift: basic sanity of the helper
        // this module's math relies on elsewhere.
        let a = Tensor::randn(&[3, 4], 9);
        let b = Tensor::randn(&[5, 4], 10);
        assert_eq!(matmul_transb(&a, &b).shape(), &[3, 5]);
    }
}
