//! Weight quantization (RTN — the paper's Table-2 choice, since weight
//! quantization is "completely perpendicular to sequence transforms").
//!
//! Weights are stored `[in, out]`; per-output-channel quantization groups
//! each *column*, per-block groups `block` consecutive in-entries within a
//! column (the SVDQuant W4 block-64 setting of Table 1).
//!
//! Two forms: [`quantize_weight`] is the f32 QDQ simulation, and
//! [`quantize_weight_packed`] produces the bit-packed [`QTensor`] (in the
//! transposed `[out, in]` layout [`crate::tensor::qgemm`] consumes) whose
//! dequantized values match the simulation bit-for-bit.

use crate::quant::{QTensor, QuantParams};
use crate::tensor::Tensor;

#[derive(Clone, Copy, Debug)]
pub struct WeightQuantCfg {
    pub bits: u32,
    /// Group size along the input dimension; `None` = whole column
    /// (per-output-channel, the Table-2 LLM setting).
    pub block: Option<usize>,
}

impl WeightQuantCfg {
    pub fn w4_per_channel() -> Self {
        WeightQuantCfg { bits: 4, block: None }
    }

    pub fn w4_block64() -> Self {
        WeightQuantCfg { bits: 4, block: Some(64) }
    }
}

/// Round-to-nearest QDQ of a weight matrix under `cfg`.
pub fn quantize_weight(w: &Tensor, cfg: &WeightQuantCfg) -> Tensor {
    let (din, dout) = (w.rows(), w.cols());
    let block = cfg.block.unwrap_or(din).min(din);
    let mut out = w.clone();
    // Column-major grouping on a row-major matrix: gather, qdq, scatter.
    let mut col = vec![0.0f32; din];
    for j in 0..dout {
        for i in 0..din {
            col[i] = w.at(i, j);
        }
        for blk in col.chunks_mut(block) {
            let p = QuantParams::min_max(blk, cfg.bits);
            p.qdq_slice(blk);
        }
        for i in 0..din {
            out.set(i, j, col[i]);
        }
    }
    out
}

/// Pack a weight matrix (stored `[in, out]`) for the integer GEMM.
///
/// The packed layout is the transpose `[out, in]` — one row per output
/// channel — so per-output-channel groups become per-row groups, per-block
/// groups stay contiguous within a row, and the dot-product inner loop of
/// [`crate::tensor::qgemm`] runs unit-stride over both operands. The
/// codes/parameters are exactly those of [`quantize_weight`] under the
/// same `cfg`: `quantize_weight_packed(w, cfg).dequantize()` equals
/// `quantize_weight(w, cfg).transpose()` bit-for-bit.
///
/// The returned tensor lazily caches its GEMM-side derivations (per-row
/// chunk code sums, and an unpacked image for the mixed 8-bit-activation
/// pairing) on first multiply; `baselines::PreparedWeights` warms the
/// chunk sums at registration so serving never pays the build per call.
pub fn quantize_weight_packed(w: &Tensor, cfg: &WeightQuantCfg) -> QTensor {
    assert!(
        cfg.bits == 4 || cfg.bits == 8,
        "packed weights need 4- or 8-bit lanes, got {}-bit",
        cfg.bits
    );
    QTensor::from_weight(w, cfg.bits, cfg.block)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn high_bits_near_identity() {
        let w = Tensor::randn(&[32, 16], 1);
        let q = quantize_weight(&w, &WeightQuantCfg { bits: 16, block: None });
        assert!(q.max_abs_diff(&w) < 1e-3);
    }

    #[test]
    fn per_channel_isolation() {
        // An outlier in column 0 must not affect column 1's error.
        let mut w = Tensor::randn(&[64, 2], 2);
        for i in 0..64 {
            w.set(i, 0, w.at(i, 0) * 100.0);
        }
        let q = quantize_weight(&w, &WeightQuantCfg::w4_per_channel());
        let col_err = |j: usize| -> f64 {
            (0..64).map(|i| ((w.at(i, j) - q.at(i, j)) as f64).powi(2)).sum()
        };
        // Column 1's error must be that of a normal 4-bit column, i.e. tiny
        // relative to column 0's (which has 100× the scale).
        assert!(col_err(1) * 100.0 < col_err(0));
    }

    #[test]
    fn block_grouping_beats_per_channel_with_inlier_outlier_mix() {
        let mut w = Tensor::randn(&[128, 4], 3);
        for j in 0..4 {
            w.set(0, j, 50.0); // one outlier entry per column
        }
        let pc = quantize_weight(&w, &WeightQuantCfg { bits: 4, block: None });
        let pb = quantize_weight(&w, &WeightQuantCfg { bits: 4, block: Some(16) });
        assert!(pb.sub(&w).sq_norm() < pc.sub(&w).sq_norm());
    }

    #[test]
    fn packed_matches_simulated_bit_for_bit() {
        let w = Tensor::randn(&[96, 12], 6);
        for cfg in [
            WeightQuantCfg::w4_per_channel(),
            WeightQuantCfg::w4_block64(),
            WeightQuantCfg { bits: 8, block: Some(16) },
            WeightQuantCfg { bits: 8, block: Some(1024) }, // block > din clamps
        ] {
            let packed = quantize_weight_packed(&w, &cfg);
            assert_eq!(packed.rows(), 12, "packed layout is [out, in]");
            assert_eq!(packed.cols(), 96);
            let want = quantize_weight(&w, &cfg).transpose();
            assert_eq!(packed.dequantize(), want, "{cfg:?}");
        }
    }

    #[test]
    fn fewer_bits_more_error() {
        let w = Tensor::randn(&[64, 8], 4);
        let e4 = quantize_weight(&w, &WeightQuantCfg { bits: 4, block: None }).sub(&w).sq_norm();
        let e8 = quantize_weight(&w, &WeightQuantCfg { bits: 8, block: None }).sub(&w).sq_norm();
        assert!(e8 < e4);
    }
}
