//! [`QuantHook`] — executes a [`QuantStack`] inside any model forward.
//!
//! Per linear layer (site):
//! 1. feature transform: `a = X R` (site's calibrated `R`, else identity);
//! 2. optional range shrink (QuaRot's 10% clip);
//! 3. STaMP: `a_q = L⁻¹ Q_mixed(L a)` — or plain mixed/uniform QDQ;
//! 4. weight: `w_q = Q_w(R⁻¹ W)` (cached per site; SVDQuant subtracts the
//!    low-rank branch first);
//! 5. `y = a_q · w_q (+ X·U·V for SVDQuant) + β`.
//!
//! Because QDQ is simulated in fp, applying `R⁻¹`/`L⁻¹` on the activation
//! side is bit-identical to fusing them into the weight — the overhead of
//! the *real* kernel placement is measured separately in the Table-3 bench.
//!
//! With [`QuantStack::packed`] set (the `quant.packed` config switch),
//! step 3–5 instead run the real integer pipeline: the activation is
//! quantized *once* into a bit-packed [`QTensor`] (in the transformed
//! domain when STaMP is on, with `L⁻¹` applied after the product per
//! Eq. 7), multiplied against a cached packed weight by
//! [`crate::tensor::qgemm`], and scales fold on output. Configurations
//! the packed lanes cannot express (non-4/8-bit widths, attention-sink
//! exclusion, unquantized weights) fall back to the simulation per site.
//!
//! Weight caches are per-hook-instance by default; serving paths hoist
//! them to per-variant via [`PreparedWeights`] so repeated executor calls
//! (and every decode step) reuse the same quantized/packed weights.

use super::{
    identity_for, quantize_weight, quantize_weight_packed, ActQuantCfg, QuantStack, WeightQuantCfg,
};
use crate::model::LinearHook;
use crate::quant::{BitAllocation, QTensor, QuantScheme, Quantizer};
use crate::stamp::{Stamp, StampConfig};
use crate::tensor::{matmul, qgemm, Tensor};
use crate::transforms::FeatureTransform;
use std::cell::RefCell;
use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

/// QuaRot's symmetric range clip, applied per token row: keep `keep` of
/// each row's min-max range around its midpoint.
fn shrink_rows(x: &mut Tensor, keep: f32) {
    for i in 0..x.rows() {
        let row = x.row_mut(i);
        let mx = row.iter().cloned().fold(f32::MIN, f32::max);
        let mn = row.iter().cloned().fold(f32::MAX, f32::min);
        let mid = 0.5 * (mx + mn);
        let half = 0.5 * (mx - mn) * keep;
        for v in row.iter_mut() {
            *v = v.clamp(mid - half, mid + half);
        }
    }
}

/// Whether a bit width fits the packed u8 lane formats.
fn lanes_ok(bits: u32) -> bool {
    bits == 4 || bits == 8
}

/// Build-once weight caches shared across every forward of one model
/// variant.
///
/// [`QuantHook`]'s own caches are per-instance interior state
/// (`RefCell`), so a serving executor that builds a hook per batch used
/// to re-quantize every weight per call. Preparing a variant hoists that
/// cost to registration time: run one dummy forward through a fresh
/// hook, freeze its caches here ([`QuantHook::into_prepared`]), and hand
/// the result to every later hook ([`QuantHook::with_prepared`]) —
/// weights then quantize exactly once per variant. The maps are
/// read-only after the build, so the struct is `Send + Sync` and
/// shareable across worker threads.
pub struct PreparedWeights {
    w: HashMap<String, Tensor>,
    wq: HashMap<String, Arc<QTensor>>,
    /// Per-call weight builds that bypassed this cache; stays 0 once the
    /// preparation forward covered every quantized site (pinned by the
    /// `runtime::native` tests).
    misses: AtomicUsize,
}

impl PreparedWeights {
    /// Sites with a cached simulated (fused/QDQ) weight.
    pub fn simulated_sites(&self) -> usize {
        self.w.len()
    }

    /// Sites with a cached bit-packed weight.
    pub fn packed_sites(&self) -> usize {
        self.wq.len()
    }

    /// Cache-bypassing weight builds observed since preparation.
    pub fn misses(&self) -> usize {
        self.misses.load(Ordering::Relaxed)
    }
}

pub struct QuantHook<'a> {
    stack: &'a QuantStack,
    /// Variant-lifetime weight caches built at registration (serving);
    /// consulted before the per-instance caches below.
    prepared: Option<&'a PreparedWeights>,
    /// Quantized (fused) weights, keyed by site.
    w_cache: RefCell<HashMap<String, Tensor>>,
    /// Bit-packed fused weights for the integer path, keyed by site.
    wq_cache: RefCell<HashMap<String, Arc<QTensor>>>,
    /// STaMP instances keyed by sequence length.
    stamp_cache: RefCell<HashMap<usize, Stamp>>,
}

impl<'a> QuantHook<'a> {
    pub fn new(stack: &'a QuantStack) -> Self {
        Self::build(stack, None)
    }

    /// A hook that reads weights from a per-variant [`PreparedWeights`]
    /// cache instead of rebuilding them per instance.
    pub fn with_prepared(stack: &'a QuantStack, prepared: &'a PreparedWeights) -> Self {
        Self::build(stack, Some(prepared))
    }

    fn build(stack: &'a QuantStack, prepared: Option<&'a PreparedWeights>) -> Self {
        QuantHook {
            stack,
            prepared,
            w_cache: RefCell::new(HashMap::new()),
            wq_cache: RefCell::new(HashMap::new()),
            stamp_cache: RefCell::new(HashMap::new()),
        }
    }

    /// Freeze this hook's weight caches into a shareable
    /// [`PreparedWeights`] (run a representative forward first so every
    /// site is populated — weight caches depend only on the weights, not
    /// the sequence length).
    pub fn into_prepared(self) -> PreparedWeights {
        let wq = self.wq_cache.into_inner();
        // Warm the GEMM-side weight caches (per-row 16-chunk code sums)
        // at preparation time so the first decode step doesn't pay the
        // build; the unpacked code image stays lazy — it only exists for
        // the mixed 8-bit-activation pairing, and materializing it here
        // would cost n×k bytes per site even for pure-4-bit serving.
        for q in wq.values() {
            q.gemm_chunk_sums();
        }
        PreparedWeights { w: self.w_cache.into_inner(), wq, misses: AtomicUsize::new(0) }
    }

    fn site_enabled(&self, site: &str) -> bool {
        if self.stack.skip_sites.iter().any(|s| site.contains(s.as_str())) {
            return false;
        }
        match &self.stack.only_site {
            Some(f) => site.contains(f.as_str()),
            None => true,
        }
    }

    /// STaMP instance for sequence length `s` under the stack's act config
    /// (the body of the per-length cache used by both execution paths).
    fn build_stamp(&self, cfg: &StampConfig, act: &ActQuantCfg, s: usize) -> Stamp {
        let mut c = cfg.clone();
        c.hp_bits = act.hp_bits;
        c.lp_bits = act.bits;
        c.hp_tokens = act.hp_tokens;
        c.granularity = act.granularity;
        // 2-D grids don't apply to arbitrary (e.g. d_ff-wide context)
        // lengths; fall back to 1-D DWT when the grid doesn't match this
        // sequence length.
        if let crate::stamp::SeqTransformKind::HaarDwt2d { h, w } = c.transform {
            let s_eff = if c.skip_first_token { s - 1 } else { s };
            if h * w != s_eff {
                c.transform = crate::stamp::SeqTransformKind::HaarDwt;
            }
        }
        Stamp::new(c, s)
    }

    /// Activation QDQ under the stack's act config (+ optional STaMP).
    fn quantize_activation(&self, a: &Tensor) -> Tensor {
        let act = match &self.stack.act {
            Some(a) => a,
            None => return a.clone(),
        };
        let mut x = a.clone();
        if act.range_shrink < 1.0 {
            shrink_rows(&mut x, act.range_shrink);
        }
        let s = x.rows();
        match &self.stack.stamp {
            Some(cfg) => {
                let mut cache = self.stamp_cache.borrow_mut();
                let stamp = cache.entry(s).or_insert_with(|| self.build_stamp(cfg, act, s));
                stamp.quantize_dequantize(&x)
            }
            None => {
                // Baseline: uniform bits with the first hp_tokens kept high
                // (the paper applies this to baselines too, §B.2).
                let scheme = QuantScheme {
                    granularity: act.granularity,
                    bits: BitAllocation::two_level(act.hp_tokens.min(s), act.hp_bits, act.bits),
                };
                Quantizer::new(scheme, s).apply(&x)
            }
        }
    }

    /// The site's weight after SVDQuant low-rank removal and `R⁻¹` fusion
    /// — shared by the simulated and packed weight caches.
    fn fused_weight(&self, site: &str, w: &Tensor) -> Tensor {
        let mut wt = w.clone();
        // SVDQuant: remove the low-rank branch before quantizing.
        if let Some((u, v)) = self.stack.lowrank.get(site) {
            wt = wt.sub(&matmul(u, v));
        }
        // Fuse R⁻¹.
        if let Some(r) = self.stack.feature.get(site) {
            wt = r.fuse_into_weight(&wt);
        }
        wt
    }

    /// Quantized fused weight for a site (cached). Sites are unique per
    /// weight matrix (model contract); the shape check guards against a
    /// site accidentally being reused across different weights.
    fn weight_for(&self, site: &str, w: &Tensor) -> Tensor {
        if let Some(cached) = self.prepared.and_then(|p| p.w.get(site)) {
            assert_eq!(cached.shape(), w.shape(), "site {site} reused for a different weight");
            return cached.clone();
        }
        if let Some(cached) = self.w_cache.borrow().get(site) {
            assert_eq!(cached.shape(), w.shape(), "site {site} reused for a different weight");
            return cached.clone();
        }
        if let Some(p) = self.prepared {
            // A prepared variant should never rebuild weights per call;
            // count the bypass so serving tests can pin "once per variant".
            p.misses.fetch_add(1, Ordering::Relaxed);
        }
        let mut wt = self.fused_weight(site, w);
        if let Some(cfg) = &self.stack.weight {
            wt = quantize_weight(&wt, cfg);
        }
        self.w_cache.borrow_mut().insert(site.to_string(), wt.clone());
        wt
    }

    /// Bit-packed fused weight for a site (cached), in the `[out, in]`
    /// layout `qgemm` consumes.
    fn packed_weight_for(&self, site: &str, w: &Tensor, cfg: &WeightQuantCfg) -> Arc<QTensor> {
        if let Some(cached) = self.prepared.and_then(|p| p.wq.get(site)) {
            assert_eq!(
                (cached.rows(), cached.cols()),
                (w.cols(), w.rows()),
                "site {site} reused for a different weight"
            );
            return cached.clone();
        }
        if let Some(cached) = self.wq_cache.borrow().get(site) {
            assert_eq!(
                (cached.rows(), cached.cols()),
                (w.cols(), w.rows()),
                "site {site} reused for a different weight"
            );
            return cached.clone();
        }
        if let Some(p) = self.prepared {
            p.misses.fetch_add(1, Ordering::Relaxed);
        }
        let packed = Arc::new(quantize_weight_packed(&self.fused_weight(site, w), cfg));
        self.wq_cache.borrow_mut().insert(site.to_string(), packed.clone());
        packed
    }

    /// The packed integer route for one linear, or `None` when this
    /// stack/site cannot pack — non-4/8-bit lanes, attention-sink
    /// exclusion, or no weight quantization — in which case the caller
    /// falls back to the simulated QDQ path.
    fn packed_linear(
        &self,
        site: &str,
        x: &Tensor,
        w: &Tensor,
        bias: Option<&[f32]>,
    ) -> Option<Tensor> {
        if !self.stack.packed {
            return None;
        }
        let act = self.stack.act.as_ref()?;
        let wcfg = self.stack.weight.as_ref()?;
        if !lanes_ok(act.bits) || !lanes_ok(wcfg.bits) {
            return None;
        }
        if act.hp_tokens > 0 && !lanes_ok(act.hp_bits) {
            return None;
        }
        if self.stack.stamp.as_ref().is_some_and(|c| c.skip_first_token) {
            return None;
        }
        // Feature transform (+ QuaRot range shrink) on the activation side.
        let mut a = match self.stack.feature.get(site) {
            Some(r) => r.apply(x),
            None => x.clone(),
        };
        if act.range_shrink < 1.0 {
            shrink_rows(&mut a, act.range_shrink);
        }
        let s = a.rows();
        let wq = self.packed_weight_for(site, w, wcfg);
        let mut y = match &self.stack.stamp {
            Some(cfg) => {
                // Eq. 7: quantize L·a once into packed codes, integer-GEMM,
                // then apply L⁻¹ *after* the product.
                let mut cache = self.stamp_cache.borrow_mut();
                let stamp = cache.entry(s).or_insert_with(|| self.build_stamp(cfg, act, s));
                let qa = stamp.quantize_transformed_packed(&a);
                stamp.inverse_trim(&qgemm(&qa, &wq))
            }
            None => {
                let scheme = QuantScheme {
                    granularity: act.granularity,
                    bits: BitAllocation::two_level(act.hp_tokens.min(s), act.hp_bits, act.bits),
                };
                qgemm(&Quantizer::new(scheme, s).quantize(&a), &wq)
            }
        };
        // SVDQuant low-rank branch stays in fp on the *original* input.
        if let Some((u, v)) = self.stack.lowrank.get(site) {
            y = y.add(&matmul(&matmul(x, u), v));
        }
        if let Some(b) = bias {
            y = y.add_row_broadcast(b);
        }
        Some(y)
    }
}

impl LinearHook for QuantHook<'_> {
    fn linear(&self, site: &str, x: &Tensor, w: &Tensor, bias: Option<&[f32]>) -> Tensor {
        if !self.site_enabled(site) {
            return crate::model::FpHook.linear(site, x, w, bias);
        }
        // Packed integer path (QTensor + qgemm) when the stack opts in and
        // the configuration can pack; falls through to simulated QDQ.
        if let Some(y) = self.packed_linear(site, x, w, bias) {
            return y;
        }
        // Feature transform on the activation side.
        let a = match self.stack.feature.get(site) {
            Some(r) => r.apply(x),
            None => identity_for(x.cols()).apply(x),
        };
        let a_q = self.quantize_activation(&a);
        let w_q = self.weight_for(site, w);
        let mut y = matmul(&a_q, &w_q);
        // SVDQuant low-rank branch stays in fp on the *original* input.
        if let Some((u, v)) = self.stack.lowrank.get(site) {
            y = y.add(&matmul(&matmul(x, u), v));
        }
        if let Some(b) = bias {
            y = y.add_row_broadcast(b);
        }
        y
    }

    fn kv(&self, site: &str, t: &Tensor) -> Tensor {
        if !self.site_enabled(site) {
            return t.clone();
        }
        let kv = match &self.stack.kv {
            Some(k) => k,
            None => return t.clone(),
        };
        let s = t.rows();
        let scheme = QuantScheme {
            granularity: crate::quant::Granularity::PerToken,
            bits: BitAllocation::two_level(kv.hp_tokens.min(s), kv.hp_bits, kv.bits),
        };
        Quantizer::new(scheme, s).apply(t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::{ActQuantCfg, BaselineKind, CalibHook, KvQuantCfg, WeightQuantCfg};
    use crate::model::{FpHook, Gpt, GptConfig};
    use crate::stats::sqnr;

    fn tokens(n: usize) -> Vec<u32> {
        (0..n).map(|i| ((i * 13 + 5) % 70) as u32).collect()
    }

    fn calibrated_stats(gpt: &Gpt) -> HashMap<String, super::super::SiteStats> {
        let hook = CalibHook::new(4);
        for seed in 0..3usize {
            let t: Vec<u32> = (0..64).map(|i| ((i * 7 + seed) % 70) as u32).collect();
            let _ = gpt.logits_hooked(&hook, &t);
        }
        hook.take()
    }

    #[test]
    fn fp_stack_is_exact() {
        let gpt = Gpt::new(GptConfig::tiny(), 1);
        let stack = QuantStack::fp();
        let hook = QuantHook::new(&stack);
        let t = tokens(32);
        let a = gpt.logits_hooked(&hook, &t);
        let b = gpt.logits_hooked(&FpHook, &t);
        assert!(a.max_abs_diff(&b) < 1e-5);
    }

    #[test]
    fn rtn_w4a4_degrades_then_stamp_recovers() {
        let gpt = Gpt::new(GptConfig::tiny(), 2);
        let t = tokens(128);
        let fp = gpt.logits_hooked(&FpHook, &t);

        let stats = calibrated_stats(&gpt);
        let mk = |stamp: bool| {
            let mut s = QuantStack::build(
                BaselineKind::Rtn,
                &stats,
                Some(ActQuantCfg::w4a4_per_token()),
                Some(WeightQuantCfg::w4_per_channel()),
                Some(KvQuantCfg::kv4()),
                7,
            );
            if stamp {
                s = s.with_stamp(QuantStack::llm_stamp(crate::stamp::SeqTransformKind::HaarDwt));
            }
            s
        };
        let s_plain = mk(false);
        let s_stamp = mk(true);
        let q_plain = gpt.logits_hooked(&QuantHook::new(&s_plain), &t);
        let q_stamp = gpt.logits_hooked(&QuantHook::new(&s_stamp), &t);
        let sq_plain = sqnr(&fp, &q_plain);
        let sq_stamp = sqnr(&fp, &q_stamp);
        assert!(sq_plain < 40.0, "4-bit must visibly degrade ({sq_plain} dB)");
        assert!(
            sq_stamp > sq_plain,
            "STaMP must improve logit fidelity: {sq_stamp} vs {sq_plain}"
        );
    }

    #[test]
    fn quarot_beats_rtn() {
        let mut gpt = Gpt::new(GptConfig::tiny(), 3);
        // Give the residual stream outlier channels (the regime QuaRot is
        // built for): a few large RMSNorm gains create per-channel
        // activation outliers at every linear input.
        for b in &mut gpt.blocks {
            for &j in &[3usize, 17, 41] {
                b.norm1.gamma[j] = 12.0;
                b.norm2.gamma[j] = 12.0;
            }
        }
        let t = tokens(128);
        let fp = gpt.logits_hooked(&FpHook, &t);
        let stats = calibrated_stats(&gpt);
        let act = Some(ActQuantCfg::w4a4_per_token());
        let wq = Some(WeightQuantCfg::w4_per_channel());
        let rtn = QuantStack::build(BaselineKind::Rtn, &stats, act.clone(), wq, None, 7);
        let mut quarot = QuantStack::build(BaselineKind::QuaRot, &stats, act, wq, None, 7);
        // QuaRot's 10% range shrink.
        if let Some(a) = &mut quarot.act {
            a.range_shrink = 0.9;
        }
        let s_rtn = sqnr(&fp, &gpt.logits_hooked(&QuantHook::new(&rtn), &t));
        let s_qr = sqnr(&fp, &gpt.logits_hooked(&QuantHook::new(&quarot), &t));
        assert!(s_qr > s_rtn, "QuaRot {s_qr} !> RTN {s_rtn}");
    }

    #[test]
    fn weight_cache_reused() {
        let gpt = Gpt::new(GptConfig::tiny(), 4);
        let stats = calibrated_stats(&gpt);
        let stack = QuantStack::build(
            BaselineKind::Rtn,
            &stats,
            None,
            Some(WeightQuantCfg::w4_per_channel()),
            None,
            7,
        );
        let hook = QuantHook::new(&stack);
        let t = tokens(32);
        let _ = gpt.logits_hooked(&hook, &t);
        let n1 = hook.w_cache.borrow().len();
        let _ = gpt.logits_hooked(&hook, &t);
        let n2 = hook.w_cache.borrow().len();
        assert_eq!(n1, n2, "second pass must hit the cache");
        assert!(n1 >= 8);
    }

    #[test]
    fn prepared_weights_reused_without_misses() {
        let gpt = Gpt::new(GptConfig::tiny(), 10);
        let act = ActQuantCfg { hp_tokens: 8, ..ActQuantCfg::w4a4_per_token() };
        let stack = QuantStack::build(
            BaselineKind::Rtn,
            &HashMap::new(),
            Some(act),
            Some(WeightQuantCfg::w4_per_channel()),
            None,
            7,
        )
        .with_packed();
        // Build the per-variant cache from one dummy forward.
        let build = QuantHook::new(&stack);
        let _ = gpt.logits_hooked(&build, &[0]);
        let prepared = build.into_prepared();
        assert!(prepared.packed_sites() >= 8, "dummy forward must cover all sites");
        // Fresh hooks resolve every weight from the prepared cache…
        let t = tokens(32);
        let a = gpt.logits_hooked(&QuantHook::with_prepared(&stack, &prepared), &t);
        let b = gpt.logits_hooked(&QuantHook::with_prepared(&stack, &prepared), &t);
        assert_eq!(prepared.misses(), 0, "prepared variants must never rebuild weights");
        assert_eq!(a, b);
        // …and produce exactly what an unprepared hook computes.
        let c = gpt.logits_hooked(&QuantHook::new(&stack), &t);
        assert_eq!(a, c, "prepared and per-call weights must be identical");
    }

    #[test]
    fn packed_stack_matches_simulated_closely() {
        let gpt = Gpt::new(GptConfig::tiny(), 8);
        let t = tokens(64);
        let act = ActQuantCfg { hp_tokens: 8, ..ActQuantCfg::w4a4_per_token() };
        let mk = |packed: bool| {
            let s = QuantStack::build(
                BaselineKind::Rtn,
                &HashMap::new(),
                Some(act.clone()),
                Some(WeightQuantCfg::w4_per_channel()),
                None,
                7,
            );
            if packed {
                s.with_packed()
            } else {
                s
            }
        };
        let sim_stack = mk(false);
        let packed_stack = mk(true);
        let sim = gpt.logits_hooked(&QuantHook::new(&sim_stack), &t);
        let hook = QuantHook::new(&packed_stack);
        let packed = gpt.logits_hooked(&hook, &t);
        assert!(hook.wq_cache.borrow().len() >= 8, "packed weights must be cached per site");
        assert!(packed.all_finite());
        // Same quantized values either way — only f32-vs-integer
        // accumulation differs — so logits must agree tightly.
        let s = sqnr(&sim, &packed);
        assert!(s > 35.0, "packed vs simulated logits SQNR {s} dB");
    }

    #[test]
    fn packed_stack_with_stamp_matches_simulated() {
        let gpt = Gpt::new(GptConfig::tiny(), 12);
        let t = tokens(64);
        let act = ActQuantCfg { hp_tokens: 8, ..ActQuantCfg::w4a4_per_token() };
        // STaMP without sink exclusion packs; L⁻¹ moves after the product
        // (Eq. 7), so outputs match the simulated path up to accumulation.
        let stamp_cfg = StampConfig::default();
        let mk = |packed: bool| {
            let s = QuantStack::build(
                BaselineKind::Rtn,
                &HashMap::new(),
                Some(act.clone()),
                Some(WeightQuantCfg::w4_per_channel()),
                None,
                7,
            )
            .with_stamp(stamp_cfg.clone());
            if packed {
                s.with_packed()
            } else {
                s
            }
        };
        let sim_stack = mk(false);
        let packed_stack = mk(true);
        let sim = gpt.logits_hooked(&QuantHook::new(&sim_stack), &t);
        let packed = gpt.logits_hooked(&QuantHook::new(&packed_stack), &t);
        assert!(packed.all_finite());
        let s = sqnr(&sim, &packed);
        assert!(s > 30.0, "packed-STaMP vs simulated logits SQNR {s} dB");
    }

    #[test]
    fn packed_falls_back_exactly_when_unpackable() {
        let gpt = Gpt::new(GptConfig::tiny(), 9);
        let t = tokens(48);
        // Sink exclusion (llm_stamp) cannot pack: the packed flag must not
        // change a single bit of the output.
        let act = ActQuantCfg { hp_tokens: 4, ..ActQuantCfg::w4a4_per_token() };
        let mk = |packed: bool| {
            let s = QuantStack::build(
                BaselineKind::Rtn,
                &HashMap::new(),
                Some(act.clone()),
                Some(WeightQuantCfg::w4_per_channel()),
                None,
                7,
            )
            .with_stamp(QuantStack::llm_stamp(crate::stamp::SeqTransformKind::HaarDwt));
            if packed {
                s.with_packed()
            } else {
                s
            }
        };
        let sim_stack = mk(false);
        let packed_stack = mk(true);
        let a = gpt.logits_hooked(&QuantHook::new(&sim_stack), &t);
        let b = gpt.logits_hooked(&QuantHook::new(&packed_stack), &t);
        assert_eq!(a, b, "fallback must be bit-identical to the simulated path");

        // Unpackable lane width (3-bit) likewise falls back bit-identically.
        let act3 = ActQuantCfg { bits: 3, hp_tokens: 0, ..ActQuantCfg::w4a4_per_token() };
        let s3 = QuantStack::build(
            BaselineKind::Rtn,
            &HashMap::new(),
            Some(act3.clone()),
            Some(WeightQuantCfg::w4_per_channel()),
            None,
            7,
        );
        let s3p = QuantStack::build(
            BaselineKind::Rtn,
            &HashMap::new(),
            Some(act3),
            Some(WeightQuantCfg::w4_per_channel()),
            None,
            7,
        )
        .with_packed();
        let a = gpt.logits_hooked(&QuantHook::new(&s3), &t);
        let b = gpt.logits_hooked(&QuantHook::new(&s3p), &t);
        assert_eq!(a, b);
    }

    #[test]
    fn svdquant_low_rank_helps_outlier_weights() {
        // Craft a model whose weights have strong rank-1 outliers, then
        // check SVDQuant beats RTN at W4.
        let mut gpt = Gpt::new(GptConfig::tiny(), 5);
        gpt.visit_weights_mut(&mut |_site, w| {
            let a = Tensor::randn(&[w.rows(), 1], 11);
            let b = Tensor::randn(&[1, w.cols()], 12);
            *w = w.add(&matmul(&a, &b).scale(1.5));
        });
        let t = tokens(64);
        let fp = gpt.logits_hooked(&FpHook, &t);
        let stats = calibrated_stats(&gpt);
        let wq = Some(WeightQuantCfg { bits: 3, block: None });
        let rtn = QuantStack::build(BaselineKind::Rtn, &stats, None, wq, None, 7);
        let svd = QuantStack::build(BaselineKind::SvdQuant, &stats, None, wq, None, 7);
        let s_rtn = sqnr(&fp, &gpt.logits_hooked(&QuantHook::new(&rtn), &t));
        let s_svd = sqnr(&fp, &gpt.logits_hooked(&QuantHook::new(&svd), &t));
        assert!(s_svd > s_rtn, "SVDQuant {s_svd} !> RTN {s_rtn}");
    }

    #[test]
    fn only_site_filter() {
        let gpt = Gpt::new(GptConfig::tiny(), 6);
        let t = tokens(64);
        let fp = gpt.logits_hooked(&FpHook, &t);
        let stats = calibrated_stats(&gpt);
        // Quantizing only ffn.up_proj at 2 bits must hurt less than
        // quantizing everything at 2 bits.
        let mk = |only: Option<&str>| {
            let mut s = QuantStack::build(
                BaselineKind::Rtn,
                &stats,
                Some(ActQuantCfg { bits: 2, ..ActQuantCfg::w4a4_per_token() }),
                None,
                None,
                7,
            );
            if let Some(o) = only {
                s = s.only(o);
            }
            s
        };
        let s_one = sqnr(&fp, &gpt.logits_hooked(&QuantHook::new(&mk(Some("ffn.up_proj"))), &t));
        let s_all = sqnr(&fp, &gpt.logits_hooked(&QuantHook::new(&mk(None)), &t));
        assert!(s_one > s_all, "one-site {s_one} !> all {s_all}");
    }

    #[test]
    fn kv_quant_applied() {
        let gpt = Gpt::new(GptConfig::tiny(), 7);
        let t = tokens(64);
        let fp = gpt.logits_hooked(&FpHook, &t);
        let stack = QuantStack {
            kv: Some(KvQuantCfg { bits: 2, hp_tokens: 0, hp_bits: 8 }),
            ..QuantStack::fp()
        };
        let q = gpt.logits_hooked(&QuantHook::new(&stack), &t);
        // KV2 alone must measurably perturb the logits.
        assert!(q.max_abs_diff(&fp) > 1e-3);
    }
}
