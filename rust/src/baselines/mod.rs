//! Baseline quantization stacks and their composition with STaMP.
//!
//! A [`QuantStack`] bundles everything the paper's tables vary:
//! per-site **feature transforms** (SmoothQuant scaling / QuaRot Hadamard /
//! FlatQuant affine / ViDiT-Q SDCB scaling), an optional **SVDQuant**
//! low-rank weight branch, **weight quantization** (RTN), **activation
//! quantization** (bits, granularity, mixed-precision tokens), **KV-cache
//! quantization**, and the optional **STaMP sequence transform**. The
//! [`QuantHook`] turns a stack into a [`crate::model::LinearHook`] so any
//! model forward can run under it unchanged.
//!
//! Equivalences used (exact for the QDQ simulation):
//! `Q(XR)·Q_w(R⁻¹W) ≡ [Q(XR)]·[Q_w(R⁻¹W)]` — we quantize the activation in
//! the transformed domain and multiply by the cached quantized fused
//! weight, which is bit-identical to an engine that fuses `R⁻¹` into `W`
//! offline (Ashkboos et al. 2024). The sequence inverse `L⁻¹` is applied
//! after the matmul, exactly as in Figure 2a.

mod calib;
mod hook;
mod lowrank;
mod weights;

pub use calib::{CalibHook, SiteStats};
pub use hook::{PreparedWeights, QuantHook};
pub use lowrank::low_rank_factor;
pub use weights::{quantize_weight, quantize_weight_packed, WeightQuantCfg};

use crate::quant::Granularity;
use crate::stamp::{SeqTransformKind, StampConfig};
use crate::transforms::{
    AffineFeature, FeatureTransform, HadamardFeature, IdentityFeature, ScalingFeature,
};
use std::collections::HashMap;

/// Which published method a stack reproduces.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum BaselineKind {
    /// Round-to-nearest: no transforms at all.
    Rtn,
    /// SmoothQuant channel scaling (α = 0.5).
    SmoothQuant,
    /// QuaRot randomized Hadamard rotations (+10% range shrink).
    QuaRot,
    /// FlatQuant-lite calibrated affine transform.
    FlatQuant,
    /// ViDiT-Q static-dynamic channel balancing (α = 0.01).
    ViDitQ,
    /// SVDQuant: fp low-rank branch absorbs outliers, residual quantized.
    SvdQuant,
}

impl BaselineKind {
    pub fn label(&self) -> &'static str {
        match self {
            BaselineKind::Rtn => "RTN",
            BaselineKind::SmoothQuant => "SmoothQuant",
            BaselineKind::QuaRot => "QuaRot",
            BaselineKind::FlatQuant => "FlatQuant",
            BaselineKind::ViDitQ => "ViDiT-Q",
            BaselineKind::SvdQuant => "SVDQuant",
        }
    }

    /// Whether this baseline needs calibration activations.
    pub fn needs_calibration(&self) -> bool {
        !matches!(self, BaselineKind::Rtn)
    }
}

/// Activation quantization settings.
#[derive(Clone, Debug)]
pub struct ActQuantCfg {
    /// Low-precision bits (the "A4" in W4A4).
    pub bits: u32,
    /// High-precision token count (64 in the paper — applied to *all*
    /// methods incl. baselines, §B.2) and bit width.
    pub hp_tokens: usize,
    pub hp_bits: u32,
    pub granularity: Granularity,
    /// Min-max range multiplier (<1 introduces deliberate clipping;
    /// QuaRot uses 0.9 per its paper).
    pub range_shrink: f32,
}

impl ActQuantCfg {
    pub fn w4a4_per_token() -> Self {
        ActQuantCfg {
            bits: 4,
            hp_tokens: 64,
            hp_bits: 8,
            granularity: Granularity::PerToken,
            range_shrink: 1.0,
        }
    }

    pub fn per_block(bits: u32, block: usize) -> Self {
        ActQuantCfg {
            bits,
            hp_tokens: 64,
            hp_bits: 8,
            granularity: Granularity::PerBlock { block },
            range_shrink: 1.0,
        }
    }

    /// Microscaling activations: hardware-friendly 16- or 32-wide shared
    /// scales ([`Granularity::MicroBlock`]), served by the dedicated
    /// in-register folding path in [`crate::tensor::qgemm`].
    pub fn micro(bits: u32, block: usize) -> Self {
        ActQuantCfg {
            bits,
            hp_tokens: 64,
            hp_bits: 8,
            granularity: Granularity::MicroBlock { block },
            range_shrink: 1.0,
        }
    }
}

/// KV-cache quantization settings (paper: KV4 with 64 8-bit tokens).
#[derive(Clone, Debug)]
pub struct KvQuantCfg {
    pub bits: u32,
    pub hp_tokens: usize,
    pub hp_bits: u32,
}

impl KvQuantCfg {
    pub fn kv4() -> Self {
        KvQuantCfg { bits: 4, hp_tokens: 64, hp_bits: 8 }
    }
}

/// A fully-specified quantization configuration for one table row.
pub struct QuantStack {
    pub kind: BaselineKind,
    /// Per-site feature transforms; sites not present use identity.
    pub feature: HashMap<String, Box<dyn FeatureTransform>>,
    /// Per-site low-rank branches `(U, V)` for SVDQuant (weight ≈ U·V).
    pub lowrank: HashMap<String, (crate::tensor::Tensor, crate::tensor::Tensor)>,
    pub act: Option<ActQuantCfg>,
    pub weight: Option<WeightQuantCfg>,
    pub kv: Option<KvQuantCfg>,
    /// STaMP sequence transform; `None` disables it (baseline column).
    pub stamp: Option<StampConfig>,
    /// Sites never quantized (e.g. cross-attention K/V per §5.1). Checked
    /// by substring.
    pub skip_sites: Vec<String>,
    /// If set, ONLY sites containing this substring are quantized
    /// (Table-4 per-site ablation).
    pub only_site: Option<String>,
    /// Serve linears through the packed integer path
    /// ([`crate::quant::QTensor`] + [`crate::tensor::qgemm`]) where the
    /// configuration allows. Sites/configs the packed path cannot express
    /// (non-4/8-bit lanes, attention-sink exclusion, no weight
    /// quantization) fall back to the simulated QDQ transparently.
    pub packed: bool,
}

impl QuantStack {
    /// An FP stack (no quantization at all) — the `FP` table rows.
    pub fn fp() -> Self {
        QuantStack {
            kind: BaselineKind::Rtn,
            feature: HashMap::new(),
            lowrank: HashMap::new(),
            act: None,
            weight: None,
            kv: None,
            stamp: None,
            skip_sites: Vec::new(),
            only_site: None,
            packed: false,
        }
    }

    /// Build a baseline stack from calibration statistics.
    ///
    /// `stats` may be empty only for RTN.
    pub fn build(
        kind: BaselineKind,
        stats: &HashMap<String, SiteStats>,
        act: Option<ActQuantCfg>,
        weight: Option<WeightQuantCfg>,
        kv: Option<KvQuantCfg>,
        seed: u64,
    ) -> Self {
        let mut feature: HashMap<String, Box<dyn FeatureTransform>> = HashMap::new();
        let mut lowrank = HashMap::new();
        match kind {
            BaselineKind::Rtn => {}
            BaselineKind::QuaRot => {
                // One Hadamard per site dimension; same seed ⇒ same rotation
                // for equal dims (mirrors QuaRot's shared rotations).
                for (site, st) in stats {
                    feature.insert(
                        site.clone(),
                        Box::new(HadamardFeature::new(st.dim, seed)) as Box<dyn FeatureTransform>,
                    );
                }
            }
            BaselineKind::SmoothQuant | BaselineKind::ViDitQ => {
                let alpha = if kind == BaselineKind::SmoothQuant { 0.5 } else { 0.01 };
                for (site, st) in stats {
                    feature.insert(
                        site.clone(),
                        Box::new(ScalingFeature::calibrate(&st.act_absmax, &st.w_absmax, alpha)),
                    );
                }
            }
            BaselineKind::FlatQuant => {
                for (site, st) in stats {
                    if !st.samples.is_empty() {
                        feature.insert(
                            site.clone(),
                            Box::new(AffineFeature::calibrate(&st.samples, seed)),
                        );
                    }
                }
            }
            BaselineKind::SvdQuant => {
                for (site, st) in stats {
                    if let Some(w) = &st.weight {
                        let rank = (w.cols().min(w.rows()) / 8).clamp(2, 16);
                        lowrank.insert(site.clone(), low_rank_factor(w, rank, 12));
                    }
                }
            }
        }
        QuantStack {
            kind,
            feature,
            lowrank,
            act,
            weight,
            kv,
            stamp: None,
            skip_sites: Vec::new(),
            only_site: None,
            packed: false,
        }
    }

    /// Enable STaMP on this stack (the ✓ columns of Tables 1–2).
    pub fn with_stamp(mut self, cfg: StampConfig) -> Self {
        self.stamp = Some(cfg);
        self
    }

    /// Serve through the packed integer path (the `quant.packed` config
    /// switch): activations quantize once into [`crate::quant::QTensor`]
    /// codes and multiply against pre-packed weights via
    /// [`crate::tensor::qgemm`] instead of the f32 QDQ simulation.
    pub fn with_packed(mut self) -> Self {
        self.packed = true;
        self
    }

    /// LVM convention (§5.1): leave cross-attention K/V unquantized.
    pub fn with_lvm_skips(mut self) -> Self {
        self.skip_sites.push("attn2.k".into());
        self.skip_sites.push("attn2.v".into());
        self
    }

    /// Restrict quantization to one site (Table-4 ablation).
    pub fn only(mut self, site: &str) -> Self {
        self.only_site = Some(site.to_string());
        self
    }

    /// Row label like `QuaRot + STaMP(dwt)`.
    pub fn label(&self) -> String {
        match &self.stamp {
            Some(s) => format!("{} + STaMP({})", self.kind.label(), s.transform.label()),
            None => self.kind.label().to_string(),
        }
    }

    /// Default STaMP config for LLM eval (1-D DWT, skip sink token).
    pub fn llm_stamp(kind: SeqTransformKind) -> StampConfig {
        StampConfig { transform: kind, skip_first_token: true, ..Default::default() }
    }

    /// Default STaMP config for LVM eval (2-D DWT over the latent grid).
    pub fn lvm_stamp(h: usize, w: usize) -> StampConfig {
        StampConfig { transform: SeqTransformKind::HaarDwt2d { h, w }, ..Default::default() }
    }
}

/// Identity transform helper used by the hook for un-calibrated sites.
pub(crate) fn identity_for(dim: usize) -> IdentityFeature {
    IdentityFeature::new(dim)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn labels() {
        let s = QuantStack::build(BaselineKind::QuaRot, &HashMap::new(), None, None, None, 1);
        assert_eq!(s.label(), "QuaRot");
        let s = s.with_stamp(StampConfig::default());
        assert_eq!(s.label(), "QuaRot + STaMP(dwt)");
    }

    #[test]
    fn fp_stack_is_empty() {
        let s = QuantStack::fp();
        assert!(s.act.is_none() && s.weight.is_none() && s.kv.is_none() && s.stamp.is_none());
    }

    #[test]
    fn calibration_flags() {
        assert!(!BaselineKind::Rtn.needs_calibration());
        assert!(BaselineKind::QuaRot.needs_calibration());
        assert!(BaselineKind::SmoothQuant.needs_calibration());
        assert!(BaselineKind::SvdQuant.needs_calibration());
    }
}
