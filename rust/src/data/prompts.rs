//! Prompt sets standing in for the paper's COCO / MJHQ evaluation prompts.
//!
//! In the LVM tables each prompt seeds the DiT latent generator (conditioning
//! embedding + initial latent), so what matters for the reproduction is that
//! the two sets induce *different but fixed* conditioning distributions —
//! mirroring how COCO (natural captions) and MJHQ (aesthetic prompts) differ.

use crate::tensor::{Tensor, XorShiftRng};

/// A named prompt set; prompts are hashed into conditioning embeddings.
pub struct PromptSet {
    pub name: &'static str,
    pub prompts: Vec<&'static str>,
}

const COCO_LIKE: &[&str] = &[
    "a cat that has a shirt on its back",
    "a guy with a backpack looking at the ground to his left",
    "two dogs running across a grassy field",
    "a red bicycle leaning against a brick wall",
    "a bowl of fruit on a wooden table",
    "a train arriving at a crowded station",
    "children playing soccer in a park",
    "a fishing boat docked at the harbor",
    "an old clock tower above the town square",
    "a plate of pasta with tomato sauce",
    "a person riding a horse on the beach",
    "a laptop and a cup of coffee on a desk",
    "a bus stopped at a traffic light downtown",
    "a bird perched on a power line",
    "a kitchen with stainless steel appliances",
    "a man holding an umbrella in the rain",
];

const MJHQ_LIKE: &[&str] = &[
    "a cute little dog looking up at the stars in the night sky, filled with hope and determination",
    "ethereal crystal palace floating above clouds, golden hour, highly detailed",
    "portrait of a wise elder with intricate tattoos, dramatic lighting",
    "bioluminescent forest at midnight, fantasy concept art",
    "steampunk airship over a victorian city, cinematic composition",
    "a serene japanese garden with koi pond, studio ghibli style",
    "futuristic neon metropolis in the rain, cyberpunk aesthetic",
    "ancient library with floating books and warm candlelight",
    "majestic dragon curled around a snowy mountain peak",
    "underwater city with glass domes and schools of fish",
    "cosmic whale swimming through a nebula, surreal art",
    "a knight in ornate armor standing in a field of silver flowers",
    "desert oasis under two moons, science fantasy illustration",
    "clockwork butterfly resting on a mechanical rose",
    "northern lights over a frozen lake, photorealistic",
    "floating islands connected by rope bridges at sunset",
];

impl PromptSet {
    pub fn coco() -> Self {
        PromptSet { name: "COCO", prompts: COCO_LIKE.to_vec() }
    }

    pub fn mjhq() -> Self {
        PromptSet { name: "MJHQ", prompts: MJHQ_LIKE.to_vec() }
    }

    /// Deterministic 64-bit hash of a prompt (FNV-1a).
    pub fn hash(prompt: &str) -> u64 {
        let mut h = 0xcbf2_9ce4_8422_2325u64;
        for b in prompt.bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01B3);
        }
        h
    }

    /// Embed a prompt into a conditioning vector of width `d` (unit RMS).
    /// Stand-in for the pooled T5/CLIP text embedding the DiT consumes.
    pub fn embed(prompt: &str, d: usize) -> Tensor {
        let mut rng = XorShiftRng::new(Self::hash(prompt));
        let mut v = Vec::with_capacity(d);
        for _ in 0..d {
            v.push(rng.next_gaussian());
        }
        let rms = (v.iter().map(|x| x * x).sum::<f32>() / d as f32).sqrt().max(1e-6);
        Tensor::from_vec(&[1, d], v.into_iter().map(|x| x / rms).collect())
    }

    /// Per-prompt token embeddings (seq of conditioning tokens, for
    /// cross-attention K/V). `n` tokens of width `d`.
    pub fn embed_tokens(prompt: &str, n: usize, d: usize) -> Tensor {
        let mut rng = XorShiftRng::new(Self::hash(prompt) ^ 0x746f6b656e73);
        let mut v = Vec::with_capacity(n * d);
        for _ in 0..n * d {
            v.push(rng.next_gaussian() * 0.7);
        }
        Tensor::from_vec(&[n, d], v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sets_nonempty_distinct() {
        let c = PromptSet::coco();
        let m = PromptSet::mjhq();
        assert_eq!(c.prompts.len(), 16);
        assert_eq!(m.prompts.len(), 16);
        assert_ne!(c.prompts[0], m.prompts[0]);
    }

    #[test]
    fn embedding_deterministic_and_distinct() {
        let a = PromptSet::embed("a cat", 32);
        let b = PromptSet::embed("a cat", 32);
        let c = PromptSet::embed("a dog", 32);
        assert_eq!(a, b);
        assert!(a.max_abs_diff(&c) > 0.1);
    }

    #[test]
    fn embedding_unit_rms() {
        let e = PromptSet::embed("test prompt", 64);
        let rms = (e.sq_norm() / 64.0).sqrt();
        assert!((rms - 1.0).abs() < 1e-3);
    }

    #[test]
    fn token_embeddings_shape() {
        let t = PromptSet::embed_tokens("hello", 8, 16);
        assert_eq!(t.shape(), &[8, 16]);
    }
}
