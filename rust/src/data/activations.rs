//! Synthetic activation matrices with paper-calibrated structure.
//!
//! Figure 3 of the paper establishes three facts about intermediate
//! activations that STaMP exploits or must survive:
//!
//! 1. the sequence autocorrelation is ≈ Toeplitz (LLM) or block-Toeplitz
//!    (LVM, from flattening a 2-D grid);
//! 2. a few feature channels carry large outliers (what feature transforms
//!    fix — SmoothQuant/QuaRot's motivation);
//! 3. LLMs have a "massive activation" attention-sink first token
//!    (paper §B.2, Sun et al. 2024).
//!
//! [`ActivationGenerator`] samples matrices with all three properties with
//! tunable strength, used for calibration sets, Figure 2/3/4 inputs, and
//! property tests.

use crate::linalg::{ar1_covariance, block_toeplitz_2d, cholesky};
use crate::tensor::{Tensor, XorShiftRng};

/// Declarative description of an activation distribution.
#[derive(Clone, Debug)]
pub struct ActivationSpec {
    /// Sequence length (for Grid: h·w).
    pub seq_len: usize,
    /// Feature width.
    pub dim: usize,
    /// Sequence correlation structure.
    pub correlation: Correlation,
    /// Number of outlier feature channels.
    pub outlier_channels: usize,
    /// Outlier magnitude multiplier (×RMS).
    pub outlier_scale: f32,
    /// Massive first-token (attention sink) magnitude, 0 = none.
    pub sink_scale: f32,
}

#[derive(Clone, Debug)]
pub enum Correlation {
    /// Independent tokens (negative control: sequence transforms cannot help).
    None,
    /// AR(1) along the sequence: `S[i,j] = ρ^|i−j|` (LLM-like, Fig 3a right).
    Ar1 { rho: f32 },
    /// Separable 2-D AR over an `h×w` grid (LVM-like, Fig 3a left).
    Grid2d { h: usize, w: usize, rho_y: f32, rho_x: f32 },
}

impl ActivationSpec {
    /// LLM-layer preset (≈ LLaMA attention-layer input, Fig 3 right).
    pub fn llm(seq_len: usize, dim: usize) -> Self {
        ActivationSpec {
            seq_len,
            dim,
            correlation: Correlation::Ar1 { rho: 0.95 },
            outlier_channels: dim / 64,
            outlier_scale: 20.0,
            sink_scale: 50.0,
        }
    }

    /// LVM-layer preset (≈ PixArt-Σ cross-attn input over a token grid).
    pub fn lvm(h: usize, w: usize, dim: usize) -> Self {
        ActivationSpec {
            seq_len: h * w,
            dim,
            correlation: Correlation::Grid2d { h, w, rho_y: 0.9, rho_x: 0.9 },
            outlier_channels: dim / 64,
            outlier_scale: 15.0,
            sink_scale: 0.0,
        }
    }

    /// Uncorrelated control.
    pub fn iid(seq_len: usize, dim: usize) -> Self {
        ActivationSpec {
            seq_len,
            dim,
            correlation: Correlation::None,
            outlier_channels: 0,
            outlier_scale: 1.0,
            sink_scale: 0.0,
        }
    }
}

/// Sampler bound to one spec; factors the covariance once.
pub struct ActivationGenerator {
    spec: ActivationSpec,
    /// Cholesky factor of the sequence covariance (None for iid).
    chol: Option<Tensor>,
    /// Which channels are outliers (chosen deterministically from the spec).
    outlier_idx: Vec<usize>,
}

impl ActivationGenerator {
    pub fn new(spec: ActivationSpec) -> Self {
        let chol = match &spec.correlation {
            Correlation::None => None,
            Correlation::Ar1 { rho } => Some(cholesky(&ar1_covariance(spec.seq_len, *rho, 1.0))),
            Correlation::Grid2d { h, w, rho_y, rho_x } => {
                assert_eq!(h * w, spec.seq_len);
                Some(cholesky(&block_toeplitz_2d(*h, *w, *rho_y, *rho_x, 1.0)))
            }
        };
        // Spread outlier channels deterministically.
        let stride = if spec.outlier_channels > 0 { spec.dim / spec.outlier_channels } else { 1 };
        let outlier_idx = (0..spec.outlier_channels).map(|k| k * stride + stride / 2).collect();
        ActivationGenerator { spec, chol, outlier_idx }
    }

    pub fn spec(&self) -> &ActivationSpec {
        &self.spec
    }

    /// Sample one `seq_len × dim` activation matrix.
    pub fn sample(&self, seed: u64) -> Tensor {
        let s = self.spec.seq_len;
        let d = self.spec.dim;
        let z = Tensor::randn(&[s, d], seed);
        let mut x = match &self.chol {
            Some(l) => l.matmul(&z),
            None => z,
        };
        // Outlier channels: amplify, with a per-channel deterministic sign
        // pattern (mimics the static channel outliers of LLM activations).
        let mut rng = XorShiftRng::new(seed ^ 0xA5A5_A5A5);
        for &j in &self.outlier_idx {
            let sign = if rng.next_f32() < 0.5 { -1.0 } else { 1.0 };
            for i in 0..s {
                let v = x.at(i, j);
                x.set(i, j, sign * (v.abs() + 1.0) * self.spec.outlier_scale);
            }
        }
        // Attention-sink token.
        if self.spec.sink_scale > 0.0 {
            for j in 0..d {
                let v = x.at(0, j);
                x.set(0, j, v * self.spec.sink_scale);
            }
        }
        x
    }

    /// A calibration set of `n` samples.
    pub fn calibration_set(&self, n: usize, seed: u64) -> Vec<Tensor> {
        (0..n).map(|i| self.sample(seed.wrapping_add(i as u64 * 7919))).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats;

    #[test]
    fn ar1_sample_is_correlated() {
        let g = ActivationGenerator::new(ActivationSpec {
            outlier_channels: 0,
            sink_scale: 0.0,
            ..ActivationSpec::llm(64, 32)
        });
        let samples = g.calibration_set(32, 1);
        let cov = stats::autocorrelation(&samples);
        // Adjacent-token correlation ≈ ρ = 0.95.
        let c01 = cov.at(0, 1) / (cov.at(0, 0) * cov.at(1, 1)).sqrt();
        assert!((c01 - 0.95).abs() < 0.05, "adjacent corr {c01}");
    }

    #[test]
    fn iid_sample_is_uncorrelated() {
        let g = ActivationGenerator::new(ActivationSpec::iid(64, 32));
        let samples = g.calibration_set(64, 2);
        let cov = stats::autocorrelation(&samples);
        let c01 = cov.at(0, 1) / cov.at(0, 0);
        assert!(c01.abs() < 0.1, "iid corr {c01}");
    }

    #[test]
    fn outlier_channels_present() {
        let spec = ActivationSpec::llm(32, 128);
        let g = ActivationGenerator::new(spec);
        let x = g.sample(3);
        let absmax = stats::channel_absmax(&x);
        let median = {
            let mut v = absmax.clone();
            v.sort_by(|a, b| a.partial_cmp(b).unwrap());
            v[v.len() / 2]
        };
        let outliers = absmax.iter().filter(|&&m| m > 5.0 * median).count();
        assert!(outliers >= 2, "found {outliers} outlier channels");
    }

    #[test]
    fn sink_token_massive() {
        let g = ActivationGenerator::new(ActivationSpec::llm(64, 64));
        let x = g.sample(4);
        let e = stats::token_energies(&x);
        let rest_max = e[1..].iter().cloned().fold(0.0f64, f64::max);
        assert!(e[0] > 10.0 * rest_max, "sink energy {} vs rest max {}", e[0], rest_max);
    }

    #[test]
    fn grid_sample_block_structure() {
        let g = ActivationGenerator::new(ActivationSpec {
            outlier_channels: 0,
            ..ActivationSpec::lvm(8, 8, 16)
        });
        let samples = g.calibration_set(48, 5);
        let cov = stats::autocorrelation(&samples);
        let norm = |i: usize, j: usize| cov.at(i, j) / (cov.at(i, i) * cov.at(j, j)).sqrt();
        // Neighbor within a grid row more correlated than across rows at
        // equal sequence distance... sequence distance 1 (same row) vs
        // sequence distance 8 (vertical neighbor) both high; distance 7
        // (row wrap) low.
        assert!(norm(0, 1) > 0.7);
        assert!(norm(0, 8) > 0.7);
        assert!(norm(7, 8) < norm(0, 1) - 0.2, "wrap {} vs in-row {}", norm(7, 8), norm(0, 1));
    }

    #[test]
    fn deterministic_given_seed() {
        let g = ActivationGenerator::new(ActivationSpec::llm(16, 16));
        assert_eq!(g.sample(9), g.sample(9));
        assert_ne!(g.sample(9), g.sample(10));
    }
}
