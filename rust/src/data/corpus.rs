//! Deterministic synthetic corpus + tokenizer (WikiText-2 stand-in).
//!
//! Sentences are produced by a small probabilistic template grammar over a
//! Zipf-distributed vocabulary. The result has (i) a heavy-tailed unigram
//! distribution, (ii) strong local syntactic structure (so a tiny LM can
//! learn something and quantization damage is *measurable* as a PPL gap),
//! and (iii) full determinism from a seed, keeping every table reproducible.

use crate::tensor::XorShiftRng;

/// Word-level tokenizer over a fixed vocabulary.
pub struct Tokenizer {
    vocab: Vec<String>,
    // index lookup; linear scan is fine at this vocab size but we keep a
    // sorted index for O(log n).
    sorted: Vec<(String, u32)>,
}

pub const PAD: u32 = 0;
pub const BOS: u32 = 1;
pub const UNK: u32 = 2;

impl Tokenizer {
    pub fn new(vocab: Vec<String>) -> Self {
        let mut sorted: Vec<(String, u32)> =
            vocab.iter().enumerate().map(|(i, w)| (w.clone(), i as u32)).collect();
        sorted.sort();
        Tokenizer { vocab, sorted }
    }

    pub fn vocab_size(&self) -> usize {
        self.vocab.len()
    }

    pub fn encode(&self, text: &str) -> Vec<u32> {
        text.split_whitespace()
            .map(|w| {
                self.sorted
                    .binary_search_by(|(s, _)| s.as_str().cmp(w))
                    .map(|i| self.sorted[i].1)
                    .unwrap_or(UNK)
            })
            .collect()
    }

    pub fn decode(&self, ids: &[u32]) -> String {
        ids.iter()
            .map(|&i| self.vocab.get(i as usize).map(|s| s.as_str()).unwrap_or("<unk>"))
            .collect::<Vec<_>>()
            .join(" ")
    }
}

/// A generated corpus: token stream + tokenizer.
pub struct Corpus {
    pub tokenizer: Tokenizer,
    pub tokens: Vec<u32>,
}

// Template grammar word pools. Deliberately small so bigram structure is
// strong (≈ low entropy) and tiny models train quickly.
const DETS: &[&str] = &["the", "a", "this", "every", "some"];
const ADJS: &[&str] = &[
    "small", "large", "quick", "quiet", "bright", "ancient", "gentle", "rusty", "hollow",
    "distant", "narrow", "golden",
];
const NOUNS: &[&str] = &[
    "model", "sequence", "token", "signal", "river", "engine", "garden", "library", "mountain",
    "letter", "circuit", "window", "harbor", "forest", "machine", "village",
];
const VERBS: &[&str] = &[
    "transforms", "compresses", "encodes", "follows", "crosses", "improves", "holds", "reads",
    "carries", "quantizes", "measures", "builds",
];
const ADVS: &[&str] = &["slowly", "carefully", "often", "rarely", "precisely", "smoothly"];
const CONJS: &[&str] = &["and", "but", "while", "because", "so"];
const PREPS: &[&str] = &["over", "under", "near", "through", "beyond", "within"];

impl Corpus {
    /// Generate `n_tokens` of corpus text from `seed`.
    pub fn generate(n_tokens: usize, seed: u64) -> Self {
        let mut vocab: Vec<String> = vec!["<pad>".into(), "<bos>".into(), "<unk>".into(), ".".into()];
        for pool in [DETS, ADJS, NOUNS, VERBS, ADVS, CONJS, PREPS] {
            for w in pool {
                vocab.push((*w).to_string());
            }
        }
        let tokenizer = Tokenizer::new(vocab);
        let mut rng = XorShiftRng::new(seed);
        let mut text = String::new();
        while text.split_whitespace().count() < n_tokens {
            text.push_str(&Self::sentence(&mut rng));
            text.push(' ');
        }
        let mut tokens = vec![BOS];
        tokens.extend(tokenizer.encode(&text));
        tokens.truncate(n_tokens);
        Corpus { tokenizer, tokens }
    }

    /// One grammatical sentence; Zipf-ish by biasing pool indices low.
    fn sentence(rng: &mut XorShiftRng) -> String {
        // Zipf-biased pick: square the uniform to favor small indices.
        fn pick<'a>(rng: &mut XorShiftRng, pool: &[&'a str]) -> &'a str {
            let u = rng.next_f64();
            let idx = ((u * u) * pool.len() as f64) as usize;
            pool[idx.min(pool.len() - 1)]
        }
        let mut s = String::new();
        s.push_str(pick(rng, DETS));
        s.push(' ');
        if rng.next_f32() < 0.6 {
            s.push_str(pick(rng, ADJS));
            s.push(' ');
        }
        s.push_str(pick(rng, NOUNS));
        s.push(' ');
        s.push_str(pick(rng, VERBS));
        s.push(' ');
        if rng.next_f32() < 0.4 {
            s.push_str(pick(rng, ADVS));
            s.push(' ');
        }
        s.push_str(pick(rng, PREPS));
        s.push(' ');
        s.push_str(pick(rng, DETS));
        s.push(' ');
        s.push_str(pick(rng, NOUNS));
        if rng.next_f32() < 0.3 {
            s.push(' ');
            s.push_str(pick(rng, CONJS));
            s.push(' ');
            s.push_str(pick(rng, DETS));
            s.push(' ');
            s.push_str(pick(rng, NOUNS));
            s.push(' ');
            s.push_str(pick(rng, VERBS));
        }
        s.push_str(" .");
        s
    }

    /// Split into fixed-length non-overlapping sequences (LM batches).
    pub fn sequences(&self, seq_len: usize) -> Vec<&[u32]> {
        self.tokens.chunks_exact(seq_len).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let a = Corpus::generate(1000, 7);
        let b = Corpus::generate(1000, 7);
        assert_eq!(a.tokens, b.tokens);
        assert_ne!(a.tokens, Corpus::generate(1000, 8).tokens);
    }

    #[test]
    fn tokenizer_roundtrip() {
        let c = Corpus::generate(100, 1);
        let text = "the small model transforms over the river .";
        let ids = c.tokenizer.encode(text);
        assert!(!ids.contains(&UNK), "all grammar words must be in vocab");
        assert_eq!(c.tokenizer.decode(&ids), text);
    }

    #[test]
    fn unk_for_oov() {
        let c = Corpus::generate(100, 1);
        assert_eq!(c.tokenizer.encode("xyzzy"), vec![UNK]);
    }

    #[test]
    fn length_and_bos() {
        let c = Corpus::generate(5000, 3);
        assert_eq!(c.tokens.len(), 5000);
        assert_eq!(c.tokens[0], BOS);
    }

    #[test]
    fn heavy_tailed_unigrams() {
        // Zipf bias: the most frequent non-period word should appear much
        // more often than the median word.
        let c = Corpus::generate(20_000, 11);
        let mut counts = vec![0usize; c.tokenizer.vocab_size()];
        for &t in &c.tokens {
            counts[t as usize] += 1;
        }
        let mut nonzero: Vec<usize> = counts.iter().cloned().filter(|&c| c > 0).collect();
        nonzero.sort_unstable_by(|a, b| b.cmp(a));
        assert!(nonzero[0] > 4 * nonzero[nonzero.len() / 2]);
    }

    #[test]
    fn sequences_chunking() {
        let c = Corpus::generate(1024, 2);
        let seqs = c.sequences(256);
        assert_eq!(seqs.len(), 4);
        assert!(seqs.iter().all(|s| s.len() == 256));
    }
}
