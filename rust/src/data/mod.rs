//! Data substrate.
//!
//! The reproduction environment has no datasets (see DESIGN.md §3), so this
//! module builds the closest synthetic equivalents:
//!
//! * [`corpus`] — a deterministic English-like corpus + word tokenizer,
//!   standing in for WikiText-2. Generated from a template grammar with a
//!   Zipfian vocabulary so that n-gram statistics are non-trivial and a
//!   tiny LM trained on it reaches meaningfully-below-uniform perplexity.
//! * [`activations`] — samplers for activation matrices with prescribed
//!   (block-)Toeplitz autocorrelation and per-channel outliers, calibrated
//!   to the qualitative structure of the paper's Figure 3.
//! * [`prompts`] — small prompt sets standing in for COCO / MJHQ in the
//!   LVM tables (they seed the DiT latent generator).

pub mod activations;
pub mod corpus;
pub mod prompts;

pub use activations::{ActivationGenerator, ActivationSpec};
pub use corpus::{Corpus, Tokenizer};
pub use prompts::PromptSet;
