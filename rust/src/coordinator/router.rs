//! Router: owns one [`DynamicBatcher`] per registered variant and decides
//! which worker pool a formed batch goes to. Unknown variants are rejected
//! at submit time (routing totality over the registered set).

use super::{Batch, DynamicBatcher, Request};
use std::collections::HashMap;
use std::time::{Duration, Instant};

pub struct Router {
    batchers: HashMap<String, DynamicBatcher>,
    max_batch: usize,
    max_wait: Duration,
}

impl Router {
    pub fn new(variants: &[&str], max_batch: usize, max_wait: Duration) -> Self {
        let batchers = variants
            .iter()
            .map(|v| (v.to_string(), DynamicBatcher::new(v, max_batch, max_wait)))
            .collect();
        Router { batchers, max_batch, max_wait }
    }

    pub fn variants(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.batchers.keys().map(|s| s.as_str()).collect();
        v.sort();
        v
    }

    /// Register a variant at runtime (e.g. a newly calibrated stack).
    pub fn register(&mut self, variant: &str) {
        self.batchers
            .entry(variant.to_string())
            .or_insert_with(|| DynamicBatcher::new(variant, self.max_batch, self.max_wait));
    }

    /// Route a request into its variant's batcher. Returns `Err(req)` for
    /// unknown variants; `Ok(Some(batch))` when the push filled a batch.
    pub fn route(&mut self, req: Request, now: Instant) -> Result<Option<Batch>, Request> {
        match self.batchers.get_mut(&req.variant) {
            Some(b) => Ok(b.push(req, now)),
            None => Err(req),
        }
    }

    /// Collect every batch whose deadline has passed.
    pub fn poll_deadlines(&mut self, now: Instant) -> Vec<Batch> {
        let mut out = Vec::new();
        for b in self.batchers.values_mut() {
            while let Some(batch) = b.poll(now) {
                out.push(batch);
            }
        }
        out
    }

    /// Earliest pending deadline across variants (sleep hint).
    pub fn next_deadline(&self) -> Option<Instant> {
        self.batchers.values().filter_map(|b| b.next_deadline()).min()
    }

    /// Flush everything (shutdown).
    pub fn flush_all(&mut self, now: Instant) -> Vec<Batch> {
        let mut out = Vec::new();
        for b in self.batchers.values_mut() {
            while let Some(batch) = b.flush(now) {
                out.push(batch);
            }
        }
        out
    }

    pub fn total_pending(&self) -> usize {
        self.batchers.values().map(|b| b.pending()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;
    use std::sync::mpsc;

    fn req(id: u64, variant: &str) -> Request {
        let (tx, _rx) = mpsc::channel();
        Request {
            id,
            variant: variant.into(),
            input: Tensor::zeros(&[1, 1]),
            submitted: Instant::now(),
            respond: tx,
        }
    }

    #[test]
    fn routes_by_variant() {
        let now = Instant::now();
        let mut r = Router::new(&["a", "b"], 2, Duration::from_millis(10));
        assert!(r.route(req(1, "a"), now).unwrap().is_none());
        assert!(r.route(req(2, "b"), now).unwrap().is_none());
        // Filling `a` must not emit `b`'s pending request.
        let batch = r.route(req(3, "a"), now).unwrap().expect("a full");
        assert_eq!(batch.variant, "a");
        assert_eq!(batch.len(), 2);
        assert_eq!(r.total_pending(), 1);
    }

    #[test]
    fn unknown_variant_rejected() {
        let now = Instant::now();
        let mut r = Router::new(&["a"], 2, Duration::from_millis(10));
        let rejected = r.route(req(1, "nope"), now).unwrap_err();
        assert_eq!(rejected.variant, "nope");
        r.register("nope");
        assert!(r.route(req(2, "nope"), now).is_ok());
    }

    #[test]
    fn poll_deadlines_across_variants() {
        let t0 = Instant::now();
        let mut r = Router::new(&["a", "b"], 8, Duration::from_millis(5));
        r.route(req(1, "a"), t0).unwrap();
        r.route(req(2, "b"), t0).unwrap();
        let later = t0 + Duration::from_millis(6);
        let batches = r.poll_deadlines(later);
        assert_eq!(batches.len(), 2);
        assert_eq!(r.total_pending(), 0);
    }

    #[test]
    fn property_no_cross_variant_mixing() {
        crate::testkit::check(
            "router-no-mixing",
            40,
            0x40073,
            |g| {
                let n = g.usize_in(1, 40);
                (0..n).map(|_| g.usize_in(0, 2)).collect::<Vec<usize>>()
            },
            |variant_ids| {
                let now = Instant::now();
                let names = ["a", "b", "c"];
                let mut r = Router::new(&names, 3, Duration::from_millis(50));
                let mut batches = Vec::new();
                for (i, &v) in variant_ids.iter().enumerate() {
                    if let Some(b) = r.route(req(i as u64, names[v]), now).unwrap() {
                        batches.push(b);
                    }
                }
                batches.extend(r.flush_all(now));
                let emitted: usize = batches.iter().map(|b| b.len()).sum();
                if emitted != variant_ids.len() {
                    return Err(format!("lost: {} != {}", emitted, variant_ids.len()));
                }
                for b in &batches {
                    for rq in &b.requests {
                        if rq.variant != b.variant {
                            return Err(format!("mixed batch: {} in {}", rq.variant, b.variant));
                        }
                    }
                }
                Ok(())
            },
        );
    }
}
