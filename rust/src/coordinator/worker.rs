//! Worker pool: N std threads draining a bounded batch queue and running
//! an [`Executor`]. Bounded queues give natural backpressure: the router
//! blocks (or sheds) when workers fall behind.
//!
//! Sizing comes from the same [`crate::parallel`] policy the tensor/quant
//! kernels use (`STAMP_THREADS`), via [`WorkerPool::default_workers`], and
//! worker threads are marked kernel-serial
//! ([`crate::parallel::set_kernel_serial`]): kernels invoked from a worker
//! run on that worker's thread alone, so batch-level and kernel-level
//! parallelism never multiply into oversubscription.

use super::batcher::AdmissionQueue;
use super::{Batch, Metrics, Request, Response};
use crate::obs::{EngineObs, TraceKind, SHED_STREAM};
use crate::tensor::Tensor;
use std::collections::HashMap;
use std::sync::atomic::Ordering;
use std::sync::mpsc::{
    channel, sync_channel, Receiver, RecvTimeoutError, Sender, SyncSender, TryRecvError,
    TrySendError,
};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// What a worker runs on a batch of inputs (all same variant + shape).
pub trait Executor: Send + Sync + 'static {
    /// Process each input; one output per input. An `Err` fails the whole
    /// batch (each request receives the error). The executor sees the
    /// *whole* batch, so it can fuse it (the native executor admits a
    /// batch of generate requests into one step-synchronized
    /// [`crate::decode::DecodeEngine`] run) rather than loop per request.
    fn execute(&self, variant: &str, inputs: &[&Tensor]) -> Result<Vec<Tensor>, String>;

    /// Engine-side observability for `variant`, if this executor runs a
    /// decode engine for it. Workers link it into the variant's
    /// [`super::VariantMetrics`] so TTFT/TPOT reach the expositions. The
    /// default keeps closure executors and mocks trivially conforming.
    fn obs(&self, _variant: &str) -> Option<Arc<EngineObs>> {
        None
    }
}

/// Blanket impl so closures can be executors in tests/examples.
impl<F> Executor for F
where
    F: Fn(&str, &[&Tensor]) -> Result<Vec<Tensor>, String> + Send + Sync + 'static,
{
    fn execute(&self, variant: &str, inputs: &[&Tensor]) -> Result<Vec<Tensor>, String> {
        self(variant, inputs)
    }
}

pub struct WorkerPool {
    tx: SyncSender<Batch>,
    handles: Vec<JoinHandle<()>>,
}

impl WorkerPool {
    /// Worker count used when a config doesn't pin one: the crate-wide
    /// thread policy ([`crate::parallel::num_threads`], i.e.
    /// `STAMP_THREADS` when set), capped at 8. Workers run kernels
    /// serially (see [`crate::parallel::set_kernel_serial`]), so N workers
    /// use ≈ N cores; the cap just bounds idle threads on very wide hosts.
    pub fn default_workers() -> usize {
        crate::parallel::num_threads().clamp(1, 8)
    }

    pub fn new(
        workers: usize,
        queue_depth: usize,
        executor: Arc<dyn Executor>,
        metrics: Arc<Metrics>,
    ) -> Self {
        assert!(workers >= 1);
        let (tx, rx) = sync_channel::<Batch>(queue_depth);
        let rx = Arc::new(Mutex::new(rx));
        let mut handles = Vec::new();
        for wid in 0..workers {
            let rx = rx.clone();
            let executor = executor.clone();
            let metrics = metrics.clone();
            handles.push(
                std::thread::Builder::new()
                    .name(format!("stamp-worker-{wid}"))
                    .spawn(move || worker_loop(rx, executor, metrics))
                    .expect("spawn worker"),
            );
        }
        WorkerPool { tx, handles }
    }

    /// Submit a batch; blocks when the queue is full (backpressure).
    pub fn submit(&self, batch: Batch) {
        self.tx.send(batch).expect("worker pool shut down");
    }

    /// Clone the ingest sender (used by the server's router thread, which
    /// outlives this borrow).
    pub fn clone_sender(&self) -> SyncSender<Batch> {
        self.tx.clone()
    }

    /// Non-blocking submit; returns the batch back on a full queue so the
    /// caller can shed or retry.
    pub fn try_submit(&self, batch: Batch) -> Result<(), Batch> {
        match self.tx.try_send(batch) {
            Ok(()) => Ok(()),
            Err(TrySendError::Full(b)) => Err(b),
            Err(TrySendError::Disconnected(_)) => panic!("worker pool shut down"),
        }
    }

    /// Drop the sender and join the workers (drains remaining batches).
    pub fn shutdown(self) {
        drop(self.tx);
        for h in self.handles {
            h.join().expect("worker panicked");
        }
    }
}

fn worker_loop(rx: Arc<Mutex<Receiver<Batch>>>, executor: Arc<dyn Executor>, metrics: Arc<Metrics>) {
    // Workers own the cores at batch granularity; kernels they call run
    // serially so inter-op × intra-op parallelism can't oversubscribe.
    crate::parallel::set_kernel_serial(true);
    loop {
        // Hold the lock only while receiving so workers pull concurrently.
        let batch = match rx.lock().unwrap().recv() {
            Ok(b) => b,
            Err(_) => return, // all senders dropped
        };
        let vm = metrics.variant(&batch.variant);
        if vm.engine_obs().is_none() {
            if let Some(obs) = executor.obs(&batch.variant) {
                vm.link_engine_obs(obs);
            }
        }
        vm.queue_depth.fetch_add(1, Ordering::Relaxed);
        let t0 = Instant::now();
        let inputs: Vec<&Tensor> = batch.requests.iter().map(|r| &r.input).collect();
        let result = executor.execute(&batch.variant, &inputs);
        let service_us = t0.elapsed().as_micros() as u64;
        let batch_size = batch.requests.len();
        let queued_us = batch
            .requests
            .iter()
            .map(|r| batch.formed_at.duration_since(r.submitted).as_micros() as u64)
            .sum::<u64>()
            / batch_size.max(1) as u64;
        vm.record_batch(batch_size, queued_us, service_us);

        match result {
            Ok(outputs) => {
                assert_eq!(outputs.len(), batch_size, "executor output arity");
                for (req, out) in batch.requests.into_iter().zip(outputs) {
                    let _ = req.respond.send(Response {
                        id: req.id,
                        variant: batch.variant.clone(),
                        output: Ok(out),
                        queued_us,
                        service_us,
                        batch_size,
                    });
                }
            }
            Err(msg) => {
                // `errors` counts *requests* that received an error
                // response (see [`super::VariantMetrics::errors`]): a
                // failed batch errors every one of its `batch_size`
                // requests, matching the streaming path's one-increment-
                // per-request accounting.
                vm.errors.fetch_add(batch_size as u64, Ordering::Relaxed);
                for req in batch.requests {
                    let _ = req.respond.send(Response {
                        id: req.id,
                        variant: batch.variant.clone(),
                        output: Err(msg.clone()),
                        queued_us,
                        service_us,
                        batch_size,
                    });
                }
            }
        }
        vm.queue_depth.fetch_sub(1, Ordering::Relaxed);
    }
}

/// The continuous-batching counterpart of [`Executor`] (PR 6): instead of
/// one blocking call per formed batch, the executor exposes a *running*
/// decode engine — streams are seated one at a time as slots free up,
/// advanced collectively one step at a time, and handed back as each one
/// finishes. [`crate::runtime::NativeExecutor`] implements this over its
/// per-variant resident [`crate::decode::DecodeEngine`].
///
/// All methods take `&self`: implementations guard their engine with
/// interior locking, and one [`StreamWorker`] thread drives one variant.
pub trait StreamExecutor: Send + Sync + 'static {
    /// Engine slots free for `variant` right now (0 for unknown /
    /// non-streaming variants — nothing will ever be admitted).
    fn free_slots(&self, variant: &str) -> usize;
    /// Seat one request in a free slot; returns the engine-assigned
    /// stream id. `Err` rejects just this request (malformed input, no
    /// free slot) — in-flight streams are unaffected.
    fn admit(&self, variant: &str, input: &Tensor) -> Result<u64, String>;
    /// Advance every in-flight stream by one unit of work and return the
    /// streams that finished, as (stream id, output).
    fn step(&self, variant: &str) -> Vec<(u64, Result<Tensor, String>)>;
    /// `true` while any stream is in flight for `variant`.
    fn has_work(&self, variant: &str) -> bool;
    /// Cumulative prompt-prefix cache hits for `variant`'s engine (see
    /// [`crate::decode::DecodeEngine::prefix_hits`]). The default keeps
    /// executors without a prefix cache — and test mocks — trivially
    /// conforming at 0.
    fn prefix_hits(&self, _variant: &str) -> u64 {
        0
    }
    /// Engine-side observability for `variant` (same contract as
    /// [`Executor::obs`]). Default `None` keeps mocks conforming.
    fn obs(&self, _variant: &str) -> Option<Arc<EngineObs>> {
        None
    }
    /// Drain `variant`'s trace ring to JSONL (empty when tracing is off
    /// or the executor has no engine for the variant).
    fn drain_trace(&self, _variant: &str) -> String {
        String::new()
    }
}

/// Ingest message for a [`StreamWorker`].
pub enum StreamIngest {
    Req(Request),
    Shutdown,
}

/// One thread continuously feeding one variant's decode engine
/// (module-level scheduler of the PR 6 continuous-batching path):
///
/// ```text
/// ingest ──► AdmissionQueue (FIFO, max_pending bound, admit deadline)
///               │ pop_ready(free_slots, now)     │ expire(now)
///               │ ready        └─ expired ──┐    ▼
///               ▼                           └► shed (error response)
///        StreamExecutor::admit
///               │
///        StreamExecutor::step ──► finished streams ──► responses
/// ```
///
/// Scheduling policy: arrival-order fairness (strict FIFO admission),
/// backpressure by shedding pushes past `max_pending`, and optional
/// per-request admission deadlines. Every decision is surfaced through
/// [`super::VariantMetrics`]: `admitted`/`admit_wait_us_total` per seated
/// stream, `shed` (monotone) per rejected/expired request, `inflight` as
/// the live gauge, and each completed stream records a size-1 batch with
/// its true queued/service split. On shutdown the worker stops accepting
/// work but keeps stepping until the queue and engine are empty — no
/// stream is lost or double-retired (pinned by the drain test).
pub struct StreamWorker {
    tx: Sender<StreamIngest>,
    handle: Option<JoinHandle<()>>,
}

impl StreamWorker {
    pub fn new(
        variant: &str,
        executor: Arc<dyn StreamExecutor>,
        metrics: Arc<Metrics>,
        max_pending: usize,
        admit_deadline: Option<Duration>,
    ) -> Self {
        let (tx, rx) = channel::<StreamIngest>();
        let variant = variant.to_string();
        let handle = std::thread::Builder::new()
            .name(format!("stamp-stream-{variant}"))
            .spawn(move || {
                stream_worker_loop(rx, variant, executor, metrics, max_pending, admit_deadline)
            })
            .expect("spawn stream worker");
        StreamWorker { tx, handle: Some(handle) }
    }

    /// Submit one request (never blocks; backpressure is applied by the
    /// worker shedding past its queue bound).
    pub fn submit(&self, req: Request) {
        self.tx.send(StreamIngest::Req(req)).expect("stream worker shut down");
    }

    /// Clone the ingest sender (for the server's router thread).
    pub fn clone_sender(&self) -> Sender<StreamIngest> {
        self.tx.clone()
    }

    /// Stop accepting work, finish every queued and in-flight stream,
    /// then join the worker thread.
    pub fn shutdown(mut self) {
        let _ = self.tx.send(StreamIngest::Shutdown);
        if let Some(h) = self.handle.take() {
            h.join().expect("stream worker panicked");
        }
    }
}

fn stream_worker_loop(
    rx: Receiver<StreamIngest>,
    variant: String,
    executor: Arc<dyn StreamExecutor>,
    metrics: Arc<Metrics>,
    max_pending: usize,
    admit_deadline: Option<Duration>,
) {
    // Like pool workers: the thread owns its core at stream granularity;
    // kernels it calls run serially (no inter-op × intra-op blowup).
    crate::parallel::set_kernel_serial(true);
    let vm = metrics.variant(&variant);
    let mut queue: AdmissionQueue<Request> = AdmissionQueue::new(max_pending, admit_deadline);
    // Stream id → (request, admitted-at), for routing finished streams
    // back to their response channels. One entry per admission; removed
    // exactly once on completion.
    let mut inflight: HashMap<u64, (Request, Instant)> = HashMap::new();
    let mut open = true;

    // Engine-side observability, linked once so `Metrics::prometheus()`/
    // `to_json()` can surface this variant's TTFT/TPOT, and so scheduler
    // sheds land in the same trace timeline as the engine's own events.
    let eng_obs = executor.obs(&variant);
    if let Some(obs) = &eng_obs {
        vm.link_engine_obs(obs.clone());
    }

    /// Why a request was shed — each reason has its own monotone counter
    /// (`shed` stays their sum for snapshot compatibility).
    enum ShedReason {
        Overflow,
        Deadline,
    }
    let shed = |req: Request, reason: ShedReason, msg: String| {
        match reason {
            ShedReason::Overflow => vm.record_shed_overflow(),
            ShedReason::Deadline => vm.record_shed_deadline(),
        }
        // A shed request received an error response: per-request `errors`
        // semantics, same as the batch path.
        vm.errors.fetch_add(1, Ordering::Relaxed);
        if let Some(obs) = &eng_obs {
            // Shed happens before the request has a stream id — the
            // sentinel serializes as `"stream":null` in the timeline.
            obs.record_event(TraceKind::Shed, SHED_STREAM, obs.now_us(), 0);
        }
        let _ = req.respond.send(Response {
            id: req.id,
            variant: variant.clone(),
            output: Err(msg),
            queued_us: 0,
            service_us: 0,
            batch_size: 0,
        });
    };

    loop {
        // (1) Ingest. Block only when fully idle (nothing queued, nothing
        // in flight); under load, drain whatever is waiting and keep
        // stepping.
        if open && queue.is_empty() && inflight.is_empty() {
            match rx.recv_timeout(Duration::from_millis(50)) {
                Ok(StreamIngest::Req(r)) => {
                    if let Err(r) = queue.push(r, Instant::now()) {
                        shed(
                            r,
                            ShedReason::Overflow,
                            format!("admission queue full ({max_pending} pending): request shed"),
                        );
                    }
                }
                Ok(StreamIngest::Shutdown) | Err(RecvTimeoutError::Disconnected) => open = false,
                Err(RecvTimeoutError::Timeout) => {}
            }
        }
        while open {
            match rx.try_recv() {
                Ok(StreamIngest::Req(r)) => {
                    if let Err(r) = queue.push(r, Instant::now()) {
                        shed(
                            r,
                            ShedReason::Overflow,
                            format!("admission queue full ({max_pending} pending): request shed"),
                        );
                    }
                }
                Ok(StreamIngest::Shutdown) | Err(TryRecvError::Disconnected) => {
                    open = false;
                }
                Err(TryRecvError::Empty) => break,
            }
        }

        // (2) Shed requests whose admission deadline expired while they
        // waited for a slot.
        let now = Instant::now();
        for (req, submitted) in queue.expire(now) {
            let waited_us = now.duration_since(submitted).as_micros();
            shed(
                req,
                ShedReason::Deadline,
                format!("admission deadline exceeded after {waited_us}µs in queue"),
            );
        }

        // (3) Admit in arrival order while the engine has free slots.
        // pop_ready re-checks deadlines at the pop instant (boundary
        // inclusive), so a request expiring in the gap since (2) is shed
        // here, never seated late.
        let now = Instant::now();
        let popped = queue.pop_ready(executor.free_slots(&variant), now);
        for (req, submitted) in popped.expired {
            let waited_us = now.duration_since(submitted).as_micros();
            shed(
                req,
                ShedReason::Deadline,
                format!("admission deadline exceeded after {waited_us}µs in queue"),
            );
        }
        let mut admitted_any = false;
        for (req, _submitted) in popped.ready {
            let now = Instant::now();
            let wait_us = now.duration_since(req.submitted).as_micros() as u64;
            match executor.admit(&variant, &req.input) {
                Ok(sid) => {
                    vm.record_admit(wait_us);
                    vm.inflight.fetch_add(1, Ordering::Relaxed);
                    inflight.insert(sid, (req, now));
                    admitted_any = true;
                }
                Err(msg) => {
                    vm.errors.fetch_add(1, Ordering::Relaxed);
                    let _ = req.respond.send(Response {
                        id: req.id,
                        variant: variant.clone(),
                        output: Err(msg),
                        queued_us: wait_us,
                        service_us: 0,
                        batch_size: 0,
                    });
                }
            }
        }
        if admitted_any {
            // Mirror the engine's cumulative prefix-cache hit counter into
            // the variant metrics; only admissions can change it.
            vm.prefix_hits.store(executor.prefix_hits(&variant), Ordering::Relaxed);
        }

        // (4) One engine step; deliver every stream that finished. Also
        // step when *our* queue is blocked behind someone else's in-flight
        // streams (the engine is shared state) — advancing them frees
        // slots.
        if !inflight.is_empty() || (!queue.is_empty() && executor.has_work(&variant)) {
            for (sid, out) in executor.step(&variant) {
                if let Some((req, admitted_at)) = inflight.remove(&sid) {
                    vm.dec_inflight();
                    let done = Instant::now();
                    let queued_us = admitted_at.duration_since(req.submitted).as_micros() as u64;
                    let service_us = done.duration_since(admitted_at).as_micros() as u64;
                    vm.record_batch(1, queued_us, service_us);
                    if out.is_err() {
                        vm.errors.fetch_add(1, Ordering::Relaxed);
                    }
                    let _ = req.respond.send(Response {
                        id: req.id,
                        variant: variant.clone(),
                        output: out,
                        queued_us,
                        service_us,
                        batch_size: 1,
                    });
                }
            }
        }

        // (5) Drain-on-shutdown: exit only once every accepted request has
        // been answered.
        if !open && queue.is_empty() && inflight.is_empty() {
            return;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Request;
    use std::sync::mpsc;
    use std::time::Duration;

    fn mk_batch(variant: &str, n: usize) -> (Batch, Vec<mpsc::Receiver<Response>>) {
        let now = Instant::now();
        let mut reqs = Vec::new();
        let mut rxs = Vec::new();
        for i in 0..n {
            let (tx, rx) = mpsc::channel();
            reqs.push(Request {
                id: i as u64,
                variant: variant.into(),
                input: Tensor::full(&[2, 2], i as f32),
                submitted: now,
                respond: tx,
            });
            rxs.push(rx);
        }
        (Batch { variant: variant.into(), requests: reqs, formed_at: now }, rxs)
    }

    #[test]
    fn executes_and_responds() {
        let metrics = Arc::new(Metrics::new());
        let exec: Arc<dyn Executor> = Arc::new(|_v: &str, inputs: &[&Tensor]| {
            Ok(inputs.iter().map(|t| t.scale(2.0)).collect::<Vec<_>>())
        });
        let pool = WorkerPool::new(2, 8, exec, metrics.clone());
        let (batch, rxs) = mk_batch("v", 4);
        pool.submit(batch);
        for (i, rx) in rxs.iter().enumerate() {
            let resp = rx.recv_timeout(Duration::from_secs(5)).unwrap();
            assert_eq!(resp.id, i as u64);
            assert_eq!(resp.batch_size, 4);
            let out = resp.output.unwrap();
            assert_eq!(out.at(0, 0), 2.0 * i as f32);
        }
        pool.shutdown();
        assert_eq!(metrics.variant("v").requests.load(Ordering::Relaxed), 4);
    }

    #[test]
    fn errors_propagate_to_every_request() {
        let metrics = Arc::new(Metrics::new());
        let exec: Arc<dyn Executor> =
            Arc::new(|_v: &str, _i: &[&Tensor]| -> Result<Vec<Tensor>, String> { Err("boom".into()) });
        let pool = WorkerPool::new(1, 4, exec, metrics.clone());
        let (batch, rxs) = mk_batch("v", 3);
        pool.submit(batch);
        for rx in &rxs {
            let resp = rx.recv_timeout(Duration::from_secs(5)).unwrap();
            assert_eq!(resp.output.unwrap_err(), "boom");
        }
        pool.shutdown();
        assert_eq!(metrics.variant("v").errors.load(Ordering::Relaxed), 3);
    }

    #[test]
    fn try_submit_sheds_on_full_queue() {
        let metrics = Arc::new(Metrics::new());
        // Slow executor + queue depth 1 forces Full.
        let exec: Arc<dyn Executor> = Arc::new(|_v: &str, inputs: &[&Tensor]| {
            std::thread::sleep(Duration::from_millis(50));
            Ok(inputs.iter().map(|t| (*t).clone()).collect::<Vec<_>>())
        });
        let pool = WorkerPool::new(1, 1, exec, metrics);
        let mut shed = 0;
        let mut rx_keep = Vec::new();
        for _ in 0..6 {
            let (batch, rxs) = mk_batch("v", 1);
            match pool.try_submit(batch) {
                Ok(()) => rx_keep.extend(rxs),
                Err(_returned) => shed += 1,
            }
        }
        assert!(shed > 0, "bounded queue must shed under load");
        for rx in &rx_keep {
            rx.recv_timeout(Duration::from_secs(5)).unwrap().output.unwrap();
        }
        pool.shutdown();
    }

    // ---- StreamWorker -------------------------------------------------

    /// Deterministic fake engine: `slots` seats, each stream finishes
    /// after `steps_to_finish` steps (optionally sleeping per step to
    /// make queueing observable), output = input × 2.
    struct MockStream {
        slots: usize,
        steps_to_finish: usize,
        step_sleep: Duration,
        state: Mutex<MockState>,
    }

    #[derive(Default)]
    struct MockState {
        next_id: u64,
        inflight: Vec<(u64, Tensor, usize)>,
        peak: usize,
        admitted_inputs: Vec<f32>,
    }

    impl MockStream {
        fn new(slots: usize, steps_to_finish: usize, step_sleep: Duration) -> Self {
            MockStream { slots, steps_to_finish, step_sleep, state: Mutex::new(MockState::default()) }
        }
    }

    impl StreamExecutor for MockStream {
        fn free_slots(&self, _v: &str) -> usize {
            self.slots - self.state.lock().unwrap().inflight.len()
        }

        fn admit(&self, _v: &str, input: &Tensor) -> Result<u64, String> {
            let mut st = self.state.lock().unwrap();
            if st.inflight.len() >= self.slots {
                return Err("no free slot".into());
            }
            let id = st.next_id;
            st.next_id += 1;
            st.inflight.push((id, input.clone(), self.steps_to_finish));
            st.admitted_inputs.push(input.at(0, 0));
            let n = st.inflight.len();
            st.peak = st.peak.max(n);
            Ok(id)
        }

        fn step(&self, _v: &str) -> Vec<(u64, Result<Tensor, String>)> {
            if !self.step_sleep.is_zero() {
                std::thread::sleep(self.step_sleep);
            }
            let mut st = self.state.lock().unwrap();
            let mut done = Vec::new();
            st.inflight.retain_mut(|(id, input, left)| {
                *left -= 1;
                if *left == 0 {
                    done.push((*id, Ok(input.scale(2.0))));
                    false
                } else {
                    true
                }
            });
            done
        }

        fn has_work(&self, _v: &str) -> bool {
            !self.state.lock().unwrap().inflight.is_empty()
        }
    }

    /// All requests share one response channel, so recv order IS the
    /// completion order.
    fn stream_reqs(n: usize) -> (Vec<Request>, mpsc::Receiver<Response>) {
        let (tx, rx) = mpsc::channel();
        let reqs = (0..n)
            .map(|i| Request {
                id: i as u64,
                variant: "gen".into(),
                input: Tensor::full(&[1, 1], i as f32),
                submitted: Instant::now(),
                respond: tx.clone(),
            })
            .collect();
        (reqs, rx)
    }

    #[test]
    fn stream_worker_is_fifo_and_never_exceeds_slot_cap() {
        let metrics = Arc::new(Metrics::new());
        let mock = Arc::new(MockStream::new(2, 2, Duration::ZERO));
        let w = StreamWorker::new("gen", mock.clone(), metrics.clone(), 64, None);
        let (reqs, rx) = stream_reqs(6);
        for r in reqs {
            w.submit(r);
        }
        let mut order = Vec::new();
        for _ in 0..6 {
            let resp = rx.recv_timeout(Duration::from_secs(5)).unwrap();
            assert_eq!(resp.output.unwrap().at(0, 0), 2.0 * resp.id as f32);
            assert_eq!(resp.batch_size, 1);
            order.push(resp.id);
        }
        w.shutdown();
        // Arrival-order fairness under equal deadlines: equal-length
        // streams admitted FIFO finish FIFO — nobody jumps the queue.
        let sorted: Vec<u64> = (0..6).collect();
        assert_eq!(order, sorted, "completion order must match arrival order");
        let st = mock.state.lock().unwrap();
        assert_eq!(st.admitted_inputs, vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0], "admission is FIFO");
        assert!(st.peak <= 2, "admitted past max_inflight: peak {}", st.peak);
        let vm = metrics.variant("gen");
        assert_eq!(vm.admitted.load(Ordering::Relaxed), 6);
        assert_eq!(vm.inflight.load(Ordering::Relaxed), 0, "gauge returns to zero");
        assert_eq!(vm.shed.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn stream_worker_sheds_past_queue_bound_without_losing_requests() {
        let metrics = Arc::new(Metrics::new());
        // One slot, slow steps, queue bound 1: a fast burst must shed.
        let mock = Arc::new(MockStream::new(1, 20, Duration::from_millis(1)));
        let w = StreamWorker::new("gen", mock, metrics.clone(), 1, None);
        let (reqs, rx) = stream_reqs(8);
        for r in reqs {
            w.submit(r);
        }
        let mut served = 0;
        let mut shed = 0;
        for _ in 0..8 {
            let resp = rx.recv_timeout(Duration::from_secs(10)).unwrap();
            match resp.output {
                Ok(_) => served += 1,
                Err(msg) => {
                    assert!(msg.contains("admission queue full"), "{msg}");
                    shed += 1;
                }
            }
        }
        w.shutdown();
        // Every request is answered exactly once: served + shed == sent.
        assert_eq!(served + shed, 8);
        assert!(shed > 0, "bounded admission queue must shed under burst");
        let vm = metrics.variant("gen");
        assert_eq!(vm.shed.load(Ordering::Relaxed), shed as u64);
        // Regression (PR 8): the queue-bound path must land in
        // `shed_overflow`, never `shed_deadline`.
        assert_eq!(vm.shed_overflow.load(Ordering::Relaxed), shed as u64);
        assert_eq!(vm.shed_deadline.load(Ordering::Relaxed), 0);
        assert_eq!(vm.admitted.load(Ordering::Relaxed), served as u64);
        // Every shed request received an error response (per-request
        // `errors` semantics on the streaming path).
        assert_eq!(vm.errors.load(Ordering::Relaxed), shed as u64);
    }

    #[test]
    fn stream_worker_sheds_on_admission_deadline() {
        let metrics = Arc::new(Metrics::new());
        // One busy slot (~40ms of stepping) and a 5ms admission deadline:
        // the queued request must expire, not wait for the slot.
        let mock = Arc::new(MockStream::new(1, 40, Duration::from_millis(1)));
        let w = StreamWorker::new("gen", mock, metrics.clone(), 8, Some(Duration::from_millis(5)));
        let (reqs, rx) = stream_reqs(2);
        for r in reqs {
            w.submit(r);
        }
        let mut outcomes: Vec<(u64, Result<Tensor, String>)> = (0..2)
            .map(|_| rx.recv_timeout(Duration::from_secs(10)).unwrap())
            .map(|r| (r.id, r.output))
            .collect();
        w.shutdown();
        outcomes.sort_by_key(|(id, _)| *id);
        assert!(outcomes[0].1.is_ok(), "first request holds the slot and completes");
        let err = outcomes[1].1.as_ref().unwrap_err();
        assert!(err.contains("admission deadline exceeded"), "{err}");
        let vm = metrics.variant("gen");
        assert_eq!(vm.shed.load(Ordering::Relaxed), 1);
        // Regression (PR 8): the deadline path must land in
        // `shed_deadline`, never `shed_overflow`.
        assert_eq!(vm.shed_deadline.load(Ordering::Relaxed), 1);
        assert_eq!(vm.shed_overflow.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn stream_worker_counts_errors_per_request() {
        // Pin the per-request `errors` meaning on the streaming path: an
        // executor that rejects every admission errors each request once
        // (the batch path's counterpart is `errors_propagate_to_every_
        // request`, where a failed batch of 3 counts 3).
        struct RejectAll;
        impl StreamExecutor for RejectAll {
            fn free_slots(&self, _v: &str) -> usize {
                1
            }
            fn admit(&self, _v: &str, _input: &Tensor) -> Result<u64, String> {
                Err("malformed input".into())
            }
            fn step(&self, _v: &str) -> Vec<(u64, Result<Tensor, String>)> {
                Vec::new()
            }
            fn has_work(&self, _v: &str) -> bool {
                false
            }
        }
        let metrics = Arc::new(Metrics::new());
        let w = StreamWorker::new("gen", Arc::new(RejectAll), metrics.clone(), 8, None);
        let (reqs, rx) = stream_reqs(3);
        for r in reqs {
            w.submit(r);
        }
        for _ in 0..3 {
            let resp = rx.recv_timeout(Duration::from_secs(5)).unwrap();
            assert_eq!(resp.output.unwrap_err(), "malformed input");
        }
        w.shutdown();
        let vm = metrics.variant("gen");
        assert_eq!(vm.errors.load(Ordering::Relaxed), 3, "one error per rejected request");
        assert_eq!(vm.admitted.load(Ordering::Relaxed), 0);
        assert_eq!(vm.inflight.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn stream_worker_drains_on_shutdown_exactly_once() {
        let metrics = Arc::new(Metrics::new());
        let mock = Arc::new(MockStream::new(2, 3, Duration::ZERO));
        let w = StreamWorker::new("gen", mock, metrics.clone(), 64, None);
        let (reqs, rx) = stream_reqs(5);
        for r in reqs {
            w.submit(r);
        }
        // Shutdown races the first step: accepted work must still finish.
        w.shutdown();
        let responses: Vec<Response> = rx.try_iter().collect();
        assert_eq!(responses.len(), 5, "no stream lost or double-retired on shutdown");
        let mut ids: Vec<u64> = responses.iter().map(|r| r.id).collect();
        ids.sort();
        assert_eq!(ids, vec![0, 1, 2, 3, 4], "each stream answered exactly once");
        for r in responses {
            assert_eq!(r.output.unwrap().at(0, 0), 2.0 * r.id as f32);
        }
        let vm = metrics.variant("gen");
        assert_eq!(vm.admitted.load(Ordering::Relaxed), 5);
        assert_eq!(vm.inflight.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn parallel_workers_make_progress() {
        let metrics = Arc::new(Metrics::new());
        let exec: Arc<dyn Executor> = Arc::new(|_v: &str, inputs: &[&Tensor]| {
            std::thread::sleep(Duration::from_millis(20));
            Ok(inputs.iter().map(|t| (*t).clone()).collect::<Vec<_>>())
        });
        let pool = WorkerPool::new(4, 16, exec, metrics);
        let t0 = Instant::now();
        let mut rxs = Vec::new();
        for _ in 0..8 {
            let (batch, r) = mk_batch("v", 1);
            pool.submit(batch);
            rxs.extend(r);
        }
        for rx in &rxs {
            rx.recv_timeout(Duration::from_secs(5)).unwrap();
        }
        let elapsed = t0.elapsed();
        pool.shutdown();
        // 8 × 20 ms serial = 160 ms; 4 workers should finish well under.
        assert!(elapsed < Duration::from_millis(120), "no parallelism: {elapsed:?}");
    }
}
