//! Worker pool: N std threads draining a bounded batch queue and running
//! an [`Executor`]. Bounded queues give natural backpressure: the router
//! blocks (or sheds) when workers fall behind.
//!
//! Sizing comes from the same [`crate::parallel`] policy the tensor/quant
//! kernels use (`STAMP_THREADS`), via [`WorkerPool::default_workers`], and
//! worker threads are marked kernel-serial
//! ([`crate::parallel::set_kernel_serial`]): kernels invoked from a worker
//! run on that worker's thread alone, so batch-level and kernel-level
//! parallelism never multiply into oversubscription.

use super::{Batch, Metrics, Response};
use crate::tensor::Tensor;
use std::sync::atomic::Ordering;
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

/// What a worker runs on a batch of inputs (all same variant + shape).
pub trait Executor: Send + Sync + 'static {
    /// Process each input; one output per input. An `Err` fails the whole
    /// batch (each request receives the error). The executor sees the
    /// *whole* batch, so it can fuse it (the native executor admits a
    /// batch of generate requests into one step-synchronized
    /// [`crate::decode::DecodeEngine`] run) rather than loop per request.
    fn execute(&self, variant: &str, inputs: &[&Tensor]) -> Result<Vec<Tensor>, String>;
}

/// Blanket impl so closures can be executors in tests/examples.
impl<F> Executor for F
where
    F: Fn(&str, &[&Tensor]) -> Result<Vec<Tensor>, String> + Send + Sync + 'static,
{
    fn execute(&self, variant: &str, inputs: &[&Tensor]) -> Result<Vec<Tensor>, String> {
        self(variant, inputs)
    }
}

pub struct WorkerPool {
    tx: SyncSender<Batch>,
    handles: Vec<JoinHandle<()>>,
}

impl WorkerPool {
    /// Worker count used when a config doesn't pin one: the crate-wide
    /// thread policy ([`crate::parallel::num_threads`], i.e.
    /// `STAMP_THREADS` when set), capped at 8. Workers run kernels
    /// serially (see [`crate::parallel::set_kernel_serial`]), so N workers
    /// use ≈ N cores; the cap just bounds idle threads on very wide hosts.
    pub fn default_workers() -> usize {
        crate::parallel::num_threads().clamp(1, 8)
    }

    pub fn new(
        workers: usize,
        queue_depth: usize,
        executor: Arc<dyn Executor>,
        metrics: Arc<Metrics>,
    ) -> Self {
        assert!(workers >= 1);
        let (tx, rx) = sync_channel::<Batch>(queue_depth);
        let rx = Arc::new(Mutex::new(rx));
        let mut handles = Vec::new();
        for wid in 0..workers {
            let rx = rx.clone();
            let executor = executor.clone();
            let metrics = metrics.clone();
            handles.push(
                std::thread::Builder::new()
                    .name(format!("stamp-worker-{wid}"))
                    .spawn(move || worker_loop(rx, executor, metrics))
                    .expect("spawn worker"),
            );
        }
        WorkerPool { tx, handles }
    }

    /// Submit a batch; blocks when the queue is full (backpressure).
    pub fn submit(&self, batch: Batch) {
        self.tx.send(batch).expect("worker pool shut down");
    }

    /// Clone the ingest sender (used by the server's router thread, which
    /// outlives this borrow).
    pub fn clone_sender(&self) -> SyncSender<Batch> {
        self.tx.clone()
    }

    /// Non-blocking submit; returns the batch back on a full queue so the
    /// caller can shed or retry.
    pub fn try_submit(&self, batch: Batch) -> Result<(), Batch> {
        match self.tx.try_send(batch) {
            Ok(()) => Ok(()),
            Err(TrySendError::Full(b)) => Err(b),
            Err(TrySendError::Disconnected(_)) => panic!("worker pool shut down"),
        }
    }

    /// Drop the sender and join the workers (drains remaining batches).
    pub fn shutdown(self) {
        drop(self.tx);
        for h in self.handles {
            h.join().expect("worker panicked");
        }
    }
}

fn worker_loop(rx: Arc<Mutex<Receiver<Batch>>>, executor: Arc<dyn Executor>, metrics: Arc<Metrics>) {
    // Workers own the cores at batch granularity; kernels they call run
    // serially so inter-op × intra-op parallelism can't oversubscribe.
    crate::parallel::set_kernel_serial(true);
    loop {
        // Hold the lock only while receiving so workers pull concurrently.
        let batch = match rx.lock().unwrap().recv() {
            Ok(b) => b,
            Err(_) => return, // all senders dropped
        };
        let vm = metrics.variant(&batch.variant);
        vm.queue_depth.fetch_add(1, Ordering::Relaxed);
        let t0 = Instant::now();
        let inputs: Vec<&Tensor> = batch.requests.iter().map(|r| &r.input).collect();
        let result = executor.execute(&batch.variant, &inputs);
        let service_us = t0.elapsed().as_micros() as u64;
        let batch_size = batch.requests.len();
        let queued_us = batch
            .requests
            .iter()
            .map(|r| batch.formed_at.duration_since(r.submitted).as_micros() as u64)
            .sum::<u64>()
            / batch_size.max(1) as u64;
        vm.record_batch(batch_size, queued_us, service_us);

        match result {
            Ok(outputs) => {
                assert_eq!(outputs.len(), batch_size, "executor output arity");
                for (req, out) in batch.requests.into_iter().zip(outputs) {
                    let _ = req.respond.send(Response {
                        id: req.id,
                        variant: batch.variant.clone(),
                        output: Ok(out),
                        queued_us,
                        service_us,
                        batch_size,
                    });
                }
            }
            Err(msg) => {
                vm.errors.fetch_add(batch_size as u64, Ordering::Relaxed);
                for req in batch.requests {
                    let _ = req.respond.send(Response {
                        id: req.id,
                        variant: batch.variant.clone(),
                        output: Err(msg.clone()),
                        queued_us,
                        service_us,
                        batch_size,
                    });
                }
            }
        }
        vm.queue_depth.fetch_sub(1, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Request;
    use std::sync::mpsc;
    use std::time::Duration;

    fn mk_batch(variant: &str, n: usize) -> (Batch, Vec<mpsc::Receiver<Response>>) {
        let now = Instant::now();
        let mut reqs = Vec::new();
        let mut rxs = Vec::new();
        for i in 0..n {
            let (tx, rx) = mpsc::channel();
            reqs.push(Request {
                id: i as u64,
                variant: variant.into(),
                input: Tensor::full(&[2, 2], i as f32),
                submitted: now,
                respond: tx,
            });
            rxs.push(rx);
        }
        (Batch { variant: variant.into(), requests: reqs, formed_at: now }, rxs)
    }

    #[test]
    fn executes_and_responds() {
        let metrics = Arc::new(Metrics::new());
        let exec: Arc<dyn Executor> = Arc::new(|_v: &str, inputs: &[&Tensor]| {
            Ok(inputs.iter().map(|t| t.scale(2.0)).collect::<Vec<_>>())
        });
        let pool = WorkerPool::new(2, 8, exec, metrics.clone());
        let (batch, rxs) = mk_batch("v", 4);
        pool.submit(batch);
        for (i, rx) in rxs.iter().enumerate() {
            let resp = rx.recv_timeout(Duration::from_secs(5)).unwrap();
            assert_eq!(resp.id, i as u64);
            assert_eq!(resp.batch_size, 4);
            let out = resp.output.unwrap();
            assert_eq!(out.at(0, 0), 2.0 * i as f32);
        }
        pool.shutdown();
        assert_eq!(metrics.variant("v").requests.load(Ordering::Relaxed), 4);
    }

    #[test]
    fn errors_propagate_to_every_request() {
        let metrics = Arc::new(Metrics::new());
        let exec: Arc<dyn Executor> =
            Arc::new(|_v: &str, _i: &[&Tensor]| -> Result<Vec<Tensor>, String> { Err("boom".into()) });
        let pool = WorkerPool::new(1, 4, exec, metrics.clone());
        let (batch, rxs) = mk_batch("v", 3);
        pool.submit(batch);
        for rx in &rxs {
            let resp = rx.recv_timeout(Duration::from_secs(5)).unwrap();
            assert_eq!(resp.output.unwrap_err(), "boom");
        }
        pool.shutdown();
        assert_eq!(metrics.variant("v").errors.load(Ordering::Relaxed), 3);
    }

    #[test]
    fn try_submit_sheds_on_full_queue() {
        let metrics = Arc::new(Metrics::new());
        // Slow executor + queue depth 1 forces Full.
        let exec: Arc<dyn Executor> = Arc::new(|_v: &str, inputs: &[&Tensor]| {
            std::thread::sleep(Duration::from_millis(50));
            Ok(inputs.iter().map(|t| (*t).clone()).collect::<Vec<_>>())
        });
        let pool = WorkerPool::new(1, 1, exec, metrics);
        let mut shed = 0;
        let mut rx_keep = Vec::new();
        for _ in 0..6 {
            let (batch, rxs) = mk_batch("v", 1);
            match pool.try_submit(batch) {
                Ok(()) => rx_keep.extend(rxs),
                Err(_returned) => shed += 1,
            }
        }
        assert!(shed > 0, "bounded queue must shed under load");
        for rx in &rx_keep {
            rx.recv_timeout(Duration::from_secs(5)).unwrap().output.unwrap();
        }
        pool.shutdown();
    }

    #[test]
    fn parallel_workers_make_progress() {
        let metrics = Arc::new(Metrics::new());
        let exec: Arc<dyn Executor> = Arc::new(|_v: &str, inputs: &[&Tensor]| {
            std::thread::sleep(Duration::from_millis(20));
            Ok(inputs.iter().map(|t| (*t).clone()).collect::<Vec<_>>())
        });
        let pool = WorkerPool::new(4, 16, exec, metrics);
        let t0 = Instant::now();
        let mut rxs = Vec::new();
        for _ in 0..8 {
            let (batch, r) = mk_batch("v", 1);
            pool.submit(batch);
            rxs.extend(r);
        }
        for rx in &rxs {
            rx.recv_timeout(Duration::from_secs(5)).unwrap();
        }
        let elapsed = t0.elapsed();
        pool.shutdown();
        // 8 × 20 ms serial = 160 ms; 4 workers should finish well under.
        assert!(elapsed < Duration::from_millis(120), "no parallelism: {elapsed:?}");
    }
}
