//! Server: glues ingest → router → worker pool behind one thread, giving
//! clients a simple blocking/async-ish `submit` + response channel API.

use super::{Executor, Metrics, Request, Response, Router, WorkerPool};
use crate::config::ServeSpec;
use crate::tensor::Tensor;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

enum Ingest {
    Req(Request),
    Shutdown,
}

/// Handle returned to clients for submitting work.
pub struct ServerHandle {
    tx: Sender<Ingest>,
    next_id: AtomicU64,
    pub metrics: Arc<Metrics>,
}

impl ServerHandle {
    /// Submit one input; returns (request id, response receiver).
    pub fn submit(&self, variant: &str, input: Tensor) -> (u64, Receiver<Response>) {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (rtx, rrx) = channel();
        let req = Request {
            id,
            variant: variant.to_string(),
            input,
            submitted: Instant::now(),
            respond: rtx,
        };
        self.tx.send(Ingest::Req(req)).expect("server stopped");
        (id, rrx)
    }

    /// Submit and block for the response.
    pub fn call(&self, variant: &str, input: Tensor, timeout: Duration) -> Result<Response, String> {
        let (_, rx) = self.submit(variant, input);
        rx.recv_timeout(timeout).map_err(|e| format!("response timeout: {e}"))
    }
}

/// The running server.
pub struct Server {
    handle: Arc<ServerHandle>,
    router_thread: std::thread::JoinHandle<()>,
    pool: Option<WorkerPool>,
    shutdown_tx: Sender<Ingest>,
}

impl Server {
    pub fn start(spec: &ServeSpec, variants: &[&str], executor: Arc<dyn Executor>) -> Server {
        let metrics = Arc::new(Metrics::new());
        let pool = WorkerPool::new(spec.workers, spec.queue_depth, executor, metrics.clone());
        let (tx, rx) = channel::<Ingest>();
        let handle =
            Arc::new(ServerHandle { tx: tx.clone(), next_id: AtomicU64::new(1), metrics });

        let mut router =
            Router::new(variants, spec.max_batch, Duration::from_micros(spec.max_wait_us));
        let pool_tx = pool.clone_sender();
        let router_thread = std::thread::Builder::new()
            .name("stamp-router".into())
            .spawn(move || {
                router_loop(rx, &mut router, move |batch| {
                    let _ = pool_tx.send(batch);
                })
            })
            .expect("spawn router");

        Server { handle, router_thread, pool: Some(pool), shutdown_tx: tx }
    }

    pub fn handle(&self) -> Arc<ServerHandle> {
        self.handle.clone()
    }

    /// Graceful shutdown: flush batchers, drain workers.
    pub fn shutdown(mut self) {
        let _ = self.shutdown_tx.send(Ingest::Shutdown);
        self.router_thread.join().expect("router panicked");
        if let Some(pool) = self.pool.take() {
            pool.shutdown();
        }
    }
}

fn router_loop(
    rx: Receiver<Ingest>,
    router: &mut Router,
    dispatch: impl Fn(super::Batch),
) {
    loop {
        // Sleep until the next flush deadline or a new request.
        let timeout = router
            .next_deadline()
            .map(|d| d.saturating_duration_since(Instant::now()))
            .unwrap_or(Duration::from_millis(50));
        match rx.recv_timeout(timeout) {
            Ok(Ingest::Req(req)) => {
                let now = Instant::now();
                match router.route(req, now) {
                    Ok(Some(batch)) => dispatch(batch),
                    Ok(None) => {}
                    Err(rejected) => {
                        let _ = rejected.respond.send(Response {
                            id: rejected.id,
                            variant: rejected.variant.clone(),
                            output: Err(format!("unknown variant `{}`", rejected.variant)),
                            queued_us: 0,
                            service_us: 0,
                            batch_size: 0,
                        });
                    }
                }
            }
            Ok(Ingest::Shutdown) => {
                for batch in router.flush_all(Instant::now()) {
                    dispatch(batch);
                }
                return;
            }
            Err(RecvTimeoutError::Timeout) => {
                for batch in router.poll_deadlines(Instant::now()) {
                    dispatch(batch);
                }
            }
            Err(RecvTimeoutError::Disconnected) => {
                for batch in router.flush_all(Instant::now()) {
                    dispatch(batch);
                }
                return;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> ServeSpec {
        ServeSpec { workers: 2, max_batch: 4, max_wait_us: 1_000, queue_depth: 64 }
    }

    fn doubling_executor() -> Arc<dyn Executor> {
        Arc::new(|_v: &str, inputs: &[&Tensor]| {
            Ok(inputs.iter().map(|t| t.scale(2.0)).collect::<Vec<_>>())
        })
    }

    #[test]
    fn end_to_end_single_call() {
        let server = Server::start(&spec(), &["fp"], doubling_executor());
        let h = server.handle();
        let resp = h.call("fp", Tensor::full(&[2, 2], 3.0), Duration::from_secs(5)).unwrap();
        assert_eq!(resp.output.unwrap().at(0, 0), 6.0);
        server.shutdown();
    }

    #[test]
    fn batches_form_under_load() {
        let server = Server::start(&spec(), &["fp"], doubling_executor());
        let h = server.handle();
        let rxs: Vec<_> = (0..16).map(|i| h.submit("fp", Tensor::full(&[1, 1], i as f32)).1).collect();
        for rx in &rxs {
            rx.recv_timeout(Duration::from_secs(5)).unwrap().output.unwrap();
        }
        let vm = h.metrics.variant("fp");
        let batches = vm.batches.load(Ordering::Relaxed);
        assert!(batches < 16, "batching must coalesce: {batches} batches for 16 reqs");
        server.shutdown();
    }

    #[test]
    fn unknown_variant_gets_error_response() {
        let server = Server::start(&spec(), &["fp"], doubling_executor());
        let h = server.handle();
        let resp = h.call("mystery", Tensor::zeros(&[1, 1]), Duration::from_secs(5)).unwrap();
        assert!(resp.output.unwrap_err().contains("unknown variant"));
        server.shutdown();
    }

    #[test]
    fn time_flush_delivers_partial_batches() {
        // One lone request must still complete (deadline flush).
        let server = Server::start(&spec(), &["fp"], doubling_executor());
        let h = server.handle();
        let t0 = Instant::now();
        let resp = h.call("fp", Tensor::full(&[1, 1], 1.0), Duration::from_secs(5)).unwrap();
        assert!(resp.output.is_ok());
        assert!(t0.elapsed() < Duration::from_secs(1));
        server.shutdown();
    }

    #[test]
    fn shutdown_flushes_pending() {
        let server = Server::start(&spec(), &["fp"], doubling_executor());
        let h = server.handle();
        let (_, rx) = h.submit("fp", Tensor::full(&[1, 1], 9.0));
        server.shutdown();
        // The response must have been produced during shutdown drain.
        let resp = rx.recv_timeout(Duration::from_secs(1)).unwrap();
        assert_eq!(resp.output.unwrap().at(0, 0), 18.0);
    }
}
