//! Server: glues ingest → router → worker pool behind one thread, giving
//! clients a simple blocking/async-ish `submit` + response channel API.

use super::{
    Executor, Metrics, Request, Response, Router, StreamExecutor, StreamIngest, StreamWorker,
    WorkerPool,
};
use crate::config::ServeSpec;
use crate::tensor::Tensor;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

enum Ingest {
    Req(Request),
    Shutdown,
}

/// Handle returned to clients for submitting work.
pub struct ServerHandle {
    tx: Sender<Ingest>,
    next_id: AtomicU64,
    pub metrics: Arc<Metrics>,
}

impl ServerHandle {
    /// Submit one input; returns (request id, response receiver).
    pub fn submit(&self, variant: &str, input: Tensor) -> (u64, Receiver<Response>) {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (rtx, rrx) = channel();
        let req = Request {
            id,
            variant: variant.to_string(),
            input,
            submitted: Instant::now(),
            respond: rtx,
        };
        self.tx.send(Ingest::Req(req)).expect("server stopped");
        (id, rrx)
    }

    /// Submit and block for the response.
    pub fn call(&self, variant: &str, input: Tensor, timeout: Duration) -> Result<Response, String> {
        let (_, rx) = self.submit(variant, input);
        rx.recv_timeout(timeout).map_err(|e| format!("response timeout: {e}"))
    }
}

/// The running server.
pub struct Server {
    handle: Arc<ServerHandle>,
    router_thread: std::thread::JoinHandle<()>,
    pool: Option<WorkerPool>,
    stream_workers: Vec<StreamWorker>,
    /// Retained so [`Server::drain_trace`] can reach the decode engines'
    /// trace rings while the server runs (`None` for batch-only servers).
    stream_executor: Option<Arc<dyn StreamExecutor>>,
    shutdown_tx: Sender<Ingest>,
}

impl Server {
    pub fn start(spec: &ServeSpec, variants: &[&str], executor: Arc<dyn Executor>) -> Server {
        Server::start_streaming(spec, variants, &[], executor, None, None)
    }

    /// Start with a continuous-batching path (PR 6): requests for a
    /// variant in `stream_variants` bypass the batcher and go to a
    /// dedicated [`StreamWorker`] that admits them into the running
    /// decode engine behind `stream_executor` as slots free up. All other
    /// variants take the classic batch → worker-pool path. The admission
    /// queue bound is `spec.queue_depth`; `admit_deadline` (from
    /// `[generate] admit_deadline_ms`) sheds requests that can't be
    /// seated in time.
    pub fn start_streaming(
        spec: &ServeSpec,
        variants: &[&str],
        stream_variants: &[&str],
        executor: Arc<dyn Executor>,
        stream_executor: Option<Arc<dyn StreamExecutor>>,
        admit_deadline: Option<Duration>,
    ) -> Server {
        assert!(
            stream_variants.is_empty() || stream_executor.is_some(),
            "stream variants require a StreamExecutor"
        );
        let metrics = Arc::new(Metrics::new());
        let pool = WorkerPool::new(spec.workers, spec.queue_depth, executor, metrics.clone());
        let (tx, rx) = channel::<Ingest>();
        let handle = Arc::new(ServerHandle {
            tx: tx.clone(),
            next_id: AtomicU64::new(1),
            metrics: metrics.clone(),
        });

        let mut stream_workers = Vec::new();
        let mut stream_tx: HashMap<String, Sender<StreamIngest>> = HashMap::new();
        for v in stream_variants {
            let sx = stream_executor.clone().expect("checked above");
            let w = StreamWorker::new(v, sx, metrics.clone(), spec.queue_depth, admit_deadline);
            stream_tx.insert(v.to_string(), w.clone_sender());
            stream_workers.push(w);
        }

        let mut router =
            Router::new(variants, spec.max_batch, Duration::from_micros(spec.max_wait_us));
        let pool_tx = pool.clone_sender();
        let router_thread = std::thread::Builder::new()
            .name("stamp-router".into())
            .spawn(move || {
                router_loop(rx, &mut router, stream_tx, move |batch| {
                    let _ = pool_tx.send(batch);
                })
            })
            .expect("spawn router");

        Server {
            handle,
            router_thread,
            pool: Some(pool),
            stream_workers,
            stream_executor,
            shutdown_tx: tx,
        }
    }

    pub fn handle(&self) -> Arc<ServerHandle> {
        self.handle.clone()
    }

    /// Drain a streaming variant's trace ring to JSONL (empty when the
    /// server has no stream executor, the variant doesn't stream, or
    /// tracing is disabled). Safe while serving: the ring's producer side
    /// is lock-free for the engine and each drain returns a disjoint
    /// window of the timeline.
    pub fn drain_trace(&self, variant: &str) -> String {
        self.stream_executor.as_ref().map_or(String::new(), |sx| sx.drain_trace(variant))
    }

    /// Graceful shutdown: flush batchers, drain stream workers (every
    /// queued/in-flight stream finishes), drain pool workers.
    pub fn shutdown(mut self) {
        let _ = self.shutdown_tx.send(Ingest::Shutdown);
        self.router_thread.join().expect("router panicked");
        for w in self.stream_workers.drain(..) {
            w.shutdown();
        }
        if let Some(pool) = self.pool.take() {
            pool.shutdown();
        }
    }
}

fn router_loop(
    rx: Receiver<Ingest>,
    router: &mut Router,
    stream_tx: HashMap<String, Sender<StreamIngest>>,
    dispatch: impl Fn(super::Batch),
) {
    loop {
        // Sleep until the next flush deadline or a new request.
        let timeout = router
            .next_deadline()
            .map(|d| d.saturating_duration_since(Instant::now()))
            .unwrap_or(Duration::from_millis(50));
        match rx.recv_timeout(timeout) {
            Ok(Ingest::Req(req)) => {
                // Streaming variants bypass the batcher entirely.
                if let Some(stx) = stream_tx.get(&req.variant) {
                    let _ = stx.send(StreamIngest::Req(req));
                    continue;
                }
                let now = Instant::now();
                match router.route(req, now) {
                    Ok(Some(batch)) => dispatch(batch),
                    Ok(None) => {}
                    Err(rejected) => {
                        let _ = rejected.respond.send(Response {
                            id: rejected.id,
                            variant: rejected.variant.clone(),
                            output: Err(format!("unknown variant `{}`", rejected.variant)),
                            queued_us: 0,
                            service_us: 0,
                            batch_size: 0,
                        });
                    }
                }
            }
            Ok(Ingest::Shutdown) => {
                for batch in router.flush_all(Instant::now()) {
                    dispatch(batch);
                }
                return;
            }
            Err(RecvTimeoutError::Timeout) => {
                for batch in router.poll_deadlines(Instant::now()) {
                    dispatch(batch);
                }
            }
            Err(RecvTimeoutError::Disconnected) => {
                for batch in router.flush_all(Instant::now()) {
                    dispatch(batch);
                }
                return;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> ServeSpec {
        ServeSpec { workers: 2, max_batch: 4, max_wait_us: 1_000, queue_depth: 64 }
    }

    fn doubling_executor() -> Arc<dyn Executor> {
        Arc::new(|_v: &str, inputs: &[&Tensor]| {
            Ok(inputs.iter().map(|t| t.scale(2.0)).collect::<Vec<_>>())
        })
    }

    #[test]
    fn end_to_end_single_call() {
        let server = Server::start(&spec(), &["fp"], doubling_executor());
        let h = server.handle();
        let resp = h.call("fp", Tensor::full(&[2, 2], 3.0), Duration::from_secs(5)).unwrap();
        assert_eq!(resp.output.unwrap().at(0, 0), 6.0);
        server.shutdown();
    }

    #[test]
    fn batches_form_under_load() {
        let server = Server::start(&spec(), &["fp"], doubling_executor());
        let h = server.handle();
        let rxs: Vec<_> = (0..16).map(|i| h.submit("fp", Tensor::full(&[1, 1], i as f32)).1).collect();
        for rx in &rxs {
            rx.recv_timeout(Duration::from_secs(5)).unwrap().output.unwrap();
        }
        let vm = h.metrics.variant("fp");
        let batches = vm.batches.load(Ordering::Relaxed);
        assert!(batches < 16, "batching must coalesce: {batches} batches for 16 reqs");
        server.shutdown();
    }

    #[test]
    fn unknown_variant_gets_error_response() {
        let server = Server::start(&spec(), &["fp"], doubling_executor());
        let h = server.handle();
        let resp = h.call("mystery", Tensor::zeros(&[1, 1]), Duration::from_secs(5)).unwrap();
        assert!(resp.output.unwrap_err().contains("unknown variant"));
        server.shutdown();
    }

    #[test]
    fn time_flush_delivers_partial_batches() {
        // One lone request must still complete (deadline flush).
        let server = Server::start(&spec(), &["fp"], doubling_executor());
        let h = server.handle();
        let t0 = Instant::now();
        let resp = h.call("fp", Tensor::full(&[1, 1], 1.0), Duration::from_secs(5)).unwrap();
        assert!(resp.output.is_ok());
        assert!(t0.elapsed() < Duration::from_secs(1));
        server.shutdown();
    }

    /// Two-slot streaming engine: ×3, finishes every in-flight stream on
    /// each step.
    #[derive(Default)]
    struct TripleStream {
        state: std::sync::Mutex<(u64, Vec<(u64, Tensor)>)>,
    }

    impl StreamExecutor for TripleStream {
        fn free_slots(&self, _v: &str) -> usize {
            2 - self.state.lock().unwrap().1.len()
        }

        fn admit(&self, _v: &str, input: &Tensor) -> Result<u64, String> {
            let mut st = self.state.lock().unwrap();
            if st.1.len() >= 2 {
                return Err("no free slot".into());
            }
            let id = st.0;
            st.0 += 1;
            st.1.push((id, input.clone()));
            Ok(id)
        }

        fn step(&self, _v: &str) -> Vec<(u64, Result<Tensor, String>)> {
            let mut st = self.state.lock().unwrap();
            st.1.drain(..).map(|(id, t)| (id, Ok(t.scale(3.0)))).collect()
        }

        fn has_work(&self, _v: &str) -> bool {
            !self.state.lock().unwrap().1.is_empty()
        }
    }

    #[test]
    fn streaming_variant_serves_alongside_batch_variants() {
        let server = Server::start_streaming(
            &spec(),
            &["fp"],
            &["gen"],
            doubling_executor(),
            Some(Arc::new(TripleStream::default())),
            None,
        );
        let h = server.handle();
        let rxs: Vec<_> =
            (0..6).map(|i| h.submit("gen", Tensor::full(&[1, 1], i as f32)).1).collect();
        // Batch path still works while streams are in flight.
        let fp = h.call("fp", Tensor::full(&[1, 1], 2.0), Duration::from_secs(5)).unwrap();
        assert_eq!(fp.output.unwrap().at(0, 0), 4.0);
        for (i, rx) in rxs.iter().enumerate() {
            let resp = rx.recv_timeout(Duration::from_secs(5)).unwrap();
            assert_eq!(resp.output.unwrap().at(0, 0), 3.0 * i as f32);
            assert_eq!(resp.batch_size, 1, "streams retire independently");
        }
        assert_eq!(h.metrics.variant("gen").admitted.load(Ordering::Relaxed), 6);
        server.shutdown();
    }

    #[test]
    fn streaming_shutdown_drains_pending_streams() {
        let server = Server::start_streaming(
            &spec(),
            &["fp"],
            &["gen"],
            doubling_executor(),
            Some(Arc::new(TripleStream::default())),
            None,
        );
        let h = server.handle();
        let rxs: Vec<_> =
            (0..4).map(|i| h.submit("gen", Tensor::full(&[1, 1], i as f32)).1).collect();
        server.shutdown();
        for (i, rx) in rxs.iter().enumerate() {
            let resp = rx.recv_timeout(Duration::from_secs(1)).unwrap();
            assert_eq!(resp.output.unwrap().at(0, 0), 3.0 * i as f32);
        }
    }

    #[test]
    fn drain_trace_is_empty_without_a_traced_stream_executor() {
        // Batch-only servers have no stream executor at all…
        let server = Server::start(&spec(), &["fp"], doubling_executor());
        assert_eq!(server.drain_trace("fp"), "");
        server.shutdown();
        // …and a stream executor that doesn't override `drain_trace`
        // (tracing off) reports an empty window, not an error.
        let server = Server::start_streaming(
            &spec(),
            &["fp"],
            &["gen"],
            doubling_executor(),
            Some(Arc::new(TripleStream::default())),
            None,
        );
        assert_eq!(server.drain_trace("gen"), "");
        server.shutdown();
    }

    #[test]
    fn shutdown_flushes_pending() {
        let server = Server::start(&spec(), &["fp"], doubling_executor());
        let h = server.handle();
        let (_, rx) = h.submit("fp", Tensor::full(&[1, 1], 9.0));
        server.shutdown();
        // The response must have been produced during shutdown drain.
        let resp = rx.recv_timeout(Duration::from_secs(1)).unwrap();
        assert_eq!(resp.output.unwrap().at(0, 0), 18.0);
    }
}
