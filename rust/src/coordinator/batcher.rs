//! Dynamic batching: group compatible requests (same variant, same input
//! shape) up to `max_batch`, flushing early once the oldest request has
//! waited `max_wait`. Pure logic — no threads — so invariants are directly
//! property-testable.
//!
//! A formed batch is the executor's unit of fusion: for generate variants
//! the whole batch is admitted into one [`crate::decode::DecodeEngine`]
//! run (N concurrent streams, one GEMM per linear per step), so
//! `max_batch` is also the natural upper bound for the engine's
//! `decode_batch` knob.

use super::Request;
use std::collections::VecDeque;
use std::time::{Duration, Instant};

/// A formed batch, ready for a worker.
pub struct Batch {
    pub variant: String,
    pub requests: Vec<Request>,
    pub formed_at: Instant,
}

impl Batch {
    pub fn len(&self) -> usize {
        self.requests.len()
    }

    pub fn is_empty(&self) -> bool {
        self.requests.is_empty()
    }
}

/// Batcher for ONE variant.
pub struct DynamicBatcher {
    variant: String,
    max_batch: usize,
    max_wait: Duration,
    pending: VecDeque<Request>,
}

impl DynamicBatcher {
    pub fn new(variant: &str, max_batch: usize, max_wait: Duration) -> Self {
        assert!(max_batch >= 1);
        DynamicBatcher { variant: variant.to_string(), max_batch, max_wait, pending: VecDeque::new() }
    }

    pub fn pending(&self) -> usize {
        self.pending.len()
    }

    /// Add a request; returns a full batch if `max_batch` was reached.
    pub fn push(&mut self, req: Request, now: Instant) -> Option<Batch> {
        debug_assert_eq!(req.variant, self.variant);
        self.pending.push_back(req);
        if self.pending.len() >= self.max_batch {
            return self.flush(now);
        }
        None
    }

    /// Time-based flush: emit the partial batch if the oldest entry has
    /// waited past `max_wait`.
    pub fn poll(&mut self, now: Instant) -> Option<Batch> {
        let oldest = self.pending.front()?;
        if now.duration_since(oldest.submitted) >= self.max_wait {
            self.flush(now)
        } else {
            None
        }
    }

    /// Unconditional flush (shutdown path).
    pub fn flush(&mut self, now: Instant) -> Option<Batch> {
        if self.pending.is_empty() {
            return None;
        }
        let take = self.pending.len().min(self.max_batch);
        let requests: Vec<Request> = self.pending.drain(..take).collect();
        Some(Batch { variant: self.variant.clone(), requests, formed_at: now })
    }

    /// Deadline for the next time-based flush (router sleep hint).
    pub fn next_deadline(&self) -> Option<Instant> {
        self.pending.front().map(|r| r.submitted + self.max_wait)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;
    use std::sync::mpsc;

    fn req(id: u64, variant: &str, at: Instant) -> Request {
        let (tx, _rx) = mpsc::channel();
        Request { id, variant: variant.into(), input: Tensor::zeros(&[1, 1]), submitted: at, respond: tx }
    }

    #[test]
    fn flushes_at_max_batch() {
        let now = Instant::now();
        let mut b = DynamicBatcher::new("v", 3, Duration::from_millis(100));
        assert!(b.push(req(1, "v", now), now).is_none());
        assert!(b.push(req(2, "v", now), now).is_none());
        let batch = b.push(req(3, "v", now), now).expect("full batch");
        assert_eq!(batch.len(), 3);
        assert_eq!(b.pending(), 0);
        // FIFO order preserved.
        let ids: Vec<u64> = batch.requests.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![1, 2, 3]);
    }

    #[test]
    fn time_based_flush() {
        let t0 = Instant::now();
        let mut b = DynamicBatcher::new("v", 8, Duration::from_millis(10));
        b.push(req(1, "v", t0), t0);
        assert!(b.poll(t0).is_none(), "too early");
        let later = t0 + Duration::from_millis(11);
        let batch = b.poll(later).expect("deadline passed");
        assert_eq!(batch.len(), 1);
    }

    #[test]
    fn deadline_hint() {
        let t0 = Instant::now();
        let mut b = DynamicBatcher::new("v", 8, Duration::from_millis(10));
        assert!(b.next_deadline().is_none());
        b.push(req(1, "v", t0), t0);
        assert_eq!(b.next_deadline(), Some(t0 + Duration::from_millis(10)));
    }

    #[test]
    fn property_batch_invariants() {
        // Invariants under random push/poll interleavings:
        //   (1) every batch ≤ max_batch;
        //   (2) FIFO within a variant (ids strictly increasing);
        //   (3) nothing lost: Σ batch sizes + pending == pushed.
        crate::testkit::check(
            "batcher-invariants",
            50,
            0xBA7C4,
            |g| {
                let max_batch = g.usize_in(1, 8);
                let ops: Vec<u8> = (0..g.usize_in(1, 60)).map(|_| (g.usize_in(0, 3)) as u8).collect();
                (max_batch, ops)
            },
            |(max_batch, ops)| {
                let t0 = Instant::now();
                let mut b = DynamicBatcher::new("v", *max_batch, Duration::from_millis(5));
                let mut next_id = 0u64;
                let mut emitted = 0usize;
                let mut last_emitted_id: Option<u64> = None;
                let mut clock = t0;
                for op in ops {
                    clock += Duration::from_millis(2);
                    let out = match op {
                        0 | 1 => {
                            next_id += 1;
                            b.push(req(next_id, "v", clock), clock)
                        }
                        2 => b.poll(clock),
                        _ => b.flush(clock),
                    };
                    if let Some(batch) = out {
                        if batch.len() > *max_batch {
                            return Err(format!("batch {} > max {}", batch.len(), max_batch));
                        }
                        for r in &batch.requests {
                            if let Some(prev) = last_emitted_id {
                                if r.id <= prev {
                                    return Err(format!("FIFO violated: {} after {}", r.id, prev));
                                }
                            }
                            last_emitted_id = Some(r.id);
                        }
                        emitted += batch.len();
                    }
                }
                if emitted + b.pending() != next_id as usize {
                    return Err(format!(
                        "lost requests: emitted {} + pending {} != pushed {}",
                        emitted,
                        b.pending(),
                        next_id
                    ));
                }
                Ok(())
            },
        );
    }
}
