//! Dynamic batching: group compatible requests (same variant, same input
//! shape) up to `max_batch`, flushing early once the oldest request has
//! waited `max_wait`. Pure logic — no threads — so invariants are directly
//! property-testable.
//!
//! A formed batch is the executor's unit of fusion: for generate variants
//! the whole batch is admitted into one [`crate::decode::DecodeEngine`]
//! run (N concurrent streams, one GEMM per linear per step), so
//! `max_batch` is also the natural upper bound for the engine's
//! `decode_batch` knob.

use super::Request;
use std::collections::VecDeque;
use std::time::{Duration, Instant};

/// A formed batch, ready for a worker.
pub struct Batch {
    pub variant: String,
    pub requests: Vec<Request>,
    pub formed_at: Instant,
}

impl Batch {
    pub fn len(&self) -> usize {
        self.requests.len()
    }

    pub fn is_empty(&self) -> bool {
        self.requests.is_empty()
    }
}

/// Batcher for ONE variant.
pub struct DynamicBatcher {
    variant: String,
    max_batch: usize,
    max_wait: Duration,
    pending: VecDeque<Request>,
}

impl DynamicBatcher {
    pub fn new(variant: &str, max_batch: usize, max_wait: Duration) -> Self {
        assert!(max_batch >= 1);
        DynamicBatcher { variant: variant.to_string(), max_batch, max_wait, pending: VecDeque::new() }
    }

    pub fn pending(&self) -> usize {
        self.pending.len()
    }

    /// Add a request; returns a full batch if `max_batch` was reached —
    /// or a partial one if the *oldest* pending request's flush deadline
    /// has already passed. The deadline check makes a push count as a
    /// clock tick: the router only polls deadlines on ingest timeouts, so
    /// without it a request arriving exactly at (or after) the oldest
    /// entry's deadline would ride along silently and the batch would
    /// wait up to a full extra `max_wait` for the next quiet period
    /// (pinned by `push_at_deadline_flushes_immediately`).
    pub fn push(&mut self, req: Request, now: Instant) -> Option<Batch> {
        debug_assert_eq!(req.variant, self.variant);
        self.pending.push_back(req);
        if self.pending.len() >= self.max_batch {
            return self.flush(now);
        }
        self.poll(now)
    }

    /// Time-based flush: emit the partial batch if the oldest entry has
    /// waited past `max_wait`.
    pub fn poll(&mut self, now: Instant) -> Option<Batch> {
        let oldest = self.pending.front()?;
        if now.duration_since(oldest.submitted) >= self.max_wait {
            self.flush(now)
        } else {
            None
        }
    }

    /// Unconditional flush (shutdown path).
    pub fn flush(&mut self, now: Instant) -> Option<Batch> {
        if self.pending.is_empty() {
            return None;
        }
        let take = self.pending.len().min(self.max_batch);
        let requests: Vec<Request> = self.pending.drain(..take).collect();
        Some(Batch { variant: self.variant.clone(), requests, formed_at: now })
    }

    /// Deadline for the next time-based flush (router sleep hint).
    pub fn next_deadline(&self) -> Option<Instant> {
        self.pending.front().map(|r| r.submitted + self.max_wait)
    }
}

/// FIFO admission queue feeding a continuous-batching stream worker
/// (PR 6): requests wait here until the decode engine has a free slot,
/// in strict arrival order, bounded by `max_pending` (backpressure — a
/// push past the bound is rejected back to the caller to shed) and an
/// optional per-request admission deadline (a request that cannot be
/// seated in time is expired out rather than served arbitrarily late).
///
/// Pure logic — no threads, no engine handle — so fairness and bound
/// invariants are directly property-testable; the generic payload keeps
/// the tests free of coordinator plumbing.
pub struct AdmissionQueue<T> {
    max_pending: usize,
    admit_deadline: Option<Duration>,
    pending: VecDeque<(T, Instant)>,
}

/// Result of [`AdmissionQueue::pop_ready`]: requests to seat now and
/// requests whose admission deadline elapsed at (or before) the pop
/// instant, which the caller must shed and count. Each entry carries its
/// submission [`Instant`].
pub struct Popped<T> {
    /// Seatable requests, strictly FIFO, at most `free_slots` of them.
    pub ready: Vec<(T, Instant)>,
    /// Requests expired at the pop instant (deadline boundary inclusive).
    pub expired: Vec<(T, Instant)>,
}

impl<T> AdmissionQueue<T> {
    /// `admit_deadline = None` disables expiry (requests wait as long as
    /// it takes); `max_pending` is the backpressure bound (≥ 1).
    pub fn new(max_pending: usize, admit_deadline: Option<Duration>) -> Self {
        assert!(max_pending >= 1, "max_pending must be ≥ 1");
        AdmissionQueue { max_pending, admit_deadline, pending: VecDeque::new() }
    }

    pub fn len(&self) -> usize {
        self.pending.len()
    }

    pub fn is_empty(&self) -> bool {
        self.pending.is_empty()
    }

    /// Enqueue in arrival order; returns the item back when the queue is
    /// at its backpressure bound (the caller sheds it with an error).
    pub fn push(&mut self, item: T, now: Instant) -> Result<(), T> {
        if self.pending.len() >= self.max_pending {
            return Err(item);
        }
        self.pending.push_back((item, now));
        Ok(())
    }

    /// Dequeue up to `free_slots` seatable items, strictly FIFO — a
    /// younger request can never jump an older one, regardless of how
    /// slots free up (arrival-order fairness).
    ///
    /// Expiry is checked *at the pop instant*: a request whose admission
    /// deadline has elapsed — including one elapsing exactly at `now` —
    /// is returned in [`Popped::expired`] for the caller to shed, and
    /// does not consume a free slot. This mirrors the PR 6
    /// [`DynamicBatcher`] boundary fix: before it, `pop_ready` was
    /// deadline-blind, so a request expiring in the gap between the
    /// caller's `expire()` poll and the pop would be seated late instead
    /// of shed (pinned by
    /// `pop_ready_sheds_request_expiring_exactly_at_the_pop_instant`).
    pub fn pop_ready(&mut self, free_slots: usize, now: Instant) -> Popped<T> {
        let mut popped = Popped { ready: Vec::new(), expired: Vec::new() };
        while popped.ready.len() < free_slots {
            let Some((_, submitted)) = self.pending.front() else { break };
            if self.admit_deadline.is_some_and(|d| now.duration_since(*submitted) >= d) {
                popped.expired.push(self.pending.pop_front().expect("front exists"));
            } else {
                popped.ready.push(self.pending.pop_front().expect("front exists"));
            }
        }
        popped
    }

    /// Remove and return every entry whose admission deadline has passed
    /// (the caller sheds them). FIFO arrival means the front is always
    /// the earliest deadline, so expiry only ever pops from the front.
    pub fn expire(&mut self, now: Instant) -> Vec<(T, Instant)> {
        let Some(d) = self.admit_deadline else { return Vec::new() };
        let mut out = Vec::new();
        while let Some((_, submitted)) = self.pending.front() {
            if now.duration_since(*submitted) >= d {
                out.push(self.pending.pop_front().expect("front exists"));
            } else {
                break;
            }
        }
        out
    }

    /// Earliest pending expiry (stream-worker sleep hint); `None` without
    /// a deadline or pending work.
    pub fn next_deadline(&self) -> Option<Instant> {
        let d = self.admit_deadline?;
        self.pending.front().map(|(_, submitted)| *submitted + d)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;
    use std::sync::mpsc;

    fn req(id: u64, variant: &str, at: Instant) -> Request {
        let (tx, _rx) = mpsc::channel();
        Request { id, variant: variant.into(), input: Tensor::zeros(&[1, 1]), submitted: at, respond: tx }
    }

    #[test]
    fn flushes_at_max_batch() {
        let now = Instant::now();
        let mut b = DynamicBatcher::new("v", 3, Duration::from_millis(100));
        assert!(b.push(req(1, "v", now), now).is_none());
        assert!(b.push(req(2, "v", now), now).is_none());
        let batch = b.push(req(3, "v", now), now).expect("full batch");
        assert_eq!(batch.len(), 3);
        assert_eq!(b.pending(), 0);
        // FIFO order preserved.
        let ids: Vec<u64> = batch.requests.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![1, 2, 3]);
    }

    #[test]
    fn time_based_flush() {
        let t0 = Instant::now();
        let mut b = DynamicBatcher::new("v", 8, Duration::from_millis(10));
        b.push(req(1, "v", t0), t0);
        assert!(b.poll(t0).is_none(), "too early");
        let later = t0 + Duration::from_millis(11);
        let batch = b.poll(later).expect("deadline passed");
        assert_eq!(batch.len(), 1);
    }

    #[test]
    fn push_at_deadline_flushes_immediately() {
        // Regression (PR 6): the router polls deadlines only on ingest
        // *timeouts*, so under continuous arrivals a push landing exactly
        // at — or after — the oldest request's flush deadline used to
        // ride along silently and wait up to a full extra max_wait. A
        // push must count as a clock tick.
        let t0 = Instant::now();
        let max_wait = Duration::from_millis(5);
        let mut b = DynamicBatcher::new("v", 8, max_wait);
        assert!(b.push(req(1, "v", t0), t0).is_none());
        // Exactly at the oldest entry's deadline…
        let batch = b.push(req(2, "v", t0 + max_wait), t0 + max_wait).expect("deadline flush");
        assert_eq!(batch.len(), 2, "both the old and the arriving request flush together");
        assert_eq!(b.pending(), 0);
        // …and past it.
        let t1 = t0 + Duration::from_millis(100);
        assert!(b.push(req(3, "v", t1), t1).is_none(), "a fresh request alone must wait");
        let late = t1 + max_wait + Duration::from_millis(3);
        let batch = b.push(req(4, "v", late), late).expect("past-deadline flush");
        assert_eq!(batch.len(), 2);
    }

    #[test]
    fn deadline_hint() {
        let t0 = Instant::now();
        let mut b = DynamicBatcher::new("v", 8, Duration::from_millis(10));
        assert!(b.next_deadline().is_none());
        b.push(req(1, "v", t0), t0);
        assert_eq!(b.next_deadline(), Some(t0 + Duration::from_millis(10)));
    }

    #[test]
    fn property_batch_invariants() {
        // Invariants under random push/poll interleavings:
        //   (1) every batch ≤ max_batch;
        //   (2) FIFO within a variant (ids strictly increasing);
        //   (3) nothing lost: Σ batch sizes + pending == pushed.
        crate::testkit::check(
            "batcher-invariants",
            50,
            0xBA7C4,
            |g| {
                let max_batch = g.usize_in(1, 8);
                let ops: Vec<u8> = (0..g.usize_in(1, 60)).map(|_| (g.usize_in(0, 3)) as u8).collect();
                (max_batch, ops)
            },
            |(max_batch, ops)| {
                let t0 = Instant::now();
                let mut b = DynamicBatcher::new("v", *max_batch, Duration::from_millis(5));
                let mut next_id = 0u64;
                let mut emitted = 0usize;
                let mut last_emitted_id: Option<u64> = None;
                let mut clock = t0;
                for op in ops {
                    clock += Duration::from_millis(2);
                    let out = match op {
                        0 | 1 => {
                            next_id += 1;
                            b.push(req(next_id, "v", clock), clock)
                        }
                        2 => b.poll(clock),
                        _ => b.flush(clock),
                    };
                    if let Some(batch) = out {
                        if batch.len() > *max_batch {
                            return Err(format!("batch {} > max {}", batch.len(), max_batch));
                        }
                        for r in &batch.requests {
                            if let Some(prev) = last_emitted_id {
                                if r.id <= prev {
                                    return Err(format!("FIFO violated: {} after {}", r.id, prev));
                                }
                            }
                            last_emitted_id = Some(r.id);
                        }
                        emitted += batch.len();
                    }
                }
                if emitted + b.pending() != next_id as usize {
                    return Err(format!(
                        "lost requests: emitted {} + pending {} != pushed {}",
                        emitted,
                        b.pending(),
                        next_id
                    ));
                }
                Ok(())
            },
        );
    }

    // ---- AdmissionQueue ----------------------------------------------

    #[test]
    fn admission_queue_is_fifo_and_bounded() {
        let t0 = Instant::now();
        let mut q: AdmissionQueue<u64> = AdmissionQueue::new(3, None);
        assert!(q.push(1, t0).is_ok());
        assert!(q.push(2, t0).is_ok());
        assert!(q.push(3, t0).is_ok());
        // Backpressure: the bound rejects, returning the item to shed.
        assert_eq!(q.push(4, t0), Err(4));
        // Strict FIFO, capped by free slots; no deadline → nothing expires.
        let popped = q.pop_ready(2, t0);
        assert!(popped.expired.is_empty());
        let got: Vec<u64> = popped.ready.into_iter().map(|(v, _)| v).collect();
        assert_eq!(got, vec![1, 2]);
        assert_eq!(q.len(), 1);
        // A freed entry makes room again.
        assert!(q.push(5, t0).is_ok());
        let got: Vec<u64> = q.pop_ready(10, t0).ready.into_iter().map(|(v, _)| v).collect();
        assert_eq!(got, vec![3, 5]);
        assert!(q.is_empty());
        let popped = q.pop_ready(4, t0);
        assert!(popped.ready.is_empty() && popped.expired.is_empty());
    }

    #[test]
    fn admission_queue_expires_only_past_deadline() {
        let t0 = Instant::now();
        let d = Duration::from_millis(10);
        let mut q: AdmissionQueue<u64> = AdmissionQueue::new(8, Some(d));
        q.push(1, t0).unwrap();
        q.push(2, t0 + Duration::from_millis(6)).unwrap();
        assert_eq!(q.next_deadline(), Some(t0 + d));
        assert!(q.expire(t0 + Duration::from_millis(9)).is_empty(), "nothing due yet");
        // At t0+10 only the first entry is due; the second still has 6ms.
        let shed: Vec<u64> = q.expire(t0 + d).into_iter().map(|(v, _)| v).collect();
        assert_eq!(shed, vec![1]);
        assert_eq!(q.len(), 1);
        let shed: Vec<u64> = q.expire(t0 + Duration::from_millis(30)).into_iter().map(|(v, _)| v).collect();
        assert_eq!(shed, vec![2]);
        // No deadline → never expires.
        let mut q: AdmissionQueue<u64> = AdmissionQueue::new(8, None);
        q.push(9, t0).unwrap();
        assert!(q.expire(t0 + Duration::from_secs(3600)).is_empty());
        assert_eq!(q.next_deadline(), None);
    }

    #[test]
    fn pop_ready_sheds_request_expiring_exactly_at_the_pop_instant() {
        // Regression (PR 7, mirroring the PR 6 DynamicBatcher boundary
        // fix): pop_ready used to be deadline-blind, so a request whose
        // admit deadline elapsed in the gap between the caller's
        // expire() poll and the pop — including exactly at the pop
        // instant — was seated late instead of shed.
        let t0 = Instant::now();
        let d = Duration::from_millis(10);
        let mut q: AdmissionQueue<u64> = AdmissionQueue::new(8, Some(d));
        q.push(1, t0).unwrap();
        q.push(2, t0 + Duration::from_millis(6)).unwrap();
        // Exactly at request 1's deadline: it must come back as expired —
        // not seated — and must not consume the free slot, which request 2
        // (4ms of budget left) takes instead.
        let popped = q.pop_ready(1, t0 + d);
        let expired: Vec<u64> = popped.expired.into_iter().map(|(v, _)| v).collect();
        let ready: Vec<u64> = popped.ready.into_iter().map(|(v, _)| v).collect();
        assert_eq!(expired, vec![1], "boundary expiry must shed, not seat");
        assert_eq!(ready, vec![2], "unexpired successor takes the slot");
        assert!(q.is_empty(), "nothing silently retained for the next poll");
        // Past the deadline behaves the same.
        q.push(3, t0).unwrap();
        let popped = q.pop_ready(1, t0 + Duration::from_millis(30));
        assert_eq!(popped.expired.len(), 1);
        assert!(popped.ready.is_empty());
        // Without a deadline, pop_ready never expires anything.
        let mut q: AdmissionQueue<u64> = AdmissionQueue::new(8, None);
        q.push(9, t0).unwrap();
        let popped = q.pop_ready(1, t0 + Duration::from_secs(3600));
        assert!(popped.expired.is_empty());
        assert_eq!(popped.ready.len(), 1);
    }

    #[test]
    fn property_admission_queue_invariants() {
        // Under random push/pop/expire interleavings:
        //   (1) queue length never exceeds max_pending;
        //   (2) admitted order is strictly FIFO (ids increasing);
        //   (3) nothing lost: admitted + expired + rejected + pending ==
        //       pushed (every request is accounted for exactly once).
        crate::testkit::check(
            "admission-queue-invariants",
            50,
            0xAD417,
            |g| {
                let max_pending = g.usize_in(1, 6);
                let deadline_ms = g.usize_in(0, 8); // 0 = no deadline
                let ops: Vec<(u8, usize)> = (0..g.usize_in(1, 60))
                    .map(|_| ((g.usize_in(0, 3)) as u8, g.usize_in(0, 3)))
                    .collect();
                (max_pending, deadline_ms, ops)
            },
            |(max_pending, deadline_ms, ops)| {
                let t0 = Instant::now();
                let deadline = (*deadline_ms > 0)
                    .then(|| Duration::from_millis(*deadline_ms as u64));
                let mut q: AdmissionQueue<u64> = AdmissionQueue::new(*max_pending, deadline);
                let mut clock = t0;
                let (mut pushed, mut admitted, mut expired, mut rejected) = (0u64, 0u64, 0u64, 0u64);
                let mut last_admitted: Option<u64> = None;
                for (op, arg) in ops {
                    clock += Duration::from_millis(2);
                    match op {
                        0 | 1 => {
                            pushed += 1;
                            match q.push(pushed, clock) {
                                Ok(()) => {}
                                Err(_) => rejected += 1,
                            }
                        }
                        2 => {
                            let popped = q.pop_ready(*arg, clock);
                            expired += popped.expired.len() as u64;
                            for (id, _) in popped.ready {
                                if let Some(prev) = last_admitted {
                                    if id <= prev {
                                        return Err(format!("FIFO violated: {id} after {prev}"));
                                    }
                                }
                                last_admitted = Some(id);
                                admitted += 1;
                            }
                        }
                        _ => expired += q.expire(clock).len() as u64,
                    }
                    if q.len() > *max_pending {
                        return Err(format!("bound violated: {} > {max_pending}", q.len()));
                    }
                }
                let accounted = admitted + expired + rejected + q.len() as u64;
                if accounted != pushed {
                    return Err(format!("lost requests: {accounted} accounted != {pushed} pushed"));
                }
                Ok(())
            },
        );
    }
}
