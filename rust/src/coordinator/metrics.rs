//! Serving metrics: lock-free per-variant counters (requests, batches,
//! latency sums, queue depth) suitable for reading from any thread, plus
//! log2 latency histograms ([`crate::obs::Histogram`]) for queue wait,
//! admission wait, and service time, and machine-readable exposition —
//! [`Metrics::prometheus`] (text exposition format) and
//! [`Metrics::to_json`] — alongside the human-oriented sorted
//! [`Metrics::snapshot`] line.
//!
//! TTFT and time-per-output-token live engine-side (the engine is the
//! only place that knows when the first token of a stream was sampled);
//! a worker links its executor's [`crate::obs::EngineObs`] into the
//! variant's metrics via [`VariantMetrics::link_engine_obs`] so both
//! expositions can surface per-variant TTFT/TPOT quantiles.

use crate::obs::{EngineObs, Histogram};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

/// Quantiles surfaced per latency histogram in both expositions:
/// (quantile, Prometheus label, JSON key suffix).
const QUANTILES: [(f64, &str, &str); 4] =
    [(0.5, "0.5", "50"), (0.9, "0.9", "90"), (0.95, "0.95", "95"), (0.99, "0.99", "99")];

#[derive(Default)]
pub struct VariantMetrics {
    pub requests: AtomicU64,
    pub batches: AtomicU64,
    /// Requests that received an error `Response` — *per-request*
    /// semantics on every path: a failed batch of N adds N (each of its
    /// requests got the error), a failed stream adds 1, a shed request
    /// adds 1. Pinned by `errors_propagate_to_every_request` and the
    /// shed tests in `coordinator::worker`.
    pub errors: AtomicU64,
    pub queued_us_total: AtomicU64,
    pub service_us_total: AtomicU64,
    pub batch_size_total: AtomicU64,
    pub queue_depth: AtomicU64,
    /// Streams seated into a decode-engine slot by the continuous-batching
    /// scheduler (PR 6). Monotone counter.
    pub admitted: AtomicU64,
    /// Requests shed for any reason — always exactly
    /// `shed_overflow + shed_deadline`, kept as its own counter so the
    /// snapshot line and dashboards watching it predate the split keep
    /// working. Monotone counter — a delta is always the shed *rate*.
    pub shed: AtomicU64,
    /// Requests shed by backpressure: the bounded admission queue was
    /// full at arrival.
    pub shed_overflow: AtomicU64,
    /// Requests shed because their `admit_deadline_ms` expired before a
    /// slot freed up.
    pub shed_deadline: AtomicU64,
    /// Streams currently in flight inside the engine (gauge). Decrement
    /// through [`VariantMetrics::dec_inflight`] — a raw `fetch_sub`
    /// would wrap to `u64::MAX` on a double retire.
    pub inflight: AtomicU64,
    /// Total µs admitted streams spent waiting in the admission queue.
    pub admit_wait_us_total: AtomicU64,
    /// Admitted streams seated on a pooled prompt prefix instead of
    /// re-running prefill for the shared span (PR 7; mirrors
    /// [`crate::decode::DecodeEngine::prefix_hits`]). Monotone counter.
    pub prefix_hits: AtomicU64,
    /// Per-request queue-wait distribution (same samples whose sum feeds
    /// `queued_us_total`).
    pub queue_wait_us: Histogram,
    /// Admission-wait distribution (same samples as `admit_wait_us_total`).
    pub admit_wait_us: Histogram,
    /// Per-request service-time distribution.
    pub service_us: Histogram,
    /// Engine-side observability (TTFT/TPOT histograms + trace ring),
    /// linked by the worker that owns this variant's executor.
    engine: RwLock<Option<Arc<EngineObs>>>,
}

impl VariantMetrics {
    pub fn record_batch(&self, batch_size: usize, queued_us: u64, service_us: u64) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.requests.fetch_add(batch_size as u64, Ordering::Relaxed);
        self.batch_size_total.fetch_add(batch_size as u64, Ordering::Relaxed);
        self.queued_us_total.fetch_add(queued_us * batch_size as u64, Ordering::Relaxed);
        self.service_us_total.fetch_add(service_us * batch_size as u64, Ordering::Relaxed);
        // One histogram sample per request, mirroring the totals above
        // (every request in the batch waited and was served together).
        for _ in 0..batch_size {
            self.queue_wait_us.record(queued_us);
            self.service_us.record(service_us);
        }
    }

    /// One stream seated into an engine slot after `wait_us` in the
    /// admission queue.
    pub fn record_admit(&self, wait_us: u64) {
        self.admitted.fetch_add(1, Ordering::Relaxed);
        self.admit_wait_us_total.fetch_add(wait_us, Ordering::Relaxed);
        self.admit_wait_us.record(wait_us);
    }

    /// One request shed by backpressure (admission queue full). Also
    /// bumps the aggregate `shed` counter.
    pub fn record_shed_overflow(&self) {
        self.shed_overflow.fetch_add(1, Ordering::Relaxed);
        self.shed.fetch_add(1, Ordering::Relaxed);
    }

    /// One request shed by an expired admission deadline. Also bumps the
    /// aggregate `shed` counter.
    pub fn record_shed_deadline(&self) {
        self.shed_deadline.fetch_add(1, Ordering::Relaxed);
        self.shed.fetch_add(1, Ordering::Relaxed);
    }

    /// Saturating decrement of the `inflight` gauge: a double retire (or
    /// any bookkeeping slip) leaves the gauge at 0 instead of wrapping
    /// to `u64::MAX` and poisoning every dashboard reading after it.
    pub fn dec_inflight(&self) {
        let mut cur = self.inflight.load(Ordering::Relaxed);
        while cur > 0 {
            match self.inflight.compare_exchange_weak(
                cur,
                cur - 1,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => return,
                Err(now) => cur = now,
            }
        }
    }

    /// Link the engine-side observability for this variant so the
    /// expositions can surface TTFT/TPOT. Idempotent; last link wins.
    pub fn link_engine_obs(&self, obs: Arc<EngineObs>) {
        *self.engine.write().unwrap() = Some(obs);
    }

    pub fn engine_obs(&self) -> Option<Arc<EngineObs>> {
        self.engine.read().unwrap().clone()
    }

    pub fn mean_admit_wait_us(&self) -> f64 {
        let a = self.admitted.load(Ordering::Relaxed);
        if a == 0 {
            return 0.0;
        }
        self.admit_wait_us_total.load(Ordering::Relaxed) as f64 / a as f64
    }

    pub fn mean_batch_size(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            return 0.0;
        }
        self.batch_size_total.load(Ordering::Relaxed) as f64 / b as f64
    }

    pub fn mean_queued_us(&self) -> f64 {
        let r = self.requests.load(Ordering::Relaxed);
        if r == 0 {
            return 0.0;
        }
        self.queued_us_total.load(Ordering::Relaxed) as f64 / r as f64
    }

    pub fn mean_service_us(&self) -> f64 {
        let r = self.requests.load(Ordering::Relaxed);
        if r == 0 {
            return 0.0;
        }
        self.service_us_total.load(Ordering::Relaxed) as f64 / r as f64
    }
}

/// Registry of per-variant metrics.
#[derive(Default)]
pub struct Metrics {
    inner: RwLock<HashMap<String, Arc<VariantMetrics>>>,
}

/// Escape a Prometheus label value (`\` → `\\`, `"` → `\"`, newline →
/// `\n` — the exposition-format rules).
fn prom_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
    out
}

fn json_escape(s: &str) -> String {
    // Same escape set; JSON and the Prometheus label rules agree on it.
    prom_escape(s)
}

/// Append one histogram family's samples for one variant: cumulative
/// `_bucket{le=...}` lines up to the highest non-empty bucket, then
/// `+Inf`, `_sum`, `_count`.
fn prom_histogram(out: &mut String, family: &str, variant: &str, h: &Histogram) {
    let counts = h.bucket_counts();
    let hi = counts.iter().rposition(|&c| c != 0);
    let v = prom_escape(variant);
    let mut cum = 0u64;
    if let Some(hi) = hi {
        for (i, c) in counts.iter().enumerate().take(hi + 1) {
            cum += c;
            out.push_str(&format!(
                "{family}_bucket{{variant=\"{v}\",le=\"{}\"}} {cum}\n",
                Histogram::bucket_bound(i)
            ));
        }
    }
    out.push_str(&format!("{family}_bucket{{variant=\"{v}\",le=\"+Inf\"}} {}\n", h.count()));
    out.push_str(&format!("{family}_sum{{variant=\"{v}\"}} {}\n", h.sum()));
    out.push_str(&format!("{family}_count{{variant=\"{v}\"}} {}\n", h.count()));
}

/// One histogram as a JSON object (count/sum/mean + quantiles).
fn json_histogram(h: &Histogram) -> String {
    let mut out = format!("{{\"count\":{},\"sum\":{},\"mean\":{:.3}", h.count(), h.sum(), h.mean());
    for (q, _, key) in QUANTILES {
        out.push_str(&format!(",\"p{key}\":{}", h.quantile(q)));
    }
    out.push('}');
    out
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn variant(&self, name: &str) -> Arc<VariantMetrics> {
        if let Some(m) = self.inner.read().unwrap().get(name) {
            return m.clone();
        }
        let mut w = self.inner.write().unwrap();
        w.entry(name.to_string()).or_default().clone()
    }

    /// Sorted `(name, metrics)` view — the shared iteration base of all
    /// three expositions (the registry is a `HashMap`, so every output
    /// must impose its own deterministic order).
    fn sorted(&self) -> Vec<(String, Arc<VariantMetrics>)> {
        let r = self.inner.read().unwrap();
        let mut v: Vec<(String, Arc<VariantMetrics>)> =
            r.iter().map(|(k, m)| (k.clone(), m.clone())).collect();
        v.sort_by(|a, b| a.0.cmp(&b.0));
        v
    }

    /// Text snapshot for the CLI / logs. Lines are sorted by variant name:
    /// the backing registry is a `HashMap` whose iteration order varies
    /// run to run (and even snapshot to snapshot), and diff-based log
    /// tooling treats a reordered line as churn — the sort pins the order
    /// (regression: `snapshot_orders_variants_by_name_deterministically`).
    pub fn snapshot(&self) -> String {
        let mut out = String::new();
        for (n, m) in self.sorted() {
            out.push_str(&format!(
                "{n}: reqs={} batches={} errs={} mean_batch={:.2} queue={:.0}µs service={:.0}µs depth={} admitted={} shed={} inflight={} admit_wait={:.0}µs prefix_hits={}\n",
                m.requests.load(Ordering::Relaxed),
                m.batches.load(Ordering::Relaxed),
                m.errors.load(Ordering::Relaxed),
                m.mean_batch_size(),
                m.mean_queued_us(),
                m.mean_service_us(),
                m.queue_depth.load(Ordering::Relaxed),
                m.admitted.load(Ordering::Relaxed),
                m.shed.load(Ordering::Relaxed),
                m.inflight.load(Ordering::Relaxed),
                m.mean_admit_wait_us(),
                m.prefix_hits.load(Ordering::Relaxed),
            ));
        }
        out
    }

    /// Prometheus text exposition: every counter/gauge/histogram family
    /// with `# HELP`/`# TYPE` headers, families and variant labels
    /// sorted, label values escaped per the format rules. TTFT/TPOT
    /// families (and their quantile gauges) appear when at least one
    /// variant has linked engine observability.
    pub fn prometheus(&self) -> String {
        let vars = self.sorted();
        let mut out = String::new();

        let counter = |out: &mut String, name: &str, help: &str, get: &dyn Fn(&VariantMetrics) -> u64| {
            out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} counter\n"));
            for (n, m) in &vars {
                out.push_str(&format!("{name}{{variant=\"{}\"}} {}\n", prom_escape(n), get(m)));
            }
        };
        let gauge = |out: &mut String, name: &str, help: &str, get: &dyn Fn(&VariantMetrics) -> u64| {
            out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} gauge\n"));
            for (n, m) in &vars {
                out.push_str(&format!("{name}{{variant=\"{}\"}} {}\n", prom_escape(n), get(m)));
            }
        };
        let histogram = |out: &mut String, name: &str, help: &str, get: &dyn Fn(&VariantMetrics) -> Option<&Histogram>| {
            // Skip the family entirely when no variant carries it (the
            // engine-linked TTFT/TPOT case before any link happens).
            if vars.iter().all(|(_, m)| get(m).is_none()) {
                return;
            }
            out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} histogram\n"));
            for (n, m) in &vars {
                if let Some(h) = get(m) {
                    prom_histogram(out, name, n, h);
                }
            }
        };

        // Families in sorted order (the format test greps for this).
        histogram(&mut out, "stamp_admit_wait_us", "Admission-queue wait per admitted stream (microseconds).", &|m| {
            Some(&m.admit_wait_us)
        });
        counter(&mut out, "stamp_admitted_total", "Streams seated into a decode-engine slot.", &|m| {
            m.admitted.load(Ordering::Relaxed)
        });
        counter(&mut out, "stamp_batches_total", "Batches executed.", &|m| {
            m.batches.load(Ordering::Relaxed)
        });
        counter(&mut out, "stamp_errors_total", "Requests that received an error response.", &|m| {
            m.errors.load(Ordering::Relaxed)
        });
        gauge(&mut out, "stamp_inflight", "Streams currently in flight inside the engine.", &|m| {
            m.inflight.load(Ordering::Relaxed)
        });
        counter(&mut out, "stamp_prefix_hits_total", "Admissions seated on a pooled prompt prefix.", &|m| {
            m.prefix_hits.load(Ordering::Relaxed)
        });
        gauge(&mut out, "stamp_queue_depth", "Requests waiting in the admission/batch queue.", &|m| {
            m.queue_depth.load(Ordering::Relaxed)
        });
        histogram(&mut out, "stamp_queue_wait_us", "Queue wait per request (microseconds).", &|m| {
            Some(&m.queue_wait_us)
        });
        counter(&mut out, "stamp_requests_total", "Requests processed.", &|m| {
            m.requests.load(Ordering::Relaxed)
        });
        histogram(&mut out, "stamp_service_us", "Service time per request (microseconds).", &|m| {
            Some(&m.service_us)
        });
        counter(&mut out, "stamp_shed_deadline_total", "Requests shed by an expired admission deadline.", &|m| {
            m.shed_deadline.load(Ordering::Relaxed)
        });
        counter(&mut out, "stamp_shed_overflow_total", "Requests shed by admission-queue backpressure.", &|m| {
            m.shed_overflow.load(Ordering::Relaxed)
        });
        counter(&mut out, "stamp_shed_total", "Requests shed (overflow + deadline).", &|m| {
            m.shed.load(Ordering::Relaxed)
        });

        // Engine-linked TTFT/TPOT: histogram families plus quantile
        // gauges, only for variants with a linked engine.
        let engines: Vec<(String, Arc<EngineObs>)> = vars
            .iter()
            .filter_map(|(n, m)| m.engine_obs().map(|o| (n.clone(), o)))
            .collect();
        let eng_hist = |out: &mut String, name: &str, help: &str, get: &dyn Fn(&EngineObs) -> &Histogram| {
            if engines.is_empty() {
                return;
            }
            out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} histogram\n"));
            for (n, o) in &engines {
                prom_histogram(out, name, n, get(o));
            }
        };
        let eng_quantiles = |out: &mut String, name: &str, help: &str, get: &dyn Fn(&EngineObs) -> &Histogram| {
            if engines.is_empty() {
                return;
            }
            out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} gauge\n"));
            for (n, o) in &engines {
                for (q, label, _) in QUANTILES {
                    out.push_str(&format!(
                        "{name}{{variant=\"{}\",quantile=\"{label}\"}} {}\n",
                        prom_escape(n),
                        get(o).quantile(q)
                    ));
                }
            }
        };
        eng_hist(&mut out, "stamp_spec_accepted_len", "Accepted draft length per speculative verify step (tokens).", &|o| {
            &o.accepted_len
        });
        eng_quantiles(&mut out, "stamp_spec_accepted_len_quantile", "Accepted-draft-length quantiles (tokens).", &|o| {
            &o.accepted_len
        });
        eng_hist(&mut out, "stamp_tpot_us", "Time per output token (microseconds).", &|o| &o.tpot_us);
        eng_quantiles(&mut out, "stamp_tpot_us_quantile", "Time-per-output-token quantiles (microseconds).", &|o| {
            &o.tpot_us
        });
        eng_hist(&mut out, "stamp_ttft_us", "Time to first token (microseconds).", &|o| &o.ttft_us);
        eng_quantiles(&mut out, "stamp_ttft_us_quantile", "Time-to-first-token quantiles (microseconds).", &|o| {
            &o.ttft_us
        });
        out
    }

    /// JSON exposition: one object per variant (sorted) with the raw
    /// counters and each latency histogram as count/sum/mean +
    /// p50/p90/p95/p99. `ttft_us`/`tpot_us`/`spec_accepted_len` are
    /// `null` until an engine is linked (`spec_accepted_len` counts
    /// tokens, not microseconds, and stays empty on non-speculative
    /// engines).
    pub fn to_json(&self) -> String {
        let mut out = String::from("{\"variants\":{");
        for (i, (n, m)) in self.sorted().iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!("\"{}\":{{", json_escape(n)));
            out.push_str(&format!("\"requests\":{}", m.requests.load(Ordering::Relaxed)));
            out.push_str(&format!(",\"batches\":{}", m.batches.load(Ordering::Relaxed)));
            out.push_str(&format!(",\"errors\":{}", m.errors.load(Ordering::Relaxed)));
            out.push_str(&format!(",\"queue_depth\":{}", m.queue_depth.load(Ordering::Relaxed)));
            out.push_str(&format!(",\"admitted\":{}", m.admitted.load(Ordering::Relaxed)));
            out.push_str(&format!(",\"shed\":{}", m.shed.load(Ordering::Relaxed)));
            out.push_str(&format!(",\"shed_overflow\":{}", m.shed_overflow.load(Ordering::Relaxed)));
            out.push_str(&format!(",\"shed_deadline\":{}", m.shed_deadline.load(Ordering::Relaxed)));
            out.push_str(&format!(",\"inflight\":{}", m.inflight.load(Ordering::Relaxed)));
            out.push_str(&format!(",\"prefix_hits\":{}", m.prefix_hits.load(Ordering::Relaxed)));
            out.push_str(&format!(",\"mean_batch_size\":{:.3}", m.mean_batch_size()));
            out.push_str(&format!(",\"queue_wait_us\":{}", json_histogram(&m.queue_wait_us)));
            out.push_str(&format!(",\"admit_wait_us\":{}", json_histogram(&m.admit_wait_us)));
            out.push_str(&format!(",\"service_us\":{}", json_histogram(&m.service_us)));
            match m.engine_obs() {
                Some(o) => {
                    out.push_str(&format!(",\"ttft_us\":{}", json_histogram(&o.ttft_us)));
                    out.push_str(&format!(",\"tpot_us\":{}", json_histogram(&o.tpot_us)));
                    out.push_str(&format!(
                        ",\"spec_accepted_len\":{}",
                        json_histogram(&o.accepted_len)
                    ));
                }
                None => out
                    .push_str(",\"ttft_us\":null,\"tpot_us\":null,\"spec_accepted_len\":null"),
            }
            out.push('}');
        }
        out.push_str("}}");
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_and_read() {
        let m = Metrics::new();
        let v = m.variant("rtn");
        v.record_batch(4, 100, 500);
        v.record_batch(2, 50, 200);
        assert_eq!(v.requests.load(Ordering::Relaxed), 6);
        assert_eq!(v.batches.load(Ordering::Relaxed), 2);
        assert!((v.mean_batch_size() - 3.0).abs() < 1e-9);
        // queued: (100·4 + 50·2)/6 = 83.3
        assert!((v.mean_queued_us() - 500.0 / 6.0).abs() < 1e-6);
        assert!(m.snapshot().contains("rtn"));
        // Histograms saw one sample per request.
        assert_eq!(v.queue_wait_us.count(), 6);
        assert_eq!(v.service_us.count(), 6);
        assert_eq!(v.queue_wait_us.sum(), 100 * 4 + 50 * 2);
    }

    #[test]
    fn same_arc_for_same_name() {
        let m = Metrics::new();
        let a = m.variant("x");
        let b = m.variant("x");
        a.requests.fetch_add(1, Ordering::Relaxed);
        assert_eq!(b.requests.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn admission_counters_record_and_average() {
        let m = Metrics::new();
        let v = m.variant("gen");
        v.record_admit(100);
        v.record_admit(50);
        v.record_shed_overflow();
        assert_eq!(v.admitted.load(Ordering::Relaxed), 2);
        assert_eq!(v.shed.load(Ordering::Relaxed), 1);
        assert!((v.mean_admit_wait_us() - 75.0).abs() < 1e-9);
        assert_eq!(v.admit_wait_us.count(), 2);
        let snap = m.snapshot();
        assert!(snap.contains("admitted=2") && snap.contains("shed=1"), "{snap}");
    }

    #[test]
    fn shed_split_increments_the_right_counter_and_the_sum() {
        // Regression (PR 8): `shed` used to conflate backpressure and
        // deadline sheds; each path must bump its own counter and the
        // aggregate must stay their exact sum for snapshot compatibility.
        let m = Metrics::new();
        let v = m.variant("gen");
        v.record_shed_overflow();
        v.record_shed_overflow();
        v.record_shed_deadline();
        assert_eq!(v.shed_overflow.load(Ordering::Relaxed), 2);
        assert_eq!(v.shed_deadline.load(Ordering::Relaxed), 1);
        assert_eq!(v.shed.load(Ordering::Relaxed), 3);
        assert!(m.snapshot().contains("shed=3"));
    }

    #[test]
    fn dec_inflight_saturates_at_zero() {
        // Regression (PR 8): a double retire used to `fetch_sub` the
        // gauge straight past zero to u64::MAX.
        let v = VariantMetrics::default();
        v.inflight.fetch_add(1, Ordering::Relaxed);
        v.dec_inflight();
        assert_eq!(v.inflight.load(Ordering::Relaxed), 0);
        v.dec_inflight(); // double retire: must stay 0, not wrap
        assert_eq!(v.inflight.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn snapshot_orders_variants_by_name_deterministically() {
        // Regression (PR 7): the registry is a HashMap, whose iteration
        // order is nondeterministic — unsorted, successive snapshots could
        // reorder lines and diff-based log tooling saw spurious churn.
        // Lines must come out sorted by variant name, stably across
        // repeated snapshots.
        let m = Metrics::new();
        m.variant("zeta").record_batch(1, 10, 20);
        m.variant("alpha").record_shed_overflow();
        let snap = m.snapshot();
        let lines: Vec<&str> = snap.lines().collect();
        assert_eq!(lines.len(), 2);
        assert!(lines[0].starts_with("alpha:"), "first line must be alpha: {snap}");
        assert!(lines[1].starts_with("zeta:"), "second line must be zeta: {snap}");
        for _ in 0..10 {
            assert_eq!(m.snapshot(), snap, "snapshot order must be stable");
        }
    }

    #[test]
    fn shed_counter_is_monotone_under_concurrency() {
        // The backpressure counter is cumulative: observed values from any
        // thread form a non-decreasing sequence, and the final total is
        // exact (no lost increments) — with the PR 8 split, the aggregate
        // stays the exact sum of the two per-reason counters.
        let m = Arc::new(Metrics::new());
        let writers: Vec<_> = (0..4)
            .map(|t| {
                let mc = m.clone();
                std::thread::spawn(move || {
                    for _ in 0..500 {
                        if t % 2 == 0 {
                            mc.variant("gen").record_shed_overflow();
                        } else {
                            mc.variant("gen").record_shed_deadline();
                        }
                    }
                })
            })
            .collect();
        let reader = {
            let mc = m.clone();
            std::thread::spawn(move || {
                let v = mc.variant("gen");
                let mut last = 0u64;
                for _ in 0..2000 {
                    let s = v.shed.load(Ordering::Relaxed);
                    assert!(s >= last, "shed counter went backwards: {s} < {last}");
                    last = s;
                }
            })
        };
        for h in writers {
            h.join().unwrap();
        }
        reader.join().unwrap();
        let v = m.variant("gen");
        assert_eq!(v.shed.load(Ordering::Relaxed), 2000);
        assert_eq!(v.shed_overflow.load(Ordering::Relaxed), 1000);
        assert_eq!(v.shed_deadline.load(Ordering::Relaxed), 1000);
    }

    #[test]
    fn concurrent_updates() {
        let m = Arc::new(Metrics::new());
        let mut handles = Vec::new();
        for _ in 0..4 {
            let mc = m.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..1000 {
                    mc.variant("shared").record_batch(1, 1, 1);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(m.variant("shared").requests.load(Ordering::Relaxed), 4000);
    }

    #[test]
    fn prometheus_surfaces_linked_engine_quantiles() {
        let m = Metrics::new();
        let v = m.variant("gen");
        v.record_batch(1, 10, 20);
        // No engine linked: TTFT/TPOT families are absent.
        let text = m.prometheus();
        assert!(!text.contains("stamp_ttft_us"), "{text}");
        let obs = Arc::new(EngineObs::new());
        obs.ttft_us.record(1000);
        obs.tpot_us.record(100);
        obs.tpot_us.record(200);
        obs.accepted_len.record(3);
        v.link_engine_obs(obs);
        let text = m.prometheus();
        assert!(text.contains("# TYPE stamp_ttft_us histogram"), "{text}");
        assert!(text.contains("stamp_ttft_us_quantile{variant=\"gen\",quantile=\"0.5\"}"), "{text}");
        assert!(text.contains("stamp_tpot_us_count{variant=\"gen\"} 2"), "{text}");
        // Speculative accepted-length family rides along with the other
        // engine-linked families, keeping the global alphabetical order
        // (…shed… < spec < tpot < ttft).
        assert!(text.contains("stamp_spec_accepted_len_count{variant=\"gen\"} 1"), "{text}");
        assert!(text.contains("stamp_spec_accepted_len_quantile{variant=\"gen\",quantile=\"0.9\"}"), "{text}");
        let spec_at = text.find("# TYPE stamp_spec_accepted_len histogram").unwrap();
        let tpot_at = text.find("# TYPE stamp_tpot_us histogram").unwrap();
        let shed_at = text.find("# TYPE stamp_shed_total counter").unwrap();
        assert!(shed_at < spec_at && spec_at < tpot_at, "families must stay sorted:\n{text}");
    }

    #[test]
    fn json_exposition_has_quantiles_and_null_engine_fields() {
        let m = Metrics::new();
        let v = m.variant("gen");
        v.record_batch(2, 100, 300);
        let j = m.to_json();
        assert!(j.contains("\"queue_wait_us\":{\"count\":2"), "{j}");
        assert!(j.contains("\"p99\":"), "{j}");
        assert!(j.contains("\"ttft_us\":null,\"tpot_us\":null,\"spec_accepted_len\":null"), "{j}");
        let obs = Arc::new(EngineObs::new());
        obs.ttft_us.record(500);
        obs.accepted_len.record(2);
        v.link_engine_obs(obs);
        let j = m.to_json();
        assert!(j.contains("\"ttft_us\":{\"count\":1"), "{j}");
        assert!(j.contains("\"spec_accepted_len\":{\"count\":1,\"sum\":2"), "{j}");
    }
}
