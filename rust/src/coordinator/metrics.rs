//! Serving metrics: lock-free per-variant counters (requests, batches,
//! latency sums, queue depth) suitable for reading from any thread.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::RwLock;

#[derive(Default)]
pub struct VariantMetrics {
    pub requests: AtomicU64,
    pub batches: AtomicU64,
    pub errors: AtomicU64,
    pub queued_us_total: AtomicU64,
    pub service_us_total: AtomicU64,
    pub batch_size_total: AtomicU64,
    pub queue_depth: AtomicU64,
    /// Streams seated into a decode-engine slot by the continuous-batching
    /// scheduler (PR 6). Monotone counter.
    pub admitted: AtomicU64,
    /// Requests shed by backpressure (admission queue full) or an expired
    /// admission deadline. Monotone counter — it only ever grows, so a
    /// dashboard delta is always the shed *rate*.
    pub shed: AtomicU64,
    /// Streams currently in flight inside the engine (gauge).
    pub inflight: AtomicU64,
    /// Total µs admitted streams spent waiting in the admission queue.
    pub admit_wait_us_total: AtomicU64,
    /// Admitted streams seated on a pooled prompt prefix instead of
    /// re-running prefill for the shared span (PR 7; mirrors
    /// [`crate::decode::DecodeEngine::prefix_hits`]). Monotone counter.
    pub prefix_hits: AtomicU64,
}

impl VariantMetrics {
    pub fn record_batch(&self, batch_size: usize, queued_us: u64, service_us: u64) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.requests.fetch_add(batch_size as u64, Ordering::Relaxed);
        self.batch_size_total.fetch_add(batch_size as u64, Ordering::Relaxed);
        self.queued_us_total.fetch_add(queued_us * batch_size as u64, Ordering::Relaxed);
        self.service_us_total.fetch_add(service_us * batch_size as u64, Ordering::Relaxed);
    }

    /// One stream seated into an engine slot after `wait_us` in the
    /// admission queue.
    pub fn record_admit(&self, wait_us: u64) {
        self.admitted.fetch_add(1, Ordering::Relaxed);
        self.admit_wait_us_total.fetch_add(wait_us, Ordering::Relaxed);
    }

    /// One request shed (backpressure bound or admission deadline).
    pub fn record_shed(&self) {
        self.shed.fetch_add(1, Ordering::Relaxed);
    }

    pub fn mean_admit_wait_us(&self) -> f64 {
        let a = self.admitted.load(Ordering::Relaxed);
        if a == 0 {
            return 0.0;
        }
        self.admit_wait_us_total.load(Ordering::Relaxed) as f64 / a as f64
    }

    pub fn mean_batch_size(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            return 0.0;
        }
        self.batch_size_total.load(Ordering::Relaxed) as f64 / b as f64
    }

    pub fn mean_queued_us(&self) -> f64 {
        let r = self.requests.load(Ordering::Relaxed);
        if r == 0 {
            return 0.0;
        }
        self.queued_us_total.load(Ordering::Relaxed) as f64 / r as f64
    }

    pub fn mean_service_us(&self) -> f64 {
        let r = self.requests.load(Ordering::Relaxed);
        if r == 0 {
            return 0.0;
        }
        self.service_us_total.load(Ordering::Relaxed) as f64 / r as f64
    }
}

/// Registry of per-variant metrics.
#[derive(Default)]
pub struct Metrics {
    inner: RwLock<HashMap<String, std::sync::Arc<VariantMetrics>>>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn variant(&self, name: &str) -> std::sync::Arc<VariantMetrics> {
        if let Some(m) = self.inner.read().unwrap().get(name) {
            return m.clone();
        }
        let mut w = self.inner.write().unwrap();
        w.entry(name.to_string()).or_default().clone()
    }

    /// Text snapshot for the CLI / logs. Lines are sorted by variant name:
    /// the backing registry is a `HashMap` whose iteration order varies
    /// run to run (and even snapshot to snapshot), and diff-based log
    /// tooling treats a reordered line as churn — the sort pins the order
    /// (regression: `snapshot_orders_variants_by_name_deterministically`).
    pub fn snapshot(&self) -> String {
        let r = self.inner.read().unwrap();
        let mut names: Vec<&String> = r.keys().collect();
        names.sort();
        let mut out = String::new();
        for n in names {
            let m = &r[n];
            out.push_str(&format!(
                "{n}: reqs={} batches={} errs={} mean_batch={:.2} queue={:.0}µs service={:.0}µs depth={} admitted={} shed={} inflight={} admit_wait={:.0}µs prefix_hits={}\n",
                m.requests.load(Ordering::Relaxed),
                m.batches.load(Ordering::Relaxed),
                m.errors.load(Ordering::Relaxed),
                m.mean_batch_size(),
                m.mean_queued_us(),
                m.mean_service_us(),
                m.queue_depth.load(Ordering::Relaxed),
                m.admitted.load(Ordering::Relaxed),
                m.shed.load(Ordering::Relaxed),
                m.inflight.load(Ordering::Relaxed),
                m.mean_admit_wait_us(),
                m.prefix_hits.load(Ordering::Relaxed),
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_and_read() {
        let m = Metrics::new();
        let v = m.variant("rtn");
        v.record_batch(4, 100, 500);
        v.record_batch(2, 50, 200);
        assert_eq!(v.requests.load(Ordering::Relaxed), 6);
        assert_eq!(v.batches.load(Ordering::Relaxed), 2);
        assert!((v.mean_batch_size() - 3.0).abs() < 1e-9);
        // queued: (100·4 + 50·2)/6 = 83.3
        assert!((v.mean_queued_us() - 500.0 / 6.0).abs() < 1e-6);
        assert!(m.snapshot().contains("rtn"));
    }

    #[test]
    fn same_arc_for_same_name() {
        let m = Metrics::new();
        let a = m.variant("x");
        let b = m.variant("x");
        a.requests.fetch_add(1, Ordering::Relaxed);
        assert_eq!(b.requests.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn admission_counters_record_and_average() {
        let m = Metrics::new();
        let v = m.variant("gen");
        v.record_admit(100);
        v.record_admit(50);
        v.record_shed();
        assert_eq!(v.admitted.load(Ordering::Relaxed), 2);
        assert_eq!(v.shed.load(Ordering::Relaxed), 1);
        assert!((v.mean_admit_wait_us() - 75.0).abs() < 1e-9);
        let snap = m.snapshot();
        assert!(snap.contains("admitted=2") && snap.contains("shed=1"), "{snap}");
    }

    #[test]
    fn snapshot_orders_variants_by_name_deterministically() {
        // Regression (PR 7): the registry is a HashMap, whose iteration
        // order is nondeterministic — unsorted, successive snapshots could
        // reorder lines and diff-based log tooling saw spurious churn.
        // Lines must come out sorted by variant name, stably across
        // repeated snapshots.
        let m = Metrics::new();
        m.variant("zeta").record_batch(1, 10, 20);
        m.variant("alpha").record_shed();
        let snap = m.snapshot();
        let lines: Vec<&str> = snap.lines().collect();
        assert_eq!(lines.len(), 2);
        assert!(lines[0].starts_with("alpha:"), "first line must be alpha: {snap}");
        assert!(lines[1].starts_with("zeta:"), "second line must be zeta: {snap}");
        for _ in 0..10 {
            assert_eq!(m.snapshot(), snap, "snapshot order must be stable");
        }
    }

    #[test]
    fn shed_counter_is_monotone_under_concurrency() {
        // The backpressure counter is cumulative: observed values from any
        // thread form a non-decreasing sequence, and the final total is
        // exact (no lost increments).
        let m = std::sync::Arc::new(Metrics::new());
        let writers: Vec<_> = (0..4)
            .map(|_| {
                let mc = m.clone();
                std::thread::spawn(move || {
                    for _ in 0..500 {
                        mc.variant("gen").record_shed();
                    }
                })
            })
            .collect();
        let reader = {
            let mc = m.clone();
            std::thread::spawn(move || {
                let v = mc.variant("gen");
                let mut last = 0u64;
                for _ in 0..2000 {
                    let s = v.shed.load(Ordering::Relaxed);
                    assert!(s >= last, "shed counter went backwards: {s} < {last}");
                    last = s;
                }
            })
        };
        for h in writers {
            h.join().unwrap();
        }
        reader.join().unwrap();
        assert_eq!(m.variant("gen").shed.load(Ordering::Relaxed), 2000);
    }

    #[test]
    fn concurrent_updates() {
        let m = std::sync::Arc::new(Metrics::new());
        let mut handles = Vec::new();
        for _ in 0..4 {
            let mc = m.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..1000 {
                    mc.variant("shared").record_batch(1, 1, 1);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(m.variant("shared").requests.load(Ordering::Relaxed), 4000);
    }
}
