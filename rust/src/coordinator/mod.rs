//! L3 coordinator: the serving layer that turns quantized model variants
//! into a request-driven service (vLLM-router-shaped, scaled to this
//! testbed; DESIGN.md §6).
//!
//! Data flow:
//! ```text
//! client → submit() → [router thread] → per-variant DynamicBatcher
//!                                          │ (max_batch / max_wait)
//!                                          ▼
//!                               worker pool (N std threads)
//!                                          │ Executor::execute(batch)
//!                                          ▼
//!                               per-request response channels
//! ```
//!
//! The [`Executor`] trait abstracts what a worker runs: the PJRT engine
//! (AOT artifacts), the Rust-native quantized model, or a mock (tests).
//!
//! Generate variants can opt into a second, continuous path (PR 6): the
//! router forwards their requests to a per-variant [`StreamWorker`] that
//! feeds a *running* decode engine through a bounded [`AdmissionQueue`] —
//! streams are admitted as slots free up and retire independently instead
//! of travelling as a fixed batch (see [`StreamExecutor`]).

mod batcher;
mod metrics;
mod router;
mod server;
mod worker;

pub use batcher::{AdmissionQueue, Batch, DynamicBatcher, Popped};
pub use metrics::{Metrics, VariantMetrics};
pub use router::Router;
pub use server::{Server, ServerHandle};
pub use worker::{Executor, StreamExecutor, StreamIngest, StreamWorker, WorkerPool};

use crate::tensor::Tensor;
use std::sync::mpsc;
use std::time::Instant;

/// A unit of work: one activation matrix to push through one variant.
pub struct Request {
    pub id: u64,
    pub variant: String,
    pub input: Tensor,
    pub submitted: Instant,
    pub respond: mpsc::Sender<Response>,
}

/// The result delivered back to the submitter.
#[derive(Debug)]
pub struct Response {
    pub id: u64,
    pub variant: String,
    pub output: Result<Tensor, String>,
    /// Time spent queued before the batch was formed.
    pub queued_us: u64,
    /// Batch execution time.
    pub service_us: u64,
    /// How many requests shared the batch.
    pub batch_size: usize,
}

impl std::fmt::Debug for Request {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Request(id={}, variant={}, input={:?})", self.id, self.variant, self.input.shape())
    }
}

impl std::fmt::Debug for Batch {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Batch(variant={}, n={})", self.variant, self.requests.len())
    }
}
