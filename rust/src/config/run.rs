//! Typed run configuration assembled from a parsed TOML document, with
//! defaults matching the paper's main experimental setting (W4A4KV4,
//! 64 high-precision tokens, DWT STaMP).

use super::parser::Toml;
use crate::baselines::{ActQuantCfg, BaselineKind, KvQuantCfg, WeightQuantCfg};
use crate::quant::Granularity;

#[derive(Clone, Debug)]
pub struct ModelSpec {
    /// "gpt" or "dit".
    pub kind: String,
    /// gpt: tiny|small|medium|wide; dit: pixart|sana.
    pub variant: String,
    pub seq_len: usize,
    /// Training steps for GPT build (0 = untrained).
    pub train_steps: usize,
}

#[derive(Clone, Debug)]
pub struct QuantSpec {
    /// rtn|smoothquant|quarot|flatquant|viditq|svdquant|fp.
    pub baseline: String,
    pub stamp: bool,
    /// dwt|dct|wht|identity (sequence transform when stamp=true).
    pub transform: String,
    pub act_bits: u32,
    pub weight_bits: u32,
    pub kv_bits: u32,
    pub hp_tokens: usize,
    pub hp_bits: u32,
    /// 0 = per-token; >0 = per-block with this block size.
    pub act_block: usize,
    /// Activation scale granularity: `"auto"` (the default — per-token,
    /// or per-block when `act_block > 0`), `"per_tensor"`, `"per_token"`,
    /// `"block"` (requires `act_block`), or the microscaling formats
    /// `"micro16"` / `"micro32"` served by the in-register folding path
    /// in [`crate::tensor::qgemm`].
    pub granularity: String,
    /// Serve linears through the packed integer path (QTensor + qgemm)
    /// instead of the f32 QDQ simulation; see
    /// [`crate::baselines::QuantStack::with_packed`].
    pub packed: bool,
}

#[derive(Clone, Debug)]
pub struct ServeSpec {
    pub workers: usize,
    pub max_batch: usize,
    /// Max microseconds a batch may wait for more requests.
    pub max_wait_us: u64,
    pub queue_depth: usize,
}

/// Autoregressive generation settings (`[generate]` section): the decode
/// budget, the batched-engine and sampling knobs, and the KV-cache policy
/// handed to [`crate::kvcache::KvCacheConfig`]. TOML keys mirror the
/// field paths: `max_new_tokens`, `decode_batch`, `temperature`, `top_k`,
/// `seed`, `max_inflight`, `admit_deadline_ms`, `speculative.draft`,
/// `speculative.k`, `kv.hp_tokens`, `kv.hp_bits`, `kv.lp_bits`,
/// `kv.block`, `kv.packed`, `kv.transform`, `kv.window`,
/// `kv.sink_tokens`, `kv.prefix_cache`.
#[derive(Clone, Debug)]
pub struct GenerateSpec {
    /// Per-request cap on generated tokens.
    pub max_new_tokens: usize,
    /// Max concurrent streams fused into one decode-step GEMM
    /// ([`crate::decode::DecodeEngine`]); 1 degenerates to serial
    /// per-request stepping.
    pub decode_batch: usize,
    /// Softmax temperature for sampling; `0` (the default) keeps greedy
    /// argmax decoding.
    pub temperature: f32,
    /// Top-k cutoff when sampling. The engine accepts `0` as "full
    /// vocabulary", but the config layer requires an explicit `≥ 1`
    /// whenever `temperature > 0` ([`GenerateSpec::check`]) so a
    /// sampled run never inherits the shortlist by omission.
    pub top_k: usize,
    /// Sampler seed — every stream draws from its own generator seeded
    /// here, so batched runs stay deterministic.
    pub seed: u64,
    /// Slots in the variant's resident [`crate::decode::DecodeEngine`]:
    /// the most streams that can be in flight at once under continuous
    /// admission (and the most a one-shot batch can seat in one wave).
    pub max_inflight: usize,
    /// Continuous-admission deadline: a request still waiting for a free
    /// engine slot after this many milliseconds is shed with an error
    /// instead of queueing indefinitely. `0` (the default) disables the
    /// deadline.
    pub admit_deadline_ms: u64,
    /// Self-speculative decoding drafter: `"off"` (the default),
    /// `"packed"` (greedy low-bit forward on a throwaway fork of the
    /// stream's own KV cache), or `"ngram"` (prompt n-gram lookahead).
    /// Greedy-only — rejected when `temperature > 0`
    /// ([`GenerateSpec::check`]); greedy output is bit-identical either
    /// way, only throughput changes
    /// ([`crate::decode::DecodeEngine::with_speculative`]).
    pub speculative_draft: String,
    /// Max draft tokens verified per speculative step (≥ 1; ignored when
    /// `speculative.draft = "off"`). Each step is further capped by the
    /// stream's budget and its cache's speculative headroom.
    pub speculative_k: usize,
    /// Leading (attention-sink) positions stored at `kv_hp_bits`.
    pub kv_hp_tokens: usize,
    pub kv_hp_bits: u32,
    pub kv_lp_bits: u32,
    /// Tokens per packed cache block (and per block transform).
    pub kv_block: usize,
    /// `false` serves the fp32 reference cache.
    pub kv_packed: bool,
    /// identity|dwt|dct|wht — block-wise sequence transform.
    pub kv_transform: String,
    /// Sliding-window KV eviction: recent tokens kept resident behind the
    /// retained sinks ([`crate::kvcache::EvictionPolicy::SlidingWindow`]).
    /// `0` (the default) disables eviction — streams stay bounded by the
    /// model's `max_seq` exactly as before.
    pub kv_window: usize,
    /// Leading positions permanently retained under a window policy
    /// (block-rounded up; for packed caches they must be ≤ `kv_hp_tokens`
    /// — the sinks are the hp tokens of the two-level policy).
    pub kv_sink_tokens: usize,
    /// Prompt-prefix sharing through the paged block pool
    /// ([`crate::kvcache::BlockPool`], PR 7): streams whose prompt prefix
    /// is already pooled are seated on the shared blocks copy-on-write
    /// instead of re-running prefill for the span. `false` (the default)
    /// keeps every stream's cache fully private.
    pub kv_prefix_cache: bool,
}

impl GenerateSpec {
    /// Resolve into the kvcache subsystem's config.
    pub fn kv_cfg(&self) -> crate::error::Result<crate::kvcache::KvCacheConfig> {
        let transform = match self.kv_transform.as_str() {
            "identity" => crate::stamp::SeqTransformKind::Identity,
            "dwt" => crate::stamp::SeqTransformKind::HaarDwt,
            "dct" => crate::stamp::SeqTransformKind::Dct,
            "wht" => crate::stamp::SeqTransformKind::Wht,
            other => crate::bail!("unknown kv.transform `{other}`"),
        };
        let eviction = if self.kv_window > 0 {
            crate::kvcache::EvictionPolicy::SlidingWindow {
                sink_tokens: self.kv_sink_tokens,
                window: self.kv_window,
            }
        } else {
            crate::kvcache::EvictionPolicy::None
        };
        let cfg = crate::kvcache::KvCacheConfig {
            hp_tokens: self.kv_hp_tokens,
            hp_bits: self.kv_hp_bits,
            lp_bits: self.kv_lp_bits,
            block: self.kv_block,
            packed: self.kv_packed,
            transform,
            // The serving layer bounds the cache to the model's `max_seq`
            // at engine construction (windowed caches stay unbounded and
            // only their *residency* is checked against the model); the
            // config itself stays model-free.
            max_seq: None,
            eviction,
            prefix_cache: self.kv_prefix_cache,
        };
        // Same error surface as a bad kv.transform: invalid lanes/blocks
        // fail here, recoverably, instead of panicking at registration.
        cfg.check().map_err(crate::error::Error::msg)?;
        Ok(cfg)
    }

    /// Resolve the `speculative.*` knobs into the decode engine's
    /// config: `None` when `speculative.draft = "off"` (the default).
    /// Validated at config parse via [`GenerateSpec::check`], so serving
    /// paths can rely on a clean value.
    pub fn speculative(&self) -> crate::error::Result<Option<crate::decode::SpecConfig>> {
        let draft = match self.speculative_draft.as_str() {
            "off" => return Ok(None),
            "packed" => crate::decode::DraftKind::Packed,
            "ngram" => crate::decode::DraftKind::Ngram,
            other => crate::bail!(
                "unknown generate.speculative.draft `{other}` (expected off|packed|ngram)"
            ),
        };
        if self.speculative_k < 1 {
            crate::bail!(
                "generate.speculative.k must be ≥ 1, got {}",
                self.speculative_k
            );
        }
        Ok(Some(crate::decode::SpecConfig { draft, k: self.speculative_k }))
    }

    /// Validate the sampling knobs, recoverably, at config-parse time.
    /// The sampler's own API doc says "temperature must be positive" but
    /// its runtime guard is a silent `.max(1e-6)` clamp — without this
    /// check a misconfigured `temperature = -0.5` would quietly serve
    /// near-argmax draws instead of failing. `temperature = 0` stays
    /// valid (greedy decoding, the default); a positive temperature
    /// requires a usable shortlist (`top_k ≥ 1`). The clamp itself is
    /// kept as defense-in-depth for engines built directly.
    ///
    /// Speculative decoding is greedy-only (the accept rule is an
    /// argmax-agreement argument, DESIGN.md §18), so a positive
    /// temperature combined with a drafter is rejected here rather than
    /// panicking at engine construction.
    pub fn check(&self) -> crate::error::Result<()> {
        if !self.temperature.is_finite() || self.temperature < 0.0 {
            crate::bail!(
                "generate.temperature must be ≥ 0 (0 = greedy, > 0 = sampled), got {}",
                self.temperature
            );
        }
        if self.temperature > 0.0 && self.top_k < 1 {
            crate::bail!(
                "generate.top_k must be ≥ 1 when generate.temperature > 0, got {}",
                self.top_k
            );
        }
        let spec = self.speculative()?;
        if spec.is_some() && self.temperature > 0.0 {
            crate::bail!(
                "generate.speculative.draft = \"{}\" requires greedy decoding \
                 (generate.temperature = 0): speculative verification is an argmax argument",
                self.speculative_draft
            );
        }
        Ok(())
    }

    /// The admission deadline as the scheduler consumes it: `None` when
    /// disabled (`admit_deadline_ms = 0`).
    pub fn admit_deadline(&self) -> Option<std::time::Duration> {
        if self.admit_deadline_ms == 0 {
            None
        } else {
            Some(std::time::Duration::from_millis(self.admit_deadline_ms))
        }
    }

    /// Resolve the sampling knobs into the decode engine's policy:
    /// greedy unless a positive `temperature` is set.
    pub fn sampling(&self) -> crate::decode::Sampling {
        if self.temperature > 0.0 {
            crate::decode::Sampling::TopK {
                k: self.top_k,
                temperature: self.temperature,
                seed: self.seed,
            }
        } else {
            crate::decode::Sampling::Greedy
        }
    }
}

/// Observability settings (`[observability]` section). TOML keys mirror
/// the field paths: `trace.enabled`, `trace.capacity`, `trace.sink`,
/// `kernel_profile`. Everything here is off by default — the histogram
/// metrics in [`crate::coordinator::Metrics`] and
/// [`crate::obs::EngineObs`] are always on (a few relaxed atomics per
/// event); these knobs gate the paths that cost memory or timer reads.
#[derive(Clone, Debug)]
pub struct ObsSpec {
    /// Record per-stream decode timelines into each engine's bounded
    /// [`crate::obs::TraceRing`] (drain via
    /// `NativeExecutor::drain_trace` / `Server::drain_trace`).
    pub trace_enabled: bool,
    /// Events retained per engine ring; oldest are overwritten when full.
    pub trace_capacity: usize,
    /// Where drained traces go. Only `"memory"` (drain through the API)
    /// is implemented; the knob exists so a file sink can be added
    /// without a config break, and anything else is a parse error.
    pub trace_sink: String,
    /// Time every `tensor::matmul` / `tensor::qgemm` call and aggregate
    /// by (kernel, site) — see [`crate::obs::kernel_profile_snapshot`].
    /// Process-wide (the kernels are free functions).
    pub kernel_profile: bool,
}

impl ObsSpec {
    /// Validate the sink name, recoverably, at config-parse time.
    pub fn check(&self) -> crate::error::Result<()> {
        if self.trace_sink != "memory" {
            crate::bail!(
                "observability.trace.sink must be \"memory\" (the only implemented sink), got `{}`",
                self.trace_sink
            );
        }
        Ok(())
    }
}

#[derive(Clone, Debug)]
pub struct RunConfig {
    pub model: ModelSpec,
    pub quant: QuantSpec,
    pub serve: ServeSpec,
    pub generate: GenerateSpec,
    pub obs: ObsSpec,
    /// Where AOT artifacts live.
    pub artifacts_dir: String,
}

impl RunConfig {
    pub fn defaults() -> Self {
        RunConfig {
            model: ModelSpec {
                kind: "gpt".into(),
                variant: "small".into(),
                seq_len: 256,
                train_steps: 200,
            },
            quant: QuantSpec {
                baseline: "quarot".into(),
                stamp: true,
                transform: "dwt".into(),
                act_bits: 4,
                weight_bits: 4,
                kv_bits: 4,
                hp_tokens: 64,
                hp_bits: 8,
                act_block: 0,
                granularity: "auto".into(),
                packed: false,
            },
            serve: ServeSpec {
                workers: crate::coordinator::WorkerPool::default_workers(),
                max_batch: 8,
                max_wait_us: 2000,
                queue_depth: 256,
            },
            generate: GenerateSpec {
                max_new_tokens: 64,
                decode_batch: 8,
                temperature: 0.0,
                top_k: 0,
                seed: 0x5EED,
                max_inflight: 8,
                admit_deadline_ms: 0,
                speculative_draft: "off".into(),
                speculative_k: 4,
                kv_hp_tokens: 64,
                kv_hp_bits: 8,
                kv_lp_bits: 4,
                kv_block: 32,
                kv_packed: true,
                kv_transform: "identity".into(),
                kv_window: 0,
                kv_sink_tokens: 64,
                kv_prefix_cache: false,
            },
            obs: ObsSpec {
                trace_enabled: false,
                trace_capacity: 4096,
                trace_sink: "memory".into(),
                kernel_profile: false,
            },
            artifacts_dir: "artifacts".into(),
        }
    }

    pub fn from_toml_str(text: &str) -> crate::error::Result<Self> {
        let doc = Toml::parse(text).map_err(crate::error::Error::msg)?;
        let d = Self::defaults();
        let cfg = RunConfig {
            model: ModelSpec {
                kind: doc.str_or("model", "kind", &d.model.kind),
                variant: doc.str_or("model", "variant", &d.model.variant),
                seq_len: doc.int_or("model", "seq_len", d.model.seq_len as i64) as usize,
                train_steps: doc.int_or("model", "train_steps", d.model.train_steps as i64)
                    as usize,
            },
            quant: QuantSpec {
                baseline: doc.str_or("quant", "baseline", &d.quant.baseline),
                stamp: doc.bool_or("quant", "stamp", d.quant.stamp),
                transform: doc.str_or("quant", "transform", &d.quant.transform),
                act_bits: doc.int_or("quant", "act_bits", d.quant.act_bits as i64) as u32,
                weight_bits: doc.int_or("quant", "weight_bits", d.quant.weight_bits as i64) as u32,
                kv_bits: doc.int_or("quant", "kv_bits", d.quant.kv_bits as i64) as u32,
                hp_tokens: doc.int_or("quant", "hp_tokens", d.quant.hp_tokens as i64) as usize,
                hp_bits: doc.int_or("quant", "hp_bits", d.quant.hp_bits as i64) as u32,
                act_block: doc.int_or("quant", "act_block", d.quant.act_block as i64) as usize,
                granularity: doc.str_or("quant", "granularity", &d.quant.granularity),
                packed: doc.bool_or("quant", "packed", d.quant.packed),
            },
            serve: ServeSpec {
                workers: doc.int_or("serve", "workers", d.serve.workers as i64) as usize,
                max_batch: doc.int_or("serve", "max_batch", d.serve.max_batch as i64) as usize,
                max_wait_us: doc.int_or("serve", "max_wait_us", d.serve.max_wait_us as i64) as u64,
                queue_depth: doc.int_or("serve", "queue_depth", d.serve.queue_depth as i64)
                    as usize,
            },
            generate: GenerateSpec {
                max_new_tokens: doc
                    .int_or("generate", "max_new_tokens", d.generate.max_new_tokens as i64)
                    as usize,
                decode_batch: doc
                    .int_or("generate", "decode_batch", d.generate.decode_batch as i64)
                    .max(1) as usize,
                temperature: doc
                    .float_or("generate", "temperature", d.generate.temperature as f64)
                    as f32,
                top_k: doc.int_or("generate", "top_k", d.generate.top_k as i64) as usize,
                seed: doc.int_or("generate", "seed", d.generate.seed as i64) as u64,
                max_inflight: doc
                    .int_or("generate", "max_inflight", d.generate.max_inflight as i64)
                    .max(1) as usize,
                admit_deadline_ms: doc
                    .int_or("generate", "admit_deadline_ms", d.generate.admit_deadline_ms as i64)
                    .max(0) as u64,
                speculative_draft: doc.str_or(
                    "generate",
                    "speculative.draft",
                    &d.generate.speculative_draft,
                ),
                speculative_k: doc
                    .int_or("generate", "speculative.k", d.generate.speculative_k as i64)
                    as usize,
                kv_hp_tokens: doc
                    .int_or("generate", "kv.hp_tokens", d.generate.kv_hp_tokens as i64)
                    as usize,
                kv_hp_bits: doc.int_or("generate", "kv.hp_bits", d.generate.kv_hp_bits as i64)
                    as u32,
                kv_lp_bits: doc.int_or("generate", "kv.lp_bits", d.generate.kv_lp_bits as i64)
                    as u32,
                kv_block: doc.int_or("generate", "kv.block", d.generate.kv_block as i64) as usize,
                kv_packed: doc.bool_or("generate", "kv.packed", d.generate.kv_packed),
                kv_transform: doc.str_or("generate", "kv.transform", &d.generate.kv_transform),
                kv_window: doc.int_or("generate", "kv.window", d.generate.kv_window as i64)
                    as usize,
                kv_sink_tokens: doc
                    .int_or("generate", "kv.sink_tokens", d.generate.kv_sink_tokens as i64)
                    as usize,
                kv_prefix_cache: doc
                    .bool_or("generate", "kv.prefix_cache", d.generate.kv_prefix_cache),
            },
            obs: ObsSpec {
                trace_enabled: doc.bool_or("observability", "trace.enabled", d.obs.trace_enabled),
                trace_capacity: doc
                    .int_or("observability", "trace.capacity", d.obs.trace_capacity as i64)
                    .max(1) as usize,
                trace_sink: doc.str_or("observability", "trace.sink", &d.obs.trace_sink),
                kernel_profile: doc
                    .bool_or("observability", "kernel_profile", d.obs.kernel_profile),
            },
            artifacts_dir: doc.str_or("", "artifacts_dir", &d.artifacts_dir),
        };
        // Sampling knobs fail here, recoverably, instead of being silently
        // clamped at sample time (see [`GenerateSpec::check`]); same for
        // an unimplemented trace sink.
        cfg.generate.check()?;
        cfg.obs.check()?;
        // An unknown or inconsistent granularity name fails here,
        // recoverably, instead of panicking at variant registration.
        cfg.quant.act_granularity()?;
        Ok(cfg)
    }

    pub fn from_file(path: &str) -> crate::error::Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| crate::err!("reading config {path}: {e}"))?;
        Self::from_toml_str(&text)
    }
}

impl QuantSpec {
    pub fn baseline_kind(&self) -> crate::error::Result<Option<BaselineKind>> {
        Ok(Some(match self.baseline.as_str() {
            "fp" => return Ok(None),
            "rtn" => BaselineKind::Rtn,
            "smoothquant" => BaselineKind::SmoothQuant,
            "quarot" => BaselineKind::QuaRot,
            "flatquant" => BaselineKind::FlatQuant,
            "viditq" => BaselineKind::ViDitQ,
            "svdquant" => BaselineKind::SvdQuant,
            other => crate::bail!("unknown baseline `{other}`"),
        }))
    }

    pub fn seq_transform(&self) -> crate::error::Result<crate::stamp::SeqTransformKind> {
        Ok(match self.transform.as_str() {
            "dwt" => crate::stamp::SeqTransformKind::HaarDwt,
            "dct" => crate::stamp::SeqTransformKind::Dct,
            "wht" => crate::stamp::SeqTransformKind::Wht,
            "identity" => crate::stamp::SeqTransformKind::Identity,
            other => crate::bail!("unknown sequence transform `{other}`"),
        })
    }

    /// Resolve the `quant.granularity` knob (validated at config parse,
    /// so serving paths can unwrap via [`QuantSpec::act_cfg`]).
    pub fn act_granularity(&self) -> crate::error::Result<Granularity> {
        Ok(match self.granularity.as_str() {
            // Legacy mapping: per-token unless an act_block is set.
            "auto" => {
                if self.act_block == 0 {
                    Granularity::PerToken
                } else {
                    Granularity::PerBlock { block: self.act_block }
                }
            }
            "per_tensor" => Granularity::PerTensor,
            "per_token" => Granularity::PerToken,
            "block" => {
                if self.act_block == 0 {
                    crate::bail!(
                        "quant.granularity = \"block\" requires quant.act_block > 0"
                    );
                }
                Granularity::PerBlock { block: self.act_block }
            }
            "micro16" => Granularity::MicroBlock { block: 16 },
            "micro32" => Granularity::MicroBlock { block: 32 },
            other => crate::bail!(
                "unknown quant.granularity `{other}` (expected auto|per_tensor|per_token|block|micro16|micro32)"
            ),
        })
    }

    pub fn act_cfg(&self) -> ActQuantCfg {
        ActQuantCfg {
            bits: self.act_bits,
            hp_tokens: self.hp_tokens,
            hp_bits: self.hp_bits,
            granularity: self.act_granularity().expect("validated at config parse"),
            range_shrink: if self.baseline == "quarot" { 0.9 } else { 1.0 },
        }
    }

    pub fn weight_cfg(&self) -> WeightQuantCfg {
        WeightQuantCfg { bits: self.weight_bits, block: None }
    }

    pub fn kv_cfg(&self) -> KvQuantCfg {
        KvQuantCfg { bits: self.kv_bits, hp_tokens: self.hp_tokens, hp_bits: self.hp_bits }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_paper_setting() {
        let d = RunConfig::defaults();
        assert_eq!(d.quant.act_bits, 4);
        assert_eq!(d.quant.hp_tokens, 64);
        assert_eq!(d.quant.hp_bits, 8);
        assert!(d.quant.stamp);
    }

    #[test]
    fn baseline_mapping() {
        let mut q = RunConfig::defaults().quant;
        q.baseline = "fp".into();
        assert!(q.baseline_kind().unwrap().is_none());
        q.baseline = "svdquant".into();
        assert_eq!(q.baseline_kind().unwrap(), Some(BaselineKind::SvdQuant));
        q.baseline = "bogus".into();
        assert!(q.baseline_kind().is_err());
    }

    #[test]
    fn packed_switch_parses() {
        assert!(!RunConfig::defaults().quant.packed, "packed path is opt-in");
        let cfg = RunConfig::from_toml_str("[quant]\npacked = true\n").unwrap();
        assert!(cfg.quant.packed);
    }

    #[test]
    fn generate_section_parses_with_dotted_kv_keys() {
        let cfg = RunConfig::from_toml_str(
            "[generate]\nmax_new_tokens = 16\nkv.hp_tokens = 8\nkv.hp_bits = 8\nkv.lp_bits = 4\nkv.block = 16\nkv.packed = true\nkv.transform = \"dwt\"\n",
        )
        .unwrap();
        assert_eq!(cfg.generate.max_new_tokens, 16);
        let kv = cfg.generate.kv_cfg().unwrap();
        assert_eq!((kv.hp_tokens, kv.hp_bits, kv.lp_bits, kv.block), (8, 8, 4, 16));
        assert!(kv.packed);
        assert_eq!(kv.transform, crate::stamp::SeqTransformKind::HaarDwt);
    }

    #[test]
    fn generate_defaults_are_paper_kv_setting() {
        let d = RunConfig::defaults();
        assert_eq!(d.generate.kv_hp_tokens, 64);
        assert_eq!(d.generate.kv_lp_bits, 4);
        let kv = d.generate.kv_cfg().unwrap();
        assert!(kv.packed);
        assert_eq!(kv.transform, crate::stamp::SeqTransformKind::Identity);
        let mut bad = d.generate.clone();
        bad.kv_transform = "bogus".into();
        assert!(bad.kv_cfg().is_err());
        // Invalid lanes/blocks surface as the same recoverable error, not
        // a later panic at variant registration.
        let mut bad = d.generate.clone();
        bad.kv_lp_bits = 6;
        assert!(bad.kv_cfg().unwrap_err().to_string().contains("4- or 8-bit"));
        let mut bad = d.generate.clone();
        bad.kv_block = 0;
        assert!(bad.kv_cfg().is_err());
    }

    #[test]
    fn generate_window_knobs_parse_and_validate_recoverably() {
        // Off by default: no eviction, exactly the pre-window behavior.
        let d = RunConfig::defaults();
        assert_eq!(d.generate.kv_window, 0);
        assert_eq!(
            d.generate.kv_cfg().unwrap().eviction,
            crate::kvcache::EvictionPolicy::None
        );
        // Dotted keys resolve into the sliding-window policy.
        let cfg = RunConfig::from_toml_str(
            "[generate]\nkv.block = 16\nkv.window = 96\nkv.sink_tokens = 32\n",
        )
        .unwrap();
        assert_eq!(cfg.generate.kv_window, 96);
        assert_eq!(cfg.generate.kv_sink_tokens, 32);
        let kv = cfg.generate.kv_cfg().unwrap();
        assert_eq!(
            kv.eviction,
            crate::kvcache::EvictionPolicy::SlidingWindow { sink_tokens: 32, window: 96 }
        );
        assert_eq!(kv.resident_bound(), Some(32 + 96 + 16));
        // Boundary rules surface as recoverable parse-time errors, not
        // panics at variant registration: window < block…
        let bad = RunConfig::from_toml_str("[generate]\nkv.block = 32\nkv.window = 8\n").unwrap();
        let err = bad.generate.kv_cfg().unwrap_err().to_string();
        assert!(err.contains("must be ≥ kv.block"), "{err}");
        // …and sinks past the hp prefix on a packed cache.
        let bad = RunConfig::from_toml_str(
            "[generate]\nkv.window = 64\nkv.sink_tokens = 96\nkv.hp_tokens = 64\n",
        )
        .unwrap();
        let err = bad.generate.kv_cfg().unwrap_err().to_string();
        assert!(err.contains("≤ kv.hp_tokens"), "{err}");
        // An fp32 windowed cache has no hp prefix to respect.
        let ok = RunConfig::from_toml_str(
            "[generate]\nkv.packed = false\nkv.window = 64\nkv.sink_tokens = 96\n",
        )
        .unwrap();
        assert!(ok.generate.kv_cfg().is_ok());
    }

    #[test]
    fn generate_decode_batch_and_sampling_parse() {
        // Greedy stays the default; decode_batch defaults to the fused
        // coordinator batch width.
        let d = RunConfig::defaults();
        assert_eq!(d.generate.decode_batch, 8);
        assert_eq!(d.generate.sampling(), crate::decode::Sampling::Greedy);
        let cfg = RunConfig::from_toml_str(
            "[generate]\ndecode_batch = 4\ntemperature = 0.8\ntop_k = 16\nseed = 99\n",
        )
        .unwrap();
        assert_eq!(cfg.generate.decode_batch, 4);
        assert_eq!(
            cfg.generate.sampling(),
            crate::decode::Sampling::TopK { k: 16, temperature: 0.8, seed: 99 }
        );
        // decode_batch is clamped to ≥ 1 rather than panicking later.
        let cfg = RunConfig::from_toml_str("[generate]\ndecode_batch = 0\n").unwrap();
        assert_eq!(cfg.generate.decode_batch, 1);
    }

    #[test]
    fn generate_admission_knobs_parse() {
        // Defaults: 8 engine slots, no admission deadline.
        let d = RunConfig::defaults();
        assert_eq!(d.generate.max_inflight, 8);
        assert_eq!(d.generate.admit_deadline_ms, 0);
        assert_eq!(d.generate.admit_deadline(), None);
        let cfg = RunConfig::from_toml_str(
            "[generate]\nmax_inflight = 3\nadmit_deadline_ms = 250\n",
        )
        .unwrap();
        assert_eq!(cfg.generate.max_inflight, 3);
        assert_eq!(
            cfg.generate.admit_deadline(),
            Some(std::time::Duration::from_millis(250))
        );
        // max_inflight is clamped to ≥ 1 rather than panicking at
        // registration.
        let cfg = RunConfig::from_toml_str("[generate]\nmax_inflight = 0\n").unwrap();
        assert_eq!(cfg.generate.max_inflight, 1);
    }

    #[test]
    fn generate_prefix_cache_knob_parses_and_is_off_by_default() {
        let d = RunConfig::defaults();
        assert!(!d.generate.kv_prefix_cache, "prefix sharing is opt-in");
        assert!(!d.generate.kv_cfg().unwrap().prefix_cache);
        let cfg = RunConfig::from_toml_str("[generate]\nkv.prefix_cache = true\n").unwrap();
        assert!(cfg.generate.kv_prefix_cache);
        assert!(cfg.generate.kv_cfg().unwrap().prefix_cache);
    }

    #[test]
    fn generate_sampling_knobs_validate_recoverably_at_parse() {
        // Regression (PR 7): a negative temperature used to be silently
        // clamped to 1e-6 at sample time (near-argmax draws) — it must be
        // a recoverable parse error instead.
        let err = RunConfig::from_toml_str("[generate]\ntemperature = -0.5\n").unwrap_err();
        assert!(err.to_string().contains("temperature"), "{err}");
        // Sampling with an empty shortlist is equally misconfigured.
        let err = RunConfig::from_toml_str("[generate]\ntemperature = 0.7\n").unwrap_err();
        assert!(err.to_string().contains("top_k"), "{err}");
        // A coherent sampled config and the greedy default both pass.
        let cfg =
            RunConfig::from_toml_str("[generate]\ntemperature = 0.7\ntop_k = 16\n").unwrap();
        assert_eq!(
            cfg.generate.sampling(),
            crate::decode::Sampling::TopK { k: 16, temperature: 0.7, seed: 0x5EED }
        );
        RunConfig::defaults().generate.check().unwrap();
        // top_k without sampling stays valid: greedy ignores it.
        RunConfig::from_toml_str("[generate]\ntop_k = 4\n").unwrap();
    }

    #[test]
    fn generate_speculative_knobs_parse_and_validate() {
        // Off by default: no drafter, plain one-token stepping.
        let d = RunConfig::defaults();
        assert_eq!(d.generate.speculative_draft, "off");
        assert_eq!(d.generate.speculative().unwrap(), None);
        // Both drafters resolve, with the depth knob applied.
        let cfg = RunConfig::from_toml_str(
            "[generate]\nspeculative.draft = \"ngram\"\nspeculative.k = 6\n",
        )
        .unwrap();
        assert_eq!(
            cfg.generate.speculative().unwrap(),
            Some(crate::decode::SpecConfig { draft: crate::decode::DraftKind::Ngram, k: 6 })
        );
        let cfg = RunConfig::from_toml_str("[generate]\nspeculative.draft = \"packed\"\n").unwrap();
        assert_eq!(
            cfg.generate.speculative().unwrap(),
            Some(crate::decode::SpecConfig { draft: crate::decode::DraftKind::Packed, k: 4 })
        );
        // Misconfigurations fail recoverably at parse time: an unknown
        // drafter, a zero depth, and the sampled + speculative clash.
        let err =
            RunConfig::from_toml_str("[generate]\nspeculative.draft = \"bogus\"\n").unwrap_err();
        assert!(err.to_string().contains("speculative.draft"), "{err}");
        let err = RunConfig::from_toml_str(
            "[generate]\nspeculative.draft = \"ngram\"\nspeculative.k = 0\n",
        )
        .unwrap_err();
        assert!(err.to_string().contains("speculative.k"), "{err}");
        let err = RunConfig::from_toml_str(
            "[generate]\nspeculative.draft = \"ngram\"\ntemperature = 0.7\ntop_k = 8\n",
        )
        .unwrap_err();
        assert!(err.to_string().contains("greedy"), "{err}");
        // k is ignored while the drafter is off — no spurious failure.
        RunConfig::from_toml_str("[generate]\nspeculative.k = 0\n").unwrap();
    }

    #[test]
    fn observability_section_parses_and_is_off_by_default() {
        let d = RunConfig::defaults();
        assert!(!d.obs.trace_enabled, "tracing is opt-in");
        assert!(!d.obs.kernel_profile, "kernel profiling is opt-in");
        assert_eq!(d.obs.trace_capacity, 4096);
        assert_eq!(d.obs.trace_sink, "memory");
        d.obs.check().unwrap();
        let cfg = RunConfig::from_toml_str(
            "[observability]\ntrace.enabled = true\ntrace.capacity = 128\nkernel_profile = true\n",
        )
        .unwrap();
        assert!(cfg.obs.trace_enabled);
        assert_eq!(cfg.obs.trace_capacity, 128);
        assert!(cfg.obs.kernel_profile);
        // capacity is clamped to ≥ 1 rather than building a zero ring.
        let cfg = RunConfig::from_toml_str("[observability]\ntrace.capacity = 0\n").unwrap();
        assert_eq!(cfg.obs.trace_capacity, 1);
        // An unimplemented sink is a recoverable parse error, not a
        // silently dropped trace.
        let err =
            RunConfig::from_toml_str("[observability]\ntrace.sink = \"file\"\n").unwrap_err();
        assert!(err.to_string().contains("trace.sink"), "{err}");
    }

    #[test]
    fn granularity_knob_parses_and_validates() {
        // Default "auto" keeps the legacy mapping: per-token, or per-block
        // when act_block is set.
        let d = RunConfig::defaults();
        assert_eq!(d.quant.granularity, "auto");
        assert_eq!(d.quant.act_granularity().unwrap(), Granularity::PerToken);
        let cfg =
            RunConfig::from_toml_str("[quant]\nact_block = 16\n").unwrap();
        assert_eq!(
            cfg.quant.act_granularity().unwrap(),
            Granularity::PerBlock { block: 16 }
        );
        // Explicit names resolve directly.
        let cfg = RunConfig::from_toml_str("[quant]\ngranularity = \"micro16\"\n").unwrap();
        assert_eq!(
            cfg.quant.act_granularity().unwrap(),
            Granularity::MicroBlock { block: 16 }
        );
        assert_eq!(cfg.quant.act_cfg().granularity, Granularity::MicroBlock { block: 16 });
        let cfg = RunConfig::from_toml_str("[quant]\ngranularity = \"micro32\"\n").unwrap();
        assert_eq!(
            cfg.quant.act_granularity().unwrap(),
            Granularity::MicroBlock { block: 32 }
        );
        let cfg = RunConfig::from_toml_str(
            "[quant]\ngranularity = \"block\"\nact_block = 32\n",
        )
        .unwrap();
        assert_eq!(
            cfg.quant.act_granularity().unwrap(),
            Granularity::PerBlock { block: 32 }
        );
        let cfg = RunConfig::from_toml_str("[quant]\ngranularity = \"per_tensor\"\n").unwrap();
        assert_eq!(cfg.quant.act_granularity().unwrap(), Granularity::PerTensor);
        // Misconfigurations fail recoverably at parse time.
        let err = RunConfig::from_toml_str("[quant]\ngranularity = \"bogus\"\n").unwrap_err();
        assert!(err.to_string().contains("granularity"), "{err}");
        let err = RunConfig::from_toml_str("[quant]\ngranularity = \"block\"\n").unwrap_err();
        assert!(err.to_string().contains("act_block"), "{err}");
    }

    #[test]
    fn quarot_gets_range_shrink() {
        let mut q = RunConfig::defaults().quant;
        q.baseline = "quarot".into();
        assert!((q.act_cfg().range_shrink - 0.9).abs() < 1e-6);
        q.baseline = "rtn".into();
        assert!((q.act_cfg().range_shrink - 1.0).abs() < 1e-6);
    }
}
