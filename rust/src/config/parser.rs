//! The TOML-subset parser. Hand-rolled recursive-descent over lines;
//! good error messages with line numbers.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed value.
#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_int(&self) -> Option<i64> {
        match self {
            TomlValue::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_float(&self) -> Option<f64> {
        match self {
            TomlValue::Float(f) => Some(*f),
            TomlValue::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// Parse error with location.
#[derive(Debug)]
pub struct ParseError {
    pub line: usize,
    pub message: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "config parse error on line {}: {}", self.line, self.message)
    }
}

impl std::error::Error for ParseError {}

/// A parsed document: `section -> key -> value`. Keys outside any section
/// live under the empty-string section.
#[derive(Debug, Default)]
pub struct Toml {
    pub sections: BTreeMap<String, BTreeMap<String, TomlValue>>,
}

impl Toml {
    pub fn parse(text: &str) -> Result<Toml, ParseError> {
        let mut doc = Toml::default();
        let mut current = String::new();
        for (ln, raw) in text.lines().enumerate() {
            let line_no = ln + 1;
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest.strip_suffix(']').ok_or_else(|| ParseError {
                    line: line_no,
                    message: "unterminated section header".into(),
                })?;
                current = name.trim().to_string();
                doc.sections.entry(current.clone()).or_default();
                continue;
            }
            let eq = line.find('=').ok_or_else(|| ParseError {
                line: line_no,
                message: format!("expected `key = value`, got `{line}`"),
            })?;
            let key = line[..eq].trim().to_string();
            if key.is_empty() {
                return Err(ParseError { line: line_no, message: "empty key".into() });
            }
            let value = parse_value(line[eq + 1..].trim(), line_no)?;
            doc.sections.entry(current.clone()).or_default().insert(key, value);
        }
        Ok(doc)
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&TomlValue> {
        self.sections.get(section)?.get(key)
    }

    /// Typed getters with defaults.
    pub fn str_or(&self, section: &str, key: &str, default: &str) -> String {
        self.get(section, key).and_then(|v| v.as_str()).unwrap_or(default).to_string()
    }

    pub fn int_or(&self, section: &str, key: &str, default: i64) -> i64 {
        self.get(section, key).and_then(|v| v.as_int()).unwrap_or(default)
    }

    pub fn float_or(&self, section: &str, key: &str, default: f64) -> f64 {
        self.get(section, key).and_then(|v| v.as_float()).unwrap_or(default)
    }

    pub fn bool_or(&self, section: &str, key: &str, default: bool) -> bool {
        self.get(section, key).and_then(|v| v.as_bool()).unwrap_or(default)
    }
}

fn strip_comment(line: &str) -> &str {
    // Respect '#' inside quoted strings.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str, line: usize) -> Result<TomlValue, ParseError> {
    if s.is_empty() {
        return Err(ParseError { line, message: "missing value".into() });
    }
    if let Some(body) = s.strip_prefix('"') {
        let inner = body.strip_suffix('"').ok_or_else(|| ParseError {
            line,
            message: "unterminated string".into(),
        })?;
        return Ok(TomlValue::Str(inner.to_string()));
    }
    if s == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if s == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if let Some(body) = s.strip_prefix('[') {
        let inner = body.strip_suffix(']').ok_or_else(|| ParseError {
            line,
            message: "unterminated array".into(),
        })?;
        let mut items = Vec::new();
        let trimmed = inner.trim();
        if !trimmed.is_empty() {
            for part in split_top_level(trimmed) {
                items.push(parse_value(part.trim(), line)?);
            }
        }
        return Ok(TomlValue::Array(items));
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    Err(ParseError { line, message: format!("cannot parse value `{s}`") })
}

/// Split an array body on commas, respecting quoted strings.
fn split_top_level(s: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let mut start = 0usize;
    let mut in_str = false;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            ',' if !in_str => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&s[start..]);
    parts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars() {
        let doc = Toml::parse("a = 1\nb = 2.5\nc = \"hi\"\nd = true\n").unwrap();
        assert_eq!(doc.get("", "a"), Some(&TomlValue::Int(1)));
        assert_eq!(doc.get("", "b"), Some(&TomlValue::Float(2.5)));
        assert_eq!(doc.get("", "c"), Some(&TomlValue::Str("hi".into())));
        assert_eq!(doc.get("", "d"), Some(&TomlValue::Bool(true)));
    }

    #[test]
    fn sections_and_comments() {
        let doc = Toml::parse("# top\n[x]\nk = 3 # trailing\n[y]\nk = 4\n").unwrap();
        assert_eq!(doc.int_or("x", "k", 0), 3);
        assert_eq!(doc.int_or("y", "k", 0), 4);
        assert_eq!(doc.int_or("z", "k", 9), 9);
    }

    #[test]
    fn hash_inside_string_kept() {
        let doc = Toml::parse("s = \"a#b\"\n").unwrap();
        assert_eq!(doc.str_or("", "s", ""), "a#b");
    }

    #[test]
    fn arrays() {
        let doc = Toml::parse("xs = [1, 2, 3]\nys = [\"a\", \"b,c\"]\n").unwrap();
        match doc.get("", "xs").unwrap() {
            TomlValue::Array(v) => assert_eq!(v.len(), 3),
            _ => panic!(),
        }
        match doc.get("", "ys").unwrap() {
            TomlValue::Array(v) => {
                assert_eq!(v[1], TomlValue::Str("b,c".into()));
            }
            _ => panic!(),
        }
    }

    #[test]
    fn errors_carry_line_numbers() {
        let err = Toml::parse("ok = 1\nbroken\n").unwrap_err();
        assert_eq!(err.line, 2);
        let err = Toml::parse("x = \"unterminated\n").unwrap_err();
        assert_eq!(err.line, 1);
    }

    #[test]
    fn int_float_coercion() {
        let doc = Toml::parse("x = 3\n").unwrap();
        assert_eq!(doc.float_or("", "x", 0.0), 3.0);
    }
}
