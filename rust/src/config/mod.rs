//! Configuration system: a minimal TOML-subset parser (offline stand-in
//! for serde+toml; DESIGN.md §3) plus the typed run configuration the CLI
//! and coordinator consume.
//!
//! Supported TOML subset: `[section]` headers, `key = value` with string
//! ("…"), integer, float, boolean, and flat arrays of those. Comments with
//! `#`. This covers every config this repo ships.

mod parser;
mod run;

pub use parser::{ParseError, TomlValue, Toml};
pub use run::{GenerateSpec, ModelSpec, ObsSpec, QuantSpec, RunConfig, ServeSpec};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn end_to_end_config_parse() {
        let text = r#"
# demo config
[model]
kind = "gpt"
variant = "small"
seq_len = 256

[quant]
baseline = "quarot"
stamp = true
act_bits = 4
hp_tokens = 64

[serve]
workers = 2
max_batch = 8
"#;
        let cfg = RunConfig::from_toml_str(text).unwrap();
        assert_eq!(cfg.model.kind, "gpt");
        assert_eq!(cfg.model.variant, "small");
        assert_eq!(cfg.model.seq_len, 256);
        assert_eq!(cfg.quant.baseline, "quarot");
        assert!(cfg.quant.stamp);
        assert_eq!(cfg.quant.act_bits, 4);
        assert_eq!(cfg.serve.workers, 2);
        assert_eq!(cfg.serve.max_batch, 8);
    }
}
