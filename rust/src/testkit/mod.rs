//! Minimal property-testing harness (offline stand-in for proptest; see
//! DESIGN.md §3 crate-availability substitutions).
//!
//! [`check`] runs a property over `n` generated cases; on failure it
//! re-runs with progressively simpler cases drawn from the same generator
//! (size-bounded shrinking) and reports the smallest failing seed/case so
//! the failure is reproducible from the printed seed.

use crate::tensor::XorShiftRng;

/// Case generation context handed to generators: a seeded RNG plus a
/// "size" knob that shrinking reduces.
pub struct Gen {
    pub rng: XorShiftRng,
    pub size: usize,
}

impl Gen {
    /// Uniform usize in `[lo, hi]`, scaled down when shrinking.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        let hi_eff = lo + ((hi - lo) * self.size) / 100;
        lo + self.rng.next_below(hi_eff - lo + 1)
    }

    /// Uniform f32 in `[lo, hi)`.
    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.rng.next_f32()
    }

    /// Power of two in `[lo, hi]` (both must be powers of two).
    pub fn pow2_in(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo.is_power_of_two() && hi.is_power_of_two());
        let lo_log = lo.trailing_zeros();
        let hi_log = hi.trailing_zeros();
        let span = ((hi_log - lo_log) as usize * self.size) / 100;
        1 << (lo_log as usize + self.rng.next_below(span + 1))
    }
}

/// Result of a property run.
pub struct PropResult {
    pub cases: usize,
    pub failed_seed: Option<u64>,
}

/// Run `prop` over `n` cases generated from `base_seed`. Panics with the
/// smallest failing case description on failure.
pub fn check<T: std::fmt::Debug>(
    name: &str,
    n: usize,
    base_seed: u64,
    generate: impl Fn(&mut Gen) -> T,
    prop: impl Fn(&T) -> Result<(), String>,
) {
    let run_case = |seed: u64, size: usize| -> Option<(T, String)> {
        let mut g = Gen { rng: XorShiftRng::new(seed), size };
        let case = generate(&mut g);
        match prop(&case) {
            Ok(()) => None,
            Err(msg) => Some((case, msg)),
        }
    };

    for i in 0..n {
        let seed = base_seed.wrapping_add(i as u64 * 0x9E37_79B9);
        if let Some((case, msg)) = run_case(seed, 100) {
            // Shrink: retry the same seed at smaller sizes, keep the
            // smallest size that still fails.
            let mut smallest: (usize, T, String) = (100, case, msg);
            for size in [50usize, 25, 10, 5] {
                if let Some((c, m)) = run_case(seed, size) {
                    smallest = (size, c, m);
                }
            }
            panic!(
                "property '{name}' failed (seed={seed}, size={}):\n  case: {:?}\n  error: {}",
                smallest.0, smallest.1, smallest.2
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        check(
            "add-commutes",
            50,
            1,
            |g| (g.f32_in(-10.0, 10.0), g.f32_in(-10.0, 10.0)),
            |(a, b)| {
                if a + b == b + a {
                    Ok(())
                } else {
                    Err("non-commutative".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "always-fails")]
    fn failing_property_panics_with_seed() {
        check("always-fails", 5, 2, |g| g.usize_in(0, 10), |_| Err("always-fails".into()));
    }

    #[test]
    fn pow2_in_range() {
        let mut g = Gen { rng: XorShiftRng::new(3), size: 100 };
        for _ in 0..100 {
            let v = g.pow2_in(4, 64);
            assert!(v.is_power_of_two() && (4..=64).contains(&v));
        }
    }

    #[test]
    fn shrinking_reduces_size_bound() {
        let mut big = Gen { rng: XorShiftRng::new(7), size: 100 };
        let mut small = Gen { rng: XorShiftRng::new(7), size: 5 };
        // At size 5, usize_in(0, 100) can produce at most 5.
        for _ in 0..50 {
            assert!(small.usize_in(0, 100) <= 5);
            let _ = big.usize_in(0, 100);
        }
    }
}
