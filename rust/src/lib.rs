//! # STaMP — Sequence Transformation and Mixed Precision
//!
//! Full-stack reproduction of *"STaMP: Sequence Transformation and Mixed
//! Precision for Low-Precision Activation Quantization"* (Federici et al.,
//! 2025): a post-training activation-quantization technique that applies an
//! orthogonal transform **along the sequence dimension** to concentrate
//! token energy into a few coefficients, then quantizes those at higher
//! precision (8b) and the rest at low precision (4b).
//!
//! The crate is organised in three layers:
//!
//! * **Substrates** — [`tensor`], [`linalg`], [`stats`], [`parallel`]:
//!   dense f32 math with row-parallel hot kernels — including the integer
//!   GEMM [`tensor::qgemm`] over bit-packed [`quant::QTensor`] operands —
//!   a Jacobi eigensolver (for the KLT), autocorrelation estimation, and
//!   the scoped fork-join layer (`STAMP_THREADS` override) the kernels and
//!   the coordinator share.
//! * **Core library** — [`transforms`] (KLT / DCT / WHT / Haar-DWT sequence
//!   transforms and Hadamard / SmoothQuant / FlatQuant feature transforms),
//!   [`quant`] (per-token / per-block quantizers, mixed-precision bit
//!   allocation, the Theorem-1 error bound), [`baselines`] (RTN,
//!   SmoothQuant, QuaRot, ViDiT-Q SDCB, SVDQuant, FlatQuant-lite),
//!   [`model`] (tiny GPT / DiT with quantization hook points), [`eval`]
//!   (perplexity, SQNR, the paper's table harnesses).
//! * **Runtime** — [`runtime`] (the always-available pure-Rust
//!   `NativeExecutor`, plus — behind the `pjrt` cargo feature — the PJRT
//!   client that loads AOT-lowered HLO text produced by
//!   `python/compile/aot.py`), [`kvcache`] (the STaMP-aware quantized KV
//!   cache behind `Gpt::prefill`/`Gpt::decode_step` autoregressive
//!   generation), [`decode`] (the step-synchronized batched decode engine
//!   that fuses concurrent generation streams into one GEMM per linear
//!   per step, with greedy or temperature/top-k sampling),
//!   [`coordinator`] (request router, dynamic batcher, worker pools,
//!   metrics) so quantized variants can be *served*, not just evaluated,
//!   and [`obs`] (log2 latency histograms with Prometheus/JSON
//!   exposition, per-stream trace timelines, opt-in kernel profiling).
//!
//! Python/JAX/Pallas exists only on the compile path (`python/compile/`);
//! the request path is pure Rust (+ PJRT when the `pjrt` feature is on).
//! Default builds have **zero external dependencies** — see README.md for
//! the feature matrix and DESIGN.md §3 for the stand-in policy.

// CI lints with `clippy -- -D warnings` (.github/workflows/ci.yml). The
// hand-rolled substrate code (DESIGN.md §3) deliberately uses explicit
// index arithmetic mirroring the paper's notation, and several types keep
// argument-taking constructors without a meaningful `Default`.
#![allow(
    clippy::needless_range_loop,
    clippy::new_without_default,
    clippy::too_many_arguments
)]

pub mod baselines;
pub mod bench;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod decode;
pub mod error;
pub mod eval;
pub mod kvcache;
pub mod linalg;
pub mod model;
pub mod obs;
pub mod parallel;
pub mod quant;
pub mod report;
pub mod runtime;
pub mod stamp;
pub mod stats;
pub mod tensor;
pub mod testkit;
pub mod train;
pub mod transforms;

/// Convenience re-exports for downstream users and the examples.
pub mod prelude {
    pub use crate::decode::{DecodeEngine, GenRequest, Sampling, StreamId, StreamResult};
    pub use crate::kvcache::{BlockPool, EvictionPolicy, KvCache, KvCacheConfig};
    pub use crate::quant::{BitAllocation, Granularity, QTensor, QuantScheme, Quantizer};
    pub use crate::stamp::{SeqTransformKind, Stamp, StampConfig};
    pub use crate::stats::sqnr;
    pub use crate::tensor::{qgemm, qgemm_scalar, Tensor};
    pub use crate::transforms::{FeatureTransform, SequenceTransform};
}
