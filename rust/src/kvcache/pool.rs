//! Paged KV block pool — reference-counted, immutable finalized blocks
//! shared across streams, plus the token-ID prefix index that lets the
//! decode engine skip prefill over a pooled prompt prefix (DESIGN.md §15).
//!
//! ## Why a pool
//!
//! STaMP's cache already stores history as *immutable* finalized blocks
//! (the flush rule re-represents a token exactly once, and a block's
//! representation depends only on its absolute base position and the
//! cache config — see [`super::KvStream`]). That is precisely the
//! representation paged attention wants: under production traffic, N
//! concurrent streams overwhelmingly share a common prompt prefix
//! (system prompts, few-shot templates), so the prefix blocks can be
//! stored *once* and every stream can hold a cheap handle. Streams fork
//! copy-on-write at the divergence point: the fp32 tail window is always
//! private to its stream, and a stream never mutates a finalized block —
//! divergence simply appends new private tail rows and flushes new
//! private blocks, while the shared prefix handles stay untouched.
//!
//! ## Refcounts vs. eviction
//!
//! Handles are explicit refcounts on pool slots: [`BlockHandle::clone`]
//! retains, dropping releases, and the pool frees the slot only at zero.
//! Sliding-window eviction ([`super::EvictionPolicy::SlidingWindow`])
//! drops a *handle* from one stream's resident window — the physical
//! block survives as long as any other stream (or the prefix index)
//! still references it, so eviction can never free memory another
//! stream is reading.
//!
//! ## The prefix index
//!
//! [`BlockPool::register_prefix`] records, for a block-aligned run of
//! prompt token IDs, the per-layer K/V block handles that store it.
//! [`BlockPool::lookup_prefix`] hashes block-aligned prefixes of a new
//! prompt from the longest candidate down and — after an exact token
//! comparison, so hash collisions are harmless — returns freshly
//! retained handles for the longest hit. The candidate span is capped at
//! `prompt.len() − 1` rounded down to a block: the final prompt token is
//! always prefilled by the engine so it produces the logits that sample
//! the first generated token. Registered entries are owned by the pool
//! and hold one reference per block, pinning the prefix resident for the
//! pool's lifetime (an engine-owned pool lives as long as its variant).

use crate::quant::QTensor;
use crate::tensor::Tensor;
use std::collections::HashMap;
use std::sync::{Arc, Mutex, MutexGuard, PoisonError, Weak};

/// One (K, V) pair of block-handle runs per model layer — the payload of
/// a [`PrefixEntry`] / [`PrefixHit`], outer index = layer.
pub type LayerHandles = Vec<(Vec<BlockHandle>, Vec<BlockHandle>)>;

/// The immutable payload of one finalized block: the flush-time fp32
/// view every gather reads, plus the bit-packed representation for
/// packed streams (`None` for finalized fp32 blocks).
pub struct BlockData {
    view: Tensor,
    packed: Option<QTensor>,
}

impl BlockData {
    /// Flush-time dequantized (+ inverse-transformed) fp32 view — what
    /// [`super::KvStream::gather`] copies for these tokens.
    pub fn view(&self) -> &Tensor {
        &self.view
    }

    /// Bit-packed representation (`None` for finalized fp32 blocks).
    pub fn packed(&self) -> Option<&QTensor> {
        self.packed.as_ref()
    }

    /// Stored footprint in bits: the packed payload + per-group params
    /// when packed ([`QTensor::storage_bits`]), else 32 bits/element of
    /// the fp32 view. Matches the per-stream accounting of
    /// [`super::KvStream::storage_bits`] exactly, so shared/private
    /// splits stay additive.
    pub fn bits(&self) -> usize {
        match &self.packed {
            Some(q) => q.storage_bits(),
            None => self.view.len() * 32,
        }
    }
}

/// A refcounted reference to one pooled block. Cloning retains the pool
/// slot, dropping releases it; the payload is reachable lock-free via
/// [`BlockHandle::data`] so the decode hot path (gather) never touches
/// the pool mutex.
pub struct BlockHandle {
    /// Weak so pool-owned prefix entries (which hold handles) do not form
    /// a strong cycle; a handle outliving its pool degrades to a plain
    /// owner of the payload `Arc`.
    pool: Weak<BlockPool>,
    idx: usize,
    data: Arc<BlockData>,
}

impl BlockHandle {
    pub fn data(&self) -> &BlockData {
        &self.data
    }

    /// Shorthand for [`BlockData::view`].
    pub fn view(&self) -> &Tensor {
        &self.data.view
    }

    /// Shorthand for [`BlockData::bits`].
    pub fn bits(&self) -> usize {
        self.data.bits()
    }

    /// The pool slot index this handle retains (stable for the block's
    /// lifetime; slots are recycled only after the refcount hits zero).
    pub fn slot(&self) -> usize {
        self.idx
    }

    /// Current pool refcount of the underlying block — ≥ 1 while this
    /// handle is alive (0 only if the owning pool itself is gone). A
    /// block with `refs() ≥ 2` is physically shared.
    pub fn refs(&self) -> usize {
        match self.pool.upgrade() {
            Some(pool) => {
                let inner = pool.lock();
                inner.slots[self.idx].as_ref().map_or(0, |e| e.refs)
            }
            None => 0,
        }
    }

    /// Whether another handle (a different stream, or the prefix index)
    /// currently references the same physical block.
    pub fn is_shared(&self) -> bool {
        self.refs() >= 2
    }
}

impl Clone for BlockHandle {
    fn clone(&self) -> Self {
        if let Some(pool) = self.pool.upgrade() {
            let mut inner = pool.lock();
            if let Some(e) = inner.slots[self.idx].as_mut() {
                e.refs += 1;
            }
        }
        BlockHandle { pool: self.pool.clone(), idx: self.idx, data: self.data.clone() }
    }
}

impl Drop for BlockHandle {
    fn drop(&mut self) {
        if let Some(pool) = self.pool.upgrade() {
            let mut inner = pool.lock();
            if let Some(e) = inner.slots[self.idx].as_mut() {
                assert!(e.refs > 0, "kv block pool refcount underflow (slot {})", self.idx);
                e.refs -= 1;
                if e.refs == 0 {
                    inner.slots[self.idx] = None;
                    inner.free.push(self.idx);
                }
            }
        }
    }
}

impl std::fmt::Debug for BlockHandle {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("BlockHandle")
            .field("slot", &self.idx)
            .field("rows", &self.data.view.rows())
            .field("bits", &self.data.bits())
            .finish()
    }
}

/// One registered prompt prefix: the exact token IDs (compared verbatim
/// at lookup, so the hash index can never alias two prompts) and the
/// per-layer K/V handles storing them. Owned by the pool once
/// registered; holds one reference per block.
pub struct PrefixEntry {
    tokens: Vec<u32>,
    layers: LayerHandles,
}

impl PrefixEntry {
    /// `tokens` must be the block-aligned prompt prefix the handles
    /// store; every layer must contribute the same number of K and V
    /// blocks. (The pool does not know the block size — entries whose
    /// length is not a multiple of the lookup block simply never match.)
    pub fn new(tokens: Vec<u32>, layers: LayerHandles) -> Self {
        assert!(!tokens.is_empty(), "prefix entries need at least one token");
        assert!(!layers.is_empty(), "prefix entries need at least one layer");
        let n = layers[0].0.len();
        assert!(n >= 1, "prefix entries need at least one block per stream");
        for (k, v) in &layers {
            assert_eq!(k.len(), n, "ragged K handle runs in prefix entry");
            assert_eq!(v.len(), n, "ragged V handle runs in prefix entry");
        }
        PrefixEntry { tokens, layers }
    }

    pub fn tokens(&self) -> &[u32] {
        &self.tokens
    }
}

/// A successful [`BlockPool::lookup_prefix`]: freshly retained handles
/// covering the first `span` prompt tokens, ready to seed a new cache
/// via [`super::KvCache::seed_prefix`].
pub struct PrefixHit {
    /// Shared tokens (block-aligned, always < the prompt length).
    pub span: usize,
    /// Per-layer (K, V) handle runs covering `span` tokens.
    pub layers: LayerHandles,
}

/// Consistent snapshot of a pool's diagnostics counters, taken under a
/// single lock acquisition by [`BlockPool::stats`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PoolStats {
    /// Live (refcounted) blocks right now.
    pub live_blocks: usize,
    /// Sum of all slot refcounts (handles + prefix-entry references).
    pub total_refs: usize,
    /// Physical resident footprint in bits (each block counted once).
    pub resident_bits: usize,
    /// Registered prefix entries.
    pub prefix_entries: usize,
}

struct PoolEntry {
    refs: usize,
    data: Arc<BlockData>,
}

struct PoolInner {
    /// Slot-indexed block table; `None` = free slot awaiting reuse.
    slots: Vec<Option<PoolEntry>>,
    free: Vec<usize>,
    /// Prefix index: token-hash → entries (exact tokens disambiguate).
    prefix: HashMap<u64, Vec<PrefixEntry>>,
}

/// The process-wide paged block pool (module docs). One pool per decode
/// engine — and therefore one per generate variant — so every stream of
/// a variant allocates its finalized blocks here and common prompt
/// prefixes are stored once.
pub struct BlockPool {
    /// Self-reference so `&self` methods can mint handles
    /// (`Arc::new_cyclic` wires it at construction).
    me: Weak<BlockPool>,
    inner: Mutex<PoolInner>,
}

impl BlockPool {
    pub fn new() -> Arc<BlockPool> {
        Arc::new_cyclic(|me| BlockPool {
            me: me.clone(),
            inner: Mutex::new(PoolInner {
                slots: Vec::new(),
                free: Vec::new(),
                prefix: HashMap::new(),
            }),
        })
    }

    /// Refcount bookkeeping must survive a panicking appender: recover
    /// the guard from poisoning instead of cascading during unwind.
    fn lock(&self) -> MutexGuard<'_, PoolInner> {
        self.inner.lock().unwrap_or_else(PoisonError::into_inner)
    }

    /// Take ownership of a freshly finalized block and return the first
    /// handle to it (refcount 1).
    pub fn insert(&self, view: Tensor, packed: Option<QTensor>) -> BlockHandle {
        let data = Arc::new(BlockData { view, packed });
        let mut inner = self.lock();
        let idx = match inner.free.pop() {
            Some(i) => {
                debug_assert!(inner.slots[i].is_none(), "free list pointed at a live slot");
                inner.slots[i] = Some(PoolEntry { refs: 1, data: data.clone() });
                i
            }
            None => {
                inner.slots.push(Some(PoolEntry { refs: 1, data: data.clone() }));
                inner.slots.len() - 1
            }
        };
        drop(inner);
        BlockHandle { pool: self.me.clone(), idx, data }
    }

    /// Live (refcounted) blocks right now.
    pub fn live_blocks(&self) -> usize {
        self.lock().slots.iter().flatten().count()
    }

    /// Sum of all slot refcounts — equals the number of live handles
    /// plus one per block-reference held by registered prefix entries.
    pub fn total_refs(&self) -> usize {
        self.lock().slots.iter().flatten().map(|e| e.refs).sum()
    }

    /// *Physical* resident footprint: every live block counted exactly
    /// once, regardless of how many streams hold it. Compare with the sum
    /// of per-stream [`super::KvStream::storage_bits`] (which counts a
    /// shared block once per stream) to see the prefix-reuse win.
    pub fn resident_bits(&self) -> usize {
        self.lock().slots.iter().flatten().map(|e| e.data.bits()).sum()
    }

    /// Registered prefix entries (diagnostics).
    pub fn prefix_entries(&self) -> usize {
        self.lock().prefix.values().map(Vec::len).sum()
    }

    /// One-lock-acquisition snapshot of the diagnostics counters above,
    /// for observability surfaces (the traced `generate` example prints
    /// one; polling the individual accessors would take the pool lock
    /// once per field and could interleave with mutations).
    pub fn stats(&self) -> PoolStats {
        let inner = self.lock();
        let mut live_blocks = 0usize;
        let mut total_refs = 0usize;
        let mut resident_bits = 0usize;
        for e in inner.slots.iter().flatten() {
            live_blocks += 1;
            total_refs += e.refs;
            resident_bits += e.data.bits();
        }
        PoolStats {
            live_blocks,
            total_refs,
            resident_bits,
            prefix_entries: inner.prefix.values().map(Vec::len).sum(),
        }
    }

    /// Install (or refresh) a prefix entry. Re-registering the same token
    /// run replaces the old entry — the stale entry's handles are
    /// released *outside* the pool lock (handle drops re-enter the pool).
    pub fn register_prefix(&self, entry: PrefixEntry) {
        let h = hash_tokens(&entry.tokens);
        let stale;
        {
            let mut inner = self.lock();
            let bucket = inner.prefix.entry(h).or_default();
            match bucket.iter().position(|e| e.tokens == entry.tokens) {
                Some(p) => stale = Some(std::mem::replace(&mut bucket[p], entry)),
                None => {
                    bucket.push(entry);
                    stale = None;
                }
            }
        }
        drop(stale);
    }

    /// Longest registered block-aligned strict prefix of `prompt`,
    /// walking candidate spans from `((prompt.len() − 1) / block) · block`
    /// down in `block` steps. The final prompt token is never part of a
    /// hit — the engine must prefill it to obtain sampling logits.
    /// Returned handles are freshly retained inside a single lock
    /// acquisition (no per-handle locking).
    pub fn lookup_prefix(&self, prompt: &[u32], block: usize) -> Option<PrefixHit> {
        if block == 0 || prompt.len() <= 1 {
            return None;
        }
        let mut inner = self.lock();
        let PoolInner { slots, prefix, .. } = &mut *inner;
        let mut span = ((prompt.len() - 1) / block) * block;
        while span >= block {
            let h = hash_tokens(&prompt[..span]);
            let entry = prefix
                .get(&h)
                .and_then(|bucket| bucket.iter().find(|e| e.tokens[..] == prompt[..span]));
            if let Some(entry) = entry {
                let layers = entry
                    .layers
                    .iter()
                    .map(|(k, v)| (retain_run(slots, &self.me, k), retain_run(slots, &self.me, v)))
                    .collect();
                return Some(PrefixHit { span, layers });
            }
            span -= block;
        }
        None
    }
}

/// Mint retained copies of a handle run with the pool lock already held
/// (calling [`BlockHandle::clone`] here would deadlock on re-entry).
fn retain_run(
    slots: &mut [Option<PoolEntry>],
    me: &Weak<BlockPool>,
    run: &[BlockHandle],
) -> Vec<BlockHandle> {
    run.iter()
        .map(|h| {
            let e = slots[h.idx].as_mut().expect("prefix entry references a live block");
            e.refs += 1;
            BlockHandle { pool: me.clone(), idx: h.idx, data: h.data.clone() }
        })
        .collect()
}

/// FNV-1a over the little-endian token bytes — stable across platforms;
/// collisions are harmless (exact token comparison disambiguates).
fn hash_tokens(tokens: &[u32]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &t in tokens {
        for b in t.to_le_bytes() {
            h = (h ^ u64::from(b)).wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    fn blk(rows: usize, cols: usize) -> Tensor {
        Tensor::zeros(&[rows, cols])
    }

    #[test]
    fn handle_lifecycle_retains_releases_and_recycles_slots() {
        let pool = BlockPool::new();
        let a = pool.insert(blk(2, 3), None);
        assert_eq!((a.refs(), pool.live_blocks()), (1, 1));
        assert_eq!(pool.resident_bits(), 2 * 3 * 32);
        let b = a.clone();
        assert_eq!((a.refs(), b.refs(), pool.live_blocks()), (2, 2, 1));
        assert!(a.is_shared());
        drop(a);
        assert_eq!((b.refs(), pool.live_blocks()), (1, 1));
        assert!(!b.is_shared());
        let slot = b.slot();
        drop(b);
        assert_eq!((pool.live_blocks(), pool.resident_bits()), (0, 0));
        // Freed slots are recycled, not leaked.
        let c = pool.insert(blk(1, 1), None);
        assert_eq!(c.slot(), slot);
    }

    #[test]
    fn prefix_index_pins_blocks_walks_down_and_verifies_tokens() {
        let pool = BlockPool::new();
        let h = pool.insert(blk(4, 2), None);
        pool.register_prefix(PrefixEntry::new(
            vec![1, 2, 3, 4],
            vec![(vec![h.clone()], vec![h.clone()])],
        ));
        // handle + K ref + V ref
        assert_eq!(h.refs(), 3);
        assert_eq!(pool.prefix_entries(), 1);
        drop(h);
        assert_eq!(pool.live_blocks(), 1, "the index pins the block resident");

        // Exact aligned hit: span covers the first block, handles retained.
        let hit = pool.lookup_prefix(&[1, 2, 3, 4, 9], 4).expect("aligned prefix must hit");
        assert_eq!(hit.span, 4);
        assert_eq!(hit.layers.len(), 1);
        assert_eq!(hit.layers[0].0[0].refs(), 4, "lookup retains K and V");
        // A whole-prompt match is never returned: the last token must be
        // prefilled for sampling logits.
        assert!(pool.lookup_prefix(&[1, 2, 3, 4], 4).is_none());
        // The hash is verified against exact tokens.
        assert!(pool.lookup_prefix(&[1, 2, 9, 4, 9], 4).is_none());
        // Walk-down: an 9-token prompt misses at span 8, hits at span 4.
        let hit2 = pool.lookup_prefix(&[1, 2, 3, 4, 5, 6, 7, 8, 9], 4).unwrap();
        assert_eq!(hit2.span, 4);
    }

    #[test]
    fn reregistering_a_prefix_replaces_the_entry_without_leaking_refs() {
        let pool = BlockPool::new();
        let h = pool.insert(blk(4, 2), None);
        let mk = || PrefixEntry::new(vec![7, 7, 7, 7], vec![(vec![h.clone()], vec![h.clone()])]);
        pool.register_prefix(mk());
        pool.register_prefix(mk());
        assert_eq!(pool.prefix_entries(), 1, "same tokens replace, not duplicate");
        assert_eq!(h.refs(), 3, "stale entry's references were released");
    }

    #[test]
    fn stats_snapshot_matches_individual_accessors() {
        let pool = BlockPool::new();
        let a = pool.insert(blk(4, 2), None);
        let _b = a.clone();
        pool.register_prefix(PrefixEntry::new(
            vec![1, 2, 3, 4],
            vec![(vec![a.clone()], vec![a.clone()])],
        ));
        let st = pool.stats();
        assert_eq!(st.live_blocks, pool.live_blocks());
        assert_eq!(st.total_refs, pool.total_refs());
        assert_eq!(st.resident_bits, pool.resident_bits());
        assert_eq!(st.prefix_entries, pool.prefix_entries());
        assert_eq!(st.live_blocks, 1);
        assert_eq!(st.total_refs, 4, "two handles + K ref + V ref");
    }

    #[test]
    fn refcounts_never_underflow_under_random_interleavings() {
        // Satellite property test: random admit (insert) / share (clone) /
        // evict (drop one handle) / retire (drop a whole stream)
        // interleavings across 4 simulated streams. The release path
        // asserts on underflow, so surviving the schedule *is* the
        // property; on top we pin conservation: total refs == held
        // handles, live blocks == distinct held slots, and an emptied
        // pool frees everything.
        crate::testkit::check(
            "pool refcount interleavings",
            60,
            0xB10C,
            |g| {
                let n = g.usize_in(1, 40);
                (0..n)
                    .map(|_| (g.usize_in(0, 3), g.usize_in(0, 7), g.usize_in(0, 7)))
                    .collect::<Vec<_>>()
            },
            |ops| {
                let pool = BlockPool::new();
                let mut streams: Vec<Vec<BlockHandle>> = (0..4).map(|_| Vec::new()).collect();
                for &(op, a, b) in ops {
                    match op {
                        0 => streams[a % 4].push(pool.insert(blk(2, 3), None)),
                        1 => {
                            let src = &streams[a % 4];
                            let h = (!src.is_empty()).then(|| src[b % src.len()].clone());
                            if let Some(h) = h {
                                streams[b % 4].push(h);
                            }
                        }
                        2 => {
                            let s = &mut streams[a % 4];
                            if !s.is_empty() {
                                s.remove(0);
                            }
                        }
                        _ => streams[a % 4].clear(),
                    }
                    let held: usize = streams.iter().map(Vec::len).sum();
                    if pool.total_refs() != held {
                        return Err(format!(
                            "refs {} != held handles {held}",
                            pool.total_refs()
                        ));
                    }
                    let distinct: std::collections::HashSet<usize> =
                        streams.iter().flatten().map(BlockHandle::slot).collect();
                    if pool.live_blocks() != distinct.len() {
                        return Err(format!(
                            "live {} != distinct held slots {}",
                            pool.live_blocks(),
                            distinct.len()
                        ));
                    }
                    for h in streams.iter().flatten() {
                        if h.refs() == 0 {
                            return Err("live handle with zero refcount".into());
                        }
                    }
                }
                streams.clear();
                if pool.live_blocks() != 0 || pool.resident_bits() != 0 {
                    return Err("pool leaked blocks after all handles dropped".into());
                }
                Ok(())
            },
        );
    }
}
