//! STaMP-aware quantized KV cache — the sequence-incremental consumer of
//! [`crate::quant::BitAllocation`] + [`crate::quant::QTensor`] that lets
//! the paper's two-level mixed-precision policy (§3.3, Theorem 1) run
//! where autoregressive serving actually spends its memory.
//!
//! ## Layout (DESIGN.md §11)
//!
//! Each transformer layer owns one [`KvStream`] per K/V tensor. A stream
//! is a sequence of finalized packed blocks followed by an fp32 tail:
//!
//! ```text
//! [ packed block 0 | packed block 1 | … | fp32 tail (< block tokens) ]
//! ```
//!
//! * **Packed blocks** — `block` consecutive tokens, optionally passed
//!   through a block-wise sequence transform (`L` over the block's rows),
//!   quantized per token into a bit-packed [`QTensor`]. Bit widths follow
//!   the global two-level policy: rows overlapping the first `hp_tokens`
//!   (attention-sink) positions store at `hp_bits`, steady-state rows at
//!   `lp_bits`. For transformed blocks the hp rows are the *leading*
//!   coefficients — which every shipped transform orders by energy — so
//!   the storage accounting is identical either way.
//! * **fp32 tail** — the most recent `len mod block` tokens, kept exact
//!   until a full block accumulates.
//!
//! ## The tail-window flush rule keeps block transforms causal
//!
//! A sequence transform mixes tokens, so applying it across the whole
//! stream at every decode step would make a token's stored representation
//! depend on *future* tokens. The flush rule restores causality: a token
//! is re-represented exactly once — when its block completes — and the
//! transform mixes only the tokens of that (entirely past) block.
//! Appending token `t` therefore never alters any block that does not
//! contain `t`, and attention at step `t` reads only data derived from
//! tokens `≤ t`.
//!
//! With `packed = false` the stream stores plain fp32 rows and
//! [`KvStream::gather`] returns exactly what was appended — the parity
//! reference under which decode is bit-identical to the full-sequence
//! forward at any thread count (`tests/decode.rs`).
//!
//! ## Sliding-window eviction (DESIGN.md §13)
//!
//! An [`EvictionPolicy::SlidingWindow`] turns the stream into a bounded-
//! residency window over an unbounded logical sequence: the first
//! `sink_tokens` positions (rounded up to whole blocks — exactly the
//! hp-tokens of the two-level policy) are retained permanently, and a
//! finalized block is dropped from the front of the recent region once it
//! has slid entirely out of the last `window` tokens. Only *finalized*
//! blocks are ever evicted — the fp32 tail is always the newest `< block`
//! tokens, strictly inside the window (`window ≥ block` is validated), so
//! a token can never be evicted before it has been flushed. The resident
//! set is therefore always `sinks ∪ last-window` at block granularity,
//! the eviction gap is one contiguous run starting at the sink boundary,
//! and [`KvStream::gather`] returns the `[sinks ‖ recent]` rows while
//! [`KvStream::gap_row`] / [`KvStream::evicted`] recover every resident
//! row's *absolute* position for causal masking
//! ([`crate::model::attention::MultiHeadAttention::forward_decode`]).
//! Because a block's quantized representation depends only on its
//! absolute base position, evicting the past never re-represents what
//! remains: resident rows stay bit-identical to an unevicted reference
//! stream (`tests/eviction.rs` pins it property-style).
//!
//! ## Paged block pool and prefix sharing (DESIGN.md §15)
//!
//! Finalized blocks are immutable and position-determined, so they are
//! owned by a refcounted [`BlockPool`] and streams hold [`BlockHandle`]s
//! instead of block payloads. Streams of one decode engine share one
//! pool: N streams with a common prompt prefix reference the *same*
//! physical prefix blocks (found through the pool's token-ID prefix
//! index) and fork copy-on-write at the divergence point — the fp32
//! tail is always private, and divergence only ever appends new private
//! blocks. Eviction composes with sharing because dropping a handle
//! releases a reference; the pool frees a block only when no stream
//! (and no prefix-index entry) still holds it. See [`pool`] for the
//! layout and [`KvCache::seed_prefix`] for the fork entry point.

use crate::quant::{BitAllocation, Granularity, QTensor};
use crate::stamp::SeqTransformKind;
use crate::tensor::Tensor;
use crate::transforms::{DctTransform, HaarDwt, SequenceTransform, WhtTransform};
use std::sync::Arc;

pub mod pool;

pub use pool::{BlockData, BlockHandle, BlockPool, LayerHandles, PoolStats, PrefixEntry, PrefixHit};

/// When (and what) a stream evicts (module docs, DESIGN.md §13).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EvictionPolicy {
    /// Never evict: the stream grows until [`KvCacheConfig::max_seq`]
    /// (all pre-eviction behavior, and the default).
    None,
    /// Permanently retain the first `sink_tokens` positions (rounded up
    /// to whole blocks) and keep at least the last `window` tokens
    /// resident, evicting older finalized blocks from the front of the
    /// recent region. Residency is bounded by
    /// [`KvCacheConfig::resident_bound`] while the logical sequence grows
    /// without limit — the attention-sink recipe (StreamingLLM, cf.
    /// PAPERS.md) mapped onto the paper's two-level token policy.
    SlidingWindow { sink_tokens: usize, window: usize },
}

/// Two-level token policy + block layout for one KV cache
/// (the `[generate]` config section's `kv.*` keys,
/// [`crate::config::GenerateSpec`]).
#[derive(Clone, Debug)]
pub struct KvCacheConfig {
    /// Leading (attention-sink) token positions stored at `hp_bits`.
    pub hp_tokens: usize,
    pub hp_bits: u32,
    /// Steady-state width (the "KV4" of the tables).
    pub lp_bits: u32,
    /// Tokens per packed block — also the span of the block-wise sequence
    /// transform. The fp32 tail holds at most `block − 1` tokens.
    pub block: usize,
    /// `false` keeps every token fp32 (the parity/reference cache); the
    /// remaining fields are then ignored.
    pub packed: bool,
    /// Block-wise sequence transform applied before quantization
    /// (`Identity` = plain two-level rows). 2-D kinds are rejected:
    /// decode streams are 1-D.
    pub transform: SeqTransformKind,
    /// Optional token capacity. `None` (the default) keeps the pre-PR-4
    /// behavior: the stream grows unboundedly and it is the *caller's* job
    /// to respect the model's `max_seq`. With `Some(cap)`,
    /// [`KvStream::try_append`] refuses — recoverably — to grow past `cap`
    /// tokens, so a decode engine can retire the stream with a truncation
    /// flag instead of panicking mid-batch. The cap bounds the *logical*
    /// length: sequences that should outlive any cap use an
    /// [`EvictionPolicy::SlidingWindow`] instead (which bounds residency,
    /// not length — the serving layer then leaves this `None`).
    pub max_seq: Option<usize>,
    /// Memory-management policy. [`EvictionPolicy::SlidingWindow`] keeps
    /// residency bounded so streams can decode indefinitely past any
    /// positional budget; [`EvictionPolicy::None`] (the default) keeps
    /// every appended token.
    pub eviction: EvictionPolicy,
    /// Opt into prompt-prefix sharing (the `[generate] kv.prefix_cache`
    /// knob). When set, the decode engine looks completed prompts up in
    /// its [`BlockPool`] prefix index at admission and seeds new streams
    /// from pooled blocks instead of re-running prefill over the shared
    /// span. Also forces *fp32* streams to finalize full blocks (exact
    /// rows move into immutable block views — lossless) so an fp32
    /// cache has shareable block granularity too. Default `false`.
    pub prefix_cache: bool,
}

impl Default for KvCacheConfig {
    /// The paper's main KV setting: 64 sink tokens at 8 bits, KV4
    /// steady-state, 32-token blocks, no block transform.
    fn default() -> Self {
        KvCacheConfig {
            hp_tokens: 64,
            hp_bits: 8,
            lp_bits: 4,
            block: 32,
            packed: true,
            transform: SeqTransformKind::Identity,
            max_seq: None,
            eviction: EvictionPolicy::None,
            prefix_cache: false,
        }
    }
}

impl KvCacheConfig {
    /// The fp32 reference cache (no quantization at all).
    pub fn fp32() -> Self {
        KvCacheConfig { packed: false, ..Default::default() }
    }

    /// Packed two-level cache with the given allocation and block size.
    pub fn two_level(hp_tokens: usize, hp_bits: u32, lp_bits: u32, block: usize) -> Self {
        KvCacheConfig { hp_tokens, hp_bits, lp_bits, block, ..Default::default() }
    }

    /// Builder-style block transform selection.
    pub fn with_transform(mut self, kind: SeqTransformKind) -> Self {
        self.transform = kind;
        self
    }

    /// Builder-style token capacity (see [`KvCacheConfig::max_seq`]).
    pub fn with_max_seq(mut self, cap: usize) -> Self {
        self.max_seq = Some(cap);
        self
    }

    /// Builder-style sliding-window eviction policy (module docs).
    pub fn with_window(mut self, sink_tokens: usize, window: usize) -> Self {
        self.eviction = EvictionPolicy::SlidingWindow { sink_tokens, window };
        self
    }

    /// Builder-style prompt-prefix sharing
    /// (see [`KvCacheConfig::prefix_cache`]).
    pub fn with_prefix_cache(mut self) -> Self {
        self.prefix_cache = true;
        self
    }

    /// Upper bound on tokens resident at any instant, `None` when nothing
    /// evicts. Under a sliding window the resident set is the block-rounded
    /// sink span plus fewer than `window + block` recent tokens, and the
    /// *next* token joins at that rank — so a positional table of
    /// `resident_bound()` entries always suffices
    /// ([`crate::decode::DecodeEngine`] validates it against the model).
    pub fn resident_bound(&self) -> Option<usize> {
        match self.eviction {
            EvictionPolicy::None => None,
            EvictionPolicy::SlidingWindow { sink_tokens, window } => {
                Some(sink_tokens.div_ceil(self.block) * self.block + window + self.block)
            }
        }
    }

    /// Field-specific error when the packed lanes or block transforms
    /// cannot express this configuration; always `Ok` for fp32 caches.
    /// The config layer ([`crate::config::GenerateSpec::kv_cfg`]) surfaces
    /// this as a recoverable parse-time error.
    pub fn check(&self) -> Result<(), String> {
        if let EvictionPolicy::SlidingWindow { sink_tokens, window } = self.eviction {
            if self.block == 0 {
                return Err("kv.block must be ≥ 1".into());
            }
            if window < self.block {
                return Err(format!(
                    "kv.window ({window}) must be ≥ kv.block ({}) so the fp32 tail and the \
                     newest finalized block always stay resident",
                    self.block
                ));
            }
            if self.packed && sink_tokens > self.hp_tokens {
                return Err(format!(
                    "kv.sink_tokens ({sink_tokens}) must be ≤ kv.hp_tokens ({}) — the \
                     permanently retained sinks are the hp tokens of the two-level policy",
                    self.hp_tokens
                ));
            }
        }
        if !self.packed {
            return Ok(());
        }
        if self.block == 0 {
            return Err("kv.block must be ≥ 1".into());
        }
        if self.lp_bits != 4 && self.lp_bits != 8 {
            return Err(format!("packed kv lanes are 4- or 8-bit, got lp_bits = {}", self.lp_bits));
        }
        if self.hp_tokens > 0 && self.hp_bits != 4 && self.hp_bits != 8 {
            return Err(format!("packed kv lanes are 4- or 8-bit, got hp_bits = {}", self.hp_bits));
        }
        match self.transform {
            SeqTransformKind::Identity | SeqTransformKind::Dct => Ok(()),
            SeqTransformKind::HaarDwt if self.block % 2 != 0 => {
                Err(format!("HaarDwt kv blocks need an even block size, got {}", self.block))
            }
            SeqTransformKind::Wht if !self.block.is_power_of_two() => {
                Err(format!("WHT kv blocks need a power-of-two block size, got {}", self.block))
            }
            SeqTransformKind::HaarDwt2d { .. } => {
                Err("2-D sequence transforms do not apply to 1-D decode streams".into())
            }
            _ => Ok(()),
        }
    }

    /// Panicking form of [`KvCacheConfig::check`], for construction sites
    /// where an invalid config is a programming error.
    pub fn validate(&self) {
        if let Err(e) = self.check() {
            panic!("{e}");
        }
    }

    /// The block-wise transform instance (`None` for identity / fp32).
    fn block_transform(&self) -> Option<Box<dyn SequenceTransform>> {
        if !self.packed {
            return None;
        }
        match self.transform {
            SeqTransformKind::Identity => None,
            SeqTransformKind::HaarDwt => {
                // Same depth policy as `Stamp`: up to the paper's 3 levels,
                // bounded by the block's divisibility.
                let levels = HaarDwt::max_levels(self.block).clamp(1, 3);
                Some(Box::new(HaarDwt::new(self.block, levels)))
            }
            SeqTransformKind::Dct => Some(Box::new(DctTransform::new(self.block))),
            SeqTransformKind::Wht => Some(Box::new(WhtTransform::new(self.block))),
            SeqTransformKind::HaarDwt2d { .. } => {
                panic!("2-D sequence transforms do not apply to 1-D decode streams")
            }
        }
    }
}

/// One K or V token stream: finalized pooled blocks + fp32 tail window.
pub struct KvStream {
    cfg: KvCacheConfig,
    /// Built once per stream; every block shares it (blocks have one
    /// fixed length, `cfg.block`).
    transform: Option<Box<dyn SequenceTransform>>,
    /// Owner of this stream's finalized blocks. Private by default
    /// ([`KvStream::new`]); streams of one decode engine share the
    /// engine's pool ([`KvStream::with_pool`]) so common prompt prefixes
    /// are stored once.
    pool: Arc<BlockPool>,
    /// Handles to the *resident* finalized blocks, `cfg.block` tokens
    /// each, oldest first (the front of the vector is the retained sink
    /// span, then the recent region). Each handle carries the flush-time
    /// dequantized (+ inverse-transformed) fp32 view every gather reads
    /// — blocks are immutable, so decompressing once per flush instead
    /// of once per [`KvStream::gather`] keeps the per-step decode cost
    /// O(copy) — plus, for packed streams, the bit-packed [`QTensor`]
    /// that remains the stored representation. Evicting drops the
    /// *handle*; the pool frees the block only when no other stream (or
    /// prefix-index entry) still references it.
    blocks: Vec<BlockHandle>,
    /// Recent tokens not yet covering a full block (always `Some` with
    /// ≥ 1 row when non-empty; an unwindowed `packed = false` stream
    /// without [`KvCacheConfig::prefix_cache`] keeps everything here).
    /// Always private to this stream — the copy-on-write divergence
    /// point of prefix sharing.
    tail: Option<Tensor>,
    /// Feature width, fixed by the first append.
    dim: Option<usize>,
    /// Total tokens appended (the *logical* length — evicted tokens
    /// still count, so absolute positions never regress).
    len: usize,
    /// Tokens evicted from the front of the recent region. The evicted
    /// absolute range is always the contiguous
    /// `[sink_span, sink_span + evicted)`.
    evicted: usize,
}

impl KvStream {
    /// Stream with a private block pool (no cross-stream sharing).
    pub fn new(cfg: KvCacheConfig) -> Self {
        let pool = BlockPool::new();
        KvStream::with_pool(cfg, pool)
    }

    /// Stream allocating its finalized blocks from a shared `pool` —
    /// how a decode engine makes its streams prefix-shareable.
    pub fn with_pool(cfg: KvCacheConfig, pool: Arc<BlockPool>) -> Self {
        cfg.validate();
        let transform = cfg.block_transform();
        KvStream {
            cfg,
            transform,
            pool,
            blocks: Vec::new(),
            tail: None,
            dim: None,
            len: 0,
            evicted: 0,
        }
    }

    /// Tokens appended so far — the *logical* sequence length; evicted
    /// tokens still count so absolute positions never regress.
    pub fn len(&self) -> usize {
        self.len
    }

    /// Tokens evicted from the front of the recent region (0 without a
    /// window policy). Non-decreasing over the stream's lifetime.
    pub fn evicted(&self) -> usize {
        self.evicted
    }

    /// Tokens currently resident — [`KvStream::gather`]'s row count:
    /// `len() − evicted()`, bounded by
    /// [`KvCacheConfig::resident_bound`] under a window policy.
    pub fn resident_len(&self) -> usize {
        self.len - self.evicted
    }

    /// Gathered row index where the eviction gap sits: gathered row `r`
    /// holds absolute position `r` for `r < gap_row()`, and
    /// `r + evicted()` past the gap. (With nothing evicted the mapping is
    /// the identity either way.)
    pub fn gap_row(&self) -> usize {
        self.sink_span().min(self.resident_len())
    }

    /// The permanently retained sink prefix, rounded up to whole blocks
    /// (0 without a window policy).
    fn sink_span(&self) -> usize {
        match self.cfg.eviction {
            EvictionPolicy::SlidingWindow { sink_tokens, .. } => {
                sink_tokens.div_ceil(self.cfg.block) * self.cfg.block
            }
            EvictionPolicy::None => 0,
        }
    }

    /// Whether a sliding-window policy is active (windowed fp32 streams
    /// finalize blocks too, so eviction has block granularity to work at).
    fn windowed(&self) -> bool {
        matches!(self.cfg.eviction, EvictionPolicy::SlidingWindow { .. })
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Feature width (`None` until the first append).
    pub fn dim(&self) -> Option<usize> {
        self.dim
    }

    /// *Resident* finalized blocks (evicted handles are dropped). Packed
    /// streams finalize every full block; fp32 streams finalize under a
    /// window policy or with [`KvCacheConfig::prefix_cache`] set.
    pub fn n_blocks(&self) -> usize {
        self.blocks.len()
    }

    /// This stream's block pool.
    pub fn pool(&self) -> &Arc<BlockPool> {
        &self.pool
    }

    /// The stream's configuration (shared-config equality is what makes
    /// pooled blocks bit-exact across streams).
    pub fn config(&self) -> &KvCacheConfig {
        &self.cfg
    }

    /// Tokens currently in the fp32 tail window.
    pub fn tail_len(&self) -> usize {
        self.tail.as_ref().map_or(0, Tensor::rows)
    }

    /// Tokens still appendable before the [`KvCacheConfig::max_seq`] bound
    /// (`None` = unbounded).
    pub fn remaining(&self) -> Option<usize> {
        self.cfg.max_seq.map(|cap| cap.saturating_sub(self.len))
    }

    /// Append `m` new tokens (an `m×d` matrix, oldest first). Completed
    /// blocks flush immediately; partial tokens wait in the fp32 tail.
    /// Panics when the stream is capacity-bounded and full — callers that
    /// need to recover (the decode engine retiring a stream with a
    /// truncation flag) use [`KvStream::try_append`] or check
    /// [`KvStream::remaining`] first.
    pub fn append(&mut self, rows: &Tensor) {
        if let Err(e) = self.try_append(rows) {
            panic!("{e}");
        }
    }

    /// [`KvStream::append`] with the capacity bound surfaced as a
    /// recoverable [`crate::error::Error`] instead of a panic. Shape and
    /// feature-width violations remain panics: those are programming
    /// errors, while running out of sequence budget is a normal condition
    /// under real traffic.
    pub fn try_append(&mut self, rows: &Tensor) -> crate::error::Result<()> {
        assert_eq!(rows.ndim(), 2, "kv append expects a 2-D m×d tensor");
        if rows.rows() == 0 {
            return Ok(());
        }
        if let Some(cap) = self.cfg.max_seq {
            if self.len + rows.rows() > cap {
                crate::bail!(
                    "kv stream at capacity: {} stored + {} new tokens exceeds max_seq {cap}",
                    self.len,
                    rows.rows()
                );
            }
        }
        match self.dim {
            Some(d) => assert_eq!(rows.cols(), d, "kv append feature width changed"),
            None => self.dim = Some(rows.cols()),
        }
        self.tail = Some(match self.tail.take() {
            Some(t) => t.vcat(rows),
            None => rows.clone(),
        });
        self.len += rows.rows();
        if self.cfg.packed || self.windowed() || self.cfg.prefix_cache {
            while self.tail_len() >= self.cfg.block {
                self.flush_block();
            }
            self.evict();
        }
        Ok(())
    }

    /// Finalize the oldest `block` tail tokens into a pooled block:
    /// packed streams quantize them (handle carries both the packed
    /// payload and its decompressed view), fp32 streams move the exact
    /// rows into an immutable block view (so eviction and prefix sharing
    /// have block granularity to work at). Only ever called with a full
    /// block accumulated — the flush rule that keeps block-wise
    /// transforms causal (module docs).
    fn flush_block(&mut self) {
        let tail = self.tail.take().expect("flush with empty tail");
        let b = self.cfg.block;
        // The block's *absolute* start position — `len` minus whatever is
        // still unfinalized — decides how many of its rows fall under the
        // hp (sink) budget; computing it from `len` (not from the resident
        // block count) keeps the representation eviction-independent.
        // Transforms concentrate the block's energy into the leading
        // coefficients, so the hp rows are the leading ones in either
        // domain and the accounting is position-equivalent.
        let base = self.len - tail.rows();
        let block = tail.slice_rows(0, b);
        self.tail = if tail.rows() > b { Some(tail.slice_rows(b, tail.rows())) } else { None };
        let handle = if self.cfg.packed {
            let hp_rows = self.cfg.hp_tokens.saturating_sub(base).min(b);
            let bits = BitAllocation::two_level(hp_rows, self.cfg.hp_bits, self.cfg.lp_bits);
            let coeffs = match &self.transform {
                Some(t) => t.forward(&block),
                None => block,
            };
            let q = QTensor::quantize(&coeffs, &bits, Granularity::PerToken);
            // Decompress the (now immutable) block exactly once — what
            // every later gather will read for these tokens. High-precision
            // rows (8-bit lanes under the two-level allocation) take the
            // no-unpack fast path inside `dequantize`: the packed payload
            // *is* the code stream, so no per-row unpack copy is made.
            let deq = q.dequantize();
            let view = match &self.transform {
                Some(t) => t.inverse(&deq),
                None => deq,
            };
            self.pool.insert(view, Some(q))
        } else {
            self.pool.insert(block, None)
        };
        self.blocks.push(handle);
    }

    /// Drop every finalized block that has slid entirely out of the
    /// logical window `[sinks ‖ last-window]`. The candidate is always the
    /// oldest non-sink resident block — absolute range
    /// `[sink_span + evicted, sink_span + evicted + block)` — evictable
    /// iff it is finalized (never the fp32 tail) and its newest token is
    /// older than the last `window` positions.
    fn evict(&mut self) {
        let EvictionPolicy::SlidingWindow { window, .. } = self.cfg.eviction else {
            return;
        };
        let b = self.cfg.block;
        let sink_span = self.sink_span();
        loop {
            let start = sink_span + self.evicted;
            let end = start + b;
            let finalized = self.len - self.tail_len();
            if end > finalized || end + window > self.len {
                return;
            }
            // Dropping the handle releases this stream's reference only —
            // the pool frees the physical block when (and only when) no
            // other stream or prefix-index entry still holds it, so
            // evicting here can never invalidate a sharer's view.
            drop(self.blocks.remove(sink_span / b));
            self.evicted += b;
        }
    }

    /// Materialize the *resident* stream as a `resident_len×d` fp32 matrix
    /// for attention — the logical window `[sinks ‖ recent]`: finalized
    /// blocks read from the flush-time decompressed view (each block
    /// dequantized + inverse-transformed exactly once, at flush), the fp32
    /// tail copies through exactly. Row `r`'s absolute position is
    /// recovered by [`KvStream::gap_row`] / [`KvStream::evicted`]; without
    /// eviction this is the whole `len×d` stream, unchanged.
    pub fn gather(&self) -> Tensor {
        let d = match self.dim {
            Some(d) => d,
            None => return Tensor::zeros(&[0, 0]),
        };
        let mut out = Tensor::zeros(&[self.resident_len(), d]);
        let mut r = 0usize;
        for h in &self.blocks {
            let v = h.view();
            let start = r * d;
            out.data_mut()[start..start + v.len()].copy_from_slice(v.data());
            r += v.rows();
        }
        if let Some(t) = &self.tail {
            let start = r * d;
            out.data_mut()[start..start + t.len()].copy_from_slice(t.data());
            r += t.rows();
        }
        debug_assert_eq!(r, self.resident_len());
        out
    }

    /// *Resident* storage footprint in bits: the packed payload plus
    /// 16-bit scale + 16-bit zero per group for resident finalized blocks
    /// (the Appendix-C accounting, [`QTensor::storage_bits`]), and 32
    /// bits/element for fp32 rows (the tail, plus the finalized region of
    /// windowed fp32 streams). Evicted blocks cost nothing — under a
    /// window policy this stays bounded by the sink + window budget while
    /// `len` grows without limit (`tests/eviction.rs`).
    pub fn storage_bits(&self) -> usize {
        let finalized: usize = self.blocks.iter().map(BlockHandle::bits).sum();
        finalized + self.tail_bits()
    }

    /// The fp32 tail's footprint — always private to this stream (the
    /// copy-on-write divergence point; never pooled).
    pub fn tail_bits(&self) -> usize {
        self.tail.as_ref().map_or(0, |t| t.len() * 32)
    }

    /// The part of [`KvStream::storage_bits`] stored in pool blocks that
    /// another holder (stream or prefix-index entry) also references —
    /// physically stored once, counted once per sharing stream here.
    pub fn shared_bits(&self) -> usize {
        self.blocks.iter().filter(|h| h.is_shared()).map(BlockHandle::bits).sum()
    }

    /// The part of [`KvStream::storage_bits`] only this stream holds:
    /// sole-reference blocks plus the fp32 tail. Always
    /// `storage_bits() == shared_bits() + private_bits()`.
    pub fn private_bits(&self) -> usize {
        self.storage_bits() - self.shared_bits()
    }

    /// [`KvStream::storage_bits`] per *resident* element (0 when empty).
    pub fn average_storage_bits(&self) -> f64 {
        match self.dim {
            Some(d) if self.resident_len() > 0 => {
                self.storage_bits() as f64 / (self.resident_len() * d) as f64
            }
            _ => 0.0,
        }
    }

    /// Retained handles to the first `n_blocks` *resident* finalized
    /// blocks (panics past the resident run) — what prefix registration
    /// records. Resident-indexed, not absolute: after front-eviction the
    /// first resident block past the sink span is a *post-gap* block, so
    /// callers that need the absolute prompt prefix (prefix-cache
    /// registration) must refuse once `evicted() > 0` —
    /// [`KvCache::prefix_entry`] enforces exactly that, per stream.
    pub fn block_handles(&self, n_blocks: usize) -> Vec<BlockHandle> {
        self.blocks[..n_blocks].to_vec()
    }

    /// Seed an empty stream from pooled prefix blocks: the copy-on-write
    /// fork. The stream starts as if `span = handles·block` tokens had
    /// been appended and finalized — subsequent appends go to the private
    /// fp32 tail and flush new private blocks, never touching the shared
    /// prefix. Under a window policy the seed is immediately normalized
    /// by eviction (out-of-window handles released). Because a block's
    /// representation depends only on its absolute base position and the
    /// config — identical across streams of one engine — a seeded stream
    /// gathers bit-identically to one that re-ran prefill.
    pub fn seed(&mut self, handles: Vec<BlockHandle>, span: usize) {
        assert!(self.is_empty(), "seed requires an empty stream");
        assert!(span > 0 && span % self.cfg.block == 0, "seed span must be whole blocks");
        assert_eq!(
            handles.len() * self.cfg.block,
            span,
            "seed handles must cover the span exactly"
        );
        if let Some(cap) = self.cfg.max_seq {
            assert!(span <= cap, "seed span {span} exceeds max_seq {cap}");
        }
        self.dim = Some(handles[0].view().cols());
        self.blocks = handles;
        self.len = span;
        self.evict();
    }

    /// Roll the stream back to `len` tokens by popping rows off the fp32
    /// tail — the rejection half of speculative decode (DESIGN.md §18).
    /// Only the tail is ever touched: finalized blocks are immutable and
    /// possibly shared (pooled handles, prefix index), so a rollback that
    /// would reach into them is a programming error — the speculation
    /// depth must be capped by [`KvStream::spec_headroom`] so every
    /// overshoot token is still in the private tail. After the rollback
    /// the stream is bit-identical to one that only ever appended the
    /// first `len` tokens: the tail holds exact fp32 rows, so slicing
    /// them off leaves no trace, and `len`/`evicted`/`blocks` are
    /// unchanged by construction.
    pub fn truncate_to(&mut self, len: usize) {
        assert!(
            len <= self.len,
            "kv truncate_to({len}) cannot grow a stream of {} tokens",
            self.len
        );
        let cut = self.len - len;
        if cut == 0 {
            return;
        }
        let tl = self.tail_len();
        assert!(
            cut <= tl,
            "kv rollback must stay inside the fp32 tail: popping {cut} tokens but the tail \
             holds {tl} (cap draft depth with spec_headroom)"
        );
        let tail = self.tail.take().expect("non-empty tail");
        self.tail = if cut < tl { Some(tail.slice_rows(0, tl - cut)) } else { None };
        self.len = len;
    }

    /// Maximum number of *speculative* tokens that may be appended after
    /// the pending (non-speculative) token such that rolling back to any
    /// accepted length is exact (see [`KvStream::truncate_to`]). Three
    /// caps compose, each derived from a state change that a rollback
    /// could not undo:
    ///
    /// * **capacity** — `len + 1 + d ≤ max_seq`, so the speculative
    ///   append never trips the recoverable capacity error mid-verify;
    /// * **flush** — for block-finalizing streams (packed, windowed, or
    ///   prefix-cached), the overshoot must not complete a block beyond
    ///   those the pending token itself completes: finalization
    ///   quantizes/pools rows irreversibly, so
    ///   `⌊(len+1+d)/block⌋ == ⌊(len+1)/block⌋`;
    /// * **eviction** — under a sliding window, growth of `len` alone
    ///   can trigger an eviction. An eviction at exactly `len + 1` fires
    ///   identically in the non-speculative path, but it shifts the
    ///   *resident* positions every later token embeds at — so when one
    ///   is due at the pending append, the headroom is 0; otherwise the
    ///   overshoot must stop short of the next trigger length.
    ///
    /// Plain unbounded fp32 streams (the parity reference) are limited
    /// only by capacity: everything lives in the tail.
    pub fn spec_headroom(&self) -> usize {
        let l1 = self.len + 1; // length after the pending token lands
        let mut d = usize::MAX;
        if let Some(cap) = self.cfg.max_seq {
            d = d.min(cap.saturating_sub(l1));
        }
        if self.cfg.packed || self.windowed() || self.cfg.prefix_cache {
            let b = self.cfg.block;
            d = d.min(b - 1 - (l1 % b));
            if let EvictionPolicy::SlidingWindow { window, .. } = self.cfg.eviction {
                let start = self.sink_span() + self.evicted;
                let finalized_at_l1 = (l1 / b) * b;
                if start + b <= finalized_at_l1 {
                    // An evictable finalized block exists; it drops once
                    // the logical length reaches `t0`.
                    let t0 = start + b + window;
                    d = if t0 <= l1 { 0 } else { d.min(t0 - l1 - 1) };
                }
            }
        }
        d
    }

    /// Throwaway copy for a speculative drafter: shares the finalized
    /// blocks (handle refcounts retained — dropped with the fork) and
    /// *degrades* the private fp32 tail through a per-token QDQ round
    /// trip at `lp_bits`, so a packed-path drafter reads the same
    /// low-precision representation the steady-state cache stores rather
    /// than a bit-exact clone of the verifier's state. The fork is fully
    /// independent: its appends flush into the shared pool as private
    /// handles and never touch this stream.
    pub fn fork_draft(&self) -> KvStream {
        let tail = self.tail.as_ref().map(|t| {
            let bits = BitAllocation::two_level(0, self.cfg.hp_bits, self.cfg.lp_bits);
            QTensor::quantize(t, &bits, Granularity::PerToken).dequantize()
        });
        KvStream {
            cfg: self.cfg.clone(),
            transform: self.cfg.block_transform(),
            pool: self.pool.clone(),
            blocks: self.blocks.clone(),
            tail,
            dim: self.dim,
            len: self.len,
            evicted: self.evicted,
        }
    }
}

/// Per-layer K and V streams (what
/// [`crate::model::attention::MultiHeadAttention::forward_decode`]
/// consumes).
pub struct KvLayer {
    pub k: KvStream,
    pub v: KvStream,
}

impl KvLayer {
    pub fn new(cfg: KvCacheConfig) -> Self {
        let pool = BlockPool::new();
        KvLayer::with_pool(cfg, pool)
    }

    /// Layer whose K and V streams allocate from a shared `pool`.
    pub fn with_pool(cfg: KvCacheConfig, pool: Arc<BlockPool>) -> Self {
        KvLayer {
            k: KvStream::with_pool(cfg.clone(), pool.clone()),
            v: KvStream::with_pool(cfg, pool),
        }
    }

    /// fp32 reference layer (parity path).
    pub fn fp32() -> Self {
        KvLayer::new(KvCacheConfig::fp32())
    }
}

/// Whole-model cache: one [`KvLayer`] per transformer block, advancing in
/// lock-step through [`crate::model::Gpt::prefill`] /
/// [`crate::model::Gpt::decode_step`].
pub struct KvCache {
    layers: Vec<KvLayer>,
}

impl KvCache {
    pub fn new(n_layers: usize, cfg: KvCacheConfig) -> Self {
        let pool = BlockPool::new();
        KvCache::with_pool(n_layers, cfg, pool)
    }

    /// Cache whose streams (all layers, K and V) allocate from one shared
    /// `pool` — what [`crate::decode::DecodeEngine::admit`] builds so
    /// every stream of an engine can share prefix blocks.
    pub fn with_pool(n_layers: usize, cfg: KvCacheConfig, pool: Arc<BlockPool>) -> Self {
        assert!(n_layers >= 1, "cache needs at least one layer");
        let layers = (0..n_layers).map(|_| KvLayer::with_pool(cfg.clone(), pool.clone())).collect();
        KvCache { layers }
    }

    /// fp32 reference cache (parity path).
    pub fn fp32(n_layers: usize) -> Self {
        KvCache::new(n_layers, KvCacheConfig::fp32())
    }

    pub fn n_layers(&self) -> usize {
        self.layers.len()
    }

    /// Tokens appended so far (layers advance in lock-step during a
    /// forward, so layer 0's K stream is authoritative).
    pub fn len(&self) -> usize {
        self.layers[0].k.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Tokens still appendable before the configured capacity (`None` =
    /// unbounded). Layers advance in lock-step, so layer 0's K stream is
    /// authoritative here too.
    pub fn remaining(&self) -> Option<usize> {
        self.layers[0].k.remaining()
    }

    /// Tokens evicted from every stream so far (lock-step; layer 0
    /// authoritative).
    pub fn evicted(&self) -> usize {
        self.layers[0].k.evicted()
    }

    /// Tokens currently resident in each stream.
    pub fn resident_len(&self) -> usize {
        self.layers[0].k.resident_len()
    }

    /// Finalized (quantized) blocks per stream (lock-step; layer 0
    /// authoritative). The decode engine's trace instrumentation diffs
    /// this across steps to emit `BlockFinalize` events.
    pub fn n_blocks(&self) -> usize {
        self.layers[0].k.n_blocks()
    }

    /// Positional-embedding index for the next appended token: its rank
    /// in the *resident* sequence. Without eviction this is exactly
    /// [`KvCache::len`]; under a window policy it is bounded by
    /// [`KvCacheConfig::resident_bound`], so a fixed positional table
    /// serves an unbounded logical sequence
    /// ([`crate::model::Gpt::prefill`] embeds from here).
    pub fn pos_next(&self) -> usize {
        self.resident_len()
    }

    pub fn layer(&self, l: usize) -> &KvLayer {
        &self.layers[l]
    }

    pub fn layer_mut(&mut self, l: usize) -> &mut KvLayer {
        &mut self.layers[l]
    }

    /// Total footprint across all layers and both streams.
    pub fn storage_bits(&self) -> usize {
        self.layers.iter().map(|l| l.k.storage_bits() + l.v.storage_bits()).sum()
    }

    /// The pool this cache's streams allocate from (layers share one;
    /// layer 0's K stream is authoritative).
    pub fn pool(&self) -> &Arc<BlockPool> {
        self.layers[0].k.pool()
    }

    /// [`KvStream::shared_bits`] summed over all layers and both streams.
    pub fn shared_bits(&self) -> usize {
        self.layers.iter().map(|l| l.k.shared_bits() + l.v.shared_bits()).sum()
    }

    /// [`KvStream::private_bits`] summed over all layers and both
    /// streams. `storage_bits() == shared_bits() + private_bits()`.
    pub fn private_bits(&self) -> usize {
        self.layers.iter().map(|l| l.k.private_bits() + l.v.private_bits()).sum()
    }

    /// [`KvStream::tail_bits`] summed over all layers and both streams —
    /// with the pool's physical bits, the whole-system footprint of N
    /// shared-prefix streams is `pool.resident_bits() + Σ tail_bits()`.
    pub fn tail_bits(&self) -> usize {
        self.layers.iter().map(|l| l.k.tail_bits() + l.v.tail_bits()).sum()
    }

    /// Copy-on-write fork from a pool prefix hit
    /// ([`BlockPool::lookup_prefix`]): seed every layer's K and V stream
    /// from the hit's handles, as if the first `hit.span` tokens had
    /// already been appended. The engine then prefills only from the
    /// divergence point. Panics unless the cache is empty and the hit's
    /// layer count matches.
    pub fn seed_prefix(&mut self, hit: PrefixHit) {
        assert!(self.is_empty(), "seed_prefix requires an empty cache");
        assert_eq!(hit.layers.len(), self.layers.len(), "prefix hit layer count mismatch");
        for (layer, (k, v)) in self.layers.iter_mut().zip(hit.layers) {
            layer.k.seed(k, hit.span);
            layer.v.seed(v, hit.span);
        }
    }

    /// Build a [`PrefixEntry`] recording the first `tokens.len()` cached
    /// positions for registration in the pool's prefix index, or `None`
    /// when the cache cannot vouch for them (unaligned length, eviction
    /// already dropped part of the run, or the blocks are not finalized
    /// yet). `tokens` must be the prompt token IDs those positions hold.
    ///
    /// The eviction guard is checked on *every* stream, not just the
    /// authoritative layer-0 K: [`KvStream::block_handles`] is
    /// resident-indexed, so once any stream has front-evicted, its
    /// leading handles are post-gap blocks — registering them under the
    /// absolute prompt token IDs would seed later streams with the wrong
    /// positions (`tests/prefix.rs` pins the window × prefix_cache
    /// interaction).
    pub fn prefix_entry(&self, tokens: &[u32]) -> Option<PrefixEntry> {
        let block = self.layers[0].k.config().block;
        if block == 0 || tokens.is_empty() || tokens.len() % block != 0 {
            return None;
        }
        let n = tokens.len() / block;
        for l in &self.layers {
            if l.k.evicted() > 0
                || l.v.evicted() > 0
                || l.k.n_blocks() < n
                || l.v.n_blocks() < n
            {
                return None;
            }
        }
        let layers = self.layers.iter().map(|l| (l.k.block_handles(n), l.v.block_handles(n)));
        Some(PrefixEntry::new(tokens.to_vec(), layers.collect()))
    }

    /// [`KvStream::truncate_to`] across every layer's K and V stream —
    /// the whole-model rollback of speculative decode. Layers advance in
    /// lock-step, so one target length applies to all streams.
    pub fn truncate_to(&mut self, len: usize) {
        for l in &mut self.layers {
            l.k.truncate_to(len);
            l.v.truncate_to(len);
        }
    }

    /// Minimum [`KvStream::spec_headroom`] across every stream. Lock-step
    /// appends make all streams agree; taking the minimum keeps the bound
    /// safe even if a future cache variant lets layers diverge.
    pub fn spec_headroom(&self) -> usize {
        self.layers
            .iter()
            .map(|l| l.k.spec_headroom().min(l.v.spec_headroom()))
            .min()
            .expect("cache has at least one layer")
    }

    /// [`KvStream::fork_draft`] across every layer — the throwaway cache
    /// a packed-path drafter decodes on. Shares finalized blocks with
    /// this cache (refcounts retained, released when the fork drops) and
    /// reads a QDQ-degraded copy of each fp32 tail.
    pub fn fork_draft(&self) -> KvCache {
        KvCache {
            layers: self
                .layers
                .iter()
                .map(|l| KvLayer { k: l.k.fork_draft(), v: l.v.fork_draft() })
                .collect(),
        }
    }

    /// Mean bits per *resident* K/V element across the whole cache.
    pub fn average_storage_bits(&self) -> f64 {
        let elems: usize = self
            .layers
            .iter()
            .map(|l| {
                l.k.dim().map_or(0, |d| l.k.resident_len() * d)
                    + l.v.dim().map_or(0, |d| l.v.resident_len() * d)
            })
            .sum();
        if elems == 0 {
            0.0
        } else {
            self.storage_bits() as f64 / elems as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::quantize_dequantize_rows;
    use crate::stats::sqnr;

    fn cfg(hp: usize, hp_bits: u32, lp: u32, block: usize) -> KvCacheConfig {
        KvCacheConfig::two_level(hp, hp_bits, lp, block)
    }

    #[test]
    fn fp32_gather_is_exact() {
        let mut st = KvStream::new(KvCacheConfig::fp32());
        let a = Tensor::randn(&[5, 8], 1);
        let b = Tensor::randn(&[3, 8], 2);
        st.append(&a);
        st.append(&b);
        assert_eq!(st.len(), 8);
        assert_eq!(st.n_blocks(), 0, "fp32 cache never flushes");
        assert_eq!(st.gather(), a.vcat(&b), "fp32 gather must be bit-exact");
        assert_eq!(st.storage_bits(), 8 * 8 * 32);
    }

    #[test]
    fn flush_boundaries_and_tail_window() {
        let mut st = KvStream::new(cfg(0, 8, 4, 8));
        // 20 tokens in odd chunks: 2 full blocks + 4 tail tokens.
        let x = Tensor::randn(&[20, 6], 3);
        st.append(&x.slice_rows(0, 7));
        assert_eq!((st.n_blocks(), st.tail_len()), (0, 7));
        st.append(&x.slice_rows(7, 9));
        assert_eq!((st.n_blocks(), st.tail_len()), (1, 1));
        st.append(&x.slice_rows(9, 20));
        assert_eq!((st.n_blocks(), st.tail_len()), (2, 4));
        assert_eq!(st.len(), 20);
        // Tail rows are exact fp32 copies.
        let g = st.gather();
        for i in 16..20 {
            assert_eq!(g.row(i), x.row(i), "tail row {i} must be exact");
        }
    }

    #[test]
    fn identity_blocks_match_qdq_oracle_bit_for_bit() {
        // Per-token QDQ is row-independent, so with an identity transform
        // the flushed region must equal the one-shot simulated QDQ under
        // the same positional two-level policy.
        let (s, d, block, hp) = (37usize, 12usize, 8usize, 11usize);
        let x = Tensor::randn(&[s, d], 5);
        let mut st = KvStream::new(cfg(hp, 8, 4, block));
        st.append(&x);
        let g = st.gather();
        let flushed = (s / block) * block;
        let want = quantize_dequantize_rows(
            &x.slice_rows(0, flushed),
            &BitAllocation::two_level(hp, 8, 4),
            Granularity::PerToken,
        );
        for i in 0..flushed {
            assert_eq!(g.row(i), want.row(i), "flushed row {i}");
        }
        for i in flushed..s {
            assert_eq!(g.row(i), x.row(i), "tail row {i}");
        }
    }

    #[test]
    fn transformed_blocks_roundtrip_closely() {
        // 8-bit blocks through a Haar DWT: gather must reconstruct the
        // input to 8-bit fidelity (transform is orthonormal; only the
        // coefficient rounding remains), and the tail stays exact.
        let (s, d, block) = (70usize, 16usize, 16usize);
        let x = Tensor::randn(&[s, d], 7);
        for kind in [SeqTransformKind::HaarDwt, SeqTransformKind::Dct, SeqTransformKind::Wht] {
            let mut st = KvStream::new(cfg(0, 8, 8, block).with_transform(kind));
            st.append(&x);
            let g = st.gather();
            let s_db = sqnr(&x, &g);
            assert!(s_db > 35.0, "{kind:?}: round-trip SQNR {s_db} dB");
            for i in (s / block) * block..s {
                assert_eq!(g.row(i), x.row(i), "{kind:?} tail row {i}");
            }
        }
    }

    #[test]
    fn incremental_append_equals_batch_append() {
        let (s, d, block) = (41usize, 10usize, 8usize);
        let x = Tensor::randn(&[s, d], 9);
        let mk = || KvStream::new(cfg(6, 8, 4, block).with_transform(SeqTransformKind::HaarDwt));
        let mut batch = mk();
        batch.append(&x);
        let mut inc = mk();
        for i in 0..s {
            inc.append(&x.slice_rows(i, i + 1));
        }
        assert_eq!(inc.gather(), batch.gather(), "append granularity must not matter");
        assert_eq!(inc.storage_bits(), batch.storage_bits());
        assert_eq!(inc.n_blocks(), batch.n_blocks());
    }

    #[test]
    fn storage_accounting_two_level_across_block_boundary() {
        // hp_tokens = 12 spans 1.5 blocks of 8: block 0 all-hp, block 1
        // half-hp — Appendix-C accounting per row: payload bits·d + 32
        // (fp16 scale + zero, per-token granularity).
        let (s, d, block, hp) = (32usize, 16usize, 8usize, 12usize);
        let x = Tensor::randn(&[s, d], 11);
        let mut st = KvStream::new(cfg(hp, 8, 4, block));
        st.append(&x);
        let expect: usize =
            (0..s).map(|i| if i < hp { 8 * d + 32 } else { 4 * d + 32 }).sum();
        assert_eq!(st.storage_bits(), expect);
        assert_eq!(st.n_blocks(), 4);
    }

    #[test]
    fn append_and_gather_thread_count_invariant() {
        // Blocks of 256×512 clear MIN_PARALLEL_ELEMS, so the flush-time
        // packing + decompression fan out on multi-core hosts; a stream
        // built with serial kernels must be byte-identical.
        let x = Tensor::randn(&[512, 512], 13);
        let mk = || KvStream::new(cfg(64, 8, 4, 256));
        let mut threaded = mk();
        threaded.append(&x);
        let g_threaded = threaded.gather();
        crate::parallel::set_kernel_serial(true);
        let mut serial = mk();
        serial.append(&x);
        let g_serial = serial.gather();
        crate::parallel::set_kernel_serial(false);
        assert_eq!(g_threaded, g_serial, "cache must not depend on thread count");
        assert_eq!(threaded.storage_bits(), serial.storage_bits());
    }

    #[test]
    fn whole_cache_storage_and_average() {
        let mut cache = KvCache::new(2, cfg(0, 8, 4, 16));
        for _ in 0..32 {
            let k = Tensor::randn(&[1, 8], 17);
            let v = Tensor::randn(&[1, 8], 18);
            for l in 0..2 {
                cache.layer_mut(l).k.append(&k);
                cache.layer_mut(l).v.append(&v);
            }
        }
        assert_eq!(cache.len(), 32);
        // All-lp, fully flushed: 4 payload + 32/8 param bits per element.
        let avg = cache.average_storage_bits();
        assert!((avg - 8.0).abs() < 1e-9, "avg {avg}");
        assert_eq!(cache.storage_bits(), 2 * 2 * 32 * (4 * 8 + 32));
    }

    #[test]
    #[should_panic(expected = "even block size")]
    fn rejects_odd_block_for_dwt() {
        let _ = KvStream::new(cfg(0, 8, 4, 7).with_transform(SeqTransformKind::HaarDwt));
    }

    #[test]
    #[should_panic(expected = "4- or 8-bit")]
    fn rejects_unpackable_lp_bits() {
        let _ = KvStream::new(cfg(0, 8, 6, 8));
    }

    #[test]
    #[should_panic(expected = "1-D decode streams")]
    fn rejects_2d_transform() {
        let _ = KvStream::new(
            cfg(0, 8, 4, 16).with_transform(SeqTransformKind::HaarDwt2d { h: 4, w: 4 }),
        );
    }

    #[test]
    fn empty_and_width_guards() {
        let mut st = KvStream::new(KvCacheConfig::default());
        st.append(&Tensor::zeros(&[0, 4]));
        assert!(st.is_empty());
        assert_eq!(st.gather().shape(), &[0, 0]);
        assert_eq!(st.average_storage_bits(), 0.0);
    }

    #[test]
    fn capacity_bound_is_recoverable() {
        let mut st = KvStream::new(KvCacheConfig::fp32().with_max_seq(5));
        assert_eq!(st.remaining(), Some(5));
        st.append(&Tensor::randn(&[3, 4], 21));
        assert_eq!(st.remaining(), Some(2));
        // Overflow via try_append is a recoverable error that leaves the
        // stream untouched…
        let err = st.try_append(&Tensor::randn(&[3, 4], 22)).unwrap_err();
        assert!(err.to_string().contains("at capacity"), "{err}");
        assert_eq!(st.len(), 3);
        // …and an exact fill is fine.
        st.try_append(&Tensor::randn(&[2, 4], 23)).unwrap();
        assert_eq!((st.len(), st.remaining()), (5, Some(0)));
        // Unbounded streams report no capacity.
        let un = KvStream::new(KvCacheConfig::fp32());
        assert_eq!(un.remaining(), None);
        // Whole-cache view mirrors layer 0.
        let cache = KvCache::new(2, KvCacheConfig::fp32().with_max_seq(7));
        assert_eq!(cache.remaining(), Some(7));
    }

    #[test]
    fn sliding_window_evicts_whole_blocks_and_keeps_sinks() {
        // sinks 8 (= one block), window 16, block 8: after 64 tokens the
        // resident set is positions 0..8 ∪ 40..64 (blocks 5/6 + 8-row
        // window remainder — block granularity keeps [40,48) resident).
        let x = Tensor::randn(&[64, 6], 31);
        let mut st = KvStream::new(cfg(8, 8, 4, 8).with_window(8, 16));
        let mut reference = KvStream::new(cfg(8, 8, 4, 8));
        reference.append(&x);
        for i in 0..64 {
            st.append(&x.slice_rows(i, i + 1));
        }
        assert_eq!(st.len(), 64, "logical length counts evicted tokens");
        // The oldest non-sink block [8,16) evicts at len 32 (end 16 +
        // window 16 ≤ 32); by len 64 every block through [40,48) is out:
        // resident = sinks [0,8) ∪ last-window [48,64).
        assert_eq!(st.evicted(), 40);
        assert_eq!(st.resident_len(), 24);
        assert_eq!(st.gap_row(), 8);
        assert_eq!(st.n_blocks(), 3, "1 sink + 2 recent resident blocks");
        // Resident rows are bit-identical to the unevicted reference at
        // their absolute positions.
        let g = st.gather();
        let r = reference.gather();
        for row in 0..24 {
            let abs = if row < st.gap_row() { row } else { row + st.evicted() };
            assert_eq!(g.row(row), r.row(abs), "resident row {row} (abs {abs})");
        }
        // Storage counts resident blocks only: the all-hp sink block plus
        // two lp recent blocks, no tail.
        let expect: usize = (8 * (8 * 6 + 32)) + 2 * (8 * (4 * 6 + 32));
        assert_eq!(st.storage_bits(), expect);
    }

    #[test]
    fn windowed_fp32_stream_finalizes_and_evicts_exactly() {
        // packed = false + window: finalization moves exact rows, eviction
        // drops them at block granularity, tail rows stay bit-exact.
        let x = Tensor::randn(&[23, 5], 33);
        let mut st =
            KvStream::new(KvCacheConfig { block: 4, ..KvCacheConfig::fp32() }.with_window(0, 4));
        for i in 0..23 {
            st.append(&x.slice_rows(i, i + 1));
        }
        // block 4, window 4, sinks 0: finalized 20, evictable end+4 ≤ 23
        // → blocks [0,4),[4,8),[8,12),[12,16) gone; resident 16..23.
        assert_eq!(st.evicted(), 16);
        assert_eq!(st.resident_len(), 7);
        assert_eq!(st.gap_row(), 0);
        let g = st.gather();
        for row in 0..7 {
            assert_eq!(g.row(row), x.row(16 + row), "resident row {row} must be exact");
        }
        // All-resident fp32 rows at 32 bits.
        assert_eq!(st.storage_bits(), 7 * 5 * 32);
        assert!((st.average_storage_bits() - 32.0).abs() < 1e-12);
    }

    #[test]
    fn window_covering_everything_is_a_noop() {
        let x = Tensor::randn(&[40, 6], 35);
        let mk_base = || KvStream::new(cfg(8, 8, 4, 8));
        let mk_win = || KvStream::new(cfg(8, 8, 4, 8).with_window(8, 64));
        let (mut base, mut win) = (mk_base(), mk_win());
        base.append(&x);
        win.append(&x);
        assert_eq!(win.evicted(), 0);
        assert_eq!(win.gather(), base.gather(), "window ≥ len must be bit-identical");
        assert_eq!(win.storage_bits(), base.storage_bits());
    }

    #[test]
    fn resident_bound_is_respected_under_any_schedule() {
        let mut st = KvStream::new(cfg(4, 8, 4, 4).with_window(4, 8));
        let bound = st.cfg.resident_bound().unwrap();
        assert_eq!(bound, 4 + 8 + 4);
        for i in 0..200 {
            st.append(&Tensor::randn(&[1 + (i % 3), 4], 100 + i as u64));
            assert!(st.resident_len() < bound, "resident {} ≥ bound {bound}", st.resident_len());
        }
    }

    #[test]
    #[should_panic(expected = "must be ≥ kv.block")]
    fn rejects_window_smaller_than_block() {
        let _ = KvStream::new(cfg(0, 8, 4, 8).with_window(0, 4));
    }

    #[test]
    #[should_panic(expected = "≤ kv.hp_tokens")]
    fn rejects_sinks_past_hp_tokens_for_packed() {
        let _ = KvStream::new(cfg(4, 8, 4, 8).with_window(16, 32));
    }

    #[test]
    #[should_panic(expected = "at capacity")]
    fn bounded_append_panics_past_capacity() {
        let mut st = KvStream::new(KvCacheConfig::fp32().with_max_seq(2));
        st.append(&Tensor::randn(&[3, 4], 25));
    }

    #[test]
    #[should_panic(expected = "feature width changed")]
    fn rejects_width_change() {
        let mut st = KvStream::new(KvCacheConfig::fp32());
        st.append(&Tensor::zeros(&[1, 4]));
        st.append(&Tensor::zeros(&[1, 5]));
    }

    #[test]
    fn prefix_cache_fp32_finalization_is_lossless() {
        // With prefix_cache set, an *unwindowed fp32* stream finalizes
        // full blocks into immutable pool views — exact rows move, so
        // gather stays bit-identical to the plain fp32 reference.
        let x = Tensor::randn(&[19, 6], 41);
        let mut plain = KvStream::new(KvCacheConfig::fp32());
        let mut pooled = KvStream::new(
            KvCacheConfig { block: 4, ..KvCacheConfig::fp32() }.with_prefix_cache(),
        );
        plain.append(&x);
        for i in 0..19 {
            pooled.append(&x.slice_rows(i, i + 1));
        }
        assert_eq!(pooled.n_blocks(), 4, "prefix_cache forces fp32 finalization");
        assert_eq!(pooled.gather(), plain.gather(), "finalization must be lossless");
        assert_eq!(pooled.storage_bits(), plain.storage_bits(), "all rows still fp32");
    }

    #[test]
    fn seeded_stream_gathers_bit_identically_and_forks_cow() {
        // Stream A appends 3 blocks + tail into a shared pool; stream B
        // seeds from A's first 2 blocks and re-appends the rest itself.
        // B must gather bit-identically to A, and the seeded blocks stay
        // physically shared while post-divergence blocks stay private.
        let (block, d) = (8usize, 6usize);
        let x = Tensor::randn(&[29, d], 43);
        let pool = BlockPool::new();
        let mut a = KvStream::with_pool(cfg(6, 8, 4, block), pool.clone());
        a.append(&x);
        let mut b = KvStream::with_pool(cfg(6, 8, 4, block), pool.clone());
        b.seed(a.block_handles(2), 2 * block);
        assert_eq!(b.len(), 16);
        b.append(&x.slice_rows(16, 29));
        assert_eq!(b.gather(), a.gather(), "seeded stream must be bit-identical");
        // Shared/private split: 2 prefix blocks shared by both streams,
        // the 3rd block + tail private to each (B's 3rd block is a fresh
        // quantization of the same rows — bit-identical data, but a
        // separate pool block: copy-on-write, not aliasing).
        assert_eq!(a.shared_bits(), b.shared_bits());
        let prefix_bits: usize = a.block_handles(2).iter().map(BlockHandle::bits).sum();
        assert_eq!(a.shared_bits(), prefix_bits);
        assert_eq!(a.storage_bits(), a.shared_bits() + a.private_bits());
        // The pool stores the prefix once: physical bits = one stream's
        // full footprint plus only the *private* part of the other.
        let physical = pool.resident_bits() + a.tail_bits() + b.tail_bits();
        assert_eq!(physical, a.storage_bits() + b.private_bits());
    }

    #[test]
    fn eviction_of_a_shared_block_never_frees_it_under_the_sharer() {
        // A windowed stream evicts a block another stream still holds:
        // the handle drop must only release a reference, and the sharer's
        // gather must stay byte-identical afterwards.
        let (block, d) = (8usize, 6usize);
        let x = Tensor::randn(&[64, d], 47);
        let pool = BlockPool::new();
        let mut holder = KvStream::with_pool(cfg(8, 8, 4, block), pool.clone());
        holder.append(&x.slice_rows(0, 16));
        let before = holder.gather();
        let mut win = KvStream::with_pool(cfg(8, 8, 4, block).with_window(8, 16), pool.clone());
        win.seed(holder.block_handles(2), 16);
        // Probe handle on the block the window will evict ([8, 16)):
        // refs = holder + win + probe.
        let probe = holder.block_handles(2).remove(1);
        assert_eq!(probe.refs(), 3);
        for i in 16..64 {
            win.append(&x.slice_rows(i, i + 1));
        }
        assert_eq!(win.evicted(), 40, "window evicted the non-sink prefix block");
        assert_eq!(holder.gather(), before, "sharer's rows survive the eviction");
        assert_eq!(holder.n_blocks(), 2);
        // Eviction released win's reference only — holder + probe remain.
        assert_eq!(probe.refs(), 2);
    }

    #[test]
    #[should_panic(expected = "requires an empty stream")]
    fn seed_rejects_nonempty_streams() {
        let pool = BlockPool::new();
        let mut a = KvStream::with_pool(cfg(0, 8, 4, 4), pool.clone());
        a.append(&Tensor::randn(&[8, 4], 51));
        let mut b = KvStream::with_pool(cfg(0, 8, 4, 4), pool.clone());
        b.append(&Tensor::randn(&[1, 4], 52));
        b.seed(a.block_handles(1), 4);
    }

    #[test]
    fn truncate_to_pops_tail_rows_exactly() {
        let x = Tensor::randn(&[10, 6], 61);
        let mut st = KvStream::new(KvCacheConfig::fp32());
        st.append(&x);
        st.truncate_to(10); // same-length rollback is a no-op
        assert_eq!(st.len(), 10);
        st.truncate_to(6);
        assert_eq!((st.len(), st.tail_len()), (6, 6));
        assert_eq!(st.gather(), x.slice_rows(0, 6), "rollback must be exact");
        assert_eq!(st.storage_bits(), 6 * 6 * 32);
        st.truncate_to(0);
        assert!(st.is_empty());
        assert_eq!(st.gather().rows(), 0);
    }

    #[test]
    fn truncate_to_matches_a_stream_that_never_overshot() {
        let x = Tensor::randn(&[13, 6], 63);
        let mk = || KvStream::new(cfg(0, 8, 4, 8));
        let mut over = mk();
        over.append(&x); // 1 finalized block + 5 tail rows
        over.truncate_to(10);
        let mut direct = mk();
        direct.append(&x.slice_rows(0, 10));
        assert_eq!(over.gather(), direct.gather(), "overshoot must leave no trace");
        assert_eq!(over.n_blocks(), direct.n_blocks());
        assert_eq!(over.tail_len(), direct.tail_len());
        assert_eq!(over.storage_bits(), direct.storage_bits());
    }

    #[test]
    #[should_panic(expected = "inside the fp32 tail")]
    fn truncate_into_finalized_blocks_panics() {
        let mut st = KvStream::new(cfg(0, 8, 4, 4));
        st.append(&Tensor::randn(&[8, 4], 65));
        st.truncate_to(7);
    }

    #[test]
    #[should_panic(expected = "cannot grow")]
    fn truncate_to_rejects_growth() {
        let mut st = KvStream::new(KvCacheConfig::fp32());
        st.append(&Tensor::randn(&[3, 4], 66));
        st.truncate_to(4);
    }

    #[test]
    fn spec_headroom_overshoot_rolls_back_exactly() {
        // For every prefix length: append 1 + headroom tokens (the
        // pending token plus a maximal speculative overshoot), roll back
        // to the pending length, and require the stream to be
        // indistinguishable from one that never overshot — across
        // packed, windowed-packed, windowed-fp32, and capacity-bounded
        // configs.
        let x = Tensor::randn(&[48, 5], 67);
        let configs: Vec<KvCacheConfig> = vec![
            cfg(0, 8, 4, 8),
            cfg(4, 8, 4, 4).with_window(4, 8),
            KvCacheConfig { block: 4, ..KvCacheConfig::fp32() }.with_window(0, 4),
            KvCacheConfig::fp32().with_max_seq(12),
            cfg(0, 8, 4, 8).with_max_seq(20),
        ];
        for c in configs {
            let top = c.max_seq.map_or(40, |cap| 40.min(cap - 1));
            for len in 0..top {
                let mut over = KvStream::new(c.clone());
                over.append(&x.slice_rows(0, len));
                let d = over.spec_headroom().min(x.rows() - len - 1);
                if let Some(cap) = c.max_seq {
                    assert!(len + 1 + d <= cap, "{c:?}: headroom exceeds capacity");
                }
                over.append(&x.slice_rows(len, len + 1 + d));
                over.truncate_to(len + 1);
                let mut direct = KvStream::new(c.clone());
                direct.append(&x.slice_rows(0, len + 1));
                assert_eq!(over.gather(), direct.gather(), "{c:?} len {len} d {d}");
                assert_eq!(over.evicted(), direct.evicted(), "{c:?} len {len} d {d}");
                assert_eq!(over.n_blocks(), direct.n_blocks(), "{c:?} len {len} d {d}");
                assert_eq!(
                    over.storage_bits(),
                    direct.storage_bits(),
                    "{c:?} len {len} d {d}"
                );
            }
        }
    }

    #[test]
    fn fork_draft_shares_blocks_and_qdqs_the_tail() {
        let x = Tensor::randn(&[13, 6], 69);
        let mut st = KvStream::new(cfg(0, 8, 4, 8));
        st.append(&x); // 1 finalized block + 5 tail rows
        let probe = st.block_handles(1).remove(0);
        assert_eq!(probe.refs(), 2); // stream + probe
        let fork = st.fork_draft();
        assert_eq!(probe.refs(), 3, "fork retains the finalized block");
        assert_eq!((fork.len(), fork.evicted(), fork.n_blocks()), (13, 0, 1));
        let (g, gf) = (st.gather(), fork.gather());
        for i in 0..8 {
            assert_eq!(gf.row(i), g.row(i), "finalized row {i} is shared");
        }
        // The fork's tail is the lp-bits QDQ of the exact tail rows —
        // the drafter reads the steady-state low-precision
        // representation, not the verifier's bit-exact state.
        let want = quantize_dequantize_rows(
            &x.slice_rows(8, 13),
            &BitAllocation::two_level(0, 8, 4),
            Granularity::PerToken,
        );
        for i in 0..5 {
            assert_eq!(gf.row(8 + i), want.row(i), "tail row {i} is QDQ-degraded");
        }
        drop(fork);
        assert_eq!(probe.refs(), 2, "dropping the fork releases its references");
    }

    #[test]
    fn prefix_entry_refuses_once_any_stream_has_evicted() {
        // Windowed cache: registration must refuse post-eviction handles
        // — they are post-gap blocks, not the absolute prompt prefix.
        let c = cfg(4, 8, 4, 4).with_window(4, 4);
        let mut cache = KvCache::new(2, c);
        let tokens: Vec<u32> = (0..8).collect();
        let push = |cache: &mut KvCache, i: u64| {
            let k = Tensor::randn(&[1, 6], 200 + i);
            let v = Tensor::randn(&[1, 6], 300 + i);
            for l in 0..2 {
                cache.layer_mut(l).k.append(&k);
                cache.layer_mut(l).v.append(&v);
            }
        };
        for i in 0..8 {
            push(&mut cache, i);
        }
        assert_eq!(cache.evicted(), 0);
        assert!(cache.prefix_entry(&tokens).is_some(), "pre-eviction prefix registers");
        for i in 8..20 {
            push(&mut cache, i);
        }
        assert!(cache.evicted() > 0, "window must have evicted by now");
        assert!(
            cache.prefix_entry(&tokens[..4]).is_none(),
            "post-eviction registration must refuse"
        );
    }
}
