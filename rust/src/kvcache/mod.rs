//! STaMP-aware quantized KV cache — the sequence-incremental consumer of
//! [`crate::quant::BitAllocation`] + [`crate::quant::QTensor`] that lets
//! the paper's two-level mixed-precision policy (§3.3, Theorem 1) run
//! where autoregressive serving actually spends its memory.
//!
//! ## Layout (DESIGN.md §11)
//!
//! Each transformer layer owns one [`KvStream`] per K/V tensor. A stream
//! is a sequence of finalized packed blocks followed by an fp32 tail:
//!
//! ```text
//! [ packed block 0 | packed block 1 | … | fp32 tail (< block tokens) ]
//! ```
//!
//! * **Packed blocks** — `block` consecutive tokens, optionally passed
//!   through a block-wise sequence transform (`L` over the block's rows),
//!   quantized per token into a bit-packed [`QTensor`]. Bit widths follow
//!   the global two-level policy: rows overlapping the first `hp_tokens`
//!   (attention-sink) positions store at `hp_bits`, steady-state rows at
//!   `lp_bits`. For transformed blocks the hp rows are the *leading*
//!   coefficients — which every shipped transform orders by energy — so
//!   the storage accounting is identical either way.
//! * **fp32 tail** — the most recent `len mod block` tokens, kept exact
//!   until a full block accumulates.
//!
//! ## The tail-window flush rule keeps block transforms causal
//!
//! A sequence transform mixes tokens, so applying it across the whole
//! stream at every decode step would make a token's stored representation
//! depend on *future* tokens. The flush rule restores causality: a token
//! is re-represented exactly once — when its block completes — and the
//! transform mixes only the tokens of that (entirely past) block.
//! Appending token `t` therefore never alters any block that does not
//! contain `t`, and attention at step `t` reads only data derived from
//! tokens `≤ t`.
//!
//! With `packed = false` the stream stores plain fp32 rows and
//! [`KvStream::gather`] returns exactly what was appended — the parity
//! reference under which decode is bit-identical to the full-sequence
//! forward at any thread count (`tests/decode.rs`).

use crate::quant::{BitAllocation, Granularity, QTensor};
use crate::stamp::SeqTransformKind;
use crate::tensor::Tensor;
use crate::transforms::{DctTransform, HaarDwt, SequenceTransform, WhtTransform};

/// Two-level token policy + block layout for one KV cache
/// (the `[generate]` config section's `kv.*` keys,
/// [`crate::config::GenerateSpec`]).
#[derive(Clone, Debug)]
pub struct KvCacheConfig {
    /// Leading (attention-sink) token positions stored at `hp_bits`.
    pub hp_tokens: usize,
    pub hp_bits: u32,
    /// Steady-state width (the "KV4" of the tables).
    pub lp_bits: u32,
    /// Tokens per packed block — also the span of the block-wise sequence
    /// transform. The fp32 tail holds at most `block − 1` tokens.
    pub block: usize,
    /// `false` keeps every token fp32 (the parity/reference cache); the
    /// remaining fields are then ignored.
    pub packed: bool,
    /// Block-wise sequence transform applied before quantization
    /// (`Identity` = plain two-level rows). 2-D kinds are rejected:
    /// decode streams are 1-D.
    pub transform: SeqTransformKind,
    /// Optional token capacity. `None` (the default) keeps the pre-PR-4
    /// behavior: the stream grows unboundedly and it is the *caller's* job
    /// to respect the model's `max_seq`. With `Some(cap)`,
    /// [`KvStream::try_append`] refuses — recoverably — to grow past `cap`
    /// tokens, so a decode engine can retire the stream with a truncation
    /// flag instead of panicking mid-batch (groundwork for the ROADMAP
    /// sliding-window/eviction item, which stays out of scope here).
    pub max_seq: Option<usize>,
}

impl Default for KvCacheConfig {
    /// The paper's main KV setting: 64 sink tokens at 8 bits, KV4
    /// steady-state, 32-token blocks, no block transform.
    fn default() -> Self {
        KvCacheConfig {
            hp_tokens: 64,
            hp_bits: 8,
            lp_bits: 4,
            block: 32,
            packed: true,
            transform: SeqTransformKind::Identity,
            max_seq: None,
        }
    }
}

impl KvCacheConfig {
    /// The fp32 reference cache (no quantization at all).
    pub fn fp32() -> Self {
        KvCacheConfig { packed: false, ..Default::default() }
    }

    /// Packed two-level cache with the given allocation and block size.
    pub fn two_level(hp_tokens: usize, hp_bits: u32, lp_bits: u32, block: usize) -> Self {
        KvCacheConfig { hp_tokens, hp_bits, lp_bits, block, ..Default::default() }
    }

    /// Builder-style block transform selection.
    pub fn with_transform(mut self, kind: SeqTransformKind) -> Self {
        self.transform = kind;
        self
    }

    /// Builder-style token capacity (see [`KvCacheConfig::max_seq`]).
    pub fn with_max_seq(mut self, cap: usize) -> Self {
        self.max_seq = Some(cap);
        self
    }

    /// Field-specific error when the packed lanes or block transforms
    /// cannot express this configuration; always `Ok` for fp32 caches.
    /// The config layer ([`crate::config::GenerateSpec::kv_cfg`]) surfaces
    /// this as a recoverable parse-time error.
    pub fn check(&self) -> Result<(), String> {
        if !self.packed {
            return Ok(());
        }
        if self.block == 0 {
            return Err("kv.block must be ≥ 1".into());
        }
        if self.lp_bits != 4 && self.lp_bits != 8 {
            return Err(format!("packed kv lanes are 4- or 8-bit, got lp_bits = {}", self.lp_bits));
        }
        if self.hp_tokens > 0 && self.hp_bits != 4 && self.hp_bits != 8 {
            return Err(format!("packed kv lanes are 4- or 8-bit, got hp_bits = {}", self.hp_bits));
        }
        match self.transform {
            SeqTransformKind::Identity | SeqTransformKind::Dct => Ok(()),
            SeqTransformKind::HaarDwt if self.block % 2 != 0 => {
                Err(format!("HaarDwt kv blocks need an even block size, got {}", self.block))
            }
            SeqTransformKind::Wht if !self.block.is_power_of_two() => {
                Err(format!("WHT kv blocks need a power-of-two block size, got {}", self.block))
            }
            SeqTransformKind::HaarDwt2d { .. } => {
                Err("2-D sequence transforms do not apply to 1-D decode streams".into())
            }
            _ => Ok(()),
        }
    }

    /// Panicking form of [`KvCacheConfig::check`], for construction sites
    /// where an invalid config is a programming error.
    pub fn validate(&self) {
        if let Err(e) = self.check() {
            panic!("{e}");
        }
    }

    /// The block-wise transform instance (`None` for identity / fp32).
    fn block_transform(&self) -> Option<Box<dyn SequenceTransform>> {
        if !self.packed {
            return None;
        }
        match self.transform {
            SeqTransformKind::Identity => None,
            SeqTransformKind::HaarDwt => {
                // Same depth policy as `Stamp`: up to the paper's 3 levels,
                // bounded by the block's divisibility.
                let levels = HaarDwt::max_levels(self.block).clamp(1, 3);
                Some(Box::new(HaarDwt::new(self.block, levels)))
            }
            SeqTransformKind::Dct => Some(Box::new(DctTransform::new(self.block))),
            SeqTransformKind::Wht => Some(Box::new(WhtTransform::new(self.block))),
            SeqTransformKind::HaarDwt2d { .. } => {
                panic!("2-D sequence transforms do not apply to 1-D decode streams")
            }
        }
    }
}

/// One K or V token stream: finalized packed blocks + fp32 tail window.
pub struct KvStream {
    cfg: KvCacheConfig,
    /// Built once per stream; every block shares it (blocks have one
    /// fixed length, `cfg.block`).
    transform: Option<Box<dyn SequenceTransform>>,
    /// Finalized blocks, `cfg.block` tokens each, oldest first.
    blocks: Vec<QTensor>,
    /// Dequantized (+ inverse-transformed) fp32 view of the finalized
    /// blocks, grown incrementally at flush time. Finalized blocks are
    /// immutable, so decompressing once per flush instead of once per
    /// [`KvStream::gather`] keeps the per-step decode cost O(copy) rather
    /// than O(re-dequantize · history). Serving scratch only: the packed
    /// blocks remain the stored representation and the sole input to
    /// [`KvStream::storage_bits`].
    decoded: Option<Tensor>,
    /// Recent tokens not yet covering a full block (always `Some` with
    /// ≥ 1 row when non-empty; `packed = false` keeps everything here).
    tail: Option<Tensor>,
    /// Feature width, fixed by the first append.
    dim: Option<usize>,
    /// Total tokens appended.
    len: usize,
}

impl KvStream {
    pub fn new(cfg: KvCacheConfig) -> Self {
        cfg.validate();
        let transform = cfg.block_transform();
        KvStream { cfg, transform, blocks: Vec::new(), decoded: None, tail: None, dim: None, len: 0 }
    }

    /// Tokens appended so far.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Feature width (`None` until the first append).
    pub fn dim(&self) -> Option<usize> {
        self.dim
    }

    /// Finalized packed blocks.
    pub fn n_blocks(&self) -> usize {
        self.blocks.len()
    }

    /// Tokens currently in the fp32 tail window.
    pub fn tail_len(&self) -> usize {
        self.tail.as_ref().map_or(0, Tensor::rows)
    }

    /// Tokens still appendable before the [`KvCacheConfig::max_seq`] bound
    /// (`None` = unbounded).
    pub fn remaining(&self) -> Option<usize> {
        self.cfg.max_seq.map(|cap| cap.saturating_sub(self.len))
    }

    /// Append `m` new tokens (an `m×d` matrix, oldest first). Completed
    /// blocks flush immediately; partial tokens wait in the fp32 tail.
    /// Panics when the stream is capacity-bounded and full — callers that
    /// need to recover (the decode engine retiring a stream with a
    /// truncation flag) use [`KvStream::try_append`] or check
    /// [`KvStream::remaining`] first.
    pub fn append(&mut self, rows: &Tensor) {
        if let Err(e) = self.try_append(rows) {
            panic!("{e}");
        }
    }

    /// [`KvStream::append`] with the capacity bound surfaced as a
    /// recoverable [`crate::error::Error`] instead of a panic. Shape and
    /// feature-width violations remain panics: those are programming
    /// errors, while running out of sequence budget is a normal condition
    /// under real traffic.
    pub fn try_append(&mut self, rows: &Tensor) -> crate::error::Result<()> {
        assert_eq!(rows.ndim(), 2, "kv append expects a 2-D m×d tensor");
        if rows.rows() == 0 {
            return Ok(());
        }
        if let Some(cap) = self.cfg.max_seq {
            if self.len + rows.rows() > cap {
                crate::bail!(
                    "kv stream at capacity: {} stored + {} new tokens exceeds max_seq {cap}",
                    self.len,
                    rows.rows()
                );
            }
        }
        match self.dim {
            Some(d) => assert_eq!(rows.cols(), d, "kv append feature width changed"),
            None => self.dim = Some(rows.cols()),
        }
        self.tail = Some(match self.tail.take() {
            Some(t) => t.vcat(rows),
            None => rows.clone(),
        });
        self.len += rows.rows();
        if self.cfg.packed {
            while self.tail_len() >= self.cfg.block {
                self.flush_block();
            }
        }
        Ok(())
    }

    /// Quantize the oldest `block` tail tokens into a finalized packed
    /// block. Only ever called with a full block accumulated — the flush
    /// rule that keeps block-wise transforms causal (module docs).
    fn flush_block(&mut self) {
        let tail = self.tail.take().expect("flush with empty tail");
        let b = self.cfg.block;
        let block = tail.slice_rows(0, b);
        self.tail = if tail.rows() > b { Some(tail.slice_rows(b, tail.rows())) } else { None };
        // The block's absolute start position decides how many of its rows
        // fall under the hp (sink) budget. Transforms concentrate the
        // block's energy into the leading coefficients, so the hp rows are
        // the leading ones in either domain and the accounting is
        // position-equivalent.
        let base = self.blocks.len() * b;
        let hp_rows = self.cfg.hp_tokens.saturating_sub(base).min(b);
        let bits = BitAllocation::two_level(hp_rows, self.cfg.hp_bits, self.cfg.lp_bits);
        let coeffs = match &self.transform {
            Some(t) => t.forward(&block),
            None => block,
        };
        let q = QTensor::quantize(&coeffs, &bits, Granularity::PerToken);
        // Decompress the (now immutable) block exactly once — what every
        // later gather will read for these tokens.
        let deq = q.dequantize();
        let view = match &self.transform {
            Some(t) => t.inverse(&deq),
            None => deq,
        };
        self.decoded = Some(match self.decoded.take() {
            Some(d) => d.vcat(&view),
            None => view,
        });
        self.blocks.push(q);
    }

    /// Materialize the full stream as a `len×d` fp32 matrix for attention:
    /// finalized blocks read from the flush-time decompressed view (each
    /// block dequantized + inverse-transformed exactly once, at flush),
    /// the fp32 tail copies through exactly.
    pub fn gather(&self) -> Tensor {
        let d = match self.dim {
            Some(d) => d,
            None => return Tensor::zeros(&[0, 0]),
        };
        let mut out = Tensor::zeros(&[self.len, d]);
        let mut r = 0usize;
        if let Some(dec) = &self.decoded {
            out.data_mut()[..dec.len()].copy_from_slice(dec.data());
            r += dec.rows();
        }
        if let Some(t) = &self.tail {
            let start = r * d;
            out.data_mut()[start..start + t.len()].copy_from_slice(t.data());
            r += t.rows();
        }
        debug_assert_eq!(r, self.len);
        out
    }

    /// Physical storage footprint in bits: the packed payload plus 16-bit
    /// scale + 16-bit zero per group for finalized blocks (the Appendix-C
    /// accounting, [`QTensor::storage_bits`]), and 32 bits/element for the
    /// fp32 tail.
    pub fn storage_bits(&self) -> usize {
        let packed: usize = self.blocks.iter().map(QTensor::storage_bits).sum();
        packed + self.tail.as_ref().map_or(0, |t| t.len() * 32)
    }

    /// [`KvStream::storage_bits`] per stored element (0 when empty).
    pub fn average_storage_bits(&self) -> f64 {
        match self.dim {
            Some(d) if self.len > 0 => self.storage_bits() as f64 / (self.len * d) as f64,
            _ => 0.0,
        }
    }
}

/// Per-layer K and V streams (what
/// [`crate::model::attention::MultiHeadAttention::forward_decode`]
/// consumes).
pub struct KvLayer {
    pub k: KvStream,
    pub v: KvStream,
}

impl KvLayer {
    pub fn new(cfg: KvCacheConfig) -> Self {
        KvLayer { k: KvStream::new(cfg.clone()), v: KvStream::new(cfg) }
    }

    /// fp32 reference layer (parity path).
    pub fn fp32() -> Self {
        KvLayer::new(KvCacheConfig::fp32())
    }
}

/// Whole-model cache: one [`KvLayer`] per transformer block, advancing in
/// lock-step through [`crate::model::Gpt::prefill`] /
/// [`crate::model::Gpt::decode_step`].
pub struct KvCache {
    layers: Vec<KvLayer>,
}

impl KvCache {
    pub fn new(n_layers: usize, cfg: KvCacheConfig) -> Self {
        assert!(n_layers >= 1, "cache needs at least one layer");
        let layers = (0..n_layers).map(|_| KvLayer::new(cfg.clone())).collect();
        KvCache { layers }
    }

    /// fp32 reference cache (parity path).
    pub fn fp32(n_layers: usize) -> Self {
        KvCache::new(n_layers, KvCacheConfig::fp32())
    }

    pub fn n_layers(&self) -> usize {
        self.layers.len()
    }

    /// Tokens appended so far (layers advance in lock-step during a
    /// forward, so layer 0's K stream is authoritative).
    pub fn len(&self) -> usize {
        self.layers[0].k.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Tokens still appendable before the configured capacity (`None` =
    /// unbounded). Layers advance in lock-step, so layer 0's K stream is
    /// authoritative here too.
    pub fn remaining(&self) -> Option<usize> {
        self.layers[0].k.remaining()
    }

    pub fn layer(&self, l: usize) -> &KvLayer {
        &self.layers[l]
    }

    pub fn layer_mut(&mut self, l: usize) -> &mut KvLayer {
        &mut self.layers[l]
    }

    /// Total footprint across all layers and both streams.
    pub fn storage_bits(&self) -> usize {
        self.layers.iter().map(|l| l.k.storage_bits() + l.v.storage_bits()).sum()
    }

    /// Mean bits per stored K/V element across the whole cache.
    pub fn average_storage_bits(&self) -> f64 {
        let elems: usize = self
            .layers
            .iter()
            .map(|l| {
                l.k.dim().map_or(0, |d| l.k.len() * d) + l.v.dim().map_or(0, |d| l.v.len() * d)
            })
            .sum();
        if elems == 0 {
            0.0
        } else {
            self.storage_bits() as f64 / elems as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::quantize_dequantize_rows;
    use crate::stats::sqnr;

    fn cfg(hp: usize, hp_bits: u32, lp: u32, block: usize) -> KvCacheConfig {
        KvCacheConfig::two_level(hp, hp_bits, lp, block)
    }

    #[test]
    fn fp32_gather_is_exact() {
        let mut st = KvStream::new(KvCacheConfig::fp32());
        let a = Tensor::randn(&[5, 8], 1);
        let b = Tensor::randn(&[3, 8], 2);
        st.append(&a);
        st.append(&b);
        assert_eq!(st.len(), 8);
        assert_eq!(st.n_blocks(), 0, "fp32 cache never flushes");
        assert_eq!(st.gather(), a.vcat(&b), "fp32 gather must be bit-exact");
        assert_eq!(st.storage_bits(), 8 * 8 * 32);
    }

    #[test]
    fn flush_boundaries_and_tail_window() {
        let mut st = KvStream::new(cfg(0, 8, 4, 8));
        // 20 tokens in odd chunks: 2 full blocks + 4 tail tokens.
        let x = Tensor::randn(&[20, 6], 3);
        st.append(&x.slice_rows(0, 7));
        assert_eq!((st.n_blocks(), st.tail_len()), (0, 7));
        st.append(&x.slice_rows(7, 9));
        assert_eq!((st.n_blocks(), st.tail_len()), (1, 1));
        st.append(&x.slice_rows(9, 20));
        assert_eq!((st.n_blocks(), st.tail_len()), (2, 4));
        assert_eq!(st.len(), 20);
        // Tail rows are exact fp32 copies.
        let g = st.gather();
        for i in 16..20 {
            assert_eq!(g.row(i), x.row(i), "tail row {i} must be exact");
        }
    }

    #[test]
    fn identity_blocks_match_qdq_oracle_bit_for_bit() {
        // Per-token QDQ is row-independent, so with an identity transform
        // the flushed region must equal the one-shot simulated QDQ under
        // the same positional two-level policy.
        let (s, d, block, hp) = (37usize, 12usize, 8usize, 11usize);
        let x = Tensor::randn(&[s, d], 5);
        let mut st = KvStream::new(cfg(hp, 8, 4, block));
        st.append(&x);
        let g = st.gather();
        let flushed = (s / block) * block;
        let want = quantize_dequantize_rows(
            &x.slice_rows(0, flushed),
            &BitAllocation::two_level(hp, 8, 4),
            Granularity::PerToken,
        );
        for i in 0..flushed {
            assert_eq!(g.row(i), want.row(i), "flushed row {i}");
        }
        for i in flushed..s {
            assert_eq!(g.row(i), x.row(i), "tail row {i}");
        }
    }

    #[test]
    fn transformed_blocks_roundtrip_closely() {
        // 8-bit blocks through a Haar DWT: gather must reconstruct the
        // input to 8-bit fidelity (transform is orthonormal; only the
        // coefficient rounding remains), and the tail stays exact.
        let (s, d, block) = (70usize, 16usize, 16usize);
        let x = Tensor::randn(&[s, d], 7);
        for kind in [SeqTransformKind::HaarDwt, SeqTransformKind::Dct, SeqTransformKind::Wht] {
            let mut st = KvStream::new(cfg(0, 8, 8, block).with_transform(kind));
            st.append(&x);
            let g = st.gather();
            let s_db = sqnr(&x, &g);
            assert!(s_db > 35.0, "{kind:?}: round-trip SQNR {s_db} dB");
            for i in (s / block) * block..s {
                assert_eq!(g.row(i), x.row(i), "{kind:?} tail row {i}");
            }
        }
    }

    #[test]
    fn incremental_append_equals_batch_append() {
        let (s, d, block) = (41usize, 10usize, 8usize);
        let x = Tensor::randn(&[s, d], 9);
        let mk = || KvStream::new(cfg(6, 8, 4, block).with_transform(SeqTransformKind::HaarDwt));
        let mut batch = mk();
        batch.append(&x);
        let mut inc = mk();
        for i in 0..s {
            inc.append(&x.slice_rows(i, i + 1));
        }
        assert_eq!(inc.gather(), batch.gather(), "append granularity must not matter");
        assert_eq!(inc.storage_bits(), batch.storage_bits());
        assert_eq!(inc.n_blocks(), batch.n_blocks());
    }

    #[test]
    fn storage_accounting_two_level_across_block_boundary() {
        // hp_tokens = 12 spans 1.5 blocks of 8: block 0 all-hp, block 1
        // half-hp — Appendix-C accounting per row: payload bits·d + 32
        // (fp16 scale + zero, per-token granularity).
        let (s, d, block, hp) = (32usize, 16usize, 8usize, 12usize);
        let x = Tensor::randn(&[s, d], 11);
        let mut st = KvStream::new(cfg(hp, 8, 4, block));
        st.append(&x);
        let expect: usize =
            (0..s).map(|i| if i < hp { 8 * d + 32 } else { 4 * d + 32 }).sum();
        assert_eq!(st.storage_bits(), expect);
        assert_eq!(st.n_blocks(), 4);
    }

    #[test]
    fn append_and_gather_thread_count_invariant() {
        // Blocks of 256×512 clear MIN_PARALLEL_ELEMS, so the flush-time
        // packing + decompression fan out on multi-core hosts; a stream
        // built with serial kernels must be byte-identical.
        let x = Tensor::randn(&[512, 512], 13);
        let mk = || KvStream::new(cfg(64, 8, 4, 256));
        let mut threaded = mk();
        threaded.append(&x);
        let g_threaded = threaded.gather();
        crate::parallel::set_kernel_serial(true);
        let mut serial = mk();
        serial.append(&x);
        let g_serial = serial.gather();
        crate::parallel::set_kernel_serial(false);
        assert_eq!(g_threaded, g_serial, "cache must not depend on thread count");
        assert_eq!(threaded.storage_bits(), serial.storage_bits());
    }

    #[test]
    fn whole_cache_storage_and_average() {
        let mut cache = KvCache::new(2, cfg(0, 8, 4, 16));
        for _ in 0..32 {
            let k = Tensor::randn(&[1, 8], 17);
            let v = Tensor::randn(&[1, 8], 18);
            for l in 0..2 {
                cache.layer_mut(l).k.append(&k);
                cache.layer_mut(l).v.append(&v);
            }
        }
        assert_eq!(cache.len(), 32);
        // All-lp, fully flushed: 4 payload + 32/8 param bits per element.
        let avg = cache.average_storage_bits();
        assert!((avg - 8.0).abs() < 1e-9, "avg {avg}");
        assert_eq!(cache.storage_bits(), 2 * 2 * 32 * (4 * 8 + 32));
    }

    #[test]
    #[should_panic(expected = "even block size")]
    fn rejects_odd_block_for_dwt() {
        let _ = KvStream::new(cfg(0, 8, 4, 7).with_transform(SeqTransformKind::HaarDwt));
    }

    #[test]
    #[should_panic(expected = "4- or 8-bit")]
    fn rejects_unpackable_lp_bits() {
        let _ = KvStream::new(cfg(0, 8, 6, 8));
    }

    #[test]
    #[should_panic(expected = "1-D decode streams")]
    fn rejects_2d_transform() {
        let _ = KvStream::new(
            cfg(0, 8, 4, 16).with_transform(SeqTransformKind::HaarDwt2d { h: 4, w: 4 }),
        );
    }

    #[test]
    fn empty_and_width_guards() {
        let mut st = KvStream::new(KvCacheConfig::default());
        st.append(&Tensor::zeros(&[0, 4]));
        assert!(st.is_empty());
        assert_eq!(st.gather().shape(), &[0, 0]);
        assert_eq!(st.average_storage_bits(), 0.0);
    }

    #[test]
    fn capacity_bound_is_recoverable() {
        let mut st = KvStream::new(KvCacheConfig::fp32().with_max_seq(5));
        assert_eq!(st.remaining(), Some(5));
        st.append(&Tensor::randn(&[3, 4], 21));
        assert_eq!(st.remaining(), Some(2));
        // Overflow via try_append is a recoverable error that leaves the
        // stream untouched…
        let err = st.try_append(&Tensor::randn(&[3, 4], 22)).unwrap_err();
        assert!(err.to_string().contains("at capacity"), "{err}");
        assert_eq!(st.len(), 3);
        // …and an exact fill is fine.
        st.try_append(&Tensor::randn(&[2, 4], 23)).unwrap();
        assert_eq!((st.len(), st.remaining()), (5, Some(0)));
        // Unbounded streams report no capacity.
        let un = KvStream::new(KvCacheConfig::fp32());
        assert_eq!(un.remaining(), None);
        // Whole-cache view mirrors layer 0.
        let cache = KvCache::new(2, KvCacheConfig::fp32().with_max_seq(7));
        assert_eq!(cache.remaining(), Some(7));
    }

    #[test]
    #[should_panic(expected = "at capacity")]
    fn bounded_append_panics_past_capacity() {
        let mut st = KvStream::new(KvCacheConfig::fp32().with_max_seq(2));
        st.append(&Tensor::randn(&[3, 4], 25));
    }

    #[test]
    #[should_panic(expected = "feature width changed")]
    fn rejects_width_change() {
        let mut st = KvStream::new(KvCacheConfig::fp32());
        st.append(&Tensor::zeros(&[1, 4]));
        st.append(&Tensor::zeros(&[1, 5]));
    }
}
