//! `stamp` — leader entrypoint for the STaMP reproduction.
//!
//! Subcommands regenerate every table/figure of the paper (DESIGN.md §5),
//! run the quantized-variant serving demo over the coordinator, and train
//! the tiny evaluation models. See `stamp help`.

use stamp::error::Result;
use stamp::baselines::{BaselineKind, QuantHook, QuantStack};
use stamp::cli::{emit, Args, HELP};
use stamp::config::RunConfig;
use stamp::coordinator::{Executor, Server};
use stamp::data::{ActivationGenerator, ActivationSpec};
use stamp::eval::tables::{self, TableOpts};
use stamp::eval::{figures, perplexity};
use stamp::model::FpHook;
use stamp::quant::BitAllocation;
use stamp::report::Table;
use stamp::tensor::Tensor;
use stamp::transforms::{HaarDwt, IdentitySeq, SequenceTransform};
use std::sync::Arc;
use std::time::Duration;

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv);
    match args.command.as_str() {
        "eval" => cmd_eval(&args),
        "report" => cmd_report(&args),
        "serve" => cmd_serve(&args),
        "train" => cmd_train(&args),
        "info" => cmd_info(),
        _ => {
            print!("{HELP}");
            Ok(())
        }
    }
}

fn opts_for(args: &Args) -> TableOpts {
    if args.has_flag("fast") {
        TableOpts::fast()
    } else {
        TableOpts::full()
    }
}

fn cmd_eval(args: &Args) -> Result<()> {
    let what = args.positional.first().map(|s| s.as_str()).unwrap_or("table2");
    let opts = opts_for(args);
    let csv = args.csv_dir();
    match what {
        "table1" => emit(&tables::table1_lvm(&opts), csv.as_deref()),
        "table2" => emit(&tables::table2_llm(&opts), csv.as_deref()),
        "table4" => emit(&tables::table4_sites(&opts), csv.as_deref()),
        "table5" => emit(&tables::table5_metrics(&opts), csv.as_deref()),
        "fig4b" => emit(&tables::fig4b_sweep(&opts), csv.as_deref()),
        "fig7" => {
            let (lvm, llm) = tables::fig7_grid(&opts);
            emit(&lvm, csv.as_deref());
            emit(&llm, csv.as_deref());
        }
        "fig9" => emit(&tables::fig9_blockq(&opts), csv.as_deref()),
        other => stamp::bail!("unknown eval target `{other}` (see `stamp help`)"),
    }
    Ok(())
}

fn cmd_report(args: &Args) -> Result<()> {
    let what = args.positional.first().map(|s| s.as_str()).unwrap_or("fig2");
    let csv = args.csv_dir();
    // Shared activation source: LLM-preset AR(1) (Fig 3 right).
    let gen = ActivationGenerator::new(ActivationSpec {
        outlier_channels: 0,
        sink_scale: 0.0,
        ..ActivationSpec::llm(128, 64)
    });
    let samples = gen.calibration_set(16, 0xF16);
    match what {
        "fig2" => {
            let x = &samples[0];
            let mut t = Table::new(
                "Figure 2b: Theorem-1 bound vs measured error (avg 3..8 bits)",
                &["avg_bits", "scheme", "measured", "bound"],
            );
            let id = IdentitySeq::new(128);
            let dwt = HaarDwt::new(128, 3);
            for b in 3u32..=8 {
                for (name, tr, alloc) in [
                    ("uniform", &id as &dyn SequenceTransform, BitAllocation::uniform(b)),
                    (
                        "STaMP(dwt,2-level)",
                        &dwt as &dyn SequenceTransform,
                        // 16 hp tokens of 128 at 8b → avg slightly above b−1.
                        BitAllocation::two_level(16, 8, b.saturating_sub(1).max(1)),
                    ),
                ] {
                    let pts = figures::fig2_bound_curve(x, tr, &[alloc.clone()]);
                    let p = &pts[0];
                    t.row(vec![
                        format!("{:.2}", p.avg_bits),
                        name.into(),
                        format!("{:.4}", p.measured_error),
                        format!("{:.4}", p.bound),
                    ]);
                }
            }
            emit(&t, csv.as_deref());
        }
        "fig3" => {
            let sp = figures::fig3_energy_spectra(&samples);
            let mut t = Table::new(
                "Figure 3b: cumulative energy share of top-k transformed tokens",
                &["k", "identity", "KLT", "DCT", "WHT", "DWT"],
            );
            for k in [1usize, 2, 4, 8, 16, 32, 64, 128] {
                t.row(vec![
                    k.to_string(),
                    format!("{:.3}", figures::topk_share(&sp.identity, k)),
                    format!("{:.3}", figures::topk_share(&sp.klt, k)),
                    format!("{:.3}", figures::topk_share(&sp.dct, k)),
                    format!("{:.3}", figures::topk_share(&sp.wht, k)),
                    format!("{:.3}", figures::topk_share(&sp.dwt, k)),
                ]);
            }
            emit(&t, csv.as_deref());
            // Fig 3a: lag profile of the autocorrelation.
            let ac = figures::fig3_autocorrelation(&samples);
            let prof = stamp::stats::lag_profile(&ac);
            let mut t = Table::new(
                "Figure 3a: autocorrelation lag profile (Toeplitz check)",
                &["lag", "normalized |S[i,i+lag]|"],
            );
            for lag in [0usize, 1, 2, 4, 8, 16, 32, 64] {
                t.row(vec![lag.to_string(), format!("{:.4}", prof[lag])]);
            }
            emit(&t, csv.as_deref());
        }
        "fig4a" => {
            let eig = figures::autocorr_eigenvalues(&samples);
            let energies: Vec<f64> = eig.iter().map(|&l| (l as f64).max(1e-12)).collect();
            let mut t = Table::new(
                "Figure 4a: bit-allocation objective at avg 5 bits",
                &["strategy", "objective (Σ e/2^2b)"],
            );
            let c = figures::fig4a_allocations(&energies, 5.0, 16);
            t.row(vec!["uniform, no transform".into(), format!("{:.5}", c.uniform_objective)]);
            t.row(vec!["optimal continuous".into(), format!("{:.5}", c.optimal_objective)]);
            t.row(vec!["2-level {8,low}".into(), format!("{:.5}", c.two_level_objective)]);
            emit(&t, csv.as_deref());
        }
        other => stamp::bail!("unknown report target `{other}`"),
    }
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let variant = args.positional.first().map(|s| s.as_str()).unwrap_or("small");
    let steps: usize = args.flag("steps").map(|s| s.parse()).transpose()?.unwrap_or(300);
    println!("training GPT `{variant}` for {steps} steps on the synthetic corpus…");
    let t0 = std::time::Instant::now();
    let (gpt, corpus) = stamp::train::build_trained_model(variant, steps);
    let seqs_all = corpus.sequences(256);
    let seqs: Vec<&[u32]> = seqs_all.iter().take(4).cloned().collect();
    let ppl = perplexity(&gpt, &FpHook, &seqs);
    println!(
        "done in {:.1?}: {} params, eval FP perplexity {:.2}",
        t0.elapsed(),
        gpt.n_params(),
        ppl
    );
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let cfg = match args.flag("config") {
        Some(path) => RunConfig::from_file(path)?,
        None => RunConfig::defaults(),
    };
    let n_requests: usize = args.flag("requests").map(|s| s.parse()).transpose()?.unwrap_or(64);
    println!("serve: building quantized variants ({} workers)…", cfg.serve.workers);

    // Build a small DiT and three quant variants as the served "models".
    let dit = Arc::new(stamp::model::Dit::new(
        stamp::model::DitConfig { steps: 2, ..stamp::model::DitConfig::pixart() },
        0xD17,
    ));
    let stats = tables::calibrate_dit(&dit);
    let opts = TableOpts::fast();
    let mk_stack = |kind: BaselineKind, stamp: bool| -> QuantStack {
        let act = stamp::baselines::ActQuantCfg {
            bits: cfg.quant.act_bits,
            hp_tokens: opts.hp_tokens,
            hp_bits: cfg.quant.hp_bits,
            granularity: stamp::quant::Granularity::PerToken,
            range_shrink: 1.0,
        };
        let mut s = QuantStack::build(kind, &stats, Some(act), None, None, 1).with_lvm_skips();
        if stamp {
            s = s.with_stamp(QuantStack::lvm_stamp(dit.cfg.grid_h, dit.cfg.grid_w));
        }
        s
    };
    let variants: Vec<(String, QuantStack)> = vec![
        ("fp".into(), QuantStack::fp()),
        ("rtn-a4".into(), mk_stack(BaselineKind::Rtn, false)),
        ("rtn-a4+stamp".into(), mk_stack(BaselineKind::Rtn, true)),
    ];
    let names: Vec<String> = variants.iter().map(|(n, _)| n.clone()).collect();
    let name_refs: Vec<&str> = names.iter().map(|s| s.as_str()).collect();

    let dit_exec = dit.clone();
    let stacks: std::collections::HashMap<String, QuantStack> = variants.into_iter().collect();
    let executor: Arc<dyn Executor> = Arc::new(move |variant: &str, inputs: &[&Tensor]| {
        let stack = stacks.get(variant).ok_or_else(|| format!("no stack for {variant}"))?;
        let hook = QuantHook::new(stack);
        Ok(inputs
            .iter()
            .map(|z| dit_exec.denoise_step(&hook, z, "serving demo prompt", 0))
            .collect())
    });

    let server = Server::start(&cfg.serve, &name_refs, executor);
    let handle = server.handle();
    println!("submitting {n_requests} denoise requests round-robin over {names:?}…");
    let t0 = std::time::Instant::now();
    let gen = ActivationGenerator::new(ActivationSpec::lvm(dit.cfg.grid_h, dit.cfg.grid_w, dit.latent_dim));
    let receivers: Vec<_> = (0..n_requests)
        .map(|i| {
            let variant = &names[i % names.len()];
            handle.submit(variant, gen.sample(i as u64)).1
        })
        .collect();
    let mut ok = 0usize;
    for rx in &receivers {
        if rx.recv_timeout(Duration::from_secs(60)).map(|r| r.output.is_ok()).unwrap_or(false) {
            ok += 1;
        }
    }
    let elapsed = t0.elapsed();
    println!(
        "{ok}/{n_requests} ok in {elapsed:.1?} ({:.1} req/s)\n--- metrics ---\n{}",
        n_requests as f64 / elapsed.as_secs_f64(),
        handle.metrics.snapshot()
    );
    server.shutdown();
    Ok(())
}

fn cmd_info() -> Result<()> {
    println!("stamp reproduction — crate {}", env!("CARGO_PKG_VERSION"));
    println!(
        "threads: {} (STAMP_THREADS={})",
        stamp::parallel::num_threads(),
        std::env::var("STAMP_THREADS").unwrap_or_else(|_| "unset".into())
    );
    #[cfg(feature = "pjrt")]
    match stamp::runtime::Engine::cpu() {
        Ok(engine) => {
            println!("PJRT platform: {} ({} device(s))", engine.platform(), engine.device_count());
        }
        Err(e) => println!("PJRT unavailable: {e}"),
    }
    #[cfg(not(feature = "pjrt"))]
    println!("PJRT: disabled (build with `--features pjrt`; native executor always available)");
    match stamp::runtime::ArtifactRegistry::load("artifacts") {
        Ok(reg) => {
            println!("artifacts ({}):", reg.entries().len());
            for e in reg.entries() {
                println!("  {:<24} {} (inputs {})", e.name, e.file, e.inputs);
            }
        }
        Err(_) => println!("no artifacts yet — run `make artifacts`"),
    }
    Ok(())
}
