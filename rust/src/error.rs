//! Crate-wide error type: a minimal, dependency-free stand-in for `anyhow`
//! (DESIGN.md §3 crate-availability substitutions).
//!
//! The repo's error handling is message-shaped — configs that fail to
//! parse, artifacts that fail to load — so a single string-carrying
//! [`Error`] plus the `err!`/`bail!` macros (exported at the crate root)
//! cover every call site without pulling a dependency into the default
//! build.
//!
//! ```
//! use stamp::error::{Error, Result};
//!
//! fn parse_bits(s: &str) -> Result<u32> {
//!     let b: u32 = s.parse()?; // std error types convert via `?`
//!     if b == 0 {
//!         stamp::bail!("bit width must be positive, got `{s}`");
//!     }
//!     Ok(b)
//! }
//!
//! assert_eq!(parse_bits("4").unwrap(), 4);
//! assert!(parse_bits("zero").is_err());
//! assert!(parse_bits("0").unwrap_err().to_string().contains("positive"));
//! ```

/// A boxed, message-carrying error.
#[derive(Debug)]
pub struct Error {
    msg: String,
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

impl Error {
    /// Build an error from anything displayable.
    pub fn msg(m: impl std::fmt::Display) -> Self {
        Error { msg: m.to_string() }
    }
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

impl From<String> for Error {
    fn from(s: String) -> Self {
        Error { msg: s }
    }
}

impl From<&str> for Error {
    fn from(s: &str) -> Self {
        Error { msg: s.to_string() }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::msg(e)
    }
}

impl From<std::num::ParseIntError> for Error {
    fn from(e: std::num::ParseIntError) -> Self {
        Error::msg(e)
    }
}

impl From<std::num::ParseFloatError> for Error {
    fn from(e: std::num::ParseFloatError) -> Self {
        Error::msg(e)
    }
}

impl From<std::fmt::Error> for Error {
    fn from(e: std::fmt::Error) -> Self {
        Error::msg(e)
    }
}

/// Construct an [`Error`](crate::error::Error) from a format string.
#[macro_export]
macro_rules! err {
    ($($arg:tt)*) => {
        $crate::error::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`](crate::error::Error) built from a format
/// string (the `anyhow::bail!` shape).
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::err!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn displays_message() {
        let e = Error::msg("boom");
        assert_eq!(e.to_string(), "boom");
        assert!(format!("{e:?}").contains("boom"));
    }

    #[test]
    fn converts_from_std_errors() {
        fn inner() -> Result<u32> {
            Ok("17".parse::<u32>()?)
        }
        assert_eq!(inner().unwrap(), 17);
        fn bad() -> Result<u32> {
            Ok("x".parse::<u32>()?)
        }
        assert!(bad().is_err());
    }

    #[test]
    fn macros_format() {
        let e = err!("bad value `{}`", 7);
        assert_eq!(e.to_string(), "bad value `7`");
        fn f(x: i32) -> Result<i32> {
            if x < 0 {
                bail!("negative: {x}");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert!(f(-1).unwrap_err().to_string().contains("negative"));
    }
}
