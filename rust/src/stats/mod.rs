//! Activation statistics: autocorrelation estimation, energy spectra,
//! SQNR, and range/outlier summaries. These drive the KLT calibration, the
//! Figure-3 reproductions, and every fidelity number in the tables.

use crate::tensor::{matmul, Tensor};

/// Signal-to-quantization-noise ratio in dB (paper §5.1):
/// `10·log₁₀(‖orig‖² / ‖orig − quant‖²)`. Returns `f64::INFINITY` for a
/// perfect reconstruction.
pub fn sqnr(orig: &Tensor, quant: &Tensor) -> f64 {
    let sig = orig.sq_norm();
    let noise = orig.sub(quant).sq_norm();
    if noise == 0.0 {
        return f64::INFINITY;
    }
    10.0 * (sig / noise).log10()
}

/// SQNR between two flat slices.
pub fn sqnr_slices(orig: &[f32], quant: &[f32]) -> f64 {
    assert_eq!(orig.len(), quant.len());
    let sig: f64 = orig.iter().map(|&v| (v as f64).powi(2)).sum();
    let noise: f64 =
        orig.iter().zip(quant).map(|(&a, &b)| ((a - b) as f64).powi(2)).sum();
    if noise == 0.0 {
        return f64::INFINITY;
    }
    10.0 * (sig / noise).log10()
}

/// Empirical sequence autocorrelation `S = E[XXᵀ]`, averaged over samples
/// and normalized by total feature count (matches [`crate::transforms::KltTransform::calibrate`]).
pub fn autocorrelation(samples: &[Tensor]) -> Tensor {
    assert!(!samples.is_empty());
    let s = samples[0].rows();
    let mut cov = Tensor::zeros(&[s, s]);
    let mut count = 0usize;
    for x in samples {
        assert_eq!(x.rows(), s);
        cov = cov.add(&matmul(x, &x.transpose()));
        count += x.cols();
    }
    cov.scale(1.0 / count as f32)
}

/// Per-token energies `e_i = ‖x_i‖²` of one activation matrix.
pub fn token_energies(x: &Tensor) -> Vec<f64> {
    (0..x.rows())
        .map(|i| x.row(i).iter().map(|&v| (v as f64).powi(2)).sum())
        .collect()
}

/// Fraction of total energy held by the first `k` tokens.
pub fn prefix_energy_share(x: &Tensor, k: usize) -> f64 {
    let e = token_energies(x);
    let total: f64 = e.iter().sum();
    if total == 0.0 {
        return 0.0;
    }
    e[..k.min(e.len())].iter().sum::<f64>() / total
}

/// Per-token ranges `max_j x_ij − min_j x_ij` (the quantity the min-max
/// scale is built from, Eq. 3).
pub fn token_ranges(x: &Tensor) -> Vec<f32> {
    (0..x.rows())
        .map(|i| {
            let r = x.row(i);
            let mx = r.iter().cloned().fold(f32::MIN, f32::max);
            let mn = r.iter().cloned().fold(f32::MAX, f32::min);
            mx - mn
        })
        .collect()
}

/// Per-channel absolute maxima (SmoothQuant calibration input).
pub fn channel_absmax(x: &Tensor) -> Vec<f32> {
    let d = x.cols();
    let mut m = vec![0.0f32; d];
    for i in 0..x.rows() {
        for (j, &v) in x.row(i).iter().enumerate() {
            m[j] = m[j].max(v.abs());
        }
    }
    m
}

/// Kurtosis of all entries — an outlier-heaviness summary used by the
/// synthetic-activation calibration tests (massive activations ⇒ κ ≫ 3).
pub fn kurtosis(x: &Tensor) -> f64 {
    let n = x.len() as f64;
    let mean = x.mean();
    let m2: f64 = x.data().iter().map(|&v| (v as f64 - mean).powi(2)).sum::<f64>() / n;
    let m4: f64 = x.data().iter().map(|&v| (v as f64 - mean).powi(4)).sum::<f64>() / n;
    if m2 == 0.0 {
        return 0.0;
    }
    m4 / (m2 * m2)
}

/// Off-diagonal-decay profile of an autocorrelation matrix: mean |S[i,j]|
/// at each lag, normalized by the mean diagonal. Near-Toeplitz matrices
/// show a smooth decay; Figure-3a's structure check.
pub fn lag_profile(s: &Tensor) -> Vec<f64> {
    let n = s.rows();
    let diag: f64 = (0..n).map(|i| s.at(i, i).abs() as f64).sum::<f64>() / n as f64;
    (0..n)
        .map(|lag| {
            let cnt = n - lag;
            let sum: f64 = (0..cnt).map(|i| s.at(i, i + lag).abs() as f64).sum();
            sum / (cnt as f64 * diag.max(1e-12))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sqnr_perfect_is_inf() {
        let x = Tensor::randn(&[4, 4], 1);
        assert_eq!(sqnr(&x, &x), f64::INFINITY);
    }

    #[test]
    fn sqnr_known_value() {
        // noise = signal/100 → 20 dB.
        let x = Tensor::full(&[1, 100], 1.0);
        let y = x.map(|v| v + 0.1);
        assert!((sqnr(&x, &y) - 20.0).abs() < 1e-4);
    }

    #[test]
    fn autocorrelation_of_ar1_matches() {
        use crate::linalg::{ar1_covariance, cholesky};
        let s = 24;
        let cov = ar1_covariance(s, 0.9, 1.0);
        let l = cholesky(&cov);
        let samples: Vec<Tensor> =
            (0..64).map(|i| l.matmul(&Tensor::randn(&[s, 32], i))).collect();
        let est = autocorrelation(&samples);
        // Relative error on the (0, 1) entry should be small.
        assert!((est.at(0, 1) - cov.at(0, 1)).abs() < 0.1, "{}", est.at(0, 1));
        assert!((est.at(5, 5) - 1.0).abs() < 0.15);
    }

    #[test]
    fn energies_and_prefix_share() {
        let mut x = Tensor::zeros(&[4, 2]);
        x.set(0, 0, 3.0);
        x.set(1, 0, 1.0);
        let e = token_energies(&x);
        assert_eq!(e, vec![9.0, 1.0, 0.0, 0.0]);
        assert!((prefix_energy_share(&x, 1) - 0.9).abs() < 1e-9);
        assert!((prefix_energy_share(&x, 4) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn ranges() {
        let x = Tensor::from_vec(&[2, 3], vec![1.0, 5.0, 3.0, -1.0, 0.0, 1.0]);
        assert_eq!(token_ranges(&x), vec![4.0, 2.0]);
    }

    #[test]
    fn channel_absmax_basic() {
        let x = Tensor::from_vec(&[2, 2], vec![1.0, -7.0, -2.0, 3.0]);
        assert_eq!(channel_absmax(&x), vec![2.0, 7.0]);
    }

    #[test]
    fn kurtosis_gaussian_near_3() {
        let x = Tensor::randn(&[128, 128], 5);
        let k = kurtosis(&x);
        assert!((k - 3.0).abs() < 0.3, "kurtosis {k}");
    }

    #[test]
    fn lag_profile_decays_for_ar1() {
        use crate::linalg::ar1_covariance;
        let prof = lag_profile(&ar1_covariance(16, 0.8, 1.0));
        assert!((prof[0] - 1.0).abs() < 1e-6);
        assert!(prof[1] > prof[4]);
        assert!(prof[4] > prof[10]);
    }
}
