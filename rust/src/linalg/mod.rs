//! Linear-algebra substrate: symmetric eigendecomposition (for the KLT),
//! Cholesky (for sampling correlated activations), Toeplitz builders, and
//! orthogonality checks used throughout the transform tests.

mod cholesky;
mod eig;
mod toeplitz;

pub use cholesky::cholesky;
pub use eig::{eigh, EigResult};
pub use toeplitz::{ar1_covariance, block_toeplitz_2d, toeplitz};

use crate::tensor::Tensor;

/// Max |QᵀQ − I| — zero for a perfectly orthogonal matrix.
pub fn orthogonality_defect(q: &Tensor) -> f32 {
    let qtq = q.transpose().matmul(q);
    qtq.max_abs_diff(&Tensor::eye(q.cols()))
}

/// Solve `L y = b` for lower-triangular `L` (forward substitution).
pub fn solve_lower(l: &Tensor, b: &[f32]) -> Vec<f32> {
    let n = l.rows();
    assert_eq!(b.len(), n);
    let mut y = vec![0.0f32; n];
    for i in 0..n {
        let mut acc = b[i];
        for j in 0..i {
            acc -= l.at(i, j) * y[j];
        }
        y[i] = acc / l.at(i, i);
    }
    y
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_is_orthogonal() {
        assert_eq!(orthogonality_defect(&Tensor::eye(8)), 0.0);
    }

    #[test]
    fn scaled_identity_is_not() {
        let q = Tensor::eye(4).scale(2.0);
        assert!(orthogonality_defect(&q) > 1.0);
    }

    #[test]
    fn solve_lower_roundtrip() {
        let l = Tensor::from_vec(&[2, 2], vec![2.0, 0.0, 1.0, 3.0]);
        let y = solve_lower(&l, &[4.0, 7.0]);
        assert!((y[0] - 2.0).abs() < 1e-6);
        assert!((y[1] - (7.0 - 2.0) / 3.0).abs() < 1e-6);
    }
}
