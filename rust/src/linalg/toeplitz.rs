//! Toeplitz and block-Toeplitz covariance builders.
//!
//! Figure 3 of the paper shows that the sequence autocorrelation
//! `S = E[XXᵀ]` of LLM activations is approximately Toeplitz (stationary
//! local correlation), and LVM activations are *block*-Toeplitz because a
//! 2-D token grid is flattened row-major into a 1-D sequence. These
//! builders produce the idealized versions used by the synthetic activation
//! generator and by the Szegő-approximation tests (DCT ≈ KLT eigenbasis).

use crate::tensor::Tensor;

/// Symmetric Toeplitz matrix from its first row `r` (r[0] = diagonal).
pub fn toeplitz(r: &[f32]) -> Tensor {
    let n = r.len();
    let mut t = Tensor::zeros(&[n, n]);
    for i in 0..n {
        for j in 0..n {
            t.set(i, j, r[i.abs_diff(j)]);
        }
    }
    t
}

/// AR(1) covariance: `S[i,j] = σ² ρ^{|i−j|}`. The canonical stationary
/// local-correlation model; `ρ → 1` is the strongly-correlated regime where
/// sequence transforms win the most.
pub fn ar1_covariance(n: usize, rho: f32, sigma2: f32) -> Tensor {
    let r: Vec<f32> = (0..n).map(|k| sigma2 * rho.powi(k as i32)).collect();
    toeplitz(&r)
}

/// Block-Toeplitz covariance for an `h×w` token grid flattened row-major:
/// `S[(y1,x1),(y2,x2)] = σ² ρy^{|y1−y2|} ρx^{|x1−x2|}` (separable 2-D AR).
/// This reproduces the block-diagonal band structure of Figure 3a (LVM).
pub fn block_toeplitz_2d(h: usize, w: usize, rho_y: f32, rho_x: f32, sigma2: f32) -> Tensor {
    let n = h * w;
    let mut t = Tensor::zeros(&[n, n]);
    for y1 in 0..h {
        for x1 in 0..w {
            for y2 in 0..h {
                for x2 in 0..w {
                    let v = sigma2
                        * rho_y.powi(y1.abs_diff(y2) as i32)
                        * rho_x.powi(x1.abs_diff(x2) as i32);
                    t.set(y1 * w + x1, y2 * w + x2, v);
                }
            }
        }
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn toeplitz_structure() {
        let t = toeplitz(&[1.0, 0.5, 0.25]);
        assert_eq!(t.at(0, 0), 1.0);
        assert_eq!(t.at(1, 1), 1.0);
        assert_eq!(t.at(0, 1), 0.5);
        assert_eq!(t.at(1, 0), 0.5);
        assert_eq!(t.at(0, 2), 0.25);
        // Constant along diagonals.
        assert_eq!(t.at(1, 2), t.at(0, 1));
    }

    #[test]
    fn ar1_decay_and_symmetry() {
        let s = ar1_covariance(8, 0.9, 2.0);
        assert!((s.at(3, 3) - 2.0).abs() < 1e-6);
        assert!((s.at(0, 1) - 1.8).abs() < 1e-6);
        for i in 0..8 {
            for j in 0..8 {
                assert_eq!(s.at(i, j), s.at(j, i));
            }
        }
        // Monotone decay with distance.
        assert!(s.at(0, 1) > s.at(0, 4));
    }

    #[test]
    fn ar1_is_positive_definite() {
        let s = ar1_covariance(16, 0.95, 1.0);
        // Cholesky succeeding is the PD check.
        let l = crate::linalg::cholesky(&s);
        assert!(l.matmul(&l.transpose()).max_abs_diff(&s) < 1e-4);
    }

    #[test]
    fn block_structure() {
        let s = block_toeplitz_2d(3, 3, 0.8, 0.5, 1.0);
        // Same row of the grid: pure ρx decay.
        assert!((s.at(0, 1) - 0.5).abs() < 1e-6);
        // Same column of the grid (distance w in the sequence): ρy decay.
        assert!((s.at(0, 3) - 0.8).abs() < 1e-6);
        // Diagonal neighbor: product.
        assert!((s.at(0, 4) - 0.4).abs() < 1e-6);
        // Row-adjacent tokens at opposite grid edges (wrap in flattening)
        // are *less* correlated than same-row neighbors — the block
        // boundary structure of Fig 3a.
        assert!(s.at(2, 3) < s.at(0, 1));
    }

    #[test]
    fn block_toeplitz_positive_definite() {
        let s = block_toeplitz_2d(4, 4, 0.9, 0.9, 1.0);
        let l = crate::linalg::cholesky(&s);
        assert!(l.matmul(&l.transpose()).max_abs_diff(&s) < 1e-4);
    }
}
