//! Cholesky factorization, used by the synthetic-activation generator to
//! sample sequences with a prescribed (block-)Toeplitz autocorrelation:
//! if `S = L Lᵀ` then `L z` with `z ~ N(0, I)` has covariance `S`.

use crate::tensor::Tensor;

/// Lower-triangular `L` with `a = L Lᵀ`. Panics if `a` is not (numerically)
/// positive definite; callers add a small diagonal jitter when factoring
/// estimated covariances.
pub fn cholesky(a: &Tensor) -> Tensor {
    let n = a.rows();
    assert_eq!(n, a.cols(), "cholesky needs a square matrix");
    let mut l = vec![0.0f64; n * n];
    for i in 0..n {
        for j in 0..=i {
            let mut acc = a.at(i, j) as f64;
            for k in 0..j {
                acc -= l[i * n + k] * l[j * n + k];
            }
            if i == j {
                assert!(acc > 0.0, "matrix not positive definite at pivot {i} (acc={acc})");
                l[i * n + i] = acc.sqrt();
            } else {
                l[i * n + j] = acc / l[j * n + j];
            }
        }
    }
    Tensor::from_vec(&[n, n], l.into_iter().map(|x| x as f32).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity() {
        let l = cholesky(&Tensor::eye(5));
        assert!(l.max_abs_diff(&Tensor::eye(5)) < 1e-6);
    }

    #[test]
    fn reconstructs_spd() {
        let b = Tensor::randn(&[10, 10], 4);
        let mut a = b.transpose().matmul(&b);
        for i in 0..10 {
            a.set(i, i, a.at(i, i) + 0.1); // jitter
        }
        let l = cholesky(&a);
        let rec = l.matmul(&l.transpose());
        assert!(rec.max_abs_diff(&a) < 1e-3);
        // Strictly upper part must be zero.
        for i in 0..10 {
            for j in (i + 1)..10 {
                assert_eq!(l.at(i, j), 0.0);
            }
        }
    }

    #[test]
    #[should_panic]
    fn rejects_indefinite() {
        let a = Tensor::from_vec(&[2, 2], vec![1.0, 2.0, 2.0, 1.0]); // eigenvalues 3, -1
        cholesky(&a);
    }
}
