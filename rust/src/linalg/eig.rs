//! Cyclic Jacobi eigensolver for symmetric matrices.
//!
//! Used to compute the Karhunen–Loève transform: the KLT basis is the
//! eigenbasis of the sequence autocorrelation `S = E[XXᵀ]` (paper §3.2).
//! Jacobi is O(n³) per sweep but unconditionally stable and needs no
//! external LAPACK — sequence lengths here are ≤ 4096 and the KLT is a
//! calibration-time operation, so this is more than fast enough.

use crate::tensor::Tensor;

/// Eigendecomposition of a symmetric matrix: `a = V diag(λ) Vᵀ`.
///
/// Eigenvalues are sorted in **descending** order; `vectors` holds the
/// corresponding eigenvectors as **rows** (so `vectors` is `Uᵀ`, i.e. it is
/// directly usable as the KLT sequence transform `L`).
pub struct EigResult {
    pub values: Vec<f32>,
    /// Row i = eigenvector for `values[i]`.
    pub vectors: Tensor,
}

/// Cyclic-by-row Jacobi. `a` must be symmetric; asymmetry below 1e-4 is
/// tolerated (it is symmetrized internally).
pub fn eigh(a: &Tensor, max_sweeps: usize, tol: f64) -> EigResult {
    let n = a.rows();
    assert_eq!(n, a.cols(), "eigh needs a square matrix");

    // Work in f64 for accumulation accuracy.
    let mut m: Vec<f64> = vec![0.0; n * n];
    for i in 0..n {
        for j in 0..n {
            m[i * n + j] = 0.5 * (a.at(i, j) as f64 + a.at(j, i) as f64);
        }
    }
    // v accumulates the rotations; rows end up as eigenvectors of `a`.
    let mut v: Vec<f64> = vec![0.0; n * n];
    for i in 0..n {
        v[i * n + i] = 1.0;
    }

    for _sweep in 0..max_sweeps {
        let mut off = 0.0f64;
        for p in 0..n {
            for q in (p + 1)..n {
                off += m[p * n + q] * m[p * n + q];
            }
        }
        if off.sqrt() <= tol {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = m[p * n + q];
                if apq.abs() < 1e-300 {
                    continue;
                }
                let app = m[p * n + p];
                let aqq = m[q * n + q];
                let theta = (aqq - app) / (2.0 * apq);
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;

                // Rotate rows/cols p and q of m.
                for k in 0..n {
                    let mkp = m[k * n + p];
                    let mkq = m[k * n + q];
                    m[k * n + p] = c * mkp - s * mkq;
                    m[k * n + q] = s * mkp + c * mkq;
                }
                for k in 0..n {
                    let mpk = m[p * n + k];
                    let mqk = m[q * n + k];
                    m[p * n + k] = c * mpk - s * mqk;
                    m[q * n + k] = s * mpk + c * mqk;
                }
                // Accumulate the rotation into v (row-eigenvector form).
                for k in 0..n {
                    let vpk = v[p * n + k];
                    let vqk = v[q * n + k];
                    v[p * n + k] = c * vpk - s * vqk;
                    v[q * n + k] = s * vpk + c * vqk;
                }
            }
        }
    }

    // Extract and sort by descending eigenvalue.
    let mut order: Vec<usize> = (0..n).collect();
    let diag: Vec<f64> = (0..n).map(|i| m[i * n + i]).collect();
    order.sort_by(|&i, &j| diag[j].partial_cmp(&diag[i]).unwrap());

    let mut values = Vec::with_capacity(n);
    let mut vectors = Tensor::zeros(&[n, n]);
    for (row, &idx) in order.iter().enumerate() {
        values.push(diag[idx] as f32);
        for k in 0..n {
            vectors.set(row, k, v[idx * n + k] as f32);
        }
    }
    EigResult { values, vectors }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::orthogonality_defect;

    fn reconstruct(r: &EigResult) -> Tensor {
        // a = Vᵀ diag(λ) V with V rows = eigenvectors.
        let n = r.values.len();
        let mut d = Tensor::zeros(&[n, n]);
        for i in 0..n {
            d.set(i, i, r.values[i]);
        }
        r.vectors.transpose().matmul(&d).matmul(&r.vectors)
    }

    #[test]
    fn diagonal_matrix() {
        let mut a = Tensor::zeros(&[3, 3]);
        a.set(0, 0, 1.0);
        a.set(1, 1, 5.0);
        a.set(2, 2, 3.0);
        let r = eigh(&a, 30, 1e-12);
        assert!((r.values[0] - 5.0).abs() < 1e-5);
        assert!((r.values[1] - 3.0).abs() < 1e-5);
        assert!((r.values[2] - 1.0).abs() < 1e-5);
    }

    #[test]
    fn known_2x2() {
        // [[2,1],[1,2]] has eigenvalues 3 and 1.
        let a = Tensor::from_vec(&[2, 2], vec![2., 1., 1., 2.]);
        let r = eigh(&a, 30, 1e-12);
        assert!((r.values[0] - 3.0).abs() < 1e-5);
        assert!((r.values[1] - 1.0).abs() < 1e-5);
    }

    #[test]
    fn random_spd_reconstructs() {
        let b = Tensor::randn(&[16, 16], 77);
        let a = b.transpose().matmul(&b); // SPD
        let r = eigh(&a, 50, 1e-10);
        let rec = reconstruct(&r);
        assert!(rec.max_abs_diff(&a) < 1e-2, "diff {}", rec.max_abs_diff(&a));
        assert!(orthogonality_defect(&r.vectors) < 1e-4);
        // All eigenvalues of an SPD matrix are non-negative.
        assert!(r.values.iter().all(|&l| l > -1e-4));
        // Descending order.
        for w in r.values.windows(2) {
            assert!(w[0] >= w[1] - 1e-5);
        }
    }

    #[test]
    fn trace_preserved() {
        let b = Tensor::randn(&[12, 12], 5);
        let a = b.transpose().matmul(&b);
        let r = eigh(&a, 50, 1e-10);
        let tr: f32 = (0..12).map(|i| a.at(i, i)).sum();
        let sum: f32 = r.values.iter().sum();
        assert!((tr - sum).abs() / tr.abs() < 1e-4);
    }
}
