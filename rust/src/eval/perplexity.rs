//! Perplexity evaluation (Table 2's metric): `exp(mean CE)` of next-token
//! prediction over fixed-length corpus sequences, computed from the hooked
//! forward so any [`crate::baselines::QuantStack`] can be measured.

use crate::model::{Gpt, LinearHook};
use crate::tensor::Tensor;

/// Mean cross-entropy (nats/token) over the given sequences.
pub fn cross_entropy(gpt: &Gpt, hook: &dyn LinearHook, seqs: &[&[u32]]) -> f64 {
    assert!(!seqs.is_empty());
    let mut total = 0.0f64;
    let mut count = 0usize;
    for seq in seqs {
        let logits = gpt.logits_hooked(hook, seq);
        total += sequence_ce(&logits, seq);
        count += seq.len() - 1;
    }
    total / count as f64
}

/// Perplexity over the given sequences.
pub fn perplexity(gpt: &Gpt, hook: &dyn LinearHook, seqs: &[&[u32]]) -> f64 {
    cross_entropy(gpt, hook, seqs).exp()
}

/// Summed CE of one sequence from raw logits (numerically-stable
/// log-softmax).
fn sequence_ce(logits: &Tensor, seq: &[u32]) -> f64 {
    let mut total = 0.0f64;
    for i in 0..seq.len() - 1 {
        let row = logits.row(i);
        let target = seq[i + 1] as usize;
        let mx = row.iter().cloned().fold(f32::MIN, f32::max) as f64;
        let lse: f64 = row.iter().map(|&v| ((v as f64) - mx).exp()).sum::<f64>().ln() + mx;
        total += lse - row[target] as f64;
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Corpus;
    use crate::model::{FpHook, GptConfig};

    #[test]
    fn untrained_ppl_near_vocab_size() {
        let gpt = Gpt::new(GptConfig::tiny(), 1);
        let corpus = Corpus::generate(512, 2);
        let seqs = corpus.sequences(128);
        let ppl = perplexity(&gpt, &FpHook, &seqs);
        // Untrained ⇒ near-uniform ⇒ PPL ≈ vocab size (72).
        assert!(ppl > 40.0 && ppl < 110.0, "ppl {ppl}");
    }

    #[test]
    fn trained_ppl_much_lower() {
        let (gpt, corpus) = crate::train::build_trained_model("tiny", 150);
        let seqs = corpus.sequences(128);
        let ppl = perplexity(&gpt, &FpHook, &seqs[..4.min(seqs.len())]);
        assert!(ppl < 25.0, "trained ppl {ppl}");
    }

    #[test]
    fn ce_matches_forward_loss() {
        let gpt = Gpt::new(GptConfig::tiny(), 3);
        let seq: Vec<u32> = (0..64).map(|i| ((i * 11) % 70) as u32).collect();
        let (loss, _) = gpt.forward_loss(&seq);
        let ce = cross_entropy(&gpt, &FpHook, &[&seq]);
        assert!((loss - ce).abs() < 1e-3, "forward_loss {loss} vs eval ce {ce}");
    }
}
