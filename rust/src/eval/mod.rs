//! Evaluation harnesses: the computations behind every table and figure.
//!
//! * [`perplexity`] — WikiText-style PPL of a (quantized) GPT (Table 2).
//! * [`lvm`] — DiT latent/image SQNR and the proxy quality metrics
//!   (Tables 1/4/5, Figures 4/7/9). See DESIGN.md §3 for the metric
//!   substitutions — proxies are *monotone in measured fidelity*, so row
//!   orderings (the reproduced quantity) are meaningful, absolute values
//!   are not.
//! * [`figures`] — the analytic reproductions (Theorem-1 bound curves,
//!   energy spectra, bit-allocation comparisons).

pub mod figures;
pub mod lvm;
pub mod perplexity;
pub mod tables;

pub use lvm::{image_reward_proxy, lvm_eval, LvmEval};
pub use perplexity::perplexity;
pub use tables::{table1_lvm, table2_llm, table4_sites, table5_metrics, TableOpts};
