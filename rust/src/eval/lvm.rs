//! LVM fidelity evaluation (Tables 1/4/5, Figures 4b/7/9).
//!
//! The primary measured quantity is **SQNR between the FP and quantized
//! model outputs**, in two spaces:
//!
//! * *latent* — the raw DiT output (paper: "SQNR (latent)", Table 5);
//! * *image* — the latent pushed through a fixed deterministic "decoder"
//!   (a smoothing + channel-mixing linear map standing in for the VAE;
//!   DESIGN.md §3), matching the paper's image-space SQNR which is always
//!   a few dB above the latent one because decoding attenuates
//!   high-frequency quantization noise.
//!
//! Quality scores the reproduction cannot measure (Image Reward, CLIP,
//! CLIP-IQA — they need the real pretrained scorers) are replaced by
//! *documented monotone proxies* of image SQNR, so the orderings and
//! improve/degrade relationships the paper's tables demonstrate are
//! faithfully reproduced while absolute values are explicitly synthetic.

use crate::model::{Dit, FpHook, LinearHook};
use crate::stats::sqnr;
use crate::tensor::Tensor;

/// Fixed "VAE decoder" stand-in: per-token channel mixing followed by a
/// 3×3 spatial box smoothing over the latent grid.
pub fn decode_latent(dit: &Dit, z: &Tensor) -> Tensor {
    let (h, w) = (dit.cfg.grid_h, dit.cfg.grid_w);
    let d = z.cols();
    // Channel mixing with a deterministic orthogonal-ish matrix.
    let mix = Tensor::randn(&[d, d], 0xDEC0DE).scale(1.0 / (d as f32).sqrt());
    let mixed = z.matmul(&mix);
    // 3×3 box filter over the grid.
    let mut out = Tensor::zeros(&[h * w, d]);
    for y in 0..h {
        for x in 0..w {
            let mut acc = vec![0.0f32; d];
            let mut n = 0.0f32;
            for dy in -1i64..=1 {
                for dx in -1i64..=1 {
                    let (yy, xx) = (y as i64 + dy, x as i64 + dx);
                    if yy >= 0 && yy < h as i64 && xx >= 0 && xx < w as i64 {
                        let src = mixed.row((yy as usize) * w + xx as usize);
                        for j in 0..d {
                            acc[j] += src[j];
                        }
                        n += 1.0;
                    }
                }
            }
            let dst = out.row_mut(y * w + x);
            for j in 0..d {
                dst[j] = acc[j] / n;
            }
        }
    }
    out
}

/// Monotone Image-Reward proxy: saturating map of image SQNR, scaled so
/// the FP ceiling sits near the paper's FP values (≈0.9). Synthetic; see
/// module docs.
pub fn image_reward_proxy(image_sqnr_db: f64) -> f64 {
    let ceiling = 0.93;
    if image_sqnr_db.is_infinite() {
        return ceiling;
    }
    ceiling * (image_sqnr_db / 9.0).tanh().max(-1.0)
}

/// Monotone CLIP-score proxy (paper FP ≈ 31.5).
pub fn clip_proxy(image_sqnr_db: f64) -> f64 {
    let ceiling = 31.6;
    if image_sqnr_db.is_infinite() {
        return ceiling;
    }
    ceiling - 2.2 * (-(image_sqnr_db - 2.0) / 6.0).exp().min(3.0)
}

/// Monotone CLIP-IQA proxy (paper FP ≈ 0.9).
pub fn clip_iqa_proxy(image_sqnr_db: f64) -> f64 {
    let ceiling = 0.91;
    if image_sqnr_db.is_infinite() {
        return ceiling;
    }
    ceiling * (1.0 - (-(image_sqnr_db.max(0.0)) / 7.0).exp() * 0.5)
}

/// Aggregated LVM fidelity over a prompt set.
#[derive(Clone, Debug)]
pub struct LvmEval {
    pub latent_sqnr: f64,
    pub image_sqnr: f64,
    pub image_reward: f64,
    pub clip: f64,
    pub clip_iqa: f64,
    pub prompts: usize,
}

/// Run the full generation loop per prompt under both FP and the hook,
/// and aggregate fidelity. SQNR is averaged in dB across prompts (the
/// paper's convention of reporting a single dataset-level figure).
pub fn lvm_eval(dit: &Dit, hook: &dyn LinearHook, prompts: &[&str], seed: u64) -> LvmEval {
    assert!(!prompts.is_empty());
    let mut lat = 0.0f64;
    let mut img = 0.0f64;
    for (i, p) in prompts.iter().enumerate() {
        let z_fp = dit.sample(&FpHook, p, seed + i as u64);
        let z_q = dit.sample(hook, p, seed + i as u64);
        let s_lat = sqnr(&z_fp, &z_q);
        let s_img = sqnr(&decode_latent(dit, &z_fp), &decode_latent(dit, &z_q));
        lat += s_lat;
        img += s_img;
    }
    let latent_sqnr = lat / prompts.len() as f64;
    let image_sqnr = img / prompts.len() as f64;
    LvmEval {
        latent_sqnr,
        image_sqnr,
        image_reward: image_reward_proxy(image_sqnr),
        clip: clip_proxy(image_sqnr),
        clip_iqa: clip_iqa_proxy(image_sqnr),
        prompts: prompts.len(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::{ActQuantCfg, BaselineKind, QuantHook, QuantStack};
    use crate::model::DitConfig;
    use std::collections::HashMap;

    fn tiny_dit() -> Dit {
        Dit::new(
            DitConfig { grid_h: 8, grid_w: 8, d_model: 32, n_heads: 2, n_layers: 2, d_ff: 64, ctx_tokens: 4, steps: 2 },
            42,
        )
    }

    #[test]
    fn proxies_monotone() {
        for f in [image_reward_proxy, clip_proxy, clip_iqa_proxy] {
            let mut prev = f(-5.0);
            for s in [0.0, 3.0, 6.0, 9.0, 15.0, 30.0] {
                let v = f(s);
                assert!(v >= prev, "proxy not monotone at {s}");
                prev = v;
            }
            assert!(f(f64::INFINITY) >= prev);
        }
    }

    #[test]
    fn fp_eval_is_perfect() {
        let dit = tiny_dit();
        let stack = QuantStack::fp();
        let hook = QuantHook::new(&stack);
        let e = lvm_eval(&dit, &hook, &["a cat"], 1);
        assert!(e.latent_sqnr.is_infinite());
        assert!(e.image_sqnr.is_infinite());
        assert!((e.image_reward - 0.93).abs() < 1e-9);
    }

    #[test]
    fn quantized_eval_degrades_and_more_bits_help() {
        let dit = tiny_dit();
        let mk = |bits: u32| {
            QuantStack::build(
                BaselineKind::Rtn,
                &HashMap::new(),
                Some(ActQuantCfg { bits, hp_tokens: 0, ..ActQuantCfg::w4a4_per_token() }),
                None,
                None,
                7,
            )
            .with_lvm_skips()
        };
        let s3 = mk(3);
        let s6 = mk(6);
        let e3 = lvm_eval(&dit, &QuantHook::new(&s3), &["a cat", "a dog"], 2);
        let e6 = lvm_eval(&dit, &QuantHook::new(&s6), &["a cat", "a dog"], 2);
        assert!(e3.latent_sqnr.is_finite());
        assert!(e6.latent_sqnr > e3.latent_sqnr, "{} !> {}", e6.latent_sqnr, e3.latent_sqnr);
        assert!(e6.image_reward >= e3.image_reward);
    }

    #[test]
    fn decode_smooths() {
        let dit = tiny_dit();
        let z = Tensor::randn(&[64, 16], 5);
        let img = decode_latent(&dit, &z);
        assert_eq!(img.shape(), z.shape());
        // Box filtering reduces total energy of white noise.
        assert!(img.sq_norm() < z.sq_norm());
    }
}
