//! Table/figure harnesses: one function per paper table or figure, each
//! returning a [`crate::report::Table`] with the same rows/columns the
//! paper reports. Sequence lengths are scaled to the tiny testbed with the
//! *effective average bit width held at the paper's value* (e.g. 8 hp
//! tokens of 256 ⇒ 4.125 avg bits, the paper's 64/2048 LLM setting).

use crate::baselines::{
    ActQuantCfg, BaselineKind, CalibHook, KvQuantCfg, QuantHook, QuantStack, SiteStats,
    WeightQuantCfg,
};
use crate::data::{Corpus, PromptSet};
use crate::eval::lvm::{lvm_eval, LvmEval};
use crate::eval::perplexity::perplexity;
use crate::model::{Dit, DitConfig, FpHook, Gpt};
use crate::quant::Granularity;
use crate::report::Table;
use crate::stamp::SeqTransformKind;
use crate::train::build_trained_model;
use std::collections::HashMap;

/// Harness knobs (tests use `fast()`, the shipped binaries `full()`).
#[derive(Clone, Copy, Debug)]
pub struct TableOpts {
    pub train_steps: usize,
    pub eval_seqs: usize,
    pub prompts_per_set: usize,
    pub dit_steps: usize,
    /// High-precision tokens at the scaled sequence length (8/256 matches
    /// the paper's 64/2048 = 4.125 avg bits).
    pub hp_tokens: usize,
    pub seq_len: usize,
}

impl TableOpts {
    pub fn full() -> Self {
        TableOpts { train_steps: 300, eval_seqs: 4, prompts_per_set: 6, dit_steps: 6, hp_tokens: 8, seq_len: 256 }
    }

    pub fn fast() -> Self {
        TableOpts { train_steps: 60, eval_seqs: 1, prompts_per_set: 2, dit_steps: 2, hp_tokens: 8, seq_len: 128 }
    }

    fn act_cfg(&self, bits: u32) -> ActQuantCfg {
        ActQuantCfg {
            bits,
            hp_tokens: self.hp_tokens,
            hp_bits: 8,
            granularity: Granularity::PerToken,
            range_shrink: 1.0,
        }
    }
}

/// Calibrate site statistics for a GPT over a few corpus sequences.
pub fn calibrate_gpt(gpt: &Gpt, corpus: &Corpus, seq_len: usize) -> HashMap<String, SiteStats> {
    let hook = CalibHook::new(4);
    for seq in corpus.sequences(seq_len).iter().take(2) {
        let _ = gpt.logits_hooked(&hook, seq);
    }
    hook.take()
}

/// Calibrate site statistics for a DiT over a couple of prompts.
pub fn calibrate_dit(dit: &Dit) -> HashMap<String, SiteStats> {
    let hook = CalibHook::new(4);
    for (i, p) in ["calibration prompt one", "calibration prompt two"].iter().enumerate() {
        let _ = dit.sample(&hook, p, 1000 + i as u64);
    }
    hook.take()
}

fn llm_stack(
    kind: BaselineKind,
    stats: &HashMap<String, SiteStats>,
    opts: &TableOpts,
    stamp: Option<SeqTransformKind>,
) -> QuantStack {
    let mut act = opts.act_cfg(4);
    if kind == BaselineKind::QuaRot {
        act.range_shrink = 0.9;
    }
    let kv = KvQuantCfg { bits: 4, hp_tokens: opts.hp_tokens, hp_bits: 8 };
    let mut s = QuantStack::build(
        kind,
        stats,
        Some(act),
        Some(WeightQuantCfg::w4_per_channel()),
        Some(kv),
        0x5EED,
    );
    if let Some(t) = stamp {
        s = s.with_stamp(QuantStack::llm_stamp(t));
    }
    s
}

fn lvm_stack(
    kind: BaselineKind,
    stats: &HashMap<String, SiteStats>,
    opts: &TableOpts,
    grid: (usize, usize),
    stamp: bool,
) -> QuantStack {
    // LVM protocol (§B.1): non-STaMP rows use NO mixed-precision tokens
    // (unlike the LLM protocol where all baselines keep 64 hp tokens).
    let act = ActQuantCfg {
        bits: 4,
        hp_tokens: if stamp { opts.hp_tokens * 2 } else { 0 },
        hp_bits: 8,
        granularity: Granularity::PerBlock { block: 64 },
        range_shrink: 1.0,
    };
    let mut s = QuantStack::build(
        kind,
        stats,
        Some(act),
        Some(WeightQuantCfg::w4_block64()),
        None,
        0x5EED,
    )
    .with_lvm_skips();
    if stamp {
        let mut cfg = QuantStack::lvm_stamp(grid.0, grid.1);
        cfg.hp_tokens = opts.hp_tokens * 2; // 2-D grids concentrate into a quarter block
        s = s.with_stamp(cfg);
    }
    s
}

/// **Table 2** — LLM W4A4KV4 perplexity, baselines × {✗, ✓ STaMP}.
pub fn table2_llm(opts: &TableOpts) -> Table {
    let mut table = Table::new(
        "Table 2: LLM W4A4KV4 perplexity (64-token-hp effective 4.125 bits)",
        &["model", "FP", "method", "PPL", "PPL +STaMP"],
    );
    for variant in ["tiny", "small", "medium", "wide"] {
        let (gpt, corpus) = build_trained_model(variant, opts.train_steps);
        let seqs_all = corpus.sequences(opts.seq_len);
        let seqs: Vec<&[u32]> = seqs_all.iter().take(opts.eval_seqs).cloned().collect();
        let fp = perplexity(&gpt, &FpHook, &seqs);
        let stats = calibrate_gpt(&gpt, &corpus, opts.seq_len);
        for kind in [
            BaselineKind::Rtn,
            BaselineKind::SmoothQuant,
            BaselineKind::QuaRot,
            BaselineKind::FlatQuant,
        ] {
            let plain = llm_stack(kind, &stats, opts, None);
            let stamped = llm_stack(kind, &stats, opts, Some(SeqTransformKind::HaarDwt));
            let p_plain = perplexity(&gpt, &QuantHook::new(&plain), &seqs);
            let p_stamp = perplexity(&gpt, &QuantHook::new(&stamped), &seqs);
            table.row(vec![
                variant.into(),
                Table::num(fp),
                kind.label().into(),
                Table::num(p_plain),
                Table::num(p_stamp),
            ]);
        }
    }
    table
}

fn dit_for(model: &str, opts: &TableOpts) -> Dit {
    let mut cfg = match model {
        "pixart" => DitConfig::pixart(),
        "sana" => DitConfig::sana(),
        other => panic!("unknown dit {other}"),
    };
    cfg.steps = opts.dit_steps;
    let mut dit = Dit::new(cfg, 0xD17);
    // Real-DiT activation pathology (massive channels), exactly
    // function-preserving — see Dit::inject_outlier_channels.
    let d = dit.cfg.d_model;
    dit.inject_outlier_channels((d / 32).max(2), 25.0);
    dit
}

fn prompt_slice(set: &PromptSet, n: usize) -> Vec<&'static str> {
    set.prompts.iter().take(n).cloned().collect()
}

/// **Table 1** — LVM W4A4 block-64: image SQNR + IR proxy for
/// RTN/ViDiT-Q/SVDQuant × {✗, ✓}, 2 models × 2 prompt sets.
pub fn table1_lvm(opts: &TableOpts) -> Table {
    let mut table = Table::new(
        "Table 1: LVM W4A4 (block 64) image SQNR and Image-Reward proxy",
        &["model", "dataset", "method", "SQNR", "SQNR+STaMP", "IR", "IR+STaMP"],
    );
    for model in ["pixart", "sana"] {
        let dit = dit_for(model, opts);
        let grid = (dit.cfg.grid_h, dit.cfg.grid_w);
        let stats = calibrate_dit(&dit);
        for set in [PromptSet::coco(), PromptSet::mjhq()] {
            let prompts = prompt_slice(&set, opts.prompts_per_set);
            for kind in [BaselineKind::Rtn, BaselineKind::ViDitQ, BaselineKind::SvdQuant] {
                let plain = lvm_stack(kind, &stats, opts, grid, false);
                let stamped = lvm_stack(kind, &stats, opts, grid, true);
                let e_plain = lvm_eval(&dit, &QuantHook::new(&plain), &prompts, 7);
                let e_stamp = lvm_eval(&dit, &QuantHook::new(&stamped), &prompts, 7);
                table.row(vec![
                    model.into(),
                    set.name.into(),
                    kind.label().into(),
                    Table::num(e_plain.image_sqnr),
                    Table::num(e_stamp.image_sqnr),
                    Table::num(e_plain.image_reward),
                    Table::num(e_stamp.image_reward),
                ]);
            }
        }
    }
    table
}

/// **Table 4** — per-activation-site A4 ablation on the PixArt analogue.
pub fn table4_sites(opts: &TableOpts) -> Table {
    let mut table = Table::new(
        "Table 4: per-site A4 ablation (image SQNR, PixArt analogue)",
        &["site", "Identity", "QuaRot", "STaMP", "QuaRot+STaMP"],
    );
    let dit = dit_for("pixart", opts);
    let grid = (dit.cfg.grid_h, dit.cfg.grid_w);
    let stats = calibrate_dit(&dit);
    let prompts = prompt_slice(&PromptSet::coco(), opts.prompts_per_set.min(3));
    for site in ["attn1.to_q", "attn1.to_out", "attn2.to_q", "attn2.to_out", "ffn.up_proj", "ffn.down_proj"] {
        let eval_one = |kind: BaselineKind, stamp: bool| -> LvmEval {
            // Act-only quantization at the target site.
            let mut s = match kind {
                BaselineKind::Rtn => QuantStack::build(kind, &stats, Some(opts.act_cfg(4)), None, None, 0x5EED),
                k => QuantStack::build(k, &stats, Some(opts.act_cfg(4)), None, None, 0x5EED),
            }
            .with_lvm_skips()
            .only(site);
            if stamp {
                let mut cfg = QuantStack::lvm_stamp(grid.0, grid.1);
                cfg.hp_tokens = opts.hp_tokens * 2;
                s = s.with_stamp(cfg);
            }
            lvm_eval(&dit, &QuantHook::new(&s), &prompts, 11)
        };
        table.row(vec![
            site.into(),
            Table::num(eval_one(BaselineKind::Rtn, false).image_sqnr),
            Table::num(eval_one(BaselineKind::QuaRot, false).image_sqnr),
            Table::num(eval_one(BaselineKind::Rtn, true).image_sqnr),
            Table::num(eval_one(BaselineKind::QuaRot, true).image_sqnr),
        ]);
    }
    table
}

/// **Table 5** — companion metrics (CLIP / CLIP-IQA proxies + latent SQNR).
pub fn table5_metrics(opts: &TableOpts) -> Table {
    let mut table = Table::new(
        "Table 5: companion metrics (proxies; DESIGN.md metric substitutions)",
        &["model", "dataset", "method", "STaMP", "CLIP", "CLIP-IQA", "SQNR latent"],
    );
    for model in ["pixart", "sana"] {
        let dit = dit_for(model, opts);
        let grid = (dit.cfg.grid_h, dit.cfg.grid_w);
        let stats = calibrate_dit(&dit);
        for set in [PromptSet::coco(), PromptSet::mjhq()] {
            let prompts = prompt_slice(&set, opts.prompts_per_set.min(4));
            for kind in [BaselineKind::Rtn, BaselineKind::SvdQuant, BaselineKind::ViDitQ] {
                for stamp in [false, true] {
                    let s = lvm_stack(kind, &stats, opts, grid, stamp);
                    let e = lvm_eval(&dit, &QuantHook::new(&s), &prompts, 13);
                    table.row(vec![
                        model.into(),
                        set.name.into(),
                        kind.label().into(),
                        if stamp { "yes" } else { "no" }.into(),
                        Table::num(e.clip),
                        Table::num(e.clip_iqa),
                        Table::num(e.latent_sqnr),
                    ]);
                }
            }
        }
    }
    table
}

/// **Figure 4b** — #high-precision tokens vs SQNR vs average bits
/// (activation-only quantization, QuaRot features as in the paper).
pub fn fig4b_sweep(opts: &TableOpts) -> Table {
    let mut table = Table::new(
        "Figure 4b: high-precision token count vs image SQNR (A4, act-only)",
        &["hp_tokens", "avg_bits", "SQNR uniform(no transform)", "SQNR STaMP(dwt2d)"],
    );
    let dit = dit_for("pixart", opts);
    let grid = (dit.cfg.grid_h, dit.cfg.grid_w);
    let s_tokens = dit.cfg.seq_len();
    let stats = calibrate_dit(&dit);
    let prompts = prompt_slice(&PromptSet::coco(), opts.prompts_per_set.min(3));
    for hp in [0usize, 4, 8, 16, 32, 64] {
        let mk = |stamp: bool| {
            let act = ActQuantCfg {
                bits: 4,
                hp_tokens: hp,
                hp_bits: 8,
                granularity: Granularity::PerToken,
                range_shrink: 1.0,
            };
            let mut s = QuantStack::build(BaselineKind::QuaRot, &stats, Some(act), None, None, 0x5EED)
                .with_lvm_skips();
            if stamp {
                let mut cfg = QuantStack::lvm_stamp(grid.0, grid.1);
                cfg.hp_tokens = hp;
                s = s.with_stamp(cfg);
            }
            s
        };
        let avg = 4.0 + 4.0 * hp as f64 / s_tokens as f64;
        let e_uni = lvm_eval(&dit, &QuantHook::new(&mk(false)), &prompts, 17);
        let e_stamp = lvm_eval(&dit, &QuantHook::new(&mk(true)), &prompts, 17);
        table.row(vec![
            hp.to_string(),
            format!("{avg:.3}"),
            Table::num(e_uni.image_sqnr),
            Table::num(e_stamp.image_sqnr),
        ]);
    }
    table
}

/// **Figure 7** — feature transforms × sequence transforms grid.
/// LVM half: image SQNR; LLM half: perplexity.
pub fn fig7_grid(opts: &TableOpts) -> (Table, Table) {
    let seq_kinds: [(&str, Option<SeqTransformKind>); 4] = [
        ("none", None),
        ("DCT", Some(SeqTransformKind::Dct)),
        ("WHT", Some(SeqTransformKind::Wht)),
        ("DWT", Some(SeqTransformKind::HaarDwt)),
    ];
    let feat_kinds = [
        BaselineKind::Rtn, // = identity features
        BaselineKind::SmoothQuant,
        BaselineKind::QuaRot,
        BaselineKind::FlatQuant,
    ];

    // LVM half (act-only A4, as in the paper's Figure 7).
    let mut lvm = Table::new(
        "Figure 7a: feature x sequence transforms, A4 PixArt analogue (image SQNR)",
        &["feature", "none", "DCT", "WHT", "DWT"],
    );
    let dit = dit_for("pixart", opts);
    let stats = calibrate_dit(&dit);
    let prompts = prompt_slice(&PromptSet::coco(), opts.prompts_per_set.min(3));
    for kind in feat_kinds {
        let mut row = vec![kind.label().to_string()];
        for (_, seq) in &seq_kinds {
            let mut s = QuantStack::build(kind, &stats, Some(opts.act_cfg(4)), None, None, 0x5EED)
                .with_lvm_skips();
            if let Some(t) = seq {
                // 2-D DWT for the DWT cell (the paper's LVM config); 1-D
                // for DCT/WHT which have no 2-D variant in the paper.
                let cfg = if matches!(t, SeqTransformKind::HaarDwt) {
                    let mut c = QuantStack::lvm_stamp(dit.cfg.grid_h, dit.cfg.grid_w);
                    c.hp_tokens = opts.hp_tokens * 2;
                    c
                } else {
                    let mut c = crate::stamp::StampConfig {
                        transform: *t,
                        ..Default::default()
                    };
                    c.hp_tokens = opts.hp_tokens * 2;
                    c
                };
                s = s.with_stamp(cfg);
            }
            let e = lvm_eval(&dit, &QuantHook::new(&s), &prompts, 19);
            row.push(Table::num(e.image_sqnr));
        }
        lvm.row(row);
    }

    // LLM half (A4 perplexity).
    let mut llm = Table::new(
        "Figure 7b: feature x sequence transforms, A4 LLM analogue (PPL)",
        &["feature", "none", "DCT", "WHT", "DWT"],
    );
    let (gpt, corpus) = build_trained_model("small", opts.train_steps);
    let seqs_all = corpus.sequences(opts.seq_len);
    let seqs: Vec<&[u32]> = seqs_all.iter().take(opts.eval_seqs).cloned().collect();
    let stats = calibrate_gpt(&gpt, &corpus, opts.seq_len);
    for kind in feat_kinds {
        let mut row = vec![kind.label().to_string()];
        for (_, seq) in &seq_kinds {
            let mut s = QuantStack::build(kind, &stats, Some(opts.act_cfg(4)), None, None, 0x5EED);
            if let Some(t) = seq {
                s = s.with_stamp(QuantStack::llm_stamp(*t));
            }
            let p = perplexity(&gpt, &QuantHook::new(&s), &seqs);
            row.push(Table::num(p));
        }
        llm.row(row);
    }
    (lvm, llm)
}

/// **Figure 9** — per-token vs per-block(16..256) vs STaMP: SQNR at equal
/// *storage-accounted* average bits (16-bit scales, paper Appendix C).
pub fn fig9_blockq(opts: &TableOpts) -> Table {
    let mut table = Table::new(
        "Figure 9: granularity tradeoff (act-only, incl. 16-bit scale overhead)",
        &["scheme", "avg_bits", "image SQNR"],
    );
    let dit = dit_for("pixart", opts);
    let grid = (dit.cfg.grid_h, dit.cfg.grid_w);
    let d = dit.cfg.d_model;
    let stats = calibrate_dit(&dit);
    let prompts = prompt_slice(&PromptSet::coco(), opts.prompts_per_set.min(3));

    let run = |gran: Granularity, hp: usize, stamp: bool| -> LvmEval {
        let act = ActQuantCfg { bits: 4, hp_tokens: hp, hp_bits: 8, granularity: gran, range_shrink: 1.0 };
        let mut s =
            QuantStack::build(BaselineKind::Rtn, &stats, Some(act), None, None, 0x5EED).with_lvm_skips();
        if stamp {
            let mut cfg = QuantStack::lvm_stamp(grid.0, grid.1);
            cfg.hp_tokens = hp;
            s = s.with_stamp(cfg);
        }
        lvm_eval(&dit, &QuantHook::new(&s), &prompts, 23)
    };

    // Per-token baseline.
    let pt = run(Granularity::PerToken, 0, false);
    table.row(vec![
        "per-token".into(),
        format!("{:.3}", 4.0 + Granularity::PerToken.param_overhead_bits(d)),
        Table::num(pt.image_sqnr),
    ]);
    // Per-block at several block sizes.
    for block in [16usize, 32, 64, 128] {
        let e = run(Granularity::PerBlock { block }, 0, false);
        table.row(vec![
            format!("per-block {block}"),
            format!("{:.3}", 4.0 + Granularity::PerBlock { block }.param_overhead_bits(d)),
            Table::num(e.image_sqnr),
        ]);
    }
    // STaMP per-token with a few hp counts.
    let s_tokens = dit.cfg.seq_len();
    for hp in [8usize, 16, 32] {
        let e = run(Granularity::PerToken, hp, true);
        let avg = 4.0
            + 4.0 * hp as f64 / s_tokens as f64
            + Granularity::PerToken.param_overhead_bits(d);
        table.row(vec![format!("STaMP hp={hp}"), format!("{avg:.3}"), Table::num(e.image_sqnr)]);
    }
    table
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(v: &str) -> f64 {
        if v == "inf" {
            f64::INFINITY
        } else {
            v.parse().unwrap()
        }
    }

    #[test]
    fn table2_shape_holds() {
        // The paper's core LLM claim: STaMP improves (reduces PPL for)
        // every baseline row.
        let mut opts = TableOpts::fast();
        opts.train_steps = 80;
        let t = table2_llm(&opts);
        assert_eq!(t.rows.len(), 16);
        let mut improved = 0usize;
        for row in &t.rows {
            let plain = parse(&row[3]);
            let stamped = parse(&row[4]);
            if stamped < plain {
                improved += 1;
            }
        }
        // Allow a little slack on the tiny testbed but demand the shape.
        assert!(improved >= 12, "STaMP improved only {improved}/16 rows:\n{}", t.render());
    }

    #[test]
    fn table1_shape_holds() {
        let t = table1_lvm(&TableOpts::fast());
        assert_eq!(t.rows.len(), 12);
        let mut improved = 0usize;
        for row in &t.rows {
            if parse(&row[4]) > parse(&row[3]) {
                improved += 1;
            }
        }
        assert!(improved >= 9, "STaMP improved only {improved}/12 rows:\n{}", t.render());
    }

    #[test]
    fn fig4b_knee_exists() {
        let t = fig4b_sweep(&TableOpts::fast());
        // SQNR with STaMP at hp=16 must beat hp=0 substantially.
        let find = |hp: &str| -> f64 {
            t.rows.iter().find(|r| r[0] == hp).map(|r| parse(&r[3])).unwrap()
        };
        assert!(find("16") > find("0") + 1.0, "no knee:\n{}", t.render());
    }
}
