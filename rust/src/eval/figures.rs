//! Analytic figure reproductions: the Theorem-1 bound-vs-error comparison
//! (Fig. 2b), autocorrelation / energy-spectrum / basis data (Fig. 3),
//! and the bit-allocation strategy comparison (Fig. 4a).

use crate::linalg::eigh;
use crate::quant::{optimal_bits, quantization_error, theorem1_bound, BitAllocation, Granularity};
use crate::stats::{autocorrelation, token_energies};
use crate::tensor::Tensor;
use crate::transforms::{
    DctTransform, HaarDwt, IdentitySeq, KltTransform, SequenceTransform, WhtTransform,
};

/// One point of the Figure-2b curves.
#[derive(Clone, Debug)]
pub struct BoundPoint {
    pub avg_bits: f64,
    pub measured_error: f64,
    pub bound: f64,
}

/// Figure 2b: upper bound and measured quantization error across average
/// bit widths, for a given transform + allocation strategy.
pub fn fig2_bound_curve(
    x: &Tensor,
    transform: &dyn SequenceTransform,
    allocations: &[BitAllocation],
) -> Vec<BoundPoint> {
    allocations
        .iter()
        .map(|bits| BoundPoint {
            avg_bits: bits.average_bits(x.rows()),
            measured_error: quantization_error(x, transform, bits, Granularity::PerToken),
            bound: theorem1_bound(x, transform, bits),
        })
        .collect()
}

/// Figure-3b data: per-token energy spectra (descending) under each
/// transform, normalized to total energy 1.
pub struct EnergySpectra {
    pub identity: Vec<f64>,
    pub klt: Vec<f64>,
    pub dct: Vec<f64>,
    pub wht: Vec<f64>,
    pub dwt: Vec<f64>,
}

pub fn fig3_energy_spectra(samples: &[Tensor]) -> EnergySpectra {
    let s = samples[0].rows();
    let cov = autocorrelation(samples);
    let klt = KltTransform::from_autocorrelation(&cov);
    let dct = DctTransform::new(s);
    let wht = WhtTransform::new(s);
    let dwt = HaarDwt::new(s, HaarDwt::max_levels(s).min(3));
    let id = IdentitySeq::new(s);

    let spectrum = |t: &dyn SequenceTransform| -> Vec<f64> {
        let mut acc = vec![0.0f64; s];
        for x in samples {
            let y = t.forward(x);
            for (a, e) in acc.iter_mut().zip(token_energies(&y)) {
                *a += e;
            }
        }
        let total: f64 = acc.iter().sum();
        let mut v: Vec<f64> = acc.iter().map(|&e| e / total).collect();
        v.sort_by(|a, b| b.partial_cmp(a).unwrap());
        v
    };

    EnergySpectra {
        identity: spectrum(&id),
        klt: spectrum(&klt),
        dct: spectrum(&dct),
        wht: spectrum(&wht),
        dwt: spectrum(&dwt),
    }
}

/// Fraction of energy in the top-k coefficients of a (sorted) spectrum.
pub fn topk_share(spectrum: &[f64], k: usize) -> f64 {
    spectrum[..k.min(spectrum.len())].iter().sum()
}

/// Figure-3a data: the (normalized) autocorrelation matrix itself.
pub fn fig3_autocorrelation(samples: &[Tensor]) -> Tensor {
    let cov = autocorrelation(samples);
    let n = cov.rows();
    let mut out = cov.clone();
    for i in 0..n {
        for j in 0..n {
            let d = (cov.at(i, i) * cov.at(j, j)).sqrt().max(1e-12);
            out.set(i, j, cov.at(i, j) / d);
        }
    }
    out
}

/// Figure-3 eigenvalue spectrum of the autocorrelation (for DESIGN.md's
/// Szegő checks).
pub fn autocorr_eigenvalues(samples: &[Tensor]) -> Vec<f32> {
    let cov = autocorrelation(samples);
    eigh(&cov, 60, 1e-9).values
}

/// Figure-4a: the three bit-allocation strategies compared on one energy
/// vector — (uniform, continuous-optimal, 2-level) with their Theorem-1
/// objective values `Σ eᵢ/2^{2bᵢ}`.
pub struct AllocationComparison {
    pub uniform_objective: f64,
    pub optimal_objective: f64,
    pub two_level_objective: f64,
    pub avg_bits: f64,
}

pub fn fig4a_allocations(energies: &[f64], avg_bits: f64, hp_tokens: usize) -> AllocationComparison {
    let s = energies.len();
    let objective = |bits: &[f64]| -> f64 {
        energies.iter().zip(bits).map(|(&e, &b)| e / 2f64.powf(2.0 * b)).sum()
    };
    let uniform = vec![avg_bits; s];
    let e32: Vec<f32> = energies.iter().map(|&e| e as f32).collect();
    let optimal = optimal_bits(&e32, avg_bits * s as f64);
    // 2-level at the same budget: hp_tokens at hp bits, rest at lp such
    // that the average matches (continuous lp for a fair comparison).
    let hp_bits = 8.0f64;
    let lp_bits = (avg_bits * s as f64 - hp_bits * hp_tokens as f64) / (s - hp_tokens) as f64;
    let two_level: Vec<f64> =
        (0..s).map(|i| if i < hp_tokens { hp_bits } else { lp_bits }).collect();
    AllocationComparison {
        uniform_objective: objective(&uniform),
        optimal_objective: objective(&optimal),
        two_level_objective: objective(&two_level),
        avg_bits,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{ActivationGenerator, ActivationSpec};

    fn samples() -> Vec<Tensor> {
        let spec = ActivationSpec {
            outlier_channels: 0,
            sink_scale: 0.0,
            ..ActivationSpec::llm(64, 32)
        };
        ActivationGenerator::new(spec).calibration_set(8, 3)
    }

    #[test]
    fn bound_dominates_error_everywhere() {
        let x = &samples()[0];
        let t = HaarDwt::new(64, 3);
        let allocs: Vec<BitAllocation> =
            (3..=8).map(|b| BitAllocation::uniform(b)).collect();
        for p in fig2_bound_curve(x, &t, &allocs) {
            assert!(p.measured_error <= p.bound, "err {} > bound {}", p.measured_error, p.bound);
            assert!(p.bound.is_finite());
        }
    }

    #[test]
    fn stamp_curve_below_uniform_identity() {
        // The Fig-2b claim: at avg 5 bits, DWT + 2-level < identity uniform.
        let x = &samples()[0];
        let id = IdentitySeq::new(64);
        let dwt = HaarDwt::new(64, 3);
        let uni = quantization_error(x, &id, &BitAllocation::uniform(5), Granularity::PerToken);
        // 8 hp tokens of 64 at 8b, rest ~4.57b -> use 8/4 mix at avg 4.5.
        let mix = quantization_error(
            x,
            &dwt,
            &BitAllocation::two_level(8, 8, 4),
            Granularity::PerToken,
        );
        assert!(mix < uni, "stamp {mix} !< uniform {uni}");
    }

    #[test]
    fn spectra_ordering_klt_best() {
        let sp = fig3_energy_spectra(&samples());
        let k = 8;
        let klt = topk_share(&sp.klt, k);
        let dct = topk_share(&sp.dct, k);
        let dwt = topk_share(&sp.dwt, k);
        let id = topk_share(&sp.identity, k);
        assert!(klt >= dct - 0.02, "klt {klt} dct {dct}");
        assert!(dct > id, "dct {dct} id {id}");
        assert!(dwt > id, "dwt {dwt} id {id}");
        // KLT top-8 of 64 on ρ=0.95 AR(1) data concentrates hard.
        assert!(klt > 0.6, "klt share {klt}");
    }

    #[test]
    fn autocorr_normalized_diag() {
        let ac = fig3_autocorrelation(&samples());
        for i in 0..ac.rows() {
            assert!((ac.at(i, i) - 1.0).abs() < 1e-4);
        }
    }

    #[test]
    fn allocation_objectives_ordered() {
        // optimal ≤ two-level ≤ uniform on a concentrated energy vector.
        let energies: Vec<f64> = (0..64).map(|i| 100.0 / (1.0 + i as f64).powi(2)).collect();
        let c = fig4a_allocations(&energies, 5.0, 8);
        assert!(c.optimal_objective <= c.two_level_objective * 1.0001);
        assert!(c.two_level_objective < c.uniform_objective);
    }
}
