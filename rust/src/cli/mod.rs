//! Hand-rolled CLI (offline stand-in for clap; DESIGN.md §3).
//!
//! ```text
//! stamp eval  <table1|table2|table4|table5|fig4b|fig7|fig9> [--fast] [--csv DIR]
//! stamp report <fig2|fig3|fig4a> [--csv DIR]
//! stamp serve [--config FILE] [--requests N]
//! stamp train <tiny|small|medium|wide> [--steps N]
//! stamp info
//! ```

use crate::report::Table;
use std::path::PathBuf;

/// Parsed command line.
#[derive(Debug)]
pub struct Args {
    pub command: String,
    pub positional: Vec<String>,
    pub flags: std::collections::HashMap<String, String>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Args {
        let command = argv.first().cloned().unwrap_or_else(|| "help".into());
        let mut positional = Vec::new();
        let mut flags = std::collections::HashMap::new();
        let mut i = 1;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(name) = a.strip_prefix("--") {
                // `--flag value` or bare `--flag`.
                if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    flags.insert(name.to_string(), argv[i + 1].clone());
                    i += 2;
                } else {
                    flags.insert(name.to_string(), "true".into());
                    i += 1;
                }
            } else {
                positional.push(a.clone());
                i += 1;
            }
        }
        Args { command, positional, flags }
    }

    pub fn flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.contains_key(name)
    }

    pub fn csv_dir(&self) -> Option<PathBuf> {
        self.flag("csv").map(PathBuf::from)
    }
}

pub const HELP: &str = "\
stamp — STaMP: Sequence Transformation and Mixed Precision (reproduction)

USAGE:
  stamp eval <table1|table2|table4|table5|fig4b|fig7|fig9> [--fast] [--csv DIR]
  stamp report <fig2|fig3|fig4a> [--csv DIR]
  stamp serve [--config FILE] [--requests N]
  stamp train <tiny|small|medium|wide> [--steps N]
  stamp info

Tables/figures map 1:1 to the paper's evaluation section; see DESIGN.md
for the experiment index and EXPERIMENTS.md for recorded runs.
";

/// Print a table and optionally emit CSV.
pub fn emit(table: &Table, csv_dir: Option<&std::path::Path>) {
    match table.emit(csv_dir) {
        Ok(text) => println!("{text}"),
        Err(e) => eprintln!("warning: CSV emission failed: {e}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_command_and_positional() {
        let a = Args::parse(&argv("eval table2 --fast --csv out"));
        assert_eq!(a.command, "eval");
        assert_eq!(a.positional, vec!["table2"]);
        assert!(a.has_flag("fast"));
        assert_eq!(a.flag("csv"), Some("out"));
    }

    #[test]
    fn bare_flags() {
        let a = Args::parse(&argv("serve --config cfg.toml --verbose"));
        assert_eq!(a.flag("config"), Some("cfg.toml"));
        assert!(a.has_flag("verbose"));
    }

    #[test]
    fn empty_is_help() {
        let a = Args::parse(&[]);
        assert_eq!(a.command, "help");
    }
}
