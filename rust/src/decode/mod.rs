//! Step-synchronized batched decode engine: many autoregressive streams,
//! one fused GEMM per linear per step.
//!
//! PR 3's serving path batched *requests* at the coordinator but decoded
//! them serially inside the executor — every layer ran a `[1 × d_model]`
//! GEMV that re-streamed the full weight matrix per request per token.
//! [`DecodeEngine`] owns a set of in-flight streams (each with its own
//! [`KvCache`], position offset, sampler state, and remaining-token
//! budget) and advances **all** active streams one token per step: the
//! streams' current tokens are stacked into one `[n_active × d_model]`
//! activation, every projection / FFN / logits-head linear runs as a
//! single `matmul`/`qgemm` call, and attention scatters per stream over
//! each stream's own cached K/V
//! ([`crate::model::attention::MultiHeadAttention::forward_decode_batch`]).
//! Arithmetic intensity on the weight-bound hot path rises by ~n_active —
//! the continuous-batching insight of Orca/vLLM-style serving (PAPERS.md),
//! here applied to the paper's low-bit serving setting.
//!
//! ## Ragged-batch slot lifecycle (DESIGN.md §12)
//!
//! * **Admission** — streams join with different prompt lengths; prefill
//!   stays per-stream ([`crate::model::Gpt::prefill`] handles any number
//!   of rows of *one* stream, which is a different shape of work than the
//!   fused step).
//! * **Stepping** — active slots advance in lock-step. The fused step is
//!   chunked at `decode_batch` streams per GEMM so a huge admission wave
//!   cannot blow up the working set; `decode_batch = 1` degenerates to
//!   PR 3's serial per-request stepping, same results.
//! * **Retirement** — a slot retires when its budget is exhausted, or —
//!   with a `truncated` flag — when its capacity-bounded cache cannot take
//!   another token ([`crate::kvcache::KvStream::try_append`] surfaces the
//!   same condition recoverably). Retirement never stalls the remaining
//!   streams: the slot simply leaves the stacked activation from the next
//!   step on. Under a sliding-window cache policy
//!   ([`crate::kvcache::EvictionPolicy::SlidingWindow`]) streams are
//!   unbounded instead: long prompts prefill in chunks, eviction keeps the
//!   resident set (and the positional rank) below the model's `max_seq`,
//!   and a stream decodes arbitrarily far past it — truncation then only
//!   arises from an explicit caller-supplied logical cap (DESIGN.md §13).
//!
//! ## Why batching preserves per-stream causality and bit-parity
//!
//! Streams share *weights*, never *state*: attention reads only the
//! stream's own cache, and every fused kernel on the step (matmul,
//! matmul_transb, qgemm, RMSNorm, SiLU gating) is row-wise — row `i` of
//! the output depends only on row `i` of the input, with a reduction
//! order independent of how many rows are present. So with an fp32 cache
//! and [`FpHook`], each stream's batched output is **bit-identical** to
//! PR 3's serial [`crate::model::Gpt::generate_greedy`] at any thread
//! count and any batch composition (`tests/decode.rs` pins it, including
//! mixed prompt lengths and mid-run retirement). A packed cache quantizes
//! each stream's history independently, so the same argument makes
//! batched packed decode bit-identical to serial packed decode; only the
//! cache policy itself introduces drift (quantified in `tests/decode.rs`).
//!
//! One caveat for quantized *activation* stacks ([`crate::baselines::QuantHook`]):
//! window-relative policies (e.g. `hp_tokens` treating row 0 of each call
//! as "token 0") see one `[n_active × d]` window instead of n 1-row
//! windows, so a stack's decode-time activation QDQ may differ between
//! batched and serial stepping. That matches what a fused deployment
//! kernel would see; the paper-shaped serving setup (FP linears +
//! quantized KV cache, `stack = None`) is unaffected.

use crate::kvcache::{EvictionPolicy, KvCache, KvCacheConfig};
use crate::model::gpt::argmax_row;
use crate::model::{FpHook, Gpt, LinearHook};
use crate::tensor::XorShiftRng;

/// Token-selection policy, applied per stream per step.
///
/// `Greedy` is the default everywhere and keeps PR 3's deterministic
/// argmax (first-maximum tie-break). `TopK` samples from the temperature-
/// scaled softmax over the `k` highest logits via [`XorShiftRng`]; each
/// stream draws from its own generator seeded with `seed`, so a stream's
/// sampled continuation is a pure function of (weights, prompt, spec) —
/// independent of batch composition, chunking, and retirement order —
/// and batched runs stay exactly reproducible.
#[derive(Clone, Debug, PartialEq)]
pub enum Sampling {
    /// Deterministic argmax (the PR 3 behavior; the default).
    Greedy,
    /// Temperature + top-k sampling. `k = 0` means the full vocabulary;
    /// `temperature` must be positive.
    TopK { k: usize, temperature: f32, seed: u64 },
}

/// Per-stream sampler state (spec + that stream's own RNG).
struct Sampler {
    spec: Sampling,
    rng: XorShiftRng,
}

impl Sampler {
    fn new(spec: &Sampling) -> Self {
        let seed = match spec {
            Sampling::Greedy => 0,
            Sampling::TopK { seed, .. } => *seed,
        };
        Sampler { spec: spec.clone(), rng: XorShiftRng::new(seed) }
    }

    /// Pick the next token from one logits row.
    fn next(&mut self, row: &[f32]) -> u32 {
        match self.spec {
            Sampling::Greedy => argmax_row(row),
            Sampling::TopK { k, temperature, .. } => {
                let k = if k == 0 { row.len() } else { k.min(row.len()) };
                // Candidates by (logit desc, index asc) — a total,
                // deterministic order even under ties, so the top-k *set*
                // is unique and select-then-sort equals sort-then-truncate
                // while skipping the O(V log V) full-vocab sort on this
                // per-token hot path.
                let cmp = |a: &usize, b: &usize| {
                    row[*b]
                        .partial_cmp(&row[*a])
                        .unwrap_or(std::cmp::Ordering::Equal)
                        .then(a.cmp(b))
                };
                let mut idx: Vec<usize> = (0..row.len()).collect();
                if k < idx.len() {
                    idx.select_nth_unstable_by(k - 1, cmp);
                    idx.truncate(k);
                }
                idx.sort_by(cmp);
                // Softmax over the shortlist at temperature t, in f64 and
                // in shortlist order — a fixed reduction order, so the
                // draw is bit-reproducible.
                let t = temperature.max(1e-6) as f64;
                let top = row[idx[0]] as f64;
                let weights: Vec<f64> =
                    idx.iter().map(|&i| ((row[i] as f64 - top) / t).exp()).collect();
                let total: f64 = weights.iter().sum();
                let mut u = self.rng.next_f64() * total;
                for (w, &i) in weights.iter().zip(&idx) {
                    u -= w;
                    if u <= 0.0 {
                        return i as u32;
                    }
                }
                // Float-tail fallback: the last (least likely) candidate.
                idx[k - 1] as u32
            }
        }
    }
}

/// One generation request: a prompt plus a new-token budget.
#[derive(Clone, Debug)]
pub struct GenRequest {
    pub prompt: Vec<u32>,
    pub n_new: usize,
}

/// What a stream produced by the time it retired.
#[derive(Clone, Debug, PartialEq)]
pub struct StreamResult {
    /// Generated ids, in order (length ≤ the request's `n_new`).
    pub tokens: Vec<u32>,
    /// `true` when the stream hit its cache capacity before exhausting
    /// its budget and was retired early instead of panicking the batch.
    pub truncated: bool,
}

/// An in-flight stream between admission and retirement.
struct Slot {
    /// Index into the request (and result) vector.
    idx: usize,
    cache: KvCache,
    sampler: Sampler,
    /// Generated so far; the last entry is the token fed at the next step.
    out: Vec<u32>,
    n_new: usize,
}

/// Step-synchronized batched decode over a shared model (module docs).
///
/// The engine is reusable: [`DecodeEngine::run`] owns all per-run state,
/// so one engine can serve successive coordinator batches.
pub struct DecodeEngine<'m> {
    gpt: &'m Gpt,
    kv: KvCacheConfig,
    sampling: Sampling,
    decode_batch: usize,
}

/// Default cap on streams fused into one GEMM (the `[generate]`
/// `decode_batch` TOML knob): matches the coordinator's default
/// `max_batch`, so a full coordinator batch fuses into a single step.
pub const DEFAULT_DECODE_BATCH: usize = 8;

impl<'m> DecodeEngine<'m> {
    /// Build an engine over `gpt` with a per-stream cache policy and a
    /// sampling spec.
    ///
    /// Without an eviction policy the cache capacity is clamped to the
    /// model's `max_seq` (tighter caller-supplied bounds are kept), so a
    /// stream that outgrows the model retires with a truncation flag
    /// instead of panicking mid-batch. With a sliding window the stream is
    /// *unbounded*: only the resident set must fit the positional table
    /// ([`KvCacheConfig::resident_bound`] ≤ model `max_seq`, asserted
    /// here), prompts longer than `max_seq` prefill in chunks, and streams
    /// decode indefinitely — truncation can then only arise from an
    /// explicit caller-supplied `kv.max_seq` logical cap.
    pub fn new(gpt: &'m Gpt, kv: KvCacheConfig, sampling: Sampling) -> Self {
        let mut kv = kv;
        match kv.eviction {
            EvictionPolicy::None => {
                let cap = kv.max_seq.map_or(gpt.cfg.max_seq, |m| m.min(gpt.cfg.max_seq));
                kv.max_seq = Some(cap);
            }
            EvictionPolicy::SlidingWindow { .. } => {
                let bound = kv.resident_bound().expect("sliding window bounds residency");
                assert!(
                    bound <= gpt.cfg.max_seq,
                    "kv window residency bound {bound} (block-rounded sinks + window + block) \
                     exceeds model max_seq {}",
                    gpt.cfg.max_seq
                );
            }
        }
        kv.validate();
        DecodeEngine { gpt, kv, sampling, decode_batch: DEFAULT_DECODE_BATCH }
    }

    /// Cap on streams fused into one step GEMM (≥ 1; 1 = serial stepping).
    pub fn with_decode_batch(mut self, decode_batch: usize) -> Self {
        assert!(decode_batch >= 1, "decode_batch must be ≥ 1");
        self.decode_batch = decode_batch;
        self
    }

    /// Greedy fp32-linear convenience entry (the paper-shaped serving
    /// setup quantizes only the KV cache).
    pub fn run_fp(&self, reqs: &[GenRequest]) -> crate::error::Result<Vec<StreamResult>> {
        self.run(&FpHook, reqs)
    }

    /// Admit every request, advance all active streams one token per
    /// step, and return one [`StreamResult`] per request, in request
    /// order. Errors (empty or out-of-vocab prompt, prompt longer than a
    /// *bounded* cache's capacity) reject the whole run before any
    /// decoding; a windowed (unbounded) cache accepts prompts of any
    /// length and prefills them in chunks.
    pub fn run(
        &self,
        hook: &dyn LinearHook,
        reqs: &[GenRequest],
    ) -> crate::error::Result<Vec<StreamResult>> {
        let vocab = self.gpt.cfg.vocab_size;
        // `Some` for bounded caches (always, without eviction); `None`
        // when a sliding window keeps the stream unbounded.
        let cap = self.kv.max_seq;
        for (i, r) in reqs.iter().enumerate() {
            if r.prompt.is_empty() {
                crate::bail!("stream {i}: prompt must be non-empty");
            }
            if let Some(&t) = r.prompt.iter().find(|&&t| t as usize >= vocab) {
                crate::bail!("stream {i}: token {t} out of vocab {vocab}");
            }
            if let Some(cap) = cap {
                if r.prompt.len() > cap {
                    crate::bail!(
                        "stream {i}: prompt {} exceeds cache capacity {cap}",
                        r.prompt.len()
                    );
                }
            }
        }

        let mut done: Vec<Option<StreamResult>> = reqs.iter().map(|_| None).collect();
        let mut slots: Vec<Slot> = Vec::new();
        // Admission: per-stream prefill (ragged prompt lengths), then the
        // first sampled token. Prefill is chunked so each chunk starts at
        // the cache's resident rank: for a bounded cache the whole
        // (validated ≤ cap ≤ max_seq) prompt is one chunk — exactly the
        // pre-eviction path — while a windowed cache admits prompts past
        // `max_seq` because eviction between chunks keeps the rank low.
        // Windowed chunks are additionally capped at `window` tokens: a
        // chunk's K/V are appended (and evicted) *before* its attention
        // runs, so a chunk wider than the window would let eviction drop
        // its own middle mid-append — queries would attend only the sinks
        // instead of their recency window. With `chunk ≤ window` a query's
        // whole same-chunk prefix survives (its newest key is within
        // `window` of the chunk end), so every query sees
        // `[sinks ‖ chunk prefix ‖ most recent pre-chunk remainder]` — the
        // same approximation class as windowed decode itself.
        let chunk_cap = match self.kv.eviction {
            EvictionPolicy::SlidingWindow { window, .. } => window,
            EvictionPolicy::None => usize::MAX,
        };
        for (i, r) in reqs.iter().enumerate() {
            let mut cache = KvCache::new(self.gpt.cfg.n_layers, self.kv.clone());
            let mut logits = None;
            let mut off = 0usize;
            while off < r.prompt.len() {
                let take = (self.gpt.cfg.max_seq - cache.pos_next())
                    .min(chunk_cap)
                    .min(r.prompt.len() - off);
                logits = Some(self.gpt.prefill(hook, &r.prompt[off..off + take], &mut cache));
                off += take;
            }
            let logits = logits.expect("validated prompts are non-empty");
            let mut sampler = Sampler::new(&self.sampling);
            let mut out = Vec::with_capacity(r.n_new);
            if r.n_new > 0 {
                out.push(sampler.next(logits.row(logits.rows() - 1)));
            }
            if out.len() >= r.n_new {
                done[i] = Some(StreamResult { tokens: out, truncated: false });
            } else {
                slots.push(Slot { idx: i, cache, sampler, out, n_new: r.n_new });
            }
        }

        // Step loop: every iteration advances all still-active streams by
        // exactly one token (step-synchronized), fused in decode_batch
        // chunks.
        while !slots.is_empty() {
            // Retire streams whose cache cannot take the pending token —
            // the recoverable per-stream form of the max_seq overflow.
            let mut j = 0;
            while j < slots.len() {
                if matches!(slots[j].cache.remaining(), Some(0)) {
                    let s = slots.swap_remove(j);
                    done[s.idx] = Some(StreamResult { tokens: s.out, truncated: true });
                } else {
                    j += 1;
                }
            }
            for chunk in slots.chunks_mut(self.decode_batch) {
                let tokens: Vec<u32> =
                    chunk.iter().map(|s| *s.out.last().expect("active slot has a token")).collect();
                let mut caches: Vec<&mut KvCache> =
                    chunk.iter_mut().map(|s| &mut s.cache).collect();
                let logits = self.gpt.decode_step_batch(hook, &tokens, &mut caches);
                drop(caches);
                for (row, s) in chunk.iter_mut().enumerate() {
                    let t = s.sampler.next(logits.row(row));
                    s.out.push(t);
                }
            }
            // Retire streams that reached their budget.
            let mut j = 0;
            while j < slots.len() {
                if slots[j].out.len() >= slots[j].n_new {
                    let s = slots.swap_remove(j);
                    done[s.idx] = Some(StreamResult { tokens: s.out, truncated: false });
                } else {
                    j += 1;
                }
            }
        }
        Ok(done.into_iter().map(|o| o.expect("every stream resolved")).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::GptConfig;

    fn prompt(n: usize, salt: usize) -> Vec<u32> {
        (0..n).map(|i| ((i * 7 + salt * 11 + 3) % 70) as u32).collect()
    }

    #[test]
    fn greedy_batch_matches_serial_generate_greedy() {
        let gpt = Gpt::new(GptConfig::tiny(), 41);
        let reqs = vec![
            GenRequest { prompt: prompt(5, 0), n_new: 12 },
            GenRequest { prompt: prompt(11, 1), n_new: 3 },
            GenRequest { prompt: prompt(2, 2), n_new: 8 },
        ];
        let engine = DecodeEngine::new(&gpt, KvCacheConfig::fp32(), Sampling::Greedy)
            .with_decode_batch(2);
        let got = engine.run_fp(&reqs).unwrap();
        for (i, r) in reqs.iter().enumerate() {
            let mut cache = KvCache::fp32(gpt.cfg.n_layers);
            let want = gpt.generate_greedy(&FpHook, &r.prompt, r.n_new, &mut cache);
            assert_eq!(got[i].tokens, want, "stream {i}");
            assert!(!got[i].truncated);
        }
    }

    #[test]
    fn zero_budget_and_bad_requests() {
        let gpt = Gpt::new(GptConfig::tiny(), 42);
        let engine = DecodeEngine::new(&gpt, KvCacheConfig::fp32(), Sampling::Greedy);
        let got = engine
            .run_fp(&[GenRequest { prompt: prompt(4, 0), n_new: 0 }])
            .unwrap();
        assert!(got[0].tokens.is_empty() && !got[0].truncated);
        let err = engine.run_fp(&[GenRequest { prompt: vec![], n_new: 4 }]).unwrap_err();
        assert!(err.to_string().contains("non-empty"), "{err}");
        let err = engine.run_fp(&[GenRequest { prompt: vec![9999], n_new: 4 }]).unwrap_err();
        assert!(err.to_string().contains("out of vocab"), "{err}");
        let long = prompt(300, 0).iter().map(|&t| t % 70).collect::<Vec<u32>>();
        let err = engine.run_fp(&[GenRequest { prompt: long, n_new: 1 }]).unwrap_err();
        assert!(err.to_string().contains("exceeds cache capacity"), "{err}");
    }

    #[test]
    fn truncation_retires_one_stream_without_stalling_the_rest() {
        let gpt = Gpt::new(GptConfig::tiny(), 43);
        // Tight engine-level bound: prefill 8 + 4 appends fill cap 12; the
        // 5th generated token is sampled but the 6th needs a 13th slot.
        let kv = KvCacheConfig::fp32().with_max_seq(12);
        let reqs = vec![
            GenRequest { prompt: prompt(8, 0), n_new: 20 },
            GenRequest { prompt: prompt(2, 1), n_new: 6 },
        ];
        let engine = DecodeEngine::new(&gpt, kv, Sampling::Greedy);
        let got = engine.run_fp(&reqs).unwrap();
        assert!(got[0].truncated);
        assert_eq!(got[0].tokens.len(), 5, "prefill 8 + 4 appends under cap 12 → 5 tokens");
        assert!(!got[1].truncated);
        assert_eq!(got[1].tokens.len(), 6);
        // Each stream still matches its unbounded serial run (prefix-wise
        // for the truncated one).
        let mut c = KvCache::fp32(gpt.cfg.n_layers);
        let serial0 = gpt.generate_greedy(&FpHook, &reqs[0].prompt, 20, &mut c);
        assert_eq!(got[0].tokens[..], serial0[..5]);
        let mut c = KvCache::fp32(gpt.cfg.n_layers);
        let serial1 = gpt.generate_greedy(&FpHook, &reqs[1].prompt, 6, &mut c);
        assert_eq!(got[1].tokens, serial1);
    }

    #[test]
    fn windowed_stream_decodes_past_max_seq_untruncated() {
        // The headline of the eviction subsystem: with a window policy a
        // stream's budget can exceed the model's positional table many
        // times over and it still returns exactly n_new tokens, while an
        // unwindowed batch-mate behaves as before.
        let gpt = Gpt::new(GptConfig::tiny(), 45);
        let kv = KvCacheConfig::two_level(16, 8, 4, 8).with_window(16, 48);
        let n_long = 4 * gpt.cfg.max_seq; // 1024 ≫ max_seq = 256
        let reqs = vec![
            GenRequest { prompt: prompt(8, 0), n_new: n_long },
            GenRequest { prompt: prompt(3, 1), n_new: 5 },
        ];
        let engine = DecodeEngine::new(&gpt, kv, Sampling::Greedy);
        let got = engine.run_fp(&reqs).unwrap();
        assert_eq!(got[0].tokens.len(), n_long);
        assert!(!got[0].truncated, "windowed streams never truncate");
        for &t in &got[0].tokens {
            assert!((t as usize) < gpt.cfg.vocab_size);
        }
        assert_eq!(got[1].tokens.len(), 5);
        assert!(!got[1].truncated);
    }

    #[test]
    fn windowed_prompt_longer_than_max_seq_prefills_chunked() {
        // A prompt past the positional table is admitted by chunked
        // prefill under a window policy — and rejected, as before, by a
        // bounded engine.
        let gpt = Gpt::new(GptConfig::tiny(), 46);
        let long: Vec<u32> = (0..300).map(|i| ((i * 3 + 1) % 70) as u32).collect();
        let (window, n_new) = (48usize, 8usize);
        let kv = KvCacheConfig::two_level(16, 8, 4, 8).with_window(16, window);
        let engine = DecodeEngine::new(&gpt, kv.clone(), Sampling::Greedy);
        let reqs = vec![GenRequest { prompt: long.clone(), n_new }];
        let got = engine.run_fp(&reqs).unwrap();
        assert_eq!(got[0].tokens.len(), n_new);
        assert!(!got[0].truncated);
        // Deterministic: the same long request reproduces exactly.
        assert_eq!(engine.run_fp(&reqs).unwrap(), got);
        // The chunk width is pinned to the *window* budget (a chunk's K/V
        // append — and eviction — precedes its attention, so wider chunks
        // would evict their own middle before it is ever attended): a
        // manual window-sized chunked prefill + greedy loop reproduces
        // the engine bit-for-bit.
        let argmax = |row: &[f32]| {
            row.iter().enumerate().fold(0usize, |b, (i, &v)| if v > row[b] { i } else { b }) as u32
        };
        let mut cache = KvCache::new(gpt.cfg.n_layers, kv);
        let mut last = None;
        let mut off = 0usize;
        while off < long.len() {
            let take = window.min(long.len() - off);
            last = Some(gpt.prefill(&FpHook, &long[off..off + take], &mut cache));
            off += take;
        }
        let logits = last.unwrap();
        let mut want = Vec::with_capacity(n_new);
        let mut next = argmax(logits.row(logits.rows() - 1));
        want.push(next);
        while want.len() < n_new {
            let l = gpt.decode_step(&FpHook, next, &mut cache);
            next = argmax(l.row(0));
            want.push(next);
        }
        assert_eq!(got[0].tokens, want, "engine must chunk admission at the window budget");
        let bounded = DecodeEngine::new(&gpt, KvCacheConfig::fp32(), Sampling::Greedy);
        let err = bounded.run_fp(&reqs).unwrap_err();
        assert!(err.to_string().contains("exceeds cache capacity"), "{err}");
    }

    #[test]
    #[should_panic(expected = "exceeds model max_seq")]
    fn rejects_window_residency_larger_than_positional_table() {
        let gpt = Gpt::new(GptConfig::tiny(), 47);
        // sinks 64 (block-rounded 64) + window 256 + block 32 > 256.
        let kv = KvCacheConfig::default().with_window(64, 256);
        let _ = DecodeEngine::new(&gpt, kv, Sampling::Greedy);
    }

    #[test]
    fn topk_sampling_is_deterministic_and_batch_invariant() {
        let gpt = Gpt::new(GptConfig::tiny(), 44);
        let sampling = Sampling::TopK { k: 8, temperature: 0.9, seed: 0x5EED };
        let reqs = vec![
            GenRequest { prompt: prompt(6, 0), n_new: 10 },
            GenRequest { prompt: prompt(3, 1), n_new: 10 },
            GenRequest { prompt: prompt(9, 2), n_new: 4 },
        ];
        let engine = DecodeEngine::new(&gpt, KvCacheConfig::fp32(), sampling.clone());
        let batched = engine.run_fp(&reqs).unwrap();
        // Same spec, streams run one at a time: per-stream RNGs make the
        // draws independent of batch composition.
        for (i, r) in reqs.iter().enumerate() {
            let solo = engine.run_fp(std::slice::from_ref(r)).unwrap();
            assert_eq!(solo[0], batched[i], "stream {i} must not depend on batch-mates");
        }
        // And the run is reproducible wholesale.
        assert_eq!(engine.run_fp(&reqs).unwrap(), batched);
        for r in &batched {
            for &t in &r.tokens {
                assert!((t as usize) < gpt.cfg.vocab_size);
            }
        }
        // Different seed, different continuation (overwhelmingly likely
        // over 10 draws from a near-uniform untrained model).
        let other = DecodeEngine::new(
            &gpt,
            KvCacheConfig::fp32(),
            Sampling::TopK { k: 8, temperature: 0.9, seed: 0xBEEF },
        );
        let alt = other.run_fp(&reqs).unwrap();
        assert_ne!(alt[0].tokens, batched[0].tokens, "seed must steer the draw");
    }

    #[test]
    fn greedy_sampler_matches_argmax_and_topk1_collapses() {
        // temperature>0 with k=1 must reproduce greedy's argmax choice.
        let row = [0.1f32, 2.5, -1.0, 2.5, 0.3];
        let mut g = Sampler::new(&Sampling::Greedy);
        let mut k1 = Sampler::new(&Sampling::TopK { k: 1, temperature: 1.0, seed: 7 });
        assert_eq!(g.next(&row), 1, "first maximum wins ties");
        assert_eq!(k1.next(&row), 1, "top-1 sampling is argmax with the same tie-break");
    }
}
