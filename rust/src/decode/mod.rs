//! Step-synchronized batched decode engine with **in-flight admission**:
//! many autoregressive streams, one fused GEMM per linear per step, and
//! streams that join a *running* engine as slots free up.
//!
//! PR 3's serving path batched *requests* at the coordinator but decoded
//! them serially inside the executor — every layer ran a `[1 × d_model]`
//! GEMV that re-streamed the full weight matrix per request per token.
//! PR 4's [`DecodeEngine`] fused a fixed batch: the streams' current
//! tokens are stacked into one `[n_active × d_model]` activation, every
//! projection / FFN / logits-head linear runs as a single
//! `matmul`/`qgemm` call, and attention scatters per stream over each
//! stream's own cached K/V
//! ([`crate::model::attention::MultiHeadAttention::forward_decode_batch`]).
//! Arithmetic intensity on the weight-bound hot path rises by ~n_active —
//! the continuous-batching insight of Orca/vLLM-style serving (PAPERS.md),
//! here applied to the paper's low-bit serving setting.
//!
//! This PR removes the last batch boundary: the engine is now a
//! *long-lived* object with a fixed slot array and a free-slot list.
//! [`DecodeEngine::admit`] seats a request in a free slot at any time —
//! including while other streams are mid-decode — [`DecodeEngine::step`]
//! advances every in-flight stream by one unit of work, and
//! [`DecodeEngine::drain`] hands back finished streams. Short requests no
//! longer wait for the longest batch-mate (the head-of-line blocking the
//! ROADMAP names as the wall in one-shot batching); a retiring stream's
//! slot is refilled on the very next scheduler tick.
//!
//! ## Slot lifecycle (DESIGN.md §14)
//!
//! * **Admission** — [`DecodeEngine::admit`] validates the request,
//!   pops a slot index off the free list, and seats the stream in the
//!   `Prefill` phase. No model work happens at admission (the hook is a
//!   `step` parameter, not engine state).
//! * **Prefill** — each [`DecodeEngine::step`] runs **one** prefill chunk
//!   per prefilling slot, after the fused decode of the already-active
//!   streams. Chunking follows the PR 5 rule: a chunk never exceeds the
//!   positional headroom (`max_seq − pos_next`) and, under a sliding
//!   window, never exceeds `window` tokens (a wider chunk would evict its
//!   own middle before attending it — DESIGN.md §13). When the prompt is
//!   exhausted the slot samples its first token from the final chunk's
//!   logits and enters `Decode` the *next* step, so every decoding stream
//!   gains exactly one token per step (step-synchronization is preserved).
//! * **Stepping** — active `Decode` slots advance in lock-step, fused in
//!   `decode_batch`-sized chunks so a huge admission wave cannot blow up
//!   the working set; `decode_batch = 1` degenerates to PR 3's serial
//!   per-request stepping, same results.
//! * **Retirement** — a slot retires when its budget is exhausted, or —
//!   with a `truncated` flag — when its capacity-bounded cache cannot take
//!   another token. The slot index returns to the free list and the
//!   result queues for [`DecodeEngine::drain`]; remaining streams never
//!   stall. Under a sliding-window cache policy
//!   ([`crate::kvcache::EvictionPolicy::SlidingWindow`]) streams are
//!   unbounded instead: long prompts prefill in chunks, eviction keeps the
//!   resident set (and the positional rank) below the model's `max_seq`,
//!   and a stream decodes arbitrarily far past it (DESIGN.md §13).
//!
//! ## Why admission order preserves per-stream bit-parity
//!
//! Streams share *weights*, never *state*: attention reads only the
//! stream's own cache, and every fused kernel on the step (matmul,
//! matmul_transb, qgemm, RMSNorm, SiLU gating) is row-wise — row `i` of
//! the output depends only on row `i` of the input, with a reduction
//! order independent of how many rows are present. A stream's chunk
//! sequence is likewise a pure function of its *own* cache state, and its
//! sampler is seeded per stream. So a stream's output is a pure function
//! of (weights, prompt, budget, kv config, sampling spec) — independent
//! of **when** it was admitted, which streams it shared steps with, and
//! the thread count. With an fp32 cache and [`FpHook`] each stream is
//! **bit-identical** to serial [`crate::model::Gpt::generate_greedy`];
//! with a packed cache it is bit-identical to its own serial packed run
//! (`tests/decode.rs` and `tests/continuous.rs` pin both, across random
//! admission schedules).
//!
//! One caveat for quantized *activation* stacks ([`crate::baselines::QuantHook`]):
//! window-relative policies (e.g. `hp_tokens` treating row 0 of each call
//! as "token 0") see one `[n_active × d]` window instead of n 1-row
//! windows, so a stack's decode-time activation QDQ may differ between
//! batched and serial stepping. That matches what a fused deployment
//! kernel would see; the paper-shaped serving setup (FP linears +
//! quantized KV cache, `stack = None`) is unaffected.
//!
//! ## Prompt-prefix sharing (DESIGN.md §15)
//!
//! Every engine owns one [`BlockPool`]; every admitted stream's cache
//! allocates its finalized blocks there. With
//! [`KvCacheConfig::prefix_cache`] set, [`DecodeEngine::admit`] looks the
//! prompt up in the pool's token-ID prefix index and, on a hit, seeds the
//! new cache from the pooled blocks ([`KvCache::seed_prefix`]) so prefill
//! starts at the divergence point — the shared span is neither
//! re-computed nor re-stored. When a prompt finishes prefilling (and
//! nothing was evicted), the engine registers every block-aligned prefix
//! of it, so later prompts sharing any aligned prefix can seat against
//! it. Sharing preserves the bit-parity argument above: a block's
//! representation depends only on its absolute base position and the
//! engine-wide cache config, so a seeded stream's gather — and therefore
//! its logits and tokens — is bit-identical to an unshared run
//! (`tests/prefix.rs` pins it, fp32 and packed, at any thread count).

mod speculate;

pub use speculate::{DraftKind, SpecConfig};

use crate::kvcache::{BlockPool, EvictionPolicy, KvCache, KvCacheConfig};
use crate::model::gpt::argmax_row;
use crate::model::{FpHook, Gpt, LinearHook};
use crate::obs::{site_guard, EngineObs, KernelSite, TraceKind};
use crate::tensor::XorShiftRng;
use speculate::{draft_ngram, draft_packed};
use std::collections::VecDeque;
use std::sync::Arc;

/// Token-selection policy, applied per stream per step.
///
/// `Greedy` is the default everywhere and keeps PR 3's deterministic
/// argmax (first-maximum tie-break). `TopK` samples from the temperature-
/// scaled softmax over the `k` highest logits via [`XorShiftRng`]; each
/// stream draws from its own generator seeded with `seed`, so a stream's
/// sampled continuation is a pure function of (weights, prompt, spec) —
/// independent of batch composition, chunking, admission time, and
/// retirement order — and batched runs stay exactly reproducible.
#[derive(Clone, Debug, PartialEq)]
pub enum Sampling {
    /// Deterministic argmax (the PR 3 behavior; the default).
    Greedy,
    /// Temperature + top-k sampling. `k = 0` means the full vocabulary;
    /// `temperature` must be positive.
    TopK { k: usize, temperature: f32, seed: u64 },
}

/// Per-stream sampler state (spec + that stream's own RNG).
struct Sampler {
    spec: Sampling,
    rng: XorShiftRng,
}

impl Sampler {
    fn new(spec: &Sampling) -> Self {
        let seed = match spec {
            Sampling::Greedy => 0,
            Sampling::TopK { seed, .. } => *seed,
        };
        Sampler { spec: spec.clone(), rng: XorShiftRng::new(seed) }
    }

    /// Pick the next token from one logits row.
    fn next(&mut self, row: &[f32]) -> u32 {
        match self.spec {
            Sampling::Greedy => argmax_row(row),
            Sampling::TopK { k, temperature, .. } => {
                let k = if k == 0 { row.len() } else { k.min(row.len()) };
                // Candidates by (logit desc, index asc) — a total,
                // deterministic order even under ties, so the top-k *set*
                // is unique and select-then-sort equals sort-then-truncate
                // while skipping the O(V log V) full-vocab sort on this
                // per-token hot path. NaN logits (a poisoned upstream
                // kernel or hook) order deterministically *last*, below
                // every finite value: `select_nth_unstable_by` and
                // `sort_by` require a strict weak ordering, and the old
                // `partial_cmp(..).unwrap_or(Equal)` collapse made the
                // comparator non-transitive in their presence (NaN ≈ 2.0
                // and NaN ≈ 5.0 while 2.0 < 5.0), so a NaN could seat
                // anywhere in the shortlist — partition-dependent output
                // at best, a sort-invariant panic at worst.
                let cmp = |a: &usize, b: &usize| {
                    let (x, y) = (row[*a], row[*b]);
                    match (x.is_nan(), y.is_nan()) {
                        (true, true) => a.cmp(b),
                        (true, false) => std::cmp::Ordering::Greater,
                        (false, true) => std::cmp::Ordering::Less,
                        (false, false) => {
                            y.partial_cmp(&x).expect("non-NaN floats compare").then(a.cmp(b))
                        }
                    }
                };
                let mut idx: Vec<usize> = (0..row.len()).collect();
                if k < idx.len() {
                    idx.select_nth_unstable_by(k - 1, cmp);
                    idx.truncate(k);
                }
                idx.sort_by(cmp);
                // Softmax over the shortlist at temperature t, in f64 and
                // in shortlist order — a fixed reduction order, so the
                // draw is bit-reproducible. The config layer rejects
                // non-positive temperatures at parse time
                // ([`crate::config::GenerateSpec::check`]); the clamp
                // stays as defense-in-depth for engines built directly.
                let t = temperature.max(1e-6) as f64;
                let top = row[idx[0]] as f64;
                let weights: Vec<f64> =
                    idx.iter().map(|&i| ((row[i] as f64 - top) / t).exp()).collect();
                let total: f64 = weights.iter().sum();
                let mut u = self.rng.next_f64() * total;
                for (w, &i) in weights.iter().zip(&idx) {
                    u -= w;
                    if u <= 0.0 {
                        return i as u32;
                    }
                }
                // Float-tail fallback: the last (least likely) candidate.
                idx[k - 1] as u32
            }
        }
    }
}

/// One generation request: a prompt plus a new-token budget.
#[derive(Clone, Debug)]
pub struct GenRequest {
    pub prompt: Vec<u32>,
    pub n_new: usize,
}

/// What a stream produced by the time it retired.
#[derive(Clone, Debug, PartialEq)]
pub struct StreamResult {
    /// Generated ids, in order (length ≤ the request's `n_new`).
    pub tokens: Vec<u32>,
    /// `true` when the stream hit its cache capacity before exhausting
    /// its budget and was retired early instead of panicking the batch.
    pub truncated: bool,
}

/// Engine-assigned identity of an admitted stream, monotonically
/// increasing in admission order (so it doubles as an arrival stamp).
pub type StreamId = u64;

/// Where a slot is in its lifecycle (module docs).
enum Phase {
    /// Prompt ingestion: one chunk per step; `off` tokens already cached.
    Prefill { prompt: Vec<u32>, off: usize },
    /// One fused token per step.
    Decode,
}

/// An in-flight stream between admission and retirement.
struct Slot {
    id: StreamId,
    cache: KvCache,
    sampler: Sampler,
    /// Generated so far; the last entry is the token fed at the next step.
    out: Vec<u32>,
    /// Full token context (prompt ‖ generated), maintained alongside
    /// `out` — the n-gram drafter's lookup corpus
    /// ([`speculate::draft_ngram`]). A few bytes per token, negligible
    /// next to the KV cache.
    ctx: Vec<u32>,
    n_new: usize,
    phase: Phase,
    /// Obs-epoch µs of admission — TTFT is measured from here. The same
    /// reading stamps the `Admit` trace event, so trace-derived TTFT
    /// equals the histogram sample exactly.
    admit_us: u64,
    /// Obs-epoch µs of the latest sampled token (TPOT = delta between
    /// consecutive readings).
    last_token_us: u64,
    /// Finalized-block count at the last trace check (delta → one
    /// `BlockFinalize` event).
    prev_blocks: usize,
    /// Evicted-row count at the last trace check (delta → one `Evict`
    /// event).
    prev_evicted: usize,
}

/// Long-lived decode engine with in-flight admission (module docs).
///
/// The engine owns a fixed array of `max_inflight` slots and a free-slot
/// list. [`DecodeEngine::admit`] / [`DecodeEngine::step`] /
/// [`DecodeEngine::drain`] are the continuous-serving surface; the
/// one-shot [`DecodeEngine::run`] wrapper (admit everything, step until
/// done) remains for batch callers and is what PR 4 callers see.
pub struct DecodeEngine {
    gpt: Arc<Gpt>,
    kv: KvCacheConfig,
    sampling: Sampling,
    decode_batch: usize,
    /// Fixed slot array; `None` = free.
    slots: Vec<Option<Slot>>,
    /// Indices of free entries in `slots` (LIFO; order is irrelevant to
    /// results — per-stream parity is slot-position independent).
    free: Vec<usize>,
    next_stream: StreamId,
    /// Finished streams awaiting [`DecodeEngine::drain`], in retirement
    /// order.
    retired: VecDeque<(StreamId, StreamResult)>,
    /// Shared block pool: every admitted stream's cache allocates its
    /// finalized blocks here, and the prefix index lives here too (one
    /// pool per engine — and therefore one per generate variant).
    pool: Arc<BlockPool>,
    /// Admissions seated against a pooled prefix (engine lifetime).
    prefix_hits: u64,
    /// Prompt tokens whose prefill was skipped via prefix hits.
    prefix_tokens_reused: u64,
    /// Engine observability: TTFT/TPOT histograms (always recorded — a
    /// few relaxed atomics per token) plus the opt-in trace ring
    /// (attached via [`DecodeEngine::with_obs`]).
    obs: Arc<EngineObs>,
    /// Speculative-decode configuration (`None` = plain one-token
    /// stepping). Greedy-only; set via [`DecodeEngine::with_speculative`]
    /// (the `[generate] speculative.*` TOML knobs).
    spec: Option<SpecConfig>,
}

/// Default cap on streams fused into one GEMM (the `[generate]`
/// `decode_batch` TOML knob): matches the coordinator's default
/// `max_batch`, so a full coordinator batch fuses into a single step.
pub const DEFAULT_DECODE_BATCH: usize = 8;

/// Default slot count (the `[generate]` `max_inflight` TOML knob):
/// matches [`DEFAULT_DECODE_BATCH`], so by default one admission wave
/// fills exactly one fused step.
pub const DEFAULT_MAX_INFLIGHT: usize = 8;

impl DecodeEngine {
    /// Build an engine over `gpt` with a per-stream cache policy and a
    /// sampling spec.
    ///
    /// Without an eviction policy the cache capacity is clamped to the
    /// model's `max_seq` (tighter caller-supplied bounds are kept), so a
    /// stream that outgrows the model retires with a truncation flag
    /// instead of panicking mid-batch. With a sliding window the stream is
    /// *unbounded*: only the resident set must fit the positional table
    /// ([`KvCacheConfig::resident_bound`] ≤ model `max_seq`, asserted
    /// here), prompts longer than `max_seq` prefill in chunks, and streams
    /// decode indefinitely — truncation can then only arise from an
    /// explicit caller-supplied `kv.max_seq` logical cap.
    pub fn new(gpt: Arc<Gpt>, kv: KvCacheConfig, sampling: Sampling) -> Self {
        let mut kv = kv;
        match kv.eviction {
            EvictionPolicy::None => {
                let cap = kv.max_seq.map_or(gpt.cfg.max_seq, |m| m.min(gpt.cfg.max_seq));
                kv.max_seq = Some(cap);
            }
            EvictionPolicy::SlidingWindow { .. } => {
                let bound = kv.resident_bound().expect("sliding window bounds residency");
                assert!(
                    bound <= gpt.cfg.max_seq,
                    "kv window residency bound {bound} (block-rounded sinks + window + block) \
                     exceeds model max_seq {}",
                    gpt.cfg.max_seq
                );
            }
        }
        kv.validate();
        let max_inflight = DEFAULT_MAX_INFLIGHT;
        DecodeEngine {
            gpt,
            kv,
            sampling,
            decode_batch: DEFAULT_DECODE_BATCH,
            slots: (0..max_inflight).map(|_| None).collect(),
            free: (0..max_inflight).rev().collect(),
            next_stream: 0,
            retired: VecDeque::new(),
            pool: BlockPool::new(),
            prefix_hits: 0,
            prefix_tokens_reused: 0,
            obs: Arc::new(EngineObs::new()),
            spec: None,
        }
    }

    /// Cap on streams fused into one step GEMM (≥ 1; 1 = serial stepping).
    pub fn with_decode_batch(mut self, decode_batch: usize) -> Self {
        assert!(decode_batch >= 1, "decode_batch must be ≥ 1");
        self.decode_batch = decode_batch;
        self
    }

    /// Enable self-speculative decoding: each step drafts up to
    /// `spec.k` tokens per stream, verifies them in one ragged GEMM
    /// ([`crate::model::Gpt::decode_step_batch_ragged`]), keeps the
    /// longest target-agreed prefix, and rolls the rest back off the
    /// cache's fp32 tail ([`KvCache::truncate_to`]) — DESIGN.md §18.
    /// Greedy output is **bit-identical** to the non-speculative engine
    /// at any draft quality, thread count, and admission schedule
    /// (`tests/speculative.rs`); only throughput changes. Greedy-only:
    /// the accept rule is an argmax-agreement argument, so sampled
    /// (`TopK`) engines reject speculation here and at config parse
    /// ([`crate::config::GenerateSpec::check`]). Must be set on an idle
    /// engine.
    pub fn with_speculative(mut self, spec: SpecConfig) -> Self {
        assert!(spec.k >= 1, "speculative draft depth k must be ≥ 1");
        assert!(
            matches!(self.sampling, Sampling::Greedy),
            "speculative decoding requires greedy sampling (verification is an argmax argument)"
        );
        assert!(
            self.slots.iter().all(|s| s.is_none()) && self.retired.is_empty(),
            "speculative mode must be set on an idle engine"
        );
        self.spec = Some(spec);
        self
    }

    /// The engine's speculative-decode configuration (`None` = plain
    /// one-token stepping).
    pub fn speculative(&self) -> Option<SpecConfig> {
        self.spec
    }

    /// Slot-array size: the hard cap on concurrently in-flight streams
    /// (≥ 1). Must be set before any stream is admitted.
    pub fn with_max_inflight(mut self, max_inflight: usize) -> Self {
        assert!(max_inflight >= 1, "max_inflight must be ≥ 1");
        assert!(
            self.slots.iter().all(|s| s.is_none()) && self.retired.is_empty(),
            "max_inflight must be set on an idle engine"
        );
        self.slots = (0..max_inflight).map(|_| None).collect();
        self.free = (0..max_inflight).rev().collect();
        self
    }

    /// Swap in pre-built engine observability — e.g.
    /// [`EngineObs::with_trace`] to attach a trace ring (the TTFT/TPOT
    /// histograms are recorded either way). Must be set on an idle
    /// engine: slot timestamps are relative to the obs epoch.
    pub fn set_obs(&mut self, obs: Arc<EngineObs>) {
        assert!(
            self.slots.iter().all(|s| s.is_none()) && self.retired.is_empty(),
            "obs must be set on an idle engine"
        );
        self.obs = obs;
    }

    /// Builder form of [`DecodeEngine::set_obs`].
    pub fn with_obs(mut self, obs: Arc<EngineObs>) -> Self {
        self.set_obs(obs);
        self
    }

    /// This engine's observability handle (share it with
    /// [`crate::coordinator::VariantMetrics::link_engine_obs`] or drain
    /// its trace ring).
    pub fn obs(&self) -> &Arc<EngineObs> {
        &self.obs
    }

    /// Hard cap on concurrently in-flight streams (the slot-array size).
    pub fn max_inflight(&self) -> usize {
        self.slots.len()
    }

    /// Streams currently seated in a slot (prefilling or decoding).
    pub fn n_inflight(&self) -> usize {
        self.slots.len() - self.free.len()
    }

    /// Slots available to [`DecodeEngine::admit`] right now.
    pub fn free_slots(&self) -> usize {
        self.free.len()
    }

    /// `true` while any stream is in flight (a [`DecodeEngine::step`]
    /// would do model work).
    pub fn has_work(&self) -> bool {
        self.n_inflight() > 0
    }

    /// Finished streams waiting to be [`DecodeEngine::drain`]ed.
    pub fn n_retired(&self) -> usize {
        self.retired.len()
    }

    /// The engine's (normalized) per-stream cache policy.
    pub fn kv(&self) -> &KvCacheConfig {
        &self.kv
    }

    /// The engine's shared block pool (prefix index + physical blocks;
    /// [`BlockPool::resident_bits`] is the physical footprint with every
    /// shared block counted once).
    pub fn pool(&self) -> &Arc<BlockPool> {
        &self.pool
    }

    /// Admissions whose prompt prefix was found pooled, over the
    /// engine's lifetime (0 unless [`KvCacheConfig::prefix_cache`] is
    /// set — surfaced per variant by the coordinator's metrics).
    pub fn prefix_hits(&self) -> u64 {
        self.prefix_hits
    }

    /// Prompt tokens whose prefill was skipped via prefix hits, over the
    /// engine's lifetime.
    pub fn prefix_tokens_reused(&self) -> u64 {
        self.prefix_tokens_reused
    }

    /// Sum of the in-flight streams' *per-stream* cache footprints
    /// ([`KvCache::storage_bits`] — a shared block counts once per
    /// stream). Compare with [`BlockPool::resident_bits`] plus
    /// [`DecodeEngine::inflight_tail_bits`] (the physical total) to see
    /// the prefix-reuse saving.
    pub fn inflight_storage_bits(&self) -> usize {
        self.slots.iter().flatten().map(|s| s.cache.storage_bits()).sum()
    }

    /// Sum of the in-flight streams' private fp32 tail bits (never
    /// pooled); `pool().resident_bits() + inflight_tail_bits()` is the
    /// engine's whole physical KV footprint.
    pub fn inflight_tail_bits(&self) -> usize {
        self.slots.iter().flatten().map(|s| s.cache.tail_bits()).sum()
    }

    /// Check a request against the engine's vocab and cache policy.
    /// Returns the bare failure message (callers add stream context).
    fn validate(&self, r: &GenRequest) -> std::result::Result<(), String> {
        let vocab = self.gpt.cfg.vocab_size;
        if r.prompt.is_empty() {
            return Err("prompt must be non-empty".into());
        }
        if let Some(&t) = r.prompt.iter().find(|&&t| t as usize >= vocab) {
            return Err(format!("token {t} out of vocab {vocab}"));
        }
        // `Some` for bounded caches (always, without eviction); `None`
        // when a sliding window keeps the stream unbounded.
        if let Some(cap) = self.kv.max_seq {
            if r.prompt.len() > cap {
                return Err(format!("prompt {} exceeds cache capacity {cap}", r.prompt.len()));
            }
        }
        Ok(())
    }

    /// Per-step prefill chunk bound (PR 5 rule): windowed caches chunk at
    /// the window budget — a chunk's K/V are appended (and evicted)
    /// *before* its attention runs, so a chunk wider than the window would
    /// let eviction drop its own middle mid-append — queries would attend
    /// only the sinks instead of their recency window. With
    /// `chunk ≤ window` a query's whole same-chunk prefix survives (its
    /// newest key is within `window` of the chunk end), so every query
    /// sees `[sinks ‖ chunk prefix ‖ most recent pre-chunk remainder]` —
    /// the same approximation class as windowed decode itself. Bounded
    /// caches (validated prompt ≤ cap ≤ max_seq) take the whole prompt in
    /// one chunk, exactly the pre-eviction path.
    fn chunk_cap(&self) -> usize {
        match self.kv.eviction {
            EvictionPolicy::SlidingWindow { window, .. } => window,
            EvictionPolicy::None => usize::MAX,
        }
    }

    /// Seat a request in a free slot of the (possibly running) engine.
    ///
    /// Errors — without touching engine state — when the request is
    /// invalid (empty or out-of-vocab prompt, prompt longer than a
    /// *bounded* cache's capacity) or when no slot is free; a windowed
    /// (unbounded) cache accepts prompts of any length and prefills them
    /// in chunks across subsequent [`DecodeEngine::step`]s. Returns the
    /// stream's id, unique per engine and increasing in admission order.
    pub fn admit(&mut self, req: GenRequest) -> crate::error::Result<StreamId> {
        if let Err(msg) = self.validate(&req) {
            crate::bail!("{msg}");
        }
        let Some(i) = self.free.pop() else {
            crate::bail!(
                "no free slot: {} streams in flight (max_inflight {})",
                self.n_inflight(),
                self.max_inflight()
            );
        };
        let id = self.next_stream;
        self.next_stream += 1;
        let plen = req.prompt.len();
        let mut cache = KvCache::with_pool(self.gpt.cfg.n_layers, self.kv.clone(), self.pool.clone());
        let mut off = 0usize;
        if self.kv.prefix_cache {
            // Longest pooled block-aligned strict prefix of the prompt
            // (never the whole prompt: the final token must prefill so
            // its logits can sample the first generated token). On a hit
            // the cache forks copy-on-write from the pooled blocks and
            // prefill starts at the divergence point.
            if let Some(hit) = self.pool.lookup_prefix(&req.prompt, self.kv.block) {
                off = hit.span;
                self.prefix_hits += 1;
                self.prefix_tokens_reused += hit.span as u64;
                cache.seed_prefix(hit);
            }
        }
        // One `now` reading stamps both the Admit trace event and the
        // slot's TTFT base, so trace-derived TTFT (first DecodeStep −
        // Admit) equals the histogram-recorded value exactly.
        let now = self.obs.now_us();
        self.obs.record_event(TraceKind::Admit, id, now, plen as u64);
        if off > 0 {
            self.obs.record_event(TraceKind::PrefixHit, id, now, off as u64);
        }
        self.slots[i] = Some(Slot {
            id,
            admit_us: now,
            last_token_us: now,
            prev_blocks: cache.n_blocks(),
            prev_evicted: cache.evicted(),
            cache,
            sampler: Sampler::new(&self.sampling),
            out: Vec::with_capacity(req.n_new),
            ctx: req.prompt.clone(),
            n_new: req.n_new,
            phase: Phase::Prefill { prompt: req.prompt, off },
        });
        Ok(id)
    }

    /// Move slot `i`'s stream to the retired queue and free the slot.
    fn retire_slot(&mut self, i: usize, truncated: bool) {
        let s = self.slots[i].take().expect("retiring an occupied slot");
        self.free.push(i);
        self.obs.record_event(TraceKind::Retire, s.id, self.obs.now_us(), s.out.len() as u64);
        self.retired.push_back((s.id, StreamResult { tokens: s.out, truncated }));
    }

    /// Advance every in-flight stream by one unit of work:
    ///
    /// 1. retire decoding streams whose bounded cache cannot take the
    ///    pending token (the recoverable per-stream form of the max_seq
    ///    overflow), flagged `truncated`;
    /// 2. fused decode — all decoding slots advance one token, chunked at
    ///    `decode_batch` streams per GEMM;
    /// 3. retire streams that reached their budget;
    /// 4. one prefill chunk per prefilling slot; a slot whose prompt
    ///    completes samples its first token from the chunk's logits and
    ///    joins the fused decode from the *next* step (or retires at once
    ///    when the budget is already met).
    ///
    /// A no-op on an idle engine.
    pub fn step(&mut self, hook: &dyn LinearHook) {
        // (1) Capacity retirement, before any model work this step.
        for i in 0..self.slots.len() {
            let full = matches!(
                &self.slots[i],
                Some(s) if matches!(s.phase, Phase::Decode)
                    && matches!(s.cache.remaining(), Some(0))
            );
            if full {
                self.retire_slot(i, true);
            }
        }

        // (2) Fused decode over the active decoding slots, in slot order.
        // With speculation enabled, each chunk runs draft → ragged
        // verify → accept/rollback instead of the single-token GEMM; the
        // plain path below is exactly that loop at draft depth 0, kept
        // separate so the default hot path is untouched.
        if let Some(sc) = self.spec {
            self.step_decode_speculative(hook, sc);
        } else {
            let gpt = &self.gpt;
            let obs = &self.obs;
            let mut active: Vec<&mut Slot> = self
                .slots
                .iter_mut()
                .filter_map(|o| o.as_mut())
                .filter(|s| matches!(s.phase, Phase::Decode))
                .collect();
            for chunk in active.chunks_mut(self.decode_batch) {
                let tokens: Vec<u32> =
                    chunk.iter().map(|s| *s.out.last().expect("decoding slot has a token")).collect();
                let mut caches: Vec<&mut KvCache> =
                    chunk.iter_mut().map(|s| &mut s.cache).collect();
                let logits = {
                    let _site = site_guard(KernelSite::Decode);
                    gpt.decode_step_batch(hook, &tokens, &mut caches)
                };
                drop(caches);
                // One `now` per fused GEMM: every stream in the chunk got
                // its token from the same step, and the shared reading is
                // what keeps trace-derived TPOT equal to the histogram's.
                let now = obs.now_us();
                for (row, s) in chunk.iter_mut().enumerate() {
                    let t = s.sampler.next(logits.row(row));
                    s.out.push(t);
                    s.ctx.push(t);
                    obs.tpot_us.record(now.saturating_sub(s.last_token_us));
                    s.last_token_us = now;
                    obs.record_event(TraceKind::DecodeStep, s.id, now, s.out.len() as u64);
                    if obs.trace_enabled() {
                        let nb = s.cache.n_blocks();
                        if nb > s.prev_blocks {
                            obs.record_event(
                                TraceKind::BlockFinalize,
                                s.id,
                                now,
                                (nb - s.prev_blocks) as u64,
                            );
                        }
                        s.prev_blocks = nb;
                        let ev = s.cache.evicted();
                        if ev > s.prev_evicted {
                            obs.record_event(
                                TraceKind::Evict,
                                s.id,
                                now,
                                (ev - s.prev_evicted) as u64,
                            );
                        }
                        s.prev_evicted = ev;
                    }
                }
            }
        }

        // (3) Budget retirement.
        for i in 0..self.slots.len() {
            let done = matches!(
                &self.slots[i],
                Some(s) if matches!(s.phase, Phase::Decode) && s.out.len() >= s.n_new
            );
            if done {
                self.retire_slot(i, false);
            }
        }

        // (4) Prefill: one chunk per prefilling slot, interleaved with the
        // ongoing decode above. The chunk sequence is a pure function of
        // the stream's own cache state, so spreading it over steps cannot
        // change the stream's output (module docs).
        let chunk_cap = self.chunk_cap();
        for i in 0..self.slots.len() {
            let mut retire_now = false;
            {
                let gpt = &self.gpt;
                let obs = &self.obs;
                let Some(s) = self.slots[i].as_mut() else { continue };
                let mut finished = false;
                let mut register: Option<Vec<u32>> = None;
                if let Phase::Prefill { prompt, off } = &mut s.phase {
                    let take = (gpt.cfg.max_seq - s.cache.pos_next())
                        .min(chunk_cap)
                        .min(prompt.len() - *off);
                    let logits = {
                        let _site = site_guard(KernelSite::Prefill);
                        gpt.prefill(hook, &prompt[*off..*off + take], &mut s.cache)
                    };
                    *off += take;
                    let now = obs.now_us();
                    obs.record_event(TraceKind::PrefillChunk, s.id, now, *off as u64);
                    if obs.trace_enabled() {
                        let nb = s.cache.n_blocks();
                        if nb > s.prev_blocks {
                            obs.record_event(
                                TraceKind::BlockFinalize,
                                s.id,
                                now,
                                (nb - s.prev_blocks) as u64,
                            );
                        }
                        s.prev_blocks = nb;
                        let ev = s.cache.evicted();
                        if ev > s.prev_evicted {
                            obs.record_event(
                                TraceKind::Evict,
                                s.id,
                                now,
                                (ev - s.prev_evicted) as u64,
                            );
                        }
                        s.prev_evicted = ev;
                    }
                    if *off == prompt.len() {
                        finished = true;
                        if s.n_new > 0 {
                            let t = s.sampler.next(logits.row(logits.rows() - 1));
                            s.out.push(t);
                            s.ctx.push(t);
                            // First generated token: TTFT against the
                            // Admit timestamp, and a DecodeStep event
                            // sharing this chunk's `now` so the trace
                            // yields the identical TTFT.
                            obs.ttft_us.record(now.saturating_sub(s.admit_us));
                            s.last_token_us = now;
                            obs.record_event(TraceKind::DecodeStep, s.id, now, s.out.len() as u64);
                        }
                        if self.kv.prefix_cache {
                            let aligned = (prompt.len() / self.kv.block) * self.kv.block;
                            if aligned > 0 {
                                register = Some(prompt[..aligned].to_vec());
                            }
                        }
                    }
                } else {
                    continue;
                }
                if finished {
                    // The prompt is fully cached and nothing past it yet:
                    // register every block-aligned prefix, so later
                    // prompts sharing *any* aligned prefix can seat
                    // against the pooled blocks. `prefix_entry` declines
                    // (returns None) when eviction already dropped part
                    // of the run — a windowed stream only registers what
                    // it can still vouch for.
                    if let Some(tokens) = register {
                        let b = self.kv.block;
                        for nb in 1..=tokens.len() / b {
                            if let Some(entry) = s.cache.prefix_entry(&tokens[..nb * b]) {
                                self.pool.register_prefix(entry);
                            }
                        }
                    }
                    s.phase = Phase::Decode;
                    retire_now = s.out.len() >= s.n_new;
                }
            }
            if retire_now {
                self.retire_slot(i, false);
            }
        }
    }

    /// Phase 2 of [`DecodeEngine::step`] with speculation enabled:
    /// draft → ragged verify → accept/rollback, per `decode_batch`
    /// chunk (DESIGN.md §18).
    ///
    /// Per stream: the drafter proposes `d ≤ k` tokens, further capped
    /// by the stream's remaining budget and by
    /// [`KvCache::spec_headroom`] so the `d+1` verify appends cannot
    /// finalize a packed block, trip an eviction, or overrun a
    /// capacity/positional bound — which is what makes the rollback
    /// provably tail-only. The ragged GEMM scores `[pending ‖ draft]`
    /// in one pass; row `j`'s argmax `y_j` is exactly what `j+1` serial
    /// greedy steps would have produced, so the engine keeps
    /// `y_0 … y_a` (the accepted draft prefix plus the target's own
    /// next token), trims to the budget, and pops the rejected rows off
    /// the fp32 tail. Greedy output is therefore bit-identical to the
    /// non-speculative engine at any draft quality.
    fn step_decode_speculative(&mut self, hook: &dyn LinearHook, sc: SpecConfig) {
        let gpt = &self.gpt;
        let obs = &self.obs;
        let mut active: Vec<&mut Slot> = self
            .slots
            .iter_mut()
            .filter_map(|o| o.as_mut())
            .filter(|s| matches!(s.phase, Phase::Decode))
            .collect();
        for chunk in active.chunks_mut(self.decode_batch) {
            // Draft. An empty draft (no n-gram match, or zero headroom)
            // degenerates this stream's verify to the plain one-token
            // step.
            let mut pre_len: Vec<usize> = Vec::with_capacity(chunk.len());
            let mut token_lists: Vec<Vec<u32>> = Vec::with_capacity(chunk.len());
            for s in chunk.iter_mut() {
                let pending = *s.out.last().expect("decoding slot has a token");
                let budget = (s.n_new - s.out.len()).saturating_sub(1);
                let pos_room = (gpt.cfg.max_seq - s.cache.pos_next()).saturating_sub(1);
                let depth = sc.k.min(s.cache.spec_headroom()).min(budget).min(pos_room);
                let draft = match sc.draft {
                    DraftKind::Ngram => draft_ngram(&s.ctx, depth),
                    DraftKind::Packed => draft_packed(gpt, hook, pending, &s.cache, depth),
                };
                pre_len.push(s.cache.len());
                let mut toks = Vec::with_capacity(1 + draft.len());
                toks.push(pending);
                toks.extend_from_slice(&draft);
                token_lists.push(toks);
            }
            let now_d = obs.now_us();
            for (s, toks) in chunk.iter().zip(&token_lists) {
                obs.record_event(TraceKind::Draft, s.id, now_d, (toks.len() - 1) as u64);
            }
            // Verify: one ragged GEMM scores every stream's pending
            // token and drafts together.
            let slices: Vec<&[u32]> = token_lists.iter().map(|t| t.as_slice()).collect();
            let mut caches: Vec<&mut KvCache> = chunk.iter_mut().map(|s| &mut s.cache).collect();
            let logits = {
                let _site = site_guard(KernelSite::Decode);
                gpt.decode_step_batch_ragged(hook, &slices, &mut caches)
            };
            drop(caches);
            // Accept / rollback. One `now` per fused GEMM, as in the
            // plain path: every token emitted here came from this step.
            let now = obs.now_us();
            let mut row0 = 0usize;
            for (i, s) in chunk.iter_mut().enumerate() {
                let rows = token_lists[i].len();
                let draft = &token_lists[i][1..];
                // Target argmax per appended row; `ys[0]` is exactly the
                // plain step's output.
                let ys: Vec<u32> = (0..rows).map(|j| argmax_row(logits.row(row0 + j))).collect();
                row0 += rows;
                // `draft[j]` survives iff the target, fed the accepted
                // prefix before it, agrees.
                let mut a = 0usize;
                while a < draft.len() && ys[a] == draft[a] {
                    a += 1;
                }
                // Emit the accepted prefix plus the target's own next
                // token (the "free" correction row), trimmed so the
                // stream never overshoots its `n_new` budget.
                let emit = (a + 1).min(s.n_new - s.out.len());
                // Rollback: pop the rejected rows off the fp32 tail; the
                // cache ends at [history ‖ pending ‖ accepted], exactly
                // the plain path's state after `emit` steps.
                s.cache.truncate_to(pre_len[i] + emit);
                obs.accepted_len.record(a as u64);
                obs.record_event(TraceKind::Verify, s.id, now, a as u64);
                if rows > emit {
                    obs.record_event(TraceKind::Rollback, s.id, now, (rows - emit) as u64);
                }
                // One DecodeStep event per emitted token, all sharing
                // this GEMM's `now`, and matching TPOT samples (the real
                // delta, then zeros) — trace-derived latencies still
                // equal histogram-recorded ones (tests/obs.rs).
                for (e, &t) in ys[..emit].iter().enumerate() {
                    s.out.push(t);
                    s.ctx.push(t);
                    obs.record_event(TraceKind::DecodeStep, s.id, now, s.out.len() as u64);
                    let dt = if e == 0 { now.saturating_sub(s.last_token_us) } else { 0 };
                    obs.tpot_us.record(dt);
                }
                s.last_token_us = now;
                if obs.trace_enabled() {
                    let nb = s.cache.n_blocks();
                    if nb > s.prev_blocks {
                        obs.record_event(
                            TraceKind::BlockFinalize,
                            s.id,
                            now,
                            (nb - s.prev_blocks) as u64,
                        );
                    }
                    s.prev_blocks = nb;
                    let ev = s.cache.evicted();
                    if ev > s.prev_evicted {
                        obs.record_event(TraceKind::Evict, s.id, now, (ev - s.prev_evicted) as u64);
                    }
                    s.prev_evicted = ev;
                }
            }
        }
    }

    /// Take every finished stream (id, result), in retirement order. The
    /// engine keeps no record of drained streams.
    pub fn drain(&mut self) -> Vec<(StreamId, StreamResult)> {
        self.retired.drain(..).collect()
    }

    /// Greedy fp32-linear convenience entry (the paper-shaped serving
    /// setup quantizes only the KV cache).
    pub fn run_fp(&mut self, reqs: &[GenRequest]) -> crate::error::Result<Vec<StreamResult>> {
        self.run(&FpHook, reqs)
    }

    /// One-shot wrapper over the continuous surface: admit every request
    /// (in waves, as slots free up, when `reqs` outnumber `max_inflight`
    /// or the engine already holds streams), step until all of them
    /// retire, and return one [`StreamResult`] per request, in request
    /// order. Errors (empty or out-of-vocab prompt, prompt longer than a
    /// *bounded* cache's capacity) reject the whole run before any
    /// decoding; a windowed (unbounded) cache accepts prompts of any
    /// length. Streams admitted by other callers keep advancing and their
    /// results stay queued for that caller's [`DecodeEngine::drain`].
    pub fn run(
        &mut self,
        hook: &dyn LinearHook,
        reqs: &[GenRequest],
    ) -> crate::error::Result<Vec<StreamResult>> {
        for (i, r) in reqs.iter().enumerate() {
            if let Err(msg) = self.validate(r) {
                crate::bail!("stream {i}: {msg}");
            }
        }
        let mut results: Vec<Option<StreamResult>> = reqs.iter().map(|_| None).collect();
        let mut own: std::collections::HashMap<StreamId, usize> = std::collections::HashMap::new();
        let mut next = 0usize;
        while next < reqs.len() || !own.is_empty() {
            while next < reqs.len() && self.free_slots() > 0 {
                let id = self
                    .admit(reqs[next].clone())
                    .expect("validated request admits into a free slot");
                own.insert(id, next);
                next += 1;
            }
            self.step(hook);
            // Claim this run's retirees; foreign streams (admitted through
            // the continuous surface) go back to the queue untouched.
            for (id, res) in self.drain() {
                match own.remove(&id) {
                    Some(idx) => results[idx] = Some(res),
                    None => self.retired.push_back((id, res)),
                }
            }
        }
        Ok(results.into_iter().map(|o| o.expect("every admitted stream retires")).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::GptConfig;

    fn prompt(n: usize, salt: usize) -> Vec<u32> {
        (0..n).map(|i| ((i * 7 + salt * 11 + 3) % 70) as u32).collect()
    }

    fn tiny(seed: u64) -> Arc<Gpt> {
        Arc::new(Gpt::new(GptConfig::tiny(), seed))
    }

    #[test]
    fn greedy_batch_matches_serial_generate_greedy() {
        let gpt = tiny(41);
        let reqs = vec![
            GenRequest { prompt: prompt(5, 0), n_new: 12 },
            GenRequest { prompt: prompt(11, 1), n_new: 3 },
            GenRequest { prompt: prompt(2, 2), n_new: 8 },
        ];
        let mut engine = DecodeEngine::new(gpt.clone(), KvCacheConfig::fp32(), Sampling::Greedy)
            .with_decode_batch(2);
        let got = engine.run_fp(&reqs).unwrap();
        for (i, r) in reqs.iter().enumerate() {
            let mut cache = KvCache::fp32(gpt.cfg.n_layers);
            let want = gpt.generate_greedy(&FpHook, &r.prompt, r.n_new, &mut cache);
            assert_eq!(got[i].tokens, want, "stream {i}");
            assert!(!got[i].truncated);
        }
    }

    #[test]
    fn zero_budget_and_bad_requests() {
        let gpt = tiny(42);
        let mut engine = DecodeEngine::new(gpt, KvCacheConfig::fp32(), Sampling::Greedy);
        let got = engine
            .run_fp(&[GenRequest { prompt: prompt(4, 0), n_new: 0 }])
            .unwrap();
        assert!(got[0].tokens.is_empty() && !got[0].truncated);
        let err = engine.run_fp(&[GenRequest { prompt: vec![], n_new: 4 }]).unwrap_err();
        assert!(err.to_string().contains("non-empty"), "{err}");
        let err = engine.run_fp(&[GenRequest { prompt: vec![9999], n_new: 4 }]).unwrap_err();
        assert!(err.to_string().contains("out of vocab"), "{err}");
        let long = prompt(300, 0).iter().map(|&t| t % 70).collect::<Vec<u32>>();
        let err = engine.run_fp(&[GenRequest { prompt: long, n_new: 1 }]).unwrap_err();
        assert!(err.to_string().contains("exceeds cache capacity"), "{err}");
        // A rejected run leaves the engine clean: nothing in flight,
        // nothing queued.
        assert_eq!(engine.n_inflight(), 0);
        assert_eq!(engine.n_retired(), 0);
    }

    #[test]
    fn truncation_retires_one_stream_without_stalling_the_rest() {
        let gpt = tiny(43);
        // Tight engine-level bound: prefill 8 + 4 appends fill cap 12; the
        // 5th generated token is sampled but the 6th needs a 13th slot.
        let kv = KvCacheConfig::fp32().with_max_seq(12);
        let reqs = vec![
            GenRequest { prompt: prompt(8, 0), n_new: 20 },
            GenRequest { prompt: prompt(2, 1), n_new: 6 },
        ];
        let mut engine = DecodeEngine::new(gpt.clone(), kv, Sampling::Greedy);
        let got = engine.run_fp(&reqs).unwrap();
        assert!(got[0].truncated);
        assert_eq!(got[0].tokens.len(), 5, "prefill 8 + 4 appends under cap 12 → 5 tokens");
        assert!(!got[1].truncated);
        assert_eq!(got[1].tokens.len(), 6);
        // Each stream still matches its unbounded serial run (prefix-wise
        // for the truncated one).
        let mut c = KvCache::fp32(gpt.cfg.n_layers);
        let serial0 = gpt.generate_greedy(&FpHook, &reqs[0].prompt, 20, &mut c);
        assert_eq!(got[0].tokens[..], serial0[..5]);
        let mut c = KvCache::fp32(gpt.cfg.n_layers);
        let serial1 = gpt.generate_greedy(&FpHook, &reqs[1].prompt, 6, &mut c);
        assert_eq!(got[1].tokens, serial1);
    }

    #[test]
    fn windowed_stream_decodes_past_max_seq_untruncated() {
        // The headline of the eviction subsystem: with a window policy a
        // stream's budget can exceed the model's positional table many
        // times over and it still returns exactly n_new tokens, while an
        // unwindowed batch-mate behaves as before.
        let gpt = tiny(45);
        let kv = KvCacheConfig::two_level(16, 8, 4, 8).with_window(16, 48);
        let n_long = 4 * gpt.cfg.max_seq; // 1024 ≫ max_seq = 256
        let reqs = vec![
            GenRequest { prompt: prompt(8, 0), n_new: n_long },
            GenRequest { prompt: prompt(3, 1), n_new: 5 },
        ];
        let mut engine = DecodeEngine::new(gpt.clone(), kv, Sampling::Greedy);
        let got = engine.run_fp(&reqs).unwrap();
        assert_eq!(got[0].tokens.len(), n_long);
        assert!(!got[0].truncated, "windowed streams never truncate");
        for &t in &got[0].tokens {
            assert!((t as usize) < gpt.cfg.vocab_size);
        }
        assert_eq!(got[1].tokens.len(), 5);
        assert!(!got[1].truncated);
    }

    #[test]
    fn windowed_prompt_longer_than_max_seq_prefills_chunked() {
        // A prompt past the positional table is admitted by chunked
        // prefill under a window policy — and rejected, as before, by a
        // bounded engine.
        let gpt = tiny(46);
        let long: Vec<u32> = (0..300).map(|i| ((i * 3 + 1) % 70) as u32).collect();
        let (window, n_new) = (48usize, 8usize);
        let kv = KvCacheConfig::two_level(16, 8, 4, 8).with_window(16, window);
        let mut engine = DecodeEngine::new(gpt.clone(), kv.clone(), Sampling::Greedy);
        let reqs = vec![GenRequest { prompt: long.clone(), n_new }];
        let got = engine.run_fp(&reqs).unwrap();
        assert_eq!(got[0].tokens.len(), n_new);
        assert!(!got[0].truncated);
        // Deterministic: the same long request reproduces exactly.
        assert_eq!(engine.run_fp(&reqs).unwrap(), got);
        // The chunk width is pinned to the *window* budget (a chunk's K/V
        // append — and eviction — precedes its attention, so wider chunks
        // would evict their own middle before it is ever attended): a
        // manual window-sized chunked prefill + greedy loop reproduces
        // the engine bit-for-bit.
        let argmax = |row: &[f32]| {
            row.iter().enumerate().fold(0usize, |b, (i, &v)| if v > row[b] { i } else { b }) as u32
        };
        let mut cache = KvCache::new(gpt.cfg.n_layers, kv);
        let mut last = None;
        let mut off = 0usize;
        while off < long.len() {
            let take = window.min(long.len() - off);
            last = Some(gpt.prefill(&FpHook, &long[off..off + take], &mut cache));
            off += take;
        }
        let logits = last.unwrap();
        let mut want = Vec::with_capacity(n_new);
        let mut next = argmax(logits.row(logits.rows() - 1));
        want.push(next);
        while want.len() < n_new {
            let l = gpt.decode_step(&FpHook, next, &mut cache);
            next = argmax(l.row(0));
            want.push(next);
        }
        assert_eq!(got[0].tokens, want, "engine must chunk admission at the window budget");
        let mut bounded = DecodeEngine::new(gpt, KvCacheConfig::fp32(), Sampling::Greedy);
        let err = bounded.run_fp(&reqs).unwrap_err();
        assert!(err.to_string().contains("exceeds cache capacity"), "{err}");
    }

    #[test]
    #[should_panic(expected = "exceeds model max_seq")]
    fn rejects_window_residency_larger_than_positional_table() {
        let gpt = tiny(47);
        // sinks 64 (block-rounded 64) + window 256 + block 32 > 256.
        let kv = KvCacheConfig::default().with_window(64, 256);
        let _ = DecodeEngine::new(gpt, kv, Sampling::Greedy);
    }

    #[test]
    fn topk_sampling_is_deterministic_and_batch_invariant() {
        let gpt = tiny(44);
        let sampling = Sampling::TopK { k: 8, temperature: 0.9, seed: 0x5EED };
        let reqs = vec![
            GenRequest { prompt: prompt(6, 0), n_new: 10 },
            GenRequest { prompt: prompt(3, 1), n_new: 10 },
            GenRequest { prompt: prompt(9, 2), n_new: 4 },
        ];
        let mut engine = DecodeEngine::new(gpt.clone(), KvCacheConfig::fp32(), sampling.clone());
        let batched = engine.run_fp(&reqs).unwrap();
        // Same spec, streams run one at a time: per-stream RNGs make the
        // draws independent of batch composition.
        for (i, r) in reqs.iter().enumerate() {
            let solo = engine.run_fp(std::slice::from_ref(r)).unwrap();
            assert_eq!(solo[0], batched[i], "stream {i} must not depend on batch-mates");
        }
        // And the run is reproducible wholesale.
        assert_eq!(engine.run_fp(&reqs).unwrap(), batched);
        for r in &batched {
            for &t in &r.tokens {
                assert!((t as usize) < gpt.cfg.vocab_size);
            }
        }
        // Different seed, different continuation (overwhelmingly likely
        // over 10 draws from a near-uniform untrained model).
        let mut other = DecodeEngine::new(
            gpt,
            KvCacheConfig::fp32(),
            Sampling::TopK { k: 8, temperature: 0.9, seed: 0xBEEF },
        );
        let alt = other.run_fp(&reqs).unwrap();
        assert_ne!(alt[0].tokens, batched[0].tokens, "seed must steer the draw");
    }

    #[test]
    fn greedy_sampler_matches_argmax_and_topk1_collapses() {
        // temperature>0 with k=1 must reproduce greedy's argmax choice.
        let row = [0.1f32, 2.5, -1.0, 2.5, 0.3];
        let mut g = Sampler::new(&Sampling::Greedy);
        let mut k1 = Sampler::new(&Sampling::TopK { k: 1, temperature: 1.0, seed: 7 });
        assert_eq!(g.next(&row), 1, "first maximum wins ties");
        assert_eq!(k1.next(&row), 1, "top-1 sampling is argmax with the same tie-break");
    }

    #[test]
    fn topk_orders_nan_logits_deterministically_last() {
        // Regression: the shortlist comparator used
        // `partial_cmp(..).unwrap_or(Equal)`, which is non-transitive
        // when NaN is present (NaN ≈ 2.0 and NaN ≈ 3.0 while 2.0 < 3.0)
        // — `select_nth_unstable_by` could then seat a NaN anywhere in
        // the top-k shortlist. NaN now orders strictly last: the draw
        // always comes from the finite candidates.
        let row = [f32::NAN, 2.0, f32::NAN, 3.0, 1.0, f32::NAN];
        for seed in 0..32u64 {
            let mut s = Sampler::new(&Sampling::TopK { k: 3, temperature: 0.7, seed });
            let t = s.next(&row);
            assert!(
                t == 1 || t == 3 || t == 4,
                "seed {seed} sampled index {t}, which is a NaN logit"
            );
        }
        // k = 1 collapses onto the finite maximum even with NaN around.
        let mut k1 = Sampler::new(&Sampling::TopK { k: 1, temperature: 1.0, seed: 9 });
        assert_eq!(k1.next(&row), 3);
        // Degenerate all-NaN row: still deterministic (index-ascending
        // shortlist, float-tail fallback) instead of panicking.
        let nan_row = [f32::NAN; 4];
        let mut s = Sampler::new(&Sampling::TopK { k: 2, temperature: 1.0, seed: 3 });
        assert_eq!(s.next(&nan_row), 1, "all-NaN rows fall back to the last candidate");
    }

    // ---- speculative decode ------------------------------------------

    #[test]
    fn speculative_greedy_is_bit_identical_to_plain_greedy() {
        // The tentpole invariant in miniature: both drafters, fp32 and
        // packed caches, same tokens as the non-speculative engine.
        let gpt = tiny(52);
        let reqs = vec![
            GenRequest { prompt: prompt(5, 0), n_new: 12 },
            GenRequest { prompt: prompt(11, 1), n_new: 3 },
            GenRequest { prompt: prompt(2, 2), n_new: 8 },
        ];
        for kv in [KvCacheConfig::fp32(), KvCacheConfig::two_level(4, 8, 4, 8)] {
            let mut plain = DecodeEngine::new(gpt.clone(), kv.clone(), Sampling::Greedy);
            let want = plain.run_fp(&reqs).unwrap();
            for draft in [DraftKind::Ngram, DraftKind::Packed] {
                let mut eng = DecodeEngine::new(gpt.clone(), kv.clone(), Sampling::Greedy)
                    .with_speculative(SpecConfig { draft, k: 4 });
                let got = eng.run_fp(&reqs).unwrap();
                assert_eq!(got, want, "{draft:?} over {:?} cache", kv.packed);
                let verifies = eng.obs().accepted_len.count();
                assert!(verifies > 0, "speculative engines record accepted_len per verify");
            }
        }
    }

    #[test]
    fn speculative_packed_drafter_accepts_when_the_fork_is_exact() {
        // An 8-token prompt fills block 8 exactly, so at the first
        // decode step the fp32 tail is empty and the drafter's fork is
        // *bit-identical* to the verifier's cache (the QDQ degradation
        // only touches tail rows). The first draft token is then the
        // verifier's own argmax, so at least one acceptance is
        // guaranteed — the accepted-length histogram cannot stay at
        // sum 0.
        let gpt = tiny(54);
        let reqs = vec![GenRequest { prompt: prompt(8, 0), n_new: 24 }];
        let mut eng = DecodeEngine::new(gpt, KvCacheConfig::two_level(4, 8, 4, 8), Sampling::Greedy)
            .with_speculative(SpecConfig { draft: DraftKind::Packed, k: 4 });
        let _ = eng.run_fp(&reqs).unwrap();
        let h = &eng.obs().accepted_len;
        assert!(h.count() > 0);
        assert!(h.sum() > 0, "an exact fork's first draft token must be accepted");
    }

    #[test]
    #[should_panic(expected = "greedy sampling")]
    fn speculative_rejects_sampled_engines() {
        let gpt = tiny(53);
        let sampling = Sampling::TopK { k: 4, temperature: 1.0, seed: 1 };
        let _ = DecodeEngine::new(gpt, KvCacheConfig::fp32(), sampling)
            .with_speculative(SpecConfig { draft: DraftKind::Ngram, k: 2 });
    }

    #[test]
    #[should_panic(expected = "idle engine")]
    fn speculative_must_be_set_before_admission() {
        let gpt = tiny(53);
        let mut eng = DecodeEngine::new(gpt, KvCacheConfig::fp32(), Sampling::Greedy);
        eng.admit(GenRequest { prompt: prompt(3, 0), n_new: 2 }).unwrap();
        let _ = eng.with_speculative(SpecConfig { draft: DraftKind::Ngram, k: 2 });
    }

    // ---- continuous surface: admit / step / drain --------------------

    #[test]
    fn inflight_admission_is_bit_identical_to_serial_decode() {
        // The tentpole invariant at its smallest: stream B joins while A
        // is mid-decode, and both match their serial runs exactly.
        let gpt = tiny(48);
        let mut engine = DecodeEngine::new(gpt.clone(), KvCacheConfig::fp32(), Sampling::Greedy);
        let a = GenRequest { prompt: prompt(6, 0), n_new: 10 };
        let b = GenRequest { prompt: prompt(9, 1), n_new: 4 };
        let id_a = engine.admit(a.clone()).unwrap();
        for _ in 0..4 {
            engine.step(&FpHook); // A prefills, then decodes alone
        }
        assert!(engine.has_work());
        let id_b = engine.admit(b.clone()).unwrap();
        assert!(id_b > id_a, "stream ids increase in admission order");
        let mut got: Vec<(StreamId, StreamResult)> = Vec::new();
        while engine.has_work() {
            engine.step(&FpHook);
            got.extend(engine.drain());
        }
        assert_eq!(got.len(), 2);
        assert_eq!(engine.free_slots(), engine.max_inflight());
        for (req, id) in [(&a, id_a), (&b, id_b)] {
            let res = &got.iter().find(|(i, _)| *i == id).unwrap().1;
            let mut c = KvCache::fp32(gpt.cfg.n_layers);
            let want = gpt.generate_greedy(&FpHook, &req.prompt, req.n_new, &mut c);
            assert_eq!(res.tokens, want, "admission time must not change stream {id}");
            assert!(!res.truncated);
        }
    }

    #[test]
    fn admit_rejects_when_no_slot_is_free_and_recovers_after_retirement() {
        let gpt = tiny(49);
        let mut engine = DecodeEngine::new(gpt, KvCacheConfig::fp32(), Sampling::Greedy)
            .with_max_inflight(1);
        engine.admit(GenRequest { prompt: prompt(3, 0), n_new: 2 }).unwrap();
        assert_eq!(engine.free_slots(), 0);
        let err = engine.admit(GenRequest { prompt: prompt(3, 1), n_new: 2 }).unwrap_err();
        assert!(err.to_string().contains("no free slot"), "{err}");
        // Invalid requests are rejected before slot accounting is touched.
        let err = engine.admit(GenRequest { prompt: vec![], n_new: 1 }).unwrap_err();
        assert!(err.to_string().contains("non-empty"), "{err}");
        while engine.has_work() {
            engine.step(&FpHook);
        }
        assert_eq!(engine.free_slots(), 1, "retirement returns the slot to the free list");
        engine.admit(GenRequest { prompt: prompt(3, 1), n_new: 2 }).unwrap();
        while engine.has_work() {
            engine.step(&FpHook);
        }
        assert_eq!(engine.drain().len(), 2, "each stream retires exactly once");
        assert_eq!(engine.drain().len(), 0, "drain empties the queue");
    }

    #[test]
    fn run_on_a_busy_engine_leaves_foreign_streams_queued() {
        // `run` claims only its own streams; a stream admitted through the
        // continuous surface retires into the queue for its own caller.
        let gpt = tiny(50);
        let mut engine = DecodeEngine::new(gpt.clone(), KvCacheConfig::fp32(), Sampling::Greedy);
        let fg = GenRequest { prompt: prompt(4, 3), n_new: 3 };
        let id_fg = engine.admit(fg.clone()).unwrap();
        let reqs = vec![
            GenRequest { prompt: prompt(5, 0), n_new: 12 },
            GenRequest { prompt: prompt(11, 1), n_new: 3 },
        ];
        let got = engine.run_fp(&reqs).unwrap();
        for (i, r) in reqs.iter().enumerate() {
            let mut c = KvCache::fp32(gpt.cfg.n_layers);
            let want = gpt.generate_greedy(&FpHook, &r.prompt, r.n_new, &mut c);
            assert_eq!(got[i].tokens, want, "stream {i}");
        }
        let foreign = engine.drain();
        assert_eq!(foreign.len(), 1, "foreign stream stays queued for its own caller");
        assert_eq!(foreign[0].0, id_fg);
        let mut c = KvCache::fp32(gpt.cfg.n_layers);
        let want = gpt.generate_greedy(&FpHook, &fg.prompt, fg.n_new, &mut c);
        assert_eq!(foreign[0].1.tokens, want, "sharing steps with a run() batch is invisible");
    }

    #[test]
    fn run_admits_in_waves_when_requests_outnumber_slots() {
        let gpt = tiny(51);
        let reqs: Vec<GenRequest> = (0..5)
            .map(|i| GenRequest { prompt: prompt(3 + i, i), n_new: 2 + i })
            .collect();
        let mut waves = DecodeEngine::new(gpt.clone(), KvCacheConfig::fp32(), Sampling::Greedy)
            .with_max_inflight(2);
        let got = waves.run_fp(&reqs).unwrap();
        for (i, r) in reqs.iter().enumerate() {
            let mut c = KvCache::fp32(gpt.cfg.n_layers);
            let want = gpt.generate_greedy(&FpHook, &r.prompt, r.n_new, &mut c);
            assert_eq!(got[i].tokens, want, "wave admission must not change stream {i}");
        }
    }
}
