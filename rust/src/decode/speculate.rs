//! Self-speculative drafters for the batched decode engine.
//!
//! Speculative decoding splits a greedy decode step into **draft** —
//! guess the next `d` tokens cheaply — and **verify** — run the target
//! model once over `[pending ‖ draft]` as a ragged multi-row step
//! ([`crate::model::Gpt::decode_step_batch_ragged`]) and keep the
//! longest prefix the target agrees with. A good draft turns `d+1`
//! weight-bound GEMV-shaped steps into one GEMM over `d+1` rows; a bad
//! draft costs only the rejected rows, which
//! [`crate::kvcache::KvCache::truncate_to`] pops back off the fp32
//! tail. Greedy output is bit-identical either way (DESIGN.md §18) —
//! the drafter only steers *throughput*, never *content*.
//!
//! Both drafters here are **self**-speculative: no second model, no new
//! weights.
//!
//! * [`DraftKind::Ngram`] — prompt lookahead: find the longest recent
//!   n-gram match of the stream's current suffix in its own context and
//!   propose the tokens that followed it. Free (no model work) and
//!   surprisingly effective on repetitive or structured continuations;
//!   proposes nothing when the context has no match, which degenerates
//!   to the ordinary one-token step.
//! * [`DraftKind::Packed`] — low-precision forward: fork the stream's
//!   cache ([`crate::kvcache::KvCache::fork_draft`] — pooled blocks
//!   shared by refcount, private tail re-quantized to the packed
//!   low-bit representation) and greedily decode `d` tokens on the
//!   throwaway fork. The draft reads the *degraded* cache the finalized
//!   blocks already live in, so it is exactly the "cheap approximate
//!   model" the paper's low-bit setting provides for free; the fork is
//!   dropped after drafting, so the real stream's state is untouched.

use crate::kvcache::KvCache;
use crate::model::gpt::argmax_row;
use crate::model::{Gpt, LinearHook};

/// Which self-drafter proposes tokens for the verify step.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum DraftKind {
    /// Greedy low-bit forward on a throwaway fork of the stream's own
    /// KV cache (`draft = "packed"` in TOML).
    Packed,
    /// Longest-suffix n-gram lookahead over the stream's prompt +
    /// generated context (`draft = "ngram"` in TOML).
    Ngram,
}

/// Engine-level speculative-decode configuration (the `[generate]`
/// `speculative.draft` / `speculative.k` TOML knobs).
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub struct SpecConfig {
    pub draft: DraftKind,
    /// Maximum draft depth per verify step (≥ 1). The engine further
    /// caps each step by the stream's budget and by
    /// [`KvCache::spec_headroom`], so rollback always stays inside the
    /// private fp32 tail.
    pub k: usize,
}

/// Prompt-lookahead drafter: match the longest suffix of `ctx` (n-grams
/// of length 3 down to 1) against earlier context, most recent match
/// first, and propose up to `max_k` tokens that followed the match.
/// Returns an empty draft when nothing matches — the caller then runs a
/// plain one-token step.
pub(crate) fn draft_ngram(ctx: &[u32], max_k: usize) -> Vec<u32> {
    if max_k == 0 || ctx.len() < 2 {
        return Vec::new();
    }
    let max_n = 3usize.min(ctx.len() - 1);
    for n in (1..=max_n).rev() {
        let suffix = &ctx[ctx.len() - n..];
        // Most recent earlier occurrence wins: recency is the best
        // predictor of continuation in autoregressive text.
        for start in (0..ctx.len() - n).rev() {
            if &ctx[start..start + n] == suffix {
                let from = start + n;
                let to = (from + max_k).min(ctx.len());
                if to > from {
                    return ctx[from..to].to_vec();
                }
            }
        }
    }
    Vec::new()
}

/// Low-bit forward drafter: fork the cache (shared finalized blocks,
/// re-quantized tail) and greedily decode up to `max_k` tokens on the
/// fork. The fork is dropped on return, so the parent stream's cache —
/// and the engine's accounting — never see the draft.
pub(crate) fn draft_packed(
    gpt: &Gpt,
    hook: &dyn LinearHook,
    pending: u32,
    cache: &KvCache,
    max_k: usize,
) -> Vec<u32> {
    let mut fork = cache.fork_draft();
    let mut out = Vec::with_capacity(max_k);
    let mut tok = pending;
    for _ in 0..max_k {
        if matches!(fork.remaining(), Some(0)) || fork.pos_next() >= gpt.cfg.max_seq {
            break;
        }
        let logits = gpt.decode_step(hook, tok, &mut fork);
        tok = argmax_row(logits.row(0));
        out.push(tok);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::KvCacheConfig;
    use crate::model::{FpHook, GptConfig};

    #[test]
    fn ngram_proposes_the_continuation_of_the_latest_match() {
        // Suffix [7] last occurred at index 1; the tokens after it are
        // proposed, capped at max_k.
        let ctx = [3, 7, 9, 4, 7];
        assert_eq!(draft_ngram(&ctx, 4), vec![9, 4, 7]);
        assert_eq!(draft_ngram(&ctx, 2), vec![9, 4]);
        // A longer suffix match is preferred: suffix [9, 4, 7] of the
        // extended context matches at index 2, proposing what followed.
        let ctx = [3, 7, 9, 4, 7, 1, 9, 4, 7];
        assert_eq!(draft_ngram(&ctx, 3), vec![1, 9, 4]);
    }

    #[test]
    fn ngram_recency_breaks_ties() {
        // Suffix [5] occurs at 0 and 2; the later match (followed by 8)
        // wins over the earlier one (followed by 6).
        let ctx = [5, 6, 5, 8, 5];
        assert_eq!(draft_ngram(&ctx, 1), vec![8]);
    }

    #[test]
    fn ngram_empty_cases() {
        assert!(draft_ngram(&[], 4).is_empty());
        assert!(draft_ngram(&[9], 4).is_empty());
        assert!(draft_ngram(&[1, 2, 3], 0).is_empty());
        // No repeated token anywhere → no match → empty draft.
        assert!(draft_ngram(&[1, 2, 3, 4], 4).is_empty());
    }

    #[test]
    fn packed_draft_leaves_the_parent_cache_untouched_and_respects_caps() {
        let gpt = Gpt::new(GptConfig::tiny(), 11);
        let mut cache =
            KvCache::new(gpt.cfg.n_layers, KvCacheConfig::two_level(0, 8, 4, 8));
        let prompt: Vec<u32> = (0..10).map(|i| (i * 5 + 2) % 70).collect();
        let logits = gpt.prefill(&FpHook, &prompt, &mut cache);
        let pending = argmax_row(logits.row(logits.rows() - 1));
        let before = (cache.len(), cache.n_blocks(), cache.storage_bits());
        let draft = draft_packed(&gpt, &FpHook, pending, &cache, 4);
        assert_eq!(draft.len(), 4, "an unconstrained fork drafts the full depth");
        for &t in &draft {
            assert!((t as usize) < gpt.cfg.vocab_size);
        }
        assert_eq!(
            (cache.len(), cache.n_blocks(), cache.storage_bits()),
            before,
            "drafting must not mutate the parent cache"
        );
        // Deterministic: the same fork state drafts the same tokens.
        assert_eq!(draft_packed(&gpt, &FpHook, pending, &cache, 4), draft);
        // A capacity-bounded cache stops the fork at the wall instead of
        // panicking: cap 12, 10 cached + pending leaves 2 appends, so at
        // most 2 draft tokens come back.
        let mut bounded = KvCache::new(
            gpt.cfg.n_layers,
            KvCacheConfig::two_level(0, 8, 4, 8).with_max_seq(12),
        );
        let logits = gpt.prefill(&FpHook, &prompt, &mut bounded);
        let pending = argmax_row(logits.row(logits.rows() - 1));
        let draft = draft_packed(&gpt, &FpHook, pending, &bounded, 4);
        assert_eq!(draft.len(), 2, "the fork stops at the capacity wall");
    }
}
