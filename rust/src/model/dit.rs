//! DiT-style latent diffusion transformer (PixArt-Σ / SANA stand-in).
//!
//! Operates on an `h×w` grid of latent tokens flattened to a sequence,
//! with per-block: AdaLN modulation from a conditioning embedding, 2-D
//! self-attention (`attn1`), cross-attention to prompt tokens (`attn2`),
//! and a gated FFN — the block diagram of the paper's Figure 5, including
//! the site names used by the Table-4 per-activation ablation. Forward
//! only (the SQNR experiments compare quantized vs FP outputs of the same
//! random-but-fixed weights; see DESIGN.md §3).

use super::attention::MultiHeadAttention;
use super::linear::{Linear, LinearHook};
use super::norm::RmsNorm;
use crate::data::prompts::PromptSet;
use crate::tensor::{Tensor, XorShiftRng};

#[derive(Clone, Debug)]
pub struct DitConfig {
    /// Latent token grid.
    pub grid_h: usize,
    pub grid_w: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub n_layers: usize,
    pub d_ff: usize,
    /// Number of prompt (cross-attention context) tokens.
    pub ctx_tokens: usize,
    /// Denoising steps for the toy sampler.
    pub steps: usize,
}

impl DitConfig {
    /// PixArt-Σ analogue: larger grid, deeper.
    pub fn pixart() -> Self {
        DitConfig { grid_h: 16, grid_w: 16, d_model: 128, n_heads: 4, n_layers: 6, d_ff: 256, ctx_tokens: 8, steps: 8 }
    }

    /// SANA analogue: wider, shallower (mirrors its efficiency focus).
    pub fn sana() -> Self {
        DitConfig { grid_h: 16, grid_w: 16, d_model: 256, n_heads: 8, n_layers: 4, d_ff: 512, ctx_tokens: 8, steps: 8 }
    }

    pub fn seq_len(&self) -> usize {
        self.grid_h * self.grid_w
    }
}

struct DitBlock {
    norm1: RmsNorm,
    attn1: MultiHeadAttention,
    norm_ca: RmsNorm,
    attn2: MultiHeadAttention,
    norm2: RmsNorm,
    up: Linear,
    down: Linear,
    /// AdaLN modulation: conditioning vector → per-block (scale, shift).
    ada: Linear,
}

impl DitBlock {
    fn new(cfg: &DitConfig, rng: &mut XorShiftRng) -> Self {
        DitBlock {
            norm1: RmsNorm::new(cfg.d_model),
            attn1: MultiHeadAttention::new(cfg.d_model, cfg.n_heads, false, rng),
            norm_ca: RmsNorm::new(cfg.d_model),
            attn2: MultiHeadAttention::new(cfg.d_model, cfg.n_heads, false, rng),
            norm2: RmsNorm::new(cfg.d_model),
            up: Linear::new(cfg.d_model, cfg.d_ff, false, rng),
            down: Linear::new(cfg.d_ff, cfg.d_model, false, rng),
            ada: Linear::new(cfg.d_model, 2 * cfg.d_model, true, rng),
        }
    }

    fn forward(&self, hook: &dyn LinearHook, layer: usize, x: &Tensor, cond: &Tensor, ctx: &Tensor) -> Tensor {
        let d = x.cols();
        // AdaLN: (scale, shift) from the pooled conditioning embedding.
        // Kept FP (tiny 1×d input; the paper quantizes only the big
        // sequence-length activations).
        let mod_sc = self.ada.forward(cond); // 1×2d
        let scale: Vec<f32> = (0..d).map(|j| 1.0 + 0.1 * mod_sc.at(0, j)).collect();
        let shift: Vec<f32> = (0..d).map(|j| 0.1 * mod_sc.at(0, d + j)).collect();

        let (n1, _) = self.norm1.forward(x);
        let n1m = {
            let mut t = n1;
            for i in 0..t.rows() {
                for (j, v) in t.row_mut(i).iter_mut().enumerate() {
                    *v = *v * scale[j] + shift[j];
                }
            }
            t
        };
        let a1 = self.attn1.forward_hooked(hook, &format!("layer{layer}.attn1"), &n1m);
        let x = x.add(&a1);

        let (nca, _) = self.norm_ca.forward(&x);
        let a2 = self.attn2.forward_cross_hooked(hook, &format!("layer{layer}.attn2"), &nca, ctx);
        let x = x.add(&a2);

        let (n2, _) = self.norm2.forward(&x);
        let u =
            hook.linear(&format!("layer{layer}.ffn.up_proj"), &n2, &self.up.w, self.up.b.as_deref());
        let act = u.map(|v| v / (1.0 + (-v).exp())); // SiLU
        let m = hook.linear(
            &format!("layer{layer}.ffn.down_proj"),
            &act,
            &self.down.w,
            self.down.b.as_deref(),
        );
        x.add(&m)
    }
}

/// The DiT model: patch-embed → blocks → final projection back to latent.
pub struct Dit {
    pub cfg: DitConfig,
    proj_in: Linear,
    blocks: Vec<DitBlock>,
    final_norm: RmsNorm,
    proj_out: Linear,
    /// Latent channel width (input/output of proj_in/out).
    pub latent_dim: usize,
}

impl Dit {
    pub fn new(cfg: DitConfig, seed: u64) -> Self {
        let mut rng = XorShiftRng::new(seed);
        let latent_dim = 16;
        Dit {
            proj_in: Linear::new(latent_dim, cfg.d_model, true, &mut rng),
            blocks: (0..cfg.n_layers).map(|_| DitBlock::new(&cfg, &mut rng)).collect(),
            final_norm: RmsNorm::new(cfg.d_model),
            proj_out: Linear::new(cfg.d_model, latent_dim, true, &mut rng),
            latent_dim,
            cfg,
        }
    }

    pub fn n_params(&self) -> usize {
        let b: usize = self
            .blocks
            .iter()
            .map(|b| {
                b.attn1.n_params() + b.attn2.n_params() + b.up.n_params() + b.down.n_params() + b.ada.n_params()
            })
            .sum();
        b + self.proj_in.n_params() + self.proj_out.n_params()
    }

    /// Function-preserving outlier-channel injection (the DiT analogue of
    /// [`crate::model::Gpt::inject_outlier_channels`]): adds a large
    /// token-constant offset at the attn1 (via the AdaLN shift), attn2.to_q
    /// and ffn.up_proj inputs and compensates exactly in the consumers'
    /// biases. Reproduces the hard-to-quantize activations of real DiTs
    /// (paper Table 4: identity-transform SQNR as low as 0.4 dB).
    pub fn inject_outlier_channels(&mut self, count: usize, scale: f32) {
        let d = self.cfg.d_model;
        let stride = (d / count.max(1)).max(1);
        let channels: Vec<usize> = (0..count).map(|k| (k * stride + stride / 2) % d).collect();
        fn compensate(lin: &mut Linear, j: usize, c: f32) {
            let comp: Vec<f32> = lin.w.row(j).iter().map(|&w| -c * w).collect();
            match &mut lin.b {
                Some(bias) => {
                    for (b, v) in bias.iter_mut().zip(&comp) {
                        *b += v;
                    }
                }
                None => {
                    lin.b = Some(comp);
                    lin.gb = Some(vec![0.0; lin.w.cols()]);
                }
            }
        }
        for blk in &mut self.blocks {
            for (idx, &j) in channels.iter().enumerate() {
                let c = scale * if idx % 2 == 0 { 1.0 } else { -1.0 };
                // attn1 input: route through the AdaLN shift so the offset
                // survives the conditioning-dependent scale (shift_j =
                // 0.1 * ada_out[d + j], so bump the ada bias by c / 0.1).
                if let Some(ab) = &mut blk.ada.b {
                    ab[d + j] += c / 0.1;
                }
                compensate(&mut blk.attn1.wq, j, c);
                compensate(&mut blk.attn1.wk, j, c);
                compensate(&mut blk.attn1.wv, j, c);
                // attn2 queries (norm_ca output).
                blk.norm_ca.beta[j] += c;
                compensate(&mut blk.attn2.wq, j, c);
                // ffn input (norm2 output).
                blk.norm2.beta[j] += c;
                compensate(&mut blk.up, j, c);
            }
        }
    }

    /// One denoising step: predict the noise residual for latent `z` under
    /// prompt conditioning.
    pub fn denoise_step(&self, hook: &dyn LinearHook, z: &Tensor, prompt: &str, t: usize) -> Tensor {
        assert_eq!(z.rows(), self.cfg.seq_len());
        assert_eq!(z.cols(), self.latent_dim);
        // Conditioning: pooled prompt embedding + a timestep channel.
        let mut cond = PromptSet::embed(prompt, self.cfg.d_model);
        let tval = (t as f32 + 1.0) / self.cfg.steps as f32;
        for v in cond.data_mut().iter_mut().take(8) {
            *v += tval;
        }
        let ctx = PromptSet::embed_tokens(prompt, self.cfg.ctx_tokens, self.cfg.d_model);

        let mut h = self.proj_in.forward(z);
        for (l, b) in self.blocks.iter().enumerate() {
            h = b.forward(hook, l, &h, &cond, &ctx);
        }
        let (hn, _) = self.final_norm.forward(&h);
        self.proj_out.forward(&hn)
    }

    /// Full toy diffusion sampling loop: start from smooth correlated noise
    /// and iteratively refine. Returns the final latent (`seq × latent_dim`).
    pub fn sample(&self, hook: &dyn LinearHook, prompt: &str, seed: u64) -> Tensor {
        let s = self.cfg.seq_len();
        // Initial latent: spatially-correlated noise over the grid —
        // natural-image-like 1/f structure (drives the block-Toeplitz
        // autocorrelation the 2-D DWT exploits).
        let gen = crate::data::ActivationGenerator::new(crate::data::ActivationSpec {
            seq_len: s,
            dim: self.latent_dim,
            correlation: crate::data::activations::Correlation::Grid2d {
                h: self.cfg.grid_h,
                w: self.cfg.grid_w,
                rho_y: 0.9,
                rho_x: 0.9,
            },
            outlier_channels: 0,
            outlier_scale: 1.0,
            sink_scale: 0.0,
        });
        let mut z = gen.sample(seed ^ PromptSet::hash(prompt));
        for t in 0..self.cfg.steps {
            let eps = self.denoise_step(hook, &z, prompt, t);
            // Simple Euler-style update.
            let alpha = 0.35;
            z = z.zip(&eps, |zi, ei| zi - alpha * ei);
        }
        z
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{CaptureHook, FpHook};

    fn tiny_cfg() -> DitConfig {
        DitConfig { grid_h: 8, grid_w: 8, d_model: 32, n_heads: 2, n_layers: 2, d_ff: 64, ctx_tokens: 4, steps: 2 }
    }

    #[test]
    fn denoise_shapes() {
        let dit = Dit::new(tiny_cfg(), 1);
        let z = Tensor::randn(&[64, 16], 2);
        let eps = dit.denoise_step(&FpHook, &z, "a cat", 0);
        assert_eq!(eps.shape(), &[64, 16]);
        assert!(eps.all_finite());
    }

    #[test]
    fn sample_deterministic_per_prompt() {
        let dit = Dit::new(tiny_cfg(), 3);
        let a = dit.sample(&FpHook, "a cat", 7);
        let b = dit.sample(&FpHook, "a cat", 7);
        let c = dit.sample(&FpHook, "a dog", 7);
        assert_eq!(a, b);
        assert!(a.max_abs_diff(&c) > 1e-3, "prompts must matter");
    }

    #[test]
    fn capture_records_figure5_sites() {
        let dit = Dit::new(tiny_cfg(), 4);
        let hook = CaptureHook::new();
        let z = Tensor::randn(&[64, 16], 5);
        let _ = dit.denoise_step(&hook, &z, "test", 0);
        let sites = hook.sites();
        for want in [
            "layer0.attn1.to_q",
            "layer0.attn1.to_out",
            "layer0.attn2.to_q",
            "layer0.attn2.to_out",
            "layer0.ffn.up_proj",
            "layer0.ffn.down_proj",
        ] {
            assert!(sites.iter().any(|s| s == want), "missing site {want}: {sites:?}");
        }
    }

    #[test]
    fn outlier_injection_preserves_function() {
        let mut dit = Dit::new(tiny_cfg(), 8);
        let z = Tensor::randn(&[64, 16], 9);
        let before = dit.denoise_step(&FpHook, &z, "a cat", 1);
        dit.inject_outlier_channels(3, 25.0);
        let after = dit.denoise_step(&FpHook, &z, "a cat", 1);
        let rel = before.max_abs_diff(&after) / before.abs_max().max(1e-6);
        assert!(rel < 1e-2, "function changed: rel {rel}");
        // Outlier channels must now dominate the ffn.up_proj input ranges.
        let hook = CaptureHook::with_filter("ffn.up_proj");
        let _ = dit.denoise_step(&hook, &z, "a cat", 1);
        let acts = hook.take().remove("layer0.ffn.up_proj").unwrap();
        let absmax = crate::stats::channel_absmax(&acts[0]);
        let mut sorted = absmax.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert!(
            sorted[sorted.len() - 1] > 8.0 * sorted[sorted.len() / 2],
            "no outliers injected"
        );
    }

    #[test]
    fn activations_have_2d_correlation() {
        // The attn1 input autocorrelation must show the grid structure
        // (Fig 3a left) — neighbor in row and neighbor in column both
        // strongly correlated.
        let dit = Dit::new(tiny_cfg(), 6);
        let hook = CaptureHook::with_filter("layer1.attn1.to_q");
        for seed in 0..4u64 {
            let _ = dit.sample(&hook, "a landscape", seed);
        }
        let acts: Vec<Tensor> = hook
            .take()
            .remove("layer1.attn1.to_q")
            .unwrap();
        let cov = crate::stats::autocorrelation(&acts);
        let norm = |i: usize, j: usize| cov.at(i, j) / (cov.at(i, i) * cov.at(j, j)).sqrt();
        assert!(norm(9, 10) > 0.3, "row-neighbor corr {}", norm(9, 10));
        assert!(norm(9, 17) > 0.3, "col-neighbor corr {}", norm(9, 17));
    }
}
