//! Linear layers + the quantization hook interface.
//!
//! Every linear in the models calls `hook.linear(site, x, w, bias)` instead
//! of multiplying directly, so a single forward implementation serves FP
//! evaluation, activation capture (calibration), and every quantized
//! baseline — the hook *is* the quantization configuration.

use crate::tensor::{matmul, Tensor, XorShiftRng};
use std::cell::RefCell;
use std::collections::HashMap;

/// Interception point for every linear layer input.
pub trait LinearHook {
    /// Compute `x @ w + bias` with whatever transformation/quantization the
    /// hook implements. `site` is the Figure-5 activation-site name, with a
    /// `layerN.` prefix (e.g. `layer3.ffn.up_proj`).
    fn linear(&self, site: &str, x: &Tensor, w: &Tensor, bias: Option<&[f32]>) -> Tensor;

    /// Hook for KV-cache tensors (`k`/`v` per layer), post-projection.
    /// Default: identity (FP cache).
    fn kv(&self, _site: &str, t: &Tensor) -> Tensor {
        t.clone()
    }
}

/// Full-precision pass-through hook.
pub struct FpHook;

impl LinearHook for FpHook {
    fn linear(&self, _site: &str, x: &Tensor, w: &Tensor, bias: Option<&[f32]>) -> Tensor {
        let mut y = matmul(x, w);
        if let Some(b) = bias {
            y = y.add_row_broadcast(b);
        }
        y
    }
}

/// Calibration hook: records every site's input activation, then computes
/// the FP result. Interior mutability because the hook is shared immutably
/// across the forward pass.
#[derive(Default)]
pub struct CaptureHook {
    captured: RefCell<HashMap<String, Vec<Tensor>>>,
    /// Optional site filter: only capture sites containing this substring.
    pub filter: Option<String>,
}

impl CaptureHook {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn with_filter(filter: &str) -> Self {
        CaptureHook { captured: RefCell::new(HashMap::new()), filter: Some(filter.to_string()) }
    }

    pub fn take(&self) -> HashMap<String, Vec<Tensor>> {
        self.captured.borrow_mut().drain().collect()
    }

    pub fn sites(&self) -> Vec<String> {
        let mut v: Vec<String> = self.captured.borrow().keys().cloned().collect();
        v.sort();
        v
    }
}

impl LinearHook for CaptureHook {
    fn linear(&self, site: &str, x: &Tensor, w: &Tensor, bias: Option<&[f32]>) -> Tensor {
        let keep = self.filter.as_ref().map(|f| site.contains(f.as_str())).unwrap_or(true);
        if keep {
            self.captured.borrow_mut().entry(site.to_string()).or_default().push(x.clone());
        }
        FpHook.linear(site, x, w, bias)
    }
}

/// A trainable linear layer, weight stored `[in, out]`.
pub struct Linear {
    pub w: Tensor,
    pub b: Option<Vec<f32>>,
    // Gradients (allocated lazily by backward).
    pub gw: Tensor,
    pub gb: Option<Vec<f32>>,
}

impl Linear {
    /// Kaiming-ish init: N(0, 1/√in).
    pub fn new(d_in: usize, d_out: usize, bias: bool, rng: &mut XorShiftRng) -> Self {
        let scale = 1.0 / (d_in as f32).sqrt();
        let mut w = Tensor::zeros(&[d_in, d_out]);
        for v in w.data_mut() {
            *v = rng.next_gaussian() * scale;
        }
        Linear {
            w,
            b: if bias { Some(vec![0.0; d_out]) } else { None },
            gw: Tensor::zeros(&[d_in, d_out]),
            gb: if bias { Some(vec![0.0; d_out]) } else { None },
        }
    }

    pub fn forward(&self, x: &Tensor) -> Tensor {
        let mut y = matmul(x, &self.w);
        if let Some(b) = &self.b {
            y = y.add_row_broadcast(b);
        }
        y
    }

    /// Hooked forward for quantized evaluation.
    pub fn forward_hooked(&self, hook: &dyn LinearHook, site: &str, x: &Tensor) -> Tensor {
        hook.linear(site, x, &self.w, self.b.as_deref())
    }

    /// Backward: given input `x` and output grad `dy`, accumulate `gw`,
    /// `gb` and return `dx`.
    pub fn backward(&mut self, x: &Tensor, dy: &Tensor) -> Tensor {
        // gw += xᵀ dy
        let gw = matmul(&x.transpose(), dy);
        self.gw = self.gw.add(&gw);
        if let (Some(gb), true) = (&mut self.gb, self.b.is_some()) {
            for i in 0..dy.rows() {
                for (g, &v) in gb.iter_mut().zip(dy.row(i)) {
                    *g += v;
                }
            }
        }
        // dx = dy wᵀ
        crate::tensor::matmul_transb(dy, &self.w)
    }

    pub fn zero_grad(&mut self) {
        self.gw.data_mut().fill(0.0);
        if let Some(gb) = &mut self.gb {
            gb.fill(0.0);
        }
    }

    /// Visit (param, grad) pairs for the optimizer.
    pub fn visit_params(&mut self, f: &mut dyn FnMut(&mut [f32], &[f32])) {
        // Split borrows: copy grad out (small) to satisfy the borrow checker.
        let gw = self.gw.data().to_vec();
        f(self.w.data_mut(), &gw);
        if let (Some(b), Some(gb)) = (&mut self.b, &self.gb) {
            let gbc = gb.clone();
            f(b, &gbc);
        }
    }

    pub fn n_params(&self) -> usize {
        self.w.len() + self.b.as_ref().map(|b| b.len()).unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forward_matches_manual() {
        let mut rng = XorShiftRng::new(1);
        let l = Linear::new(4, 3, true, &mut rng);
        let x = Tensor::randn(&[2, 4], 2);
        let y = l.forward(&x);
        let want = matmul(&x, &l.w).add_row_broadcast(l.b.as_ref().unwrap());
        assert_eq!(y, want);
    }

    #[test]
    fn backward_gradients_numerically() {
        // Finite-difference check of dL/dw and dL/dx for L = Σ y².
        let mut rng = XorShiftRng::new(3);
        let mut l = Linear::new(3, 2, true, &mut rng);
        let x = Tensor::randn(&[4, 3], 4);
        let y = l.forward(&x);
        let dy = y.scale(2.0); // dL/dy for L = Σ y²
        let dx = l.backward(&x, &dy);

        let loss = |l: &Linear, x: &Tensor| -> f64 { l.forward(x).sq_norm() };
        let eps = 1e-3f32;

        // Check a few weight entries.
        for &(i, j) in &[(0usize, 0usize), (1, 1), (2, 0)] {
            let mut lp = Linear {
                w: l.w.clone(),
                b: l.b.clone(),
                gw: Tensor::zeros(&[3, 2]),
                gb: None,
            };
            lp.w.set(i, j, lp.w.at(i, j) + eps);
            let num = (loss(&lp, &x) - loss(&l, &x)) / eps as f64;
            let ana = l.gw.at(i, j) as f64;
            assert!((num - ana).abs() < 0.05 * ana.abs().max(1.0), "w[{i}{j}] num {num} ana {ana}");
        }
        // Check an input entry.
        let mut xp = x.clone();
        xp.set(0, 0, xp.at(0, 0) + eps);
        let num = (loss(&l, &xp) - loss(&l, &x)) / eps as f64;
        assert!((num - dx.at(0, 0) as f64).abs() < 0.05 * num.abs().max(1.0));
    }

    #[test]
    fn capture_hook_records() {
        let mut rng = XorShiftRng::new(5);
        let l = Linear::new(4, 4, false, &mut rng);
        let hook = CaptureHook::new();
        let x = Tensor::randn(&[2, 4], 6);
        let _ = l.forward_hooked(&hook, "layer0.ffn.up_proj", &x);
        let got = hook.take();
        assert_eq!(got.len(), 1);
        assert_eq!(got["layer0.ffn.up_proj"][0], x);
    }

    #[test]
    fn capture_hook_filter() {
        let mut rng = XorShiftRng::new(5);
        let l = Linear::new(4, 4, false, &mut rng);
        let hook = CaptureHook::with_filter("attn1");
        let x = Tensor::randn(&[2, 4], 6);
        let _ = l.forward_hooked(&hook, "layer0.ffn.up_proj", &x);
        let _ = l.forward_hooked(&hook, "layer0.attn1", &x);
        let got = hook.take();
        assert_eq!(got.len(), 1);
        assert!(got.contains_key("layer0.attn1"));
    }
}
