//! Multi-head attention: causal self-attention (GPT), bidirectional
//! self-attention and cross-attention (DiT) — with hand-written backward
//! for the causal path (training) and hooked forwards for quantized eval.

use super::linear::{Linear, LinearHook};
use super::softmax_rows;
use crate::tensor::{matmul, matmul_transb, Tensor, XorShiftRng};

/// Multi-head attention with combined QKV projections.
pub struct MultiHeadAttention {
    pub n_heads: usize,
    pub d_model: usize,
    pub wq: Linear,
    pub wk: Linear,
    pub wv: Linear,
    pub wo: Linear,
    pub causal: bool,
}

/// Absolute-position layout of gathered K/V rows for causal masking under
/// KV eviction ([`crate::kvcache::EvictionPolicy::SlidingWindow`]):
/// gathered key row `r` holds absolute position `r` while `r < gap_row`,
/// and `r + gap` once past the eviction gap; the first query row sits at
/// absolute position `q_pos`. Contiguous (unevicted) keys are the
/// `gap = 0` case, where the mask is bit-identical to the classic
/// `sk − s` offset rule.
struct KeyMap {
    gap_row: usize,
    gap: usize,
    q_pos: usize,
}

impl KeyMap {
    /// Contiguous keys: the full-sequence / unevicted special case.
    fn contiguous(sk: usize, s: usize) -> Self {
        debug_assert!(sk >= s, "causal sdpa needs key history ≥ query rows");
        KeyMap { gap_row: sk, gap: 0, q_pos: sk - s }
    }

    /// Layout of one stream's gathered cache for `s` newest-token queries
    /// (the stream has already absorbed their K/V appends).
    fn for_stream(stream: &crate::kvcache::KvStream, s: usize) -> Self {
        KeyMap { gap_row: stream.gap_row(), gap: stream.evicted(), q_pos: stream.len() - s }
    }
}

/// Forward caches needed by backward.
pub struct AttnCache {
    x: Tensor,
    q: Tensor,
    k: Tensor,
    v: Tensor,
    /// Per-head softmax probabilities, each `s×s`.
    probs: Vec<Tensor>,
    /// Concatenated head outputs before the output projection.
    concat: Tensor,
}

impl MultiHeadAttention {
    pub fn new(d_model: usize, n_heads: usize, causal: bool, rng: &mut XorShiftRng) -> Self {
        assert_eq!(d_model % n_heads, 0);
        MultiHeadAttention {
            n_heads,
            d_model,
            wq: Linear::new(d_model, d_model, false, rng),
            wk: Linear::new(d_model, d_model, false, rng),
            wv: Linear::new(d_model, d_model, false, rng),
            wo: Linear::new(d_model, d_model, false, rng),
            causal,
        }
    }

    fn dh(&self) -> usize {
        self.d_model / self.n_heads
    }

    /// Slice head `h` out of a packed `s×d_model` projection.
    fn head(&self, t: &Tensor, h: usize) -> Tensor {
        let (s, dh) = (t.rows(), self.dh());
        let mut out = Tensor::zeros(&[s, dh]);
        for i in 0..s {
            out.row_mut(i).copy_from_slice(&t.row(i)[h * dh..(h + 1) * dh]);
        }
        out
    }

    fn put_head(&self, dst: &mut Tensor, src: &Tensor, h: usize) {
        let dh = self.dh();
        for i in 0..src.rows() {
            dst.row_mut(i)[h * dh..(h + 1) * dh].copy_from_slice(src.row(i));
        }
    }

    /// Core scaled-dot-product given packed q/k/v; returns (output, probs).
    ///
    /// Causal masking aligns the *last* query to the last key: with `s`
    /// queries over `sk ≥ s` keys, query `i` attends keys `≤ i + (sk−s)`.
    /// The full forward is the `s == sk` special case (offset 0, the
    /// classic triangular mask); incremental decode over an evicting cache
    /// passes an explicit [`KeyMap`] instead ([`Self::sdpa_mapped`]).
    fn sdpa(&self, q: &Tensor, k: &Tensor, v: &Tensor) -> (Tensor, Vec<Tensor>) {
        // Non-causal attention (cross-attention can have sk < s) never
        // reads the map, so only derive the offset when masking will.
        let map = if self.causal {
            KeyMap::contiguous(k.rows(), q.rows())
        } else {
            KeyMap { gap_row: 0, gap: 0, q_pos: 0 }
        };
        self.sdpa_mapped(q, k, v, &map)
    }

    /// [`Self::sdpa`] with causal masking over the *absolute* key
    /// positions described by `map` (ignored for non-causal attention).
    /// Query `i` (absolute position `q_pos + i`) attends exactly the keys
    /// whose absolute position is ≤ its own; positions are strictly
    /// increasing over gathered rows, so the visible set is a prefix —
    /// `below` counts the pre-gap (sink) rows, `above` the post-gap rows.
    /// With `gap = 0` the cut reduces to `i + (sk − s) + 1`, bit-for-bit
    /// the classic offset rule.
    fn sdpa_mapped(&self, q: &Tensor, k: &Tensor, v: &Tensor, map: &KeyMap) -> (Tensor, Vec<Tensor>) {
        let s = q.rows();
        let sk = k.rows();
        let scale = 1.0 / (self.dh() as f32).sqrt();
        let mut concat = Tensor::zeros(&[s, self.d_model]);
        let mut probs = Vec::with_capacity(self.n_heads);
        for h in 0..self.n_heads {
            let qh = self.head(q, h);
            let kh = self.head(k, h);
            let vh = self.head(v, h);
            let mut scores = matmul_transb(&qh, &kh).scale(scale);
            if self.causal {
                for i in 0..s {
                    let p = map.q_pos + i;
                    let below = (p + 1).min(map.gap_row);
                    let above = (p + 1).saturating_sub(map.gap_row + map.gap);
                    let cut = (below + above).min(sk);
                    for j in cut..sk {
                        scores.set(i, j, f32::NEG_INFINITY);
                    }
                }
            }
            softmax_rows(&mut scores);
            let oh = matmul(&scores, &vh);
            self.put_head(&mut concat, &oh, h);
            probs.push(scores);
        }
        (concat, probs)
    }

    /// Training forward (self-attention) with cache for backward.
    pub fn forward_train(&self, x: &Tensor) -> (Tensor, AttnCache) {
        let q = self.wq.forward(x);
        let k = self.wk.forward(x);
        let v = self.wv.forward(x);
        let (concat, probs) = self.sdpa(&q, &k, &v);
        let out = self.wo.forward(&concat);
        (out, AttnCache { x: x.clone(), q, k, v, probs, concat })
    }

    /// Hooked eval forward (self-attention). `site` prefixes e.g.
    /// `layer2.attn1`; Figure-5 sites derived: `{site}.to_q/.to_k/.to_v`
    /// for the projections (distinct sites so per-weight state like the
    /// SVDQuant branch never crosses weights; the shared *input* is still
    /// addressable by the `attn1` substring), `{site}.to_out` for the
    /// output projection, `{site}.k/.v` for the KV cache.
    pub fn forward_hooked(&self, hook: &dyn LinearHook, site: &str, x: &Tensor) -> Tensor {
        let q = hook.linear(&format!("{site}.to_q"), x, &self.wq.w, self.wq.b.as_deref());
        let k = hook.linear(&format!("{site}.to_k"), x, &self.wk.w, self.wk.b.as_deref());
        let v = hook.linear(&format!("{site}.to_v"), x, &self.wv.w, self.wv.b.as_deref());
        let k = hook.kv(&format!("{site}.k"), &k);
        let v = hook.kv(&format!("{site}.v"), &v);
        let (concat, _) = self.sdpa(&q, &k, &v);
        hook.linear(&format!("{site}.to_out"), &concat, &self.wo.w, self.wo.b.as_deref())
    }

    /// Incremental decode forward (self-attention over the cached K/V
    /// stream plus the new tokens). `x` holds the `m` newest tokens'
    /// inputs; their K/V projections are appended to `cache`, then the
    /// new queries attend over the *gathered* stream (finalized blocks
    /// decompress once at flush; gather copies). Sites match
    /// [`Self::forward_hooked`]; the
    /// `.k`/`.v` hook sites are deliberately not applied — the cache's own
    /// quantization policy replaces the hook-level KV QDQ.
    ///
    /// With an fp32 cache ([`crate::kvcache::KvCacheConfig::fp32`]) and
    /// [`crate::model::FpHook`], every kernel here is row-wise identical
    /// to the full-sequence path, so decode logits are bit-identical to
    /// [`Self::forward_hooked`]'s corresponding rows at any thread count
    /// (pinned by `tests/decode.rs`).
    pub fn forward_decode(
        &self,
        hook: &dyn LinearHook,
        site: &str,
        x: &Tensor,
        cache: &mut crate::kvcache::KvLayer,
    ) -> Tensor {
        let q = hook.linear(&format!("{site}.to_q"), x, &self.wq.w, self.wq.b.as_deref());
        let k_new = hook.linear(&format!("{site}.to_k"), x, &self.wk.w, self.wk.b.as_deref());
        let v_new = hook.linear(&format!("{site}.to_v"), x, &self.wv.w, self.wv.b.as_deref());
        cache.k.append(&k_new);
        cache.v.append(&v_new);
        let k = cache.k.gather();
        let v = cache.v.gather();
        // Mask over *absolute* positions: an evicting cache gathers the
        // non-contiguous `[sinks ‖ recent]` window, and every resident key
        // is in the queries' past except newer same-chunk rows.
        let map = KeyMap::for_stream(&cache.k, x.rows());
        let (concat, _) = self.sdpa_mapped(&q, &k, &v, &map);
        hook.linear(&format!("{site}.to_out"), &concat, &self.wo.w, self.wo.b.as_deref())
    }

    /// One synchronized decode step across **independent streams**: row
    /// `i` of `x` is the newest token of stream `i`, and `caches[i]` is
    /// that stream's K/V layer. The q/k/v/out projections run as single
    /// `[n_streams × d]` GEMMs — the fused hot path that raises the
    /// arithmetic intensity of weight-bound decode by ~n — while attention
    /// itself scatters per stream over each stream's own cached history
    /// (streams never attend across each other; per-stream causality is
    /// exactly the single-stream rule).
    ///
    /// Every kernel on the fused path is row-wise, so with [`crate::model::FpHook`]
    /// row `i` is bit-identical to a serial [`Self::forward_decode`] call
    /// on stream `i` alone, at any thread count and any batch composition
    /// (`tests/decode.rs` pins it). `forward_decode` is the
    /// `n_streams == 1` degenerate case, kept for chunked prefill (which
    /// feeds multiple rows of *one* stream instead).
    pub fn forward_decode_batch(
        &self,
        hook: &dyn LinearHook,
        site: &str,
        x: &Tensor,
        caches: &mut [&mut crate::kvcache::KvLayer],
    ) -> Tensor {
        assert_eq!(x.rows(), caches.len(), "one kv layer per stream row");
        let lens = vec![1usize; caches.len()];
        self.forward_decode_ragged(hook, site, x, &lens, caches)
    }

    /// Ragged decode step: stream `i` contributes `lens[i] ≥ 1`
    /// consecutive rows of `x` (its pending token plus speculative draft
    /// tokens, oldest first) — the verification forward of speculative
    /// decode (DESIGN.md §18). The projections stay fused over the full
    /// `[Σ lens × d]` stack; attention scatters per stream exactly like
    /// [`Self::forward_decode_batch`] (its `lens = [1, 1, …]` case) but
    /// passes each stream's own row count to [`KeyMap::for_stream`], so
    /// row `j` of stream `i` attends precisely the keys at absolute
    /// positions ≤ its own — same-chunk futures masked by the
    /// absolute-position rule, exactly the chunked-prefill masking. Every
    /// kernel is row-wise, so each stream's rows are bit-identical to
    /// serial single-token [`Self::forward_decode`] calls feeding the same
    /// tokens (`decode_multi_token_chunk_matches` pins the chunk rule;
    /// `tests/speculative.rs` pins it end-to-end).
    pub fn forward_decode_ragged(
        &self,
        hook: &dyn LinearHook,
        site: &str,
        x: &Tensor,
        lens: &[usize],
        caches: &mut [&mut crate::kvcache::KvLayer],
    ) -> Tensor {
        let m = x.rows();
        assert_eq!(lens.len(), caches.len(), "one row count per stream");
        assert_eq!(m, lens.iter().sum::<usize>(), "rows must cover every stream's tokens");
        let q = hook.linear(&format!("{site}.to_q"), x, &self.wq.w, self.wq.b.as_deref());
        let k_new = hook.linear(&format!("{site}.to_k"), x, &self.wk.w, self.wk.b.as_deref());
        let v_new = hook.linear(&format!("{site}.to_v"), x, &self.wv.w, self.wv.b.as_deref());
        let mut concat = Tensor::zeros(&[m, self.d_model]);
        let mut r = 0usize;
        for (layer, &s) in caches.iter_mut().zip(lens) {
            assert!(s >= 1, "each stream contributes at least its pending token");
            layer.k.append(&k_new.slice_rows(r, r + s));
            layer.v.append(&v_new.slice_rows(r, r + s));
            let k = layer.k.gather();
            let v = layer.v.gather();
            let map = KeyMap::for_stream(&layer.k, s);
            let (ci, _) = self.sdpa_mapped(&q.slice_rows(r, r + s), &k, &v, &map);
            for j in 0..s {
                concat.row_mut(r + j).copy_from_slice(ci.row(j));
            }
            r += s;
        }
        hook.linear(&format!("{site}.to_out"), &concat, &self.wo.w, self.wo.b.as_deref())
    }

    /// Hooked cross-attention: queries from `x`, keys/values from `ctx`.
    /// Sites: `{site}.to_q` (query input) and `{site}.to_out` — matching
    /// the paper's attn2 naming; K/V projections from text context are
    /// left unquantized, as in the paper (§5.1: cross-attn K/V excluded).
    pub fn forward_cross_hooked(
        &self,
        hook: &dyn LinearHook,
        site: &str,
        x: &Tensor,
        ctx: &Tensor,
    ) -> Tensor {
        let q = hook.linear(&format!("{site}.to_q"), x, &self.wq.w, self.wq.b.as_deref());
        let k = self.wk.forward(ctx);
        let v = self.wv.forward(ctx);
        let (concat, _) = self.sdpa(&q, &k, &v);
        hook.linear(&format!("{site}.to_out"), &concat, &self.wo.w, self.wo.b.as_deref())
    }

    /// Backward through the training forward. Returns dx.
    pub fn backward(&mut self, cache: &AttnCache, dy: &Tensor) -> Tensor {
        let s = cache.x.rows();
        let dh = self.dh();
        let scale = 1.0 / (dh as f32).sqrt();

        // Output projection.
        let dconcat = self.wo.backward(&cache.concat, dy);

        let mut dq = Tensor::zeros(&[s, self.d_model]);
        let mut dk = Tensor::zeros(&[s, self.d_model]);
        let mut dv = Tensor::zeros(&[s, self.d_model]);

        for h in 0..self.n_heads {
            let doh = self.head(&dconcat, h);
            let p = &cache.probs[h];
            let kh = self.head(&cache.k, h);
            let vh = self.head(&cache.v, h);
            let qh = self.head(&cache.q, h);

            // dV_h = Pᵀ dO_h
            let dvh = matmul(&p.transpose(), &doh);
            // dP = dO_h V_hᵀ
            let dp = matmul_transb(&doh, &vh);
            // Softmax backward row-wise: dS_ij = P_ij (dP_ij − Σ_k dP_ik P_ik)
            let mut ds = Tensor::zeros(&[s, s]);
            for i in 0..s {
                let pr = p.row(i);
                let dpr = dp.row(i);
                let dot: f32 = pr.iter().zip(dpr).map(|(a, b)| a * b).sum();
                let dsr = ds.row_mut(i);
                for j in 0..s {
                    dsr[j] = pr[j] * (dpr[j] - dot);
                }
            }
            // scores = scale · Q Kᵀ  ⇒ dQ = scale · dS K; dK = scale · dSᵀ Q
            let dqh = matmul(&ds, &kh).scale(scale);
            let dkh = matmul(&ds.transpose(), &qh).scale(scale);

            self.put_head(&mut dq, &dqh, h);
            self.put_head(&mut dk, &dkh, h);
            self.put_head(&mut dv, &dvh, h);
        }

        let dx_q = self.wq.backward(&cache.x, &dq);
        let dx_k = self.wk.backward(&cache.x, &dk);
        let dx_v = self.wv.backward(&cache.x, &dv);
        dx_q.add(&dx_k).add(&dx_v)
    }

    pub fn zero_grad(&mut self) {
        self.wq.zero_grad();
        self.wk.zero_grad();
        self.wv.zero_grad();
        self.wo.zero_grad();
    }

    pub fn visit_params(&mut self, f: &mut dyn FnMut(&mut [f32], &[f32])) {
        self.wq.visit_params(f);
        self.wk.visit_params(f);
        self.wv.visit_params(f);
        self.wo.visit_params(f);
    }

    pub fn n_params(&self) -> usize {
        self.wq.n_params() + self.wk.n_params() + self.wv.n_params() + self.wo.n_params()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::FpHook;

    #[test]
    fn causal_masking() {
        let mut rng = XorShiftRng::new(1);
        let attn = MultiHeadAttention::new(8, 2, true, &mut rng);
        let x = Tensor::randn(&[6, 8], 2);
        let (y_full, _) = attn.forward_train(&x);
        // Changing a future token must not change earlier outputs.
        let mut x2 = x.clone();
        for j in 0..8 {
            x2.set(5, j, 99.0);
        }
        let (y2, _) = attn.forward_train(&x2);
        for i in 0..5 {
            for j in 0..8 {
                assert!(
                    (y_full.at(i, j) - y2.at(i, j)).abs() < 1e-5,
                    "row {i} leaked future info"
                );
            }
        }
        // Last row must change.
        assert!((0..8).any(|j| (y_full.at(5, j) - y2.at(5, j)).abs() > 1e-3));
    }

    #[test]
    fn hooked_matches_train_forward() {
        let mut rng = XorShiftRng::new(3);
        let attn = MultiHeadAttention::new(16, 4, true, &mut rng);
        let x = Tensor::randn(&[8, 16], 4);
        let (y_train, _) = attn.forward_train(&x);
        let y_hooked = attn.forward_hooked(&FpHook, "layer0.attn1", &x);
        assert!(y_train.max_abs_diff(&y_hooked) < 1e-5);
    }

    #[test]
    fn backward_numerical() {
        let mut rng = XorShiftRng::new(5);
        let mut attn = MultiHeadAttention::new(4, 2, true, &mut rng);
        let x = Tensor::randn(&[3, 4], 6);
        let (y, cache) = attn.forward_train(&x);
        let dy = y.scale(2.0); // L = Σ y²
        let dx = attn.backward(&cache, &dy);

        let loss = |a: &MultiHeadAttention, x: &Tensor| -> f64 { a.forward_train(x).0.sq_norm() };
        let eps = 1e-3f32;
        // dx finite difference.
        for &(i, j) in &[(0usize, 0usize), (1, 2), (2, 3)] {
            let mut xp = x.clone();
            xp.set(i, j, xp.at(i, j) + eps);
            let num = (loss(&attn, &xp) - loss(&attn, &x)) / eps as f64;
            let ana = dx.at(i, j) as f64;
            assert!(
                (num - ana).abs() < 0.1 * ana.abs().max(0.5),
                "dx[{i},{j}] num {num} ana {ana}"
            );
        }
        // dWq finite difference (one entry).
        let ana = attn.wq.gw.at(1, 1) as f64;
        attn.wq.w.set(1, 1, attn.wq.w.at(1, 1) + eps);
        let lp = loss(&attn, &x);
        attn.wq.w.set(1, 1, attn.wq.w.at(1, 1) - eps);
        let l0 = loss(&attn, &x);
        let num = (lp - l0) / eps as f64;
        assert!((num - ana).abs() < 0.1 * ana.abs().max(0.5), "dwq num {num} ana {ana}");
    }

    #[test]
    fn decode_rows_bit_identical_to_full_forward() {
        let mut rng = XorShiftRng::new(11);
        let attn = MultiHeadAttention::new(16, 4, true, &mut rng);
        let x = Tensor::randn(&[6, 16], 12);
        let full = attn.forward_hooked(&FpHook, "layer0.attn1", &x);
        let mut cache = crate::kvcache::KvLayer::fp32();
        for t in 0..6 {
            let row = x.slice_rows(t, t + 1);
            let y = attn.forward_decode(&FpHook, "layer0.attn1", &row, &mut cache);
            assert_eq!(y.row(0), full.row(t), "decode step {t} must be bit-identical");
        }
        assert_eq!(cache.k.len(), 6);
    }

    #[test]
    fn decode_multi_token_chunk_matches() {
        // Chunked prefill: 4 tokens at once, then 2 more.
        let mut rng = XorShiftRng::new(13);
        let attn = MultiHeadAttention::new(8, 2, true, &mut rng);
        let x = Tensor::randn(&[6, 8], 14);
        let full = attn.forward_hooked(&FpHook, "layer0.attn1", &x);
        let mut cache = crate::kvcache::KvLayer::fp32();
        let a = attn.forward_decode(&FpHook, "layer0.attn1", &x.slice_rows(0, 4), &mut cache);
        let b = attn.forward_decode(&FpHook, "layer0.attn1", &x.slice_rows(4, 6), &mut cache);
        for t in 0..4 {
            assert_eq!(a.row(t), full.row(t), "chunk-1 row {t}");
        }
        for t in 0..2 {
            assert_eq!(b.row(t), full.row(4 + t), "chunk-2 row {t}");
        }
    }

    #[test]
    fn batched_decode_rows_bit_identical_to_serial_streams() {
        // Three independent streams with ragged histories: a fused step
        // must reproduce each stream's serial forward_decode bit-for-bit.
        let mut rng = XorShiftRng::new(17);
        let attn = MultiHeadAttention::new(16, 4, true, &mut rng);
        let hists = [3usize, 6, 1];
        let mut serial: Vec<crate::kvcache::KvLayer> = Vec::new();
        let mut batched: Vec<crate::kvcache::KvLayer> = Vec::new();
        let mut want_rows: Vec<Vec<f32>> = Vec::new();
        let mut step = Tensor::zeros(&[hists.len(), 16]);
        for (i, &h) in hists.iter().enumerate() {
            let past = Tensor::randn(&[h, 16], 100 + i as u64);
            let mut sl = crate::kvcache::KvLayer::fp32();
            let mut bl = crate::kvcache::KvLayer::fp32();
            let _ = attn.forward_decode(&FpHook, "layer0.attn1", &past, &mut sl);
            let _ = attn.forward_decode(&FpHook, "layer0.attn1", &past, &mut bl);
            let new = Tensor::randn(&[1, 16], 200 + i as u64);
            step.row_mut(i).copy_from_slice(new.row(0));
            let y = attn.forward_decode(&FpHook, "layer0.attn1", &new, &mut sl);
            want_rows.push(y.row(0).to_vec());
            serial.push(sl);
            batched.push(bl);
        }
        let mut refs: Vec<&mut crate::kvcache::KvLayer> = batched.iter_mut().collect();
        let got = attn.forward_decode_batch(&FpHook, "layer0.attn1", &step, &mut refs);
        for (i, want) in want_rows.iter().enumerate() {
            assert_eq!(got.row(i), &want[..], "stream {i} fused row");
        }
        // Caches advanced identically too.
        for (s, b) in serial.iter().zip(&batched) {
            assert_eq!(s.k.gather(), b.k.gather());
            assert_eq!(s.v.gather(), b.v.gather());
        }
    }

    #[test]
    fn ragged_decode_rows_bit_identical_to_serial_chunks() {
        // Three streams contributing 2 / 1 / 3 rows in one ragged step:
        // every row must equal the serial token-by-token forward_decode
        // on that stream alone, and the caches must advance identically.
        let mut rng = XorShiftRng::new(23);
        let attn = MultiHeadAttention::new(16, 4, true, &mut rng);
        let hists = [4usize, 1, 6];
        let lens = [2usize, 1, 3];
        let m: usize = lens.iter().sum();
        let mut serial: Vec<crate::kvcache::KvLayer> = Vec::new();
        let mut ragged: Vec<crate::kvcache::KvLayer> = Vec::new();
        let mut want_rows: Vec<Vec<f32>> = Vec::new();
        let mut step = Tensor::zeros(&[m, 16]);
        let mut r = 0usize;
        for (i, (&h, &s)) in hists.iter().zip(&lens).enumerate() {
            let past = Tensor::randn(&[h, 16], 400 + i as u64);
            let mut sl = crate::kvcache::KvLayer::fp32();
            let mut rl = crate::kvcache::KvLayer::fp32();
            let _ = attn.forward_decode(&FpHook, "layer0.attn1", &past, &mut sl);
            let _ = attn.forward_decode(&FpHook, "layer0.attn1", &past, &mut rl);
            let new = Tensor::randn(&[s, 16], 500 + i as u64);
            for j in 0..s {
                step.row_mut(r + j).copy_from_slice(new.row(j));
                let y = attn.forward_decode(
                    &FpHook,
                    "layer0.attn1",
                    &new.slice_rows(j, j + 1),
                    &mut sl,
                );
                want_rows.push(y.row(0).to_vec());
            }
            r += s;
            serial.push(sl);
            ragged.push(rl);
        }
        let mut refs: Vec<&mut crate::kvcache::KvLayer> = ragged.iter_mut().collect();
        let got = attn.forward_decode_ragged(&FpHook, "layer0.attn1", &step, &lens, &mut refs);
        for (i, want) in want_rows.iter().enumerate() {
            assert_eq!(got.row(i), &want[..], "ragged row {i}");
        }
        for (s, rg) in serial.iter().zip(&ragged) {
            assert_eq!(s.k.gather(), rg.k.gather());
            assert_eq!(s.v.gather(), rg.v.gather());
        }
    }

    #[test]
    fn windowed_decode_chunk_matches_token_by_token() {
        // With an eviction gap already in the cache, a multi-token decode
        // chunk must reproduce the token-by-token path bit-for-bit — the
        // absolute-position mask is what keeps same-chunk futures hidden
        // while every resident (sink or recent) key stays visible.
        let mut rng = XorShiftRng::new(19);
        let attn = MultiHeadAttention::new(8, 2, true, &mut rng);
        let cfg = crate::kvcache::KvCacheConfig { block: 4, ..crate::kvcache::KvCacheConfig::fp32() }
            .with_window(4, 8);
        let x = Tensor::randn(&[19, 8], 20);
        let mk = || crate::kvcache::KvLayer::new(cfg.clone());
        let mut one = mk();
        let mut chunked = mk();
        // Shared history: 16 tokens, driven identically on both caches.
        for t in 0..16 {
            let _ = attn.forward_decode(&FpHook, "layer0.attn1", &x.slice_rows(t, t + 1), &mut one);
            let _ =
                attn.forward_decode(&FpHook, "layer0.attn1", &x.slice_rows(t, t + 1), &mut chunked);
        }
        assert!(one.k.evicted() > 0, "history must already have evicted");
        // 3 more tokens: no eviction fires before len 20, so both paths
        // see identical resident sets and must agree exactly.
        let mut want = Vec::new();
        for t in 16..19 {
            let y = attn.forward_decode(&FpHook, "layer0.attn1", &x.slice_rows(t, t + 1), &mut one);
            want.push(y.row(0).to_vec());
        }
        let got = attn.forward_decode(&FpHook, "layer0.attn1", &x.slice_rows(16, 19), &mut chunked);
        for (i, w) in want.iter().enumerate() {
            assert_eq!(got.row(i), &w[..], "chunk row {i}");
        }
        assert_eq!(one.k.evicted(), chunked.k.evicted());
        assert_eq!(one.k.gather(), chunked.k.gather());
    }

    #[test]
    fn cross_attention_shapes() {
        let mut rng = XorShiftRng::new(7);
        let attn = MultiHeadAttention::new(8, 2, false, &mut rng);
        let x = Tensor::randn(&[10, 8], 8);
        let ctx = Tensor::randn(&[4, 8], 9);
        let y = attn.forward_cross_hooked(&FpHook, "layer0.attn2", &x, &ctx);
        assert_eq!(y.shape(), &[10, 8]);
        assert!(y.all_finite());
    }
}
