//! Rust-native tiny models with quantization hook points.
//!
//! The reproduction cannot load LLaMA/PixArt weights (DESIGN.md §3), so the
//! table harnesses run on models built here:
//!
//! * [`gpt`] — a GPT-style causal LM (RMSNorm, MHA, gated MLP) with a full
//!   hand-written backward pass so [`crate::train`] can train it on the
//!   synthetic corpus; its quantized perplexity gives the Table-2 rows.
//! * [`dit`] — a DiT-style block stack over a 2-D latent token grid with
//!   cross-attention to prompt embeddings; its latent SQNR gives the
//!   Table-1/4/5 and Figure-4/7/9 rows.
//!
//! Quantization is injected through [`LinearHook`]: every linear layer in
//! both models routes its input through the hook, which either passes it
//! through (FP), captures it (calibration), or applies a baseline's
//! feature/sequence transforms + QDQ (evaluation). Hook *sites* are named
//! after Figure 5 (`attn1`, `attn1.to_out`, `attn2.to_q`, `attn2.to_out`,
//! `ffn.up_proj`, `ffn.down_proj`, …) so the Table-4 per-site ablation can
//! target them individually.

pub mod attention;
pub mod dit;
pub mod gpt;
pub mod linear;
pub mod norm;

pub use dit::{Dit, DitConfig};
pub use gpt::{Gpt, GptConfig};
pub use linear::{CaptureHook, FpHook, Linear, LinearHook};

use crate::tensor::Tensor;

/// Context threaded through a hooked forward pass.
pub struct ForwardCtx<'a> {
    pub hook: &'a dyn LinearHook,
}

impl<'a> ForwardCtx<'a> {
    pub fn fp() -> ForwardCtx<'static> {
        ForwardCtx { hook: &FpHook }
    }
}

/// Softmax over the last axis of a 2-D tensor, in place.
pub fn softmax_rows(x: &mut Tensor) {
    let d = x.cols();
    for i in 0..x.rows() {
        let row = x.row_mut(i);
        let mx = row.iter().cloned().fold(f32::MIN, f32::max);
        let mut sum = 0.0f32;
        for v in row.iter_mut() {
            *v = (*v - mx).exp();
            sum += *v;
        }
        let inv = 1.0 / sum.max(1e-20);
        for v in row.iter_mut() {
            *v *= inv;
        }
        let _ = d;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn softmax_normalizes() {
        let mut x = Tensor::randn(&[4, 8], 1);
        softmax_rows(&mut x);
        for i in 0..4 {
            let s: f32 = x.row(i).iter().sum();
            assert!((s - 1.0).abs() < 1e-5);
            assert!(x.row(i).iter().all(|&v| v >= 0.0));
        }
    }

    #[test]
    fn softmax_stable_with_large_logits() {
        let mut x = Tensor::from_vec(&[1, 3], vec![1000.0, 1001.0, 999.0]);
        softmax_rows(&mut x);
        assert!(x.all_finite());
        assert!(x.at(0, 1) > x.at(0, 0));
    }
}
