//! RMSNorm (as in LLaMA) with hand-written backward.

use crate::tensor::Tensor;

pub struct RmsNorm {
    pub gamma: Vec<f32>,
    pub ggamma: Vec<f32>,
    /// Additive per-channel offset β. Zero by default; the outlier-channel
    /// injection (Gpt::inject_outlier_channels) uses it to create the
    /// near-constant "massive activation" channels of real LLMs.
    pub beta: Vec<f32>,
    pub gbeta: Vec<f32>,
    eps: f32,
}

impl RmsNorm {
    pub fn new(d: usize) -> Self {
        RmsNorm {
            gamma: vec![1.0; d],
            ggamma: vec![0.0; d],
            beta: vec![0.0; d],
            gbeta: vec![0.0; d],
            eps: 1e-5,
        }
    }

    /// Forward, also returning the per-row inverse RMS needed by backward.
    pub fn forward(&self, x: &Tensor) -> (Tensor, Vec<f32>) {
        let d = x.cols();
        let mut out = x.clone();
        let mut inv_rms = Vec::with_capacity(x.rows());
        for i in 0..x.rows() {
            let row = out.row_mut(i);
            let ms: f32 = row.iter().map(|v| v * v).sum::<f32>() / d as f32;
            let inv = 1.0 / (ms + self.eps).sqrt();
            inv_rms.push(inv);
            for ((v, g), b) in row.iter_mut().zip(&self.gamma).zip(&self.beta) {
                *v = *v * inv * g + b;
            }
        }
        (out, inv_rms)
    }

    /// Backward. `x` is the forward input, `inv_rms` from forward.
    pub fn backward(&mut self, x: &Tensor, inv_rms: &[f32], dy: &Tensor) -> Tensor {
        let d = x.cols();
        let mut dx = Tensor::zeros(&[x.rows(), d]);
        for i in 0..x.rows() {
            let xr = x.row(i);
            let dyr = dy.row(i);
            let inv = inv_rms[i];
            // y_j = x_j · inv · γ_j + β_j with inv = (mean(x²)+eps)^{-1/2}
            // dL/dβ_j = dy_j; dL/dγ_j = dy_j · x_j · inv
            // dL/dx_j = inv·γ_j·dy_j − x_j·inv³/d · Σ_k dy_k γ_k x_k
            let mut dot = 0.0f32;
            for k in 0..d {
                dot += dyr[k] * self.gamma[k] * xr[k];
                self.ggamma[k] += dyr[k] * xr[k] * inv;
                self.gbeta[k] += dyr[k];
            }
            let coef = inv * inv * inv * dot / d as f32;
            let dxr = dx.row_mut(i);
            for j in 0..d {
                dxr[j] = inv * self.gamma[j] * dyr[j] - xr[j] * coef;
            }
        }
        dx
    }

    pub fn zero_grad(&mut self) {
        self.ggamma.fill(0.0);
        self.gbeta.fill(0.0);
    }

    pub fn visit_params(&mut self, f: &mut dyn FnMut(&mut [f32], &[f32])) {
        let g = self.ggamma.clone();
        f(&mut self.gamma, &g);
        let gb = self.gbeta.clone();
        f(&mut self.beta, &gb);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unit_rms_output() {
        let n = RmsNorm::new(8);
        let x = Tensor::randn(&[4, 8], 1).scale(5.0);
        let (y, _) = n.forward(&x);
        for i in 0..4 {
            let ms: f32 = y.row(i).iter().map(|v| v * v).sum::<f32>() / 8.0;
            assert!((ms - 1.0).abs() < 1e-3, "row {i} ms {ms}");
        }
    }

    #[test]
    fn backward_numerical() {
        let mut n = RmsNorm::new(4);
        // Non-trivial gamma.
        for (i, g) in n.gamma.iter_mut().enumerate() {
            *g = 1.0 + 0.1 * i as f32;
        }
        let x = Tensor::randn(&[3, 4], 2);
        let (y, inv) = n.forward(&x);
        let dy = y.scale(2.0); // L = Σy²
        let dx = n.backward(&x, &inv, &dy);

        let loss = |n: &RmsNorm, x: &Tensor| -> f64 { n.forward(x).0.sq_norm() };
        let eps = 1e-3f32;
        // dx check.
        for &(i, j) in &[(0usize, 0usize), (1, 2), (2, 3)] {
            let mut xp = x.clone();
            xp.set(i, j, xp.at(i, j) + eps);
            let num = (loss(&n, &xp) - loss(&n, &x)) / eps as f64;
            let ana = dx.at(i, j) as f64;
            assert!((num - ana).abs() < 0.05 * ana.abs().max(0.5), "dx[{i},{j}] num {num} ana {ana}");
        }
        // dgamma check.
        let mut n2 = RmsNorm::new(4);
        n2.gamma = n.gamma.clone();
        n2.gamma[1] += eps;
        let num = (loss(&n2, &x) - loss(&n, &x)) / eps as f64;
        let ana = n.ggamma[1] as f64;
        assert!((num - ana).abs() < 0.05 * ana.abs().max(0.5), "dγ num {num} ana {ana}");
    }
}
