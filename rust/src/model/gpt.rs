//! GPT-style causal LM: token embedding → N×(RMSNorm→MHA→RMSNorm→gated MLP)
//! → final norm → tied-embedding logits. Hand-written backward for training;
//! hooked forward for quantized evaluation (sites per Figure 5: `attn1`,
//! `attn1.to_out`, `ffn.up_proj`, `ffn.down_proj`, plus `.k`/`.v` KV sites).

use super::attention::{AttnCache, MultiHeadAttention};
use super::linear::{Linear, LinearHook};
use super::norm::RmsNorm;
use crate::tensor::{Tensor, XorShiftRng};

#[derive(Clone, Debug)]
pub struct GptConfig {
    pub vocab_size: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub n_layers: usize,
    pub d_ff: usize,
    pub max_seq: usize,
}

impl GptConfig {
    /// The four "model sizes" used for the Table-2 analogue rows.
    pub fn tiny() -> Self {
        GptConfig { vocab_size: 72, d_model: 64, n_heads: 4, n_layers: 2, d_ff: 128, max_seq: 256 }
    }
    pub fn small() -> Self {
        GptConfig { vocab_size: 72, d_model: 128, n_heads: 4, n_layers: 4, d_ff: 256, max_seq: 256 }
    }
    pub fn medium() -> Self {
        // All linear in-dims are powers of two so Hadamard feature
        // transforms (QuaRot) apply without Kronecker padding.
        GptConfig { vocab_size: 72, d_model: 128, n_heads: 4, n_layers: 6, d_ff: 256, max_seq: 256 }
    }
    pub fn wide() -> Self {
        GptConfig { vocab_size: 72, d_model: 256, n_heads: 8, n_layers: 4, d_ff: 512, max_seq: 256 }
    }
}

/// One transformer block.
pub struct Block {
    pub norm1: RmsNorm,
    pub attn: MultiHeadAttention,
    pub norm2: RmsNorm,
    pub up: Linear,
    pub gate: Linear,
    pub down: Linear,
}

/// Per-block forward cache for backward.
pub struct BlockCache {
    x: Tensor,
    n1: Tensor,
    n1_inv: Vec<f32>,
    attn: AttnCache,
    x_mid: Tensor,
    n2: Tensor,
    n2_inv: Vec<f32>,
    up_out: Tensor,
    gate_out: Tensor,
    act: Tensor,
}

fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// First-maximum argmax over a logits row (deterministic tie-break).
/// Shared with [`crate::decode`]'s greedy sampler so serial and batched
/// decode can never disagree on tie-breaking.
pub(crate) fn argmax_row(row: &[f32]) -> u32 {
    let mut best = 0usize;
    for (i, &v) in row.iter().enumerate() {
        if v > row[best] {
            best = i;
        }
    }
    best as u32
}

fn silu_grad(x: f32) -> f32 {
    let s = 1.0 / (1.0 + (-x).exp());
    s * (1.0 + x * (1.0 - s))
}

impl Block {
    fn new(cfg: &GptConfig, rng: &mut XorShiftRng) -> Self {
        Block {
            norm1: RmsNorm::new(cfg.d_model),
            attn: MultiHeadAttention::new(cfg.d_model, cfg.n_heads, true, rng),
            norm2: RmsNorm::new(cfg.d_model),
            up: Linear::new(cfg.d_model, cfg.d_ff, false, rng),
            gate: Linear::new(cfg.d_model, cfg.d_ff, false, rng),
            down: Linear::new(cfg.d_ff, cfg.d_model, false, rng),
        }
    }

    fn forward_train(&self, x: &Tensor) -> (Tensor, BlockCache) {
        let (n1, n1_inv) = self.norm1.forward(x);
        let (a, attn_cache) = self.attn.forward_train(&n1);
        let x_mid = x.add(&a);
        let (n2, n2_inv) = self.norm2.forward(&x_mid);
        let up_out = self.up.forward(&n2);
        let gate_out = self.gate.forward(&n2);
        // act = silu(gate) * up
        let act = gate_out.zip(&up_out, |g, u| silu(g) * u);
        let m = self.down.forward(&act);
        let out = x_mid.add(&m);
        (
            out,
            BlockCache { x: x.clone(), n1, n1_inv, attn: attn_cache, x_mid, n2, n2_inv, up_out, gate_out, act },
        )
    }

    /// Post-attention tail (norm2 → gated FFN → residual) shared by the
    /// hooked full-sequence and decode forwards — one body, so the two
    /// paths can never drift apart and break the fp32-cache bit-parity
    /// invariant (`tests/decode.rs`). Row-wise throughout.
    fn ffn_hooked(&self, hook: &dyn LinearHook, layer: usize, x_mid: &Tensor) -> Tensor {
        let (n2, _) = self.norm2.forward(x_mid);
        let up_out =
            hook.linear(&format!("layer{layer}.ffn.up_proj"), &n2, &self.up.w, self.up.b.as_deref());
        let gate_out = hook.linear(
            &format!("layer{layer}.ffn.gate_proj"),
            &n2,
            &self.gate.w,
            self.gate.b.as_deref(),
        );
        let act = gate_out.zip(&up_out, |g, u| silu(g) * u);
        let m = hook.linear(
            &format!("layer{layer}.ffn.down_proj"),
            &act,
            &self.down.w,
            self.down.b.as_deref(),
        );
        x_mid.add(&m)
    }

    fn forward_hooked(&self, hook: &dyn LinearHook, layer: usize, x: &Tensor) -> Tensor {
        let (n1, _) = self.norm1.forward(x);
        let a = self.attn.forward_hooked(hook, &format!("layer{layer}.attn1"), &n1);
        let x_mid = x.add(&a);
        self.ffn_hooked(hook, layer, &x_mid)
    }

    /// Incremental decode forward: like [`Block::forward_hooked`] but the
    /// attention reads/extends the layer's KV cache; `x` holds only the
    /// new tokens' hidden states.
    fn forward_decode(
        &self,
        hook: &dyn LinearHook,
        layer: usize,
        x: &Tensor,
        cache: &mut crate::kvcache::KvLayer,
    ) -> Tensor {
        let (n1, _) = self.norm1.forward(x);
        let a = self.attn.forward_decode(hook, &format!("layer{layer}.attn1"), &n1, cache);
        let x_mid = x.add(&a);
        self.ffn_hooked(hook, layer, &x_mid)
    }

    /// One synchronized decode step over independent streams: row `i` of
    /// `x` belongs to stream `i` / `caches[i]`. Attention fuses the
    /// projections across streams ([`MultiHeadAttention::forward_decode_batch`]);
    /// the FFN tail is the same shared `ffn_hooked` body, which is row-wise
    /// for any `m` — so the fp32/FpHook bit-parity argument of
    /// [`Block::forward_decode`] extends row-by-row to the batched step.
    fn forward_decode_batch(
        &self,
        hook: &dyn LinearHook,
        layer: usize,
        x: &Tensor,
        caches: &mut [&mut crate::kvcache::KvLayer],
    ) -> Tensor {
        let (n1, _) = self.norm1.forward(x);
        let a = self.attn.forward_decode_batch(hook, &format!("layer{layer}.attn1"), &n1, caches);
        let x_mid = x.add(&a);
        self.ffn_hooked(hook, layer, &x_mid)
    }

    /// Ragged decode step: stream `i` contributes `lens[i]` consecutive
    /// rows of `x` — the verification forward of speculative decode.
    /// Attention fuses projections and masks per-row absolute positions
    /// ([`MultiHeadAttention::forward_decode_ragged`]); norms and the FFN
    /// tail are row-wise, so the bit-parity argument of
    /// [`Block::forward_decode_batch`] extends row-by-row.
    fn forward_decode_ragged(
        &self,
        hook: &dyn LinearHook,
        layer: usize,
        x: &Tensor,
        lens: &[usize],
        caches: &mut [&mut crate::kvcache::KvLayer],
    ) -> Tensor {
        let (n1, _) = self.norm1.forward(x);
        let a = self.attn.forward_decode_ragged(
            hook,
            &format!("layer{layer}.attn1"),
            &n1,
            lens,
            caches,
        );
        let x_mid = x.add(&a);
        self.ffn_hooked(hook, layer, &x_mid)
    }

    fn backward(&mut self, cache: &BlockCache, dy: &Tensor) -> Tensor {
        // out = x_mid + down(act)
        let dact = self.down.backward(&cache.act, dy);
        // act = silu(gate) * up
        let dgate = dact.zip(&cache.up_out, |d, u| d * u).zip(&cache.gate_out, |du, g| du * silu_grad(g));
        let dup = dact.zip(&cache.gate_out, |d, g| d * silu(g));
        let dn2 = self.up.backward(&cache.n2, &dup).add(&self.gate.backward(&cache.n2, &dgate));
        let dx_mid_from_mlp = self.norm2.backward(&cache.x_mid, &cache.n2_inv, &dn2);
        let dx_mid = dy.add(&dx_mid_from_mlp);
        // x_mid = x + attn(n1)
        let dn1 = self.attn.backward(&cache.attn, &dx_mid);
        let dx_from_attn = self.norm1.backward(&cache.x, &cache.n1_inv, &dn1);
        dx_mid.add(&dx_from_attn)
    }

    fn zero_grad(&mut self) {
        self.norm1.zero_grad();
        self.attn.zero_grad();
        self.norm2.zero_grad();
        self.up.zero_grad();
        self.gate.zero_grad();
        self.down.zero_grad();
    }

    fn visit_params(&mut self, f: &mut dyn FnMut(&mut [f32], &[f32])) {
        self.norm1.visit_params(f);
        self.attn.visit_params(f);
        self.norm2.visit_params(f);
        self.up.visit_params(f);
        self.gate.visit_params(f);
        self.down.visit_params(f);
    }

    fn n_params(&self) -> usize {
        self.attn.n_params()
            + self.up.n_params()
            + self.gate.n_params()
            + self.down.n_params()
            + 2 * self.norm1.gamma.len()
    }
}

/// The full GPT model.
pub struct Gpt {
    pub cfg: GptConfig,
    /// Token embedding `[vocab, d_model]`; also used (tied) for logits.
    pub embed: Tensor,
    gembed: Tensor,
    /// Learned positional embedding `[max_seq, d_model]`.
    pub pos: Tensor,
    gpos: Tensor,
    pub blocks: Vec<Block>,
    pub final_norm: RmsNorm,
}

/// Full forward cache.
pub struct GptCache {
    tokens: Vec<u32>,
    h0: Tensor,
    block_caches: Vec<BlockCache>,
    hn: Tensor,
    hn_inv: Vec<f32>,
    normed: Tensor,
    /// Softmax probabilities `[s, vocab]`.
    probs: Tensor,
}

impl Gpt {
    pub fn new(cfg: GptConfig, seed: u64) -> Self {
        let mut rng = XorShiftRng::new(seed);
        let mut embed = Tensor::zeros(&[cfg.vocab_size, cfg.d_model]);
        for v in embed.data_mut() {
            *v = rng.next_gaussian() * 0.05;
        }
        let mut pos = Tensor::zeros(&[cfg.max_seq, cfg.d_model]);
        for v in pos.data_mut() {
            *v = rng.next_gaussian() * 0.02;
        }
        let blocks = (0..cfg.n_layers).map(|_| Block::new(&cfg, &mut rng)).collect();
        Gpt {
            gembed: Tensor::zeros(embed.shape()),
            gpos: Tensor::zeros(pos.shape()),
            embed,
            pos,
            blocks,
            final_norm: RmsNorm::new(cfg.d_model),
            cfg,
        }
    }

    pub fn n_params(&self) -> usize {
        self.embed.len()
            + self.pos.len()
            + self.blocks.iter().map(|b| b.n_params()).sum::<usize>()
            + self.final_norm.gamma.len()
    }

    fn embed_tokens(&self, tokens: &[u32]) -> Tensor {
        self.embed_tokens_at(tokens, 0)
    }

    /// Token + positional embedding with the positions offset by `pos0` —
    /// the decode path embeds new tokens at their absolute positions.
    fn embed_tokens_at(&self, tokens: &[u32], pos0: usize) -> Tensor {
        let d = self.cfg.d_model;
        assert!(pos0 + tokens.len() <= self.cfg.max_seq, "positions exceed max_seq");
        let mut h = Tensor::zeros(&[tokens.len(), d]);
        for (i, &t) in tokens.iter().enumerate() {
            let t = t as usize;
            assert!(t < self.cfg.vocab_size, "token {t} out of vocab");
            for j in 0..d {
                let v = self.embed.at(t, j) + self.pos.at(pos0 + i, j);
                h.set(i, j, v);
            }
        }
        h
    }

    /// Logits for a token sequence (hooked; pass [`super::FpHook`] for FP).
    pub fn logits_hooked(&self, hook: &dyn LinearHook, tokens: &[u32]) -> Tensor {
        assert!(tokens.len() <= self.cfg.max_seq);
        let mut h = self.embed_tokens(tokens);
        for (l, b) in self.blocks.iter().enumerate() {
            h = b.forward_hooked(hook, l, &h);
        }
        let (hn, _) = self.final_norm.forward(&h);
        // Tied embedding head — the `head` site (kept FP, like the paper
        // which only quantizes linears inside transformer blocks). The
        // kernel profiler attributes it to `logits` rather than the
        // surrounding phase.
        let _site = crate::obs::site_guard(crate::obs::KernelSite::Logits);
        crate::tensor::matmul_transb(&hn, &self.embed)
    }

    /// Incremental hooked forward: consume `tokens` starting at the
    /// cache's current position, appending every new token's K/V to
    /// `cache`, and return the logits rows for the new tokens only.
    ///
    /// Call once with the whole prompt (prefill), or repeatedly with
    /// chunks — the split does not change the result. With an fp32 cache
    /// and [`super::FpHook`] the returned rows are bit-identical to
    /// [`Gpt::logits_hooked`] on the same prefix at any thread count
    /// (every kernel on the path is row-wise; `tests/decode.rs` pins it).
    ///
    /// Tokens embed at [`crate::kvcache::KvCache::pos_next`] — their rank
    /// in the *resident* sequence. Without eviction that is exactly the
    /// absolute position (the parity setting above); under a sliding
    /// window it stays below [`crate::kvcache::KvCacheConfig::resident_bound`],
    /// so the fixed `max_seq` positional table serves an unbounded logical
    /// sequence as long as callers chunk their prompts to fit
    /// (`pos_next + chunk ≤ max_seq`, as [`crate::decode::DecodeEngine`]
    /// does at admission). Windowed callers should also keep each chunk
    /// ≤ the window: a chunk's K/V append (and eviction) precedes its
    /// attention, so a wider chunk would evict its own middle before any
    /// query attends it — the engine caps admission chunks accordingly.
    pub fn prefill(
        &self,
        hook: &dyn LinearHook,
        tokens: &[u32],
        cache: &mut crate::kvcache::KvCache,
    ) -> Tensor {
        assert!(!tokens.is_empty(), "prefill needs at least one token");
        assert_eq!(cache.n_layers(), self.cfg.n_layers, "cache layer count mismatch");
        let pos0 = cache.pos_next();
        assert!(pos0 + tokens.len() <= self.cfg.max_seq, "sequence exceeds max_seq");
        let mut h = self.embed_tokens_at(tokens, pos0);
        for (l, b) in self.blocks.iter().enumerate() {
            h = b.forward_decode(hook, l, &h, cache.layer_mut(l));
        }
        let (hn, _) = self.final_norm.forward(&h);
        let _site = crate::obs::site_guard(crate::obs::KernelSite::Logits);
        crate::tensor::matmul_transb(&hn, &self.embed)
    }

    /// One decode step: append a single token, return its `1×vocab`
    /// logits row.
    pub fn decode_step(
        &self,
        hook: &dyn LinearHook,
        token: u32,
        cache: &mut crate::kvcache::KvCache,
    ) -> Tensor {
        self.prefill(hook, &[token], cache)
    }

    /// One synchronized decode step across `tokens.len()` independent
    /// streams: `tokens[i]` is appended to `caches[i]` at that stream's
    /// own position, and row `i` of the returned `[n_streams × vocab]`
    /// logits is stream `i`'s next-token distribution.
    ///
    /// This is the fused hot path of [`crate::decode::DecodeEngine`]:
    /// every linear on the step — q/k/v/out projections, the gated FFN,
    /// the tied-embedding head — runs once over the stacked
    /// `[n_streams × d_model]` activation instead of once per stream,
    /// while attention and the KV appends stay per-stream (each stream's
    /// causal history is its own). Embeddings use per-row positions, so
    /// streams may sit at arbitrary, different offsets. With an fp32
    /// cache and [`super::FpHook`] each row is bit-identical to a serial
    /// [`Gpt::decode_step`] on that stream alone (row-wise kernels;
    /// `tests/decode.rs`).
    pub fn decode_step_batch(
        &self,
        hook: &dyn LinearHook,
        tokens: &[u32],
        caches: &mut [&mut crate::kvcache::KvCache],
    ) -> Tensor {
        let slices: Vec<&[u32]> = tokens.iter().map(std::slice::from_ref).collect();
        self.decode_step_batch_ragged(hook, &slices, caches)
    }

    /// Ragged decode step across independent streams: `tokens[i]` (≥ 1
    /// tokens, oldest first — the pending token plus speculative drafts)
    /// is appended to `caches[i]`, and the returned `[Σ lens × vocab]`
    /// logits hold stream `i`'s rows consecutively, one per appended
    /// token. The verification GEMM of speculative decode
    /// ([`crate::decode::DecodeEngine`], DESIGN.md §18):
    /// [`Gpt::decode_step_batch`] is the `lens = [1, 1, …]` degenerate
    /// case.
    ///
    /// Row `j` of stream `i` embeds at `pos_next() + j` — valid because
    /// the engine caps draft depth so no flush or eviction beyond the
    /// pending token's own fires mid-step
    /// ([`crate::kvcache::KvCache::spec_headroom`]). With an fp32 cache
    /// and [`super::FpHook`], each stream's rows are bit-identical to
    /// serial [`Gpt::decode_step`] calls feeding the same tokens, at any
    /// thread count and batch composition (`tests/speculative.rs`).
    pub fn decode_step_batch_ragged(
        &self,
        hook: &dyn LinearHook,
        tokens: &[&[u32]],
        caches: &mut [&mut crate::kvcache::KvCache],
    ) -> Tensor {
        let n = tokens.len();
        assert!(n >= 1, "batched decode step needs at least one stream");
        assert_eq!(n, caches.len(), "one cache per stream");
        let d = self.cfg.d_model;
        let m: usize = tokens.iter().map(|t| t.len()).sum();
        let mut h = Tensor::zeros(&[m, d]);
        let mut lens = Vec::with_capacity(n);
        let mut r = 0usize;
        for (i, toks) in tokens.iter().enumerate() {
            assert!(!toks.is_empty(), "stream {i} needs at least its pending token");
            assert_eq!(caches[i].n_layers(), self.cfg.n_layers, "cache layer count mismatch");
            // Resident rank, like `prefill`: bounded under a window
            // policy, the absolute position otherwise.
            let pos0 = caches[i].pos_next();
            assert!(
                pos0 + toks.len() <= self.cfg.max_seq,
                "stream {i} position {pos0}+{} exceeds max_seq",
                toks.len()
            );
            for (j, &tok) in toks.iter().enumerate() {
                let t = tok as usize;
                assert!(t < self.cfg.vocab_size, "token {t} out of vocab");
                for c in 0..d {
                    h.set(r + j, c, self.embed.at(t, c) + self.pos.at(pos0 + j, c));
                }
            }
            lens.push(toks.len());
            r += toks.len();
        }
        for (l, b) in self.blocks.iter().enumerate() {
            let mut layers: Vec<&mut crate::kvcache::KvLayer> =
                caches.iter_mut().map(|c| c.layer_mut(l)).collect();
            h = b.forward_decode_ragged(hook, l, &h, &lens, &mut layers);
        }
        let (hn, _) = self.final_norm.forward(&h);
        let _site = crate::obs::site_guard(crate::obs::KernelSite::Logits);
        crate::tensor::matmul_transb(&hn, &self.embed)
    }

    /// Greedy autoregressive generation: prefill `prompt`, then decode
    /// `n_new` tokens (argmax at every step), returning the generated ids.
    /// `prompt.len() + n_new` must fit `max_seq`.
    pub fn generate_greedy(
        &self,
        hook: &dyn LinearHook,
        prompt: &[u32],
        n_new: usize,
        cache: &mut crate::kvcache::KvCache,
    ) -> Vec<u32> {
        let logits = self.prefill(hook, prompt, cache);
        let mut out = Vec::with_capacity(n_new);
        if n_new == 0 {
            return out;
        }
        let mut next = argmax_row(logits.row(logits.rows() - 1));
        out.push(next);
        while out.len() < n_new {
            let l = self.decode_step(hook, next, cache);
            next = argmax_row(l.row(0));
            out.push(next);
        }
        out
    }

    /// Training forward: returns (mean cross-entropy over next-token
    /// prediction, cache). Targets are `tokens[1..]`.
    pub fn forward_loss(&self, tokens: &[u32]) -> (f64, GptCache) {
        let s = tokens.len();
        assert!(s >= 2);
        let h0 = self.embed_tokens(tokens);
        let mut h = h0.clone();
        let mut block_caches = Vec::with_capacity(self.blocks.len());
        for b in &self.blocks {
            let (nh, c) = b.forward_train(&h);
            h = nh;
            block_caches.push(c);
        }
        let (normed, hn_inv) = self.final_norm.forward(&h);
        let mut logits = crate::tensor::matmul_transb(&normed, &self.embed);
        super::softmax_rows(&mut logits);
        let probs = logits;
        // CE over positions 0..s-1 predicting tokens[i+1].
        let mut loss = 0.0f64;
        for i in 0..s - 1 {
            let t = tokens[i + 1] as usize;
            loss -= (probs.at(i, t).max(1e-12) as f64).ln();
        }
        loss /= (s - 1) as f64;
        (
            loss,
            GptCache { tokens: tokens.to_vec(), h0, block_caches, hn: h, hn_inv, normed, probs },
        )
    }

    /// Backward from the cached forward; accumulates all gradients.
    pub fn backward(&mut self, cache: &GptCache) {
        let s = cache.tokens.len();
        let scale = 1.0 / (s - 1) as f32;
        // dlogits = (probs − onehot)/ (s−1) for rows 0..s−2, zero for last.
        let mut dlogits = cache.probs.clone();
        for i in 0..s {
            if i < s - 1 {
                let t = cache.tokens[i + 1] as usize;
                let row = dlogits.row_mut(i);
                row[t] -= 1.0;
                for v in row.iter_mut() {
                    *v *= scale;
                }
            } else {
                dlogits.row_mut(i).fill(0.0);
            }
        }
        // logits = normed @ embedᵀ ⇒ dnormed = dlogits @ embed;
        // dembed += dlogitsᵀ @ normed.
        let dnormed = crate::tensor::matmul(&dlogits, &self.embed);
        let dembed_head = crate::tensor::matmul(&dlogits.transpose(), &cache.normed);
        self.gembed = self.gembed.add(&dembed_head);

        let mut dh = self.final_norm.backward(&cache.hn, &cache.hn_inv, &dnormed);
        for (b, c) in self.blocks.iter_mut().zip(&cache.block_caches).rev() {
            dh = b.backward(c, &dh);
        }
        // Embedding + positional grads.
        for (i, &t) in cache.tokens.iter().enumerate() {
            let t = t as usize;
            for j in 0..self.cfg.d_model {
                let g = dh.at(i, j);
                self.gembed.set(t, j, self.gembed.at(t, j) + g);
                self.gpos.set(i, j, self.gpos.at(i, j) + g);
            }
        }
        let _ = &cache.h0;
    }

    pub fn zero_grad(&mut self) {
        self.gembed.data_mut().fill(0.0);
        self.gpos.data_mut().fill(0.0);
        for b in &mut self.blocks {
            b.zero_grad();
        }
        self.final_norm.zero_grad();
    }

    pub fn visit_params(&mut self, f: &mut dyn FnMut(&mut [f32], &[f32])) {
        let ge = self.gembed.data().to_vec();
        f(self.embed.data_mut(), &ge);
        let gp = self.gpos.data().to_vec();
        f(self.pos.data_mut(), &gp);
        for b in &mut self.blocks {
            b.visit_params(f);
        }
        self.final_norm.visit_params(f);
    }

    /// Function-preserving outlier-channel injection.
    ///
    /// Real LLMs exhibit per-channel "massive activations" (Sun et al.
    /// 2024) that make low-bit activation quantization catastrophic — the
    /// regime Table 2 studies. Tiny models trained on a synthetic corpus
    /// lack them, so we create them *exactly function-preservingly* (the
    /// inverse of SmoothQuant's rebalancing): scale RMSNorm gains (and V/up
    /// projection columns) by `scale` on `count` channels and divide the
    /// consuming weight rows by `scale`. FP outputs are bit-identical up
    /// to float associativity; quantized behaviour becomes realistic.
    pub fn inject_outlier_channels(&mut self, count: usize, scale: f32) {
        let d = self.cfg.d_model;
        let pick = |n: usize| -> Vec<usize> {
            let stride = (n / count.max(1)).max(1);
            (0..count).map(|k| (k * stride + stride / 2) % n).collect()
        };
        // Add a large near-constant offset c·e_j at each norm output
        // (massive activations are approximately token-constant — the
        // property STaMP's sequence transform compresses), and subtract
        // the exact compensation c·W[j,:] from each consumer's bias.
        fn compensate(lin: &mut Linear, j: usize, c: f32) {
            let comp: Vec<f32> = lin.w.row(j).iter().map(|&w| -c * w).collect();
            match &mut lin.b {
                Some(bias) => {
                    for (b, v) in bias.iter_mut().zip(&comp) {
                        *b += v;
                    }
                }
                None => {
                    lin.b = Some(comp);
                    lin.gb = Some(vec![0.0; lin.w.cols()]);
                }
            }
        }
        let ch_d = pick(d);
        for blk in &mut self.blocks {
            for (idx, &j) in ch_d.iter().enumerate() {
                let c = scale * if idx % 2 == 0 { 1.0 } else { -1.0 };
                blk.norm1.beta[j] += c;
                compensate(&mut blk.attn.wq, j, c);
                compensate(&mut blk.attn.wk, j, c);
                compensate(&mut blk.attn.wv, j, c);
                blk.norm2.beta[j] += c;
                compensate(&mut blk.up, j, c);
                compensate(&mut blk.gate, j, c);
            }
        }
    }

    /// Iterate `f` over every block-internal weight matrix with its site
    /// name — used by weight-quantizing baselines (RTN etc.).
    pub fn visit_weights_mut(&mut self, f: &mut dyn FnMut(&str, &mut Tensor)) {
        for (l, b) in self.blocks.iter_mut().enumerate() {
            f(&format!("layer{l}.attn1.wq"), &mut b.attn.wq.w);
            f(&format!("layer{l}.attn1.wk"), &mut b.attn.wk.w);
            f(&format!("layer{l}.attn1.wv"), &mut b.attn.wv.w);
            f(&format!("layer{l}.attn1.to_out"), &mut b.attn.wo.w);
            f(&format!("layer{l}.ffn.up_proj"), &mut b.up.w);
            f(&format!("layer{l}.ffn.gate_proj"), &mut b.gate.w);
            f(&format!("layer{l}.ffn.down_proj"), &mut b.down.w);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::FpHook;

    #[test]
    fn forward_shapes_and_finite() {
        let gpt = Gpt::new(GptConfig::tiny(), 1);
        let tokens: Vec<u32> = (0..16).map(|i| i % 72).collect();
        let logits = gpt.logits_hooked(&FpHook, &tokens);
        assert_eq!(logits.shape(), &[16, 72]);
        assert!(logits.all_finite());
    }

    #[test]
    fn loss_near_uniform_at_init() {
        let gpt = Gpt::new(GptConfig::tiny(), 2);
        let tokens: Vec<u32> = (0..32).map(|i| (i * 7) % 72).collect();
        let (loss, _) = gpt.forward_loss(&tokens);
        let uniform = (72f64).ln();
        assert!((loss - uniform).abs() < 0.5, "loss {loss} vs uniform {uniform}");
    }

    #[test]
    fn backward_decreases_loss_one_sgd_step() {
        let mut gpt = Gpt::new(GptConfig::tiny(), 3);
        let tokens: Vec<u32> = (0..32).map(|i| (i * 3 + 1) % 72).collect();
        let (l0, cache) = gpt.forward_loss(&tokens);
        gpt.zero_grad();
        gpt.backward(&cache);
        let lr = 0.1f32;
        gpt.visit_params(&mut |p, g| {
            for (pi, gi) in p.iter_mut().zip(g) {
                *pi -= lr * gi;
            }
        });
        let (l1, _) = gpt.forward_loss(&tokens);
        assert!(l1 < l0, "loss did not decrease: {l0} -> {l1}");
    }

    #[test]
    fn grad_numerical_embedding() {
        let mut gpt = Gpt::new(GptConfig { n_layers: 1, ..GptConfig::tiny() }, 4);
        let tokens: Vec<u32> = vec![1, 5, 9, 5, 1, 3];
        let (_, cache) = gpt.forward_loss(&tokens);
        gpt.zero_grad();
        gpt.backward(&cache);
        let ana = gpt.gembed.at(5, 3) as f64;
        let eps = 1e-3f32;
        let l0 = gpt.forward_loss(&tokens).0;
        gpt.embed.set(5, 3, gpt.embed.at(5, 3) + eps);
        let l1 = gpt.forward_loss(&tokens).0;
        let num = (l1 - l0) / eps as f64;
        assert!((num - ana).abs() < 0.05 * ana.abs().max(0.1), "num {num} ana {ana}");
    }

    #[test]
    fn hooked_fp_matches_train_path_logits() {
        let gpt = Gpt::new(GptConfig::tiny(), 5);
        let tokens: Vec<u32> = (0..24).map(|i| (i * 5) % 72).collect();
        let logits = gpt.logits_hooked(&FpHook, &tokens);
        // Recompute through forward_loss's internals: probs row argmax equal.
        let (_, cache) = gpt.forward_loss(&tokens);
        for i in 0..tokens.len() {
            let a = logits.row(i).iter().cloned().fold(f32::MIN, f32::max);
            let ai = logits.row(i).iter().position(|&v| v == a).unwrap();
            let p = cache.probs.row(i).iter().cloned().fold(f32::MIN, f32::max);
            let pi = cache.probs.row(i).iter().position(|&v| v == p).unwrap();
            assert_eq!(ai, pi, "argmax mismatch at {i}");
        }
    }

    #[test]
    fn outlier_injection_preserves_function() {
        let mut gpt = Gpt::new(GptConfig::tiny(), 9);
        let tokens: Vec<u32> = (0..48).map(|i| ((i * 7 + 2) % 70) as u32).collect();
        let before = gpt.logits_hooked(&FpHook, &tokens);
        gpt.inject_outlier_channels(4, 30.0);
        let after = gpt.logits_hooked(&FpHook, &tokens);
        let rel = before.max_abs_diff(&after) / before.abs_max().max(1e-6);
        assert!(rel < 1e-3, "function changed: rel {rel}");
        // And the activations now have outlier channels.
        let hook = crate::model::CaptureHook::with_filter("ffn.up_proj");
        let _ = gpt.logits_hooked(&hook, &tokens);
        let acts = hook.take().remove("layer0.ffn.up_proj").unwrap();
        let absmax = crate::stats::channel_absmax(&acts[0]);
        let mut sorted = absmax.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = sorted[sorted.len() / 2];
        let top = sorted[sorted.len() - 1];
        assert!(top > 10.0 * median, "no outliers: top {top} median {median}");
    }

    #[test]
    fn prefill_rows_match_full_forward_bit_for_bit() {
        let gpt = Gpt::new(GptConfig::tiny(), 7);
        let tokens: Vec<u32> = (0..20).map(|i| ((i * 11 + 2) % 70) as u32).collect();
        let full = gpt.logits_hooked(&FpHook, &tokens);
        let mut cache = crate::kvcache::KvCache::fp32(gpt.cfg.n_layers);
        let pre = gpt.prefill(&FpHook, &tokens, &mut cache);
        assert_eq!(pre, full, "one-shot prefill must equal the full forward");
        assert_eq!(cache.len(), 20);
    }

    #[test]
    fn greedy_decode_matches_full_forward_greedy() {
        // Greedy continuation via decode_step must pick exactly the tokens
        // a repeated full-sequence forward would pick (fp32 cache parity).
        let gpt = Gpt::new(GptConfig::tiny(), 8);
        let prompt: Vec<u32> = vec![3, 17, 41, 5];
        let n_new = 12;
        let mut cache = crate::kvcache::KvCache::fp32(gpt.cfg.n_layers);
        let got = gpt.generate_greedy(&FpHook, &prompt, n_new, &mut cache);
        // Oracle: re-run the whole sequence through logits_hooked per step.
        let mut seq = prompt.clone();
        let mut want = Vec::new();
        for _ in 0..n_new {
            let logits = gpt.logits_hooked(&FpHook, &seq);
            let row = logits.row(logits.rows() - 1);
            let mut best = 0usize;
            for (i, &v) in row.iter().enumerate() {
                if v > row[best] {
                    best = i;
                }
            }
            want.push(best as u32);
            seq.push(best as u32);
        }
        assert_eq!(got, want, "greedy decode must match the full-forward oracle");
        // The final generated token is returned but never fed back.
        assert_eq!(cache.len(), prompt.len() + n_new - 1);
    }

    #[test]
    fn batched_decode_step_bit_identical_to_serial_steps() {
        // Streams at ragged positions: one fused step equals each
        // stream's own serial decode_step, bit for bit, and advances the
        // caches identically.
        let gpt = Gpt::new(GptConfig::tiny(), 12);
        let prompts: [&[u32]; 3] = [&[3, 17, 41], &[9], &[5, 5, 60, 2, 31]];
        let mut serial: Vec<crate::kvcache::KvCache> = Vec::new();
        let mut batched: Vec<crate::kvcache::KvCache> = Vec::new();
        let mut feed = Vec::new();
        for p in prompts {
            let mut sc = crate::kvcache::KvCache::fp32(gpt.cfg.n_layers);
            let logits = gpt.prefill(&FpHook, p, &mut sc);
            let mut bc = crate::kvcache::KvCache::fp32(gpt.cfg.n_layers);
            let _ = gpt.prefill(&FpHook, p, &mut bc);
            feed.push(argmax_row(logits.row(logits.rows() - 1)));
            serial.push(sc);
            batched.push(bc);
        }
        let mut refs: Vec<&mut crate::kvcache::KvCache> = batched.iter_mut().collect();
        let fused = gpt.decode_step_batch(&FpHook, &feed, &mut refs);
        assert_eq!(fused.shape(), &[3, gpt.cfg.vocab_size]);
        for (i, sc) in serial.iter_mut().enumerate() {
            let want = gpt.decode_step(&FpHook, feed[i], sc);
            assert_eq!(fused.row(i), want.row(0), "stream {i}");
        }
        for (i, (s, b)) in serial.iter().zip(&batched).enumerate() {
            assert_eq!(s.len(), b.len(), "stream {i} cache length");
            assert_eq!(
                s.layer(0).k.gather(),
                b.layer(0).k.gather(),
                "stream {i} cache content"
            );
        }
    }

    #[test]
    fn ragged_decode_step_bit_identical_to_serial_steps() {
        // Streams contributing 3 / 1 / 2 tokens in one ragged step (the
        // speculative verification shape): every logits row must equal
        // the stream's own serial decode_step on that token, bit for
        // bit, and the caches must advance identically.
        let gpt = Gpt::new(GptConfig::tiny(), 14);
        let prompts: [&[u32]; 3] = [&[3, 17, 41], &[9], &[5, 5, 60, 2, 31]];
        let feeds: [&[u32]; 3] = [&[7, 11, 13], &[2], &[44, 8]];
        let mut serial: Vec<crate::kvcache::KvCache> = Vec::new();
        let mut ragged: Vec<crate::kvcache::KvCache> = Vec::new();
        for p in prompts {
            let mut sc = crate::kvcache::KvCache::fp32(gpt.cfg.n_layers);
            let _ = gpt.prefill(&FpHook, p, &mut sc);
            let mut rc = crate::kvcache::KvCache::fp32(gpt.cfg.n_layers);
            let _ = gpt.prefill(&FpHook, p, &mut rc);
            serial.push(sc);
            ragged.push(rc);
        }
        let mut refs: Vec<&mut crate::kvcache::KvCache> = ragged.iter_mut().collect();
        let fused = gpt.decode_step_batch_ragged(&FpHook, &feeds, &mut refs);
        assert_eq!(fused.shape(), &[6, gpt.cfg.vocab_size]);
        let mut r = 0usize;
        for (i, toks) in feeds.iter().enumerate() {
            for &t in toks.iter() {
                let want = gpt.decode_step(&FpHook, t, &mut serial[i]);
                assert_eq!(fused.row(r), want.row(0), "stream {i} row {r}");
                r += 1;
            }
        }
        for (i, (s, b)) in serial.iter().zip(&ragged).enumerate() {
            assert_eq!(s.len(), b.len(), "stream {i} cache length");
            assert_eq!(s.layer(0).k.gather(), b.layer(0).k.gather(), "stream {i} cache content");
        }
    }

    #[test]
    fn param_count_sane() {
        let gpt = Gpt::new(GptConfig::small(), 6);
        let n = gpt.n_params();
        // 4 layers × (4·128² attn + 3·128·256 mlp) + embeddings.
        assert!(n > 500_000 && n < 1_500_000, "n_params {n}");
    }
}
