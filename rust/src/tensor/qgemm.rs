//! Integer GEMM over bit-packed quantized operands.
//!
//! `qgemm(a, w)` multiplies a packed activation matrix `a` (`m×k`,
//! [`QTensor`]) by a packed weight `w` stored **transposed** (`n×k`, one
//! row per output channel — the layout [`crate::baselines`] produces, and
//! the same convention as [`super::matmul_transb`]), returning the f32
//! product `A · Wᵀ`.
//!
//! The kernel never dequantizes element-by-element. With
//! `a[i][p] = (qa − za)·sa` and `w[j][p] = (qw − zw)·sw` (params constant
//! over a group), each output element decomposes per *segment* — the joint
//! refinement of the two operands' group partitions along `k` — as
//!
//! ```text
//! Σ_p a·w = sa·sw · ( Σ qa·qw − za·Σ qw − zw·Σ qa + len·za·zw )
//! ```
//!
//! so the hot loop is a pure u8×u8 dot product accumulated in `i32`
//! (which autovectorizes to widening integer multiply-adds), with the
//! scale/zero folding applied once per segment in f64. `Σ qw` per weight
//! row/segment is precomputed once per call; `Σ qa` once per activation
//! row. Parallelism mirrors [`super::matmul`]: contiguous row-chunks of
//! the output via [`crate::parallel`], each worker owning a disjoint
//! slice, so results are bit-identical at any thread count.

use super::Tensor;
use crate::parallel;
use crate::quant::QTensor;

/// One maximal run of `k` over which both operands' quantization
/// parameters are constant.
struct Seg {
    start: usize,
    end: usize,
    a_group: usize,
    w_group: usize,
}

/// Joint segmentation of `0..k` by the two group lengths.
fn segments(k: usize, a_blk: usize, w_blk: usize) -> Vec<Seg> {
    let mut out = Vec::new();
    let mut p = 0usize;
    while p < k {
        let a_group = p / a_blk;
        let w_group = p / w_blk;
        let end = ((a_group + 1) * a_blk).min((w_group + 1) * w_blk).min(k);
        out.push(Seg { start: p, end, a_group, w_group });
        p = end;
    }
    out
}

/// u8×u8 dot product in i32. Codes are ≤ 255, so the accumulator is safe
/// for `k ≤ 32768` (asserted by [`qgemm`]).
#[inline]
fn dot_codes(a: &[u8], b: &[u8]) -> i32 {
    let mut acc = 0i32;
    for (&x, &y) in a.iter().zip(b) {
        acc += x as i32 * y as i32;
    }
    acc
}

#[inline]
fn sum_codes(a: &[u8]) -> i32 {
    let mut acc = 0i32;
    for &x in a {
        acc += x as i32;
    }
    acc
}

/// `a (m×k, packed) · w (n×k, packed, transposed weight) -> m×n` f32, with
/// i32 integer accumulation and per-segment scale/zero folding in f64.
///
/// Supports every combination the quantizers produce: mixed per-row bit
/// widths (4/8) on either operand, and per-tensor / per-token / per-block
/// grouping on either side (group partitions need not align — the joint
/// segmentation handles, say, per-token activations against block-64
/// weights).
pub fn qgemm(a: &QTensor, w: &QTensor) -> Tensor {
    let (m, k) = (a.rows(), a.cols());
    let (n, k2) = (w.rows(), w.cols());
    assert_eq!(k, k2, "qgemm inner-dim mismatch: {m}x{k} @ ({n}x{k2})ᵀ");
    assert!(k <= 32_768, "qgemm i32 accumulators overflow beyond k = 32768 (got {k})");
    let mut out = Tensor::zeros(&[m, n]);
    if m == 0 || n == 0 || k == 0 {
        return out;
    }
    let t0 = crate::obs::kernel_timer();

    let segs = segments(k, a.group_len(), w.group_len());
    let nseg = segs.len();

    // Unpack the weight codes once (n×k u8 — ¼ the f32 weight's bytes) and
    // precompute per-row, per-segment code sums; both amortize over all m
    // activation rows.
    let mut wq = vec![0u8; n * k];
    parallel::for_each_chunk_mut(&mut wq, n, k, |_, (r0, _), chunk| {
        for (local, row) in chunk.chunks_mut(k).enumerate() {
            w.unpack_row_into(r0 + local, row);
        }
    });
    let mut wsums = vec![0i32; n * nseg];
    for (j, srow) in wsums.chunks_mut(nseg).enumerate() {
        let row = &wq[j * k..(j + 1) * k];
        for (si, seg) in segs.iter().enumerate() {
            srow[si] = sum_codes(&row[seg.start..seg.end]);
        }
    }

    let od = out.data_mut();
    let row_kernel = |chunk: &mut [f32], r0: usize, r1: usize| {
        let mut arow = vec![0u8; k];
        let mut asums = vec![0i32; nseg];
        for i in r0..r1 {
            a.unpack_row_into(i, &mut arow);
            for (si, seg) in segs.iter().enumerate() {
                asums[si] = sum_codes(&arow[seg.start..seg.end]);
            }
            let ap = a.row_params(i);
            let orow = &mut chunk[(i - r0) * n..(i - r0 + 1) * n];
            for (j, o) in orow.iter_mut().enumerate() {
                let wrow = &wq[j * k..(j + 1) * k];
                let wp = w.row_params(j);
                let wsum_row = &wsums[j * nseg..(j + 1) * nseg];
                let mut acc = 0.0f64;
                for (si, seg) in segs.iter().enumerate() {
                    let dot = dot_codes(&arow[seg.start..seg.end], &wrow[seg.start..seg.end]);
                    let pa = ap[seg.a_group];
                    let pw = wp[seg.w_group];
                    let (za, zw) = (pa.zero as f64, pw.zero as f64);
                    let len = (seg.end - seg.start) as f64;
                    acc += pa.scale as f64
                        * pw.scale as f64
                        * (dot as f64 - za * wsum_row[si] as f64 - zw * asums[si] as f64
                            + len * za * zw);
                }
                *o = acc as f32;
            }
        }
    };
    // Same small-m fast path as `matmul`: decode-shaped products (a few
    // activation rows, each individually cheap) run the row loop on the
    // caller's thread instead of paying one spawn per worker for one row
    // per worker.
    if super::matmul::gemm_small_m_serial(m, k, n) {
        row_kernel(od, 0, m);
    } else {
        parallel::for_row_chunks(od, m, n, m.saturating_mul(n).saturating_mul(k), row_kernel);
    }
    crate::obs::kernel_done(t0, crate::obs::KernelKind::Qgemm, super::matmul::gemm_ops(m, n, k));
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{quantize_dequantize_rows, BitAllocation, Granularity};

    /// The QDQ oracle: simulated-quantization matmul against the packed
    /// integer product, tolerant only of f32-accumulation differences.
    fn oracle(
        x: &Tensor,
        wt: &Tensor, // n×k, same transposed layout qgemm consumes
        abits: &BitAllocation,
        agran: Granularity,
        wbits: &BitAllocation,
        wgran: Granularity,
    ) -> Tensor {
        let xq = quantize_dequantize_rows(x, abits, agran);
        let wq = quantize_dequantize_rows(wt, wbits, wgran);
        super::super::matmul_transb(&xq, &wq)
    }

    fn assert_close(got: &Tensor, want: &Tensor, label: &str) {
        let tol = 1e-3 * want.abs_max().max(1.0);
        let diff = got.max_abs_diff(want);
        assert!(diff <= tol, "{label}: diff {diff} > tol {tol}");
    }

    #[test]
    fn matches_oracle_w4a4() {
        let x = Tensor::randn(&[12, 32], 1);
        let wt = Tensor::randn(&[9, 32], 2);
        let ab = BitAllocation::uniform(4);
        let wb = BitAllocation::uniform(4);
        let qa = QTensor::quantize(&x, &ab, Granularity::PerToken);
        let qw = QTensor::quantize(&wt, &wb, Granularity::PerToken);
        let got = qgemm(&qa, &qw);
        let want = oracle(&x, &wt, &ab, Granularity::PerToken, &wb, Granularity::PerToken);
        assert_eq!(got.shape(), &[12, 9]);
        assert_close(&got, &want, "w4a4");
    }

    #[test]
    fn matches_oracle_mixed_rows_and_blocks() {
        // Two-level mixed activation rows against block-grouped weights:
        // the segment partitions deliberately misalign (row groups of 24
        // vs weight blocks of 16 over k=48).
        let x = Tensor::randn(&[20, 48], 3);
        let wt = Tensor::randn(&[7, 48], 4);
        let ab = BitAllocation::two_level(6, 8, 4);
        let wb = BitAllocation::uniform(8);
        let agran = Granularity::PerBlock { block: 24 };
        let wgran = Granularity::PerBlock { block: 16 };
        let got = qgemm(&QTensor::quantize(&x, &ab, agran), &QTensor::quantize(&wt, &wb, wgran));
        let want = oracle(&x, &wt, &ab, agran, &wb, wgran);
        assert_close(&got, &want, "mixed+blocks");
    }

    #[test]
    fn matches_oracle_per_tensor() {
        let x = Tensor::randn(&[8, 16], 5);
        let wt = Tensor::randn(&[5, 16], 6);
        let ab = BitAllocation::two_level(2, 8, 4);
        let wb = BitAllocation::uniform(4);
        let got = qgemm(
            &QTensor::quantize(&x, &ab, Granularity::PerTensor),
            &QTensor::quantize(&wt, &wb, Granularity::PerToken),
        );
        let want = oracle(&x, &wt, &ab, Granularity::PerTensor, &wb, Granularity::PerToken);
        assert_close(&got, &want, "per-tensor");
    }

    #[test]
    fn parallel_path_is_bit_identical_to_serial() {
        // Big enough that m·n·k clears the fork threshold. The serial
        // reference runs on this thread via the kernel-serial flag.
        let x = Tensor::randn(&[96, 80], 7);
        let wt = Tensor::randn(&[72, 80], 8);
        let qa = QTensor::quantize(&x, &BitAllocation::two_level(16, 8, 4), Granularity::PerToken);
        let qw = QTensor::quantize(&wt, &BitAllocation::uniform(4), Granularity::PerBlock { block: 16 });
        let threaded = qgemm(&qa, &qw);
        crate::parallel::set_kernel_serial(true);
        let serial = qgemm(&qa, &qw);
        crate::parallel::set_kernel_serial(false);
        assert_eq!(threaded, serial, "qgemm must not depend on thread count");
    }

    #[test]
    fn small_m_fast_path_matches_oracle_and_larger_batch() {
        // Decode-shaped: a handful of activation rows against a wide
        // packed weight. The serial fast path must agree with the oracle
        // and be row-for-row identical to the same rows inside a larger
        // (dispatch-eligible) product.
        let (k, n) = (96usize, 640usize);
        let m_small = super::super::matmul::GEMM_SERIAL_MAX_ROWS;
        let x = Tensor::randn(&[4 * m_small, k], 11);
        let wt = Tensor::randn(&[n, k], 12);
        let ab = BitAllocation::two_level(2, 8, 4);
        let wb = BitAllocation::uniform(4);
        let qa_big = QTensor::quantize(&x, &ab, Granularity::PerToken);
        let qa_small =
            QTensor::quantize(&x.slice_rows(0, m_small), &ab, Granularity::PerToken);
        let qw = QTensor::quantize(&wt, &wb, Granularity::PerToken);
        let big = qgemm(&qa_big, &qw);
        let small = qgemm(&qa_small, &qw);
        for i in 0..m_small {
            assert_eq!(small.row(i), big.row(i), "row {i}");
        }
        let want = oracle(
            &x.slice_rows(0, m_small),
            &wt,
            &ab,
            Granularity::PerToken,
            &wb,
            Granularity::PerToken,
        );
        assert_close(&small, &want, "small-m");
    }

    #[test]
    fn segments_cover_k_exactly_once() {
        for &(k, a_blk, w_blk) in &[(48usize, 24usize, 16usize), (17, 17, 4), (64, 64, 64), (10, 3, 7)] {
            let segs = segments(k, a_blk, w_blk);
            let mut cursor = 0;
            for s in &segs {
                assert_eq!(s.start, cursor);
                assert!(s.end > s.start);
                assert_eq!(s.a_group, s.start / a_blk);
                assert_eq!(s.w_group, s.start / w_blk);
                // A segment never straddles a group boundary on either side.
                assert!((s.end - 1) / a_blk == s.a_group && (s.end - 1) / w_blk == s.w_group);
                cursor = s.end;
            }
            assert_eq!(cursor, k, "k={k} a={a_blk} w={w_blk}");
        }
    }

    #[test]
    fn eight_bit_is_near_fp() {
        // At 8 bits both operands quantize finely; the integer product
        // must land close to the plain f32 product.
        let x = Tensor::randn(&[10, 24], 9);
        let wt = Tensor::randn(&[6, 24], 10);
        let got = qgemm(
            &QTensor::quantize(&x, &BitAllocation::uniform(8), Granularity::PerToken),
            &QTensor::quantize(&wt, &BitAllocation::uniform(8), Granularity::PerToken),
        );
        let fp = super::super::matmul_transb(&x, &wt);
        let rel = got.max_abs_diff(&fp) / fp.abs_max();
        assert!(rel < 0.1, "rel err {rel}");
    }
}
