//! Word-parallel (SWAR) integer GEMM over bit-packed quantized operands.
//!
//! `qgemm(a, w)` multiplies a packed activation matrix `a` (`m×k`,
//! [`QTensor`]) by a packed weight `w` stored **transposed** (`n×k`, one
//! row per output channel — the layout [`crate::baselines`] produces, and
//! the same convention as [`super::matmul_transb`]), returning the f32
//! product `A · Wᵀ`.
//!
//! The kernel never dequantizes element-by-element. With
//! `a[i][p] = (qa − za)·sa` and `w[j][p] = (qw − zw)·sw` (params constant
//! over a group), each output element decomposes per *segment* — the joint
//! refinement of the two operands' group partitions along `k` — as
//!
//! ```text
//! Σ_p a·w = sa·sw · ( Σ qa·qw − za·Σ qw − zw·Σ qa + len·za·zw )
//! ```
//!
//! so the hot loop is a pure integer dot product, with the scale/zero
//! folding applied once per segment in f64 ([`fold_segment`]).
//!
//! Unlike the original lane-by-lane kernel (which unpacked every 4-bit
//! code to a byte and capped `k` at 32768 to keep i32 accumulators safe),
//! the dot products here run **on the packed words themselves**:
//!
//! * 4×4-bit pairs: [`dot4_swar`] multiplies two packed `u64` words —
//!   16 nibble codes each — as 8 byte lanes per nibble half, accumulating
//!   into split even/odd 16-bit SWAR lanes and spilling to an `i64` every
//!   [`SPILL_WORDS`] words, so `k` is unbounded (DESIGN.md §17 carries
//!   the lane-capacity argument).
//! * 8×8-bit pairs: [`dot_bytes`] reads the packed payload directly (one
//!   code per byte already — no unpack), i32 inner chunks spilled to i64.
//! * mixed 4/8 pairs fall back to byte dots against a cached unpacked
//!   image of the 4-bit side ([`QTensor::gemm_codes`]).
//!
//! Per-segment operand code sums are assembled from cached per-row
//! 16-element chunk sums ([`QTensor::gemm_chunk_sums`]) instead of
//! re-walking the codes; for weights both caches live for the tensor's
//! lifetime (one build per served variant). The outer loops are
//! cache-blocked — [`TILE_N`] weight rows × [`TILE_K`]-element segment
//! runs — so a packed weight tile stays cache-resident across activation
//! rows. Activations quantized at [`Granularity::MicroBlock`] take a
//! dedicated path whose per-micro-block folding runs in-register with no
//! segment table or materialized sum arrays at all.
//!
//! [`qgemm_scalar`] is the scalar reference kernel, and every path above
//! is **bit-identical** to it: integer dots and sums are exact no matter
//! how they are computed, and both kernels fold them through the same
//! [`fold_segment`] in the same segment order, so the f64 operation
//! sequence per output element is literally the same (property-tested in
//! `tests/packed.rs`). Parallelism mirrors [`super::matmul`]: contiguous
//! row-chunks of the output via [`crate::parallel`], each worker owning a
//! disjoint slice, so results are bit-identical at any thread count.

use super::Tensor;
use crate::parallel;
use crate::quant::{Granularity, QTensor, QuantParams};

/// One maximal run of `k` over which both operands' quantization
/// parameters are constant.
struct Seg {
    start: usize,
    end: usize,
    a_group: usize,
    w_group: usize,
}

/// Joint segmentation of `0..k` by the two group lengths.
fn segments(k: usize, a_blk: usize, w_blk: usize) -> Vec<Seg> {
    let mut out = Vec::new();
    let mut p = 0usize;
    while p < k {
        let a_group = p / a_blk;
        let w_group = p / w_blk;
        let end = ((a_group + 1) * a_blk).min((w_group + 1) * w_blk).min(k);
        out.push(Seg { start: p, end, a_group, w_group });
        p = end;
    }
    out
}

/// Low nibble of every byte lane.
const LO_NIB: u64 = 0x0F0F_0F0F_0F0F_0F0F;
/// 1 in every byte lane.
const ONES: u64 = 0x0101_0101_0101_0101;
/// Low byte of every 16-bit lane.
const LO16: u64 = 0x00FF_00FF_00FF_00FF;

/// Packed 4-bit words between 16-bit-lane spills. Each word contributes
/// ≤ 2·225 = 450 per lane (two [`mac4`] halves, nibble products ≤ 15·15),
/// so 128 words max out at 57600 < 65535 — no lane can wrap before the
/// spill (§17's capacity argument; 145 words would be the true ceiling,
/// 128 keeps the cadence a round power of two).
const SPILL_WORDS: usize = 128;

/// Multiply-accumulate two nibble-half words (8 byte lanes, each ≤ 15)
/// into split even/odd 16-bit SWAR accumulators.
///
/// Shift-add over the 4 bits of `y`: `b` extracts bit `i` of every lane,
/// `(b << 8) − b` widens it to a per-lane 0x00/0xFF mask (lane 7's
/// `b << 8` wraps past the top of the word, but the borrow it leaves is
/// exactly the lane-7 term 255·2⁵⁶ — no other lane is disturbed), and the
/// masked, shifted `x` lanes (≤ 15 << 3 = 120, never crossing a byte) are
/// split into the even/odd accumulators' 16-bit lanes.
#[inline(always)]
fn mac4(x: u64, y: u64, acc_even: &mut u64, acc_odd: &mut u64) {
    for i in 0..4 {
        let b = (y >> i) & ONES;
        let m = (b << 8).wrapping_sub(b);
        let t = (x & m) << i;
        *acc_even = acc_even.wrapping_add(t & LO16);
        *acc_odd = acc_odd.wrapping_add((t >> 8) & LO16);
    }
}

/// Horizontal sum of the four 16-bit lanes of a SWAR accumulator.
#[inline(always)]
fn spill16(acc: u64) -> i64 {
    ((acc & 0xFFFF) + ((acc >> 16) & 0xFFFF) + ((acc >> 32) & 0xFFFF) + (acc >> 48)) as i64
}

/// Code `p` of a 4-bit packed row (two codes per byte, low nibble first).
#[inline(always)]
fn nib(packed: &[u8], p: usize) -> i64 {
    ((packed[p / 2] >> (4 * (p % 2))) & 0x0F) as i64
}

/// Exact dot product of two 4-bit packed rows over elements `[start, end)`.
///
/// Both rows share element indexing, so one scalar element (if `start` is
/// odd) reaches a byte boundary for both at once; the body then runs full
/// `u64` words — 16 codes per operand word, two [`mac4`] halves each —
/// with a lane spill every [`SPILL_WORDS`] words, and the tail (< 16
/// elements) finishes scalar.
fn dot4_swar(pa: &[u8], pw: &[u8], start: usize, end: usize) -> i64 {
    let mut total = 0i64;
    let mut p = start;
    if p < end && p % 2 == 1 {
        total += nib(pa, p) * nib(pw, p);
        p += 1;
    }
    let b0 = p / 2;
    let words = (end - p) / 16;
    let mut wa = pa[b0..b0 + words * 8].chunks_exact(8);
    let mut ww = pw[b0..b0 + words * 8].chunks_exact(8);
    let mut done = 0usize;
    while done < words {
        let run = SPILL_WORDS.min(words - done);
        let (mut even, mut odd) = (0u64, 0u64);
        for _ in 0..run {
            let x = u64::from_le_bytes(wa.next().unwrap().try_into().unwrap());
            let y = u64::from_le_bytes(ww.next().unwrap().try_into().unwrap());
            mac4(x & LO_NIB, y & LO_NIB, &mut even, &mut odd);
            mac4((x >> 4) & LO_NIB, (y >> 4) & LO_NIB, &mut even, &mut odd);
        }
        total += spill16(even) + spill16(odd);
        done += run;
    }
    p += words * 16;
    while p < end {
        total += nib(pa, p) * nib(pw, p);
        p += 1;
    }
    total
}

/// Exact u8×u8 dot product in i64: i32 inner chunks (8192·255² < 2³¹)
/// that autovectorize to widening multiply-adds, spilled per chunk. The
/// 8-bit×8-bit GEMM pairing feeds packed payloads straight in — an 8-bit
/// row *is* one code per byte, so there is nothing to unpack.
fn dot_bytes(a: &[u8], b: &[u8]) -> i64 {
    debug_assert_eq!(a.len(), b.len());
    let mut total = 0i64;
    for (ca, cb) in a.chunks(8192).zip(b.chunks(8192)) {
        let mut acc = 0i32;
        for (&x, &y) in ca.iter().zip(cb) {
            acc += x as i32 * y as i32;
        }
        total += acc as i64;
    }
    total
}

/// Unpack the 4-bit codes of `packed` over elements `[start, end)` into
/// `dst` at absolute positions (the rare 4-bit-activation × 8-bit-weight
/// pairing reads this; everything else stays packed).
fn unpack4_span(packed: &[u8], dst: &mut [u8], start: usize, end: usize) {
    for p in start..end {
        dst[p] = (packed[p / 2] >> (4 * (p % 2))) & 0x0F;
    }
}

/// Per-segment scale/zero folding — Eq. above, in f64. Every kernel in
/// this module funnels its (exact) integer dot and code sums through this
/// one function, which is what makes them all bit-identical: the f64
/// operation sequence per output element is the same everywhere, only how
/// the integers were computed differs.
#[inline(always)]
fn fold_segment(
    acc: &mut f64,
    pa: QuantParams,
    pw: QuantParams,
    dot: i64,
    asum: i64,
    wsum: i64,
    len: usize,
) {
    let (za, zw) = (pa.zero as f64, pw.zero as f64);
    *acc += pa.scale as f64
        * pw.scale as f64
        * (dot as f64 - za * wsum as f64 - zw * asum as f64 + len as f64 * za * zw);
}

/// Sum of row `r`'s codes over `[start, end)`, assembled from the cached
/// aligned 16-element chunk sums with scalar edges (`chunk_sums` is the
/// row-major `rows × cpr` table from [`QTensor::gemm_chunk_sums`]).
fn seg_sum(q: &QTensor, r: usize, chunk_sums: &[i32], cpr: usize, start: usize, end: usize) -> i64 {
    let ca = start.div_ceil(16);
    let cb = end / 16;
    if ca >= cb {
        return q.code_sum_span(r, start, end);
    }
    let mut total = q.code_sum_span(r, start, ca * 16);
    for &c in &chunk_sums[r * cpr + ca..r * cpr + cb] {
        total += c as i64;
    }
    total + q.code_sum_span(r, cb * 16, end)
}

/// Weight rows per output tile: at 4-bit, 64 packed rows of a few
/// thousand k stay L2-resident while every activation row in the worker's
/// chunk streams across them.
const TILE_N: usize = 64;

/// Elements per segment run along k. Runs always end on segment
/// boundaries, so the per-(i,j) fold order is plain segment order no
/// matter how the runs split — tiling cannot perturb the f64 sum.
const TILE_K: usize = 4096;

/// Whether the dedicated micro-block path applies: the activation is
/// microscaling-quantized with whole 16-element chunks per block, and the
/// weight's groups either align with the activation's blocks or span the
/// whole row — exactly the geometries where the joint segmentation *is*
/// the activation's block partition, so folding per micro-block in
/// declaration order reproduces the generic walk bit-for-bit.
fn micro_path(a: &QTensor, w: &QTensor) -> bool {
    matches!(a.granularity(), Granularity::MicroBlock { .. })
        && a.group_len() % 16 == 0
        && (w.groups_per_row() == 1 || w.group_len() == a.group_len())
}

/// `a (m×k, packed) · w (n×k, packed, transposed weight) -> m×n` f32:
/// word-parallel SWAR dot products with per-segment scale/zero folding in
/// f64 (bit-identical to [`qgemm_scalar`] — see the module docs).
///
/// Supports every combination the quantizers produce: mixed per-row bit
/// widths (4/8) on either operand, and per-tensor / per-token / per-block
/// / micro-block grouping on either side (group partitions need not align
/// — the joint segmentation handles, say, per-token activations against
/// block-64 weights). `k` is unbounded: accumulation is exact in i64.
pub fn qgemm(a: &QTensor, w: &QTensor) -> Tensor {
    let (m, k) = (a.rows(), a.cols());
    let (n, k2) = (w.rows(), w.cols());
    assert_eq!(k, k2, "qgemm inner-dim mismatch: {m}x{k} @ ({n}x{k2})ᵀ");
    let mut out = Tensor::zeros(&[m, n]);
    if m == 0 || n == 0 || k == 0 {
        return out;
    }
    let t0 = crate::obs::kernel_timer();

    // Cached per-row chunk sums on both sides (built once per tensor; for
    // served weights that means once per variant, not once per call).
    let a_chunks = a.gemm_chunk_sums();
    let w_chunks = w.gemm_chunk_sums();
    let (a_cpr, w_cpr) = (a.sum_chunks_per_row(), w.sum_chunks_per_row());

    let a_any8 = (0..m).any(|i| a.bits_for_row(i) == 8);
    let w_any8 = (0..n).any(|j| w.bits_for_row(j) == 8);
    let w_any4 = (0..n).any(|j| w.bits_for_row(j) == 4);
    // The 8-bit-activation × 4-bit-weight pairing (hp tokens against lp
    // weights — the common mixed case) reads the weight's unpacked image;
    // build it up front (cached for the weight's lifetime) rather than
    // racing the workers into the lazy init.
    let w_codes: &[u8] = if a_any8 && w_any4 { w.gemm_codes() } else { &[] };

    let work = m.saturating_mul(n).saturating_mul(k);
    let od = out.data_mut();

    if micro_path(a, w) {
        // Micro-block fast path: no segment table, no materialized sum
        // arrays — each block's dot and both operand sums are produced and
        // folded on the spot (sums are one or two cached chunk-sum adds).
        let g = a.group_len();
        let nblk = k.div_ceil(g);
        let w_gpr1 = w.groups_per_row() == 1;
        let kernel = |chunk: &mut [f32], r0: usize, r1: usize| {
            let mut arow = vec![0u8; if w_any8 { k } else { 0 }];
            for i in r0..r1 {
                let abits = a.bits_for_row(i);
                let pa = a.packed_row(i);
                let ap = a.row_params(i);
                if abits == 4 && w_any8 {
                    unpack4_span(pa, &mut arow, 0, k);
                }
                let orow = &mut chunk[(i - r0) * n..(i - r0 + 1) * n];
                for (j, o) in orow.iter_mut().enumerate() {
                    let wbits = w.bits_for_row(j);
                    let pw = w.packed_row(j);
                    let wp = w.row_params(j);
                    let mut acc = 0.0f64;
                    for b in 0..nblk {
                        let (s, e) = (b * g, ((b + 1) * g).min(k));
                        let dot = match (abits, wbits) {
                            (4, 4) => dot4_swar(pa, pw, s, e),
                            (8, 8) => dot_bytes(&pa[s..e], &pw[s..e]),
                            (8, 4) => dot_bytes(&pa[s..e], &w_codes[j * k + s..j * k + e]),
                            _ => dot_bytes(&arow[s..e], &pw[s..e]),
                        };
                        let asum = seg_sum(a, i, a_chunks, a_cpr, s, e);
                        let wsum = seg_sum(w, j, w_chunks, w_cpr, s, e);
                        let pwb = if w_gpr1 { wp[0] } else { wp[b] };
                        fold_segment(&mut acc, ap[b], pwb, dot, asum, wsum, e - s);
                    }
                    *o = acc as f32;
                }
            }
        };
        // Same small-m fast path as `matmul`: decode-shaped products run
        // the row loop on the caller's thread instead of paying one spawn
        // per worker for one row per worker.
        if super::matmul::gemm_small_m_serial(m, k, n) {
            kernel(od, 0, m);
        } else {
            parallel::for_row_chunks(od, m, n, work, kernel);
        }
        crate::obs::kernel_done(t0, crate::obs::KernelKind::Qgemm, super::matmul::gemm_ops(m, n, k));
        return out;
    }

    let segs = segments(k, a.group_len(), w.group_len());
    let nseg = segs.len();

    // Per-weight-row, per-segment code sums, assembled in parallel from
    // the cached chunk sums (the old kernel re-summed the unpacked codes
    // in a serial loop on every call).
    let mut wsums = vec![0i64; n * nseg];
    parallel::for_each_chunk_mut(&mut wsums, n, nseg, |_, (r0, _), chunk| {
        for (local, srow) in chunk.chunks_mut(nseg).enumerate() {
            let j = r0 + local;
            for (si, seg) in segs.iter().enumerate() {
                srow[si] = seg_sum(w, j, w_chunks, w_cpr, seg.start, seg.end);
            }
        }
    });

    // Consecutive segments grouped into ≈ TILE_K-element runs (boundaries
    // on segment edges — see TILE_K).
    let mut kruns: Vec<(usize, usize)> = Vec::new();
    let mut s0 = 0usize;
    while s0 < nseg {
        let base = segs[s0].start;
        let mut s1 = s0 + 1;
        while s1 < nseg && segs[s1].end - base <= TILE_K {
            s1 += 1;
        }
        kruns.push((s0, s1));
        s0 = s1;
    }

    let kernel = |chunk: &mut [f32], r0: usize, r1: usize| {
        let rows_chunk = r1 - r0;
        // Worker-lifetime scratch, reused across every row and tile (the
        // old kernel reallocated per-row buffers in each chunk).
        let mut asums = vec![0i64; rows_chunk * nseg];
        for i in r0..r1 {
            for (si, seg) in segs.iter().enumerate() {
                asums[(i - r0) * nseg + si] = seg_sum(a, i, a_chunks, a_cpr, seg.start, seg.end);
            }
        }
        let mut arow = vec![0u8; if w_any8 { k } else { 0 }];
        let mut acc = vec![0.0f64; rows_chunk * TILE_N.min(n)];
        let mut tile0 = 0usize;
        while tile0 < n {
            let tile1 = (tile0 + TILE_N).min(n);
            let tn = tile1 - tile0;
            acc[..rows_chunk * tn].fill(0.0);
            for &(s0, s1) in &kruns {
                let (run_start, run_end) = (segs[s0].start, segs[s1 - 1].end);
                for i in r0..r1 {
                    let abits = a.bits_for_row(i);
                    let pa = a.packed_row(i);
                    let ap = a.row_params(i);
                    if abits == 4 && w_any8 {
                        unpack4_span(pa, &mut arow, run_start, run_end);
                    }
                    let arow_sums = &asums[(i - r0) * nseg..(i - r0 + 1) * nseg];
                    for j in tile0..tile1 {
                        let wbits = w.bits_for_row(j);
                        let pw = w.packed_row(j);
                        let wp = w.row_params(j);
                        let wsum_row = &wsums[j * nseg..(j + 1) * nseg];
                        let acc_el = &mut acc[(i - r0) * tn + (j - tile0)];
                        for ((seg, &asum), &wsum) in segs[s0..s1]
                            .iter()
                            .zip(&arow_sums[s0..s1])
                            .zip(&wsum_row[s0..s1])
                        {
                            let (s, e) = (seg.start, seg.end);
                            let dot = match (abits, wbits) {
                                (4, 4) => dot4_swar(pa, pw, s, e),
                                (8, 8) => dot_bytes(&pa[s..e], &pw[s..e]),
                                (8, 4) => dot_bytes(&pa[s..e], &w_codes[j * k + s..j * k + e]),
                                _ => dot_bytes(&arow[s..e], &pw[s..e]),
                            };
                            fold_segment(
                                acc_el,
                                ap[seg.a_group],
                                wp[seg.w_group],
                                dot,
                                asum,
                                wsum,
                                e - s,
                            );
                        }
                    }
                }
            }
            for (local, acc_row) in acc[..rows_chunk * tn].chunks(tn).enumerate() {
                let orow = &mut chunk[local * n + tile0..local * n + tile1];
                for (o, &v) in orow.iter_mut().zip(acc_row) {
                    *o = v as f32;
                }
            }
            tile0 = tile1;
        }
    };
    if super::matmul::gemm_small_m_serial(m, k, n) {
        kernel(od, 0, m);
    } else {
        parallel::for_row_chunks(od, m, n, work, kernel);
    }
    crate::obs::kernel_done(t0, crate::obs::KernelKind::Qgemm, super::matmul::gemm_ops(m, n, k));
    out
}

/// The scalar reference kernel: unpacks both operands to one byte per
/// code and multiply-accumulates element-by-element, folding per segment
/// through the same `fold_segment` expression as [`qgemm`]. Its dots run
/// in chunked-i32/i64 like the SWAR path, so it shares the unbounded-`k`
/// domain. This is the oracle the property tests hold `qgemm`
/// bit-identical to, and the baseline the microbench measures the SWAR
/// speedup against — not a serving path (single-threaded, no caches, no
/// tiling).
pub fn qgemm_scalar(a: &QTensor, w: &QTensor) -> Tensor {
    let (m, k) = (a.rows(), a.cols());
    let (n, k2) = (w.rows(), w.cols());
    assert_eq!(k, k2, "qgemm inner-dim mismatch: {m}x{k} @ ({n}x{k2})ᵀ");
    let mut out = Tensor::zeros(&[m, n]);
    if m == 0 || n == 0 || k == 0 {
        return out;
    }
    let segs = segments(k, a.group_len(), w.group_len());
    let nseg = segs.len();
    let sum_codes = |row: &[u8]| -> i64 { row.iter().map(|&x| x as i64).sum() };

    let mut wq = vec![0u8; n * k];
    for (j, row) in wq.chunks_mut(k).enumerate() {
        w.unpack_row_into(j, row);
    }
    let mut wsums = vec![0i64; n * nseg];
    for (j, srow) in wsums.chunks_mut(nseg).enumerate() {
        let row = &wq[j * k..(j + 1) * k];
        for (si, seg) in segs.iter().enumerate() {
            srow[si] = sum_codes(&row[seg.start..seg.end]);
        }
    }
    let od = out.data_mut();
    let mut arow = vec![0u8; k];
    let mut asums = vec![0i64; nseg];
    for i in 0..m {
        a.unpack_row_into(i, &mut arow);
        for (si, seg) in segs.iter().enumerate() {
            asums[si] = sum_codes(&arow[seg.start..seg.end]);
        }
        let ap = a.row_params(i);
        let orow = &mut od[i * n..(i + 1) * n];
        for (j, o) in orow.iter_mut().enumerate() {
            let wrow = &wq[j * k..(j + 1) * k];
            let wp = w.row_params(j);
            let wsum_row = &wsums[j * nseg..(j + 1) * nseg];
            let mut acc = 0.0f64;
            for (si, seg) in segs.iter().enumerate() {
                let dot = dot_bytes(&arow[seg.start..seg.end], &wrow[seg.start..seg.end]);
                fold_segment(
                    &mut acc,
                    ap[seg.a_group],
                    wp[seg.w_group],
                    dot,
                    asums[si],
                    wsum_row[si],
                    seg.end - seg.start,
                );
            }
            *o = acc as f32;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{quantize_dequantize_rows, BitAllocation, Granularity};

    /// The QDQ oracle: simulated-quantization matmul against the packed
    /// integer product, tolerant only of f32-accumulation differences.
    fn oracle(
        x: &Tensor,
        wt: &Tensor, // n×k, same transposed layout qgemm consumes
        abits: &BitAllocation,
        agran: Granularity,
        wbits: &BitAllocation,
        wgran: Granularity,
    ) -> Tensor {
        let xq = quantize_dequantize_rows(x, abits, agran);
        let wq = quantize_dequantize_rows(wt, wbits, wgran);
        super::super::matmul_transb(&xq, &wq)
    }

    fn assert_close(got: &Tensor, want: &Tensor, label: &str) {
        let tol = 1e-3 * want.abs_max().max(1.0);
        let diff = got.max_abs_diff(want);
        assert!(diff <= tol, "{label}: diff {diff} > tol {tol}");
    }

    /// The PR 9 invariant: the SWAR kernel equals the scalar oracle
    /// *bit-for-bit*, not merely within tolerance.
    fn assert_bit_identical(qa: &QTensor, qw: &QTensor, label: &str) {
        let got = qgemm(qa, qw);
        let want = qgemm_scalar(qa, qw);
        assert_eq!(got, want, "{label}: SWAR kernel diverged from the scalar oracle");
    }

    #[test]
    fn matches_oracle_w4a4() {
        let x = Tensor::randn(&[12, 32], 1);
        let wt = Tensor::randn(&[9, 32], 2);
        let ab = BitAllocation::uniform(4);
        let wb = BitAllocation::uniform(4);
        let qa = QTensor::quantize(&x, &ab, Granularity::PerToken);
        let qw = QTensor::quantize(&wt, &wb, Granularity::PerToken);
        let got = qgemm(&qa, &qw);
        let want = oracle(&x, &wt, &ab, Granularity::PerToken, &wb, Granularity::PerToken);
        assert_eq!(got.shape(), &[12, 9]);
        assert_close(&got, &want, "w4a4");
        assert_bit_identical(&qa, &qw, "w4a4");
    }

    #[test]
    fn matches_oracle_mixed_rows_and_blocks() {
        // Two-level mixed activation rows against block-grouped weights:
        // the segment partitions deliberately misalign (row groups of 24
        // vs weight blocks of 16 over k=48).
        let x = Tensor::randn(&[20, 48], 3);
        let wt = Tensor::randn(&[7, 48], 4);
        let ab = BitAllocation::two_level(6, 8, 4);
        let wb = BitAllocation::uniform(8);
        let agran = Granularity::PerBlock { block: 24 };
        let wgran = Granularity::PerBlock { block: 16 };
        let qa = QTensor::quantize(&x, &ab, agran);
        let qw = QTensor::quantize(&wt, &wb, wgran);
        let got = qgemm(&qa, &qw);
        let want = oracle(&x, &wt, &ab, agran, &wb, wgran);
        assert_close(&got, &want, "mixed+blocks");
        assert_bit_identical(&qa, &qw, "mixed+blocks");
    }

    #[test]
    fn matches_oracle_per_tensor() {
        let x = Tensor::randn(&[8, 16], 5);
        let wt = Tensor::randn(&[5, 16], 6);
        let ab = BitAllocation::two_level(2, 8, 4);
        let wb = BitAllocation::uniform(4);
        let qa = QTensor::quantize(&x, &ab, Granularity::PerTensor);
        let qw = QTensor::quantize(&wt, &wb, Granularity::PerToken);
        let got = qgemm(&qa, &qw);
        let want = oracle(&x, &wt, &ab, Granularity::PerTensor, &wb, Granularity::PerToken);
        assert_close(&got, &want, "per-tensor");
        assert_bit_identical(&qa, &qw, "per-tensor");
    }

    #[test]
    fn parallel_path_is_bit_identical_to_serial() {
        // Big enough that m·n·k clears the fork threshold. The serial
        // reference runs on this thread via the kernel-serial flag.
        let x = Tensor::randn(&[96, 80], 7);
        let wt = Tensor::randn(&[72, 80], 8);
        let qa = QTensor::quantize(&x, &BitAllocation::two_level(16, 8, 4), Granularity::PerToken);
        let qw = QTensor::quantize(&wt, &BitAllocation::uniform(4), Granularity::PerBlock { block: 16 });
        let threaded = qgemm(&qa, &qw);
        crate::parallel::set_kernel_serial(true);
        let serial = qgemm(&qa, &qw);
        crate::parallel::set_kernel_serial(false);
        assert_eq!(threaded, serial, "qgemm must not depend on thread count");
        assert_eq!(threaded, qgemm_scalar(&qa, &qw), "and both must equal the scalar oracle");
    }

    #[test]
    fn small_m_fast_path_matches_oracle_and_larger_batch() {
        // Decode-shaped: a handful of activation rows against a wide
        // packed weight. The serial fast path must agree with the oracle
        // and be row-for-row identical to the same rows inside a larger
        // (dispatch-eligible) product.
        let (k, n) = (96usize, 640usize);
        let m_small = super::super::matmul::GEMM_SERIAL_MAX_ROWS;
        let x = Tensor::randn(&[4 * m_small, k], 11);
        let wt = Tensor::randn(&[n, k], 12);
        let ab = BitAllocation::two_level(2, 8, 4);
        let wb = BitAllocation::uniform(4);
        let qa_big = QTensor::quantize(&x, &ab, Granularity::PerToken);
        let qa_small =
            QTensor::quantize(&x.slice_rows(0, m_small), &ab, Granularity::PerToken);
        let qw = QTensor::quantize(&wt, &wb, Granularity::PerToken);
        let big = qgemm(&qa_big, &qw);
        let small = qgemm(&qa_small, &qw);
        for i in 0..m_small {
            assert_eq!(small.row(i), big.row(i), "row {i}");
        }
        let want = oracle(
            &x.slice_rows(0, m_small),
            &wt,
            &ab,
            Granularity::PerToken,
            &wb,
            Granularity::PerToken,
        );
        assert_close(&small, &want, "small-m");
    }

    #[test]
    fn segments_cover_k_exactly_once() {
        for &(k, a_blk, w_blk) in &[(48usize, 24usize, 16usize), (17, 17, 4), (64, 64, 64), (10, 3, 7)] {
            let segs = segments(k, a_blk, w_blk);
            let mut cursor = 0;
            for s in &segs {
                assert_eq!(s.start, cursor);
                assert!(s.end > s.start);
                assert_eq!(s.a_group, s.start / a_blk);
                assert_eq!(s.w_group, s.start / w_blk);
                // A segment never straddles a group boundary on either side.
                assert!((s.end - 1) / a_blk == s.a_group && (s.end - 1) / w_blk == s.w_group);
                cursor = s.end;
            }
            assert_eq!(cursor, k, "k={k} a={a_blk} w={w_blk}");
        }
    }

    #[test]
    fn eight_bit_is_near_fp() {
        // At 8 bits both operands quantize finely; the integer product
        // must land close to the plain f32 product — and the 8-bit path
        // (packed payload read in place, no unpack) must equal the oracle
        // bit-for-bit.
        let x = Tensor::randn(&[10, 24], 9);
        let wt = Tensor::randn(&[6, 24], 10);
        let qa = QTensor::quantize(&x, &BitAllocation::uniform(8), Granularity::PerToken);
        let qw = QTensor::quantize(&wt, &BitAllocation::uniform(8), Granularity::PerToken);
        let got = qgemm(&qa, &qw);
        let fp = super::super::matmul_transb(&x, &wt);
        let rel = got.max_abs_diff(&fp) / fp.abs_max();
        assert!(rel < 0.1, "rel err {rel}");
        assert_bit_identical(&qa, &qw, "w8a8");
    }

    #[test]
    fn swar_dot_matches_nibble_loop_across_offsets() {
        // Direct primitive check: every start/end alignment class (odd and
        // even starts, sub-word tails), spans crossing the 128-word spill
        // boundary (2048 elements), against the definitionally-correct
        // nibble loop. Worst-case codes (all 15s) are in the mix via the
        // generator's byte range.
        let k = 4500usize;
        let mut state = 0x1234_5678_9ABC_DEF0u64;
        let mut next = || {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            (state >> 33) as u8
        };
        let pa: Vec<u8> = (0..k.div_ceil(2)).map(|_| next()).collect();
        let pw: Vec<u8> = (0..k.div_ceil(2)).map(|_| next()).collect();
        let naive = |s: usize, e: usize| -> i64 {
            (s..e).map(|p| nib(&pa, p) * nib(&pw, p)).sum()
        };
        for &(s, e) in &[
            (0usize, k),
            (0, 1),
            (1, 2),
            (1, 16),
            (0, 15),
            (3, 4100), // odd start, crosses the spill boundary
            (2, 4099),
            (17, 17), // empty
            (16, 2064 + 7),
            (k - 5, k),
        ] {
            assert_eq!(dot4_swar(&pa, &pw, s, e), naive(s, e), "span [{s}, {e})");
        }
    }

    #[test]
    fn swar_matches_scalar_on_odd_and_tail_geometry() {
        // k values straddling every edge the word kernel has: single
        // element, sub-word, word ± 1, and a deliberately misaligned
        // per-block-5 weight grouping that forces odd segment starts.
        for &k in &[1usize, 2, 7, 15, 16, 17, 31, 33, 95] {
            let x = Tensor::randn(&[5, k], k as u64 + 1);
            let wt = Tensor::randn(&[6, k], k as u64 + 2);
            let ab = BitAllocation::two_level(2, 8, 4);
            let qa = QTensor::quantize(&x, &ab, Granularity::PerToken);
            let qw = QTensor::quantize(
                &wt,
                &BitAllocation::uniform(4),
                Granularity::PerBlock { block: 5 },
            );
            assert_bit_identical(&qa, &qw, &format!("k={k}"));
            let want = oracle(
                &x,
                &wt,
                &ab,
                Granularity::PerToken,
                &BitAllocation::uniform(4),
                Granularity::PerBlock { block: 5 },
            );
            assert_close(&qgemm(&qa, &qw), &want, &format!("k={k} oracle"));
        }
    }

    #[test]
    fn mixed_bit_rows_in_both_operands() {
        // 4- and 8-bit rows on *both* sides in one product exercises all
        // four dot pairings (4×4 SWAR, 8×8 byte, and both mixed paths)
        // within a single call.
        let x = Tensor::randn(&[10, 50], 21);
        let wt = Tensor::randn(&[9, 50], 22);
        let qa = QTensor::quantize(
            &x,
            &BitAllocation::two_level(3, 8, 4),
            Granularity::PerBlock { block: 24 },
        );
        let qw = QTensor::quantize(
            &wt,
            &BitAllocation::two_level(4, 8, 4),
            Granularity::PerBlock { block: 16 },
        );
        assert_bit_identical(&qa, &qw, "mixed bits both operands");
    }

    #[test]
    fn large_k_crosses_spill_and_removes_old_bound() {
        // k = 40000 exceeds the old `k ≤ 32768` assert and crosses the
        // SWAR spill cadence many times; the product must simply work and
        // stay bit-identical to the (i64) scalar oracle.
        let k = 40_000usize;
        let x = Tensor::randn(&[2, k], 31);
        let wt = Tensor::randn(&[3, k], 32);
        let ab = BitAllocation::two_level(1, 8, 4);
        let qa = QTensor::quantize(&x, &ab, Granularity::PerToken);
        let qw = QTensor::quantize(&wt, &BitAllocation::uniform(4), Granularity::PerToken);
        assert_bit_identical(&qa, &qw, "k=40000");
        let want = oracle(
            &x,
            &wt,
            &ab,
            Granularity::PerToken,
            &BitAllocation::uniform(4),
            Granularity::PerToken,
        );
        // Relative tolerance: 40k accumulated rounding steps, f64 oracle
        // matmul — keep the check loose but meaningful.
        let got = qgemm(&qa, &qw);
        let rel = got.max_abs_diff(&want) / want.abs_max();
        assert!(rel < 1e-2, "rel err {rel}");
    }

    #[test]
    fn micro_block_fast_path_is_bit_identical() {
        let x = Tensor::randn(&[12, 64], 41);
        let wt = Tensor::randn(&[10, 64], 42);
        let ab = BitAllocation::two_level(3, 8, 4);
        let wb = BitAllocation::uniform(4);
        // Fast path: micro16 against per-token weights (one group per row).
        let qa = QTensor::quantize(&x, &ab, Granularity::MicroBlock { block: 16 });
        let qw = QTensor::quantize(&wt, &wb, Granularity::PerToken);
        assert!(micro_path(&qa, &qw));
        assert_bit_identical(&qa, &qw, "micro16 x per-token");
        let want = oracle(
            &x,
            &wt,
            &ab,
            Granularity::MicroBlock { block: 16 },
            &wb,
            Granularity::PerToken,
        );
        assert_close(&qgemm(&qa, &qw), &want, "micro16 oracle");
        // Fast path: micro32 against aligned block-32 weights.
        let qa = QTensor::quantize(&x, &ab, Granularity::MicroBlock { block: 32 });
        let qw32 = QTensor::quantize(&wt, &wb, Granularity::PerBlock { block: 32 });
        assert!(micro_path(&qa, &qw32));
        assert_bit_identical(&qa, &qw32, "micro32 x block-32");
        // Misaligned weight groups push micro activations onto the generic
        // segmented path — still bit-identical to the oracle kernel.
        let qa16 = QTensor::quantize(&x, &ab, Granularity::MicroBlock { block: 16 });
        let qw24 = QTensor::quantize(&wt, &wb, Granularity::PerBlock { block: 24 });
        assert!(!micro_path(&qa16, &qw24));
        assert_bit_identical(&qa16, &qw24, "micro16 x block-24 (generic path)");
    }

    #[test]
    fn micro_block_partial_tail_block() {
        // d = 40 with micro16: the last micro-block is a partial 8-wide
        // tail; k not divisible by the chunk width exercises the chunk-sum
        // edge assembly on both sides.
        let x = Tensor::randn(&[6, 40], 51);
        let wt = Tensor::randn(&[5, 40], 52);
        let qa = QTensor::quantize(&x, &BitAllocation::uniform(4), Granularity::MicroBlock { block: 16 });
        let qw = QTensor::quantize(&wt, &BitAllocation::uniform(4), Granularity::PerToken);
        assert!(micro_path(&qa, &qw));
        assert_bit_identical(&qa, &qw, "micro16 partial tail");
    }

    #[test]
    fn weight_side_prep_cache_is_transparent() {
        // Repeated calls (the second hits the cached chunk sums / codes)
        // and clones (which share the cache through the Arc) must all
        // produce the identical product.
        let x = Tensor::randn(&[16, 48], 61);
        let wt = Tensor::randn(&[12, 48], 62);
        let qa = QTensor::quantize(&x, &BitAllocation::two_level(4, 8, 4), Granularity::PerToken);
        let qw = QTensor::quantize(&wt, &BitAllocation::uniform(4), Granularity::PerToken);
        let first = qgemm(&qa, &qw);
        let second = qgemm(&qa, &qw);
        assert_eq!(first, second);
        let (qa2, qw2) = (qa.clone(), qw.clone());
        assert_eq!(first, qgemm(&qa2, &qw2));
    }
}
