//! Deterministic xorshift64* PRNG.
//!
//! The offline build vendors no `rand` crate, so every stochastic component
//! in the repo (weight init, synthetic activations, property tests) draws
//! from this generator. It is seeded explicitly everywhere to keep tables
//! and benchmarks reproducible bit-for-bit.

/// xorshift64* generator (Vigna 2016). Not cryptographic; plenty for
/// synthetic data and property-test case generation.
#[derive(Clone, Debug)]
pub struct XorShiftRng {
    state: u64,
}

impl XorShiftRng {
    pub fn new(seed: u64) -> Self {
        // Avoid the all-zero fixed point and decorrelate small seeds.
        let mut s = seed.wrapping_mul(0x9E37_79B9_7F4A_7C15).wrapping_add(0x2545_F491_4F6C_DD1D);
        if s == 0 {
            s = 0xDEAD_BEEF_CAFE_F00D;
        }
        XorShiftRng { state: s }
    }

    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    /// Uniform in `[0, 1)`.
    pub fn next_f32(&mut self) -> f32 {
        // 24 mantissa bits.
        (self.next_u64() >> 40) as f32 / (1u32 << 24) as f32
    }

    /// Uniform in `[0, 1)` with f64 resolution.
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform integer in `[0, n)`.
    pub fn next_below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// A pair of independent standard normals (Box–Muller).
    pub fn next_gaussian_pair(&mut self) -> (f32, f32) {
        // u in (0,1] to keep ln finite.
        let u = 1.0 - self.next_f64();
        let v = self.next_f64();
        let r = (-2.0 * u.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * v;
        ((r * theta.cos()) as f32, (r * theta.sin()) as f32)
    }

    pub fn next_gaussian(&mut self) -> f32 {
        self.next_gaussian_pair().0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = XorShiftRng::new(42);
        let mut b = XorShiftRng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_range() {
        let mut rng = XorShiftRng::new(1);
        for _ in 0..10_000 {
            let x = rng.next_f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_mean_near_half() {
        let mut rng = XorShiftRng::new(9);
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| rng.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn gaussian_moments() {
        let mut rng = XorShiftRng::new(3);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.next_gaussian() as f64).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn zero_seed_ok() {
        let mut rng = XorShiftRng::new(0);
        let x = rng.next_u64();
        let y = rng.next_u64();
        assert_ne!(x, 0);
        assert_ne!(x, y);
    }

    #[test]
    fn next_below_in_range() {
        let mut rng = XorShiftRng::new(5);
        for _ in 0..1000 {
            assert!(rng.next_below(7) < 7);
        }
    }
}
