//! Dense row-major `f32` tensor substrate.
//!
//! The whole reproduction works on 2-D activation matrices `X ∈ R^{s×d}`
//! (sequence × feature) plus occasional 3-D batches, so this module keeps a
//! deliberately small surface: shape bookkeeping, elementwise ops, matmul,
//! row/column views, and a couple of constructors (zeros / randn / from
//! slices). Everything is `f32`, matching both the PJRT artifacts and the
//! quantization math in the paper — except [`qgemm`], the word-parallel
//! (SWAR) integer GEMM over bit-packed [`crate::quant::QTensor`] operands
//! that multiplies packed words directly, accumulates exactly in i64, and
//! folds scales/zero-points on output ([`qgemm_scalar`] is its scalar
//! reference oracle).

mod matmul;
mod qgemm;
mod rng;

pub use matmul::{matmul, matmul_into, matmul_transb, GEMM_SERIAL_MAX_ROWS};
pub use qgemm::{qgemm, qgemm_scalar};
pub use rng::XorShiftRng;

use std::fmt;

/// A dense row-major tensor of `f32` with up to 3 dimensions.
#[derive(Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    /// Create a tensor of zeros with the given shape.
    pub fn zeros(shape: &[usize]) -> Self {
        let n: usize = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    /// Create a tensor filled with `v`.
    pub fn full(shape: &[usize], v: f32) -> Self {
        let n: usize = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![v; n] }
    }

    /// Wrap an existing buffer. Panics if the length does not match.
    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Self {
        let n: usize = shape.iter().product();
        assert_eq!(n, data.len(), "shape {:?} needs {} elements, got {}", shape, n, data.len());
        Tensor { shape: shape.to_vec(), data }
    }

    /// Standard-normal tensor from a deterministic seed (Box–Muller over
    /// xorshift). Deterministic across runs/platforms.
    pub fn randn(shape: &[usize], seed: u64) -> Self {
        let n: usize = shape.iter().product();
        let mut rng = XorShiftRng::new(seed);
        let mut data = Vec::with_capacity(n);
        while data.len() < n {
            let (a, b) = rng.next_gaussian_pair();
            data.push(a);
            if data.len() < n {
                data.push(b);
            }
        }
        Tensor { shape: shape.to_vec(), data }
    }

    /// Uniform tensor in `[lo, hi)` from a deterministic seed.
    pub fn rand_uniform(shape: &[usize], lo: f32, hi: f32, seed: u64) -> Self {
        let n: usize = shape.iter().product();
        let mut rng = XorShiftRng::new(seed);
        let data = (0..n).map(|_| lo + (hi - lo) * rng.next_f32()).collect();
        Tensor { shape: shape.to_vec(), data }
    }

    /// Identity matrix of size `n`.
    pub fn eye(n: usize) -> Self {
        let mut t = Tensor::zeros(&[n, n]);
        for i in 0..n {
            t.data[i * n + i] = 1.0;
        }
        t
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn ndim(&self) -> usize {
        self.shape.len()
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Rows of a 2-D tensor (sequence length `s` in the paper's notation).
    pub fn rows(&self) -> usize {
        assert_eq!(self.ndim(), 2, "rows() needs a 2-D tensor, got {:?}", self.shape);
        self.shape[0]
    }

    /// Columns of a 2-D tensor (feature size `d`).
    pub fn cols(&self) -> usize {
        assert_eq!(self.ndim(), 2, "cols() needs a 2-D tensor, got {:?}", self.shape);
        self.shape[1]
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// Borrow row `i` of a 2-D tensor.
    pub fn row(&self, i: usize) -> &[f32] {
        let d = self.cols();
        &self.data[i * d..(i + 1) * d]
    }

    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        let d = self.cols();
        &mut self.data[i * d..(i + 1) * d]
    }

    /// Element access for 2-D tensors.
    pub fn at(&self, i: usize, j: usize) -> f32 {
        debug_assert_eq!(self.ndim(), 2);
        self.data[i * self.shape[1] + j]
    }

    pub fn set(&mut self, i: usize, j: usize, v: f32) {
        debug_assert_eq!(self.ndim(), 2);
        self.data[i * self.shape[1] + j] = v;
    }

    /// Reinterpret the buffer with a new shape of identical element count.
    pub fn reshape(&self, shape: &[usize]) -> Tensor {
        let n: usize = shape.iter().product();
        assert_eq!(n, self.data.len(), "reshape {:?} -> {:?}", self.shape, shape);
        Tensor { shape: shape.to_vec(), data: self.data.clone() }
    }

    /// Transpose of a 2-D tensor.
    pub fn transpose(&self) -> Tensor {
        let (r, c) = (self.rows(), self.cols());
        let mut out = Tensor::zeros(&[c, r]);
        for i in 0..r {
            for j in 0..c {
                out.data[j * r + i] = self.data[i * c + j];
            }
        }
        out
    }

    /// Copy rows `[start, end)` into a new tensor.
    pub fn slice_rows(&self, start: usize, end: usize) -> Tensor {
        let d = self.cols();
        Tensor::from_vec(&[end - start, d], self.data[start * d..end * d].to_vec())
    }

    /// Vertically stack two tensors with equal column counts.
    pub fn vcat(&self, other: &Tensor) -> Tensor {
        assert_eq!(self.cols(), other.cols());
        let mut data = self.data.clone();
        data.extend_from_slice(&other.data);
        Tensor::from_vec(&[self.rows() + other.rows(), self.cols()], data)
    }

    /// Elementwise map into a new tensor.
    pub fn map(&self, f: impl Fn(f32) -> f32) -> Tensor {
        Tensor { shape: self.shape.clone(), data: self.data.iter().map(|&x| f(x)).collect() }
    }

    /// In-place elementwise map.
    pub fn map_inplace(&mut self, f: impl Fn(f32) -> f32) {
        for x in &mut self.data {
            *x = f(*x);
        }
    }

    /// Elementwise binary op; shapes must match.
    pub fn zip(&self, other: &Tensor, f: impl Fn(f32, f32) -> f32) -> Tensor {
        assert_eq!(self.shape, other.shape, "zip shape mismatch");
        let data = self.data.iter().zip(&other.data).map(|(&a, &b)| f(a, b)).collect();
        Tensor { shape: self.shape.clone(), data }
    }

    pub fn add(&self, other: &Tensor) -> Tensor {
        self.zip(other, |a, b| a + b)
    }

    pub fn sub(&self, other: &Tensor) -> Tensor {
        self.zip(other, |a, b| a - b)
    }

    pub fn scale(&self, k: f32) -> Tensor {
        self.map(|x| x * k)
    }

    /// Add `v` (length = cols) to every row, as a broadcast bias.
    pub fn add_row_broadcast(&self, v: &[f32]) -> Tensor {
        let d = self.cols();
        assert_eq!(v.len(), d);
        let mut out = self.clone();
        for i in 0..self.rows() {
            let row = out.row_mut(i);
            for j in 0..d {
                row[j] += v[j];
            }
        }
        out
    }

    /// Squared Frobenius norm.
    pub fn sq_norm(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum()
    }

    /// Frobenius norm.
    pub fn norm(&self) -> f64 {
        self.sq_norm().sqrt()
    }

    /// Max |x|.
    pub fn abs_max(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, &x| m.max(x.abs()))
    }

    /// Mean of all entries.
    pub fn mean(&self) -> f64 {
        if self.data.is_empty() {
            return 0.0;
        }
        self.data.iter().map(|&x| x as f64).sum::<f64>() / self.data.len() as f64
    }

    /// `self @ other` for 2-D tensors.
    pub fn matmul(&self, other: &Tensor) -> Tensor {
        matmul(self, other)
    }

    /// Maximum absolute difference against another tensor.
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .fold(0.0f32, |m, (&a, &b)| m.max((a - b).abs()))
    }

    /// True when every entry is finite.
    pub fn all_finite(&self) -> bool {
        self.data.iter().all(|x| x.is_finite())
    }
}

impl fmt::Debug for Tensor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Tensor{:?}", self.shape)?;
        if self.data.len() <= 16 {
            write!(f, " {:?}", self.data)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_and_shape() {
        let t = Tensor::zeros(&[3, 4]);
        assert_eq!(t.shape(), &[3, 4]);
        assert_eq!(t.len(), 12);
        assert_eq!(t.rows(), 3);
        assert_eq!(t.cols(), 4);
        assert!(t.data().iter().all(|&x| x == 0.0));
    }

    #[test]
    fn randn_deterministic() {
        let a = Tensor::randn(&[8, 8], 7);
        let b = Tensor::randn(&[8, 8], 7);
        assert_eq!(a, b);
        let c = Tensor::randn(&[8, 8], 8);
        assert_ne!(a, c);
    }

    #[test]
    fn randn_moments() {
        let t = Tensor::randn(&[64, 64], 123);
        let mean = t.mean();
        let var = t.data().iter().map(|&x| (x as f64 - mean).powi(2)).sum::<f64>()
            / t.len() as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn transpose_involution() {
        let t = Tensor::randn(&[5, 9], 1);
        assert_eq!(t.transpose().transpose(), t);
    }

    #[test]
    fn row_access() {
        let t = Tensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.row(0), &[1., 2., 3.]);
        assert_eq!(t.row(1), &[4., 5., 6.]);
        assert_eq!(t.at(1, 2), 6.0);
    }

    #[test]
    fn slice_and_vcat_roundtrip() {
        let t = Tensor::randn(&[6, 4], 2);
        let a = t.slice_rows(0, 2);
        let b = t.slice_rows(2, 6);
        assert_eq!(a.vcat(&b), t);
    }

    #[test]
    fn eye_matmul_identity() {
        let t = Tensor::randn(&[4, 4], 3);
        let i = Tensor::eye(4);
        assert!(t.matmul(&i).max_abs_diff(&t) < 1e-6);
        assert!(i.matmul(&t).max_abs_diff(&t) < 1e-6);
    }

    #[test]
    fn broadcast_bias() {
        let t = Tensor::zeros(&[2, 3]);
        let out = t.add_row_broadcast(&[1.0, 2.0, 3.0]);
        assert_eq!(out.row(0), &[1.0, 2.0, 3.0]);
        assert_eq!(out.row(1), &[1.0, 2.0, 3.0]);
    }

    #[test]
    fn norms() {
        let t = Tensor::from_vec(&[1, 2], vec![3.0, 4.0]);
        assert!((t.norm() - 5.0).abs() < 1e-9);
        assert!((t.sq_norm() - 25.0).abs() < 1e-9);
        assert_eq!(t.abs_max(), 4.0);
    }

    #[test]
    #[should_panic]
    fn from_vec_bad_len_panics() {
        Tensor::from_vec(&[2, 2], vec![1.0, 2.0, 3.0]);
    }
}
