//! Cache-blocked, row-parallel matmul kernels.
//!
//! The eval harnesses push tiny-transformer forwards through thousands of
//! quantized linear layers, so this is one of the repo's hot paths. The
//! implementation is an i-k-j loop order (unit-stride inner loop over the
//! output row) with a k-panel blocking that keeps the `b` panel in L1/L2,
//! parallelized over contiguous row-chunks of the output via
//! [`crate::parallel`] (each worker owns a disjoint slice of `out`, so the
//! per-row reduction order — and therefore the floating-point result — is
//! identical to the serial kernel). Small products stay serial; see
//! EXPERIMENTS.md §Perf for before/after numbers and the thresholds.

use super::Tensor;
use crate::parallel;

/// k-panel height: 64 rows of `b` × up to 512 f32 columns ≈ 128 KiB worst
/// case, comfortably inside L2; typical d≤256 keeps it in L1.
const KC: usize = 64;

/// At or below this many output rows a GEMM stays on the caller's thread
/// unless each row is itself heavy (see `gemm_small_m_serial`). Rows
/// are the only split axis, so a decode-shaped product (a handful of
/// token rows against a modest weight) would hand each worker a single
/// tiny row while the spawn+join overhead (~10–40 µs per worker) dwarfs
/// the per-row work — batched decode at 1–8 streams was paying the
/// fan-out on every projection. Results are unchanged by construction:
/// parallelism never alters the per-row reduction order, it only changes
/// who computes a row.
pub const GEMM_SERIAL_MAX_ROWS: usize = 8;

/// Per-row multiply-add count above which even an `m ≤`
/// [`GEMM_SERIAL_MAX_ROWS`] product forks anyway: one row per worker
/// still amortizes the spawn cost once a row alone is ~100 µs of work
/// (e.g. a big-vocab logits head at decode batch 8).
const GEMM_SERIAL_MAX_ROW_WORK: usize = 1 << 18;

/// The small-m serial gate shared by `matmul_into`, `matmul_transb`, and
/// `qgemm`: few rows, each individually cheap.
pub(super) fn gemm_small_m_serial(m: usize, k: usize, n: usize) -> bool {
    m <= GEMM_SERIAL_MAX_ROWS && k.saturating_mul(n) < GEMM_SERIAL_MAX_ROW_WORK
}

/// `a (m×k) @ b (k×n) -> (m×n)`.
pub fn matmul(a: &Tensor, b: &Tensor) -> Tensor {
    let mut out = Tensor::zeros(&[a.rows(), b.cols()]);
    matmul_into(a, b, &mut out);
    out
}

/// `a @ b` accumulated into a pre-allocated output (overwrites `out`).
pub fn matmul_into(a: &Tensor, b: &Tensor, out: &mut Tensor) {
    let (m, k) = (a.rows(), a.cols());
    let (k2, n) = (b.rows(), b.cols());
    assert_eq!(k, k2, "matmul inner-dim mismatch: {}x{} @ {}x{}", m, k, k2, n);
    assert_eq!(out.shape(), &[m, n]);
    let t0 = crate::obs::kernel_timer();

    let ad = a.data();
    let bd = b.data();
    let od = out.data_mut();
    od.fill(0.0);

    // Small-m fast path: decode-shaped products skip the dispatch
    // machinery entirely.
    if gemm_small_m_serial(m, k, n) {
        matmul_rows(ad, bd, od, 0, m, k, n);
        crate::obs::kernel_done(t0, crate::obs::KernelKind::Matmul, gemm_ops(m, n, k));
        return;
    }
    // Gate on total multiply-adds (m·n·k), not output size: a product with
    // a tall inner dimension has little output but plenty of work. Rows
    // are the only split axis, so single-row products stay serial
    // regardless (for_row_chunks enforces both).
    parallel::for_row_chunks(od, m, n, m.saturating_mul(n).saturating_mul(k), |chunk, r0, r1| {
        matmul_rows(ad, bd, chunk, r0, r1, k, n)
    });
    crate::obs::kernel_done(t0, crate::obs::KernelKind::Matmul, gemm_ops(m, n, k));
}

/// Multiply-accumulate count of an `m×k @ k×n` product, for the kernel
/// profiler (2 ops per FMA by GEMM convention).
pub(super) fn gemm_ops(m: usize, n: usize, k: usize) -> u64 {
    2 * (m as u64) * (n as u64) * (k as u64)
}

/// The serial k-blocked kernel over output rows `[r0, r1)`; `ochunk` is the
/// corresponding slice of the output buffer.
fn matmul_rows(ad: &[f32], bd: &[f32], ochunk: &mut [f32], r0: usize, r1: usize, k: usize, n: usize) {
    for kb in (0..k).step_by(KC) {
        let kend = (kb + KC).min(k);
        for i in r0..r1 {
            let arow = &ad[i * k..(i + 1) * k];
            let orow = &mut ochunk[(i - r0) * n..(i - r0 + 1) * n];
            for p in kb..kend {
                let av = arow[p];
                if av == 0.0 {
                    continue;
                }
                let brow = &bd[p * n..(p + 1) * n];
                // Unit-stride FMA loop; autovectorizes cleanly.
                for (o, &bv) in orow.iter_mut().zip(brow) {
                    *o += av * bv;
                }
            }
        }
    }
}

/// `a (m×k) @ bᵀ` where `b` is stored as `(n×k)` — the natural layout for
/// weight matrices kept as `[out, in]`. Dot-product inner loop, both
/// operands unit-stride; parallel over row-chunks of the output like
/// [`matmul_into`].
pub fn matmul_transb(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k) = (a.rows(), a.cols());
    let (n, k2) = (b.rows(), b.cols());
    assert_eq!(k, k2, "matmul_transb inner-dim mismatch");
    let t0 = crate::obs::kernel_timer();
    let mut out = Tensor::zeros(&[m, n]);
    let ad = a.data();
    let bd = b.data();
    let od = out.data_mut();
    if gemm_small_m_serial(m, k, n) {
        transb_rows(ad, bd, od, 0, m, k, n);
        crate::obs::kernel_done(t0, crate::obs::KernelKind::MatmulTransb, gemm_ops(m, n, k));
        return out;
    }
    parallel::for_row_chunks(od, m, n, m.saturating_mul(n).saturating_mul(k), |chunk, r0, r1| {
        transb_rows(ad, bd, chunk, r0, r1, k, n)
    });
    crate::obs::kernel_done(t0, crate::obs::KernelKind::MatmulTransb, gemm_ops(m, n, k));
    out
}

/// The serial dot-product kernel over output rows `[r0, r1)` of `a @ bᵀ`.
fn transb_rows(ad: &[f32], bd: &[f32], ochunk: &mut [f32], r0: usize, r1: usize, k: usize, n: usize) {
    for i in r0..r1 {
        let arow = &ad[i * k..(i + 1) * k];
        let orow = &mut ochunk[(i - r0) * n..(i - r0 + 1) * n];
        for (j, o) in orow.iter_mut().enumerate() {
            let brow = &bd[j * k..(j + 1) * k];
            let mut acc = 0.0f32;
            for (x, y) in arow.iter().zip(brow) {
                acc += x * y;
            }
            *o = acc;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive(a: &Tensor, b: &Tensor) -> Tensor {
        let (m, k, n) = (a.rows(), a.cols(), b.cols());
        let mut out = Tensor::zeros(&[m, n]);
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0;
                for p in 0..k {
                    acc += a.at(i, p) * b.at(p, j);
                }
                out.set(i, j, acc);
            }
        }
        out
    }

    #[test]
    fn matches_naive() {
        for &(m, k, n) in &[(1, 1, 1), (3, 5, 2), (17, 33, 9), (64, 128, 32)] {
            let a = Tensor::randn(&[m, k], (m * k) as u64);
            let b = Tensor::randn(&[k, n], (k * n + 1) as u64);
            let got = matmul(&a, &b);
            let want = naive(&a, &b);
            assert!(got.max_abs_diff(&want) < 1e-3, "{m}x{k}x{n}");
        }
    }

    #[test]
    fn transb_matches() {
        let a = Tensor::randn(&[7, 11], 1);
        let b = Tensor::randn(&[5, 11], 2); // (n×k)
        let got = matmul_transb(&a, &b);
        let want = naive(&a, &b.transpose());
        assert!(got.max_abs_diff(&want) < 1e-4);
    }

    #[test]
    fn known_values() {
        let a = Tensor::from_vec(&[2, 2], vec![1., 2., 3., 4.]);
        let b = Tensor::from_vec(&[2, 2], vec![1., 1., 1., 1.]);
        let c = matmul(&a, &b);
        assert_eq!(c.data(), &[3., 3., 7., 7.]);
    }

    #[test]
    fn parallel_path_matches_naive() {
        // Big enough that m·n·k clears MIN_PARALLEL_ELEMS, so the threaded
        // path runs (unless STAMP_THREADS=1, where the serial path is the
        // contract anyway).
        let (m, k, n) = (96, 80, 72);
        let a = Tensor::randn(&[m, k], 21);
        let b = Tensor::randn(&[k, n], 22);
        assert!(matmul(&a, &b).max_abs_diff(&naive(&a, &b)) < 1e-3);
        let bt = Tensor::randn(&[n, k], 23);
        assert!(matmul_transb(&a, &bt).max_abs_diff(&naive(&a, &bt.transpose())) < 1e-3);
    }

    #[test]
    fn small_m_fast_path_is_bit_identical_across_threshold() {
        // Wide k·n so the work gate alone would fork; the row gate keeps
        // m ≤ GEMM_SERIAL_MAX_ROWS serial. A (threshold)×k product must be
        // byte-identical to the same rows computed inside a larger (forked)
        // product — row-wise kernels make this exact, not approximate.
        let (k, n) = (128usize, 512usize);
        let big = Tensor::randn(&[4 * super::GEMM_SERIAL_MAX_ROWS, k], 31);
        let b = Tensor::randn(&[k, n], 32);
        let full = matmul(&big, &b);
        let small = big.slice_rows(0, super::GEMM_SERIAL_MAX_ROWS);
        let fast = matmul(&small, &b);
        for i in 0..super::GEMM_SERIAL_MAX_ROWS {
            assert_eq!(fast.row(i), full.row(i), "row {i}");
        }
        let bt = Tensor::randn(&[n, k], 33);
        let full_t = matmul_transb(&big, &bt);
        let fast_t = matmul_transb(&small, &bt);
        for i in 0..super::GEMM_SERIAL_MAX_ROWS {
            assert_eq!(fast_t.row(i), full_t.row(i), "transb row {i}");
        }
        // The gate: few cheap rows stay serial, but a small-m product with
        // heavy rows (big-vocab logits head shape) remains fork-eligible.
        assert!(super::gemm_small_m_serial(super::GEMM_SERIAL_MAX_ROWS, k, n));
        assert!(!super::gemm_small_m_serial(super::GEMM_SERIAL_MAX_ROWS, 4096, 4096));
        assert!(!super::gemm_small_m_serial(super::GEMM_SERIAL_MAX_ROWS + 1, k, n));
        // And a heavy small-m product through the parallel path still
        // matches the naive reference.
        let a = Tensor::randn(&[4, 600], 34);
        let b = Tensor::randn(&[600, 600], 35);
        assert!(matmul(&a, &b).max_abs_diff(&naive(&a, &b)) < 1e-3);
    }

    #[test]
    fn spans_kc_boundary() {
        // k larger than the KC panel exercises the blocked accumulation.
        let a = Tensor::randn(&[4, 3 * super::KC + 5], 11);
        let b = Tensor::randn(&[3 * super::KC + 5, 6], 12);
        let got = matmul(&a, &b);
        let want = naive(&a, &b);
        assert!(got.max_abs_diff(&want) < 1e-3);
    }
}
