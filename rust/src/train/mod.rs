//! Training loop for the tiny GPT models (build-time, like the python AOT
//! path: the request path never trains). Adam + cosine LR over the
//! synthetic corpus; produces the "FP model" whose quantized variants the
//! Table-2 harness evaluates.

use crate::data::Corpus;
use crate::model::{Gpt, GptConfig};
use crate::tensor::XorShiftRng;

/// Adam optimizer over the model's flattened parameter visit order.
pub struct Adam {
    lr: f32,
    beta1: f32,
    beta2: f32,
    eps: f32,
    m: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
    t: i32,
}

impl Adam {
    pub fn new(lr: f32) -> Self {
        Adam { lr, beta1: 0.9, beta2: 0.999, eps: 1e-8, m: Vec::new(), v: Vec::new(), t: 0 }
    }

    /// One optimizer step over the model parameters.
    pub fn step(&mut self, model: &mut Gpt, lr_scale: f32) {
        self.t += 1;
        let t = self.t;
        let (b1, b2, eps) = (self.beta1, self.beta2, self.eps);
        let lr = self.lr * lr_scale;
        let bc1 = 1.0 - b1.powi(t);
        let bc2 = 1.0 - b2.powi(t);
        let mut idx = 0usize;
        let m = &mut self.m;
        let v = &mut self.v;
        model.visit_params(&mut |p, g| {
            if m.len() <= idx {
                m.push(vec![0.0; p.len()]);
                v.push(vec![0.0; p.len()]);
            }
            let ms = &mut m[idx];
            let vs = &mut v[idx];
            assert_eq!(ms.len(), p.len(), "param order must be stable");
            for i in 0..p.len() {
                ms[i] = b1 * ms[i] + (1.0 - b1) * g[i];
                vs[i] = b2 * vs[i] + (1.0 - b2) * g[i] * g[i];
                let mhat = ms[i] / bc1;
                let vhat = vs[i] / bc2;
                p[i] -= lr * mhat / (vhat.sqrt() + eps);
            }
            idx += 1;
        });
    }
}

/// Training configuration.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub steps: usize,
    pub seq_len: usize,
    pub lr: f32,
    pub warmup: usize,
    pub log_every: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig { steps: 300, seq_len: 64, lr: 3e-3, warmup: 20, log_every: 50 }
    }
}

/// Train a GPT on the corpus; returns the per-log-step loss curve.
pub fn train_gpt(
    model: &mut Gpt,
    corpus: &Corpus,
    cfg: &TrainConfig,
    seed: u64,
    mut log: impl FnMut(usize, f64),
) -> Vec<(usize, f64)> {
    let seqs = corpus.sequences(cfg.seq_len);
    assert!(!seqs.is_empty(), "corpus shorter than one sequence");
    let mut adam = Adam::new(cfg.lr);
    let mut rng = XorShiftRng::new(seed);
    let mut curve = Vec::new();
    for step in 0..cfg.steps {
        let seq = seqs[rng.next_below(seqs.len())];
        let (loss, cache) = model.forward_loss(seq);
        model.zero_grad();
        model.backward(&cache);
        // Warmup then cosine decay.
        let lr_scale = if step < cfg.warmup {
            (step + 1) as f32 / cfg.warmup as f32
        } else {
            let p = (step - cfg.warmup) as f32 / (cfg.steps - cfg.warmup).max(1) as f32;
            0.5 * (1.0 + (std::f32::consts::PI * p).cos())
        };
        adam.step(model, lr_scale);
        if step % cfg.log_every == 0 || step + 1 == cfg.steps {
            log(step, loss);
            curve.push((step, loss));
        }
    }
    curve
}

/// Train one of the named Table-2 model variants on a fresh corpus.
/// Returns (model, corpus).
pub fn build_trained_model(which: &str, steps: usize) -> (Gpt, Corpus) {
    let (cfg, seed) = match which {
        "tiny" => (GptConfig::tiny(), 11),
        "small" => (GptConfig::small(), 22),
        "medium" => (GptConfig::medium(), 33),
        "wide" => (GptConfig::wide(), 44),
        other => panic!("unknown model variant {other}"),
    };
    let corpus = Corpus::generate(40_000, 123);
    assert!(cfg.vocab_size >= corpus.tokenizer.vocab_size(), "vocab too small for corpus");
    let mut model = Gpt::new(cfg, seed);
    let tc = TrainConfig { steps, ..Default::default() };
    train_gpt(&mut model, &corpus, &tc, seed ^ 0xfeed, |_, _| {});
    // Reproduce the massive-activation channels of real LLMs (exactly
    // function-preserving; see Gpt::inject_outlier_channels docs). The
    // 30x magnitude matches the order reported by Sun et al. 2024.
    let d = model.cfg.d_model;
    model.inject_outlier_channels((d / 32).max(2), 30.0);
    (model, corpus)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn training_reduces_loss_substantially() {
        let corpus = Corpus::generate(20_000, 9);
        let mut model = Gpt::new(GptConfig::tiny(), 10);
        let cfg = TrainConfig { steps: 120, seq_len: 64, lr: 3e-3, warmup: 10, log_every: 40 };
        let curve = train_gpt(&mut model, &corpus, &cfg, 1, |_, _| {});
        let first = curve.first().unwrap().1;
        let last = curve.last().unwrap().1;
        // Start near ln(64)≈4.16; corpus grammar is low-entropy so a tiny
        // model should at least halve the loss in ~100 steps.
        // The grammar's conditional entropy floor is ≈2.3 nats, so expect
        // a drop of at least ~1.3 nats in 120 steps rather than a ratio.
        assert!(first > 3.5, "init loss {first}");
        assert!(last < first - 1.2, "train failed: {first} -> {last}");
    }

    #[test]
    fn adam_param_order_stable() {
        let corpus = Corpus::generate(5_000, 9);
        let mut model = Gpt::new(GptConfig::tiny(), 10);
        let cfg = TrainConfig { steps: 3, seq_len: 32, lr: 1e-3, warmup: 1, log_every: 10 };
        // Would panic inside Adam::step on an order mismatch.
        train_gpt(&mut model, &corpus, &cfg, 2, |_, _| {});
    }
}
