//! Integer quantization substrate (paper §2.1).
//!
//! Implements the quantize/de-quantize pair `Q(X) = Q⁻¹(Q_int(X))` of
//! Eq. 1 with asymmetric min-max scales, at three granularities
//! (per-tensor / per-token / per-block), with a *per-token bit width*
//! `b_i` so the mixed-precision allocation of §3.1/§3.3 plugs in directly.
//!
//! Two execution forms share one rounding rule ([`QuantParams::code`]):
//! the f32 *simulation* ([`quantize_dequantize_rows`], [`Quantizer::apply`])
//! and the *packed* integer form ([`QTensor`], [`Quantizer::quantize`])
//! that stores real 4/8-bit codes for [`crate::tensor::qgemm`].

mod bitalloc;
mod error;
mod qdq;
mod qtensor;

pub use bitalloc::{optimal_bits, two_level_bits, BitAllocation};
pub use error::{quantization_error, theorem1_bound};
pub use qdq::{quantize_dequantize_rows, QuantParams};
pub use qtensor::QTensor;

use crate::tensor::Tensor;

/// Scale/offset sharing granularity.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Granularity {
    /// One scale for the whole matrix.
    PerTensor,
    /// One scale per token (row) — the paper's default for activations.
    PerToken,
    /// One scale per contiguous block of `block` features within a row —
    /// SVDQuant-style block quantization (Fig. 9 / Table 1 setting).
    PerBlock { block: usize },
    /// Microscaling (LATMiX-style): a fixed *hardware-friendly* block of
    /// 16 or 32 features per scale. Numerically identical to
    /// `PerBlock { block }` — same min-max parameters, same rounding —
    /// but the restricted geometry is a contract the integer GEMM
    /// exploits: whole 16-element packed chunks per block, so the
    /// per-block scale folding runs in-register off cached chunk sums
    /// instead of the generic segment walk (rust/DESIGN.md §17).
    MicroBlock { block: usize },
}

impl Granularity {
    /// Effective *storage* bits per element contributed by the fp16 scale
    /// and zero-point parameters, used for the Fig. 9 average-bit-width
    /// accounting (paper Appendix C: "16 bits for each scale parameter").
    pub fn param_overhead_bits(&self, d: usize) -> f64 {
        let per_group = 32.0; // fp16 scale + fp16 offset
        match self {
            Granularity::PerTensor => 0.0, // amortized to nothing
            Granularity::PerToken => per_group / d as f64,
            Granularity::PerBlock { block } | Granularity::MicroBlock { block } => {
                per_group / *block as f64
            }
        }
    }
}

/// A complete activation quantization scheme.
#[derive(Clone, Debug)]
pub struct QuantScheme {
    pub granularity: Granularity,
    /// Bits for each token. Length 1 means "uniform".
    pub bits: BitAllocation,
}

impl QuantScheme {
    /// Uniform b-bit scheme at the given granularity.
    pub fn uniform(bits: u32, granularity: Granularity) -> Self {
        QuantScheme { granularity, bits: BitAllocation::uniform(bits) }
    }

    /// The paper's 2-level STaMP scheme: `hp_tokens` leading tokens at
    /// `hp_bits`, the rest at `lp_bits`.
    pub fn two_level(hp_tokens: usize, hp_bits: u32, lp_bits: u32, granularity: Granularity) -> Self {
        QuantScheme { granularity, bits: BitAllocation::two_level(hp_tokens, hp_bits, lp_bits) }
    }

    /// Average bits/element over `s` tokens of width `d`, *including* the
    /// scale-parameter overhead.
    pub fn average_bits(&self, s: usize, d: usize) -> f64 {
        self.bits.average_bits(s) + self.granularity.param_overhead_bits(d)
    }

    /// Quantize-dequantize an `s×d` activation matrix.
    pub fn apply(&self, x: &Tensor) -> Tensor {
        quantize_dequantize_rows(x, &self.bits, self.granularity)
    }
}

/// A quantizer bound to a fixed sequence length — precomputes the per-token
/// bit vector once and exposes the hot-path apply.
pub struct Quantizer {
    scheme: QuantScheme,
    bits_per_token: Vec<u32>,
}

impl Quantizer {
    pub fn new(scheme: QuantScheme, s: usize) -> Self {
        let bits_per_token = scheme.bits.resolve(s);
        Quantizer { scheme, bits_per_token }
    }

    pub fn scheme(&self) -> &QuantScheme {
        &self.scheme
    }

    pub fn bits_per_token(&self) -> &[u32] {
        &self.bits_per_token
    }

    pub fn apply(&self, x: &Tensor) -> Tensor {
        assert_eq!(x.rows(), self.bits_per_token.len());
        self.scheme.apply(x)
    }

    /// Quantize into packed integer form (the deployment path). The
    /// existing [`Quantizer::apply`] QDQ is exactly
    /// `self.dequantize(&self.quantize(x))` — bit-for-bit.
    pub fn quantize(&self, x: &Tensor) -> QTensor {
        assert_eq!(x.rows(), self.bits_per_token.len());
        QTensor::quantize(x, &self.scheme.bits, self.scheme.granularity)
    }

    /// Reconstruct f32 activations from a packed tensor.
    pub fn dequantize(&self, q: &QTensor) -> Tensor {
        q.dequantize()
    }

    /// Whether every resolved bit width packs into u8 lanes (4 or 8 bits)
    /// — the precondition for [`Quantizer::quantize`] and the integer GEMM.
    pub fn packable(&self) -> bool {
        self.bits_per_token.iter().all(|&b| b == 4 || b == 8)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn average_bits_two_level() {
        // Paper §3.3: 64 tokens at 8b, rest at 4b over 1024 tokens
        // → 4 + 64·4/1024 = 4.25 raw; PixArt has s=4096 → 4.0625.
        let sch = QuantScheme::two_level(64, 8, 4, Granularity::PerTensor);
        assert!((sch.bits.average_bits(4096) - 4.0625).abs() < 1e-9);
        assert!((sch.bits.average_bits(2048) - 4.125).abs() < 1e-9);
    }

    #[test]
    fn param_overhead() {
        let g = Granularity::PerBlock { block: 64 };
        assert!((g.param_overhead_bits(4096) - 0.5).abs() < 1e-9);
        let pt = Granularity::PerToken;
        assert!((pt.param_overhead_bits(64) - 0.5).abs() < 1e-9);
        // Microscaling pays the same per-block overhead as PerBlock.
        let m16 = Granularity::MicroBlock { block: 16 };
        assert!((m16.param_overhead_bits(4096) - 2.0).abs() < 1e-9);
        let m32 = Granularity::MicroBlock { block: 32 };
        assert!((m32.param_overhead_bits(4096) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn high_bits_near_lossless() {
        let x = Tensor::randn(&[32, 64], 1);
        let sch = QuantScheme::uniform(16, Granularity::PerToken);
        let xq = sch.apply(&x);
        assert!(xq.max_abs_diff(&x) < 1e-3);
    }

    #[test]
    fn more_bits_less_error() {
        let x = Tensor::randn(&[32, 64], 2);
        let mut last = f64::MAX;
        for b in [2u32, 4, 6, 8] {
            let sch = QuantScheme::uniform(b, Granularity::PerToken);
            let err = sch.apply(&x).sub(&x).sq_norm();
            assert!(err < last, "bits {b}: err {err} !< {last}");
            last = err;
        }
    }

    #[test]
    fn quantizer_packed_roundtrip_matches_apply() {
        let x = Tensor::randn(&[16, 32], 21);
        let q = Quantizer::new(QuantScheme::two_level(4, 8, 4, Granularity::PerToken), 16);
        let packed = q.quantize(&x);
        assert_eq!(q.dequantize(&packed), q.apply(&x), "packed QDQ must equal simulated QDQ");
        assert!(q.packable());
        let wide = Quantizer::new(QuantScheme::uniform(16, Granularity::PerToken), 16);
        assert!(!wide.packable());
    }

    #[test]
    fn quantizer_resolves_bits() {
        let q = Quantizer::new(QuantScheme::two_level(4, 8, 4, Granularity::PerToken), 16);
        assert_eq!(&q.bits_per_token()[..5], &[8, 8, 8, 8, 4]);
        assert_eq!(q.bits_per_token().len(), 16);
    }
}
