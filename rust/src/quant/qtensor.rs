//! Bit-packed integer activation storage — the deployment-side counterpart
//! of the simulated f32 QDQ ([`super::quantize_dequantize_rows`]).
//!
//! A [`QTensor`] holds the integer codes `Q_int(X)` of Eq. 1 packed into
//! u8 words (4-bit rows two codes per byte, 8-bit rows one), plus the
//! per-group [`QuantParams`] needed to reconstruct
//! `X ≈ (Q_int(X) − zero)·scale`. Rows may carry *different* bit widths
//! (the two-level mixed-precision allocation of §3.1/§3.3), and groups
//! follow the same three granularities as the simulated path
//! (per-tensor / per-token / per-block).
//!
//! The packing funnels every code through [`QuantParams::code`] — the same
//! expression the f32 QDQ uses — so `QTensor::quantize(x).dequantize()` is
//! **bit-for-bit identical** to [`super::quantize_dequantize_rows`] (the
//! `packed_roundtrip_is_exact` property in `tests/packed.rs` holds this
//! invariant across shapes, bit mixes, and granularities). Unlike the
//! simulation, though, the payload here is real: `storage_bits` is the
//! footprint a deployment ships, reproducing the `average_bits` accounting
//! of the paper's tables (Appendix C: 16-bit scale + 16-bit offset per
//! group) for the per-token/per-block layouts the tables report — see
//! [`QTensor::average_storage_bits`] for the per-tensor caveat.

use super::qdq::QuantParams;
use super::{BitAllocation, Granularity};
use crate::parallel;
use crate::tensor::Tensor;
use std::fmt;
use std::sync::{Arc, OnceLock};

/// A 2-D matrix of bit-packed integer quantization codes with per-group
/// scale/zero parameters. Produced by [`QTensor::quantize`] (or
/// [`super::Quantizer::quantize`]), consumed by
/// [`crate::tensor::qgemm`] and [`QTensor::dequantize`].
#[derive(Clone)]
pub struct QTensor {
    rows: usize,
    cols: usize,
    granularity: Granularity,
    /// Resolved bit width per row; packable widths are 4 and 8.
    row_bits: Vec<u32>,
    /// Packed codes; row `r` occupies `data[row_offsets[r]..row_offsets[r+1]]`.
    /// 4-bit rows store two codes per byte, low nibble first.
    data: Vec<u8>,
    row_offsets: Vec<usize>,
    /// Per-group parameters, `groups_per_row` entries per row, row-major.
    /// For micro-block granularity this row-major table *is* the compact
    /// per-block scale layout: `cols/block` entries per row, contiguous,
    /// indexed by block in step with the packed codes.
    params: Vec<QuantParams>,
    /// Effective group length along a row (= cols for per-tensor/per-token).
    group: usize,
    /// Lazily-built GEMM-side caches (chunk sums, unpacked image). Behind
    /// an `Arc` so clones share one build; derived purely from the
    /// immutable payload, so sharing is always sound.
    prep: Arc<GemmPrep>,
}

/// Caches `qgemm` derives from a tensor's packed payload, built on first
/// use and kept for the tensor's lifetime. For served weights (held in
/// `baselines::PreparedWeights`) that means once per variant rather than
/// once per call — decode-shaped products previously re-derived both per
/// *token*.
#[derive(Default)]
struct GemmPrep {
    /// Per-row sums of each aligned 16-element code chunk (`cols/16` per
    /// row, row-major, i32: 16·255 fits trivially). Segment code sums are
    /// assembled from these plus scalar edges.
    chunk_sums: OnceLock<Vec<i32>>,
    /// Fully unpacked `rows×cols` code image — only materialized for the
    /// mixed 8-bit×4-bit GEMM pairing, which dots bytes against it.
    codes: OnceLock<Vec<u8>>,
}

/// Packed bytes for one row of `cols` codes at `bits`.
fn row_bytes(cols: usize, bits: u32) -> usize {
    match bits {
        8 => cols,
        4 => cols.div_ceil(2),
        _ => unreachable!("packable bit widths are 4 and 8"),
    }
}

/// Pack a row of integer codes (each `< 2^bits`) into `out`.
fn pack_codes(codes: &[u8], bits: u32, out: &mut [u8]) {
    match bits {
        8 => out.copy_from_slice(codes),
        4 => {
            for (byte, pair) in out.iter_mut().zip(codes.chunks(2)) {
                *byte = pair[0] | (pair.get(1).copied().unwrap_or(0) << 4);
            }
        }
        _ => unreachable!("packable bit widths are 4 and 8"),
    }
}

impl QTensor {
    /// Quantize an `s×d` matrix into packed integer form. Mirrors
    /// [`super::quantize_dequantize_rows`] exactly (same per-row bit
    /// resolution, same group parameters, same rounding) but stores the
    /// codes instead of immediately dequantizing them.
    ///
    /// Row-parallel like the simulated path: rows split into contiguous
    /// chunks across the [`crate::parallel`] workers (packed rows have
    /// variable byte strides, so the buffer is split at the precomputed
    /// row offsets), with the identical serial fallback under
    /// `STAMP_THREADS=1` or below the work threshold.
    ///
    /// Panics if any resolved bit width is not 4 or 8 — wider simulated
    /// widths have no packed lane format.
    pub fn quantize(x: &Tensor, bits: &BitAllocation, gran: Granularity) -> QTensor {
        let (s, d) = (x.rows(), x.cols());
        let row_bits: Vec<u32> = (0..s).map(|i| bits.bits_for(i, s)).collect();
        for (i, &b) in row_bits.iter().enumerate() {
            assert!(b == 4 || b == 8, "row {i}: packed lanes are 4- or 8-bit, got {b}-bit");
        }
        let group = match gran {
            Granularity::PerBlock { block } => {
                assert!(block > 0);
                block.min(d).max(1)
            }
            Granularity::MicroBlock { block } => {
                assert!(
                    block == 16 || block == 32,
                    "micro-block width must be 16 or 32, got {block}"
                );
                block.min(d).max(1)
            }
            _ => d.max(1),
        };
        let gpr = d.div_ceil(group);
        let mut row_offsets = Vec::with_capacity(s + 1);
        row_offsets.push(0usize);
        for &b in &row_bits {
            row_offsets.push(row_offsets.last().unwrap() + row_bytes(d, b));
        }
        let mut data = vec![0u8; *row_offsets.last().unwrap()];
        let mut params = vec![QuantParams { scale: 1.0, zero: 0.0, qmax: 0.0 }; s * gpr];

        // Per-tensor granularity: one global min/max pass; parameters stay
        // per row because the bit width may still vary per row.
        let global = if matches!(gran, Granularity::PerTensor) && s * d > 0 {
            let mut mn = f32::MAX;
            let mut mx = f32::MIN;
            for &v in x.data() {
                mn = mn.min(v);
                mx = mx.max(v);
            }
            Some((mn, mx))
        } else {
            None
        };

        let quantize_rows = |r0: usize, r1: usize, dchunk: &mut [u8], pchunk: &mut [QuantParams]| {
            let mut codes = vec![0u8; d];
            for r in r0..r1 {
                let b = row_bits[r];
                let dstart = row_offsets[r] - row_offsets[r0];
                let drow = &mut dchunk[dstart..dstart + row_bytes(d, b)];
                let prow = &mut pchunk[(r - r0) * gpr..(r - r0 + 1) * gpr];
                for (bi, blk) in x.row(r).chunks(group).enumerate() {
                    let p = match global {
                        Some((mn, mx)) => QuantParams::from_range(mn, mx, b),
                        None => QuantParams::min_max(blk, b),
                    };
                    let inv = 1.0 / p.scale;
                    for (c, &v) in codes[bi * group..bi * group + blk.len()].iter_mut().zip(blk)
                    {
                        *c = p.code(v, inv) as u8;
                    }
                    prow[bi] = p;
                }
                pack_codes(&codes[..d], b, drow);
            }
        };

        let threads = parallel::effective_threads();
        let ranges = parallel::split_ranges(s, threads);
        if threads == 1 || ranges.len() <= 1 || s * d < parallel::MIN_PARALLEL_ELEMS {
            quantize_rows(0, s, &mut data, &mut params);
        } else {
            std::thread::scope(|scope| {
                let mut drest: &mut [u8] = &mut data;
                let mut prest: &mut [QuantParams] = &mut params;
                for &(r0, r1) in &ranges {
                    let dlen = row_offsets[r1] - row_offsets[r0];
                    let (dchunk, dtail) = std::mem::take(&mut drest).split_at_mut(dlen);
                    drest = dtail;
                    let (pchunk, ptail) =
                        std::mem::take(&mut prest).split_at_mut((r1 - r0) * gpr);
                    prest = ptail;
                    let fr = &quantize_rows;
                    scope.spawn(move || fr(r0, r1, dchunk, pchunk));
                }
            });
        }

        QTensor {
            rows: s,
            cols: d,
            granularity: gran,
            row_bits,
            data,
            row_offsets,
            params,
            group,
            prep: Arc::new(GemmPrep::default()),
        }
    }

    /// Pack a weight matrix stored `[in, out]` into the transposed
    /// `[out, in]` layout the integer GEMM consumes: one row per output
    /// channel, quantized per row (`block = None`, per-output-channel) or
    /// per `block` consecutive in-entries within a row. Codes and
    /// parameters are exactly those of the column-grouped f32 weight QDQ
    /// (`crate::baselines::quantize_weight`) under the same settings.
    pub fn from_weight(w: &Tensor, bits: u32, block: Option<usize>) -> QTensor {
        let din = w.rows();
        let gran = match block {
            Some(b) => Granularity::PerBlock { block: b.min(din).max(1) },
            None => Granularity::PerToken,
        };
        QTensor::quantize(&w.transpose(), &BitAllocation::uniform(bits), gran)
    }

    /// Reconstruct the f32 matrix `(Q_int(X) − zero)·scale`. Bit-for-bit
    /// identical to what [`super::quantize_dequantize_rows`] returns for
    /// the same input/allocation/granularity.
    pub fn dequantize(&self) -> Tensor {
        let mut out = Tensor::zeros(&[self.rows, self.cols]);
        let (d, group) = (self.cols, self.group);
        if self.rows == 0 || d == 0 {
            return out;
        }
        parallel::for_each_chunk_mut(out.data_mut(), self.rows, d, |_, (r0, _), chunk| {
            let mut scratch = vec![0u8; d];
            for (local, orow) in chunk.chunks_mut(d).enumerate() {
                let r = r0 + local;
                // 8-bit rows already hold one code per byte — read the
                // packed payload in place instead of copying it through
                // the scratch row (every dequantize-on-read gather in the
                // kvcache pays this per hp row otherwise).
                let codes: &[u8] = if self.row_bits[r] == 8 {
                    self.packed_row(r)
                } else {
                    self.unpack_row_into(r, &mut scratch);
                    &scratch
                };
                let prow = self.row_params(r);
                for (bi, oblk) in orow.chunks_mut(group).enumerate() {
                    let p = prow[bi];
                    let cblk = &codes[bi * group..bi * group + oblk.len()];
                    for (o, &c) in oblk.iter_mut().zip(cblk) {
                        *o = (c as f32 - p.zero) * p.scale;
                    }
                }
            }
        });
        out
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn granularity(&self) -> Granularity {
        self.granularity
    }

    /// Bit width of row `r`.
    pub fn bits_for_row(&self, r: usize) -> u32 {
        self.row_bits[r]
    }

    /// Effective group length along a row (equals `cols` for per-tensor
    /// and per-token granularity).
    pub fn group_len(&self) -> usize {
        self.group
    }

    /// Quantization groups per row.
    pub fn groups_per_row(&self) -> usize {
        self.cols.div_ceil(self.group)
    }

    /// Scale/zero parameters for row `r`, one entry per group.
    pub fn row_params(&self, r: usize) -> &[QuantParams] {
        let gpr = self.groups_per_row();
        &self.params[r * gpr..(r + 1) * gpr]
    }

    /// The packed bytes of row `r`.
    pub fn packed_row(&self, r: usize) -> &[u8] {
        &self.data[self.row_offsets[r]..self.row_offsets[r + 1]]
    }

    /// Expand row `r` into one integer code per column. `dst.len()` must
    /// equal `cols`.
    pub fn unpack_row_into(&self, r: usize, dst: &mut [u8]) {
        assert_eq!(dst.len(), self.cols);
        let packed = self.packed_row(r);
        match self.row_bits[r] {
            8 => dst.copy_from_slice(packed),
            4 => {
                for (pair, &byte) in dst.chunks_mut(2).zip(packed) {
                    pair[0] = byte & 0x0F;
                    if let Some(hi) = pair.get_mut(1) {
                        *hi = byte >> 4;
                    }
                }
            }
            _ => unreachable!("packable bit widths are 4 and 8"),
        }
    }

    /// Aligned 16-element chunks per row covered by [`Self::gemm_chunk_sums`]
    /// (full chunks only — a sub-16 tail is summed scalar by callers).
    pub(crate) fn sum_chunks_per_row(&self) -> usize {
        self.cols / 16
    }

    /// Per-row, per-16-element-chunk code sums, built in parallel on first
    /// use and cached for the tensor's lifetime (clones share the cache).
    /// Row-major, [`Self::sum_chunks_per_row`] entries per row.
    pub(crate) fn gemm_chunk_sums(&self) -> &[i32] {
        self.prep.chunk_sums.get_or_init(|| {
            let cpr = self.sum_chunks_per_row();
            let mut sums = vec![0i32; self.rows * cpr];
            if self.rows * cpr > 0 {
                parallel::for_each_chunk_mut(&mut sums, self.rows, cpr, |_, (r0, _), chunk| {
                    for (local, srow) in chunk.chunks_mut(cpr).enumerate() {
                        let r = r0 + local;
                        for (c, s) in srow.iter_mut().enumerate() {
                            *s = self.code_sum_span(r, c * 16, (c + 1) * 16) as i32;
                        }
                    }
                });
            }
            sums
        })
    }

    /// The fully unpacked `rows×cols` code image, built in parallel on
    /// first use and cached (clones share it). Only the mixed
    /// 8-bit-activation × 4-bit-weight GEMM pairing reads this; leaving it
    /// lazy keeps pure-4-bit serving free of the `rows×cols` footprint.
    pub(crate) fn gemm_codes(&self) -> &[u8] {
        self.prep.codes.get_or_init(|| {
            let (rows, cols) = (self.rows, self.cols);
            let mut codes = vec![0u8; rows * cols];
            if rows * cols > 0 {
                parallel::for_each_chunk_mut(&mut codes, rows, cols, |_, (r0, _), chunk| {
                    for (local, row) in chunk.chunks_mut(cols).enumerate() {
                        self.unpack_row_into(r0 + local, row);
                    }
                });
            }
            codes
        })
    }

    /// Exact sum of row `r`'s codes over elements `[start, end)`, straight
    /// off the packed payload: 8-bit rows sum bytes; 4-bit rows sum whole
    /// words via the SWAR byte-fold (16 nibbles ≤ 240 total, so the
    /// `·0x0101…` horizontal sum cannot overflow its top byte) with scalar
    /// nibble edges.
    pub(crate) fn code_sum_span(&self, r: usize, start: usize, end: usize) -> i64 {
        let packed = self.packed_row(r);
        if self.row_bits[r] == 8 {
            return packed[start..end].iter().map(|&c| c as i64).sum();
        }
        const LO_NIB: u64 = 0x0F0F_0F0F_0F0F_0F0F;
        const ONES: u64 = 0x0101_0101_0101_0101;
        let nib = |p: usize| ((packed[p / 2] >> (4 * (p % 2))) & 0x0F) as i64;
        let mut total = 0i64;
        let mut p = start;
        if p < end && p % 2 == 1 {
            total += nib(p);
            p += 1;
        }
        let b0 = p / 2;
        let words = (end - p) / 16;
        for w in packed[b0..b0 + words * 8].chunks_exact(8) {
            let w = u64::from_le_bytes(w.try_into().unwrap());
            let bytes = (w & LO_NIB) + ((w >> 4) & LO_NIB);
            total += (bytes.wrapping_mul(ONES) >> 56) as i64;
        }
        p += words * 16;
        while p < end {
            total += nib(p);
            p += 1;
        }
        total
    }

    /// Packed payload size in bytes (what a deployment actually ships for
    /// the codes; 4-bit rows of odd width carry one padding nibble).
    pub fn payload_bytes(&self) -> usize {
        self.data.len()
    }

    /// Total storage footprint in bits: the packed payload plus 16-bit
    /// scale + 16-bit zero per stored group (the Appendix-C accounting
    /// behind the tables' `average_bits` column). Per-tensor granularity
    /// stores one parameter pair per row because the two-level allocation
    /// lets the bit width — and hence `qmax`-derived scale — vary per row.
    pub fn storage_bits(&self) -> usize {
        self.data.len() * 8 + self.params.len() * 32
    }

    /// `storage_bits` per element. Matches
    /// [`super::QuantScheme::average_bits`] exactly for per-token and
    /// block-divisible per-block layouts; per-tensor granularity reads
    /// `32/cols` bits/element *higher* here (that accounting amortizes
    /// parameters to zero, while this struct stores a pair per row since
    /// the two-level allocation varies the bit width per row), and 4-bit
    /// rows of odd width carry one padding nibble the accounting omits.
    pub fn average_storage_bits(&self) -> f64 {
        if self.rows * self.cols == 0 {
            return 0.0;
        }
        self.storage_bits() as f64 / (self.rows * self.cols) as f64
    }
}

impl fmt::Debug for QTensor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "QTensor[{}x{} {:?}, {} groups/row, {} payload bytes]",
            self.rows,
            self.cols,
            self.granularity,
            self.groups_per_row(),
            self.data.len()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::quantize_dequantize_rows;

    #[test]
    fn roundtrip_matches_qdq_all_granularities() {
        let x = Tensor::randn(&[17, 23], 3);
        let bits = BitAllocation::two_level(5, 8, 4);
        for gran in [
            Granularity::PerTensor,
            Granularity::PerToken,
            Granularity::PerBlock { block: 8 },
            Granularity::PerBlock { block: 64 }, // block > d clamps to d
            Granularity::MicroBlock { block: 16 },
            Granularity::MicroBlock { block: 32 }, // > d=23, clamps to d
        ] {
            let q = QTensor::quantize(&x, &bits, gran);
            let want = quantize_dequantize_rows(&x, &bits, gran);
            assert_eq!(q.dequantize(), want, "{gran:?} must round-trip bit-for-bit");
        }
    }

    #[test]
    fn roundtrip_exact_on_parallel_sizes() {
        // 512×256 clears MIN_PARALLEL_ELEMS, so the threaded packing path
        // runs on multi-core hosts; the result must not depend on it.
        let x = Tensor::randn(&[512, 256], 5);
        let bits = BitAllocation::two_level(64, 8, 4);
        let q = QTensor::quantize(&x, &bits, Granularity::PerToken);
        let want = quantize_dequantize_rows(&x, &bits, Granularity::PerToken);
        assert_eq!(q.dequantize(), want);
    }

    #[test]
    fn mixed_rows_pack_at_different_strides() {
        let x = Tensor::randn(&[4, 6], 7);
        let bits = BitAllocation::two_level(2, 8, 4);
        let q = QTensor::quantize(&x, &bits, Granularity::PerToken);
        // 8-bit rows: 6 bytes; 4-bit rows: 3 bytes.
        assert_eq!(q.packed_row(0).len(), 6);
        assert_eq!(q.packed_row(1).len(), 6);
        assert_eq!(q.packed_row(2).len(), 3);
        assert_eq!(q.packed_row(3).len(), 3);
        assert_eq!(q.bits_for_row(0), 8);
        assert_eq!(q.bits_for_row(3), 4);
        assert_eq!(q.payload_bytes(), 18);
    }

    #[test]
    fn unpack_handles_odd_width() {
        let x = Tensor::randn(&[2, 7], 9);
        let q = QTensor::quantize(&x, &BitAllocation::uniform(4), Granularity::PerToken);
        assert_eq!(q.packed_row(0).len(), 4); // 7 nibbles → 4 bytes
        let mut codes = vec![0u8; 7];
        q.unpack_row_into(0, &mut codes);
        assert!(codes.iter().all(|&c| c <= 15));
        // Round-trip through dequantize stays exact.
        let want = quantize_dequantize_rows(&x, &BitAllocation::uniform(4), Granularity::PerToken);
        assert_eq!(q.dequantize(), want);
    }

    #[test]
    fn storage_matches_average_bits_accounting() {
        // Uniform 4-bit per-token on an even width: payload is exactly
        // 4 bits/element, params add 32/d — the same 4.25 bits/element the
        // simulated accounting reports.
        let x = Tensor::randn(&[64, 128], 11);
        let q = QTensor::quantize(&x, &BitAllocation::uniform(4), Granularity::PerToken);
        let scheme = crate::quant::QuantScheme::uniform(4, Granularity::PerToken);
        let want = scheme.average_bits(64, 128);
        assert!(
            (q.average_storage_bits() - want).abs() < 1e-9,
            "packed {} vs accounted {want}",
            q.average_storage_bits()
        );
    }

    #[test]
    fn mixed_storage_between_lp_and_hp() {
        let x = Tensor::randn(&[128, 64], 13);
        let bits = BitAllocation::two_level(32, 8, 4);
        let q = QTensor::quantize(&x, &bits, Granularity::PerToken);
        let avg = q.average_storage_bits();
        // 0.25·8 + 0.75·4 = 5 payload bits + 0.5 param bits.
        assert!((avg - 5.5).abs() < 1e-9, "avg {avg}");
    }

    #[test]
    fn micro_block_stores_compact_scale_table() {
        // d=48 at micro16: three params per row, contiguous row-major —
        // the scale table rides directly beside the codes.
        let x = Tensor::randn(&[4, 48], 15);
        let q = QTensor::quantize(&x, &BitAllocation::uniform(4), Granularity::MicroBlock { block: 16 });
        assert_eq!(q.group_len(), 16);
        assert_eq!(q.groups_per_row(), 3);
        assert_eq!(q.row_params(2).len(), 3);
        // Numerically identical to PerBlock of the same width.
        let pb = QTensor::quantize(&x, &BitAllocation::uniform(4), Granularity::PerBlock { block: 16 });
        assert_eq!(q.dequantize(), pb.dequantize());
    }

    #[test]
    #[should_panic(expected = "micro-block width")]
    fn rejects_non_hardware_micro_widths() {
        let x = Tensor::randn(&[2, 48], 16);
        let _ = QTensor::quantize(&x, &BitAllocation::uniform(4), Granularity::MicroBlock { block: 24 });
    }

    #[test]
    fn chunk_and_span_sums_match_naive() {
        // Mixed 4/8-bit rows, odd width (d=45: two full chunks + a 13-wide
        // tail): the SWAR word-fold sums and the cached chunk sums must
        // equal the definitional unpacked sums over every alignment class.
        let x = Tensor::randn(&[6, 45], 17);
        let q = QTensor::quantize(&x, &BitAllocation::two_level(3, 8, 4), Granularity::PerToken);
        let mut codes = vec![0u8; 45];
        let cpr = q.sum_chunks_per_row();
        assert_eq!(cpr, 2);
        let sums = q.gemm_chunk_sums();
        for r in 0..6 {
            q.unpack_row_into(r, &mut codes);
            let naive =
                |s: usize, e: usize| codes[s..e].iter().map(|&c| c as i64).sum::<i64>();
            for c in 0..cpr {
                assert_eq!(sums[r * cpr + c] as i64, naive(c * 16, (c + 1) * 16), "row {r} chunk {c}");
            }
            for &(s, e) in &[(0usize, 45usize), (1, 44), (3, 3), (17, 32), (32, 45), (0, 16)] {
                assert_eq!(q.code_sum_span(r, s, e), naive(s, e), "row {r} span [{s},{e})");
            }
        }
        // The unpacked image cache matches unpack_row_into row-for-row.
        let img = q.gemm_codes();
        for r in 0..6 {
            q.unpack_row_into(r, &mut codes);
            assert_eq!(&img[r * 45..(r + 1) * 45], &codes[..], "row {r}");
        }
    }

    #[test]
    #[should_panic(expected = "packed lanes")]
    fn rejects_unpackable_bits() {
        let x = Tensor::randn(&[4, 8], 1);
        let _ = QTensor::quantize(&x, &BitAllocation::uniform(6), Granularity::PerToken);
    }

    #[test]
    fn empty_edges() {
        let x = Tensor::zeros(&[0, 8]);
        let q = QTensor::quantize(&x, &BitAllocation::uniform(4), Granularity::PerToken);
        assert_eq!(q.dequantize().shape(), &[0, 8]);
        assert_eq!(q.payload_bytes(), 0);
    }
}
