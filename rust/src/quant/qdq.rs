//! The quantize/de-quantize hot path.
//!
//! Asymmetric min-max quantization (Eq. 1 + the clipping-free scale of
//! §2.1): for a group `g` with bit width `b`,
//! `scale = range(g)/(2^b − 1)`, `zero = −min(g)/scale`, and
//! `QDQ(x) = (clamp(round(x/scale) + zero, 0, 2^b−1) − zero)·scale`.
//! With min-max scales the clamp never bites (by construction), leaving
//! pure rounding error — the regime Theorem 1 analyzes.

use super::{BitAllocation, Granularity};
use crate::tensor::Tensor;

/// Scale/offset for one quantization group.
#[derive(Clone, Copy, Debug)]
pub struct QuantParams {
    pub scale: f32,
    pub zero: f32,
    pub qmax: f32,
}

impl QuantParams {
    /// Min-max parameters for a slice at bit width `bits`.
    pub fn min_max(group: &[f32], bits: u32) -> Self {
        let mut mn = f32::MAX;
        let mut mx = f32::MIN;
        for &v in group {
            mn = mn.min(v);
            mx = mx.max(v);
        }
        QuantParams::from_range(mn, mx, bits)
    }

    /// Parameters from a precomputed `[mn, mx]` range at bit width `bits`
    /// (the per-tensor path computes the range once globally; both callers
    /// must share this derivation so packed and simulated quantization
    /// agree bit-for-bit).
    pub fn from_range(mn: f32, mx: f32, bits: u32) -> Self {
        debug_assert!(bits >= 1 && bits <= 24);
        let qmax = ((1u64 << bits) - 1) as f32;
        let range = (mx - mn).max(1e-12);
        let scale = range / qmax;
        let zero = (-mn / scale).round_ties_even();
        QuantParams { scale, zero, qmax }
    }

    /// The integer code `Q_int(v)` of Eq. 1, as an (integral) f32 in
    /// `[0, qmax]`. `inv` must be `1.0 / self.scale`, hoisted by callers'
    /// inner loops. Every quantization path — the simulated QDQ below and
    /// the bit-packing in [`super::QTensor`] — funnels through this one
    /// expression, so the packed store can never round differently from
    /// the f32 simulation.
    #[inline(always)]
    pub fn code(&self, v: f32, inv: f32) -> f32 {
        (v * inv + self.zero).round_ties_even().clamp(0.0, self.qmax)
    }

    /// Quantize-dequantize one value.
    #[inline(always)]
    pub fn qdq(&self, v: f32) -> f32 {
        (self.code(v, 1.0 / self.scale) - self.zero) * self.scale
    }

    /// Quantize-dequantize a slice in place.
    #[inline]
    pub fn qdq_slice(&self, group: &mut [f32]) {
        let inv = 1.0 / self.scale;
        for v in group.iter_mut() {
            *v = (self.code(*v, inv) - self.zero) * self.scale;
        }
    }
}

/// Quantize-dequantize an `s×d` matrix row-wise with per-token bit widths.
///
/// Every token (row) is an independent quantization problem once its
/// parameters are known, so the row loop runs chunked across the
/// [`crate::parallel`] workers for all three granularities; per-tensor
/// granularity first takes its one global min/max pass serially. Results
/// are bit-identical to the serial loop (each row's arithmetic is
/// untouched — only which thread computes it changes).
pub fn quantize_dequantize_rows(x: &Tensor, bits: &BitAllocation, gran: Granularity) -> Tensor {
    let (s, d) = (x.rows(), x.cols());
    let mut out = x.clone();
    if s == 0 || d == 0 {
        return out;
    }
    match gran {
        Granularity::PerTensor => {
            // One scale — but bit width may still vary per token, so compute
            // global min/max once and derive per-bit-width params from it.
            let data = out.data();
            let mut mn = f32::MAX;
            let mut mx = f32::MIN;
            for &v in data {
                mn = mn.min(v);
                mx = mx.max(v);
            }
            crate::parallel::for_each_chunk_mut(out.data_mut(), s, d, |_, (r0, _), chunk| {
                for (local, row) in chunk.chunks_mut(d).enumerate() {
                    let b = bits.bits_for(r0 + local, s);
                    QuantParams::from_range(mn, mx, b).qdq_slice(row);
                }
            });
        }
        Granularity::PerToken => {
            crate::parallel::for_each_chunk_mut(out.data_mut(), s, d, |_, (r0, _), chunk| {
                for (local, row) in chunk.chunks_mut(d).enumerate() {
                    let b = bits.bits_for(r0 + local, s);
                    let p = QuantParams::min_max(row, b);
                    p.qdq_slice(row);
                }
            });
        }
        Granularity::PerBlock { block } | Granularity::MicroBlock { block } => {
            assert!(block > 0);
            if matches!(gran, Granularity::MicroBlock { .. }) {
                assert!(
                    block == 16 || block == 32,
                    "micro-block width must be 16 or 32, got {block}"
                );
            }
            crate::parallel::for_each_chunk_mut(out.data_mut(), s, d, |_, (r0, _), chunk| {
                for (local, row) in chunk.chunks_mut(d).enumerate() {
                    let b = bits.bits_for(r0 + local, s);
                    for blk in row.chunks_mut(block.min(d)) {
                        let p = QuantParams::min_max(blk, b);
                        p.qdq_slice(blk);
                    }
                }
            });
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::BitAllocation;

    #[test]
    fn params_basic() {
        // [0, 1] at 2 bits → levels {0, 1/3, 2/3, 1}.
        let p = QuantParams::min_max(&[0.0, 1.0], 2);
        assert!((p.scale - 1.0 / 3.0).abs() < 1e-6);
        assert_eq!(p.zero, 0.0);
        assert!((p.qdq(0.5) - 1.0 / 3.0).abs() < 1e-6 || (p.qdq(0.5) - 2.0 / 3.0).abs() < 1e-6);
        assert!((p.qdq(1.0) - 1.0).abs() < 1e-6);
        assert!((p.qdq(0.0)).abs() < 1e-6);
    }

    #[test]
    fn minmax_endpoints_exact() {
        // Min-max asymmetric quantization represents min and max exactly
        // (up to the rounding of the zero point at fine scales).
        let data = vec![-3.7f32, 0.2, 1.9, 8.4];
        for bits in [4u32, 8] {
            let p = QuantParams::min_max(&data, bits);
            let step = p.scale;
            assert!((p.qdq(8.4) - 8.4).abs() <= step, "max at {bits}b");
            assert!((p.qdq(-3.7) + 3.7).abs() <= step, "min at {bits}b");
        }
    }

    #[test]
    fn rounding_error_bounded_by_half_scale() {
        let x = Tensor::randn(&[16, 32], 3);
        for i in 0..16 {
            let p = QuantParams::min_max(x.row(i), 4);
            for &v in x.row(i) {
                assert!((p.qdq(v) - v).abs() <= 0.5 * p.scale + 1e-6);
            }
        }
    }

    #[test]
    fn no_clipping_with_minmax() {
        // Quantized values must stay within [min, max] of the group, up to
        // the half-step shift introduced by rounding the zero point.
        let x = Tensor::randn(&[8, 64], 7);
        let out = quantize_dequantize_rows(&x, &BitAllocation::uniform(3), Granularity::PerToken);
        for i in 0..8 {
            let r = x.row(i);
            let mn = r.iter().cloned().fold(f32::MAX, f32::min);
            let mx = r.iter().cloned().fold(f32::MIN, f32::max);
            let step = QuantParams::min_max(r, 3).scale;
            for &v in out.row(i) {
                assert!(v >= mn - 0.51 * step && v <= mx + 0.51 * step);
            }
        }
    }

    #[test]
    fn per_block_better_than_per_token_with_outlier() {
        // A single outlier ruins the whole row's scale per-token, but only
        // one block's scale per-block.
        let mut x = Tensor::randn(&[4, 128], 9);
        for i in 0..4 {
            x.set(i, 0, 80.0);
        }
        let bits = BitAllocation::uniform(4);
        let pt = quantize_dequantize_rows(&x, &bits, Granularity::PerToken);
        let pb = quantize_dequantize_rows(&x, &bits, Granularity::PerBlock { block: 16 });
        assert!(pb.sub(&x).sq_norm() < pt.sub(&x).sq_norm());
    }

    #[test]
    fn micro_block_equals_per_block_of_same_width() {
        // MicroBlock is numerically PerBlock with a restricted geometry;
        // the simulated QDQ must be bit-identical at the same width.
        let x = Tensor::randn(&[8, 48], 19);
        let bits = BitAllocation::two_level(2, 8, 4);
        for block in [16usize, 32] {
            let micro = quantize_dequantize_rows(&x, &bits, Granularity::MicroBlock { block });
            let plain = quantize_dequantize_rows(&x, &bits, Granularity::PerBlock { block });
            assert_eq!(micro, plain, "block={block}");
        }
    }

    #[test]
    #[should_panic(expected = "micro-block width")]
    fn micro_block_rejects_odd_widths() {
        let x = Tensor::randn(&[2, 48], 20);
        let _ = quantize_dequantize_rows(
            &x,
            &BitAllocation::uniform(4),
            Granularity::MicroBlock { block: 24 },
        );
    }

    #[test]
    fn block_equal_to_token_when_block_is_row() {
        let x = Tensor::randn(&[6, 32], 11);
        let bits = BitAllocation::uniform(5);
        let pt = quantize_dequantize_rows(&x, &bits, Granularity::PerToken);
        let pb = quantize_dequantize_rows(&x, &bits, Granularity::PerBlock { block: 32 });
        assert_eq!(pt, pb);
    }

    #[test]
    fn mixed_bits_rows_differ() {
        let x = Tensor::randn(&[8, 64], 13);
        let two = BitAllocation::two_level(4, 8, 2);
        let out = quantize_dequantize_rows(&x, &two, Granularity::PerToken);
        // hp rows much closer than lp rows.
        let hp_err: f64 = (0..4)
            .map(|i| out.row(i).iter().zip(x.row(i)).map(|(a, b)| ((a - b) as f64).powi(2)).sum::<f64>())
            .sum();
        let lp_err: f64 = (4..8)
            .map(|i| out.row(i).iter().zip(x.row(i)).map(|(a, b)| ((a - b) as f64).powi(2)).sum::<f64>())
            .sum();
        assert!(hp_err * 100.0 < lp_err, "hp {hp_err} lp {lp_err}");
    }

    #[test]
    fn parallel_rows_match_serial_semantics() {
        // 512×256 clears the parallel threshold, so the chunked path runs;
        // every row must be bit-identical to the same row quantized inline.
        let x = Tensor::randn(&[512, 256], 17);
        let bits = BitAllocation::two_level(64, 8, 4);
        let out = quantize_dequantize_rows(&x, &bits, Granularity::PerToken);
        for i in [0usize, 63, 64, 200, 511] {
            let p = QuantParams::min_max(x.row(i), bits.bits_for(i, 512));
            let mut want = x.row(i).to_vec();
            p.qdq_slice(&mut want);
            assert_eq!(out.row(i), &want[..], "row {i}");
        }
    }

    #[test]
    fn constant_row_is_exact() {
        let x = Tensor::full(&[2, 16], 3.25);
        let out = quantize_dequantize_rows(&x, &BitAllocation::uniform(2), Granularity::PerToken);
        assert!(out.max_abs_diff(&x) < 1e-5);
    }
}
