//! Quantization-error functionals: the empirical error `L(X)` of Eq. 2 and
//! the Theorem-1 upper bound `d/2 · Σ E‖lᵢX‖² / (2^{b_i}−1)²`. These power
//! the Figure-2b reproduction and the bound-validity tests.

use super::{quantize_dequantize_rows, BitAllocation, Granularity};
use crate::tensor::Tensor;
use crate::transforms::SequenceTransform;

/// Empirical quantization error `‖Q(LX) − LX‖²` mapped back through `L⁻¹`
/// — for orthogonal `L` this equals the transformed-domain error (Eq. 10),
/// which is what we compute.
pub fn quantization_error(
    x: &Tensor,
    transform: &dyn SequenceTransform,
    bits: &BitAllocation,
    gran: Granularity,
) -> f64 {
    let lx = transform.forward(x);
    let q = quantize_dequantize_rows(&lx, bits, gran);
    q.sub(&lx).sq_norm()
}

/// End-to-end error measured in the *original* domain:
/// `‖L⁻¹ Q(L X) − X‖²`. Equal to [`quantization_error`] for orthogonal L
/// (up to round-off); kept separate so tests can verify that equality.
pub fn end_to_end_error(
    x: &Tensor,
    transform: &dyn SequenceTransform,
    bits: &BitAllocation,
    gran: Granularity,
) -> f64 {
    let lx = transform.forward(x);
    let q = quantize_dequantize_rows(&lx, bits, gran);
    transform.inverse(&q).sub(x).sq_norm()
}

/// Theorem-1 upper bound for a single sample:
/// `d/2 · Σ_i ‖(LX)_i‖² / (2^{b_i} − 1)²`.
pub fn theorem1_bound(x: &Tensor, transform: &dyn SequenceTransform, bits: &BitAllocation) -> f64 {
    let lx = transform.forward(x);
    let (s, d) = (lx.rows(), lx.cols());
    let mut acc = 0.0f64;
    for i in 0..s {
        let e: f64 = lx.row(i).iter().map(|&v| (v as f64) * (v as f64)).sum();
        let b = bits.bits_for(i, s);
        let denom = (((1u64 << b) - 1) as f64).powi(2);
        acc += e / denom;
    }
    acc * d as f64 / 2.0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transforms::{HaarDwt, IdentitySeq};

    #[test]
    fn bound_holds_identity() {
        let x = Tensor::randn(&[64, 32], 21);
        let t = IdentitySeq::new(64);
        for b in [2u32, 4, 8] {
            let bits = BitAllocation::uniform(b);
            let err = quantization_error(&x, &t, &bits, Granularity::PerToken);
            let bound = theorem1_bound(&x, &t, &bits);
            assert!(err <= bound, "b={b}: err {err} > bound {bound}");
        }
    }

    #[test]
    fn bound_holds_dwt_mixed_precision() {
        let x = Tensor::randn(&[128, 16], 22);
        let t = HaarDwt::new(128, 3);
        let bits = BitAllocation::two_level(16, 8, 4);
        let err = quantization_error(&x, &t, &bits, Granularity::PerToken);
        let bound = theorem1_bound(&x, &t, &bits);
        assert!(err <= bound, "err {err} > bound {bound}");
    }

    #[test]
    fn orthogonal_transform_preserves_error() {
        // Eq. 10: end-to-end error == transformed-domain error for
        // orthogonal L.
        let x = Tensor::randn(&[64, 16], 23);
        let t = HaarDwt::new(64, 2);
        let bits = BitAllocation::uniform(4);
        let a = quantization_error(&x, &t, &bits, Granularity::PerToken);
        let b = end_to_end_error(&x, &t, &bits, Granularity::PerToken);
        assert!((a - b).abs() / a < 1e-3, "transformed {a} vs e2e {b}");
    }

    #[test]
    fn stamp_beats_uniform_on_correlated_data() {
        // The paper's core claim at equal average bits: DWT + 2-level beats
        // identity + uniform on locally-correlated activations.
        use crate::linalg::{ar1_covariance, cholesky};
        let s = 256;
        let cov = ar1_covariance(s, 0.97, 1.0);
        let l = cholesky(&cov);
        let x = l.matmul(&Tensor::randn(&[s, 32], 24));

        // Uniform 5-bit vs STaMP {8b × 32 tokens, 4.625-avg → use 4b rest +
        // 32 hp = 4.5 avg, still below 5}.
        let id = IdentitySeq::new(s);
        let uni = quantization_error(&x, &id, &BitAllocation::uniform(5), Granularity::PerToken);
        let dwt = HaarDwt::new(s, 3);
        let stamp = quantization_error(
            &x,
            &dwt,
            &BitAllocation::two_level(32, 8, 4),
            Granularity::PerToken,
        );
        assert!(
            stamp < uni,
            "STaMP {stamp} !< uniform {uni} (avg bits {} vs 5)",
            BitAllocation::two_level(32, 8, 4).average_bits(s)
        );
    }
}
