//! Bit-width allocation across tokens (paper §3.3 + Appendix A.2).
//!
//! Given per-token energies `e`, the allocation minimizing the Theorem-1
//! bound under a total budget `B = Σ b_i` is the reverse-waterfilling
//! solution `b*_i = log₂√e_i + C`. Real hardware supports only a few
//! integer widths, so the paper ships the 2-level scheme: the leading
//! `hp_tokens` at `hp_bits`, everything else at `lp_bits`.

/// Declarative per-token bit-width policy.
#[derive(Clone, Debug, PartialEq)]
pub enum BitAllocation {
    /// Every token at the same width.
    Uniform(u32),
    /// First `hp_tokens` tokens at `hp_bits`, rest at `lp_bits` (STaMP).
    TwoLevel { hp_tokens: usize, hp_bits: u32, lp_bits: u32 },
    /// Fully explicit per-token widths.
    Explicit(Vec<u32>),
}

impl BitAllocation {
    pub fn uniform(bits: u32) -> Self {
        BitAllocation::Uniform(bits)
    }

    pub fn two_level(hp_tokens: usize, hp_bits: u32, lp_bits: u32) -> Self {
        BitAllocation::TwoLevel { hp_tokens, hp_bits, lp_bits }
    }

    /// Bit width of token `i` in a sequence of length `s`.
    pub fn bits_for(&self, i: usize, s: usize) -> u32 {
        match self {
            BitAllocation::Uniform(b) => *b,
            BitAllocation::TwoLevel { hp_tokens, hp_bits, lp_bits } => {
                if i < *hp_tokens {
                    *hp_bits
                } else {
                    *lp_bits
                }
            }
            BitAllocation::Explicit(v) => {
                assert_eq!(v.len(), s, "explicit allocation length mismatch");
                v[i]
            }
        }
    }

    /// Materialize the per-token widths for sequence length `s`.
    pub fn resolve(&self, s: usize) -> Vec<u32> {
        (0..s).map(|i| self.bits_for(i, s)).collect()
    }

    /// Average bits per token (excluding scale-parameter overhead). An
    /// empty sequence stores nothing, so `s == 0` yields 0.0 for the
    /// sequence-dependent variants (`Uniform` is a per-token width and
    /// stays `b` regardless).
    pub fn average_bits(&self, s: usize) -> f64 {
        match self {
            BitAllocation::Uniform(b) => *b as f64,
            BitAllocation::TwoLevel { hp_tokens, hp_bits, lp_bits } => {
                if s == 0 {
                    return 0.0;
                }
                let hp = (*hp_tokens).min(s) as f64;
                (hp * *hp_bits as f64 + (s as f64 - hp) * *lp_bits as f64) / s as f64
            }
            BitAllocation::Explicit(v) => {
                if v.is_empty() {
                    return 0.0;
                }
                v.iter().map(|&b| b as f64).sum::<f64>() / v.len() as f64
            }
        }
    }
}

/// Continuous-optimal allocation `b*_i = log₂ √e_i + C` for a total budget
/// of `total_bits` (Appendix A.2, Eq. 18). Returns real-valued widths;
/// callers floor/clamp for hardware.
pub fn optimal_bits(energies: &[f32], total_bits: f64) -> Vec<f64> {
    let s = energies.len();
    assert!(s > 0);
    let half_logs: Vec<f64> =
        energies.iter().map(|&e| 0.5 * (e.max(1e-30) as f64).log2()).collect();
    let c = (total_bits - half_logs.iter().sum::<f64>()) / s as f64;
    half_logs.iter().map(|&h| h + c).collect()
}

/// Integer, hardware-friendly projection of the optimal allocation onto
/// two levels {lp_bits, hp_bits}: pick `k` = number of high-precision
/// tokens that (greedily, by energy order) minimizes the Theorem-1 bound
/// subject to an average-bits budget. Energies must be sorted descending
/// (which they are after any of the sequence transforms).
pub fn two_level_bits(
    energies: &[f32],
    hp_bits: u32,
    lp_bits: u32,
    max_average_bits: f64,
) -> BitAllocation {
    let s = energies.len() as f64;
    // Max k under the average-bit budget.
    let extra_per_hp = (hp_bits - lp_bits) as f64;
    let budget_k = ((max_average_bits - lp_bits as f64) * s / extra_per_hp).floor().max(0.0)
        as usize;
    let k = budget_k.min(energies.len());

    // Verify monotonicity of benefit: adding hp tokens in energy order only
    // helps, so the budget-maximal k is also the bound-minimal one.
    BitAllocation::two_level(k, hp_bits, lp_bits)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn optimal_sums_to_budget() {
        let e = vec![16.0, 4.0, 1.0, 0.25];
        let b = optimal_bits(&e, 20.0);
        let sum: f64 = b.iter().sum();
        assert!((sum - 20.0).abs() < 1e-9);
    }

    #[test]
    fn optimal_follows_log_energy() {
        // e_i = 4·e_j ⇒ b_i = b_j + 1 (log₂√4 = 1).
        let e = vec![4.0, 1.0];
        let b = optimal_bits(&e, 10.0);
        assert!((b[0] - b[1] - 1.0).abs() < 1e-9);
    }

    #[test]
    fn optimal_equalizes_error_ratio() {
        // At the optimum, e_i / 2^{2 b_i} is constant (Eq. 13).
        let e = vec![100.0, 10.0, 1.0, 0.1];
        let b = optimal_bits(&e, 24.0);
        let ratios: Vec<f64> =
            e.iter().zip(&b).map(|(&ei, &bi)| ei as f64 / 2f64.powf(2.0 * bi)).collect();
        for r in &ratios[1..] {
            assert!((r - ratios[0]).abs() / ratios[0] < 1e-9);
        }
    }

    #[test]
    fn uniform_energies_give_uniform_bits() {
        let e = vec![2.0; 8];
        let b = optimal_bits(&e, 32.0);
        for &bi in &b {
            assert!((bi - 4.0).abs() < 1e-9);
        }
    }

    #[test]
    fn two_level_respects_budget() {
        let e: Vec<f32> = (0..1024).map(|i| 1.0 / (1.0 + i as f32)).collect();
        let alloc = two_level_bits(&e, 8, 4, 4.25);
        // 4.25 avg with {8,4} ⇒ k = 0.25·1024/4 = 64 tokens.
        assert_eq!(alloc, BitAllocation::two_level(64, 8, 4));
        assert!(alloc.average_bits(1024) <= 4.25 + 1e-9);
    }

    #[test]
    fn two_level_zero_budget_headroom() {
        let e = vec![1.0f32; 16];
        let alloc = two_level_bits(&e, 8, 4, 4.0);
        assert_eq!(alloc, BitAllocation::two_level(0, 8, 4));
    }

    #[test]
    fn bits_for_boundaries() {
        let a = BitAllocation::two_level(3, 8, 4);
        assert_eq!(a.bits_for(0, 10), 8);
        assert_eq!(a.bits_for(2, 10), 8);
        assert_eq!(a.bits_for(3, 10), 4);
        assert_eq!(a.bits_for(9, 10), 4);
    }

    #[test]
    fn explicit_allocation() {
        let a = BitAllocation::Explicit(vec![2, 4, 8]);
        assert_eq!(a.resolve(3), vec![2, 4, 8]);
        assert!((a.average_bits(3) - 14.0 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn two_level_boundary_hp_zero() {
        // hp_tokens == 0: every token is steady-state.
        let a = BitAllocation::two_level(0, 8, 4);
        assert_eq!(a.resolve(6), vec![4; 6]);
        assert!((a.average_bits(6) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn two_level_boundary_hp_saturates() {
        // hp_tokens ≥ s: every token is high-precision; the average must
        // clamp at hp_bits, not extrapolate past the sequence.
        let a = BitAllocation::two_level(16, 8, 4);
        assert_eq!(a.resolve(8), vec![8; 8]);
        assert!((a.average_bits(8) - 8.0).abs() < 1e-12);
        assert_eq!(a.bits_for(7, 8), 8);
    }

    #[test]
    fn empty_sequence_boundary() {
        // s == 0: nothing resolved, nothing stored (and no NaN from the
        // 0/0 the naive average would compute).
        let two = BitAllocation::two_level(4, 8, 4);
        assert!(two.resolve(0).is_empty());
        assert_eq!(two.average_bits(0), 0.0);
        assert_eq!(BitAllocation::Explicit(Vec::new()).average_bits(0), 0.0);
        assert!(BitAllocation::Explicit(Vec::new()).resolve(0).is_empty());
        // Uniform is a per-token width, independent of s.
        assert_eq!(BitAllocation::uniform(4).average_bits(0), 4.0);
    }

    #[test]
    fn average_bits_paper_numbers() {
        // SANA: s=2048, 64 hp tokens → 4.125 (paper §B.1).
        let a = BitAllocation::two_level(64, 8, 4);
        assert!((a.average_bits(2048) - 4.125).abs() < 1e-12);
        // PixArt-Σ: s=4096 → 4.0625.
        assert!((a.average_bits(4096) - 4.0625).abs() < 1e-12);
    }
}
