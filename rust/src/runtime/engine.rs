//! PJRT engine: client lifecycle + executable loading/execution.

use crate::tensor::Tensor;
use std::path::Path;

/// Errors surfaced from the PJRT layer.
#[derive(Debug)]
pub enum ExecError {
    Client(String),
    Load(String),
    Run(String),
}

impl std::fmt::Display for ExecError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ExecError::Client(m) => write!(f, "PJRT client error: {m}"),
            ExecError::Load(m) => write!(f, "artifact load error: {m}"),
            ExecError::Run(m) => write!(f, "execution error: {m}"),
        }
    }
}

impl std::error::Error for ExecError {}

/// A compiled executable, tied to the engine's client.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub source: String,
}

/// The PJRT engine: one client, many executables.
pub struct Engine {
    client: xla::PjRtClient,
}

impl Engine {
    /// CPU PJRT client (the only backend in this environment; a TPU/GPU
    /// plugin would slot in here unchanged).
    pub fn cpu() -> Result<Engine, ExecError> {
        let client = xla::PjRtClient::cpu().map_err(|e| ExecError::Client(e.to_string()))?;
        Ok(Engine { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn device_count(&self) -> usize {
        self.client.device_count()
    }

    /// Load + compile an HLO-text artifact.
    pub fn load(&self, path: &Path) -> Result<Executable, ExecError> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| ExecError::Load("non-utf8 path".into()))?,
        )
        .map_err(|e| ExecError::Load(format!("{}: {e}", path.display())))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| ExecError::Load(format!("compiling {}: {e}", path.display())))?;
        Ok(Executable { exe, source: path.display().to_string() })
    }

    /// Execute with f32 tensor inputs; returns flat f32 outputs.
    ///
    /// Artifacts are lowered with `return_tuple=True`, so the single
    /// result literal is a tuple that we flatten.
    pub fn run(&self, exe: &Executable, inputs: &[Tensor]) -> Result<Vec<Tensor>, ExecError> {
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| {
                let flat = xla::Literal::vec1(t.data());
                let shape: Vec<i64> = t.shape().iter().map(|&d| d as i64).collect();
                flat.reshape(&shape).map_err(|e| ExecError::Run(e.to_string()))
            })
            .collect::<Result<_, _>>()?;
        let result = self
            .exe_run(exe, &literals)?
            .to_tuple()
            .map_err(|e| ExecError::Run(format!("untupling result: {e}")))?;
        result
            .into_iter()
            .map(|lit| {
                let shape = lit
                    .array_shape()
                    .map_err(|e| ExecError::Run(e.to_string()))?;
                let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
                let data = lit.to_vec::<f32>().map_err(|e| ExecError::Run(e.to_string()))?;
                Ok(Tensor::from_vec(&dims, data))
            })
            .collect()
    }

    fn exe_run(&self, exe: &Executable, literals: &[xla::Literal]) -> Result<xla::Literal, ExecError> {
        let bufs = exe
            .exe
            .execute::<xla::Literal>(literals)
            .map_err(|e| ExecError::Run(format!("{}: {e}", exe.source)))?;
        bufs[0][0].to_literal_sync().map_err(|e| ExecError::Run(e.to_string()))
    }

    /// Convenience for smoke tests: run with zero-filled inputs of the
    /// given shapes, returning flat output vectors.
    pub fn run_f32(
        &self,
        exe: &Executable,
        input_shapes: &[Vec<usize>],
    ) -> Result<Vec<Vec<f32>>, ExecError> {
        let inputs: Vec<Tensor> = input_shapes.iter().map(|s| Tensor::zeros(s)).collect();
        Ok(self.run(exe, &inputs)?.into_iter().map(|t| t.into_vec()).collect())
    }
}
