//! Artifact registry: discovers AOT artifacts from `artifacts/manifest.toml`
//! (written by `python/compile/aot.py`) and maps model-variant names to
//! HLO files + input signatures.
//!
//! Manifest format (one section per artifact):
//! ```toml
//! [artifact.stamp_linear]
//! file = "stamp_linear.hlo.txt"
//! inputs = "256x128;128x64"   # `;`-separated, `x`-separated dims
//! outputs = "256x64"
//! ```

use crate::config::Toml;
use crate::error::{Error, Result};
use std::path::{Path, PathBuf};

#[derive(Clone, Debug)]
pub struct ArtifactManifest {
    pub name: String,
    pub file: String,
    pub inputs: String,
    pub outputs: String,
}

impl ArtifactManifest {
    /// Parse the `inputs` signature into shapes.
    pub fn input_shapes(&self) -> Vec<Vec<usize>> {
        parse_shapes(&self.inputs)
    }

    pub fn output_shapes(&self) -> Vec<Vec<usize>> {
        parse_shapes(&self.outputs)
    }
}

fn parse_shapes(sig: &str) -> Vec<Vec<usize>> {
    sig.split(';')
        .filter(|s| !s.trim().is_empty())
        .map(|s| s.trim().split('x').map(|d| d.parse::<usize>().expect("bad dim")).collect())
        .collect()
}

/// The registry: all artifacts in one directory.
pub struct ArtifactRegistry {
    dir: PathBuf,
    entries: Vec<ArtifactManifest>,
}

impl ArtifactRegistry {
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.toml");
        let text = std::fs::read_to_string(&manifest_path)
            .map_err(|e| crate::err!("reading {}: {e}", manifest_path.display()))?;
        let doc = Toml::parse(&text).map_err(Error::msg)?;
        let mut entries = Vec::new();
        for (section, kv) in &doc.sections {
            if let Some(name) = section.strip_prefix("artifact.") {
                entries.push(ArtifactManifest {
                    name: name.to_string(),
                    file: kv
                        .get("file")
                        .and_then(|v| v.as_str())
                        .ok_or_else(|| crate::err!("{section}: missing `file`"))?
                        .to_string(),
                    inputs: kv.get("inputs").and_then(|v| v.as_str()).unwrap_or("").to_string(),
                    outputs: kv.get("outputs").and_then(|v| v.as_str()).unwrap_or("").to_string(),
                });
            }
        }
        entries.sort_by(|a, b| a.name.cmp(&b.name));
        Ok(ArtifactRegistry { dir, entries })
    }

    pub fn entries(&self) -> &[ArtifactManifest] {
        &self.entries
    }

    pub fn get(&self, name: &str) -> Option<&ArtifactManifest> {
        self.entries.iter().find(|e| e.name == name)
    }

    pub fn path_for(&self, entry: &ArtifactManifest) -> PathBuf {
        self.dir.join(&entry.file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest() {
        let dir = std::env::temp_dir().join(format!("stamp-reg-test-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.toml"),
            "[artifact.alpha]\nfile = \"a.hlo.txt\"\ninputs = \"2x3;3x4\"\noutputs = \"2x4\"\n\
             [artifact.beta]\nfile = \"b.hlo.txt\"\ninputs = \"8\"\n",
        )
        .unwrap();
        let reg = ArtifactRegistry::load(&dir).unwrap();
        assert_eq!(reg.entries().len(), 2);
        let a = reg.get("alpha").unwrap();
        assert_eq!(a.input_shapes(), vec![vec![2, 3], vec![3, 4]]);
        assert_eq!(a.output_shapes(), vec![vec![2, 4]]);
        assert!(reg.path_for(a).ends_with("a.hlo.txt"));
        assert!(reg.get("gamma").is_none());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_manifest_errors() {
        assert!(ArtifactRegistry::load("/nonexistent-dir-xyz").is_err());
    }
}
