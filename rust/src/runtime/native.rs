//! Pure-Rust serving backend: runs quantized [`crate::model::gpt`] /
//! [`crate::model::dit`] forwards behind the [`Executor`] trait, so the
//! coordinator serves real quantized models in dependency-free builds
//! (no PJRT, no Python — the `pjrt` feature is purely additive).
//!
//! Each registered variant owns its model handle (shared via `Arc`, so many
//! variants can serve the same weights under different [`QuantStack`]s) and
//! an optional stack; `None` serves the FP reference. A stack with
//! [`QuantStack::packed`] set (the `quant.packed` config switch) serves
//! its forwards through the packed integer path — activations stored as
//! bit-packed [`crate::quant::QTensor`] codes, products computed by the
//! i32-accumulating [`crate::tensor::qgemm`] — instead of the f32 QDQ
//! simulation. One batch executes its requests sequentially on the calling
//! worker thread — parallelism comes from
//! [`crate::coordinator::WorkerPool`] at batch granularity (worker threads
//! are kernel-serial, see [`crate::parallel`]); when the executor is
//! driven directly, outside a pool, the matmul/QDQ/qgemm kernels fan out
//! instead. Either way every kernel is bit-identical at any thread count,
//! so served responses never depend on `STAMP_THREADS`.

use crate::baselines::{QuantHook, QuantStack};
use crate::coordinator::Executor;
use crate::model::{Dit, FpHook, Gpt, LinearHook};
use crate::tensor::Tensor;
use std::collections::HashMap;
use std::sync::Arc;

/// What a native variant runs.
pub enum NativeModel {
    /// Causal-LM next-token scoring: the request tensor is a `1×s` row of
    /// token ids encoded as f32 (the coordinator's tensor-only wire
    /// format); the response is the `s×vocab` logits matrix.
    Gpt(Arc<Gpt>),
    /// One denoising step at `t = 0` on a `seq×latent` latent under a fixed
    /// conditioning prompt; the response is the predicted residual.
    Dit { model: Arc<Dit>, prompt: String },
}

struct Variant {
    model: NativeModel,
    /// `None` serves the FP reference forward.
    stack: Option<QuantStack>,
}

/// Registry of named native variants implementing [`Executor`].
#[derive(Default)]
pub struct NativeExecutor {
    variants: HashMap<String, Variant>,
}

impl NativeExecutor {
    pub fn new() -> Self {
        NativeExecutor { variants: HashMap::new() }
    }

    /// Register a GPT variant (builder-style).
    pub fn with_gpt(mut self, name: &str, model: Arc<Gpt>, stack: Option<QuantStack>) -> Self {
        self.variants.insert(name.to_string(), Variant { model: NativeModel::Gpt(model), stack });
        self
    }

    /// Register a DiT variant conditioned on a fixed prompt.
    pub fn with_dit(
        mut self,
        name: &str,
        model: Arc<Dit>,
        prompt: &str,
        stack: Option<QuantStack>,
    ) -> Self {
        self.variants.insert(
            name.to_string(),
            Variant { model: NativeModel::Dit { model, prompt: prompt.to_string() }, stack },
        );
        self
    }

    /// Registered variant names (sorted), for wiring up the server.
    pub fn variant_names(&self) -> Vec<String> {
        let mut v: Vec<String> = self.variants.keys().cloned().collect();
        v.sort();
        v
    }

    fn run_one(&self, variant: &Variant, hook: &dyn LinearHook, input: &Tensor) -> Result<Tensor, String> {
        match &variant.model {
            NativeModel::Gpt(gpt) => {
                if input.ndim() != 2 || input.rows() != 1 {
                    return Err(format!("gpt variant expects a 1×s token row, got {:?}", input.shape()));
                }
                // Strict decode: `as u32` would saturate NaN/negatives to 0
                // and silently serve logits for token 0 on corrupt input.
                let tokens: Vec<u32> = input
                    .data()
                    .iter()
                    .map(|&v| {
                        if !v.is_finite() || v < 0.0 || v.fract() != 0.0 {
                            return Err(format!("non-token value {v} in request tensor"));
                        }
                        let t = v as u32;
                        if t as usize >= gpt.cfg.vocab_size {
                            return Err(format!("token {t} out of vocab {}", gpt.cfg.vocab_size));
                        }
                        Ok(t)
                    })
                    .collect::<Result<_, String>>()?;
                if tokens.len() > gpt.cfg.max_seq {
                    return Err(format!("sequence {} exceeds max_seq {}", tokens.len(), gpt.cfg.max_seq));
                }
                Ok(gpt.logits_hooked(hook, &tokens))
            }
            NativeModel::Dit { model, prompt } => {
                if input.ndim() != 2
                    || input.rows() != model.cfg.seq_len()
                    || input.cols() != model.latent_dim
                {
                    return Err(format!(
                        "dit variant expects {}×{} latents, got {:?}",
                        model.cfg.seq_len(),
                        model.latent_dim,
                        input.shape()
                    ));
                }
                Ok(model.denoise_step(hook, input, prompt, 0))
            }
        }
    }
}

impl Executor for NativeExecutor {
    fn execute(&self, variant: &str, inputs: &[&Tensor]) -> Result<Vec<Tensor>, String> {
        let v = self
            .variants
            .get(variant)
            .ok_or_else(|| format!("no native variant `{variant}`"))?;
        // The QuantHook's weight/STaMP caches are per-call interior state
        // (RefCell), so build one per batch — weights quantize once per
        // batch, which is the same amortization the eval harnesses get.
        let mut out = Vec::with_capacity(inputs.len());
        match &v.stack {
            Some(stack) => {
                let hook = QuantHook::new(stack);
                for x in inputs {
                    out.push(self.run_one(v, &hook, x)?);
                }
            }
            None => {
                for x in inputs {
                    out.push(self.run_one(v, &FpHook, x)?);
                }
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::{ActQuantCfg, BaselineKind, WeightQuantCfg};
    use crate::config::ServeSpec;
    use crate::coordinator::Server;
    use crate::model::{DitConfig, GptConfig};
    use std::time::Duration;

    fn tiny_gpt_exec() -> (NativeExecutor, Arc<Gpt>) {
        let gpt = Arc::new(Gpt::new(GptConfig::tiny(), 5));
        let act = ActQuantCfg { hp_tokens: 8, ..ActQuantCfg::w4a4_per_token() };
        let stack = QuantStack::build(
            BaselineKind::Rtn,
            &HashMap::new(),
            Some(act),
            None,
            None,
            1,
        );
        let exec = NativeExecutor::new()
            .with_gpt("fp", gpt.clone(), None)
            .with_gpt("rtn-a4", gpt.clone(), Some(stack));
        (exec, gpt)
    }

    fn token_row(n: usize) -> Tensor {
        let toks: Vec<f32> = (0..n).map(|i| ((i * 5) % 70) as f32).collect();
        Tensor::from_vec(&[1, n], toks)
    }

    #[test]
    fn fp_variant_matches_direct_forward() {
        let (exec, gpt) = tiny_gpt_exec();
        let input = token_row(16);
        let out = exec.execute("fp", &[&input]).unwrap();
        let tokens: Vec<u32> = input.data().iter().map(|&v| v as u32).collect();
        let want = gpt.logits_hooked(&FpHook, &tokens);
        assert_eq!(out.len(), 1);
        assert!(out[0].max_abs_diff(&want) < 1e-6);
    }

    #[test]
    fn quantized_variant_differs_but_stays_finite() {
        let (exec, _) = tiny_gpt_exec();
        let input = token_row(16);
        let fp = exec.execute("fp", &[&input]).unwrap().remove(0);
        let q = exec.execute("rtn-a4", &[&input]).unwrap().remove(0);
        assert!(q.all_finite());
        assert!(q.max_abs_diff(&fp) > 1e-6, "quantization must perturb logits");
    }

    #[test]
    fn rejects_unknown_variant_and_bad_shapes() {
        let (exec, _) = tiny_gpt_exec();
        let input = token_row(8);
        assert!(exec.execute("nope", &[&input]).unwrap_err().contains("no native variant"));
        let bad = Tensor::zeros(&[2, 8]);
        assert!(exec.execute("fp", &[&bad]).unwrap_err().contains("1×s"));
        let oov = Tensor::from_vec(&[1, 2], vec![0.0, 9999.0]);
        assert!(exec.execute("fp", &[&oov]).unwrap_err().contains("out of vocab"));
        // Corrupt values must be rejected, not saturated to token 0.
        for bad in [-1.0f32, f32::NAN, 0.5] {
            let t = Tensor::from_vec(&[1, 2], vec![1.0, bad]);
            assert!(
                exec.execute("fp", &[&t]).unwrap_err().contains("non-token value"),
                "{bad} must be rejected"
            );
        }
    }

    #[test]
    fn packed_variant_serves_and_is_thread_count_invariant() {
        let gpt = Arc::new(Gpt::new(GptConfig::tiny(), 11));
        let act = ActQuantCfg { hp_tokens: 8, ..ActQuantCfg::w4a4_per_token() };
        let mk = |packed: bool| {
            let s = QuantStack::build(
                BaselineKind::Rtn,
                &HashMap::new(),
                Some(act.clone()),
                Some(WeightQuantCfg::w4_per_channel()),
                None,
                1,
            );
            if packed {
                s.with_packed()
            } else {
                s
            }
        };
        let exec = NativeExecutor::new()
            .with_gpt("sim", gpt.clone(), Some(mk(false)))
            .with_gpt("packed", gpt, Some(mk(true)));
        let input = token_row(16);

        // Multi-threaded kernels (direct call) vs forced-serial kernels
        // must produce byte-identical responses.
        let threaded = exec.execute("packed", &[&input]).unwrap().remove(0);
        crate::parallel::set_kernel_serial(true);
        let serial = exec.execute("packed", &[&input]).unwrap().remove(0);
        crate::parallel::set_kernel_serial(false);
        assert_eq!(threaded, serial, "packed serving must not depend on thread count");

        // And the packed path tracks the simulated one tightly.
        let sim = exec.execute("sim", &[&input]).unwrap().remove(0);
        assert!(threaded.all_finite());
        let s = crate::stats::sqnr(&sim, &threaded);
        assert!(s > 35.0, "packed vs simulated served logits SQNR {s} dB");
    }

    #[test]
    fn dit_variant_serves_denoise_steps() {
        let dit = Arc::new(Dit::new(
            DitConfig { grid_h: 4, grid_w: 4, d_model: 32, n_heads: 2, n_layers: 1, d_ff: 64, ctx_tokens: 2, steps: 2 },
            7,
        ));
        let exec = NativeExecutor::new().with_dit("dit-fp", dit.clone(), "a red cube", None);
        let z = Tensor::randn(&[dit.cfg.seq_len(), dit.latent_dim], 3).scale(0.3);
        let out = exec.execute("dit-fp", &[&z]).unwrap().remove(0);
        assert_eq!(out.shape(), z.shape());
        assert!(out.all_finite());
        let want = dit.denoise_step(&FpHook, &z, "a red cube", 0);
        assert!(out.max_abs_diff(&want) < 1e-6);
    }

    #[test]
    fn serves_through_coordinator_end_to_end() {
        let (exec, gpt) = tiny_gpt_exec();
        let names = exec.variant_names();
        assert_eq!(names, vec!["fp".to_string(), "rtn-a4".to_string()]);
        let refs: Vec<&str> = names.iter().map(|s| s.as_str()).collect();
        let spec = ServeSpec { workers: 2, max_batch: 4, max_wait_us: 500, queue_depth: 16 };
        let server = Server::start(&spec, &refs, Arc::new(exec));
        let handle = server.handle();
        let input = token_row(12);
        let resp = handle.call("fp", input.clone(), Duration::from_secs(30)).unwrap();
        let logits = resp.output.unwrap();
        let tokens: Vec<u32> = input.data().iter().map(|&v| v as u32).collect();
        assert!(logits.max_abs_diff(&gpt.logits_hooked(&FpHook, &tokens)) < 1e-6);
        let resp = handle.call("rtn-a4", input, Duration::from_secs(30)).unwrap();
        assert!(resp.output.unwrap().all_finite());
        server.shutdown();
    }
}
