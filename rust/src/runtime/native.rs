//! Pure-Rust serving backend: runs quantized [`crate::model::gpt`] /
//! [`crate::model::dit`] forwards behind the [`Executor`] trait, so the
//! coordinator serves real quantized models in dependency-free builds
//! (no PJRT, no Python — the `pjrt` feature is purely additive).
//!
//! Each registered variant owns its model handle (shared via `Arc`, so many
//! variants can serve the same weights under different [`QuantStack`]s) and
//! an optional stack; `None` serves the FP reference. A stack with
//! [`QuantStack::packed`] set (the `quant.packed` config switch) serves
//! its forwards through the packed integer path — activations stored as
//! bit-packed [`crate::quant::QTensor`] codes, products computed by the
//! i32-accumulating [`crate::tensor::qgemm`] — instead of the f32 QDQ
//! simulation. Quantized/packed *weights* are built exactly once per
//! variant at registration ([`crate::baselines::PreparedWeights`]) and
//! shared across every execute call. GPT variants can additionally be
//! registered for multi-token generation
//! ([`NativeExecutor::with_gpt_generate`] /
//! [`NativeExecutor::with_gpt_generate_cfg`]), which decodes through the
//! [`crate::kvcache`] subsystem — and a whole coordinator batch of
//! generate requests is admitted into **one**
//! [`crate::decode::DecodeEngine`] run, so concurrent streams advance in
//! lock-step with their per-step activations fused into shared GEMMs
//! instead of N serial per-request loops.
//! A *forward* batch executes its requests sequentially on the calling
//! worker thread — parallelism comes from
//! [`crate::coordinator::WorkerPool`] at batch granularity (worker threads
//! are kernel-serial, see [`crate::parallel`]); when the executor is
//! driven directly, outside a pool, the matmul/QDQ/qgemm kernels fan out
//! instead. Either way every kernel is bit-identical at any thread count,
//! so served responses never depend on `STAMP_THREADS`.

use crate::baselines::{PreparedWeights, QuantHook, QuantStack};
use crate::config::ObsSpec;
use crate::coordinator::{Executor, StreamExecutor};
use crate::decode::{DecodeEngine, GenRequest, Sampling};
use crate::kvcache::KvCacheConfig;
use crate::model::{Dit, FpHook, Gpt, LinearHook};
use crate::obs::EngineObs;
use crate::tensor::Tensor;
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

/// What a native variant runs.
pub enum NativeModel {
    /// Causal-LM next-token scoring: the request tensor is a `1×s` row of
    /// token ids encoded as f32 (the coordinator's tensor-only wire
    /// format); the response is the `s×vocab` logits matrix.
    Gpt(Arc<Gpt>),
    /// Autoregressive generation through the step-synchronized
    /// [`DecodeEngine`]: the request tensor is a `1×(1+s)` row
    /// `[n_new, prompt…]` (all values token-id style f32 integers); the
    /// response is the `1×n_new` row of generated ids. A whole
    /// coordinator batch of these requests is admitted into **one**
    /// engine run — concurrent streams fuse into `[n_active × d_model]`
    /// GEMMs per step (`decode_batch` caps the fusion width) instead of
    /// decoding serially per request.
    GptGenerate {
        model: Arc<Gpt>,
        kv: KvCacheConfig,
        max_new: usize,
        sampling: Sampling,
        decode_batch: usize,
        max_inflight: usize,
        /// Speculative decode config (`[generate] speculative.*` knobs);
        /// `None` = plain one-token-per-step decode. Greedy-only — the
        /// engine builder enforces it.
        speculative: Option<crate::decode::SpecConfig>,
    },
    /// One denoising step at `t = 0` on a `seq×latent` latent under a fixed
    /// conditioning prompt; the response is the predicted residual.
    Dit { model: Arc<Dit>, prompt: String },
}

struct Variant {
    model: NativeModel,
    /// `None` serves the FP reference forward.
    stack: Option<QuantStack>,
    /// Weight caches built once at registration (when `stack` is set) and
    /// shared by every execute call — per-variant, not per-batch, so
    /// decode steps never pay a repack (ROADMAP hoist item).
    prepared: Option<PreparedWeights>,
    /// Generate variants keep ONE resident [`DecodeEngine`] for the life
    /// of the variant (PR 6) instead of building one per batch: the batch
    /// path runs on it, and the continuous-batching path
    /// ([`StreamExecutor`]) admits/steps it in place. Guarded by a mutex
    /// because [`Executor`]/[`StreamExecutor`] take `&self`.
    engine: Option<Mutex<DecodeEngine>>,
}

/// Build a variant's weight caches by running one dummy forward: weight
/// quantization depends only on the weights (never the sequence length),
/// so a single-token / zero-latent pass covers every site the stack will
/// ever quantize.
fn prepare(model: &NativeModel, stack: &QuantStack) -> PreparedWeights {
    let hook = QuantHook::new(stack);
    match model {
        NativeModel::Gpt(g) | NativeModel::GptGenerate { model: g, .. } => {
            let _ = g.logits_hooked(&hook, &[0]);
        }
        NativeModel::Dit { model, prompt } => {
            let z = Tensor::zeros(&[model.cfg.seq_len(), model.latent_dim]);
            let _ = model.denoise_step(&hook, &z, prompt, 0);
        }
    }
    hook.into_prepared()
}

/// Decode one `[n_new, prompt…]` generate-request row into an engine
/// [`GenRequest`], with the same strict validation the serial path had:
/// malformed heads and token values are rejected, never reinterpreted.
/// `cap` is the variant's effective cache capacity — the model's
/// `max_seq`, or a tighter caller-supplied `kv.max_seq` — so a request
/// the engine would have to *truncate* is rejected up front instead:
/// the wire contract is exactly `n_new` generated ids per request.
/// `None` means the variant's sliding-window policy makes its streams
/// unbounded: any prompt + budget is admissible.
fn parse_generate(
    input: &Tensor,
    model: &Gpt,
    max_new: usize,
    cap: Option<usize>,
) -> Result<GenRequest, String> {
    if input.ndim() != 2 || input.rows() != 1 || input.cols() < 2 {
        return Err(format!(
            "generate variant expects a 1×(1+s) [n_new, prompt…] row, got {:?}",
            input.shape()
        ));
    }
    let head = input.data()[0];
    if !head.is_finite() || head < 1.0 || head.fract() != 0.0 {
        return Err(format!("invalid n_new {head} in generate request"));
    }
    let n_new = head as usize;
    if n_new > max_new {
        return Err(format!("n_new {n_new} exceeds variant limit {max_new}"));
    }
    let prompt = parse_tokens(&input.data()[1..], model.cfg.vocab_size)?;
    if let Some(cap) = cap {
        if prompt.len() + n_new > cap {
            return Err(format!(
                "prompt {} + n_new {n_new} exceeds max_seq {cap}",
                prompt.len()
            ));
        }
    }
    Ok(GenRequest { prompt, n_new })
}

/// A generate variant's effective cache capacity: a tighter variant-level
/// `kv.max_seq` bound wins over the model's. Requests are validated
/// against it, so the engine never has to truncate a served stream (the
/// wire contract is exactly `n_new` ids per request). A sliding-window
/// variant is unbounded (`None`) unless the caller set an explicit
/// logical cap: long requests are admissible and decode past `max_seq`.
fn effective_cap(kv: &KvCacheConfig, model: &Gpt) -> Option<usize> {
    match kv.eviction {
        crate::kvcache::EvictionPolicy::None => {
            Some(kv.max_seq.map_or(model.cfg.max_seq, |m| m.min(model.cfg.max_seq)))
        }
        crate::kvcache::EvictionPolicy::SlidingWindow { .. } => kv.max_seq,
    }
}

/// Run `f` with the variant's serving hook: the prepared [`QuantHook`]
/// for stacked variants, [`FpHook`] otherwise. Factored out so the batch
/// [`Executor`] path and the per-step [`StreamExecutor`] path build their
/// hooks identically.
fn with_hook<R>(v: &Variant, f: impl FnOnce(&dyn LinearHook) -> R) -> R {
    match &v.stack {
        Some(stack) => {
            let hook = match &v.prepared {
                Some(p) => QuantHook::with_prepared(stack, p),
                None => QuantHook::new(stack),
            };
            f(&hook)
        }
        None => f(&FpHook),
    }
}

/// Decode a strict token-id row: NaN / negative / fractional / oversized
/// values are rejected rather than saturated (`as u32` would silently
/// serve token 0 on corrupt input).
fn parse_tokens(vals: &[f32], vocab: usize) -> Result<Vec<u32>, String> {
    vals.iter()
        .map(|&v| {
            if !v.is_finite() || v < 0.0 || v.fract() != 0.0 {
                return Err(format!("non-token value {v} in request tensor"));
            }
            let t = v as u32;
            if t as usize >= vocab {
                return Err(format!("token {t} out of vocab {vocab}"));
            }
            Ok(t)
        })
        .collect()
}

/// Registry of named native variants implementing [`Executor`].
#[derive(Default)]
pub struct NativeExecutor {
    variants: HashMap<String, Variant>,
    /// `[observability]` settings applied to every generate variant's
    /// engine (present and future); `None` = histograms only.
    obs: Option<ObsSpec>,
}

impl NativeExecutor {
    pub fn new() -> Self {
        NativeExecutor { variants: HashMap::new(), obs: None }
    }

    fn insert(&mut self, name: &str, model: NativeModel, stack: Option<QuantStack>) {
        let prepared = stack.as_ref().map(|s| prepare(&model, s));
        // Generate variants get their resident engine here, once — not
        // per batch: the engine (slot table, free list) lives as long as
        // the variant, so streams can join it while others are mid-decode.
        let engine = match &model {
            NativeModel::GptGenerate {
                model: g, kv, sampling, decode_batch, max_inflight, speculative, ..
            } => {
                let mut e = DecodeEngine::new(g.clone(), kv.clone(), sampling.clone())
                    .with_decode_batch(*decode_batch)
                    .with_max_inflight(*max_inflight);
                if let Some(sc) = speculative {
                    e = e.with_speculative(*sc);
                }
                if let Some(o) = &self.obs {
                    if o.trace_enabled {
                        e = e.with_obs(Arc::new(EngineObs::with_trace(o.trace_capacity)));
                    }
                }
                Some(Mutex::new(e))
            }
            _ => None,
        };
        self.variants.insert(name.to_string(), Variant { model, stack, prepared, engine });
    }

    /// Apply the `[observability]` config section (builder-style):
    /// enables process-wide kernel profiling when `kernel_profile` is
    /// set, and — when `trace.enabled` — gives every generate variant's
    /// engine a [`crate::obs::TraceRing`] of `trace.capacity` events
    /// (variants registered before *and* after this call). Engines must
    /// be idle, which they are during builder-style construction.
    pub fn with_observability(mut self, obs: &ObsSpec) -> Self {
        crate::obs::set_kernel_profile(obs.kernel_profile);
        if obs.trace_enabled {
            for v in self.variants.values() {
                if let Some(engine) = &v.engine {
                    let mut e = engine.lock().unwrap();
                    if !e.obs().trace_enabled() {
                        e.set_obs(Arc::new(EngineObs::with_trace(obs.trace_capacity)));
                    }
                }
            }
        }
        self.obs = Some(obs.clone());
        self
    }

    /// The [`EngineObs`] of a generate variant's resident engine (`None`
    /// for unknown or forward-only variants). This is the shared handle
    /// the coordinator links into its per-variant metrics and that
    /// [`NativeExecutor::drain_trace`] drains.
    pub fn engine_obs(&self, variant: &str) -> Option<Arc<EngineObs>> {
        let engine = self.variants.get(variant)?.engine.as_ref()?;
        let obs = engine.lock().unwrap().obs().clone();
        Some(obs)
    }

    /// Drain a generate variant's trace ring to JSONL (empty when the
    /// variant is unknown, does not generate, or tracing is disabled).
    /// Events drain oldest-first and each drain clears the ring, so
    /// successive calls return disjoint windows of the timeline.
    pub fn drain_trace(&self, variant: &str) -> String {
        self.engine_obs(variant).map(|o| o.drain_jsonl(variant)).unwrap_or_default()
    }

    /// Register a GPT variant (builder-style).
    pub fn with_gpt(mut self, name: &str, model: Arc<Gpt>, stack: Option<QuantStack>) -> Self {
        self.insert(name, NativeModel::Gpt(model), stack);
        self
    }

    /// Register a greedy-generation GPT variant with the given KV-cache
    /// policy and per-request new-token budget (decode-engine defaults:
    /// greedy sampling, [`crate::decode::DEFAULT_DECODE_BATCH`]-wide
    /// fusion). See [`NativeExecutor::with_gpt_generate_cfg`] for the
    /// sampling/fusion knobs.
    ///
    /// `stack` quantizes the decode-path *linears* per call window, and
    /// the hook's activation policies are window-relative: during batched
    /// decode a window is the fused `[n_active × d]` step (what a fused
    /// deployment kernel would see), so with `hp_tokens > 0` the leading
    /// *streams* of a step run at `hp_bits`, and STaMP sequence
    /// transforms degenerate over the small step window —
    /// *sequence-side* mixed precision during decode is the job of the
    /// KV-cache policy (`kv`), not the stack. Weight quantization applies
    /// in full (from the per-variant prepared cache). Pass `None` for the
    /// paper-shaped serving setup: FP linears + quantized cache.
    ///
    /// Consequence of the fused window: with a window-relative stack a
    /// request's output can depend on which requests the batcher
    /// co-batched with it (its row index in the step window). If strict
    /// per-request determinism matters more than fusion for a stacked
    /// variant, register it via [`NativeExecutor::with_gpt_generate_cfg`]
    /// with `decode_batch = 1` — streams still advance in lock-step but
    /// every step window is one row, restoring PR 3's semantics. FP
    /// variants (`stack = None`) are batch-invariant either way.
    pub fn with_gpt_generate(
        self,
        name: &str,
        model: Arc<Gpt>,
        stack: Option<QuantStack>,
        kv: KvCacheConfig,
        max_new: usize,
    ) -> Self {
        self.with_gpt_generate_cfg(
            name,
            model,
            stack,
            kv,
            max_new,
            Sampling::Greedy,
            crate::decode::DEFAULT_DECODE_BATCH,
            crate::decode::DEFAULT_MAX_INFLIGHT,
            None,
        )
    }

    /// [`NativeExecutor::with_gpt_generate`] with explicit sampling policy,
    /// fused-step width, and engine slot count (the `[generate]` config
    /// section's `temperature`/`top_k`/`seed`, `decode_batch`, and
    /// `max_inflight` knobs, [`crate::config::GenerateSpec::sampling`]).
    /// `max_inflight` bounds how many streams the variant's resident
    /// engine seats at once — both the batch path and the continuous
    /// admission path share those slots. `speculative` enables
    /// self-speculative decode on the resident engine (the `[generate]`
    /// `speculative.*` knobs, [`crate::config::GenerateSpec::speculative`]);
    /// greedy-only — the engine builder panics on a sampled + speculative
    /// combination, mirroring the config-level check.
    #[allow(clippy::too_many_arguments)]
    pub fn with_gpt_generate_cfg(
        mut self,
        name: &str,
        model: Arc<Gpt>,
        stack: Option<QuantStack>,
        kv: KvCacheConfig,
        max_new: usize,
        sampling: Sampling,
        decode_batch: usize,
        max_inflight: usize,
        speculative: Option<crate::decode::SpecConfig>,
    ) -> Self {
        kv.validate();
        // A windowed variant's residency must fit the positional table —
        // same rule the engine asserts, surfaced at registration.
        if let Some(bound) = kv.resident_bound() {
            assert!(
                bound <= model.cfg.max_seq,
                "kv window residency bound {bound} exceeds model max_seq {}",
                model.cfg.max_seq
            );
        }
        assert!(decode_batch >= 1, "decode_batch must be ≥ 1");
        assert!(max_inflight >= 1, "max_inflight must be ≥ 1");
        self.insert(
            name,
            NativeModel::GptGenerate {
                model,
                kv,
                max_new,
                sampling,
                decode_batch,
                max_inflight,
                speculative,
            },
            stack,
        );
        self
    }

    /// Register a DiT variant conditioned on a fixed prompt.
    pub fn with_dit(
        mut self,
        name: &str,
        model: Arc<Dit>,
        prompt: &str,
        stack: Option<QuantStack>,
    ) -> Self {
        self.insert(name, NativeModel::Dit { model, prompt: prompt.to_string() }, stack);
        self
    }

    /// Registered variant names (sorted), for wiring up the server.
    pub fn variant_names(&self) -> Vec<String> {
        let mut v: Vec<String> = self.variants.keys().cloned().collect();
        v.sort();
        v
    }

    /// The per-variant prepared weight caches (`None` for FP variants) —
    /// serving introspection; the tests pin `misses() == 0` across
    /// repeated executes.
    pub fn prepared(&self, variant: &str) -> Option<&PreparedWeights> {
        self.variants.get(variant)?.prepared.as_ref()
    }

    /// One coordinator batch of generate requests → one [`DecodeEngine`]
    /// run: all streams admitted together, advanced in lock-step, their
    /// per-step activations fused into shared GEMMs. Any malformed
    /// request fails the whole batch, matching the per-forward semantics.
    fn run_generate_batch(
        &self,
        variant: &Variant,
        hook: &dyn LinearHook,
        inputs: &[&Tensor],
    ) -> Result<Vec<Tensor>, String> {
        let NativeModel::GptGenerate { model, kv, max_new, .. } = &variant.model else {
            unreachable!("run_generate_batch called on a non-generate variant");
        };
        let cap = effective_cap(kv, model);
        let reqs: Vec<GenRequest> = inputs
            .iter()
            .map(|x| parse_generate(x, model, *max_new, cap))
            .collect::<Result<_, _>>()?;
        // The variant's ONE resident engine (built at registration), not a
        // fresh one per batch: `run` claims only this batch's streams, so
        // it composes with streams admitted through [`StreamExecutor`].
        let mut engine = variant
            .engine
            .as_ref()
            .expect("generate variants have a resident engine")
            .lock()
            .unwrap();
        let results = engine.run(hook, &reqs).map_err(|e| e.to_string())?;
        debug_assert!(
            results.iter().all(|r| !r.truncated),
            "validated requests can never truncate"
        );
        Ok(results
            .into_iter()
            .map(|r| {
                Tensor::from_vec(
                    &[1, r.tokens.len()],
                    r.tokens.iter().map(|&t| t as f32).collect(),
                )
            })
            .collect())
    }

    fn run_one(&self, variant: &Variant, hook: &dyn LinearHook, input: &Tensor) -> Result<Tensor, String> {
        match &variant.model {
            NativeModel::Gpt(gpt) => {
                if input.ndim() != 2 || input.rows() != 1 {
                    return Err(format!("gpt variant expects a 1×s token row, got {:?}", input.shape()));
                }
                let tokens = parse_tokens(input.data(), gpt.cfg.vocab_size)?;
                if tokens.len() > gpt.cfg.max_seq {
                    return Err(format!("sequence {} exceeds max_seq {}", tokens.len(), gpt.cfg.max_seq));
                }
                Ok(gpt.logits_hooked(hook, &tokens))
            }
            NativeModel::GptGenerate { .. } => {
                unreachable!("generate batches route through run_generate_batch")
            }
            NativeModel::Dit { model, prompt } => {
                if input.ndim() != 2
                    || input.rows() != model.cfg.seq_len()
                    || input.cols() != model.latent_dim
                {
                    return Err(format!(
                        "dit variant expects {}×{} latents, got {:?}",
                        model.cfg.seq_len(),
                        model.latent_dim,
                        input.shape()
                    ));
                }
                Ok(model.denoise_step(hook, input, prompt, 0))
            }
        }
    }
}

impl Executor for NativeExecutor {
    fn execute(&self, variant: &str, inputs: &[&Tensor]) -> Result<Vec<Tensor>, String> {
        let v = self
            .variants
            .get(variant)
            .ok_or_else(|| format!("no native variant `{variant}`"))?;
        // The QuantHook's STaMP caches are per-call interior state
        // (RefCell), but its *weights* resolve from the per-variant
        // [`PreparedWeights`] built once at registration — repeated
        // executes (and every decode step inside a generate request)
        // never re-quantize a weight.
        with_hook(v, |hook| self.run_batch(v, hook, inputs))
    }

    fn obs(&self, variant: &str) -> Option<Arc<EngineObs>> {
        self.engine_obs(variant)
    }
}

/// The continuous-batching face of the executor (PR 6): a
/// [`crate::coordinator::StreamWorker`] admits generate requests into the
/// variant's resident [`DecodeEngine`] one at a time and advances all
/// in-flight streams one fused token-step per `step` call. Non-generate
/// variants report zero free slots and are never admitted.
impl StreamExecutor for NativeExecutor {
    fn free_slots(&self, variant: &str) -> usize {
        self.variants
            .get(variant)
            .and_then(|v| v.engine.as_ref())
            .map_or(0, |e| e.lock().unwrap().free_slots())
    }

    fn admit(&self, variant: &str, input: &Tensor) -> Result<u64, String> {
        let v = self
            .variants
            .get(variant)
            .ok_or_else(|| format!("no native variant `{variant}`"))?;
        let NativeModel::GptGenerate { model, kv, max_new, .. } = &v.model else {
            return Err(format!("variant `{variant}` does not stream (not a generate variant)"));
        };
        let req = parse_generate(input, model, *max_new, effective_cap(kv, model))?;
        let engine = v.engine.as_ref().expect("generate variants have a resident engine");
        engine.lock().unwrap().admit(req).map_err(|e| e.to_string())
    }

    fn step(&self, variant: &str) -> Vec<(u64, Result<Tensor, String>)> {
        let Some(v) = self.variants.get(variant) else { return Vec::new() };
        let Some(engine) = v.engine.as_ref() else { return Vec::new() };
        let mut engine = engine.lock().unwrap();
        with_hook(v, |hook| engine.step(hook));
        engine
            .drain()
            .into_iter()
            .map(|(sid, r)| {
                debug_assert!(!r.truncated, "validated requests can never truncate");
                let row: Vec<f32> = r.tokens.iter().map(|&t| t as f32).collect();
                (sid, Ok(Tensor::from_vec(&[1, row.len()], row)))
            })
            .collect()
    }

    fn has_work(&self, variant: &str) -> bool {
        self.variants
            .get(variant)
            .and_then(|v| v.engine.as_ref())
            .is_some_and(|e| e.lock().unwrap().has_work())
    }

    fn prefix_hits(&self, variant: &str) -> u64 {
        // Each generate variant's resident engine owns one
        // [`crate::kvcache::BlockPool`] (PR 7), so the counter is
        // per-variant by construction; non-generate variants report 0.
        self.variants
            .get(variant)
            .and_then(|v| v.engine.as_ref())
            .map_or(0, |e| e.lock().unwrap().prefix_hits())
    }

    fn obs(&self, variant: &str) -> Option<Arc<EngineObs>> {
        self.engine_obs(variant)
    }

    fn drain_trace(&self, variant: &str) -> String {
        NativeExecutor::drain_trace(self, variant)
    }
}

impl NativeExecutor {
    /// Dispatch one formed batch: generate variants admit the whole batch
    /// into a single fused [`DecodeEngine`] run; forward variants keep the
    /// per-request loop (their batching win is worker-level parallelism).
    fn run_batch(
        &self,
        v: &Variant,
        hook: &dyn LinearHook,
        inputs: &[&Tensor],
    ) -> Result<Vec<Tensor>, String> {
        if matches!(v.model, NativeModel::GptGenerate { .. }) {
            return self.run_generate_batch(v, hook, inputs);
        }
        let mut out = Vec::with_capacity(inputs.len());
        for x in inputs {
            out.push(self.run_one(v, hook, x)?);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::{ActQuantCfg, BaselineKind, WeightQuantCfg};
    use crate::config::ServeSpec;
    use crate::coordinator::Server;
    use crate::model::{DitConfig, GptConfig};
    use std::time::Duration;

    fn tiny_gpt_exec() -> (NativeExecutor, Arc<Gpt>) {
        let gpt = Arc::new(Gpt::new(GptConfig::tiny(), 5));
        let act = ActQuantCfg { hp_tokens: 8, ..ActQuantCfg::w4a4_per_token() };
        let stack = QuantStack::build(
            BaselineKind::Rtn,
            &HashMap::new(),
            Some(act),
            None,
            None,
            1,
        );
        let exec = NativeExecutor::new()
            .with_gpt("fp", gpt.clone(), None)
            .with_gpt("rtn-a4", gpt.clone(), Some(stack));
        (exec, gpt)
    }

    fn token_row(n: usize) -> Tensor {
        let toks: Vec<f32> = (0..n).map(|i| ((i * 5) % 70) as f32).collect();
        Tensor::from_vec(&[1, n], toks)
    }

    #[test]
    fn fp_variant_matches_direct_forward() {
        let (exec, gpt) = tiny_gpt_exec();
        let input = token_row(16);
        let out = exec.execute("fp", &[&input]).unwrap();
        let tokens: Vec<u32> = input.data().iter().map(|&v| v as u32).collect();
        let want = gpt.logits_hooked(&FpHook, &tokens);
        assert_eq!(out.len(), 1);
        assert!(out[0].max_abs_diff(&want) < 1e-6);
    }

    #[test]
    fn quantized_variant_differs_but_stays_finite() {
        let (exec, _) = tiny_gpt_exec();
        let input = token_row(16);
        let fp = exec.execute("fp", &[&input]).unwrap().remove(0);
        let q = exec.execute("rtn-a4", &[&input]).unwrap().remove(0);
        assert!(q.all_finite());
        assert!(q.max_abs_diff(&fp) > 1e-6, "quantization must perturb logits");
    }

    #[test]
    fn rejects_unknown_variant_and_bad_shapes() {
        let (exec, _) = tiny_gpt_exec();
        let input = token_row(8);
        assert!(exec.execute("nope", &[&input]).unwrap_err().contains("no native variant"));
        let bad = Tensor::zeros(&[2, 8]);
        assert!(exec.execute("fp", &[&bad]).unwrap_err().contains("1×s"));
        let oov = Tensor::from_vec(&[1, 2], vec![0.0, 9999.0]);
        assert!(exec.execute("fp", &[&oov]).unwrap_err().contains("out of vocab"));
        // Corrupt values must be rejected, not saturated to token 0.
        for bad in [-1.0f32, f32::NAN, 0.5] {
            let t = Tensor::from_vec(&[1, 2], vec![1.0, bad]);
            assert!(
                exec.execute("fp", &[&t]).unwrap_err().contains("non-token value"),
                "{bad} must be rejected"
            );
        }
    }

    #[test]
    fn packed_variant_serves_and_is_thread_count_invariant() {
        let gpt = Arc::new(Gpt::new(GptConfig::tiny(), 11));
        let act = ActQuantCfg { hp_tokens: 8, ..ActQuantCfg::w4a4_per_token() };
        let mk = |packed: bool| {
            let s = QuantStack::build(
                BaselineKind::Rtn,
                &HashMap::new(),
                Some(act.clone()),
                Some(WeightQuantCfg::w4_per_channel()),
                None,
                1,
            );
            if packed {
                s.with_packed()
            } else {
                s
            }
        };
        let exec = NativeExecutor::new()
            .with_gpt("sim", gpt.clone(), Some(mk(false)))
            .with_gpt("packed", gpt, Some(mk(true)));
        let input = token_row(16);

        // Multi-threaded kernels (direct call) vs forced-serial kernels
        // must produce byte-identical responses.
        let threaded = exec.execute("packed", &[&input]).unwrap().remove(0);
        crate::parallel::set_kernel_serial(true);
        let serial = exec.execute("packed", &[&input]).unwrap().remove(0);
        crate::parallel::set_kernel_serial(false);
        assert_eq!(threaded, serial, "packed serving must not depend on thread count");

        // And the packed path tracks the simulated one tightly.
        let sim = exec.execute("sim", &[&input]).unwrap().remove(0);
        assert!(threaded.all_finite());
        let s = crate::stats::sqnr(&sim, &threaded);
        assert!(s > 35.0, "packed vs simulated served logits SQNR {s} dB");
    }

    #[test]
    fn micro_block_variant_serves_end_to_end() {
        // The `quant.granularity = "micro16"` knob flows config →
        // ActQuantCfg → QuantScheme → QTensor → the qgemm micro-block
        // fast path, served by the executor like any packed variant.
        let cfg = crate::config::RunConfig::from_toml_str(
            "[quant]\nbaseline = \"rtn\"\nstamp = false\npacked = true\nact_bits = 4\nhp_tokens = 8\ngranularity = \"micro16\"\n",
        )
        .unwrap();
        let act = cfg.quant.act_cfg();
        assert_eq!(act.granularity, crate::quant::Granularity::MicroBlock { block: 16 });
        let gpt = Arc::new(Gpt::new(GptConfig::tiny(), 19));
        let mk = |granularity| {
            QuantStack::build(
                BaselineKind::Rtn,
                &HashMap::new(),
                Some(ActQuantCfg { granularity, ..act.clone() }),
                Some(cfg.quant.weight_cfg()),
                None,
                1,
            )
            .with_packed()
        };
        let exec = NativeExecutor::new()
            .with_gpt("micro", gpt.clone(), Some(mk(act.granularity)))
            .with_gpt("block", gpt, Some(mk(crate::quant::Granularity::PerBlock { block: 16 })));
        let input = token_row(16);
        let threaded = exec.execute("micro", &[&input]).unwrap().remove(0);
        crate::parallel::set_kernel_serial(true);
        let serial = exec.execute("micro", &[&input]).unwrap().remove(0);
        crate::parallel::set_kernel_serial(false);
        assert!(threaded.all_finite());
        assert_eq!(threaded, serial, "micro-block serving must not depend on thread count");
        // MicroBlock is numerically PerBlock of the same width, and both
        // qgemm paths are bit-identical to the scalar oracle — so the two
        // variants must serve byte-identical logits (only the kernel's
        // folding path differs).
        let block = exec.execute("block", &[&input]).unwrap().remove(0);
        assert_eq!(threaded, block, "micro fast path diverged from the generic segmented path");
    }

    #[test]
    fn packed_weights_prepared_once_across_executes() {
        let gpt = Arc::new(Gpt::new(GptConfig::tiny(), 13));
        let act = ActQuantCfg { hp_tokens: 8, ..ActQuantCfg::w4a4_per_token() };
        let stack = QuantStack::build(
            BaselineKind::Rtn,
            &HashMap::new(),
            Some(act),
            Some(WeightQuantCfg::w4_per_channel()),
            None,
            1,
        )
        .with_packed();
        let exec = NativeExecutor::new().with_gpt("packed", gpt, Some(stack));
        // Registration already built the full per-variant cache…
        let sites = exec.prepared("packed").unwrap().packed_sites();
        assert!(sites >= 8, "registration must cover all linear sites, got {sites}");
        // …and repeated executes must never rebuild a weight.
        let input = token_row(16);
        let a = exec.execute("packed", &[&input]).unwrap().remove(0);
        let b = exec.execute("packed", &[&input]).unwrap().remove(0);
        assert_eq!(a, b, "prepared weights must make serving deterministic");
        let p = exec.prepared("packed").unwrap();
        assert_eq!(p.misses(), 0, "packed weights must be constructed exactly once per variant");
        assert_eq!(p.packed_sites(), sites);
        // FP variants carry no prepared cache.
        let exec_fp = NativeExecutor::new()
            .with_gpt("fp", Arc::new(Gpt::new(GptConfig::tiny(), 13)), None);
        assert!(exec_fp.prepared("fp").is_none());
    }

    #[test]
    fn generate_variant_serves_greedy_decode() {
        let gpt = Arc::new(Gpt::new(GptConfig::tiny(), 5));
        let exec = NativeExecutor::new().with_gpt_generate(
            "gen",
            gpt.clone(),
            None,
            crate::kvcache::KvCacheConfig::fp32(),
            32,
        );
        // [n_new = 8, prompt 1 2 3]
        let input = Tensor::from_vec(&[1, 4], vec![8.0, 1.0, 2.0, 3.0]);
        let out = exec.execute("gen", &[&input]).unwrap().remove(0);
        assert_eq!(out.shape(), &[1, 8]);
        // Parity with a direct greedy decode.
        let mut cache = crate::kvcache::KvCache::fp32(gpt.cfg.n_layers);
        let want = gpt.generate_greedy(&FpHook, &[1, 2, 3], 8, &mut cache);
        for (i, &w) in want.iter().enumerate() {
            assert_eq!(out.at(0, i), w as f32, "generated token {i}");
        }
        // Malformed requests are rejected, not misinterpreted.
        let zero = Tensor::from_vec(&[1, 2], vec![0.0, 1.0]);
        assert!(exec.execute("gen", &[&zero]).unwrap_err().contains("invalid n_new"));
        let over = Tensor::from_vec(&[1, 2], vec![99.0, 1.0]);
        assert!(exec.execute("gen", &[&over]).unwrap_err().contains("exceeds variant limit"));
        let short = Tensor::from_vec(&[1, 1], vec![4.0]);
        assert!(exec.execute("gen", &[&short]).unwrap_err().contains("1×(1+s)"));
    }

    #[test]
    fn generate_variant_with_packed_kv_is_deterministic() {
        let gpt = Arc::new(Gpt::new(GptConfig::tiny(), 6));
        let kv = crate::kvcache::KvCacheConfig::two_level(4, 8, 4, 8)
            .with_transform(crate::stamp::SeqTransformKind::HaarDwt);
        let exec = NativeExecutor::new().with_gpt_generate("gen-kv4", gpt, None, kv, 32);
        let input = Tensor::from_vec(&[1, 5], vec![12.0, 3.0, 17.0, 41.0, 5.0]);
        let threaded = exec.execute("gen-kv4", &[&input]).unwrap().remove(0);
        crate::parallel::set_kernel_serial(true);
        let serial = exec.execute("gen-kv4", &[&input]).unwrap().remove(0);
        crate::parallel::set_kernel_serial(false);
        assert_eq!(threaded, serial, "packed-kv decode must not depend on thread count");
        assert_eq!(threaded.shape(), &[1, 12]);
        // All generated ids are valid vocab entries.
        for &v in threaded.data() {
            assert!(v >= 0.0 && (v as usize) < 72 && v.fract() == 0.0);
        }
    }

    #[test]
    fn generate_variant_with_quantized_stack_uses_prepared_weights() {
        // A quantized stack on a generate variant: weight quantization
        // applies in full (once, at registration); activation policies are
        // window-relative per decode step (documented on
        // `with_gpt_generate`). Pin that the path serves, stays
        // deterministic, and never rebuilds a weight across the per-step
        // forwards.
        let gpt = Arc::new(Gpt::new(GptConfig::tiny(), 21));
        let act = ActQuantCfg { hp_tokens: 8, ..ActQuantCfg::w4a4_per_token() };
        let stack = QuantStack::build(
            BaselineKind::Rtn,
            &HashMap::new(),
            Some(act),
            Some(WeightQuantCfg::w4_per_channel()),
            None,
            1,
        )
        .with_packed();
        let kv = crate::kvcache::KvCacheConfig::two_level(4, 8, 4, 8);
        let exec = NativeExecutor::new().with_gpt_generate("gen-q", gpt, Some(stack), kv, 32);
        let input = Tensor::from_vec(&[1, 4], vec![16.0, 2.0, 9.0, 33.0]);
        let a = exec.execute("gen-q", &[&input]).unwrap().remove(0);
        let b = exec.execute("gen-q", &[&input]).unwrap().remove(0);
        assert_eq!(a, b, "quantized-stack generation must be deterministic");
        assert_eq!(a.shape(), &[1, 16]);
        for &v in a.data() {
            assert!(v.fract() == 0.0 && (v as usize) < 72, "token {v}");
        }
        let p = exec.prepared("gen-q").unwrap();
        assert_eq!(p.misses(), 0, "decode steps must reuse the per-variant weights");
        assert!(p.packed_sites() >= 8);
    }

    #[test]
    fn generate_batch_is_one_fused_run_matching_serial_decode() {
        // A batch of ragged generate requests must come back request-for-
        // request identical to PR 3's serial greedy decode — the fused
        // engine path is a pure perf change on the fp32/greedy setup.
        let gpt = Arc::new(Gpt::new(GptConfig::tiny(), 31));
        let exec = NativeExecutor::new().with_gpt_generate(
            "gen",
            gpt.clone(),
            None,
            crate::kvcache::KvCacheConfig::fp32(),
            32,
        );
        let mk = |n_new: f32, prompt: &[f32]| {
            let mut v = vec![n_new];
            v.extend_from_slice(prompt);
            Tensor::from_vec(&[1, v.len()], v)
        };
        let inputs = [
            mk(8.0, &[1.0, 2.0, 3.0]),
            mk(3.0, &[44.0]),
            mk(12.0, &[7.0, 7.0, 19.0, 2.0, 5.0]),
        ];
        let refs: Vec<&Tensor> = inputs.iter().collect();
        let out = exec.execute("gen", &refs).unwrap();
        assert_eq!(out.len(), 3);
        for (i, x) in inputs.iter().enumerate() {
            let n_new = x.data()[0] as usize;
            let prompt: Vec<u32> = x.data()[1..].iter().map(|&v| v as u32).collect();
            let mut cache = crate::kvcache::KvCache::fp32(gpt.cfg.n_layers);
            let want = gpt.generate_greedy(&FpHook, &prompt, n_new, &mut cache);
            assert_eq!(out[i].shape(), &[1, n_new]);
            for (j, &w) in want.iter().enumerate() {
                assert_eq!(out[i].at(0, j), w as f32, "request {i} token {j}");
            }
        }
        // One malformed request still fails the whole batch.
        let bad = mk(0.0, &[1.0]);
        let refs: Vec<&Tensor> = vec![&inputs[0], &bad];
        assert!(exec.execute("gen", &refs).unwrap_err().contains("invalid n_new"));
    }

    #[test]
    fn generate_rejects_requests_exceeding_variant_cache_capacity() {
        // A variant-level kv.max_seq tighter than the model's bounds the
        // admissible prompt + n_new: the request is rejected up front —
        // never silently truncated to a shorter response row.
        let gpt = Arc::new(Gpt::new(GptConfig::tiny(), 37));
        let kv = crate::kvcache::KvCacheConfig::fp32().with_max_seq(16);
        let exec = NativeExecutor::new().with_gpt_generate("gen-capped", gpt, None, kv, 32);
        // 8-token prompt + 20 new > 16 → rejected.
        let mut row = vec![20.0];
        row.extend((0..8).map(|i| i as f32));
        let input = Tensor::from_vec(&[1, row.len()], row);
        let err = exec.execute("gen-capped", &[&input]).unwrap_err();
        assert!(err.contains("exceeds max_seq 16"), "{err}");
        // A fitting request serves the full n_new.
        let mut row = vec![8.0];
        row.extend((0..8).map(|i| i as f32));
        let input = Tensor::from_vec(&[1, row.len()], row);
        let out = exec.execute("gen-capped", &[&input]).unwrap().remove(0);
        assert_eq!(out.shape(), &[1, 8]);
    }

    #[test]
    fn windowed_generate_variant_serves_requests_past_max_seq() {
        let gpt = Arc::new(Gpt::new(GptConfig::tiny(), 39));
        let kv = crate::kvcache::KvCacheConfig::two_level(8, 8, 4, 8).with_window(8, 32);
        let exec = NativeExecutor::new().with_gpt_generate("gen-win", gpt, None, kv, 512);
        // prompt 8 + n_new 280 > max_seq 256: admissible under the window
        // policy, and exactly n_new ids come back (never truncated).
        let mut row = vec![280.0];
        row.extend((0..8).map(|i| i as f32));
        let input = Tensor::from_vec(&[1, row.len()], row);
        let out = exec.execute("gen-win", &[&input]).unwrap().remove(0);
        assert_eq!(out.shape(), &[1, 280]);
        for &v in out.data() {
            assert!(v.fract() == 0.0 && (v as usize) < 72, "token {v}");
        }
        // The same request on an unwindowed variant still rejects up
        // front — the pre-eviction recoverable path is intact.
        let exec_bounded = NativeExecutor::new().with_gpt_generate(
            "gen",
            Arc::new(Gpt::new(GptConfig::tiny(), 39)),
            None,
            crate::kvcache::KvCacheConfig::fp32(),
            512,
        );
        let err = exec_bounded.execute("gen", &[&input]).unwrap_err();
        assert!(err.contains("exceeds max_seq"), "{err}");
    }

    #[test]
    fn sampled_generate_variant_is_deterministic() {
        let gpt = Arc::new(Gpt::new(GptConfig::tiny(), 33));
        let exec = NativeExecutor::new().with_gpt_generate_cfg(
            "gen-sampled",
            gpt.clone(),
            None,
            crate::kvcache::KvCacheConfig::fp32(),
            32,
            crate::decode::Sampling::TopK { k: 12, temperature: 0.8, seed: 0xA11CE },
            4,
            8,
            None,
        );
        let input = Tensor::from_vec(&[1, 4], vec![16.0, 2.0, 9.0, 33.0]);
        let a = exec.execute("gen-sampled", &[&input]).unwrap().remove(0);
        let b = exec.execute("gen-sampled", &[&input]).unwrap().remove(0);
        assert_eq!(a, b, "seeded sampling must reproduce exactly");
        assert_eq!(a.shape(), &[1, 16]);
        for &v in a.data() {
            assert!(v.fract() == 0.0 && (v as usize) < 72, "token {v}");
        }
        // Sampling must actually leave the greedy path (an untrained
        // model's near-uniform logits make 16 identical draws vanishingly
        // unlikely).
        let exec_g = NativeExecutor::new().with_gpt_generate(
            "gen-greedy",
            gpt,
            None,
            crate::kvcache::KvCacheConfig::fp32(),
            32,
        );
        let g = exec_g.execute("gen-greedy", &[&input]).unwrap().remove(0);
        assert_ne!(a, g, "temperature+top-k must diverge from greedy");
    }

    #[test]
    fn speculative_generate_variant_serves_identical_tokens() {
        use crate::decode::{DraftKind, SpecConfig};
        // The `[generate] speculative.*` knobs change throughput, never
        // content: a speculative variant must serve byte-identical rows
        // to the plain greedy variant, for both drafters, on both fp32
        // and packed-KV policies.
        let gpt = Arc::new(Gpt::new(GptConfig::tiny(), 59));
        let inputs = [
            Tensor::from_vec(&[1, 4], vec![12.0, 1.0, 2.0, 3.0]),
            Tensor::from_vec(&[1, 2], vec![9.0, 44.0]),
            Tensor::from_vec(&[1, 6], vec![7.0, 5.0, 9.0, 5.0, 9.0, 5.0]),
        ];
        let input_refs: Vec<&Tensor> = inputs.iter().collect();
        for kv in [
            crate::kvcache::KvCacheConfig::fp32(),
            crate::kvcache::KvCacheConfig::two_level(4, 8, 4, 8),
        ] {
            let plain = NativeExecutor::new().with_gpt_generate(
                "gen",
                gpt.clone(),
                None,
                kv.clone(),
                32,
            );
            let want = plain.execute("gen", &input_refs).unwrap();
            for draft in [DraftKind::Ngram, DraftKind::Packed] {
                let exec = NativeExecutor::new().with_gpt_generate_cfg(
                    "gen-spec",
                    gpt.clone(),
                    None,
                    kv.clone(),
                    32,
                    Sampling::Greedy,
                    crate::decode::DEFAULT_DECODE_BATCH,
                    crate::decode::DEFAULT_MAX_INFLIGHT,
                    Some(SpecConfig { draft, k: 4 }),
                );
                let got = exec.execute("gen-spec", &input_refs).unwrap();
                assert_eq!(got, want, "speculative {draft:?} serving diverged from greedy");
                // The engine really ran verify steps (not the plain path).
                let obs = exec.engine_obs("gen-spec").unwrap();
                assert!(obs.accepted_len.count() > 0, "no verify steps recorded ({draft:?})");
            }
        }
    }

    #[test]
    fn stream_admission_matches_serial_decode_exactly() {
        // Drive the StreamExecutor surface by hand: admit ragged requests
        // at different times into the resident engine, step to completion,
        // and compare every stream with PR 3's serial greedy decode.
        let gpt = Arc::new(Gpt::new(GptConfig::tiny(), 41));
        let exec = NativeExecutor::new().with_gpt_generate(
            "gen",
            gpt.clone(),
            None,
            crate::kvcache::KvCacheConfig::fp32(),
            32,
        );
        let mk = |n_new: f32, prompt: &[f32]| {
            let mut v = vec![n_new];
            v.extend_from_slice(prompt);
            Tensor::from_vec(&[1, v.len()], v)
        };
        let inputs =
            [mk(6.0, &[1.0, 2.0, 3.0]), mk(9.0, &[44.0]), mk(4.0, &[7.0, 19.0, 2.0, 5.0, 11.0])];
        // Admit the first two, step twice, then admit the third mid-run.
        let a = exec.admit("gen", &inputs[0]).unwrap();
        let b = exec.admit("gen", &inputs[1]).unwrap();
        let mut done: HashMap<u64, Tensor> = HashMap::new();
        for _ in 0..2 {
            for (sid, out) in exec.step("gen") {
                done.insert(sid, out.unwrap());
            }
        }
        let c = exec.admit("gen", &inputs[2]).unwrap();
        while exec.has_work("gen") {
            for (sid, out) in exec.step("gen") {
                done.insert(sid, out.unwrap());
            }
        }
        assert_eq!(done.len(), 3);
        assert_eq!(exec.free_slots("gen"), crate::decode::DEFAULT_MAX_INFLIGHT);
        for (sid, input) in [(a, &inputs[0]), (b, &inputs[1]), (c, &inputs[2])] {
            let n_new = input.data()[0] as usize;
            let prompt: Vec<u32> = input.data()[1..].iter().map(|&v| v as u32).collect();
            let mut cache = crate::kvcache::KvCache::fp32(gpt.cfg.n_layers);
            let want = gpt.generate_greedy(&FpHook, &prompt, n_new, &mut cache);
            let got = &done[&sid];
            assert_eq!(got.shape(), &[1, n_new]);
            for (j, &w) in want.iter().enumerate() {
                assert_eq!(got.at(0, j), w as f32, "stream {sid} token {j}");
            }
        }
    }

    #[test]
    fn stream_admission_respects_max_inflight_and_rejects_non_streams() {
        let gpt = Arc::new(Gpt::new(GptConfig::tiny(), 43));
        let exec = NativeExecutor::new()
            .with_gpt("fp", gpt.clone(), None)
            .with_gpt_generate_cfg(
                "gen",
                gpt,
                None,
                crate::kvcache::KvCacheConfig::fp32(),
                32,
                Sampling::Greedy,
                crate::decode::DEFAULT_DECODE_BATCH,
                2,
                None,
            );
        let input = Tensor::from_vec(&[1, 2], vec![4.0, 3.0]);
        assert_eq!(exec.free_slots("gen"), 2);
        exec.admit("gen", &input).unwrap();
        exec.admit("gen", &input).unwrap();
        assert_eq!(exec.free_slots("gen"), 0);
        let err = exec.admit("gen", &input).unwrap_err();
        assert!(err.contains("no free slot"), "{err}");
        // Slots come back as streams retire, and admission works again.
        while exec.has_work("gen") {
            exec.step("gen");
        }
        assert_eq!(exec.free_slots("gen"), 2);
        exec.admit("gen", &input).unwrap();
        // Forward variants never stream.
        assert_eq!(exec.free_slots("fp"), 0);
        assert!(!exec.has_work("fp"));
        assert!(exec.admit("fp", &input).unwrap_err().contains("does not stream"));
        assert!(exec.step("fp").is_empty());
        assert!(exec.admit("nope", &input).unwrap_err().contains("no native variant"));
        // Malformed requests are rejected at the admission boundary.
        let bad = Tensor::from_vec(&[1, 2], vec![0.0, 1.0]);
        assert!(exec.admit("gen", &bad).unwrap_err().contains("invalid n_new"));
    }

    #[test]
    fn batch_and_stream_paths_share_the_resident_engine() {
        // A one-shot batch run on a busy engine must leave the previously
        // admitted stream in flight and untouched.
        let gpt = Arc::new(Gpt::new(GptConfig::tiny(), 47));
        let exec = NativeExecutor::new().with_gpt_generate(
            "gen",
            gpt.clone(),
            None,
            crate::kvcache::KvCacheConfig::fp32(),
            32,
        );
        let streamed = Tensor::from_vec(&[1, 3], vec![10.0, 5.0, 9.0]);
        let sid = exec.admit("gen", &streamed).unwrap();
        let free_before = exec.free_slots("gen");
        let batched = Tensor::from_vec(&[1, 4], vec![6.0, 1.0, 2.0, 3.0]);
        let out = exec.execute("gen", &[&batched]).unwrap().remove(0);
        assert_eq!(out.shape(), &[1, 6]);
        // The streamed request survived the batch run and still completes
        // with serial-parity output.
        assert_eq!(exec.free_slots("gen"), free_before, "batch run must release its own slots");
        let mut done = None;
        while exec.has_work("gen") || done.is_none() {
            for (id, o) in exec.step("gen") {
                if id == sid {
                    done = Some(o.unwrap());
                }
            }
        }
        let got = done.unwrap();
        let mut cache = crate::kvcache::KvCache::fp32(gpt.cfg.n_layers);
        let want = gpt.generate_greedy(&FpHook, &[5, 9], 10, &mut cache);
        assert_eq!(got.shape(), &[1, 10]);
        for (j, &w) in want.iter().enumerate() {
            assert_eq!(got.at(0, j), w as f32, "token {j}");
        }
    }

    #[test]
    fn with_observability_traces_generate_variants_and_drains_jsonl() {
        use crate::obs::TraceKind;
        let gpt = Arc::new(Gpt::new(GptConfig::tiny(), 51));
        let obs_cfg = ObsSpec {
            trace_enabled: true,
            trace_capacity: 512,
            trace_sink: "memory".into(),
            kernel_profile: false,
        };
        // `with_observability` after registration: applies retroactively.
        let exec = NativeExecutor::new()
            .with_gpt_generate("gen", gpt, None, crate::kvcache::KvCacheConfig::fp32(), 32)
            .with_observability(&obs_cfg);
        let input = Tensor::from_vec(&[1, 3], vec![5.0, 1.0, 2.0]);
        let _ = exec.execute("gen", &[&input]).unwrap();
        let jsonl = NativeExecutor::drain_trace(&exec, "gen");
        let events: Vec<crate::obs::TraceEvent> = jsonl
            .lines()
            .map(|l| crate::obs::TraceEvent::from_json(l).expect("parse"))
            .collect();
        assert!(!events.is_empty());
        assert_eq!(events[0].kind, TraceKind::Admit);
        assert_eq!(events.last().unwrap().kind, TraceKind::Retire);
        // One DecodeStep per generated token (first is sampled at prefill
        // completion), TTFT once, TPOT for every token after the first.
        let steps = events.iter().filter(|e| e.kind == TraceKind::DecodeStep).count();
        assert_eq!(steps, 5);
        let o = exec.engine_obs("gen").unwrap();
        assert_eq!(o.ttft_us.count(), 1);
        assert_eq!(o.tpot_us.count(), 4);
        // Drains are destructive windows.
        assert_eq!(NativeExecutor::drain_trace(&exec, "gen"), "");
        // Unknown / forward-only variants expose nothing.
        assert!(exec.engine_obs("nope").is_none());
        assert_eq!(NativeExecutor::drain_trace(&exec, "nope"), "");
        // Registration *after* with_observability also gets a ring.
        let exec2 = NativeExecutor::new().with_observability(&obs_cfg).with_gpt_generate(
            "late",
            Arc::new(Gpt::new(GptConfig::tiny(), 51)),
            None,
            crate::kvcache::KvCacheConfig::fp32(),
            32,
        );
        assert!(exec2.engine_obs("late").unwrap().trace_enabled());
    }

    #[test]
    fn dit_variant_serves_denoise_steps() {
        let dit = Arc::new(Dit::new(
            DitConfig { grid_h: 4, grid_w: 4, d_model: 32, n_heads: 2, n_layers: 1, d_ff: 64, ctx_tokens: 2, steps: 2 },
            7,
        ));
        let exec = NativeExecutor::new().with_dit("dit-fp", dit.clone(), "a red cube", None);
        let z = Tensor::randn(&[dit.cfg.seq_len(), dit.latent_dim], 3).scale(0.3);
        let out = exec.execute("dit-fp", &[&z]).unwrap().remove(0);
        assert_eq!(out.shape(), z.shape());
        assert!(out.all_finite());
        let want = dit.denoise_step(&FpHook, &z, "a red cube", 0);
        assert!(out.max_abs_diff(&want) < 1e-6);
    }

    #[test]
    fn serves_through_coordinator_end_to_end() {
        let (exec, gpt) = tiny_gpt_exec();
        let names = exec.variant_names();
        assert_eq!(names, vec!["fp".to_string(), "rtn-a4".to_string()]);
        let refs: Vec<&str> = names.iter().map(|s| s.as_str()).collect();
        let spec = ServeSpec { workers: 2, max_batch: 4, max_wait_us: 500, queue_depth: 16 };
        let server = Server::start(&spec, &refs, Arc::new(exec));
        let handle = server.handle();
        let input = token_row(12);
        let resp = handle.call("fp", input.clone(), Duration::from_secs(30)).unwrap();
        let logits = resp.output.unwrap();
        let tokens: Vec<u32> = input.data().iter().map(|&v| v as u32).collect();
        assert!(logits.max_abs_diff(&gpt.logits_hooked(&FpHook, &tokens)) < 1e-6);
        let resp = handle.call("rtn-a4", input, Duration::from_secs(30)).unwrap();
        assert!(resp.output.unwrap().all_finite());
        server.shutdown();
    }
}
