//! PJRT runtime: loads the HLO-text artifacts that `python/compile/aot.py`
//! lowers from the JAX/Pallas model (L2/L1) and executes them from Rust —
//! Python never runs on the request path.
//!
//! Interchange is **HLO text** (not serialized protos): jax ≥ 0.5 emits
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md and DESIGN.md).

mod engine;
mod registry;

pub use engine::{Engine, ExecError};
pub use registry::{ArtifactManifest, ArtifactRegistry};

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::Path;

    /// Full AOT round-trip against real artifacts, exercised only when
    /// `make artifacts` has produced them (integration environments).
    #[test]
    fn loads_and_runs_artifacts_when_present() {
        let dir = std::env::var("STAMP_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
        let manifest = Path::new(&dir).join("manifest.toml");
        if !manifest.exists() {
            eprintln!("skipping: no artifacts at {dir} (run `make artifacts`)");
            return;
        }
        let reg = ArtifactRegistry::load(&dir).expect("manifest parses");
        assert!(!reg.entries().is_empty());
        let engine = Engine::cpu().expect("PJRT CPU client");
        for entry in reg.entries() {
            let exe = engine.load(&reg.path_for(entry)).expect("artifact compiles");
            let outputs = engine
                .run_f32(&exe, &entry.input_shapes())
                .expect("artifact executes on zero inputs");
            assert!(!outputs.is_empty(), "{}: no outputs", entry.name);
            for o in &outputs {
                assert!(o.iter().all(|v| v.is_finite()), "{}: non-finite output", entry.name);
            }
        }
    }
}
