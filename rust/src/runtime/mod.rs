//! Execution runtime: artifact discovery, the pure-Rust [`NativeExecutor`],
//! and (behind the `pjrt` cargo feature) the PJRT engine that loads the
//! HLO-text artifacts `python/compile/aot.py` lowers from the JAX/Pallas
//! model — Python never runs on the request path.
//!
//! Two backends implement the serving story (DESIGN.md §6):
//!
//! * **Native** (always available, zero dependencies) — [`NativeExecutor`]
//!   runs the quantized Rust models ([`crate::model::gpt`] /
//!   [`crate::model::dit`]) directly, so `coordinator` workers can serve
//!   without any XLA toolchain present.
//! * **PJRT** (`--features pjrt`) — `Engine` compiles and executes
//!   AOT-lowered HLO. Interchange is **HLO text** (not serialized protos):
//!   jax ≥ 0.5 emits 64-bit instruction ids that xla_extension 0.5.1
//!   rejects; the text parser reassigns ids (DESIGN.md §4). The default
//!   `xla` dependency is a vendored API stub that reports "PJRT not
//!   linked" at runtime; swap in a real `xla` crate via a `[patch]` entry
//!   to talk to actual hardware.

#[cfg(feature = "pjrt")]
mod engine;
mod native;
mod registry;

#[cfg(feature = "pjrt")]
pub use engine::{Engine, ExecError};
pub use native::{NativeExecutor, NativeModel};
pub use registry::{ArtifactManifest, ArtifactRegistry};

#[cfg(all(test, feature = "pjrt"))]
mod tests {
    use super::*;
    use std::path::Path;

    /// Full AOT round-trip against real artifacts, exercised only when
    /// `make artifacts` has produced them (integration environments).
    #[test]
    fn loads_and_runs_artifacts_when_present() {
        let dir = std::env::var("STAMP_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
        let manifest = Path::new(&dir).join("manifest.toml");
        if !manifest.exists() {
            eprintln!("skipping: no artifacts at {dir} (run `make artifacts`)");
            return;
        }
        let reg = ArtifactRegistry::load(&dir).expect("manifest parses");
        assert!(!reg.entries().is_empty());
        let engine = Engine::cpu().expect("PJRT CPU client");
        for entry in reg.entries() {
            let exe = engine.load(&reg.path_for(entry)).expect("artifact compiles");
            let outputs = engine
                .run_f32(&exe, &entry.input_shapes())
                .expect("artifact executes on zero inputs");
            assert!(!outputs.is_empty(), "{}: no outputs", entry.name);
            for o in &outputs {
                assert!(o.iter().all(|v| v.is_finite()), "{}: non-finite output", entry.name);
            }
        }
    }
}
