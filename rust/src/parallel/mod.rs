//! Home-grown data parallelism for the hot paths (DESIGN.md §7).
//!
//! One policy, two consumers:
//!
//! * **Fork-join kernels** — [`for_each_chunk_mut`] / [`for_row_chunks`] /
//!   [`join_chunks`] split a row-major buffer into one contiguous chunk
//!   per worker and run a closure on each via `std::thread::scope`, so
//!   borrowed (non-`'static`) data flows in without `Arc` gymnastics.
//!   Used by [`crate::tensor::matmul`] and the per-token QDQ loop in
//!   [`crate::quant`].
//! * **Long-lived workers** — [`crate::coordinator::WorkerPool`] sizes its
//!   thread count from the same [`num_threads`] policy, and worker threads
//!   are marked [`set_kernel_serial`]: kernels invoked from a pool worker
//!   run serially, so batch-level (inter-op) and kernel-level (intra-op)
//!   parallelism never multiply into oversubscription — one knob
//!   (`STAMP_THREADS`) governs the whole process.
//!
//! The degree of parallelism is resolved once per process:
//! `STAMP_THREADS` if set (a value of `1` forces the serial fallback on
//! every path), else `std::thread::available_parallelism()`. Kernels also
//! fall back to the serial path when the work is too small to amortize a
//! thread spawn ([`MIN_PARALLEL_ELEMS`]), so tiny tensors — the bulk of the
//! unit-test workload — never pay the fork-join cost.

use std::sync::OnceLock;

/// Below this many `f32` elements of work a kernel stays single-threaded;
/// spawn + join costs ~10–40 µs per worker, which a 64×64 matmul would
/// never win back.
pub const MIN_PARALLEL_ELEMS: usize = 64 * 1024;

static NUM_THREADS: OnceLock<usize> = OnceLock::new();

/// Worker count used by all parallel paths, resolved once per process.
///
/// Priority: `STAMP_THREADS` env var (clamped to `[1, 256]`; unparsable
/// values are ignored), then `std::thread::available_parallelism()`, then 1.
pub fn num_threads() -> usize {
    *NUM_THREADS.get_or_init(|| {
        if let Ok(v) = std::env::var("STAMP_THREADS") {
            if let Ok(n) = v.trim().parse::<usize>() {
                if n >= 1 {
                    return n.min(256);
                }
            }
        }
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    })
}

thread_local! {
    /// Set on coordinator worker threads: kernels called from them stay
    /// serial (the pool already owns the cores at batch granularity).
    static KERNEL_SERIAL: std::cell::Cell<bool> = const { std::cell::Cell::new(false) };
}

/// Mark (or unmark) the current thread as kernel-serial. Called by
/// [`crate::coordinator::WorkerPool`] worker threads at startup; test
/// harnesses may use it to pin the serial path explicitly.
pub fn set_kernel_serial(serial: bool) {
    KERNEL_SERIAL.with(|c| c.set(serial));
}

/// Whether kernels on the current thread must run serially.
pub fn kernel_serial() -> bool {
    KERNEL_SERIAL.with(|c| c.get())
}

/// Worker count for a kernel on *this* thread: 1 on kernel-serial
/// (coordinator worker) threads, [`num_threads`] otherwise. Fork-join
/// helpers gate on this, not on [`num_threads`] directly.
pub fn effective_threads() -> usize {
    if kernel_serial() {
        1
    } else {
        num_threads()
    }
}

/// Split `n` items into at most `workers` contiguous ranges of
/// near-equal length. Returns `(start, end)` pairs covering `0..n`.
pub fn split_ranges(n: usize, workers: usize) -> Vec<(usize, usize)> {
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.max(1).min(n);
    let base = n / workers;
    let extra = n % workers;
    let mut out = Vec::with_capacity(workers);
    let mut start = 0;
    for w in 0..workers {
        let len = base + usize::from(w < extra);
        out.push((start, start + len));
        start += len;
    }
    out
}

/// Run `f(chunk_index, row_range, chunk)` over `rows` equal row-chunks of a
/// row-major `rows × row_len` buffer, one chunk per worker.
///
/// Serial when [`effective_threads`] is 1, when there is a single chunk,
/// or when the buffer is smaller than [`MIN_PARALLEL_ELEMS`] — the closure
/// then runs on the caller's thread with identical semantics (and
/// identical floating-point results: parallelism only changes *who*
/// computes a row, never the reduction order within it).
pub fn for_each_chunk_mut<T, F>(data: &mut [T], rows: usize, row_len: usize, f: F)
where
    T: Send,
    F: Fn(usize, (usize, usize), &mut [T]) + Sync,
{
    assert_eq!(data.len(), rows * row_len, "buffer is not rows × row_len");
    let threads = effective_threads();
    let ranges = split_ranges(rows, threads);
    if threads == 1 || ranges.len() <= 1 || data.len() < MIN_PARALLEL_ELEMS {
        f(0, (0, rows), data);
        return;
    }
    std::thread::scope(|scope| {
        let mut rest = data;
        let mut consumed = 0usize;
        for (i, &(r0, r1)) in ranges.iter().enumerate() {
            let take = (r1 - r0) * row_len;
            let (chunk, tail) = std::mem::take(&mut rest).split_at_mut(take);
            rest = tail;
            consumed += take;
            let fr = &f;
            scope.spawn(move || fr(i, (r0, r1), chunk));
        }
        debug_assert_eq!(consumed, rows * row_len);
    });
}

/// Fork-join a row-chunked kernel over a `rows × row_len` output buffer,
/// gated on a caller-supplied **work** estimate (e.g. `m·n·k` multiply-adds
/// for a matmul, where the output alone understates the cost of a
/// tall-inner-dimension product). Runs `f(chunk, r0, r1)` per worker;
/// serial — on the caller's thread, same semantics — when
/// [`effective_threads`] is 1, `rows < 2` (rows are the only split axis),
/// or `work < MIN_PARALLEL_ELEMS`.
pub fn for_row_chunks<T, F>(out: &mut [T], rows: usize, row_len: usize, work: usize, f: F)
where
    T: Send,
    F: Fn(&mut [T], usize, usize) + Sync,
{
    assert_eq!(out.len(), rows * row_len, "buffer is not rows × row_len");
    let threads = effective_threads();
    if threads == 1 || rows < 2 || work < MIN_PARALLEL_ELEMS {
        f(out, 0, rows);
        return;
    }
    let ranges = split_ranges(rows, threads);
    if ranges.len() <= 1 {
        f(out, 0, rows);
        return;
    }
    std::thread::scope(|scope| {
        let mut rest = out;
        for &(r0, r1) in &ranges {
            let (chunk, tail) = std::mem::take(&mut rest).split_at_mut((r1 - r0) * row_len);
            rest = tail;
            let fr = &f;
            scope.spawn(move || fr(chunk, r0, r1));
        }
    });
}

/// Fork-join over precomputed ranges with shared read-only context: runs
/// `f(range)` for every range concurrently (serially when
/// [`effective_threads`] is 1 or only one range is given). Unlike
/// [`for_each_chunk_mut`] nothing is borrowed mutably — writers coordinate
/// through interior mutability or disjoint outputs of their own.
pub fn join_chunks<F>(ranges: &[(usize, usize)], f: F)
where
    F: Fn((usize, usize)) + Sync,
{
    if effective_threads() == 1 || ranges.len() <= 1 {
        for &r in ranges {
            f(r);
        }
        return;
    }
    std::thread::scope(|scope| {
        for &r in ranges {
            let fr = &f;
            scope.spawn(move || fr(r));
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_covers_everything() {
        for n in [0usize, 1, 7, 64, 1000] {
            for w in [1usize, 2, 3, 8, 64] {
                let ranges = split_ranges(n, w);
                let total: usize = ranges.iter().map(|(a, b)| b - a).sum();
                assert_eq!(total, n, "n={n} w={w}");
                // Contiguous and ordered.
                let mut cursor = 0;
                for &(a, b) in &ranges {
                    assert_eq!(a, cursor);
                    assert!(b > a);
                    cursor = b;
                }
                assert!(ranges.len() <= w.max(1));
            }
        }
    }

    #[test]
    fn split_balances_within_one() {
        let ranges = split_ranges(10, 3);
        let lens: Vec<usize> = ranges.iter().map(|(a, b)| b - a).collect();
        assert_eq!(lens.iter().sum::<usize>(), 10);
        assert!(lens.iter().max().unwrap() - lens.iter().min().unwrap() <= 1);
    }

    #[test]
    fn chunked_map_touches_every_row_once() {
        // Large enough to take the parallel path on multi-core hosts.
        let rows = 512;
        let row_len = 256;
        let mut data = vec![0.0f32; rows * row_len];
        for_each_chunk_mut(&mut data, rows, row_len, |_idx, (r0, _r1), chunk| {
            for (local, row) in chunk.chunks_mut(row_len).enumerate() {
                let global = r0 + local;
                for v in row.iter_mut() {
                    *v += global as f32;
                }
            }
        });
        for r in 0..rows {
            assert!(data[r * row_len..(r + 1) * row_len].iter().all(|&v| v == r as f32));
        }
    }

    #[test]
    fn small_buffers_run_serially_with_full_range() {
        let mut data = vec![1.0f32; 8];
        let mut seen = Vec::new();
        // Single chunk ⇒ the closure must receive the whole range.
        for_each_chunk_mut(&mut data, 4, 2, |idx, range, chunk| {
            // Serial path: safe to capture mutably via a pointer-free check.
            assert_eq!(idx, 0);
            assert_eq!(range, (0, 4));
            assert_eq!(chunk.len(), 8);
        });
        seen.push(1);
        assert_eq!(seen.len(), 1);
    }

    #[test]
    fn join_chunks_runs_all_ranges() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let total = AtomicUsize::new(0);
        let ranges = split_ranges(100, 4);
        join_chunks(&ranges, |(a, b)| {
            total.fetch_add(b - a, Ordering::Relaxed);
        });
        assert_eq!(total.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn num_threads_is_stable_and_positive() {
        let a = num_threads();
        let b = num_threads();
        assert_eq!(a, b);
        assert!(a >= 1);
    }

    #[test]
    fn row_chunks_cover_buffer_exactly_once() {
        let (rows, row_len) = (300, 8);
        let mut data = vec![0.0f32; rows * row_len];
        // Work forced above the threshold so the parallel path runs on
        // multi-core hosts.
        for_row_chunks(&mut data, rows, row_len, MIN_PARALLEL_ELEMS, |chunk, r0, _r1| {
            for (local, row) in chunk.chunks_mut(row_len).enumerate() {
                for v in row.iter_mut() {
                    *v += (r0 + local) as f32;
                }
            }
        });
        for r in 0..rows {
            assert!(data[r * row_len..(r + 1) * row_len].iter().all(|&v| v == r as f32));
        }
    }

    #[test]
    fn kernel_serial_flag_is_per_thread() {
        assert!(!kernel_serial());
        set_kernel_serial(true);
        assert!(kernel_serial());
        assert_eq!(effective_threads(), 1);
        // Other threads are unaffected.
        std::thread::scope(|s| {
            s.spawn(|| assert!(!kernel_serial()));
        });
        set_kernel_serial(false);
        assert_eq!(effective_threads(), num_threads());
    }
}
